"""Experiment configurations for AOT lowering.

Every named config fully determines one artifact set
(``artifacts/<name>/*.hlo.txt`` + ``manifest.json``): network depth/width,
batch size, PCM-model ablation flags and fixed-point geometry are all baked
at lowering time.  Runtime-variable quantities (learning rate, simulated
wall-clock time, PRNG key) remain *inputs* of the lowered programs so the
Rust coordinator can drive schedules without re-lowering.

The config names mirror DESIGN.md §5 (experiment index).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class PcmConfig:
    """Parameters of the statistical PCM model (Nandakumar et al. 2018 style).

    Conductances are normalized to [0, 1] (1.0 == G_max, ~25 uS on silicon).
    The four non-idealities can be toggled independently; the FIG3 ablation
    lowers one artifact set per combination.
    """

    # -- programming curve -------------------------------------------------
    #: expected conductance increment of the first SET pulse (fraction of range)
    dg0: float = 0.10
    #: pulse-count scale of the saturating (nonlinear) programming curve:
    #: dG(n) = dg0 / (1 + n / n0).  Ignored when `nonlinear` is False.
    n0: float = 15.0
    #: enable the nonlinear programming curve (vs. constant-increment linear)
    nonlinear: bool = True

    # -- stochastic write ---------------------------------------------------
    #: std-dev of write noise, as a fraction of the applied increment
    write_sigma: float = 0.30
    write_noise: bool = True

    # -- stochastic read ----------------------------------------------------
    #: std-dev of instantaneous read noise (fraction of full conductance range)
    read_sigma: float = 0.009
    read_noise: bool = True

    # -- conductance drift ----------------------------------------------------
    #: mean drift exponent nu (G(t) = G_prog * (t/t0)^-nu)
    drift_nu: float = 0.031
    #: device-to-device std-dev of the drift exponent
    drift_nu_sigma: float = 0.007
    #: reference time t0 (s) after programming at which G_prog is defined
    drift_t0: float = 1.0
    drift: bool = True

    # -- binary (LSB-array) devices ------------------------------------------
    #: write noise std-dev for the binary high-conductance state
    binary_write_sigma: float = 0.05
    #: read threshold separating the two binary states
    binary_threshold: float = 0.5

    def ablation(self, *, nonlinear: bool, write: bool, read: bool,
                 drift: bool) -> "PcmConfig":
        """Return a copy with the four non-idealities toggled (FIG3)."""
        return dataclasses.replace(
            self, nonlinear=nonlinear, write_noise=write, read_noise=read,
            drift=drift)


@dataclass(frozen=True)
class HicConfig:
    """Hybrid weight representation geometry (paper Fig. 1).

    The MSB differential pair gives ~`msb_bits` of weight precision across
    [-w_max, w_max]; the LSB array is an `lsb_bits`-bit signed fixed-point
    accumulator whose overflow unit equals one MSB quantum.
    """

    #: equivalent precision of the multi-level differential pair
    msb_bits: int = 4
    #: signed fixed-point accumulator width (7 binary PCM devices)
    lsb_bits: int = 7
    #: weight clip range mapped onto the conductance window
    w_max: float = 1.0
    #: batches between MSB refresh operations (paper: every 10 batches)
    refresh_every: int = 10
    #: max SET pulses applied per programming event
    max_pulses: int = 10
    #: stochastically round quantized gradients (LFSR + comparator in the
    #: digital update unit) — avoids the +-lsb_step/2 dead zone
    stochastic_rounding: bool = True

    @property
    def msb_levels(self) -> int:
        return (1 << self.msb_bits) - 1  # 15 levels across the range

    @property
    def msb_step(self) -> float:
        """One MSB weight quantum (epsilon)."""
        return 2.0 * self.w_max / self.msb_levels

    @property
    def lsb_half_range(self) -> int:
        """Accumulator saturation magnitude (64 for 7-bit signed)."""
        return 1 << (self.lsb_bits - 1)

    @property
    def lsb_step(self) -> float:
        """Weight value of one accumulator count: epsilon / 2^(lsb_bits-1)."""
        return self.msb_step / self.lsb_half_range


@dataclass(frozen=True)
class AdcDacConfig:
    """Peripheral converter model (paper: 8-bit DAC / 8-bit ADC)."""

    dac_bits: int = 8
    adc_bits: int = 8
    #: input clip range for the DAC (activations / error gradients)
    dac_range: float = 4.0
    #: ADC full-scale range, in units of (x_range * w_max * sqrt(K)) — the
    #: column-current scale; calibrated per layer at mapping time.
    adc_range: float = 16.0
    enabled: bool = True


@dataclass(frozen=True)
class NetConfig:
    """CIFAR-style ResNet family (He et al.): depth = 6n+2, 3 stages."""

    depth: int = 8
    width_mult: float = 1.0
    num_classes: int = 10
    image_size: int = 32
    image_channels: int = 3
    bn_momentum: float = 0.99

    @property
    def blocks_per_stage(self) -> int:
        assert (self.depth - 2) % 6 == 0, "depth must be 6n+2"
        return (self.depth - 2) // 6

    @property
    def stage_widths(self) -> Tuple[int, int, int]:
        def w(c: int) -> int:
            return max(4, int(round(c * self.width_mult)))
        return (w(16), w(32), w(64))


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 32
    #: paper (HIC): lr 0.05, decay 0.45 at schedule boundaries
    lr: float = 0.05
    lr_decay: float = 0.45
    #: baseline: He et al. SGD-momentum settings
    base_lr: float = 0.1
    base_momentum: float = 0.9
    base_weight_decay: float = 1e-4
    #: simulated seconds of wall-clock per training batch (drift clock)
    seconds_per_batch: float = 0.05


@dataclass(frozen=True)
class ExperimentConfig:
    """One named, fully-baked artifact set."""

    name: str
    pcm: PcmConfig = PcmConfig()
    hic: HicConfig = HicConfig()
    adc: AdcDacConfig = AdcDacConfig()
    net: NetConfig = NetConfig()
    train: TrainConfig = TrainConfig()
    #: lower the FP32 baseline entry points for this config too
    with_baseline: bool = False

    def describe(self) -> Dict:
        return {
            "name": self.name,
            "pcm": dataclasses.asdict(self.pcm),
            "hic": dataclasses.asdict(self.hic),
            "adc": dataclasses.asdict(self.adc),
            "net": dataclasses.asdict(self.net),
            "train": dataclasses.asdict(self.train),
            "with_baseline": self.with_baseline,
        }


# ---------------------------------------------------------------------------
# Named experiment sets (DESIGN.md §5)
# ---------------------------------------------------------------------------

def _fig3_variants() -> List[ExperimentConfig]:
    """FIG3: PCM non-ideality ablation (paper Fig. 3 bar order)."""
    base = ExperimentConfig(name="_", with_baseline=False)
    combos = [
        # (tag, nonlinear, write, read, drift)
        ("linear", False, False, False, False),
        ("linear_write", False, True, False, False),
        ("linear_read", False, False, True, False),
        ("linear_drift", False, False, False, True),
        ("nonlinear", True, False, False, False),
        ("nonlinear_write", True, True, False, False),
        ("nonlinear_read", True, False, True, False),
        ("full", True, True, True, True),
    ]
    out = []
    for tag, nl, w, r, d in combos:
        out.append(dataclasses.replace(
            base,
            name=f"fig3_{tag}",
            pcm=base.pcm.ablation(nonlinear=nl, write=w, read=r, drift=d),
            # FP32 reference lowered once alongside the first variant
            with_baseline=(tag == "linear"),
        ))
    return out


def _fig4_variants() -> List[ExperimentConfig]:
    """FIG4: width-multiplier sweep, HIC (full PCM model) vs FP32 baseline."""
    out = []
    for wm in (0.5, 0.75, 1.0, 1.5):
        out.append(ExperimentConfig(
            name=f"fig4_hic_w{_wtag(wm)}",
            net=NetConfig(width_mult=wm),
        ))
    for wm in (0.25, 0.5, 0.75, 1.0):
        out.append(ExperimentConfig(
            name=f"fig4_base_w{_wtag(wm)}",
            net=NetConfig(width_mult=wm),
            with_baseline=True,
        ))
    return out


def _wtag(wm: float) -> str:
    return str(wm).replace(".", "p")


def all_configs() -> Dict[str, ExperimentConfig]:
    cfgs: List[ExperimentConfig] = []

    # Core config: default training/eval/quickstart + FIG5 drift study +
    # FIG6 endurance ledger all run from this artifact set.
    cfgs.append(ExperimentConfig(name="core", with_baseline=True))

    # A deliberately tiny config for CI-grade integration tests and the
    # runtime benchmarks: depth 8, width 0.25, batch 8.
    cfgs.append(ExperimentConfig(
        name="tiny",
        net=NetConfig(depth=8, width_mult=0.25),
        train=TrainConfig(batch_size=8),
        with_baseline=True,
    ))

    # FIG5 uses a wider network (paper: width 1.7); scaled default 1.5.
    cfgs.append(ExperimentConfig(
        name="fig5_drift",
        net=NetConfig(width_mult=1.5),
    ))

    cfgs.extend(_fig3_variants())
    cfgs.extend(_fig4_variants())

    return {c.name: c for c in cfgs}


#: Artifact sets built by a bare `make artifacts` (the rest are built by
#: `make artifacts-all` or on demand by `aot.py --sets ...`).
CORE_SETS = ("core", "tiny")

SET_GROUPS: Dict[str, List[str]] = {
    "core": ["core", "tiny"],
    "fig3": [c.name for c in _fig3_variants()],
    "fig4": [c.name for c in _fig4_variants()],
    "fig5": ["fig5_drift"],
    "all": sorted(all_configs().keys()),
}
