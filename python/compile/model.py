"""Layer-2 entry points lowered by aot.py (build-time only).

Every function here is a pure ``state in -> state out`` JAX program over an
explicit pytree of device/network state (no Python on the request path).
The Rust coordinator drives training by calling the lowered artifacts:

  hic_init(key)                                        -> state
  hic_train_step(state, x, y, key, t_now, lr)          -> state', metrics
  hic_eval_step(state, x, y, key, t_now)               -> (correct, loss_sum)
  hic_refresh(state, key, t_now)                       -> state', refreshed
  hic_adabs(state, x, key, t_now, kth)                 -> state'
  baseline_init(key)                                   -> bstate
  baseline_train_step(bstate, x, y, lr)                -> bstate', metrics
  baseline_eval_step(bstate, x, y)                     -> (correct, loss_sum)
  crossbar_vmm(x, w, noise)                            -> y   (L1 microbench)

Runtime-schedulable quantities (learning rate, simulated time, PRNG key)
are *inputs*; everything structural (depth, width, batch size, PCM
ablation flags) is baked per config by aot.py.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import hic, pcm_model, resnet
from .configs import ExperimentConfig
from .kernels.pcm_vmm import TPU_BLOCK, dac_quantize, pcm_vmm


# ---------------------------------------------------------------------------
# HIC state pytree
# ---------------------------------------------------------------------------

def hic_init_fn(cfg: ExperimentConfig):
    net, pcm, hcfg = cfg.net, cfg.pcm, cfg.hic
    specs = resnet.layer_specs(net)

    def init(key: jnp.ndarray) -> Dict:
        key = _as_key(key)
        kw, *kls = jax.random.split(key, 1 + len(specs))
        w0 = resnet.he_init_weights(kw, net)
        layers = []
        for k, w in zip(kls, w0):
            w = jnp.clip(w, -hcfg.w_max, hcfg.w_max)
            layers.append(_layer_to_dict(hic.init_layer(k, w, 0.0, pcm, hcfg)))
        bn_params, bn_stats = resnet.init_bn(net)
        return {"layers": layers, "bn_params": bn_params,
                "bn_stats": bn_stats}

    return init


def _as_key(raw: jnp.ndarray) -> jax.Array:
    """u32[2] input array -> typed PRNG key."""
    return jax.random.wrap_key_data(raw.astype(jnp.uint32),
                                    impl="threefry2x32")


def _layer_to_dict(st: hic.HicLayerState) -> Dict:
    """Nested-dict pytree view (readable leaf names in the manifest)."""
    return {
        "pcm_p": st.pcm_p._asdict(),
        "pcm_m": st.pcm_m._asdict(),
        "lsb": st.lsb,
        "lsb_flips": st.lsb_flips,
        "lsb_resets": st.lsb_resets,
    }


def _layer_states(state: Dict) -> List[hic.HicLayerState]:
    return [hic.HicLayerState(
        pcm_p=pcm_model.PcmArrays(**l["pcm_p"]),
        pcm_m=pcm_model.PcmArrays(**l["pcm_m"]),
        lsb=l["lsb"], lsb_flips=l["lsb_flips"], lsb_resets=l["lsb_resets"])
        for l in state["layers"]]


def hic_train_step_fn(cfg: ExperimentConfig):
    net, pcm, hcfg, adc = cfg.net, cfg.pcm, cfg.hic, cfg.adc
    specs = resnet.layer_specs(net)
    n_layers = len(specs)
    momentum = net.bn_momentum

    def train_step(state: Dict, x: jnp.ndarray, y: jnp.ndarray,
                   key: jnp.ndarray, t_now: jnp.ndarray,
                   lr: jnp.ndarray):
        key = _as_key(key)
        layers = _layer_states(state)
        k_noise, k_write = jax.random.split(key)
        nkeys = jax.random.split(k_noise, 2 * n_layers)
        wkeys = jax.random.split(k_write, n_layers)

        weights = [hic.read_weights(st, t_now, pcm, hcfg) for st in layers]
        noises = [
            (hic.sample_read_noise(nkeys[2 * i], w.shape, pcm, hcfg),
             hic.sample_read_noise(nkeys[2 * i + 1], w.shape, pcm, hcfg))
            for i, w in enumerate(weights)
        ]

        def loss_fn(ws, bn_params):
            logits, moments = resnet.forward(
                ws, bn_params, state["bn_stats"], x, noises, net, adc,
                train=True)
            return resnet.cross_entropy(logits, y), (logits, moments)

        (loss, (logits, moments)), (gw, gbn) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                weights, state["bn_params"])

        # --- in-memory HIC update of every crossbar weight ---------------
        new_layers = []
        ovf_total = jnp.float32(0.0)
        for st, dw, wk in zip(layers, gw, wkeys):
            st2, ovf = hic.apply_update(st, dw, lr, t_now, wk, pcm, hcfg)
            new_layers.append(_layer_to_dict(st2))
            ovf_total = ovf_total + ovf

        # --- digital updates: BN parameters (SGD) + running stats --------
        bn_params = {k: v - lr * gbn[k]
                     for k, v in state["bn_params"].items()}
        bn_stats = dict(state["bn_stats"])
        for name, (mean, var) in moments.items():
            bn_stats[f"mean_{name}"] = (momentum * bn_stats[f"mean_{name}"]
                                        + (1 - momentum) * mean)
            bn_stats[f"var_{name}"] = (momentum * bn_stats[f"var_{name}"]
                                       + (1 - momentum) * var)

        new_state = {"layers": new_layers, "bn_params": bn_params,
                     "bn_stats": bn_stats}
        metrics = {
            "loss": loss,
            "acc": resnet.accuracy(logits, y),
            "overflow_events": ovf_total,
            "grad_norm": _global_norm(gw),
        }
        return new_state, metrics

    return train_step


def _global_norm(trees) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(trees)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in leaves))


def hic_eval_step_fn(cfg: ExperimentConfig):
    net, pcm, hcfg, adc = cfg.net, cfg.pcm, cfg.hic, cfg.adc
    n_layers = len(resnet.layer_specs(net))

    def eval_step(state: Dict, x: jnp.ndarray, y: jnp.ndarray,
                  key: jnp.ndarray, t_now: jnp.ndarray):
        key = _as_key(key)
        layers = _layer_states(state)
        nkeys = jax.random.split(key, 2 * n_layers)
        weights = [hic.read_weights(st, t_now, pcm, hcfg) for st in layers]
        noises = [
            (hic.sample_read_noise(nkeys[2 * i], w.shape, pcm, hcfg),
             hic.sample_read_noise(nkeys[2 * i + 1], w.shape, pcm, hcfg))
            for i, w in enumerate(weights)
        ]
        logits, _ = resnet.forward(
            weights, state["bn_params"], state["bn_stats"], x, noises, net,
            adc, train=False)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
        loss_sum = resnet.cross_entropy(logits, y) * x.shape[0]
        return correct, loss_sum

    return eval_step


def hic_refresh_fn(cfg: ExperimentConfig):
    net, pcm, hcfg = cfg.net, cfg.pcm, cfg.hic
    n_layers = len(resnet.layer_specs(net))

    def refresh(state: Dict, key: jnp.ndarray, t_now: jnp.ndarray):
        key = _as_key(key)
        layers = _layer_states(state)
        keys = jax.random.split(key, n_layers)
        new_layers = []
        refreshed = jnp.float32(0.0)
        for st, k in zip(layers, keys):
            st2, n = hic.refresh(st, t_now, k, pcm, hcfg)
            new_layers.append(_layer_to_dict(st2))
            refreshed = refreshed + n
        new_state = {"layers": new_layers, "bn_params": state["bn_params"],
                     "bn_stats": state["bn_stats"]}
        return new_state, refreshed

    return refresh


def hic_adabs_fn(cfg: ExperimentConfig):
    """One AdaBS calibration batch (Joshi et al. 2020).

    The coordinator streams K calibration batches (~5 % of the training
    set); the k-th call folds the drifted-forward batch moments into the
    running statistics with weight 1/k, so after K calls the stats equal
    the plain average of the K batch moments.
    """
    net, pcm, hcfg, adc = cfg.net, cfg.pcm, cfg.hic, cfg.adc
    n_layers = len(resnet.layer_specs(net))

    def adabs(state: Dict, x: jnp.ndarray, key: jnp.ndarray,
              t_now: jnp.ndarray, kth: jnp.ndarray):
        key = _as_key(key)
        layers = _layer_states(state)
        nkeys = jax.random.split(key, 2 * n_layers)
        weights = [hic.read_weights(st, t_now, pcm, hcfg) for st in layers]
        noises = [
            (hic.sample_read_noise(nkeys[2 * i], w.shape, pcm, hcfg),
             hic.sample_read_noise(nkeys[2 * i + 1], w.shape, pcm, hcfg))
            for i, w in enumerate(weights)
        ]
        _, moments = resnet.forward(
            weights, state["bn_params"], state["bn_stats"], x, noises, net,
            adc, train=True)
        w_new = 1.0 / jnp.maximum(kth, 1.0)
        bn_stats = dict(state["bn_stats"])
        for name, (mean, var) in moments.items():
            bn_stats[f"mean_{name}"] = ((1 - w_new)
                                        * bn_stats[f"mean_{name}"]
                                        + w_new * mean)
            bn_stats[f"var_{name}"] = ((1 - w_new) * bn_stats[f"var_{name}"]
                                       + w_new * var)
        return {"layers": state["layers"], "bn_params": state["bn_params"],
                "bn_stats": bn_stats}

    return adabs


# ---------------------------------------------------------------------------
# FP32 software baseline (SGD + momentum + weight decay, exact matmuls)
# ---------------------------------------------------------------------------

def baseline_init_fn(cfg: ExperimentConfig):
    net = cfg.net

    def init(key: jnp.ndarray) -> Dict:
        key = _as_key(key)
        w = resnet.he_init_weights(key, net)
        bn_params, bn_stats = resnet.init_bn(net)
        return {
            "weights": w,
            "vel": [jnp.zeros_like(x) for x in w],
            "bn_params": bn_params,
            "bn_vel": {k: jnp.zeros_like(v) for k, v in bn_params.items()},
            "bn_stats": bn_stats,
        }

    return init


def baseline_train_step_fn(cfg: ExperimentConfig):
    net, adc, tr = cfg.net, cfg.adc, cfg.train
    mu, wd = tr.base_momentum, tr.base_weight_decay
    momentum = net.bn_momentum

    def train_step(state: Dict, x: jnp.ndarray, y: jnp.ndarray,
                   lr: jnp.ndarray):
        def loss_fn(ws, bn_params):
            logits, moments = resnet.forward(
                ws, bn_params, state["bn_stats"], x, None, net, adc,
                train=True, matmul_fn=resnet.exact_matmul)
            return resnet.cross_entropy(logits, y), (logits, moments)

        (loss, (logits, moments)), (gw, gbn) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(
                state["weights"], state["bn_params"])

        new_w, new_v = [], []
        for w, v, g in zip(state["weights"], state["vel"], gw):
            g = g + wd * w
            v = mu * v + g
            new_v.append(v)
            new_w.append(w - lr * v)

        bn_params, bn_vel = {}, {}
        for k, p in state["bn_params"].items():
            g = gbn[k]
            v = mu * state["bn_vel"][k] + g
            bn_vel[k] = v
            bn_params[k] = p - lr * v

        bn_stats = dict(state["bn_stats"])
        for name, (mean, var) in moments.items():
            bn_stats[f"mean_{name}"] = (momentum * bn_stats[f"mean_{name}"]
                                        + (1 - momentum) * mean)
            bn_stats[f"var_{name}"] = (momentum * bn_stats[f"var_{name}"]
                                       + (1 - momentum) * var)

        new_state = {"weights": new_w, "vel": new_v, "bn_params": bn_params,
                     "bn_vel": bn_vel, "bn_stats": bn_stats}
        metrics = {"loss": loss, "acc": resnet.accuracy(logits, y)}
        return new_state, metrics

    return train_step


def baseline_eval_step_fn(cfg: ExperimentConfig):
    net, adc = cfg.net, cfg.adc

    def eval_step(state: Dict, x: jnp.ndarray, y: jnp.ndarray):
        logits, _ = resnet.forward(
            state["weights"], state["bn_params"], state["bn_stats"], x,
            None, net, adc, train=False, matmul_fn=resnet.exact_matmul)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
        loss_sum = resnet.cross_entropy(logits, y) * x.shape[0]
        return correct, loss_sum

    return eval_step


# ---------------------------------------------------------------------------
# Standalone L1 microbench artifact
# ---------------------------------------------------------------------------

def crossbar_vmm_fn(cfg: ExperimentConfig):
    adc = cfg.adc

    def vmm(x: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray):
        # Faithful crossbar/MXU tiling (128^3) — this artifact is the
        # L1 perf/cross-validation target, not a simulation shortcut.
        return (pcm_vmm(dac_quantize(x, adc), w, noise, adc,
                        block=TPU_BLOCK),)

    return vmm
