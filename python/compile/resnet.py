"""CIFAR-style ResNet family (He et al. 2015 §4.2) on crossbar VMMs.

Depth = 6n+2: one 3x3 stem conv, three stages of n basic blocks at widths
(16, 32, 64) x width_mult, strided at stage entry, identity (option-A,
parameter-free) shortcuts, global average pool, one FC classifier.
ResNet-8 -> n=1 (paper experiments scaled); ResNet-32 -> n=5 (paper
configuration, accepted unchanged).

Every conv/FC weight is crossbar-mapped: convs run as im2col x
`crossbar_matmul` (the custom-VJP wrapper around the Layer-1 Pallas VMM
kernel), which gives the paper's semantics on both passes:

  forward : y  = ADC( DAC(x_col) @ (W_eff + read-noise_f) )
  backward: dx = ADC( DAC(dy)    @ (W_eff + read-noise_b)^T ) (transposed
            crossbar read with *independent* read noise), and
            dW = DAC(x_col)^T @ dy computed digitally (the outer-product
            unit of Fig. 2) — exact, fed to the LSB accumulator.

BatchNorm runs digitally (paper: all non-VMM ops in CMOS); its running
statistics are explicit state so the coordinator's AdaBS pass can
recalibrate them (Fig. 5).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import AdcDacConfig, NetConfig
from .kernels.pcm_vmm import dac_quantize, pcm_vmm
from .kernels.ref import quantize_uniform_ref


# ---------------------------------------------------------------------------
# Crossbar matmul with the paper's backward semantics
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def crossbar_matmul(x, w, noise_f, noise_b, adc: AdcDacConfig):
    """``ADC(DAC(x) @ (w + noise_f))`` with transposed-crossbar backward."""
    return pcm_vmm(dac_quantize(x, adc), w, noise_f, adc)


def _cbm_fwd(x, w, noise_f, noise_b, adc: AdcDacConfig):
    xq = dac_quantize(x, adc)
    y = pcm_vmm(xq, w, noise_f, adc)
    return y, (xq, w, noise_b)


def _cbm_bwd(adc: AdcDacConfig, res, dy):
    xq, w, noise_b = res
    # Backpropagation VMM on the transposed crossbar.  Error gradients are
    # dynamically range-scaled before the 8-bit DAC (standard practice for
    # mixed-signal training periphery) so quantization tracks their decaying
    # magnitude across training.
    scale = jnp.maximum(jnp.max(jnp.abs(dy)), 1e-12)
    if adc.enabled:
        dyq = quantize_uniform_ref(dy / scale, adc.dac_bits, 1.0)
    else:
        dyq = dy / scale
    dx = pcm_vmm(dyq, w.T, noise_b.T, adc) * scale
    # Digital outer-product unit: exact gradient w.r.t. the crossbar weights.
    dw = xq.T @ dy
    return dx, dw, None, None


crossbar_matmul.defvjp(_cbm_fwd, _cbm_bwd)


def exact_matmul(x, w, noise_f, noise_b, adc):
    """FP32 baseline path — plain matmul, signature-compatible."""
    return x @ w


# ---------------------------------------------------------------------------
# Layer shapes
# ---------------------------------------------------------------------------

class ConvSpec(NamedTuple):
    name: str
    kh: int
    kw: int
    cin: int
    cout: int
    stride: int

    @property
    def k_dim(self) -> int:
        return self.kh * self.kw * self.cin

    @property
    def weight_shape(self) -> Tuple[int, int]:
        """Crossbar-mapped 2-D shape [K, N]."""
        return (self.k_dim, self.cout)

    @property
    def num_weights(self) -> int:
        return self.k_dim * self.cout


def layer_specs(net: NetConfig) -> List[ConvSpec]:
    """All crossbar-mapped weight tensors of the network, in forward order.

    The final FC classifier is included as a 1x1 'conv' over the pooled
    feature vector — on hardware it is simply one more crossbar.
    """
    w1, w2, w3 = net.stage_widths
    n = net.blocks_per_stage
    specs: List[ConvSpec] = [
        ConvSpec("stem", 3, 3, net.image_channels, w1, 1)]
    cin = w1
    for si, cout in enumerate((w1, w2, w3)):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            specs.append(ConvSpec(f"s{si}b{bi}c1", 3, 3, cin, cout, stride))
            specs.append(ConvSpec(f"s{si}b{bi}c2", 3, 3, cout, cout, 1))
            cin = cout
    specs.append(ConvSpec("fc", 1, 1, w3, net.num_classes, 1))
    return specs


def bn_channels(net: NetConfig) -> List[Tuple[str, int]]:
    """(name, channels) of every BatchNorm, aligned with layer_specs()[:-1]
    (each conv is followed by a BN; the FC classifier has none)."""
    return [(s.name, s.cout) for s in layer_specs(net)[:-1]]


def num_weights(net: NetConfig) -> int:
    return sum(s.num_weights for s in layer_specs(net))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _im2col(x: jnp.ndarray, spec: ConvSpec) -> Tuple[jnp.ndarray,
                                                     Tuple[int, int, int]]:
    """NHWC -> [B*OH*OW, kh*kw*cin] patches (SAME padding)."""
    b = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(spec.kh, spec.kw),
        window_strides=(spec.stride, spec.stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, oh, ow, kdim = patches.shape
    assert kdim == spec.k_dim, (patches.shape, spec)
    return patches.reshape(b * oh * ow, kdim), (b, oh, ow)


def conv(x: jnp.ndarray, w2d: jnp.ndarray, spec: ConvSpec,
         noise_f: jnp.ndarray, noise_b: jnp.ndarray, adc: AdcDacConfig,
         matmul_fn) -> jnp.ndarray:
    cols, (b, oh, ow) = _im2col(x, spec)
    y = matmul_fn(cols, w2d, noise_f, noise_b, adc)
    return y.reshape(b, oh, ow, spec.cout)


def batch_norm(x: jnp.ndarray, gamma, beta, mean, var, *, eps: float = 1e-5):
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean) * inv * gamma + beta


def batch_moments(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-channel moments over (B, H, W) of an NHWC tensor."""
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    return mean, var


def _shortcut(x: jnp.ndarray, cout: int, stride: int) -> jnp.ndarray:
    """Option-A identity shortcut: stride subsample + zero-pad channels."""
    if stride != 1:
        x = x[:, ::stride, ::stride, :]
    cin = x.shape[-1]
    if cin < cout:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cout - cin)))
    elif cin > cout:  # width multipliers can round stages non-monotonically
        x = x[..., :cout]
    return x


def forward(weights: List[jnp.ndarray], bn_params: Dict[str, jnp.ndarray],
            bn_stats: Dict[str, jnp.ndarray], x: jnp.ndarray,
            noises: Optional[List[Tuple[jnp.ndarray, jnp.ndarray]]],
            net: NetConfig, adc: AdcDacConfig, *, train: bool,
            matmul_fn=crossbar_matmul):
    """Run the network.

    Args:
      weights:  effective 2-D crossbar weights, order of `layer_specs`.
      bn_params: {'gamma_<name>', 'beta_<name>'} digital parameters.
      bn_stats:  {'mean_<name>', 'var_<name>'} running statistics.
      noises:    per layer (noise_f, noise_b) read-noise operands
                 (None -> zeros, e.g. for the FP32 baseline).
      train:     True -> normalize with batch moments and return them.

    Returns (logits, new_batch_moments) where new_batch_moments maps
    '<name>' -> (mean, var) (empty dict when train=False).
    """
    specs = layer_specs(net)
    moments: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}

    def zeros_like_w(w):
        return jnp.zeros_like(w)

    def layer_noise(i, w):
        if noises is None:
            return zeros_like_w(w), zeros_like_w(w)
        return noises[i]

    def bn_apply(h, name):
        gamma = bn_params[f"gamma_{name}"]
        beta = bn_params[f"beta_{name}"]
        if train:
            mean, var = batch_moments(h)
            moments[name] = (mean, var)
        else:
            mean = bn_stats[f"mean_{name}"]
            var = bn_stats[f"var_{name}"]
        return batch_norm(h, gamma, beta, mean, var)

    # Stem
    nf, nb = layer_noise(0, weights[0])
    h = conv(x, weights[0], specs[0], nf, nb, adc, matmul_fn)
    h = jax.nn.relu(bn_apply(h, "stem"))

    # Residual stages
    li = 1
    for si in range(3):
        for bi in range(net.blocks_per_stage):
            s1, s2 = specs[li], specs[li + 1]
            idn = _shortcut(h, s2.cout, s1.stride)
            nf, nb = layer_noise(li, weights[li])
            y = conv(h, weights[li], s1, nf, nb, adc, matmul_fn)
            y = jax.nn.relu(bn_apply(y, s1.name))
            nf, nb = layer_noise(li + 1, weights[li + 1])
            y = conv(y, weights[li + 1], s2, nf, nb, adc, matmul_fn)
            y = bn_apply(y, s2.name)
            h = jax.nn.relu(y + idn)
            li += 2

    # Head: global average pool + FC crossbar
    pooled = jnp.mean(h, axis=(1, 2))  # [B, w3]
    fc_spec = specs[-1]
    nf, nb = layer_noise(len(specs) - 1, weights[-1])
    logits = matmul_fn(pooled, weights[-1], nf, nb, adc)
    assert logits.shape[-1] == net.num_classes, (logits.shape, fc_spec)
    return logits, moments


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                    .astype(jnp.float32))


def he_init_weights(key: jax.Array, net: NetConfig,
                    scale: float = 1.0) -> List[jnp.ndarray]:
    """Kaiming-normal init for every crossbar weight (2-D [K, N] layout)."""
    specs = layer_specs(net)
    keys = jax.random.split(key, len(specs))
    out = []
    for k, s in zip(keys, specs):
        std = scale * (2.0 / s.k_dim) ** 0.5
        out.append(std * jax.random.normal(k, s.weight_shape))
    return out


def init_bn(net: NetConfig) -> Tuple[Dict[str, jnp.ndarray],
                                     Dict[str, jnp.ndarray]]:
    params: Dict[str, jnp.ndarray] = {}
    stats: Dict[str, jnp.ndarray] = {}
    for name, c in bn_channels(net):
        params[f"gamma_{name}"] = jnp.ones((c,), jnp.float32)
        params[f"beta_{name}"] = jnp.zeros((c,), jnp.float32)
        stats[f"mean_{name}"] = jnp.zeros((c,), jnp.float32)
        stats[f"var_{name}"] = jnp.ones((c,), jnp.float32)
    return params, stats
