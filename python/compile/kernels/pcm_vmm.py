"""Layer-1 Pallas kernel: the analog crossbar vector-matrix multiply.

One crossbar *tile* is one Pallas grid step.  The mapping from the paper's
analog array to a TPU-style kernel (DESIGN.md §3, Hardware-Adaptation):

  paper crossbar tile (<=128x128 differential PCM pairs)
      -> one (bm x bn) MXU-shaped block held in VMEM
  DAC row drivers streaming quantized activations
      -> the HBM->VMEM BlockSpec schedule of the `x` operand
  analog column-current MAC
      -> `jnp.dot` on the block (MXU systolic array on real TPU)
  per-read conductance noise (stochastic read, drift applied upstream)
      -> an f32 noise operand streamed with the same schedule as `w`
  ADC at each column
      -> clip + uniform quantization epilogue on the accumulated tile

The kernel is **deterministic**: all stochasticity (read noise) is drawn in
Layer-2 with an explicit PRNG key and passed in as the `noise` operand.
This makes the kernel exactly checkable against the pure-jnp oracle in
`ref.py` (assert_allclose at f32 resolution) and keeps AOT lowering free of
RNG state.

interpret=True is mandatory on this image: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Interpret-mode lowers the
grid to a `stablehlo.while` loop, so artifact size is O(kernel body), not
O(grid).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import AdcDacConfig

# Default SIMULATION block sizes.  The *hardware* mapping is one crossbar
# tile = one 128x128 MXU block (see `TPU_BLOCK` and DESIGN.md
# §Hardware-Adaptation); but because the kernel is deterministic and the
# ADC epilogue acts on the fully-accumulated output, tiling granularity
# does not change the math — only the interpret-mode execution speed.
# CPU-PJRT runs the grid as a sequential while-loop, so the training
# artifacts use large blocks (few iterations); the `crossbar_vmm`
# microbench artifact pins the faithful 128^3 TPU tiling.
DEFAULT_BLOCK_M = 4096
DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_K = 2048

#: the faithful TPU/crossbar tiling (MXU-native tile edge)
TPU_BLOCK = (128, 128, 128)


def _quantize_uniform(v: jnp.ndarray, bits: int, vmax: float) -> jnp.ndarray:
    """Mid-rise uniform quantizer over [-vmax, vmax] with 2^bits levels."""
    levels = (1 << bits) - 1
    step = 2.0 * vmax / levels
    v = jnp.clip(v, -vmax, vmax)
    return jnp.round(v / step) * step


def dac_quantize(x: jnp.ndarray, adc: AdcDacConfig) -> jnp.ndarray:
    """The row DAC: quantize activations/error-gradients to dac_bits."""
    if not adc.enabled:
        return x
    return _quantize_uniform(x, adc.dac_bits, adc.dac_range)


def _vmm_kernel(x_ref, w_ref, noise_ref, o_ref, *,
                n_k: int, adc_bits: int, adc_range: float, adc_enabled: bool):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (fastest) dimension and
    the output block index is independent of k, so the (bm x bn) output tile
    stays resident in VMEM across the whole K sweep and doubles as the
    accumulator (the standard Pallas matmul revisiting pattern)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Analog MAC of one crossbar tile + its per-read conductance noise.
    # Noise enters as an equivalent weight perturbation: x @ (w + eta).
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...] + noise_ref[...],
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = o_ref[...]
        if adc_enabled:
            # Column ADC: clip to full-scale range, quantize to adc_bits.
            levels = (1 << adc_bits) - 1
            step = 2.0 * adc_range / levels
            acc = jnp.clip(acc, -adc_range, adc_range)
            acc = jnp.round(acc / step) * step
        o_ref[...] = acc


def _pad_to(v: jnp.ndarray, m: int, axis: int) -> jnp.ndarray:
    r = v.shape[axis] % m
    if r == 0:
        return v
    pad = [(0, 0)] * v.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(v, pad)


def pcm_vmm(x: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray,
            adc: AdcDacConfig,
            block: Tuple[int, int, int] = (DEFAULT_BLOCK_M,
                                           DEFAULT_BLOCK_N,
                                           DEFAULT_BLOCK_K)) -> jnp.ndarray:
    """Crossbar VMM: ``ADC( DAC(x) @ (w + noise) )``, tiled.

    Args:
      x:     f32[M, K] — already DAC-quantized activations (see
             `dac_quantize`; kept outside the kernel so the same quantized
             values feed the digital outer-product in the update phase,
             exactly as the architecture shares the DAC output bus).
      w:     f32[K, N] — effective weights read from the MSB array
             (drift applied upstream; this operand is the *expected* read).
      noise: f32[K, N] — per-read stochastic-read perturbation, in weight
             units (zero when the config disables read noise).
      adc:   converter geometry; ADC epilogue applied per output element.

    Returns f32[M, N].
    """
    assert x.ndim == 2 and w.ndim == 2 and noise.shape == w.shape
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bm, bn, bk = block
    bm = min(bm, _ceil_pow2(m))
    bn = min(bn, _ceil_pow2(n))
    bk = min(bk, _ceil_pow2(k))

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    np_ = _pad_to(_pad_to(noise, bk, 0), bn, 1)
    mp, kp = xp.shape
    _, npad = wp.shape
    grid = (mp // bm, npad // bn, kp // bk)

    kernel = functools.partial(
        _vmm_kernel,
        n_k=grid[2],
        adc_bits=adc.adc_bits,
        adc_range=adc.adc_range,
        adc_enabled=adc.enabled,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, npad), jnp.float32),
        interpret=True,
    )(xp, wp, np_)
    return out[:m, :n]


def _ceil_pow2(v: int) -> int:
    p = 1
    while p < v:
        p <<= 1
    return p


def vmem_footprint_bytes(block: Tuple[int, int, int]) -> int:
    """Estimated VMEM residency of one grid step (perf model, DESIGN §7):
    x-tile + w-tile + noise-tile + resident output/accumulator tile, f32."""
    bm, bn, bk = block
    return 4 * (bm * bk + 2 * bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, n: int, k: int,
                             block: Tuple[int, int, int]) -> float:
    """Fraction of MXU issue slots doing useful work for an (m,k)x(k,n)
    problem under this tiling — pure padding accounting (the analytical
    stand-in for real-TPU profiling; see DESIGN.md §7 L1)."""
    bm, bn, bk = block
    bm = min(bm, _ceil_pow2(m)); bn = min(bn, _ceil_pow2(n))
    bk = min(bk, _ceil_pow2(k))
    gm = -(-m // bm) * bm
    gn = -(-n // bn) * bn
    gk = -(-k // bk) * bk
    return (m * n * k) / float(gm * gn * gk)
