"""Layer-1 Pallas kernel: the LSB-array update (paper Fig. 2, update phase).

The LSB array is a 7-bit signed fixed-point accumulator per weight, stored
on seven binary PCM devices.  The digital update circuit:

  1. quantizes the weight gradient to accumulator counts
     ``delta = round(-lr * dW / lsb_step)``  (done in Layer-2; the kernel
     receives integer counts so it is exactly checkable),
  2. adds the counts into the accumulator,
  3. extracts the **overflow**: the number of whole MSB quanta
     (+-`half_range` counts) the accumulator moved past, leaving the
     remainder behind,
  4. reports per-bit flip activity of the binary devices (endurance).

Overflow uses round-toward-zero semantics so the sign of the residue always
matches the sign of the pre-overflow sum — matching a two's-complement
carry-out circuit and the Rust twin (`rust/src/hic/fixedpoint.rs`).

Everything is elementwise, so the kernel tiles trivially; blocks are sized
to VPU lanes rather than the MXU (no contraction here).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 262144  # elements per grid step (flattened view); large
# blocks keep the interpret-mode while-loop short (elementwise math is
# identical under any tiling)


def _lsb_kernel(acc_ref, delta_ref, acc_out_ref, ovf_ref, flips_ref, *,
                half_range: int, nbits: int):
    acc = acc_ref[...].astype(jnp.int32)
    delta = delta_ref[...].astype(jnp.int32)

    s = acc + delta
    # Round-toward-zero division by half_range = arithmetic shift with sign
    # correction; jnp int division truncates toward zero already.
    ovf = s // half_range + jnp.where((s % half_range != 0) & (s < 0), 1, 0)
    res = s - ovf * half_range
    # res is now in (-half_range, half_range); saturate defensively.
    res = jnp.clip(res, -half_range, half_range - 1)

    # Per-bit flip count of the two's-complement register (offset-encoded to
    # u(nbits)): devices whose stored bit changed were rewritten.
    old_u = (acc + half_range).astype(jnp.uint32)
    new_u = (res + half_range).astype(jnp.uint32)
    changed = old_u ^ new_u
    flips = jnp.zeros_like(acc)
    resets = jnp.zeros_like(acc)
    for b in range(nbits):
        bit = (changed >> b) & 1
        flips = flips + bit.astype(jnp.int32)
        # 1 -> 0 transitions are RESET pulses (the WE-cycle commit event).
        went_low = ((old_u >> b) & 1) & bit
        resets = resets + went_low.astype(jnp.int32)

    acc_out_ref[...] = res
    ovf_ref[...] = ovf
    # One packed word per weight keeps the artifact small: low 16 bits are
    # total device flips (SET+RESET writes), high bits are RESET events —
    # the quantity the WE-cycle ledger needs (Tuma et al. definition).
    flips_ref[...] = flips + (resets << 16)


def lsb_update(acc: jnp.ndarray, delta: jnp.ndarray, *, half_range: int,
               nbits: int,
               block: int = DEFAULT_BLOCK
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Accumulate integer gradient counts into the LSB array.

    Args:
      acc:   i32[...] — current accumulator counts in (-half_range, half_range)
      delta: i32[...] — quantized update counts
    Returns:
      (acc', overflow, flip_word) with the same shape:
        acc'      — residual counts
        overflow  — whole MSB quanta to program into the MSB array (signed)
        flip_word — low 16 bits: device flips (SET+RESET); high bits: RESETs
    """
    assert acc.shape == delta.shape
    shape = acc.shape
    flat = acc.reshape(-1)
    dflat = delta.reshape(-1)
    n = flat.shape[0]
    bs = min(block, _ceil_pow2(n))
    pad = (-n) % bs
    if pad:
        flat = jnp.pad(flat, (0, pad))
        dflat = jnp.pad(dflat, (0, pad))
    grid = (flat.shape[0] // bs,)

    kernel = functools.partial(_lsb_kernel, half_range=half_range,
                               nbits=nbits)
    acc2, ovf, flips = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,)),
                  pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((bs,), lambda i: (i,)),
                   pl.BlockSpec((bs,), lambda i: (i,)),
                   pl.BlockSpec((bs,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct(flat.shape, jnp.int32),
                   jax.ShapeDtypeStruct(flat.shape, jnp.int32),
                   jax.ShapeDtypeStruct(flat.shape, jnp.int32)],
        interpret=True,
    )(flat, dflat)
    return (acc2[:n].reshape(shape), ovf[:n].reshape(shape),
            flips[:n].reshape(shape))


def _ceil_pow2(v: int) -> int:
    p = 1
    while p < v:
        p <<= 1
    return p
