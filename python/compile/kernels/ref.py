"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the CORE correctness signal of the compile path: the kernels are
deterministic (all stochasticity enters as operands), so pytest asserts
*exact / f32-resolution* agreement between each kernel and its oracle over
hypothesis-style shape/value sweeps (python/tests/test_kernel.py).

The oracles are also what the Rust substrates' golden-vector tests are
generated from.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ..configs import AdcDacConfig


def quantize_uniform_ref(v: jnp.ndarray, bits: int,
                         vmax: float) -> jnp.ndarray:
    levels = (1 << bits) - 1
    step = 2.0 * vmax / levels
    return jnp.round(jnp.clip(v, -vmax, vmax) / step) * step


def pcm_vmm_ref(x: jnp.ndarray, w: jnp.ndarray, noise: jnp.ndarray,
                adc: AdcDacConfig) -> jnp.ndarray:
    """Oracle for kernels.pcm_vmm.pcm_vmm (x already DAC-quantized)."""
    out = x @ (w + noise)
    if adc.enabled:
        out = quantize_uniform_ref(out, adc.adc_bits, adc.adc_range)
    return out


def lsb_update_ref(acc: jnp.ndarray, delta: jnp.ndarray, *, half_range: int,
                   nbits: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.lsb_update.lsb_update."""
    acc = acc.astype(jnp.int32)
    delta = delta.astype(jnp.int32)
    s = acc + delta
    ovf = s // half_range + jnp.where((s % half_range != 0) & (s < 0), 1, 0)
    res = s - ovf * half_range
    res = jnp.clip(res, -half_range, half_range - 1)

    old_u = (acc + half_range).astype(jnp.uint32)
    new_u = (res + half_range).astype(jnp.uint32)
    changed = old_u ^ new_u
    flips = jnp.zeros_like(acc)
    resets = jnp.zeros_like(acc)
    for b in range(nbits):
        bit = (changed >> b) & 1
        flips = flips + bit.astype(jnp.int32)
        went_low = ((old_u >> b) & 1) & bit
        resets = resets + went_low.astype(jnp.int32)
    return res, ovf, flips + (resets << 16)


def unpack_flip_word(word: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split the packed flip word into (total_flips, reset_events)."""
    return word & 0xFFFF, word >> 16
