"""Statistical PCM device model (JAX, build-time only).

Functional re-implementation of the phase-change-memory model of
Nandakumar et al., *J. Appl. Phys.* 2018 ("A phase-change memory model for
neuromorphic computing"), as used by the HIC paper.  Four non-idealities,
each independently switchable (FIG3 ablation):

1. **Nonlinear programming curve** — the expected conductance increment of
   the n-th SET pulse decays as an inverse function of the accumulated
   pulse count: ``dG(n) = dg0 / (1 + n / n0)``.  The *linear* ablation uses
   a constant ``dg0``.
2. **Stochastic write** — every programming event adds Gaussian noise with
   std-dev proportional to the applied increment.
3. **Stochastic read** — every read adds zero-mean Gaussian noise
   (instantaneous 1/f + thermal noise lump).
4. **Conductance drift** — ``G(t) = G_prog * ((t - t_prog)/t0)^(-nu)`` with
   a per-device drift exponent ``nu ~ N(nu_mean, nu_sigma)``.

All conductances are normalized to [0, 1] == [0, G_max].  The model is
*pulse-aggregated*: a programming event that would take ``n`` SET pulses on
silicon is applied as one vectorized update whose expected increment equals
the sum of the per-pulse increments.  The Rust substrate
(``rust/src/pcm/device.rs``) implements the true pulse-by-pulse process and
the test suite cross-validates the aggregate statistics.

Everything here is pure-functional: device state arrays in, device state
arrays out, with explicit PRNG keys — mandatory for AOT lowering.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .configs import PcmConfig


class PcmArrays(NamedTuple):
    """Per-device state of one multi-level PCM array (any shape)."""

    g: jnp.ndarray        # f32 — programmed conductance (at t_prog, no drift)
    pulses: jnp.ndarray   # f32 — SET pulses accumulated since last RESET
    t_prog: jnp.ndarray   # f32 — time of last programming event (s)
    nu: jnp.ndarray       # f32 — per-device drift exponent
    set_count: jnp.ndarray    # i32 — lifetime SET pulse count (endurance)
    reset_count: jnp.ndarray  # i32 — lifetime RESET pulse count (endurance)


def init_arrays(key: jax.Array, shape: Tuple[int, ...],
                cfg: PcmConfig) -> PcmArrays:
    """Fresh (RESET) devices with per-device drift exponents."""
    nu = cfg.drift_nu + cfg.drift_nu_sigma * jax.random.normal(key, shape)
    nu = jnp.clip(nu, 0.0, 0.12)
    zf = jnp.zeros(shape, jnp.float32)
    zi = jnp.zeros(shape, jnp.int32)
    return PcmArrays(g=zf, pulses=zf, t_prog=zf, nu=nu,
                     set_count=zi, reset_count=zi)


# ---------------------------------------------------------------------------
# Programming (SET) — increment-only, like the hardware
# ---------------------------------------------------------------------------

def expected_increment(pulses: jnp.ndarray, n_new: jnp.ndarray,
                       cfg: PcmConfig) -> jnp.ndarray:
    """Expected total conductance gain of ``n_new`` SET pulses applied to a
    device that has already received ``pulses`` pulses since RESET.

    Nonlinear curve: sum_{i=0}^{n-1} dg0/(1 + (p+i)/n0)
      ~= dg0 * n0 * log((n0 + p + n) / (n0 + p))   (continuous aggregate)
    Linear curve:    dg0 * n
    """
    if cfg.nonlinear:
        return cfg.dg0 * cfg.n0 * jnp.log(
            (cfg.n0 + pulses + n_new) / (cfg.n0 + pulses))
    return cfg.dg0 * n_new


def pulses_for_target(pulses: jnp.ndarray, dg_target: jnp.ndarray,
                      cfg: PcmConfig, max_pulses: int) -> jnp.ndarray:
    """Number of SET pulses the (digital) write circuit schedules to move the
    conductance by ``dg_target`` >= 0, given the device's pulse history.

    The write circuit knows the *expected* curve (it was characterized), so
    it inverts the aggregate expression; stochasticity makes the realized
    increment differ.
    """
    if cfg.nonlinear:
        n = (cfg.n0 + pulses) * (jnp.exp(dg_target / (cfg.dg0 * cfg.n0)) - 1.0)
    else:
        n = dg_target / cfg.dg0
    n = jnp.ceil(n)
    return jnp.clip(jnp.where(dg_target > 0, jnp.maximum(n, 1.0), 0.0),
                    0.0, float(max_pulses))


def program_increment(arr: PcmArrays, dg_target: jnp.ndarray, t_now,
                      key: jax.Array, cfg: PcmConfig,
                      max_pulses: int) -> PcmArrays:
    """Apply an increment-only programming event towards ``dg_target >= 0``.

    Elements with ``dg_target == 0`` are untouched (no pulse, no noise, no
    t_prog update — their drift reference is preserved).
    """
    n = pulses_for_target(arr.pulses, dg_target, cfg, max_pulses)
    active = n > 0
    dg_mean = expected_increment(arr.pulses, n, cfg)
    if cfg.write_noise:
        noise = jax.random.normal(key, arr.g.shape)
        dg = dg_mean + cfg.write_sigma * dg_mean * noise
    else:
        dg = dg_mean
    dg = jnp.maximum(dg, 0.0)
    g_new = jnp.clip(arr.g + dg, 0.0, 1.0)
    t_now = jnp.asarray(t_now, jnp.float32)
    return PcmArrays(
        g=jnp.where(active, g_new, arr.g),
        pulses=arr.pulses + n,
        t_prog=jnp.where(active, t_now, arr.t_prog),
        nu=arr.nu,
        set_count=arr.set_count + n.astype(jnp.int32),
        reset_count=arr.reset_count,
    )


def reset(arr: PcmArrays, t_now, mask: jnp.ndarray) -> PcmArrays:
    """RESET the masked devices to the low-conductance state.

    Counts one RESET pulse per masked device — the endurance ledger's
    write–erase cycle accounting (Tuma et al.: a WE cycle is <=10 SETs
    followed by a RESET) is derived from (set_count, reset_count) by the
    Rust `pcm::endurance` module.
    """
    t_now = jnp.asarray(t_now, jnp.float32)
    return PcmArrays(
        g=jnp.where(mask, 0.0, arr.g),
        pulses=jnp.where(mask, 0.0, arr.pulses),
        t_prog=jnp.where(mask, t_now, arr.t_prog),
        nu=arr.nu,
        set_count=arr.set_count,
        reset_count=arr.reset_count + mask.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Reading
# ---------------------------------------------------------------------------

def drifted_conductance(arr: PcmArrays, t_now, cfg: PcmConfig) -> jnp.ndarray:
    """Conductance at time ``t_now`` including temporal drift (no read noise)."""
    if not cfg.drift:
        return arr.g
    t_now = jnp.asarray(t_now, jnp.float32)
    elapsed = jnp.maximum(t_now - arr.t_prog, cfg.drift_t0)
    return arr.g * jnp.power(elapsed / cfg.drift_t0, -arr.nu)


def read(arr: PcmArrays, t_now, key: jax.Array, cfg: PcmConfig) -> jnp.ndarray:
    """One stochastic read of the whole array at time ``t_now``."""
    g = drifted_conductance(arr, t_now, cfg)
    if cfg.read_noise:
        g = g + cfg.read_sigma * jax.random.normal(key, g.shape)
    return jnp.clip(g, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Binary devices (LSB array)
# ---------------------------------------------------------------------------

def binary_write_levels(key: jax.Array, bits: jnp.ndarray,
                        cfg: PcmConfig) -> jnp.ndarray:
    """Analog conductance realized when writing the given {0,1} bits.

    SET states land at 1.0 + noise, RESET states at ~0.  Only used by the
    (test-time) analog view of the LSB array — the training path models the
    LSB array digitally because thresholded binary reads are exact until
    drift pushes a SET state below threshold, which at nu<=0.12 over a year
    stays > 0.35 of range (see python/tests/test_pcm_model.py).
    """
    noise = jax.random.normal(key, bits.shape)
    high = jnp.clip(1.0 + cfg.binary_write_sigma * noise, 0.0, 1.2)
    return jnp.where(bits > 0, high, 0.0)


def binary_read(levels: jnp.ndarray, t_prog: jnp.ndarray, nu: jnp.ndarray,
                t_now, key: jax.Array, cfg: PcmConfig) -> jnp.ndarray:
    """Thresholded read of binary devices under drift + read noise."""
    t_now = jnp.asarray(t_now, jnp.float32)
    elapsed = jnp.maximum(t_now - t_prog, cfg.drift_t0)
    g = levels * jnp.power(elapsed / cfg.drift_t0, -nu)
    if cfg.read_noise:
        g = g + cfg.read_sigma * jax.random.normal(key, g.shape)
    return (g > cfg.binary_threshold).astype(jnp.int32)
