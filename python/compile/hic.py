"""Hybrid in-memory weight representation (paper Figs. 1-2), in JAX.

Per layer, the weight lives on two memory arrays:

* **MSB array** — differential pair of multi-level PCM devices per weight
  (`PcmArrays` x2).  ``w = w_max * (G+ - G-) / g_span`` with ~4-bit
  equivalent precision.  All forward/backward VMMs read this array
  (drifted conductances + per-read stochastic noise through the Pallas
  kernel's noise operand).
* **LSB array** — 7 binary PCM devices per weight forming a signed
  fixed-point accumulator of quantized weight updates.  Overflow (one MSB
  quantum) is the only event that programs the MSB array.

Plus the **selective refresh** (every `refresh_every` batches the
coordinator invokes `refresh`, which RESET-reprograms only the pairs whose
devices approach conductance saturation — this is what keeps MSB
write-erase cycles < 150 over a full training, paper Fig. 6).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import pcm_model
from .configs import AdcDacConfig, HicConfig, PcmConfig
from .kernels.lsb_update import lsb_update

#: fraction of the normalized conductance window used by the weight map;
#: the headroom above `G_SPAN` is the saturation guard band the refresh
#: operation polices.
G_SPAN = 0.8
#: conductance level beyond which a device is considered saturating.
G_SAT = 0.9


class HicLayerState(NamedTuple):
    """Device state of one HIC-mapped weight tensor (2-D: [K, N])."""

    pcm_p: pcm_model.PcmArrays  # G+ (multi-level)
    pcm_m: pcm_model.PcmArrays  # G- (multi-level)
    lsb: jnp.ndarray            # i32 [K, N] — accumulator counts
    lsb_flips: jnp.ndarray      # i32 [K, N] — cumulative binary-device writes
    lsb_resets: jnp.ndarray     # i32 [K, N] — cumulative RESETs (WE commits)


def _w_to_g(w: jnp.ndarray, hic: HicConfig) -> jnp.ndarray:
    """Weight value -> differential conductance target (normalized)."""
    return w * (G_SPAN / hic.w_max)


def _g_to_w(g: jnp.ndarray, hic: HicConfig) -> jnp.ndarray:
    return g * (hic.w_max / G_SPAN)


def init_layer(key: jax.Array, w0: jnp.ndarray, t_now, pcm: PcmConfig,
               hic: HicConfig) -> HicLayerState:
    """Program freshly-RESET devices with the (quantized) init weights."""
    k_nu_p, k_nu_m, k_wr_p, k_wr_m = jax.random.split(key, 4)
    shape = w0.shape
    arr_p = pcm_model.init_arrays(k_nu_p, shape, pcm)
    arr_m = pcm_model.init_arrays(k_nu_m, shape, pcm)

    w0 = quantize_msb(w0, hic)
    g_target = _w_to_g(w0, hic)
    arr_p = pcm_model.program_increment(
        arr_p, jnp.maximum(g_target, 0.0), t_now, k_wr_p, pcm,
        hic.max_pulses)
    arr_m = pcm_model.program_increment(
        arr_m, jnp.maximum(-g_target, 0.0), t_now, k_wr_m, pcm,
        hic.max_pulses)
    zi = jnp.zeros(shape, jnp.int32)
    return HicLayerState(pcm_p=arr_p, pcm_m=arr_m, lsb=zi,
                         lsb_flips=zi, lsb_resets=zi)


def quantize_msb(w: jnp.ndarray, hic: HicConfig) -> jnp.ndarray:
    """Snap a weight to the MSB (4-bit, 15-level) grid.

    The representable range is ±(levels-1)/2 · ε (±7ε for 4 bits) — the
    outermost codes of the symmetric grid, so every quantized value is an
    exact multiple of ε (what the differential pair can actually store).
    """
    eps = hic.msb_step
    kmax = (hic.msb_levels - 1) // 2
    k = jnp.clip(jnp.round(w / eps), -kmax, kmax)
    return k * eps


def read_weights(st: HicLayerState, t_now, pcm: PcmConfig,
                 hic: HicConfig) -> jnp.ndarray:
    """Expected weight seen by a VMM at time t (drift, no read noise —
    the stochastic-read term rides the Pallas kernel's noise operand)."""
    gp = pcm_model.drifted_conductance(st.pcm_p, t_now, pcm)
    gm = pcm_model.drifted_conductance(st.pcm_m, t_now, pcm)
    return _g_to_w(gp - gm, hic)


def read_noise_sigma(pcm: PcmConfig, hic: HicConfig) -> float:
    """Std-dev of the per-read weight perturbation: two devices' read noise
    add in quadrature across the differential pair."""
    if not pcm.read_noise:
        return 0.0
    return float(pcm.read_sigma) * (2.0 ** 0.5) * (hic.w_max / G_SPAN)


def sample_read_noise(key: jax.Array, shape: Tuple[int, ...],
                      pcm: PcmConfig, hic: HicConfig) -> jnp.ndarray:
    sigma = read_noise_sigma(pcm, hic)
    if sigma == 0.0:
        return jnp.zeros(shape, jnp.float32)
    return sigma * jax.random.normal(key, shape)


def apply_update(st: HicLayerState, dw: jnp.ndarray, lr, t_now,
                 key: jax.Array, pcm: PcmConfig, hic: HicConfig
                 ) -> Tuple[HicLayerState, jnp.ndarray]:
    """One training update: quantize -> LSB accumulate -> overflow -> MSB.

    Returns (new_state, overflow_events) where overflow_events is the count
    of weights whose accumulator overflowed (programming activity metric).
    """
    half = hic.lsb_half_range
    # Digital gradient quantization to accumulator counts.  Stochastic
    # rounding keeps sub-quantum gradients alive in expectation (the LSB
    # grid would otherwise have a +-lsb_step/2 dead zone); it is one LFSR +
    # comparator per update unit in hardware.  A single step is clamped to
    # +-(2*half - 1) counts (< 2 MSB quanta), the hardware adder's width.
    key, k_round = jax.random.split(key)
    v = -lr * dw / hic.lsb_step
    if hic.stochastic_rounding:
        delta = jnp.floor(v + jax.random.uniform(k_round, v.shape))
    else:
        delta = jnp.round(v)
    delta = jnp.clip(delta, -(2 * half - 1), 2 * half - 1).astype(jnp.int32)

    acc2, ovf, flip_word = lsb_update(st.lsb, delta, half_range=half,
                                      nbits=hic.lsb_bits)
    flips = flip_word & 0xFFFF
    resets = flip_word >> 16

    # Program the MSB array only on overflow (increment-only: positive
    # overflow pulses G+, negative pulses G-).
    dw_msb = ovf.astype(jnp.float32) * hic.msb_step
    dg = jnp.abs(_w_to_g(dw_msb, hic))
    k_p, k_m = jax.random.split(key)
    pcm_p = pcm_model.program_increment(
        st.pcm_p, jnp.where(ovf > 0, dg, 0.0), t_now, k_p, pcm,
        hic.max_pulses)
    pcm_m = pcm_model.program_increment(
        st.pcm_m, jnp.where(ovf < 0, dg, 0.0), t_now, k_m, pcm,
        hic.max_pulses)

    new_st = HicLayerState(
        pcm_p=pcm_p, pcm_m=pcm_m, lsb=acc2,
        lsb_flips=st.lsb_flips + flips,
        lsb_resets=st.lsb_resets + resets,
    )
    return new_st, jnp.sum(jnp.abs(ovf)).astype(jnp.float32)


def refresh(st: HicLayerState, t_now, key: jax.Array, pcm: PcmConfig,
            hic: HicConfig) -> Tuple[HicLayerState, jnp.ndarray]:
    """Selective saturation refresh (paper §III-A; Boybat et al. 2018).

    Pairs whose devices climbed into the saturation guard band are read
    (through drift + read noise), RESET on both devices, and reprogrammed
    to the differential target.  Untouched pairs keep their state — this
    selectivity is what keeps MSB write-erase cycles tiny (Fig. 6).

    Returns (new_state, number_of_pairs_refreshed).
    """
    k_read_p, k_read_m, k_wr_p, k_wr_m = jax.random.split(key, 4)
    need = (st.pcm_p.g > G_SAT) | (st.pcm_m.g > G_SAT)

    # Read the current weight through the periphery (drift + read noise).
    gp = pcm_model.read(st.pcm_p, t_now, k_read_p, pcm)
    gm = pcm_model.read(st.pcm_m, t_now, k_read_m, pcm)
    w = quantize_msb(_g_to_w(gp - gm, hic), hic)
    g_target = _w_to_g(w, hic)

    # RESET both devices of the selected pairs ...
    arr_p = pcm_model.reset(st.pcm_p, t_now, need)
    arr_m = pcm_model.reset(st.pcm_m, t_now, need)
    # ... and reprogram the difference into the appropriate device.
    arr_p = pcm_model.program_increment(
        arr_p, jnp.where(need, jnp.maximum(g_target, 0.0), 0.0), t_now,
        k_wr_p, pcm, hic.max_pulses)
    arr_m = pcm_model.program_increment(
        arr_m, jnp.where(need, jnp.maximum(-g_target, 0.0), 0.0), t_now,
        k_wr_m, pcm, hic.max_pulses)

    new_st = HicLayerState(pcm_p=arr_p, pcm_m=arr_m, lsb=st.lsb,
                           lsb_flips=st.lsb_flips, lsb_resets=st.lsb_resets)
    return new_st, jnp.sum(need).astype(jnp.float32)


def inference_model_bits(num_weights: int, hic: HicConfig) -> int:
    """Inference model size in bits: only the MSB array is needed at
    inference time (paper Fig. 4's x-axis): ~msb_bits per weight."""
    return num_weights * hic.msb_bits
