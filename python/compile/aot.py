"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.

Usage (from python/):
    python -m compile.aot --sets core            # default `make artifacts`
    python -m compile.aot --sets fig3,fig4,fig5  # experiment artifact sets
    python -m compile.aot --configs tiny         # individual configs
    python -m compile.aot --list

For every named ExperimentConfig this writes:

    artifacts/<config>/<entry>.hlo.txt    — XLA HLO *text* modules
    artifacts/<config>/manifest.json      — flattened I/O signatures

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

The manifest tells the Rust runtime everything it needs to drive the
programs without Python: the flattened order/shape/dtype of every input
and output leaf, which spans are the persistent device state, and the echo
of the config the set was baked from.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import resnet
from .configs import SET_GROUPS, ExperimentConfig, all_configs


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _leaf_entries(tree) -> List[Dict[str, Any]]:
    """Flatten a pytree of ShapeDtypeStructs to manifest leaf records."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path).strip("[]").replace("'", "")
        name = (name.replace("][", "/").replace("].", "/")
                .replace(".", "/").replace("[", "").replace("]", ""))
        out.append({
            "name": name or "arg",
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return out


def _spec_like(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


class EntryPoint:
    def __init__(self, name: str, fn: Callable, args: Sequence[Any],
                 arg_names: Sequence[str], state_arg: int = -1):
        """state_arg: index of the persistent-state argument (-1 if none).

        The state (when present) must be the first argument and, for
        state-updating entries, the first element of the returned tuple —
        the Rust runtime relies on this convention.
        """
        self.name = name
        self.fn = fn
        self.args = list(args)
        self.arg_names = list(arg_names)
        self.state_arg = state_arg

    def lower(self) -> Tuple[str, Dict[str, Any]]:
        # keep_unused=True: entries like eval_step read only part of the
        # state, but the runtime contract feeds the full flattened state to
        # every stateful entry — dead-arg elimination would break it.
        lowered = jax.jit(self.fn, keep_unused=True).lower(*self.args)
        text = to_hlo_text(lowered)

        inputs: List[Dict[str, Any]] = []
        state_in = [0, 0]
        for i, (arg, an) in enumerate(zip(self.args, self.arg_names)):
            leaves = _leaf_entries(arg)
            for l in leaves:
                l["name"] = f"{an}/{l['name']}" if l["name"] != "arg" else an
            if i == self.state_arg:
                state_in = [len(inputs), len(leaves)]
            inputs.extend(leaves)

        out_shape = jax.eval_shape(self.fn, *self.args)
        outputs = _leaf_entries(out_shape)
        state_out = [0, 0]
        if self.state_arg >= 0 and isinstance(out_shape, tuple):
            n_state = len(jax.tree_util.tree_leaves(
                self.args[self.state_arg]))
            first = jax.tree_util.tree_leaves(out_shape[0])
            if len(first) == n_state:
                state_out = [0, n_state]
        elif self.state_arg >= 0 and isinstance(out_shape, dict):
            state_out = [0, len(outputs)]  # entry returns the state itself

        sig = {
            "name": self.name,
            "inputs": inputs,
            "outputs": outputs,
            "state_input_span": state_in,
            "state_output_span": state_out,
        }
        return text, sig


def build_entries(cfg: ExperimentConfig) -> List[EntryPoint]:
    net, tr = cfg.net, cfg.train
    b = tr.batch_size
    img = (b, net.image_size, net.image_size, net.image_channels)

    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    x = jax.ShapeDtypeStruct(img, jnp.float32)
    y = jax.ShapeDtypeStruct((b,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    init = M.hic_init_fn(cfg)
    state = _spec_like(jax.eval_shape(init, key))

    entries = [
        EntryPoint("hic_init", init, [key], ["key"]),
        EntryPoint("hic_train_step", M.hic_train_step_fn(cfg),
                   [state, x, y, key, scalar, scalar],
                   ["state", "x", "y", "key", "t_now", "lr"], state_arg=0),
        EntryPoint("hic_eval_step", M.hic_eval_step_fn(cfg),
                   [state, x, y, key, scalar],
                   ["state", "x", "y", "key", "t_now"], state_arg=0),
        EntryPoint("hic_refresh", M.hic_refresh_fn(cfg),
                   [state, key, scalar],
                   ["state", "key", "t_now"], state_arg=0),
        EntryPoint("hic_adabs", M.hic_adabs_fn(cfg),
                   [state, x, key, scalar, scalar],
                   ["state", "x", "key", "t_now", "kth"], state_arg=0),
    ]

    # Standalone Layer-1 microbench kernel (crossbar tile-sized).
    t = 128
    entries.append(EntryPoint(
        "crossbar_vmm", M.crossbar_vmm_fn(cfg),
        [jax.ShapeDtypeStruct((t, t), jnp.float32),
         jax.ShapeDtypeStruct((t, t), jnp.float32),
         jax.ShapeDtypeStruct((t, t), jnp.float32)],
        ["x", "w", "noise"]))

    if cfg.with_baseline:
        binit = M.baseline_init_fn(cfg)
        bstate = _spec_like(jax.eval_shape(binit, key))
        entries.extend([
            EntryPoint("baseline_init", binit, [key], ["key"]),
            EntryPoint("baseline_train_step", M.baseline_train_step_fn(cfg),
                       [bstate, x, y, scalar],
                       ["state", "x", "y", "lr"], state_arg=0),
            EntryPoint("baseline_eval_step", M.baseline_eval_step_fn(cfg),
                       [bstate, x, y], ["state", "x", "y"], state_arg=0),
        ])

    return entries


def _source_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip configs
    whose artifacts are already up to date."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def lower_config(cfg: ExperimentConfig, out_root: str, *,
                 force: bool = False) -> None:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    stamp_path = os.path.join(out_dir, ".stamp")
    fp = _source_fingerprint()
    if not force and os.path.exists(stamp_path):
        with open(stamp_path) as f:
            if f.read().strip() == fp:
                print(f"[aot] {cfg.name}: up to date")
                return

    print(f"[aot] lowering config '{cfg.name}' "
          f"(depth={cfg.net.depth} width={cfg.net.width_mult} "
          f"batch={cfg.train.batch_size})")
    specs = resnet.layer_specs(cfg.net)
    manifest: Dict[str, Any] = {
        "config": cfg.describe(),
        "num_weights": resnet.num_weights(cfg.net),
        "layers": [
            {"name": s.name, "k": s.k_dim, "n": s.cout,
             "kh": s.kh, "kw": s.kw, "cin": s.cin, "stride": s.stride}
            for s in specs
        ],
        "entries": {},
        "fingerprint": fp,
    }
    for ep in build_entries(cfg):
        text, sig = ep.lower()
        path = os.path.join(out_dir, f"{ep.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        sig["file"] = f"{ep.name}.hlo.txt"
        manifest["entries"][ep.name] = sig
        print(f"[aot]   {ep.name}: {len(text)/1e6:.2f} MB hlo, "
              f"{len(sig['inputs'])} in / {len(sig['outputs'])} out")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp_path, "w") as f:
        f.write(fp)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-root", default=None,
                    help="artifact root (default: <repo>/artifacts)")
    ap.add_argument("--sets", default="",
                    help="comma-separated set groups: "
                         + ",".join(SET_GROUPS))
    ap.add_argument("--configs", default="",
                    help="comma-separated individual config names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cfgs = all_configs()
    if args.list:
        for name, c in sorted(cfgs.items()):
            print(f"{name:24s} depth={c.net.depth} width={c.net.width_mult}"
                  f" batch={c.train.batch_size} baseline={c.with_baseline}")
        return

    out_root = args.out_root or os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))), "artifacts")

    names: List[str] = []
    for s in filter(None, args.sets.split(",")):
        if s not in SET_GROUPS:
            sys.exit(f"unknown set '{s}'; known: {sorted(SET_GROUPS)}")
        names.extend(SET_GROUPS[s])
    names.extend(filter(None, args.configs.split(",")))
    if not names:
        names = list(SET_GROUPS["core"])

    seen = set()
    for n in names:
        if n in seen:
            continue
        seen.add(n)
        if n not in cfgs:
            sys.exit(f"unknown config '{n}'; try --list")
        lower_config(cfgs[n], out_root, force=args.force)


if __name__ == "__main__":
    main()
