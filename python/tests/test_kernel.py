"""Layer-1 kernel correctness: Pallas vs pure-jnp oracle.

THE core correctness signal of the compile path.  The kernels are
deterministic (stochasticity enters as operands), so agreement is exact up
to f32 accumulation order; hypothesis sweeps shapes and value ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import AdcDacConfig
from compile.kernels import ref
from compile.kernels.lsb_update import lsb_update
from compile.kernels.pcm_vmm import (dac_quantize, mxu_utilization_estimate,
                                     pcm_vmm, vmem_footprint_bytes)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# pcm_vmm
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    block=st.sampled_from([(8, 8, 8), (16, 16, 16), (32, 32, 32)]),
    seed=st.integers(0, 2**16),
)
def test_pcm_vmm_matches_ref(m, k, n, block, seed):
    adc = AdcDacConfig()
    x = dac_quantize(rand(seed, (m, k), 2.0), adc)
    w = rand(seed + 1, (k, n), 0.3)
    noise = rand(seed + 2, (k, n), 0.01)
    out = pcm_vmm(x, w, noise, adc, block=block)
    expect = ref.pcm_vmm_ref(x, w, noise, adc)
    np.testing.assert_allclose(out, expect, rtol=0, atol=2e-5)


@pytest.mark.parametrize("enabled", [True, False])
def test_pcm_vmm_adc_toggle(enabled):
    adc = AdcDacConfig(enabled=enabled)
    x = dac_quantize(rand(0, (16, 16)), adc)
    w = rand(1, (16, 8), 0.3)
    z = jnp.zeros_like(w)
    out = pcm_vmm(x, w, z, adc, block=(8, 8, 8))
    expect = ref.pcm_vmm_ref(x, w, z, adc)
    np.testing.assert_allclose(out, expect, atol=2e-5)
    if not enabled:
        # no quantization: exact matmul
        np.testing.assert_allclose(out, x @ w, atol=1e-5)


def test_pcm_vmm_noise_is_weight_perturbation():
    adc = AdcDacConfig(enabled=False)
    x = dac_quantize(rand(3, (8, 8)), adc)
    w = rand(4, (8, 4), 0.3)
    noise = rand(5, (8, 4), 0.05)
    out = pcm_vmm(x, w, noise, adc, block=(8, 8, 8))
    np.testing.assert_allclose(out, x @ (w + noise), atol=1e-5)


def test_pcm_vmm_jit_and_grad_safe():
    # The kernel must lower inside jit (the AOT path) without surprises.
    adc = AdcDacConfig()

    @jax.jit
    def f(x, w, n):
        return pcm_vmm(x, w, n, adc, block=(16, 16, 16)).sum()

    x = rand(6, (20, 12))
    w = rand(7, (12, 8), 0.3)
    n = jnp.zeros((12, 8))
    assert jnp.isfinite(f(x, w, n))


def test_adc_clips_large_outputs():
    adc = AdcDacConfig()
    x = jnp.full((4, 64), 4.0)
    w = jnp.full((64, 4), 1.0)
    z = jnp.zeros((64, 4))
    out = pcm_vmm(dac_quantize(x, adc), w, z, adc, block=(8, 8, 8))
    assert float(jnp.max(out)) <= adc.adc_range + 1e-5


# ---------------------------------------------------------------------------
# lsb_update
# ---------------------------------------------------------------------------

@given(
    n=st.integers(1, 3000),
    half=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**16),
    block=st.sampled_from([64, 256, 1024]),
)
def test_lsb_update_matches_ref(n, half, seed, block):
    bits = int(np.log2(half)) + 1
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    acc = jax.random.randint(k1, (n,), -half + 1, half)
    delta = jax.random.randint(k2, (n,), -2 * half + 1, 2 * half)
    got = lsb_update(acc, delta, half_range=half, nbits=bits, block=block)
    want = ref.lsb_update_ref(acc, delta, half_range=half, nbits=bits)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@given(seed=st.integers(0, 2**16))
def test_lsb_conservation_invariant(seed):
    """acc + delta == acc' + half*overflow, always."""
    half = 64
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    acc = jax.random.randint(k1, (500,), -63, 64)
    delta = jax.random.randint(k2, (500,), -127, 128)
    acc2, ovf, _ = lsb_update(acc, delta, half_range=half, nbits=7)
    np.testing.assert_array_equal(np.asarray(acc + delta),
                                  np.asarray(acc2 + half * ovf))
    assert int(jnp.max(jnp.abs(acc2))) <= 64


def test_lsb_flip_word_packing():
    # 63 + 1: register 1111111 -> 1000000, 6 flips all resets.
    acc = jnp.array([63, 0, -1], jnp.int32)
    delta = jnp.array([1, 1, 1], jnp.int32)
    _, ovf, word = lsb_update(acc, delta, half_range=64, nbits=7)
    flips, resets = ref.unpack_flip_word(word)
    assert list(np.asarray(ovf)) == [1, 0, 0]
    # -1 -> 0 crosses the register midpoint: offset code 0111111 -> 1000000
    # rewrites all seven devices (six of them 1->0 RESETs) — the worst-case
    # flip cost of the offset encoding.
    assert list(np.asarray(flips)) == [6, 1, 7]
    assert list(np.asarray(resets)) == [6, 0, 6]


def test_lsb_multidim_shapes():
    acc = jnp.zeros((6, 5), jnp.int32)
    delta = jnp.ones((6, 5), jnp.int32) * 70
    acc2, ovf, _ = lsb_update(acc, delta, half_range=64, nbits=7)
    assert acc2.shape == (6, 5)
    np.testing.assert_array_equal(np.asarray(ovf), np.ones((6, 5)))
    np.testing.assert_array_equal(np.asarray(acc2), np.full((6, 5), 6))


# ---------------------------------------------------------------------------
# perf-model helpers (DESIGN §7 L1)
# ---------------------------------------------------------------------------

def test_vmem_footprint_within_budget():
    # Default 128^3 f32 tiling must fit comfortably in 16 MiB VMEM.
    assert vmem_footprint_bytes((128, 128, 128)) < 1 << 20


def test_mxu_utilization_estimate():
    assert mxu_utilization_estimate(128, 128, 128, (128, 128, 128)) == 1.0
    u = mxu_utilization_estimate(129, 128, 128, (128, 128, 128))
    assert 0.4 < u < 0.6  # padded to 256 rows
    assert mxu_utilization_estimate(1, 1, 1, (128, 128, 128)) == 1.0
