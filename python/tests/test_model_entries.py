"""Entry-point level tests: the exact functions aot.py lowers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import resnet


@pytest.fixture(scope="module")
def entries(tiny_cfg):
    return {
        "init": jax.jit(M.hic_init_fn(tiny_cfg)),
        "train": jax.jit(M.hic_train_step_fn(tiny_cfg)),
        "eval": jax.jit(M.hic_eval_step_fn(tiny_cfg)),
        "refresh": jax.jit(M.hic_refresh_fn(tiny_cfg)),
        "adabs": jax.jit(M.hic_adabs_fn(tiny_cfg)),
        "b_init": jax.jit(M.baseline_init_fn(tiny_cfg)),
        "b_train": jax.jit(M.baseline_train_step_fn(tiny_cfg)),
        "b_eval": jax.jit(M.baseline_eval_step_fn(tiny_cfg)),
    }


def batch(seed, b=4):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (b, 32, 32, 3))
    y = jax.random.randint(k, (b,), 0, 10)
    return x, y


KEY = np.array([0, 7], np.uint32)


def test_init_structure(entries, tiny_cfg):
    st = entries["init"](KEY)
    assert set(st.keys()) == {"layers", "bn_params", "bn_stats"}
    assert len(st["layers"]) == len(resnet.layer_specs(tiny_cfg.net))
    l0 = st["layers"][0]
    assert set(l0.keys()) == {"pcm_p", "pcm_m", "lsb", "lsb_flips",
                              "lsb_resets"}
    # LSB accumulators start empty
    assert int(jnp.sum(jnp.abs(l0["lsb"]))) == 0


def test_train_step_updates_state_and_metrics(entries):
    st = entries["init"](KEY)
    x, y = batch(0)
    st2, m = entries["train"](st, x, y, KEY, jnp.float32(0.0),
                              jnp.float32(0.5))
    assert set(m.keys()) == {"loss", "acc", "overflow_events", "grad_norm"}
    assert np.isfinite(float(m["loss"]))
    assert 0.0 <= float(m["acc"]) <= 1.0
    # LSB moved somewhere
    total = sum(int(jnp.sum(jnp.abs(l["lsb"]))) for l in st2["layers"])
    assert total > 0
    # determinism: same inputs -> same outputs
    _, m2 = entries["train"](st, x, y, KEY, jnp.float32(0.0),
                             jnp.float32(0.5))
    assert float(m2["loss"]) == float(m["loss"])


def test_train_loss_decreases(entries):
    st = entries["init"](KEY)
    protos = jax.random.normal(jax.random.PRNGKey(99), (10, 32, 32, 3))
    losses = []
    for i in range(30):
        k = jax.random.PRNGKey(1000 + i)
        y = jax.random.randint(k, (4,), 0, 10)
        x = protos[y] + 0.5 * jax.random.normal(k, (4, 32, 32, 3))
        st, m = entries["train"](st, x, y, np.array([1, i], np.uint32),
                                 jnp.float32(i * 0.05), jnp.float32(0.5))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


def test_eval_step_counts(entries):
    st = entries["init"](KEY)
    x, y = batch(1)
    correct, loss_sum = entries["eval"](st, x, y, KEY, jnp.float32(10.0))
    assert 0 <= int(correct) <= 4
    assert float(loss_sum) > 0


def test_refresh_rare_at_init(entries, tiny_cfg):
    """Right after init, only write-noise overshoot on the largest weights
    can sit in the guard band — refresh must touch a rare few, not sweep
    the array (that selectivity is what keeps Fig. 6's MSB counts tiny)."""
    from compile import resnet
    st = entries["init"](KEY)
    st2, n = entries["refresh"](st, KEY, jnp.float32(1.0))
    total = resnet.num_weights(tiny_cfg.net)
    assert float(n) <= 0.02 * total, (float(n), total)
    # state structurally intact
    assert len(st2["layers"]) == len(st["layers"])


def test_adabs_recalibrates_bn_stats(entries):
    st = entries["init"](KEY)
    x, _ = batch(2)
    st2 = entries["adabs"](st, x, KEY, jnp.float32(1e6), jnp.float32(1.0))
    # k=1 overwrites the running stats with the batch moments
    changed = any(
        not np.allclose(np.asarray(st["bn_stats"][k]),
                        np.asarray(st2["bn_stats"][k]))
        for k in st["bn_stats"])
    assert changed
    # layers untouched
    for l1, l2 in zip(st["layers"], st2["layers"]):
        np.testing.assert_array_equal(np.asarray(l1["pcm_p"]["g"]),
                                      np.asarray(l2["pcm_p"]["g"]))


def test_drift_between_train_and_late_eval(entries):
    """Eval far in the future must differ (drift) from eval now."""
    st = entries["init"](KEY)
    x, y = batch(3)
    # train a bit so conductances are non-trivial
    for i in range(5):
        st, _ = entries["train"](st, x, y, np.array([2, i], np.uint32),
                                 jnp.float32(i * 0.05), jnp.float32(0.5))
    _, loss_now = entries["eval"](st, x, y, KEY, jnp.float32(1.0))
    _, loss_year = entries["eval"](st, x, y, KEY, jnp.float32(3.2e7))
    assert float(loss_now) != float(loss_year)


def test_baseline_learns(entries):
    st = entries["b_init"](KEY)
    protos = jax.random.normal(jax.random.PRNGKey(98), (10, 32, 32, 3))
    losses = []
    for i in range(20):
        k = jax.random.PRNGKey(2000 + i)
        y = jax.random.randint(k, (4,), 0, 10)
        x = protos[y] + 0.5 * jax.random.normal(k, (4, 32, 32, 3))
        st, m = entries["b_train"](st, x, y, jnp.float32(0.05))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    x, y = batch(4)
    correct, _ = entries["b_eval"](st, x, y)
    assert 0 <= int(correct) <= 4
