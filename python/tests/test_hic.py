"""HIC weight-representation invariants (python/compile/hic.py)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import hic, pcm_model
from compile.configs import HicConfig, PcmConfig


def ideal_pcm() -> PcmConfig:
    return dataclasses.replace(PcmConfig(), nonlinear=False,
                               write_noise=False, read_noise=False,
                               drift=False)


def det_hic() -> HicConfig:
    return dataclasses.replace(HicConfig(), stochastic_rounding=False)


def test_geometry_constants(hic_cfg):
    assert hic_cfg.msb_levels == 15
    assert abs(hic_cfg.msb_step - 2.0 / 15.0) < 1e-9
    assert hic_cfg.lsb_half_range == 64
    assert abs(hic_cfg.lsb_step - hic_cfg.msb_step / 64) < 1e-12


def test_init_and_read_roundtrip(key):
    p, h = ideal_pcm(), det_hic()
    w0 = jnp.array([[0.4, -0.6], [0.0, 0.9]])
    st = hic.init_layer(key, w0, 0.0, p, h)
    w = hic.read_weights(st, 0.0, p, h)
    # ideal linear device quantizes to ~0.125-weight pulse granularity
    np.testing.assert_allclose(np.asarray(w), np.asarray(w0), atol=0.13)


def test_quantize_msb_grid(hic_cfg):
    w = jnp.linspace(-1.5, 1.5, 31)
    q = hic.quantize_msb(w, hic_cfg)
    assert float(jnp.max(q)) <= hic_cfg.w_max
    assert float(jnp.min(q)) >= -hic_cfg.w_max
    # on-grid: q / step integral
    k = np.asarray(q) / hic_cfg.msb_step
    np.testing.assert_allclose(k, np.round(k), atol=1e-4)


def test_apply_update_accumulates_without_msb(key):
    """Sub-quantum updates must live entirely in the LSB array."""
    p, h = ideal_pcm(), det_hic()
    st = hic.init_layer(key, jnp.zeros((2, 2)), 0.0, p, h)
    sets0 = st.pcm_p.set_count
    dw = jnp.full((2, 2), 0.01)  # small gradient: ~2 LSB counts at lr 0.5
    st2, ovf = hic.apply_update(st, dw, 0.5, 1.0, key, p, h)
    assert float(ovf) == 0.0
    np.testing.assert_array_equal(np.asarray(st2.pcm_p.set_count),
                                  np.asarray(sets0))
    assert int(jnp.sum(jnp.abs(st2.lsb))) > 0


def test_apply_update_overflow_programs_msb(key):
    p, h = ideal_pcm(), det_hic()
    st = hic.init_layer(key, jnp.zeros((1, 1)), 0.0, p, h)
    # one huge negative gradient -> positive update > 1 quantum
    dw = jnp.full((1, 1), -1.0)
    st2, ovf = hic.apply_update(st, dw, h.msb_step * 1.5, 1.0, key, p, h)
    assert float(ovf) >= 1.0
    assert int(st2.pcm_p.set_count[0, 0]) > 0
    assert int(st2.pcm_m.set_count[0, 0]) == 0
    w = hic.read_weights(st2, 1.0, p, h)
    assert float(w[0, 0]) > 0.0


def test_update_sign_symmetry(key):
    p, h = ideal_pcm(), det_hic()
    st = hic.init_layer(key, jnp.zeros((1, 1)), 0.0, p, h)
    st_pos, _ = hic.apply_update(
        st, jnp.full((1, 1), -1.0), 0.2, 1.0, key, p, h)
    st_neg, _ = hic.apply_update(
        st, jnp.full((1, 1), 1.0), 0.2, 1.0, key, p, h)
    assert int(st_pos.lsb[0, 0]) == -int(st_neg.lsb[0, 0])


def test_refresh_preserves_weights_and_resets_saturation(key):
    p, h = ideal_pcm(), det_hic()
    st = hic.init_layer(key, jnp.zeros((1, 2)), 0.0, p, h)
    # Drive device 0 into saturation with alternating +- overflows.
    for i in range(14):
        sign = 1.0 if i % 2 == 0 else -1.0
        dw = jnp.array([[-sign, 0.0]])
        st, _ = hic.apply_update(st, dw, h.msb_step * 1.2, 1.0, key, p, h)
    assert float(st.pcm_p.g[0, 0]) > hic.G_SAT

    w_before = hic.read_weights(st, 2.0, p, h)
    st2, n = hic.refresh(st, 2.0, key, p, h)
    assert float(n) == 1.0  # only the saturating pair
    w_after = hic.read_weights(st2, 2.0, p, h)
    np.testing.assert_allclose(np.asarray(w_after), np.asarray(w_before),
                               atol=0.14)
    assert float(st2.pcm_p.g[0, 0]) < hic.G_SAT
    assert int(st2.pcm_p.reset_count[0, 0]) == 1
    assert int(st2.pcm_p.reset_count[0, 1]) == 0


def test_read_noise_sigma_scaling(pcm, hic_cfg):
    s = hic.read_noise_sigma(pcm, hic_cfg)
    expect = pcm.read_sigma * np.sqrt(2.0) * hic_cfg.w_max / hic.G_SPAN
    assert abs(s - expect) < 1e-9
    off = dataclasses.replace(pcm, read_noise=False)
    assert hic.read_noise_sigma(off, hic_cfg) == 0.0
    noise = hic.sample_read_noise(jax.random.PRNGKey(0), (100, 100), pcm,
                                  hic_cfg)
    assert abs(float(noise.std()) - s) < 0.002


def test_stochastic_rounding_unbiased(key, pcm):
    h = HicConfig()  # stochastic_rounding=True
    p = ideal_pcm()
    st = hic.init_layer(key, jnp.zeros((64, 64)), 0.0, p, h)
    # gradient worth 0.3 counts: deterministic rounding would drop it
    dw = jnp.full((64, 64), -0.3 * h.lsb_step)
    st2, _ = hic.apply_update(st, dw, 1.0, 1.0, key, p, h)
    mean_counts = float(jnp.mean(st2.lsb.astype(jnp.float32)))
    assert 0.2 < mean_counts < 0.4, mean_counts


def test_inference_model_bits(hic_cfg):
    assert hic.inference_model_bits(1000, hic_cfg) == 4000
