"""AOT pipeline tests: lowering, manifest contract, HLO text properties."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, resnet
from compile.configs import SET_GROUPS, all_configs


def test_all_configs_wellformed():
    cfgs = all_configs()
    assert "core" in cfgs and "tiny" in cfgs
    # FIG3: exactly the 8 ablation variants
    fig3 = [n for n in cfgs if n.startswith("fig3_")]
    assert len(fig3) == 8
    # every set group references real configs
    for group, names in SET_GROUPS.items():
        for n in names:
            assert n in cfgs, f"{group} references unknown config {n}"


def test_fig3_ablation_flags():
    cfgs = all_configs()
    lin = cfgs["fig3_linear"].pcm
    assert not (lin.nonlinear or lin.write_noise or lin.read_noise
                or lin.drift)
    full = cfgs["fig3_full"].pcm
    assert full.nonlinear and full.write_noise and full.read_noise \
        and full.drift
    drift = cfgs["fig3_linear_drift"].pcm
    assert drift.drift and not drift.nonlinear and not drift.write_noise


def test_entry_manifest_contract(tiny_cfg):
    """Lower the two init entries and check the manifest invariants the
    Rust runtime relies on (state-first ordering, span arithmetic)."""
    entries = {e.name: e for e in aot.build_entries(tiny_cfg)}
    assert {"hic_init", "hic_train_step", "hic_eval_step", "hic_refresh",
            "hic_adabs", "crossbar_vmm", "baseline_init",
            "baseline_train_step", "baseline_eval_step"} \
        <= set(entries.keys())

    _, sig = entries["hic_train_step"].lower()
    s, l = sig["state_input_span"]
    assert s == 0 and l > 0
    so, lo = sig["state_output_span"]
    assert so == 0 and lo == l
    # state leaves come first and carry the 'state/' prefix
    assert all(i["name"].startswith("state/")
               for i in sig["inputs"][:l])
    extra = [i["name"] for i in sig["inputs"][l:]]
    assert extra == ["x", "y", "key", "t_now", "lr"]
    # outputs: state' first (same count), then sorted metrics
    metrics = [o["name"] for o in sig["outputs"][lo:]]
    assert metrics == ["1/acc", "1/grad_norm", "1/loss",
                       "1/overflow_events"]
    # input state leaf order == output state leaf order (suffix match)
    in_names = [i["name"].split("state/")[1] for i in sig["inputs"][:l]]
    out_names = [o["name"].split("/", 1)[1] for o in sig["outputs"][:lo]]
    assert in_names == out_names


def test_hlo_text_is_loadable_format(tiny_cfg, tmp_path):
    """The emitted text must be XLA HLO (not StableHLO MLIR), tuple-rooted."""
    entries = {e.name: e for e in aot.build_entries(tiny_cfg)}
    text, sig = entries["crossbar_vmm"].lower()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple
    assert "tuple(" in text.replace(") ", "(") or "(f32[" in text
    assert len(sig["inputs"]) == 3


def test_lower_config_writes_artifacts(tmp_path, tiny_cfg, monkeypatch):
    import dataclasses
    cfg = dataclasses.replace(tiny_cfg, name="pytest_lower",
                              with_baseline=False)
    aot.lower_config(cfg, str(tmp_path))
    out = tmp_path / "pytest_lower"
    man = json.loads((out / "manifest.json").read_text())
    assert man["config"]["name"] == "pytest_lower"
    assert man["num_weights"] == resnet.num_weights(cfg.net)
    for name, e in man["entries"].items():
        assert (out / e["file"]).exists(), name
        assert e["file"].endswith(".hlo.txt")
    # idempotence: second call is a no-op (stamp check)
    stamp = (out / ".stamp").read_text()
    aot.lower_config(cfg, str(tmp_path))
    assert (out / ".stamp").read_text() == stamp


def test_source_fingerprint_stable():
    assert aot._source_fingerprint() == aot._source_fingerprint()
