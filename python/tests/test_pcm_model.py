"""Statistical validation of the JAX PCM device model."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import pcm_model
from compile.configs import PcmConfig


def cfg(**kw) -> PcmConfig:
    return dataclasses.replace(PcmConfig(), **kw)


def ideal(**kw) -> PcmConfig:
    return cfg(nonlinear=False, write_noise=False, read_noise=False,
               drift=False, **kw)


def test_init_arrays_shapes_and_nu(key):
    arr = pcm_model.init_arrays(key, (50, 50), cfg())
    assert arr.g.shape == (50, 50)
    assert float(jnp.min(arr.nu)) >= 0.0
    assert float(jnp.max(arr.nu)) <= 0.12
    nu_std = float(jnp.std(arr.nu))
    assert 0.004 < nu_std < 0.010  # ~drift_nu_sigma
    assert int(jnp.sum(arr.set_count)) == 0


def test_linear_programming_exact(key):
    c = ideal()
    arr = pcm_model.init_arrays(key, (4,), c)
    target = jnp.array([0.35, 0.0, 0.1, 0.95])
    arr2 = pcm_model.program_increment(arr, target, 1.0, key, c, 10)
    # dg0=0.1: pulses = ceil(target/0.1), increment = pulses * 0.1
    np.testing.assert_allclose(
        np.asarray(arr2.g), [0.4, 0.0, 0.1, 1.0], atol=1e-6)
    np.testing.assert_array_equal(np.asarray(arr2.set_count), [4, 0, 1, 10])
    # untouched element keeps its t_prog
    assert float(arr2.t_prog[1]) == 0.0
    assert float(arr2.t_prog[0]) == 1.0


def test_nonlinear_aggregate_monotone_and_saturating(key):
    c = cfg(write_noise=False, read_noise=False, drift=False)
    # increments shrink as pulse count grows
    inc0 = pcm_model.expected_increment(jnp.float32(0.0), jnp.float32(1.0), c)
    inc20 = pcm_model.expected_increment(jnp.float32(20.0), jnp.float32(1.0), c)
    assert float(inc20) < float(inc0)
    # inverse (pulses_for_target) round-trips the aggregate
    for p0 in [0.0, 5.0, 17.0]:
        dg = 0.23
        n = pcm_model.pulses_for_target(
            jnp.float32(p0), jnp.float32(dg), c, 100)
        realized = pcm_model.expected_increment(
            jnp.float32(p0), n, c)
        assert float(realized) >= dg - 1e-5  # ceil() overshoots slightly
        under = pcm_model.expected_increment(jnp.float32(p0), n - 1, c)
        assert float(under) < dg + 1e-5


def test_write_noise_statistics(key):
    c = cfg(nonlinear=False, read_noise=False, drift=False)
    arr = pcm_model.init_arrays(key, (20000,), c)
    arr2 = pcm_model.program_increment(
        arr, jnp.full((20000,), 0.1), 0.0, key, c, 10)
    g = np.asarray(arr2.g)
    assert abs(g.mean() - 0.1) < 2e-3
    assert abs(g.std() - c.write_sigma * c.dg0) < 4e-3


def test_drift_power_law(key):
    c = cfg(write_noise=False, read_noise=False, drift_nu_sigma=0.0)
    arr = pcm_model.init_arrays(key, (8,), c)
    arr = pcm_model.program_increment(
        arr, jnp.full((8,), 0.5), 100.0, key, c, 10)
    g0 = np.asarray(pcm_model.drifted_conductance(arr, 100.0 + 1.0, c))
    g_day = np.asarray(pcm_model.drifted_conductance(arr, 100.0 + 86400.0, c))
    ratio = g_day / g0
    expect = 86400.0 ** (-c.drift_nu)
    np.testing.assert_allclose(ratio, expect, rtol=1e-3)
    # drift disabled -> no decay
    c_off = dataclasses.replace(c, drift=False)
    g_off = np.asarray(pcm_model.drifted_conductance(arr, 1e9, c_off))
    np.testing.assert_allclose(g_off, np.asarray(arr.g))


def test_reset_masks(key):
    c = ideal()
    arr = pcm_model.init_arrays(key, (4,), c)
    arr = pcm_model.program_increment(
        arr, jnp.full((4,), 0.3), 0.0, key, c, 10)
    mask = jnp.array([True, False, True, False])
    arr2 = pcm_model.reset(arr, 5.0, mask)
    np.testing.assert_allclose(np.asarray(arr2.g), [0.0, 0.3, 0.0, 0.3],
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(arr2.reset_count), [1, 0, 1, 0])


def test_read_noise_zero_mean(key):
    c = cfg(nonlinear=False, write_noise=False, drift=False)
    arr = pcm_model.init_arrays(key, (1,), c)
    arr = pcm_model.program_increment(
        arr, jnp.array([0.5]), 0.0, key, c, 10)
    keys = jax.random.split(jax.random.PRNGKey(7), 2000)
    reads = jnp.stack([pcm_model.read(arr, 0.0, k, c)[0] for k in keys[:200]])
    assert abs(float(reads.mean()) - 0.5) < 0.005
    assert abs(float(reads.std()) - c.read_sigma) < 0.003


def test_binary_devices_hold_state_between_updates(key):
    """LSB-array design assumption: binary reads are reliable over the
    intervals the *training path* actually exposes them to — an active
    register is rewritten every few batches (seconds..minutes), and even a
    cold weight sees the full-training horizon (~1e5 s) only at mean
    drift.  (Year-long retention is an MSB property; the LSB array is not
    read at inference.)"""
    c = cfg()
    bits = jnp.ones((1000,), jnp.int32)
    levels = pcm_model.binary_write_levels(key, bits, c)
    t_prog = jnp.zeros((1000,))

    # worst-case nu device, typical inter-update gap
    nu_worst = jnp.full((1000,), 0.12)
    read = pcm_model.binary_read(levels, t_prog, nu_worst, 100.0,
                                 jax.random.PRNGKey(9), c)
    assert float(jnp.mean((read == 1).astype(jnp.float32))) > 0.99

    # mean-nu device, whole-training horizon
    nu_mean = jnp.full((1000,), c.drift_nu)
    read = pcm_model.binary_read(levels, t_prog, nu_mean, 1e5,
                                 jax.random.PRNGKey(11), c)
    assert float(jnp.mean((read == 1).astype(jnp.float32))) > 0.98

    # a RESET device never reads as SET, even after a year
    zeros = pcm_model.binary_read(jnp.zeros((1000,)), t_prog, nu_worst,
                                  3.2e7, jax.random.PRNGKey(10), c)
    assert float(jnp.mean(zeros.astype(jnp.float32))) < 0.01
