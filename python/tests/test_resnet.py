"""ResNet model-family tests: shapes, gradients, crossbar backward rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import resnet
from compile.configs import AdcDacConfig, NetConfig


def test_layer_specs_depths():
    for depth, n_layers in [(8, 8), (14, 14), (20, 20), (32, 32)]:
        net = NetConfig(depth=depth)
        specs = resnet.layer_specs(net)
        # 6n+2 convs + 1 fc == depth (He et al. count the fc layer):
        # stem + 6n stage convs + fc
        assert len(specs) == n_layers
        assert specs[0].name == "stem"
        assert specs[-1].name == "fc"
    with pytest.raises(AssertionError):
        resnet.layer_specs(NetConfig(depth=9))


def test_width_multiplier_scales_parameters():
    n1 = resnet.num_weights(NetConfig(depth=8, width_mult=1.0))
    n2 = resnet.num_weights(NetConfig(depth=8, width_mult=2.0))
    assert 3.0 < n2 / n1 < 4.5  # conv params ~ width^2


def test_resnet32_parameter_count_near_paper():
    """Paper: ResNet-32 has ~470 K trainable parameters."""
    net = NetConfig(depth=32, width_mult=1.0)
    n = resnet.num_weights(net)
    bn = sum(2 * c for _, c in resnet.bn_channels(net))
    total = n + bn
    assert 4.2e5 < total < 5.2e5, total


def test_forward_shapes_and_moments(tiny_cfg):
    net, adc = tiny_cfg.net, tiny_cfg.adc
    key = jax.random.PRNGKey(0)
    ws = resnet.he_init_weights(key, net)
    bn_params, bn_stats = resnet.init_bn(net)
    x = jax.random.normal(key, (4, 32, 32, 3))
    logits, moments = resnet.forward(
        ws, bn_params, bn_stats, x, None, net, adc, train=True,
        matmul_fn=resnet.exact_matmul)
    assert logits.shape == (4, 10)
    assert set(moments.keys()) == {n for n, _ in resnet.bn_channels(net)}
    # eval mode: no moments, still finite
    logits_e, m_e = resnet.forward(
        ws, bn_params, bn_stats, x, None, net, adc, train=False,
        matmul_fn=resnet.exact_matmul)
    assert m_e == {}
    assert bool(jnp.isfinite(logits_e).all())


def test_gradients_flow_to_all_layers(tiny_cfg):
    net, adc = tiny_cfg.net, tiny_cfg.adc
    key = jax.random.PRNGKey(1)
    ws = resnet.he_init_weights(key, net)
    bn_params, bn_stats = resnet.init_bn(net)
    x = jax.random.normal(key, (4, 32, 32, 3))
    y = jnp.array([0, 1, 2, 3])

    def loss_fn(ws):
        logits, _ = resnet.forward(
            ws, bn_params, bn_stats, x, None, net, adc, train=True,
            matmul_fn=resnet.exact_matmul)
        return resnet.cross_entropy(logits, y)

    grads = jax.grad(loss_fn)(ws)
    for spec, g in zip(resnet.layer_specs(net), grads):
        assert g.shape == spec.weight_shape
        assert float(jnp.abs(g).max()) > 0.0, f"dead gradient at {spec.name}"


def test_crossbar_backward_rules():
    """The custom VJP: dW is the exact digital outer product of the
    DAC-quantized input; dx flows through the noisy transposed read."""
    adc = AdcDacConfig()
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (6, 5))
    w = 0.3 * jax.random.normal(key, (5, 3))
    nf = 0.02 * jax.random.normal(key, (5, 3))
    nb = 0.02 * jax.random.normal(jax.random.PRNGKey(3), (5, 3))

    f = lambda x, w: resnet.crossbar_matmul(x, w, nf, nb, adc).sum()
    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    dy = jnp.ones((6, 3))

    from compile.kernels.pcm_vmm import dac_quantize
    expect_dw = dac_quantize(x, adc).T @ dy
    np.testing.assert_allclose(np.asarray(dw), np.asarray(expect_dw),
                               atol=1e-5)
    # dx uses (w + nb)^T (scaled DAC/ADC path); with dy == ones the scale
    # is 1 so quantization error is bounded by the converter steps.
    rough = dy @ (w + nb).T
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rough), atol=0.2)
    # and crucially, dx is NOT computed with the forward noise
    rough_f = dy @ (w + nf).T
    assert not np.allclose(np.asarray(dx), np.asarray(rough_f), atol=1e-3)


def test_option_a_shortcut():
    x = jnp.arange(2 * 8 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 8, 4)
    s = resnet._shortcut(x, 8, 2)
    assert s.shape == (2, 4, 4, 8)
    # first 4 channels preserved (subsampled), rest zero
    np.testing.assert_allclose(np.asarray(s[..., 4:]), 0.0)
    np.testing.assert_allclose(np.asarray(s[..., :4]),
                               np.asarray(x[:, ::2, ::2, :]))


def test_cross_entropy_and_accuracy():
    logits = jnp.array([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]])
    labels = jnp.array([0, 1, 1])
    assert float(resnet.cross_entropy(logits, labels)) > 0.0
    assert abs(float(resnet.accuracy(logits, labels)) - 2 / 3) < 1e-6
    perfect = resnet.cross_entropy(logits, jnp.array([0, 1, 0]))
    assert float(perfect) < 1e-3


def test_stage_widths_respect_minimum():
    net = NetConfig(width_mult=0.05)
    assert min(net.stage_widths) >= 4
