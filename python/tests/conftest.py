"""Shared pytest fixtures for the compile-path test suite."""

import os
import sys

# Make `compile.*` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import pytest

from compile.configs import (AdcDacConfig, ExperimentConfig, HicConfig,
                             NetConfig, PcmConfig, TrainConfig)


@pytest.fixture(scope="session")
def tiny_cfg() -> ExperimentConfig:
    """A minimal config for fast model-level tests."""
    return ExperimentConfig(
        name="pytest_tiny",
        net=NetConfig(depth=8, width_mult=0.25),
        train=TrainConfig(batch_size=4),
        with_baseline=True,
    )


@pytest.fixture(scope="session")
def adc() -> AdcDacConfig:
    return AdcDacConfig()


@pytest.fixture(scope="session")
def pcm() -> PcmConfig:
    return PcmConfig()


@pytest.fixture(scope="session")
def hic_cfg() -> HicConfig:
    return HicConfig()


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
