#!/usr/bin/env python3
"""Compare two in-tree bench artifacts (``BENCH_*.json``) case by case.

The bench harness (``rust/src/bench``) writes one JSON document per
suite: ``{"suite": ..., "cases": {name: {median_ns, ...}}, "speedups":
{label: ratio}}``.  This tool prints a per-case table of the old vs new
median wall time and the resulting speedup (``old / new`` — > 1 means
the new run is faster), plus the delta of any named speedup series both
artifacts share.  Series labels starting with ``mem_`` are memory
datapoints (bytes, lower is better — e.g. the conv patch-staging
footprint per lowering) and are rendered as sizes with an ``old / new``
reduction factor instead of a speedup.  CI uses it to post the perf
trajectory of a branch
against the latest main-branch artifact in the job summary
(``--markdown``).

Usage:
    python3 python/bench_diff.py OLD.json NEW.json [--markdown]

Exit code 0 always (reporting tool, not a gate): regressions are for
humans to read, goldens and property suites are the correctness gates.
"""
import argparse
import json
import sys


def load(path):
    with open(path) as f:
        doc = json.load(f)
    for key in ("suite", "cases"):
        if key not in doc:
            raise ValueError(f"{path}: not a bench artifact "
                             f"(missing '{key}')")
    return doc


def fmt_ns(ns):
    if ns >= 1e9:
        return "%.2f s" % (ns / 1e9)
    if ns >= 1e6:
        return "%.2f ms" % (ns / 1e6)
    if ns >= 1e3:
        return "%.2f us" % (ns / 1e3)
    return "%.0f ns" % ns


def fmt_bytes(n):
    if n >= 1 << 30:
        return "%.2f GiB" % (n / (1 << 30))
    if n >= 1 << 20:
        return "%.2f MiB" % (n / (1 << 20))
    if n >= 1 << 10:
        return "%.2f KiB" % (n / (1 << 10))
    return "%.0f B" % n


def series_cells(label, old_v, new_v):
    """(old, new, delta) strings for one speedup-map entry — ``mem_``
    labels are bytes (lower is better), everything else a ratio."""
    if label.startswith("mem_"):
        reduction = old_v / new_v if new_v > 0 else float("inf")
        if 0.995 <= reduction <= 1.005:
            extra = "unchanged"
        elif reduction >= 1:
            extra = "%.2fx smaller" % reduction
        else:
            extra = "%.2fx larger" % (1 / reduction)
        return fmt_bytes(old_v), fmt_bytes(new_v), extra
    return "%.2fx" % old_v, "%.2fx" % new_v, ""


def diff_rows(old, new):
    """(name, old_median, new_median, ratio) for shared cases, plus
    names only one side has."""
    shared, only_old, only_new = [], [], []
    ocases, ncases = old["cases"], new["cases"]
    for name in sorted(set(ocases) | set(ncases)):
        if name in ocases and name in ncases:
            om = float(ocases[name]["median_ns"])
            nm = float(ncases[name]["median_ns"])
            ratio = om / nm if nm > 0 else float("inf")
            shared.append((name, om, nm, ratio))
        elif name in ocases:
            only_old.append(name)
        else:
            only_new.append(name)
    return shared, only_old, only_new


def render_text(old, new, shared, only_old, only_new):
    lines = ["bench diff [%s]: old=%d cases, new=%d cases"
             % (new["suite"], len(old["cases"]), len(new["cases"]))]
    if shared:
        width = max(len(n) for n, *_ in shared)
        lines.append("%-*s %12s %12s %9s" % (width, "case", "old median",
                                             "new median", "speedup"))
        for name, om, nm, ratio in shared:
            lines.append("%-*s %12s %12s %8.2fx"
                         % (width, name, fmt_ns(om), fmt_ns(nm), ratio))
    for name in only_old:
        lines.append("only in old: %s" % name)
    for name in only_new:
        lines.append("only in new: %s" % name)
    for label in sorted(set(old.get("speedups", {}))
                        & set(new.get("speedups", {}))):
        ov, nv, extra = series_cells(label, old["speedups"][label],
                                     new["speedups"][label])
        line = "series %-38s %10s -> %s" % (label, ov, nv)
        if extra:
            line += " (%s)" % extra
        lines.append(line)
    return "\n".join(lines)


def render_markdown(old, new, shared, only_old, only_new):
    lines = ["### Bench diff — `%s`" % new["suite"], "",
             "| case | old median | new median | speedup |",
             "|---|---:|---:|---:|"]
    for name, om, nm, ratio in shared:
        flag = "" if 0.95 <= ratio <= 1.05 else \
            (" 🟢" if ratio > 1.05 else " 🔴")
        lines.append("| `%s` | %s | %s | %.2fx%s |"
                     % (name, fmt_ns(om), fmt_ns(nm), ratio, flag))
    for name in only_old:
        lines.append("| `%s` | — | *(removed)* | |" % name)
    for name in only_new:
        lines.append("| `%s` | — | *(new)* | |" % name)
    series = sorted(set(old.get("speedups", {}))
                    & set(new.get("speedups", {})))
    if series:
        lines += ["", "| series | old | new | delta |",
                  "|---|---:|---:|---|"]
        for label in series:
            ov, nv, extra = series_cells(label, old["speedups"][label],
                                         new["speedups"][label])
            lines.append("| `%s` | %s | %s | %s |"
                         % (label, ov, nv, extra))
    lines.append("")
    return "\n".join(lines)


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="contender BENCH_*.json")
    ap.add_argument("--markdown", action="store_true",
                    help="emit a GitHub-flavored markdown table "
                         "(for $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    old, new = load(args.old), load(args.new)
    if old["suite"] != new["suite"]:
        print("warning: comparing different suites (%s vs %s)"
              % (old["suite"], new["suite"]), file=sys.stderr)
    shared, only_old, only_new = diff_rows(old, new)
    render = render_markdown if args.markdown else render_text
    print(render(old, new, shared, only_old, only_new))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
