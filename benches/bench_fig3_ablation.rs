//! FIG3 bench — end-to-end train-step cost across PCM-model ablations.
//!
//! The accuracy study itself is `hic-train fig3`; this target measures
//! what each non-ideality *costs* in simulation time (the ablation's
//! system-side counterpart): linear vs +noise terms vs the full model.

use hic_train::bench::Bench;
use hic_train::runtime::artifact::artifact_root;
use hic_train::runtime::{Engine, HostTensor};
use hic_train::util::rng::Pcg64;

fn main() {
    if !cfg!(feature = "pjrt") {
        println!("[fig3] SKIP: built without the `pjrt` feature \
                  (stub runtime backend)");
        return;
    }
    let mut b = Bench::new("fig3");
    let mut rng = Pcg64::new(9, 0);
    for tag in ["linear", "nonlinear", "full"] {
        let dir = artifact_root().join(format!("fig3_{tag}"));
        if !dir.join("manifest.json").exists() {
            println!("[fig3] SKIP {tag}: artifacts missing \
                      (python -m compile.aot --sets fig3)");
            continue;
        }
        let engine = Engine::load(&dir).expect("engine");
        engine.warmup(&["hic_init", "hic_train_step"]).expect("warmup");
        let bsz = engine.manifest.batch_size();
        let mut state = engine.init_state("hic_init", [0, 2]).expect("init");
        let img = bsz * 32 * 32 * 3;
        let x: Vec<f32> =
            (0..img).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let xt = HostTensor::from_f32(&[bsz, 32, 32, 3], &x);
        let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
        let yt = HostTensor::from_i32(&[bsz], &y);
        let mut step = 0u32;
        b.bench_with_elements(
            &format!("train_step[{tag}]"),
            Some(engine.manifest.num_weights as f64),
            || {
                step += 1;
                let m = engine
                    .call_stateful(
                        "hic_train_step",
                        &mut state,
                        &[xt.clone(), yt.clone(),
                          HostTensor::key([1, step]),
                          HostTensor::scalar_f32(step as f32 * 0.05),
                          HostTensor::scalar_f32(0.5)],
                    )
                    .expect("train");
                std::hint::black_box(m[2].scalar().unwrap());
            },
        );
    }
    b.finish();
}
