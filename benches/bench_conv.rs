//! Conv-on-grid training benches: full `NetTrainer` steps over the
//! ResNet-style layer graph (im2col patch lowering, per-layer grids,
//! transposed-VMM backprop, col2im scatter, hybrid updates) across
//! width multipliers and worker counts.
//!
//! `BENCH_conv.json` records conv steps/sec per case plus the headline
//! worker-scaling ratios — the evidence that the patch-strip sharding
//! parallelizes the conv path like the dense one.

use hic_train::bench::Bench;
use hic_train::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use hic_train::crossbar::TilingPolicy;
use hic_train::nn::features::{BlobDataset, FeatureSource};
use hic_train::nn::graph::GraphSpec;
use hic_train::pcm::device::PcmParams;
use hic_train::util::pool::WorkerPool;

const IMG: [usize; 3] = [8, 8, 3];
const STAGES: [usize; 3] = [8, 12, 16];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const TILE: usize = 32;

fn data() -> FeatureSource {
    let [h, w, c] = IMG;
    FeatureSource::Blobs(BlobDataset::with_shape(7, h, w, c, CLASSES,
                                                 0.4, 4096, 512))
}

fn trainer(width_permille: u32, workers: usize) -> NetTrainer {
    let spec = GraphSpec::resnet(IMG, STAGES, 1, CLASSES, width_permille);
    NetTrainer::from_spec(
        PcmParams::default(), &spec,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE }, data(),
        WorkerPool::new(workers),
        NetTrainerOptions { batch: BATCH, ..Default::default() })
}

fn main() {
    let mut b = Bench::new("conv");
    // One benched element = one trained sample (batch per step).
    let elements = BATCH as f64;

    // Width sweep, serial.
    for w in [500u32, 1000, 1500] {
        let mut t = trainer(w, 1);
        b.bench_with_elements(
            &format!("resnet_step_w{w}_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Worker scaling at width 1.0.
    for workers in [2usize, 4] {
        let mut t = trainer(1000, workers);
        b.bench_with_elements(
            &format!("resnet_step_w1000_workers{workers}"),
            Some(elements), || t.train_steps(1));
    }

    let mut speedups = Vec::new();
    for (label, base, cont) in [
        ("conv_w4_vs_w1",
         "resnet_step_w1000_workers1", "resnet_step_w1000_workers4"),
        ("conv_w2_vs_w1",
         "resnet_step_w1000_workers1", "resnet_step_w1000_workers2"),
    ] {
        if let Some(s) = b.speedup(base, cont) {
            println!("[conv] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    b.write_json(std::path::Path::new("BENCH_conv.json"), &speedups)
        .expect("writing BENCH_conv.json");
    b.finish();
}
