//! Conv-on-grid training benches: full `NetTrainer` steps over the
//! ResNet-style layer graph across width multipliers, worker counts
//! and **conv lowerings** — the weight-stationary streaming path
//! (default: on-demand patch segments, fused col2im drain) against the
//! retained materialized im2col path — plus the patch-VMM kernels in
//! isolation (streamed vs blocked-materialized vs the PR-4
//! sample-major reference) on this bench's stage-1 conv shape.
//!
//! `BENCH_conv.json` records conv steps/sec per case, the headline
//! worker-scaling and streamed-vs-materialized ratios, and —
//! piggybacked on the `speedups` map under `mem_` labels — the peak
//! patch-staging buffer bytes per lowering, the evidence that the
//! streaming rework removed the `[m·P, k²·cin]` patch matrices.

use hic_train::bench::Bench;
use hic_train::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use hic_train::crossbar::conv::{im2col_into, ConvPatchSource, PatchGeom,
                                PatchPlan};
use hic_train::crossbar::grid::CrossbarGrid;
use hic_train::crossbar::quant::{AdcSpec, DacSpec};
use hic_train::crossbar::TilingPolicy;
use hic_train::hic::weight::HicGeometry;
use hic_train::nn::features::{BlobDataset, FeatureSource};
use hic_train::nn::graph::{ConvLowering, GraphSpec};
use hic_train::pcm::device::PcmParams;
use hic_train::util::pool::WorkerPool;

const IMG: [usize; 3] = [8, 8, 3];
const STAGES: [usize; 3] = [8, 12, 16];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const TILE: usize = 32;

fn data() -> FeatureSource {
    let [h, w, c] = IMG;
    FeatureSource::Blobs(BlobDataset::with_shape(7, h, w, c, CLASSES,
                                                 0.4, 4096, 512))
}

fn trainer(width_permille: u32, workers: usize,
           lowering: ConvLowering) -> NetTrainer {
    let spec = GraphSpec::resnet(IMG, STAGES, 1, CLASSES, width_permille);
    let mut t = NetTrainer::from_spec(
        PcmParams::default(), &spec,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE }, data(),
        WorkerPool::new(workers),
        NetTrainerOptions { batch: BATCH, ..Default::default() });
    t.net.set_conv_lowering(lowering);
    t
}

fn pattern(len: usize) -> Vec<f32> {
    (0..len).map(|i| (((i * 3) % 15) as f32 - 7.0) / 7.0).collect()
}

fn main() {
    let mut b = Bench::new("conv");
    // One benched element = one trained sample (batch per step).
    let elements = BATCH as f64;

    // Width sweep, serial, streamed lowering (the default).
    for w in [500u32, 1000, 1500] {
        let mut t = trainer(w, 1, ConvLowering::Streamed);
        b.bench_with_elements(
            &format!("resnet_step_w{w}_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Worker scaling at width 1.0, plus the materialized-lowering
    // twins at workers {1, 4} — same graph, same seeds, bit-identical
    // results, different staging strategy.  The trainers are kept
    // alive so their post-run patch-staging footprints can be read
    // back below.
    let mut mem = Vec::new();
    {
        let mut t = trainer(1000, 1, ConvLowering::Materialized);
        b.bench_with_elements(
            "resnet_step_w1000_workers1_materialized", Some(elements),
            || t.train_steps(1));
        mem.push(("mem_patch_bytes_resnet_w1000_materialized".to_string(),
                  t.net.patch_buf_bytes() as f64));
    }
    for workers in [2usize, 4] {
        let mut t = trainer(1000, workers, ConvLowering::Streamed);
        b.bench_with_elements(
            &format!("resnet_step_w1000_workers{workers}"),
            Some(elements), || t.train_steps(1));
        if workers == 4 {
            mem.push(("mem_patch_bytes_resnet_w1000_streamed".to_string(),
                      t.net.patch_buf_bytes() as f64));
        }
    }
    {
        let mut t = trainer(1000, 4, ConvLowering::Materialized);
        b.bench_with_elements(
            "resnet_step_w1000_workers4_materialized", Some(elements),
            || t.train_steps(1));
    }

    // The stage-1 body conv's patch VMM in isolation: this bench's 8x8
    // stride-1 3x3 shape at width 1.0 (cin = cout = STAGES[0]) driven
    // three ways — the PR-4 sample-major reference, the blocked
    // tile-stationary kernel over a materialized im2col matrix, and
    // the weight-stationary streamed kernel generating the same
    // segments on the fly from the once-DAC'd image.
    let geom = PatchGeom {
        in_h: IMG[0], in_w: IMG[1], cin: STAGES[0],
        kh: 3, kw: 3, cout: STAGES[0], stride: 1, pad: 1,
    };
    let (kk, co) = (geom.patch_len(), geom.cout);
    let rows = geom.patch_rows(BATCH);
    let plan = PatchPlan::new(geom);
    let mut grid = CrossbarGrid::new(
        PcmParams::default(), HicGeometry::default(), kk, co,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE },
        DacSpec::default(), AdcSpec::default(), 11);
    grid.program_init(&pattern(kk * co), 0.0, 0, &WorkerPool::serial());
    let x = pattern(BATCH * geom.in_len());
    let mut patches = vec![0.0f32; rows * kk];
    im2col_into(&geom, &x, BATCH, &WorkerPool::serial(), &mut patches);
    let mut qimg = x.clone();
    for v in &mut qimg {
        *v = grid.dac.convert(*v);
    }
    let mut scratch = grid.scratch();
    let mut out = vec![0.0f32; rows * co];
    let pelements = (rows * kk * co) as f64;
    let mut round = 1u64;
    for workers in [1usize, 4] {
        let pool = WorkerPool::new(workers);
        b.bench_with_elements(
            &format!("patchvmm_sample_major_{kk}x{co}_w{workers}"),
            Some(pelements),
            || {
                grid.vmm_batch_sample_major_into(
                    &patches, rows, 1.0, round, &pool, &mut scratch,
                    &mut out);
                round += 1;
                std::hint::black_box(&out);
            },
        );
        b.bench_with_elements(
            &format!("patchvmm_blocked_{kk}x{co}_w{workers}"),
            Some(pelements),
            || {
                grid.vmm_batch_into(&patches, rows, 1.0, round, &pool,
                                    &mut scratch, &mut out);
                round += 1;
                std::hint::black_box(&out);
            },
        );
        b.bench_with_elements(
            &format!("patchvmm_streamed_{kk}x{co}_w{workers}"),
            Some(pelements),
            || {
                let src = ConvPatchSource::new(&plan, &qimg);
                grid.vmm_batch_src_into(&src, rows, 1.0, round, 0,
                                        &pool, &mut scratch, &mut out);
                round += 1;
                std::hint::black_box(&out);
            },
        );
    }
    // Isolated-kernel patch staging: the materialized path holds the
    // full [m·P, k²·cin] matrix; the streamed path holds only the
    // DAC'd image it reads segments from.
    mem.push((format!("mem_patch_bytes_isolated_{kk}x{co}_materialized"),
              (patches.len() * std::mem::size_of::<f32>()) as f64));
    mem.push((format!("mem_patch_bytes_isolated_{kk}x{co}_streamed"),
              (qimg.len() * std::mem::size_of::<f32>()) as f64));

    let mut speedups = Vec::new();
    let sm_w1 = format!("patchvmm_sample_major_{kk}x{co}_w1");
    let bl_w1 = format!("patchvmm_blocked_{kk}x{co}_w1");
    let sm_w4 = format!("patchvmm_sample_major_{kk}x{co}_w4");
    let bl_w4 = format!("patchvmm_blocked_{kk}x{co}_w4");
    let st_w1 = format!("patchvmm_streamed_{kk}x{co}_w1");
    let st_w4 = format!("patchvmm_streamed_{kk}x{co}_w4");
    for (label, base, cont) in [
        ("conv_w4_vs_w1",
         "resnet_step_w1000_workers1", "resnet_step_w1000_workers4"),
        ("conv_w2_vs_w1",
         "resnet_step_w1000_workers1", "resnet_step_w1000_workers2"),
        ("conv_streamed_vs_materialized_w1",
         "resnet_step_w1000_workers1_materialized",
         "resnet_step_w1000_workers1"),
        ("conv_streamed_vs_materialized_w4",
         "resnet_step_w1000_workers4_materialized",
         "resnet_step_w1000_workers4"),
        ("patch_blocked_vs_sample_major_w1", sm_w1.as_str(),
         bl_w1.as_str()),
        ("patch_blocked_vs_sample_major_w4", sm_w4.as_str(),
         bl_w4.as_str()),
        ("patch_streamed_vs_materialized_w1", bl_w1.as_str(),
         st_w1.as_str()),
        ("patch_streamed_vs_materialized_w4", bl_w4.as_str(),
         st_w4.as_str()),
    ] {
        if let Some(s) = b.speedup(base, cont) {
            println!("[conv] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    // Memory series ride in the same map under `mem_` labels (bytes,
    // lower is better) — `python/bench_diff.py` renders them as sizes.
    for (label, bytes) in mem {
        println!("[conv] {label}: {bytes:.0} B");
        speedups.push((label, bytes));
    }
    b.write_json(std::path::Path::new("BENCH_conv.json"), &speedups)
        .expect("writing BENCH_conv.json");
    b.finish();
}
