//! Conv-on-grid training benches: full `NetTrainer` steps over the
//! ResNet-style layer graph (im2col patch lowering, per-layer grids,
//! transposed-VMM backprop, col2im scatter, hybrid updates) across
//! width multipliers and worker counts, plus the **blocked
//! tile-stationary patch-VMM kernels against the retained PR-4
//! sample-major reference** on this bench's stage-1 conv shape.
//!
//! `BENCH_conv.json` records conv steps/sec per case, the headline
//! worker-scaling ratios, and the blocked-vs-sample-major patch-VMM
//! series — the evidence that sample blocking turned the single-strip
//! conv patch VMM into a parallel, cache-resident kernel.

use hic_train::bench::Bench;
use hic_train::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use hic_train::crossbar::conv::{im2col_into, PatchGeom};
use hic_train::crossbar::grid::CrossbarGrid;
use hic_train::crossbar::quant::{AdcSpec, DacSpec};
use hic_train::crossbar::TilingPolicy;
use hic_train::hic::weight::HicGeometry;
use hic_train::nn::features::{BlobDataset, FeatureSource};
use hic_train::nn::graph::GraphSpec;
use hic_train::pcm::device::PcmParams;
use hic_train::util::pool::WorkerPool;

const IMG: [usize; 3] = [8, 8, 3];
const STAGES: [usize; 3] = [8, 12, 16];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const TILE: usize = 32;

fn data() -> FeatureSource {
    let [h, w, c] = IMG;
    FeatureSource::Blobs(BlobDataset::with_shape(7, h, w, c, CLASSES,
                                                 0.4, 4096, 512))
}

fn trainer(width_permille: u32, workers: usize) -> NetTrainer {
    let spec = GraphSpec::resnet(IMG, STAGES, 1, CLASSES, width_permille);
    NetTrainer::from_spec(
        PcmParams::default(), &spec,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE }, data(),
        WorkerPool::new(workers),
        NetTrainerOptions { batch: BATCH, ..Default::default() })
}

fn pattern(len: usize) -> Vec<f32> {
    (0..len).map(|i| (((i * 3) % 15) as f32 - 7.0) / 7.0).collect()
}

fn main() {
    let mut b = Bench::new("conv");
    // One benched element = one trained sample (batch per step).
    let elements = BATCH as f64;

    // Width sweep, serial.
    for w in [500u32, 1000, 1500] {
        let mut t = trainer(w, 1);
        b.bench_with_elements(
            &format!("resnet_step_w{w}_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Worker scaling at width 1.0.
    for workers in [2usize, 4] {
        let mut t = trainer(1000, workers);
        b.bench_with_elements(
            &format!("resnet_step_w1000_workers{workers}"),
            Some(elements), || t.train_steps(1));
    }

    // The stage-1 body conv's patch VMM in isolation: a real im2col
    // patch matrix (this bench's 8x8 stride-1 shape at width 1.0, cin =
    // cout = STAGES[0]) driven through the blocked tile-stationary
    // kernel vs the PR-4 sample-major reference.  At TILE = 32 the
    // grid is one column strip, so the sample-major kernel serializes
    // and the blocked one shards the m·P patch-row axis.
    let geom = PatchGeom {
        in_h: IMG[0], in_w: IMG[1], cin: STAGES[0],
        kh: 3, kw: 3, cout: STAGES[0], stride: 1, pad: 1,
    };
    let (kk, co) = (geom.patch_len(), geom.cout);
    let rows = geom.patch_rows(BATCH);
    let mut grid = CrossbarGrid::new(
        PcmParams::default(), HicGeometry::default(), kk, co,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE },
        DacSpec::default(), AdcSpec::default(), 11);
    grid.program_init(&pattern(kk * co), 0.0, 0, &WorkerPool::serial());
    let x = pattern(BATCH * geom.in_len());
    let mut patches = vec![0.0f32; rows * kk];
    im2col_into(&geom, &x, BATCH, &WorkerPool::serial(), &mut patches);
    let mut scratch = grid.scratch();
    let mut out = vec![0.0f32; rows * co];
    let pelements = (rows * kk * co) as f64;
    let mut round = 1u64;
    for workers in [1usize, 4] {
        let pool = WorkerPool::new(workers);
        b.bench_with_elements(
            &format!("patchvmm_sample_major_{kk}x{co}_w{workers}"),
            Some(pelements),
            || {
                grid.vmm_batch_sample_major_into(
                    &patches, rows, 1.0, round, &pool, &mut scratch,
                    &mut out);
                round += 1;
                std::hint::black_box(&out);
            },
        );
        b.bench_with_elements(
            &format!("patchvmm_blocked_{kk}x{co}_w{workers}"),
            Some(pelements),
            || {
                grid.vmm_batch_into(&patches, rows, 1.0, round, &pool,
                                    &mut scratch, &mut out);
                round += 1;
                std::hint::black_box(&out);
            },
        );
    }

    let mut speedups = Vec::new();
    let sm_w1 = format!("patchvmm_sample_major_{kk}x{co}_w1");
    let bl_w1 = format!("patchvmm_blocked_{kk}x{co}_w1");
    let sm_w4 = format!("patchvmm_sample_major_{kk}x{co}_w4");
    let bl_w4 = format!("patchvmm_blocked_{kk}x{co}_w4");
    for (label, base, cont) in [
        ("conv_w4_vs_w1",
         "resnet_step_w1000_workers1", "resnet_step_w1000_workers4"),
        ("conv_w2_vs_w1",
         "resnet_step_w1000_workers1", "resnet_step_w1000_workers2"),
        ("patch_blocked_vs_sample_major_w1", sm_w1.as_str(),
         bl_w1.as_str()),
        ("patch_blocked_vs_sample_major_w4", sm_w4.as_str(),
         bl_w4.as_str()),
    ] {
        if let Some(s) = b.speedup(base, cont) {
            println!("[conv] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    b.write_json(std::path::Path::new("BENCH_conv.json"), &speedups)
        .expect("writing BENCH_conv.json");
    b.finish();
}
