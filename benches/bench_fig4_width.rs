//! FIG4 bench — train-step cost vs network width multiplier, HIC vs FP32
//! baseline.  The step-time scaling with width is the system-side view of
//! the model-size sweep `hic-train fig4` measures for accuracy.

use hic_train::bench::Bench;
use hic_train::runtime::artifact::artifact_root;
use hic_train::runtime::{Engine, HostTensor};
use hic_train::util::rng::Pcg64;

fn main() {
    if !cfg!(feature = "pjrt") {
        println!("[fig4] SKIP: built without the `pjrt` feature \
                  (stub runtime backend)");
        return;
    }
    let mut b = Bench::new("fig4");
    let mut rng = Pcg64::new(13, 0);

    for w in ["0p5", "1p0"] {
        let dir = artifact_root().join(format!("fig4_hic_w{w}"));
        if !dir.join("manifest.json").exists() {
            println!("[fig4] SKIP hic w={w}: artifacts missing");
            continue;
        }
        let engine = Engine::load(&dir).expect("engine");
        engine.warmup(&["hic_init", "hic_train_step"]).expect("warmup");
        let bsz = engine.manifest.batch_size();
        let mut state = engine.init_state("hic_init", [0, 3]).expect("init");
        let x: Vec<f32> = (0..bsz * 3072)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let xt = HostTensor::from_f32(&[bsz, 32, 32, 3], &x);
        let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
        let yt = HostTensor::from_i32(&[bsz], &y);
        let mut step = 0u32;
        b.bench_with_elements(
            &format!("hic_train_step[w={w}]"),
            Some(engine.manifest.num_weights as f64),
            || {
                step += 1;
                let m = engine
                    .call_stateful(
                        "hic_train_step",
                        &mut state,
                        &[xt.clone(), yt.clone(),
                          HostTensor::key([1, step]),
                          HostTensor::scalar_f32(step as f32 * 0.05),
                          HostTensor::scalar_f32(0.5)],
                    )
                    .expect("train");
                std::hint::black_box(m[2].scalar().unwrap());
            },
        );
    }

    // FP32 baseline at matched width for the overhead ratio.
    for w in ["0p5", "1p0"] {
        let dir = artifact_root().join(format!("fig4_base_w{w}"));
        if !dir.join("manifest.json").exists() {
            println!("[fig4] SKIP base w={w}: artifacts missing");
            continue;
        }
        let engine = Engine::load(&dir).expect("engine");
        engine
            .warmup(&["baseline_init", "baseline_train_step"])
            .expect("warmup");
        let bsz = engine.manifest.batch_size();
        let mut state =
            engine.init_state("baseline_init", [0, 4]).expect("init");
        let x: Vec<f32> = (0..bsz * 3072)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let xt = HostTensor::from_f32(&[bsz, 32, 32, 3], &x);
        let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
        let yt = HostTensor::from_i32(&[bsz], &y);
        b.bench_with_elements(
            &format!("baseline_train_step[w={w}]"),
            Some(engine.manifest.num_weights as f64),
            || {
                let m = engine
                    .call_stateful(
                        "baseline_train_step",
                        &mut state,
                        &[xt.clone(), yt.clone(),
                          HostTensor::scalar_f32(0.1)],
                    )
                    .expect("train");
                std::hint::black_box(m[1].scalar().unwrap());
            },
        );
    }

    b.finish();
}
