//! HIC update-path benches: the fixed-point accumulator, full hybrid
//! weight updates, and the refresh cycle — host-side twins of the paper's
//! update phase (Fig. 2).

use hic_train::bench::Bench;
use hic_train::hic::fixedpoint::FixedPointAccumulator;
use hic_train::hic::weight::{HicGeometry, HicWeight};
use hic_train::pcm::device::PcmParams;
use hic_train::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new("hic");
    let mut rng = Pcg64::new(11, 0);

    // Raw accumulator updates
    let mut accs: Vec<FixedPointAccumulator> =
        vec![FixedPointAccumulator::new(7); 16384];
    let deltas: Vec<i32> =
        (0..16384).map(|i| ((i * 37) % 255) as i32 - 127).collect();
    b.bench_with_elements("fixedpoint_update_16k", Some(16384.0), || {
        let mut ovf = 0i64;
        for (a, &d) in accs.iter_mut().zip(&deltas) {
            ovf += a.update(d).overflow as i64;
        }
        std::hint::black_box(ovf);
    });

    // Full hybrid update (quantize -> accumulate -> overflow -> program)
    let geom = HicGeometry::default();
    let mut hw =
        HicWeight::new(PcmParams::default(), geom, 128, 128, &mut rng);
    hw.program_init(&vec![0.0f32; 128 * 128], 0.0, &mut rng);
    let grad: Vec<f32> = (0..128 * 128)
        .map(|i| ((i % 200) as f32 - 100.0) / 1000.0)
        .collect();
    let mut t = 1.0f32;
    b.bench_with_elements("hybrid_update_128x128",
                          Some((128 * 128) as f64), || {
        t += 0.05;
        std::hint::black_box(hw.apply_update(&grad, 0.5, t, &mut rng));
    });

    // Refresh after heavy updates
    b.bench_with_elements("refresh_128x128", Some((128 * 128) as f64), || {
        t += 0.05;
        std::hint::black_box(hw.refresh(t, &mut rng));
    });

    // Decode (inference read)
    b.bench_with_elements("decode_128x128", Some((128 * 128) as f64), || {
        std::hint::black_box(hw.decode(t));
    });

    b.finish();
}
