//! Grid-engine benches: multi-tile VMM scaling across worker counts
//! against the serial single-tile path, the batched Box–Muller noise
//! fill against the scalar Box–Muller loop, and the **blocked
//! tile-stationary strip kernels against the retained PR-4
//! sample-major reference** on the resnet conv patch-VMM shape
//! (`[kh·kw·cin, cout]` grid, `m·P` patch rows — the shape where the
//! sample-major kernel serialized on one column strip).
//!
//! `tile_vmm_batch16_serial_ref` replays the pre-grid cost model — one
//! whole-matrix `CrossbarTile` with the scalar per-element `normal()`
//! read-noise draw — on the same logical workload the 4×4 grid shards
//! across workers.  `BENCH_grid.json` records the cases plus the
//! headline speedups (grid@4 workers vs the serial single-tile path,
//! the noise-fill win, and the blocked-vs-sample-major patch-VMM
//! series at 1 and 4 workers).

use hic_train::bench::Bench;
use hic_train::crossbar::grid::CrossbarGrid;
use hic_train::crossbar::quant::{AdcSpec, DacSpec};
use hic_train::crossbar::tile::CrossbarTile;
use hic_train::crossbar::TilingPolicy;
use hic_train::hic::weight::{HicGeometry, HicWeight};
use hic_train::pcm::device::PcmParams;
use hic_train::util::pool::WorkerPool;
use hic_train::util::rng::Pcg64;

const K: usize = 128;
const N: usize = 128;
const TILE: usize = 32; // 4x4 grid
const M: usize = 16;

fn pattern(len: usize) -> Vec<f32> {
    (0..len).map(|i| (((i * 3) % 15) as f32 - 7.0) / 7.0).collect()
}

/// The pre-grid serial reference: whole-matrix tile, scalar-`normal()`
/// read noise per element (the PR-1 noise path).
fn vmm_batch_scalar_noise(t: &CrossbarTile, x: &[f32], m: usize,
                          t_now: f32, rng: &mut Pcg64,
                          out: &mut [f32]) {
    let (rows, cols) = (t.rows(), t.cols());
    let msb = &t.weights.msb;
    let nelem = rows * cols;
    let mut gp = vec![0.0f32; nelem];
    let mut gm = vec![0.0f32; nelem];
    msb.plus.drift_into(t_now, &mut gp);
    msb.minus.drift_into(t_now, &mut gm);
    let sigma_p = msb.plus.params.read_sigma;
    let sigma_m = msb.minus.params.read_sigma;
    let scale = msb.g_to_w(1.0);
    let mut w = vec![0.0f32; nelem];
    let mut xq = vec![0.0f32; rows];
    for s in 0..m {
        for (wv, &g) in w.iter_mut().zip(&gp) {
            *wv = (g + sigma_p * rng.normal() as f32).clamp(0.0, 1.0);
        }
        for (wv, &g) in w.iter_mut().zip(&gm) {
            *wv = (*wv - (g + sigma_m * rng.normal() as f32)
                .clamp(0.0, 1.0)) * scale;
        }
        for (q, &v) in xq.iter_mut().zip(&x[s * rows..(s + 1) * rows]) {
            *q = t.dac.convert(v);
        }
        let y = &mut out[s * cols..(s + 1) * cols];
        y.fill(0.0);
        for (r, &xv) in xq.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[r * cols..(r + 1) * cols];
            for (yc, &wc) in y.iter_mut().zip(row) {
                *yc += xv * wc;
            }
        }
        for yc in y.iter_mut() {
            *yc = t.adc.convert(*yc);
        }
    }
}

fn main() {
    let mut b = Bench::new("grid");
    let params = PcmParams::default();
    let geom = HicGeometry::default();
    let elements = (M * K * N) as f64;
    let w = pattern(K * N);
    let x = pattern(M * K);

    // Serial single-tile reference on the same logical matrix.
    let mut rng = Pcg64::new(1, 0);
    let mut hw = HicWeight::new(params, geom, K, N, &mut rng);
    hw.program_init(&w, 0.0, &mut rng);
    let tile = CrossbarTile::new(hw, DacSpec::default(),
                                 AdcSpec::default());
    let mut out = vec![0.0f32; M * N];
    let mut r = Pcg64::new(2, 0);
    b.bench_with_elements(
        &format!("tile_vmm_batch{M}_serial_ref_{K}x{N}"),
        Some(elements),
        || {
            vmm_batch_scalar_noise(&tile, &x, M, 1.0, &mut r, &mut out);
            std::hint::black_box(&out);
        },
    );
    // The current single-tile path (batched Box–Muller, still serial).
    let mut scratch = tile.scratch();
    b.bench_with_elements(
        &format!("tile_vmm_batch{M}_fill_{K}x{N}"),
        Some(elements),
        || {
            tile.vmm_batch_into(&x, M, 1.0, &mut r, &mut scratch,
                                &mut out);
            std::hint::black_box(&out);
        },
    );

    // The 4x4 grid at 1/2/4 workers.
    let mut grid = CrossbarGrid::new(
        params, geom, K, N,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE },
        DacSpec::default(), AdcSpec::default(), 5);
    grid.program_init(&w, 0.0, 0, &WorkerPool::serial());
    let mut gscratch = grid.scratch();
    let mut round = 1u64;
    for workers in [1usize, 2, 4] {
        let pool = WorkerPool::new(workers);
        b.bench_with_elements(
            &format!("grid_vmm_batch{M}_4x4_w{workers}"),
            Some(elements),
            || {
                grid.vmm_batch_into(&x, M, 1.0, round, &pool,
                                    &mut gscratch, &mut out);
                round += 1;
                std::hint::black_box(&out);
            },
        );
    }

    // The resnet conv patch-VMM shape: a [3·3·16, 16] grid driven over
    // m·P = 8·64 patch rows (the 8x8 stride-1 stem shape of the conv
    // bench).  One column strip -> the sample-major kernel serializes;
    // the blocked kernel shards the patch-row axis.
    const PK: usize = 3 * 3 * 16;
    const PN: usize = 16;
    const PROWS: usize = 8 * 64;
    let pw = pattern(PK * PN);
    let px = pattern(PROWS * PK);
    let mut pgrid = CrossbarGrid::new(
        params, geom, PK, PN,
        TilingPolicy { tile_rows: TILE, tile_cols: TILE },
        DacSpec::default(), AdcSpec::default(), 9);
    pgrid.program_init(&pw, 0.0, 0, &WorkerPool::serial());
    let mut pscratch = pgrid.scratch();
    let mut pout = vec![0.0f32; PROWS * PN];
    let pelements = (PROWS * PK * PN) as f64;
    for workers in [1usize, 4] {
        let pool = WorkerPool::new(workers);
        b.bench_with_elements(
            &format!("patchvmm_sample_major_{PK}x{PN}_w{workers}"),
            Some(pelements),
            || {
                pgrid.vmm_batch_sample_major_into(
                    &px, PROWS, 1.0, round, &pool, &mut pscratch,
                    &mut pout);
                round += 1;
                std::hint::black_box(&pout);
            },
        );
        b.bench_with_elements(
            &format!("patchvmm_blocked_{PK}x{PN}_w{workers}"),
            Some(pelements),
            || {
                pgrid.vmm_batch_into(&px, PROWS, 1.0, round, &pool,
                                     &mut pscratch, &mut pout);
                round += 1;
                std::hint::black_box(&pout);
            },
        );
    }
    // The transposed direction on the same shape (the conv backward
    // patch-gradient kernel).
    let pe = pattern(PROWS * PN);
    let mut pout_t = vec![0.0f32; PROWS * PK];
    {
        let pool = WorkerPool::new(4);
        b.bench_with_elements(
            &format!("patchvmm_t_sample_major_{PK}x{PN}_w4"),
            Some(pelements),
            || {
                pgrid.vmm_t_batch_sample_major_into(
                    &pe, PROWS, 1.0, round, &pool, &mut pscratch,
                    &mut pout_t);
                round += 1;
                std::hint::black_box(&pout_t);
            },
        );
        b.bench_with_elements(
            &format!("patchvmm_t_blocked_{PK}x{PN}_w4"),
            Some(pelements),
            || {
                pgrid.vmm_t_batch_into(&pe, PROWS, 1.0, round, &pool,
                                       &mut pscratch, &mut pout_t);
                round += 1;
                std::hint::black_box(&pout_t);
            },
        );
    }

    // Noise fill: scalar Box–Muller loop vs the batched fill.
    let mut noise = vec![0.0f32; 65_536];
    let mut r = Pcg64::new(3, 0);
    b.bench_with_elements("fill_normal_scalar_65536", Some(65_536.0), || {
        r.fill_normal(&mut noise, 0.0, 1.0);
        std::hint::black_box(&noise);
    });
    b.bench_with_elements("fill_gaussian_65536", Some(65_536.0), || {
        r.fill_gaussian(&mut noise, 0.0, 1.0);
        std::hint::black_box(&noise);
    });

    let mut speedups = Vec::new();
    for (label, base, cont) in [
        ("grid_w4_vs_serial_tile",
         format!("tile_vmm_batch{M}_serial_ref_{K}x{N}"),
         format!("grid_vmm_batch{M}_4x4_w4")),
        ("grid_w4_vs_w1",
         format!("grid_vmm_batch{M}_4x4_w1"),
         format!("grid_vmm_batch{M}_4x4_w4")),
        // The acceptance series: blocked tile-stationary strips vs the
        // PR-4 sample-major kernel on the conv patch-VMM shape.
        ("patch_blocked_vs_sample_major_w1",
         format!("patchvmm_sample_major_{PK}x{PN}_w1"),
         format!("patchvmm_blocked_{PK}x{PN}_w1")),
        ("patch_blocked_vs_sample_major_w4",
         format!("patchvmm_sample_major_{PK}x{PN}_w4"),
         format!("patchvmm_blocked_{PK}x{PN}_w4")),
        ("patch_t_blocked_vs_sample_major_w4",
         format!("patchvmm_t_sample_major_{PK}x{PN}_w4"),
         format!("patchvmm_t_blocked_{PK}x{PN}_w4")),
        ("fill_gaussian_vs_scalar",
         "fill_normal_scalar_65536".to_string(),
         "fill_gaussian_65536".to_string()),
    ] {
        if let Some(s) = b.speedup(&base, &cont) {
            println!("[grid] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    b.write_json(std::path::Path::new("BENCH_grid.json"), &speedups)
        .expect("writing BENCH_grid.json");
    b.finish();
}
