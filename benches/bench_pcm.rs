//! PCM device-model benches: programming, reads, drift evaluation —
//! the substrate costs behind every host-side analysis.

use hic_train::bench::Bench;
use hic_train::pcm::array::DifferentialPair;
use hic_train::pcm::device::{PcmDevice, PcmParams};
use hic_train::pcm::endurance::{EnduranceLedger, Histogram};
use hic_train::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new("pcm");
    let params = PcmParams::default();
    let mut rng = Pcg64::new(7, 0);

    // Single-device pulse application
    let mut dev = PcmDevice::new(&params, &mut rng);
    b.bench("set_pulse", || {
        dev.set_pulse(&params, 1.0, &mut rng);
        if dev.g >= 1.0 {
            dev.reset(1.0);
        }
    });

    // Array-level programming (16k devices)
    let mut pair = DifferentialPair::new(params, 128, 128, 1.0, &mut rng);
    let w: Vec<f32> = (0..128 * 128)
        .map(|i| ((i % 13) as f32 - 6.0) / 7.0)
        .collect();
    b.bench_with_elements("program_weights_128x128",
                          Some((128 * 128) as f64), || {
        pair.program_weights(&w, 1.0, &mut rng);
    });

    // Drift-decoded full-array read
    b.bench_with_elements("decode_drifted_128x128",
                          Some((128 * 128) as f64), || {
        std::hint::black_box(pair.decode(1e6));
    });

    // Stochastic read
    b.bench_with_elements("noisy_read_128x128",
                          Some((128 * 128) as f64), || {
        std::hint::black_box(pair.read_weights(1e6, &mut rng));
    });

    // Selective refresh scan (mostly a predicate sweep when healthy)
    b.bench_with_elements("refresh_scan_128x128",
                          Some((128 * 128) as f64), || {
        std::hint::black_box(pair.refresh(1e6, &mut rng));
    });

    // Endurance ledger ingestion
    b.bench_with_elements("ledger_record_16k", Some(16384.0), || {
        let mut l = EnduranceLedger::new();
        for i in 0..16384u64 {
            l.record_msb(i % 300, i % 29);
        }
        std::hint::black_box(l.msb.max);
    });

    // Histogram ops
    let mut h = Histogram::new();
    for i in 0..100_000u64 {
        h.add(i % 20_000);
    }
    b.bench("histogram_percentile", || {
        std::hint::black_box(h.percentile(95.0));
    });

    b.finish();
}
