//! PCM device-model benches: programming, reads, drift evaluation —
//! the substrate costs behind every host-side analysis.
//!
//! The `aos_ref_*` cases re-run the seed's array-of-structs path (a
//! `Vec<PcmDevice>` walked device-by-device with `powf` drift and a
//! fresh allocation per read) against the planar SoA kernels, and the
//! suite emits `BENCH_pcm_soa.json` with the measured speedups — the
//! before/after datapoint for the planar-state-engine refactor.

use hic_train::bench::Bench;
use hic_train::pcm::array::{DifferentialPair, PcmArray};
use hic_train::pcm::device::{PcmDevice, PcmParams};
use hic_train::pcm::endurance::{EnduranceLedger, Histogram};
use hic_train::util::rng::Pcg64;

/// Gather the scalar (seed-layout) twin of one planar array.
fn aos_twin(arr: &PcmArray) -> Vec<PcmDevice> {
    (0..arr.len()).map(|i| arr.device_at(i)).collect()
}

fn main() {
    let mut b = Bench::new("pcm");
    let params = PcmParams::default();
    let mut rng = Pcg64::new(7, 0);
    let n = 128 * 128;

    // Single-device pulse application (scalar reference path)
    let mut dev = PcmDevice::new(&params, &mut rng);
    b.bench("set_pulse", || {
        dev.set_pulse(&params, 1.0, &mut rng);
        if dev.g >= 1.0 {
            dev.reset(1.0);
        }
    });

    // Array-level programming (16k devices, planar sweep)
    let mut pair = DifferentialPair::new(params, 128, 128, 1.0, &mut rng);
    let w: Vec<f32> = (0..n)
        .map(|i| ((i % 13) as f32 - 6.0) / 7.0)
        .collect();
    b.bench_with_elements("program_weights_128x128", Some(n as f64), || {
        pair.program_weights(&w, 1.0, &mut rng);
    });

    // ---- the SoA-vs-AoS headline cases --------------------------------
    let plus_twin = aos_twin(&pair.plus);
    let minus_twin = aos_twin(&pair.minus);

    // (a) whole-array drifted decode: seed-style device walk + alloc...
    b.bench_with_elements("decode_drifted_aos_ref_128x128",
                          Some(n as f64), || {
        let out: Vec<f32> = plus_twin
            .iter()
            .zip(&minus_twin)
            .map(|(p, m)| {
                pair.g_to_w(p.drifted(&params, 1e6)
                    - m.drifted(&params, 1e6))
            })
            .collect();
        std::hint::black_box(out);
    });
    // ...vs the planar fused kernel into a reused buffer.
    let mut decode_buf = vec![0f32; n];
    b.bench_with_elements("decode_drifted_planar_128x128",
                          Some(n as f64), || {
        pair.decode_into(1e6, &mut decode_buf);
        std::hint::black_box(&decode_buf);
    });

    // (b) whole-array stochastic read: seed-style per-device reads...
    b.bench_with_elements("noisy_read_aos_ref_128x128",
                          Some(n as f64), || {
        let gp: Vec<f32> = plus_twin
            .iter()
            .map(|d| d.read(&params, 1e6, &mut rng))
            .collect();
        let out: Vec<f32> = gp
            .iter()
            .zip(&minus_twin)
            .map(|(p, m)| pair.g_to_w(p - m.read(&params, 1e6, &mut rng)))
            .collect();
        std::hint::black_box(out);
    });
    // ...vs the planar batched read into a reused buffer.
    let mut read_buf = vec![0f32; n];
    b.bench_with_elements("noisy_read_planar_128x128",
                          Some(n as f64), || {
        pair.read_weights_into(1e6, &mut rng, &mut read_buf);
        std::hint::black_box(&read_buf);
    });

    // Selective refresh scan (mostly a predicate sweep when healthy)
    b.bench_with_elements("refresh_scan_128x128", Some(n as f64), || {
        std::hint::black_box(pair.refresh(1e6, &mut rng));
    });

    // Endurance ledger ingestion (planar count-plane sweep)
    b.bench_with_elements("ledger_record_planes_16k", Some(16384.0), || {
        let mut l = EnduranceLedger::new();
        l.record_msb_planes(&pair.plus.set_count, &pair.plus.reset_count);
        std::hint::black_box(l.msb.max);
    });

    // Histogram ops
    let mut h = Histogram::new();
    for i in 0..100_000u64 {
        h.add(i % 20_000);
    }
    b.bench("histogram_percentile", || {
        std::hint::black_box(h.percentile(95.0));
    });

    // Emit the before/after datapoint for the SoA refactor.  Speedups
    // are keyed by the planar case name so tooling can join each ratio
    // back to its measurements in the `cases` map.
    let mut speedups = Vec::new();
    for (base, plan) in [
        ("decode_drifted_aos_ref_128x128",
         "decode_drifted_planar_128x128"),
        ("noisy_read_aos_ref_128x128",
         "noisy_read_planar_128x128"),
    ] {
        if let Some(s) = b.speedup(base, plan) {
            println!("[pcm] {plan}: {s:.2}x over {base}");
            speedups.push((plan.to_string(), s));
        }
    }
    if let Err(e) = b.write_json(
        std::path::Path::new("BENCH_pcm_soa.json"), &speedups)
    {
        eprintln!("[pcm] could not write BENCH_pcm_soa.json: {e}");
    }

    b.finish();
}
