//! FIG5 bench — drifted-inference and AdaBS-calibration step costs.
//!
//! Confirms the system property behind Fig. 5's practicality argument:
//! evaluating at any drift time costs the same (drift is a read-time
//! power law, not a state rewrite), and one AdaBS calibration batch costs
//! about one eval step.

use hic_train::bench::Bench;
use hic_train::runtime::artifact::artifact_root;
use hic_train::runtime::{Engine, HostTensor};
use hic_train::util::rng::Pcg64;

fn main() {
    if !cfg!(feature = "pjrt") {
        println!("[fig5] SKIP: built without the `pjrt` feature \
                  (stub runtime backend)");
        return;
    }
    let dir = artifact_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        println!("[fig5] SKIP: tiny artifacts missing (make artifacts)");
        return;
    }
    let mut b = Bench::new("fig5");
    let engine = Engine::load(&dir).expect("engine");
    engine
        .warmup(&["hic_init", "hic_eval_step", "hic_adabs"])
        .expect("warmup");
    let bsz = engine.manifest.batch_size();
    let mut rng = Pcg64::new(17, 0);
    let mut state = engine.init_state("hic_init", [0, 5]).expect("init");
    let x: Vec<f32> =
        (0..bsz * 3072).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let xt = HostTensor::from_f32(&[bsz, 32, 32, 3], &x);
    let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
    let yt = HostTensor::from_i32(&[bsz], &y);

    for t in [1e2f32, 1e6, 4e7] {
        b.bench(&format!("eval_step@t={t:.0e}s"), || {
            let m = engine
                .call_stateful(
                    "hic_eval_step",
                    &mut state,
                    &[xt.clone(), yt.clone(), HostTensor::key([1, 1]),
                      HostTensor::scalar_f32(t)],
                )
                .expect("eval");
            std::hint::black_box(m[0].scalar_i64().unwrap());
        });
    }

    let mut k = 0u32;
    b.bench("adabs_calibration_batch", || {
        k += 1;
        engine
            .call_stateful(
                "hic_adabs",
                &mut state,
                &[xt.clone(), HostTensor::key([2, k]),
                  HostTensor::scalar_f32(1e6),
                  HostTensor::scalar_f32(k as f32)],
            )
            .expect("adabs");
    });

    b.finish();
}
