//! Multi-layer device-training benches: full `NetTrainer` steps
//! (forward VMMs + transposed-VMM backprop + hybrid updates) across
//! layer counts, width multipliers, worker counts and backward/update
//! schedules.
//!
//! `BENCH_nn.json` records steps/sec per case plus the headline
//! worker-scaling and pipelined-vs-phase-serial ratios — the evidence
//! that the backward pass shards like the forward pass does, and that
//! overlapping per-layer gradient/update chains with the backward VMM
//! walk ([`TrainMode::Pipelined`]) pushes step time toward VMM-only
//! time.  The historical series pin [`TrainMode::PhaseSerial`]
//! explicitly so their deltas stay comparable across PRs; the
//! `_pipelined_` / `_serial_` pairs are the overlap measurement, run
//! at the CI matrix worker counts {1, 4, 8}.

use hic_train::bench::Bench;
use hic_train::coordinator::nettrainer::{NetTrainer, NetTrainerOptions,
                                         TrainMode};
use hic_train::crossbar::TilingPolicy;
use hic_train::nn::features::{BlobDataset, FeatureSource};
use hic_train::nn::net::NetSpec;
use hic_train::pcm::device::PcmParams;
use hic_train::util::pool::WorkerPool;

const DIM: usize = 64;
const CLASSES: usize = 10;
const BATCH: usize = 16;
const TILE: usize = 32;

fn data() -> FeatureSource {
    FeatureSource::Blobs(BlobDataset::new(7, DIM, CLASSES, 0.4, 4096, 512))
}

fn trainer(hidden: &[usize], width_permille: u32, workers: usize,
           mode: TrainMode) -> NetTrainer {
    let spec = NetSpec {
        input: DIM,
        hidden_base: hidden.to_vec(),
        classes: CLASSES,
        width_permille,
    };
    NetTrainer::new(
        PcmParams::default(), &spec.dims(),
        TilingPolicy { tile_rows: TILE, tile_cols: TILE }, data(),
        WorkerPool::new(workers),
        NetTrainerOptions { batch: BATCH, mode, ..Default::default() })
}

fn main() {
    let mut b = Bench::new("nn");
    // One benched element = one trained sample (batch per step).
    let elements = BATCH as f64;

    // Depth sweep at width 1.0, serial.
    for hidden in [&[128][..], &[128, 64][..], &[128, 96, 64][..]] {
        let mut t = trainer(hidden, 1000, 1, TrainMode::PhaseSerial);
        let layers = hidden.len() + 1;
        b.bench_with_elements(
            &format!("net_step_l{layers}_w1000_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Width sweep on the 3-layer net, serial.
    for w in [500u32, 1500] {
        let mut t = trainer(&[128, 64], w, 1, TrainMode::PhaseSerial);
        b.bench_with_elements(
            &format!("net_step_l3_w{w}_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Worker scaling on the deepest config (phase-serial: the
    // historical flat fan-out numbers).
    for workers in [1usize, 2, 4] {
        let mut t =
            trainer(&[128, 96, 64], 1000, workers, TrainMode::PhaseSerial);
        b.bench_with_elements(
            &format!("net_step_l4_w1000_workers{workers}"),
            Some(elements), || t.train_steps(1));
    }

    // Pipelined vs. phase-serial on the deepest config at the CI
    // matrix worker counts: the overlap measurement.  Identical
    // numerics by construction, so any delta is pure scheduling.
    for workers in [1usize, 4, 8] {
        for (tag, mode) in [("serial", TrainMode::PhaseSerial),
                            ("pipelined", TrainMode::Pipelined)] {
            let mut t = trainer(&[128, 96, 64], 1000, workers, mode);
            b.bench_with_elements(
                &format!("net_step_l4_w1000_{tag}_workers{workers}"),
                Some(elements), || t.train_steps(1));
        }
    }

    let mut speedups = Vec::new();
    for (label, base, cont) in [
        ("net_l4_w4_vs_w1",
         "net_step_l4_w1000_workers1", "net_step_l4_w1000_workers4"),
        ("net_l4_w2_vs_w1",
         "net_step_l4_w1000_workers1", "net_step_l4_w1000_workers2"),
        ("net_l4_pipe_vs_serial_w1",
         "net_step_l4_w1000_serial_workers1",
         "net_step_l4_w1000_pipelined_workers1"),
        ("net_l4_pipe_vs_serial_w4",
         "net_step_l4_w1000_serial_workers4",
         "net_step_l4_w1000_pipelined_workers4"),
        ("net_l4_pipe_vs_serial_w8",
         "net_step_l4_w1000_serial_workers8",
         "net_step_l4_w1000_pipelined_workers8"),
    ] {
        if let Some(s) = b.speedup(base, cont) {
            println!("[nn] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    b.write_json(std::path::Path::new("BENCH_nn.json"), &speedups)
        .expect("writing BENCH_nn.json");
    b.finish();
}
