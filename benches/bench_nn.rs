//! Multi-layer device-training benches: full `NetTrainer` steps
//! (forward VMMs + transposed-VMM backprop + hybrid updates) across
//! layer counts, width multipliers and worker counts.
//!
//! `BENCH_nn.json` records steps/sec per case plus the headline
//! worker-scaling ratios — the evidence that the backward pass shards
//! like the forward pass does.

use hic_train::bench::Bench;
use hic_train::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use hic_train::crossbar::TilingPolicy;
use hic_train::nn::features::{BlobDataset, FeatureSource};
use hic_train::nn::net::NetSpec;
use hic_train::pcm::device::PcmParams;
use hic_train::util::pool::WorkerPool;

const DIM: usize = 64;
const CLASSES: usize = 10;
const BATCH: usize = 16;
const TILE: usize = 32;

fn data() -> FeatureSource {
    FeatureSource::Blobs(BlobDataset::new(7, DIM, CLASSES, 0.4, 4096, 512))
}

fn trainer(hidden: &[usize], width_permille: u32,
           workers: usize) -> NetTrainer {
    let spec = NetSpec {
        input: DIM,
        hidden_base: hidden.to_vec(),
        classes: CLASSES,
        width_permille,
    };
    NetTrainer::new(
        PcmParams::default(), &spec.dims(),
        TilingPolicy { tile_rows: TILE, tile_cols: TILE }, data(),
        WorkerPool::new(workers),
        NetTrainerOptions { batch: BATCH, ..Default::default() })
}

fn main() {
    let mut b = Bench::new("nn");
    // One benched element = one trained sample (batch per step).
    let elements = BATCH as f64;

    // Depth sweep at width 1.0, serial.
    for hidden in [&[128][..], &[128, 64][..], &[128, 96, 64][..]] {
        let mut t = trainer(hidden, 1000, 1);
        let layers = hidden.len() + 1;
        b.bench_with_elements(
            &format!("net_step_l{layers}_w1000_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Width sweep on the 3-layer net, serial.
    for w in [500u32, 1500] {
        let mut t = trainer(&[128, 64], w, 1);
        b.bench_with_elements(
            &format!("net_step_l3_w{w}_workers1"), Some(elements),
            || t.train_steps(1));
    }

    // Worker scaling on the deepest config.
    for workers in [1usize, 2, 4] {
        let mut t = trainer(&[128, 96, 64], 1000, workers);
        b.bench_with_elements(
            &format!("net_step_l4_w1000_workers{workers}"),
            Some(elements), || t.train_steps(1));
    }

    let mut speedups = Vec::new();
    for (label, base, cont) in [
        ("net_l4_w4_vs_w1",
         "net_step_l4_w1000_workers1", "net_step_l4_w1000_workers4"),
        ("net_l4_w2_vs_w1",
         "net_step_l4_w1000_workers1", "net_step_l4_w1000_workers2"),
    ] {
        if let Some(s) = b.speedup(base, cont) {
            println!("[nn] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    b.write_json(std::path::Path::new("BENCH_nn.json"), &speedups)
        .expect("writing BENCH_nn.json");
    b.finish();
}
