//! Crossbar-simulator benches: tile VMM throughput across geometries, and
//! the DAC/ADC transfer functions (the L3 hot path of host-side
//! cross-validation and the crossbar explorer).
//!
//! `vmm_batch16_aos_ref` replays the seed's batched-VMM cost model —
//! one full array-of-structs re-read (with `powf` drift and a fresh
//! `rows*cols` allocation) **per sample** — against the planar
//! `vmm_batch_into` path, which drifts once per batch into reusable
//! scratch and draws only fresh read noise per sample.

use hic_train::bench::Bench;
use hic_train::crossbar::quant::{AdcSpec, DacSpec};
use hic_train::crossbar::tile::CrossbarTile;
use hic_train::hic::weight::{HicGeometry, HicWeight};
use hic_train::pcm::device::{PcmDevice, PcmParams};
use hic_train::util::rng::Pcg64;

fn tile(rows: usize, cols: usize, rng: &mut Pcg64) -> CrossbarTile {
    let geom = HicGeometry::default();
    let mut hw = HicWeight::new(PcmParams::default(), geom, rows, cols, rng);
    let w: Vec<f32> = (0..rows * cols)
        .map(|i| ((i % 15) as f32 - 7.0) / 7.0)
        .collect();
    hw.program_init(&w, 0.0, rng);
    CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default())
}

/// The seed's `vmm_batch`: per-sample full-array re-read over scalar
/// device structs, allocating the weight read every time.
fn vmm_batch_aos_ref(t: &CrossbarTile, plus: &[PcmDevice],
                     minus: &[PcmDevice], x: &[f32], m: usize,
                     t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
    let (rows, cols) = (t.rows(), t.cols());
    let params = &t.weights.msb.plus.params;
    let mut out = Vec::with_capacity(m * cols);
    for s in 0..m {
        let xq: Vec<f32> = x[s * rows..(s + 1) * rows]
            .iter()
            .map(|&v| t.dac.convert(v))
            .collect();
        let gp: Vec<f32> =
            plus.iter().map(|d| d.read(params, t_now, rng)).collect();
        let w: Vec<f32> = gp
            .iter()
            .zip(minus)
            .map(|(p, d)| {
                t.weights.msb.g_to_w(p - d.read(params, t_now, rng))
            })
            .collect();
        let mut y = vec![0f32; cols];
        for (r, &xv) in xq.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[r * cols..(r + 1) * cols];
            for (yc, &wc) in y.iter_mut().zip(row) {
                *yc += xv * wc;
            }
        }
        out.extend(y.iter().map(|&v| t.adc.convert(v)));
    }
    out
}

fn main() {
    let mut b = Bench::new("crossbar");
    let mut rng = Pcg64::new(1, 0);

    for (rows, cols) in [(64, 64), (128, 128), (256, 256)] {
        let t = tile(rows, cols, &mut rng);
        let x: Vec<f32> = (0..rows).map(|i| (i as f32) / 64.0 - 1.0).collect();
        let mut r = Pcg64::new(2, 0);
        b.bench_with_elements(
            &format!("tile_vmm_{rows}x{cols}"),
            Some((rows * cols) as f64),
            || {
                std::hint::black_box(t.vmm(&x, 1.0, &mut r));
            },
        );
    }

    // Batched VMM: seed-style per-sample re-read vs the planar batched
    // path (drift once per batch, scratch reused across invocations).
    let t = tile(128, 128, &mut rng);
    let plus: Vec<PcmDevice> =
        (0..t.weights.msb.len()).map(|i| t.weights.msb.plus.device_at(i))
                                .collect();
    let minus: Vec<PcmDevice> =
        (0..t.weights.msb.len()).map(|i| t.weights.msb.minus.device_at(i))
                                .collect();
    let xb: Vec<f32> = (0..16 * 128).map(|i| (i % 128) as f32 / 64.0).collect();
    let mut r = Pcg64::new(3, 0);
    b.bench_with_elements("tile_vmm_batch16_aos_ref_128x128",
                          Some((16 * 128 * 128) as f64), || {
        std::hint::black_box(
            vmm_batch_aos_ref(&t, &plus, &minus, &xb, 16, 1.0, &mut r));
    });
    let mut scratch = t.scratch();
    let mut out = vec![0f32; 16 * 128];
    b.bench_with_elements("tile_vmm_batch16_128x128",
                          Some((16 * 128 * 128) as f64), || {
        t.vmm_batch_into(&xb, 16, 1.0, &mut r, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    if let Some(s) = b.speedup("tile_vmm_batch16_aos_ref_128x128",
                               "tile_vmm_batch16_128x128") {
        println!("[crossbar] vmm_batch16: planar {s:.2}x over AoS \
                  per-sample re-read");
    }

    // Converter transfer functions
    let dac = DacSpec::default();
    let adc = AdcSpec::default();
    let vals: Vec<f32> = (0..4096).map(|i| (i as f32) / 512.0 - 4.0).collect();
    b.bench_with_elements("dac_convert_4096", Some(4096.0), || {
        let s: f32 = vals.iter().map(|&v| dac.convert(v)).sum();
        std::hint::black_box(s);
    });
    b.bench_with_elements("adc_convert_4096", Some(4096.0), || {
        let s: f32 = vals.iter().map(|&v| adc.convert(v)).sum();
        std::hint::black_box(s);
    });

    b.finish();
}
