//! Crossbar-simulator benches: tile VMM throughput across geometries, and
//! the DAC/ADC transfer functions (the L3 hot path of host-side
//! cross-validation and the crossbar explorer).

use hic_train::bench::Bench;
use hic_train::crossbar::quant::{AdcSpec, DacSpec};
use hic_train::crossbar::tile::CrossbarTile;
use hic_train::hic::weight::{HicGeometry, HicWeight};
use hic_train::pcm::device::PcmParams;
use hic_train::util::rng::Pcg64;

fn tile(rows: usize, cols: usize, rng: &mut Pcg64) -> CrossbarTile {
    let geom = HicGeometry::default();
    let mut hw = HicWeight::new(PcmParams::default(), geom, rows, cols, rng);
    let w: Vec<f32> = (0..rows * cols)
        .map(|i| ((i % 15) as f32 - 7.0) / 7.0)
        .collect();
    hw.program_init(&w, 0.0, rng);
    CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default())
}

fn main() {
    let mut b = Bench::new("crossbar");
    let mut rng = Pcg64::new(1, 0);

    for (rows, cols) in [(64, 64), (128, 128), (256, 256)] {
        let t = tile(rows, cols, &mut rng);
        let x: Vec<f32> = (0..rows).map(|i| (i as f32) / 64.0 - 1.0).collect();
        let mut r = Pcg64::new(2, 0);
        b.bench_with_elements(
            &format!("tile_vmm_{rows}x{cols}"),
            Some((rows * cols) as f64),
            || {
                std::hint::black_box(t.vmm(&x, 1.0, &mut r));
            },
        );
    }

    // Batched VMM (amortizes the per-call read)
    let t = tile(128, 128, &mut rng);
    let xb: Vec<f32> = (0..16 * 128).map(|i| (i % 128) as f32 / 64.0).collect();
    let mut r = Pcg64::new(3, 0);
    b.bench_with_elements("tile_vmm_batch16_128x128",
                          Some((16 * 128 * 128) as f64), || {
        std::hint::black_box(t.vmm_batch(&xb, 16, 1.0, &mut r));
    });

    // Converter transfer functions
    let dac = DacSpec::default();
    let adc = AdcSpec::default();
    let vals: Vec<f32> = (0..4096).map(|i| (i as f32) / 512.0 - 4.0).collect();
    b.bench_with_elements("dac_convert_4096", Some(4096.0), || {
        let s: f32 = vals.iter().map(|&v| dac.convert(v)).sum();
        std::hint::black_box(s);
    });
    b.bench_with_elements("adc_convert_4096", Some(4096.0), || {
        let s: f32 = vals.iter().map(|&v| adc.convert(v)).sum();
        std::hint::black_box(s);
    });

    b.finish();
}
