//! Serving-path benches: full coalesced trace replays against a frozen
//! [`ModelSnapshot`] across coalescing windows and worker counts.
//!
//! `BENCH_serve.json` records requests/sec per case (one benched
//! element = one served request) plus, in the speedups map, the
//! **simulated** coalescing-latency quantiles per window
//! (`sim_p50_latency_us_*` / `sim_p99_latency_us_*`, microseconds of
//! simulated queue wait — deterministic, worker-invariant numbers
//! straight from the discrete-event replay) and the worker-scaling and
//! batching-leverage ratios.  Wall-clock throughput and simulated wait
//! are the two halves of the serving latency story: the scheduler
//! trades queue wait (grows with the window) for VMM batching leverage
//! (throughput grows with the window).

use hic_train::bench::Bench;
use hic_train::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use hic_train::crossbar::TilingPolicy;
use hic_train::nn::features::{BlobDataset, FeatureSource};
use hic_train::pcm::device::PcmParams;
use hic_train::serve::{gen_trace, serve_trace, CoalescePolicy,
                       ModelSnapshot, Request};
use hic_train::util::pool::WorkerPool;

const DIM: usize = 64;
const CLASSES: usize = 10;
const TILE: usize = 32;
const TEST_LEN: usize = 512;
const REQUESTS: usize = 256;
const MEAN_GAP: f64 = 1e-3;
const MAX_BATCH: usize = 32;
const QUEUE_CAP: usize = 64;

/// (tag, window seconds) sweep — 0 = no coalescing, then 2×/8×/32× the
/// mean inter-arrival gap.
const WINDOWS: [(&str, f64); 4] =
    [("0us", 0.0), ("2ms", 2e-3), ("8ms", 8e-3), ("32ms", 32e-3)];

fn snapshot(workers: usize) -> ModelSnapshot {
    let params = PcmParams {
        nonlinear: false,
        write_noise: false,
        read_noise: true,
        drift: true,
        drift_nu_sigma: 0.0,
        ..Default::default()
    };
    let data = FeatureSource::Blobs(
        BlobDataset::new(7, DIM, CLASSES, 0.4, 4096, TEST_LEN));
    let mut t = NetTrainer::new(
        params, &[DIM, 128, 64, CLASSES],
        TilingPolicy { tile_rows: TILE, tile_cols: TILE }, data,
        WorkerPool::new(workers),
        NetTrainerOptions { batch: 16, ..Default::default() });
    t.train_steps(4);
    ModelSnapshot::freeze(t, 64)
}

fn policy(window: f64) -> CoalescePolicy {
    CoalescePolicy { window, max_batch: MAX_BATCH, queue_cap: QUEUE_CAP }
}

fn main() {
    let mut b = Bench::new("serve");
    let trace: Vec<Request> =
        gen_trace(7, 0, REQUESTS, MEAN_GAP, TEST_LEN);
    let elements = REQUESTS as f64;
    let mut preds = Vec::new();
    // Simulated queue-wait quantiles ride along in the speedups map
    // (deterministic replay numbers, not wall-clock measurements).
    let mut extras: Vec<(String, f64)> = Vec::new();

    // Coalescing-window sweep at 4 workers: batching leverage.
    let pool = WorkerPool::new(4);
    let mut snap = snapshot(4);
    for (tag, window) in WINDOWS {
        let stats = serve_trace(&mut snap, &trace, &policy(window), 1e5,
                                true, &pool, &mut preds);
        extras.push((format!("sim_p50_latency_us_{tag}"),
                     stats.p50_latency * 1e6));
        extras.push((format!("sim_p99_latency_us_{tag}"),
                     stats.p99_latency * 1e6));
        b.bench_with_elements(
            &format!("serve_trace_{tag}_workers4"), Some(elements),
            || {
                serve_trace(&mut snap, &trace, &policy(window), 1e5,
                            true, &pool, &mut preds);
            });
    }

    // Worker scaling at the widest window (largest coalesced batches —
    // the case with parallelism to exploit; the 4-worker point is the
    // window sweep's last case above).
    for workers in [1usize, 8] {
        let pool = WorkerPool::new(workers);
        let mut snap = snapshot(workers);
        b.bench_with_elements(
            &format!("serve_trace_32ms_workers{workers}"),
            Some(elements),
            || {
                serve_trace(&mut snap, &trace, &policy(32e-3), 1e5,
                            true, &pool, &mut preds);
            });
    }

    let mut speedups = extras;
    for (label, base, cont) in [
        ("serve_coalesce_32ms_vs_0us",
         "serve_trace_0us_workers4", "serve_trace_32ms_workers4"),
        ("serve_w4_vs_w1",
         "serve_trace_32ms_workers1", "serve_trace_32ms_workers4"),
        ("serve_w8_vs_w1",
         "serve_trace_32ms_workers1", "serve_trace_32ms_workers8"),
    ] {
        if let Some(s) = b.speedup(base, cont) {
            println!("[serve] {label}: {s:.2}x");
            speedups.push((label.to_string(), s));
        }
    }
    b.write_json(std::path::Path::new("BENCH_serve.json"), &speedups)
        .expect("writing BENCH_serve.json");
    b.finish();
}
