//! FIG6 bench — endurance-ledger extraction cost: pulling the per-device
//! counters out of the state buffers and building the WE-cycle histograms
//! (the bookkeeping path behind `hic-train fig6`).

use hic_train::bench::Bench;
use hic_train::pcm::endurance::EnduranceLedger;
use hic_train::runtime::artifact::artifact_root;
use hic_train::runtime::{Engine, HostTensor};
use hic_train::util::rng::Pcg64;

fn main() {
    if !cfg!(feature = "pjrt") {
        println!("[fig6] SKIP: built without the `pjrt` feature \
                  (stub runtime backend)");
        return;
    }
    let dir = artifact_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        println!("[fig6] SKIP: tiny artifacts missing (make artifacts)");
        return;
    }
    let mut b = Bench::new("fig6");
    let engine = Engine::load(&dir).expect("engine");
    engine.warmup(&["hic_init", "hic_train_step"]).expect("warmup");
    let bsz = engine.manifest.batch_size();
    let mut rng = Pcg64::new(21, 0);
    let mut state = engine.init_state("hic_init", [0, 6]).expect("init");

    // Generate some device activity first.
    let x: Vec<f32> =
        (0..bsz * 3072).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let xt = HostTensor::from_f32(&[bsz, 32, 32, 3], &x);
    let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
    let yt = HostTensor::from_i32(&[bsz], &y);
    for i in 0..5u32 {
        engine
            .call_stateful(
                "hic_train_step",
                &mut state,
                &[xt.clone(), yt.clone(), HostTensor::key([1, i]),
                  HostTensor::scalar_f32(i as f32 * 0.05),
                  HostTensor::scalar_f32(0.5)],
            )
            .expect("train");
    }

    let weights = engine.manifest.num_weights as f64;
    b.bench_with_elements("ledger_from_state", Some(weights), || {
        let mut ledger = EnduranceLedger::new();
        for side in ["pcm_p", "pcm_m"] {
            let sets = state.find(&format!("{side}/set_count"));
            let resets = state.find(&format!("{side}/reset_count"));
            for ((_, s), (_, r)) in sets.iter().zip(resets.iter()) {
                for (a, bb) in
                    s.as_i32().unwrap().iter().zip(r.as_i32().unwrap())
                {
                    ledger.record_msb(*a as u64, *bb as u64);
                }
            }
        }
        let flips = state.find("lsb_flips");
        let resets = state.find("lsb_resets");
        for ((_, f), (_, r)) in flips.iter().zip(resets.iter()) {
            for (a, bb) in
                f.as_i32().unwrap().iter().zip(r.as_i32().unwrap())
            {
                ledger.record_lsb_weight(*a as u64, *bb as u64, 7);
            }
        }
        std::hint::black_box(ledger.msb.max);
    });

    b.finish();
}
