//! Fault-model overhead benches: the device fault plane is woven into
//! every hot kernel (reads freeze faulty cells, writes draw prog-fail
//! uniforms, verify re-pulses short writes), so this suite measures
//! what the machinery costs when it is on — and pins that the fault-off
//! path stays free (every fault branch is gated on an empty plane).
//!
//! Emits `BENCH_fault.json` with the fault-on/fault-off runtime ratios
//! of the grid's three hot kernels (VMM read, hybrid update, drifted
//! decode).

use hic_train::bench::Bench;
use hic_train::crossbar::grid::CrossbarGrid;
use hic_train::crossbar::{AdcSpec, DacSpec, TilingPolicy};
use hic_train::hic::weight::HicGeometry;
use hic_train::pcm::device::PcmParams;
use hic_train::pcm::FaultSpec;
use hic_train::util::pool::WorkerPool;

fn grid(fault: FaultSpec, k: usize, n: usize, seed: u64) -> CrossbarGrid {
    let params = PcmParams { fault, ..Default::default() };
    CrossbarGrid::new(params, HicGeometry::default(), k, n,
                      TilingPolicy { tile_rows: 16, tile_cols: 16 },
                      DacSpec::default(), AdcSpec::default(), seed)
}

fn main() {
    let mut b = Bench::new("fault");
    let (k, n, m) = (64usize, 64usize, 8usize);
    let pool = WorkerPool::new(1);
    let faulted = FaultSpec {
        stuck_set: 0.01,
        stuck_reset: 0.01,
        stuck_open: 0.01,
        prog_fail: 0.02,
        endurance_limit: 100_000,
        write_verify: true,
        max_retries: 3,
        remap: true,
    };

    // Fabrication seeding cost (construction-time, off the hot path).
    b.bench("grid_construct_seeded_64x64", || {
        std::hint::black_box(grid(faulted, k, n, 7));
    });

    let w0: Vec<f32> = (0..k * n)
        .map(|i| ((i % 13) as f32 - 6.0) / 8.0)
        .collect();
    let x: Vec<f32> = (0..m * k)
        .map(|i| ((i % 7) as f32 - 3.0) / 3.0)
        .collect();
    let grad: Vec<f32> = (0..k * n)
        .map(|i| ((i % 11) as f32 - 5.0) / 2.0)
        .collect();
    let elems = (k * n) as f64;

    for (tag, fault) in [("off", FaultSpec::default()),
                         ("on", faulted)] {
        let mut gr = grid(fault, k, n, 7);
        let mut scratch = gr.scratch();
        gr.program_init(&w0, 0.0, 0, &pool);

        let mut y = vec![0.0f32; m * n];
        b.bench_with_elements(&format!("vmm_batch_fault_{tag}"),
                              Some(elems), || {
            gr.vmm_batch_into(&x, m, 1.0, 5, &pool, &mut scratch,
                              &mut y);
            std::hint::black_box(&y);
        });

        let mut round = 100u64;
        b.bench_with_elements(&format!("apply_update_fault_{tag}"),
                              Some(elems), || {
            round += 1;
            std::hint::black_box(gr.apply_update(
                &grad, 0.05, 2.0, round, &pool, &mut scratch));
        });

        let mut decoded = vec![0.0f32; k * n];
        b.bench_with_elements(&format!("drift_decode_fault_{tag}"),
                              Some(elems), || {
            gr.drift_into(3.0, &pool, &mut scratch, &mut decoded);
            std::hint::black_box(&decoded);
        });

        if tag == "on" {
            let map = gr.fault_summary();
            println!("[fault] dead {} / {} devices, prog_failures {}, \
                      verify_retries {}",
                     map.dead(), 2 * k * n, map.prog_failures,
                     map.verify_retries);
        }
    }

    // Fault-on/fault-off ratios (a ratio near 1.0 = the machinery is
    // cheap; the off path is pinned bitwise-free by prop_fault).
    let mut ratios = Vec::new();
    for kernel in ["vmm_batch", "apply_update", "drift_decode"] {
        let on = format!("{kernel}_fault_on");
        let off = format!("{kernel}_fault_off");
        if let Some(s) = b.speedup(&on, &off) {
            println!("[fault] {kernel}: fault-off {s:.2}x over fault-on");
            ratios.push((kernel.to_string(), s));
        }
    }
    if let Err(e) = b.write_json(
        std::path::Path::new("BENCH_fault.json"), &ratios)
    {
        eprintln!("[fault] could not write BENCH_fault.json: {e}");
    }

    b.finish();
}
