//! Runtime benches over the compiled artifacts: per-entry execution cost
//! on the `tiny` set, the state round-trip overhead, and the faithful
//! 128^3 crossbar-tile kernel (the L1 perf target).
//!
//! Skips (with a message) when artifacts are missing.

use hic_train::bench::Bench;
use hic_train::runtime::artifact::artifact_root;
use hic_train::runtime::{Engine, HostTensor};
use hic_train::util::rng::Pcg64;

fn main() {
    if !cfg!(feature = "pjrt") {
        println!("[runtime] SKIP: built without the `pjrt` feature \
                  (stub runtime backend)");
        return;
    }
    let dir = artifact_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        println!("[runtime] SKIP: tiny artifacts missing (make artifacts)");
        return;
    }
    let mut b = Bench::new("runtime");
    let engine = Engine::load(&dir).expect("engine");
    engine
        .warmup(&["hic_init", "hic_train_step", "hic_eval_step",
                  "hic_refresh", "crossbar_vmm"])
        .expect("warmup");

    let bsz = engine.manifest.batch_size();
    let mut rng = Pcg64::new(5, 0);
    let mut state = engine.init_state("hic_init", [0, 1]).expect("init");

    let img = bsz * 32 * 32 * 3;
    let x: Vec<f32> = (0..img).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let xt = HostTensor::from_f32(&[bsz, 32, 32, 3], &x);
    let y: Vec<i32> = (0..bsz).map(|i| (i % 10) as i32).collect();
    let yt = HostTensor::from_i32(&[bsz], &y);

    let weights = engine.manifest.num_weights as f64;
    let mut step = 0u32;
    b.bench_with_elements("hic_train_step(tiny)", Some(weights), || {
        step += 1;
        let m = engine
            .call_stateful(
                "hic_train_step",
                &mut state,
                &[xt.clone(), yt.clone(), HostTensor::key([2, step]),
                  HostTensor::scalar_f32(step as f32 * 0.05),
                  HostTensor::scalar_f32(0.5)],
            )
            .expect("train");
        std::hint::black_box(m[2].scalar().unwrap());
    });

    b.bench_with_elements("hic_eval_step(tiny)", Some(weights), || {
        let m = engine
            .call_stateful(
                "hic_eval_step",
                &mut state,
                &[xt.clone(), yt.clone(), HostTensor::key([3, step]),
                  HostTensor::scalar_f32(10.0)],
            )
            .expect("eval");
        std::hint::black_box(m[0].scalar_i64().unwrap());
    });

    b.bench("hic_refresh(tiny)", || {
        let m = engine
            .call_stateful(
                "hic_refresh",
                &mut state,
                &[HostTensor::key([4, step]), HostTensor::scalar_f32(10.0)],
            )
            .expect("refresh");
        std::hint::black_box(m[0].scalar().unwrap());
    });

    // State round-trip cost in isolation: serialize state leaves to
    // literals and back (the Layer-3 overhead the §Perf log tracks).
    // PJRT builds only — the stub backend has no literal bridge.
    #[cfg(feature = "pjrt")]
    b.bench_with_elements(
        "state_literal_roundtrip",
        Some(state.total_bytes() as f64),
        || {
            for leaf in &state.leaves {
                let lit = leaf.to_literal().unwrap();
                std::hint::black_box(
                    HostTensor::from_literal(&lit).unwrap());
            }
        },
    );

    // The faithful 128^3 crossbar-tile kernel (TPU tiling).
    let t = 128;
    let xt2: Vec<f32> = (0..t * t).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let wt: Vec<f32> = (0..t * t).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let nt = vec![0f32; t * t];
    let xb = HostTensor::from_f32(&[t, t], &xt2);
    let wb = HostTensor::from_f32(&[t, t], &wt);
    let nb = HostTensor::from_f32(&[t, t], &nt);
    b.bench_with_elements("crossbar_vmm_128x128x128 (L1 kernel)",
                          Some((t * t * t) as f64), || {
        let out = engine
            .call("crossbar_vmm", &[xb.clone(), wb.clone(), nb.clone()])
            .expect("vmm");
        std::hint::black_box(out[0].as_f32().unwrap()[0]);
    });

    b.finish();
}
