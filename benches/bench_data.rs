//! Data-pipeline benches: synthetic sample generation, augmentation,
//! batch assembly and the prefetch pipeline (L3 overlap with execution).

use std::sync::Arc;

use hic_train::bench::Bench;
use hic_train::data::augment::{augment, hflip, pad_crop};
use hic_train::data::loader::{DataLoader, Dataset};
use hic_train::data::synthetic::SyntheticDataset;
use hic_train::data::IMG_ELEMS;
use hic_train::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new("data");
    let ds = SyntheticDataset::new(1, 5000, 500);

    b.bench_with_elements("synthetic_sample", Some(IMG_ELEMS as f64), || {
        std::hint::black_box(ds.sample(123, false));
    });

    let (img, _) = ds.sample(0, false);
    let mut out = vec![0f32; IMG_ELEMS];
    b.bench_with_elements("pad_crop", Some(IMG_ELEMS as f64), || {
        pad_crop(&img, 2, -3, &mut out);
    });
    let mut img2 = img.clone();
    b.bench_with_elements("hflip", Some(IMG_ELEMS as f64), || {
        hflip(&mut img2);
    });
    let mut rng = Pcg64::new(2, 0);
    b.bench_with_elements("augment_full", Some(IMG_ELEMS as f64), || {
        augment(&img, &mut rng, &mut out);
    });

    // Whole-batch assembly (the producer cost the prefetch thread hides)
    let dataset = Arc::new(Dataset::Synthetic(SyntheticDataset::new(
        1, 5000, 500)));
    let mut loader = DataLoader::new(Arc::clone(&dataset), 32, false, true, 3);
    b.bench_with_elements("batch_assembly_b32",
                          Some((32 * IMG_ELEMS) as f64), || {
        std::hint::black_box(loader.next_batch());
    });

    // Prefetched consumption: end-to-end throughput of the bounded queue.
    b.bench("prefetch_pipeline_64_batches", || {
        let l = DataLoader::new(Arc::clone(&dataset), 32, false, true, 4);
        let rx = l.prefetch(64, 4);
        let mut n = 0;
        for batch in rx {
            n += batch.y.as_i32().unwrap().len();
        }
        std::hint::black_box(n);
    });

    b.finish();
}
