//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate: the API subset this repository uses (`Error`, `Result`,
//! `anyhow!`, `bail!`, `Context`), implemented without any registry
//! dependency so the workspace builds in sealed environments.
//!
//! Semantics mirror the real crate where it matters:
//! * `Error` does **not** implement `std::error::Error`, which is what
//!   makes the blanket `From<E: std::error::Error>` impl coherent — the
//!   same trick the real crate uses on stable;
//! * `Display` prints the outermost message, `{:#}` prints the chain
//!   joined by `: `, and `Debug` prints the `Caused by:` block;
//! * `.context(..)` / `.with_context(..)` wrap the previous error as the
//!   new source.
//!
//! Swap back to the registry crate by replacing the `[dependencies]`
//! path entry with `anyhow = "1"`; no call sites need to change.

use std::fmt;

/// Drop-in subset of `anyhow::Error`: an error message plus a chain of
/// causes (stored as messages — sufficient for display and logging).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost error message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        &cur.msg
    }
}

/// Iterator over an error chain, outermost first.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// The blanket conversion every `?` site relies on.  Coherent with the
// std identity `From<T> for T` because `Error` itself deliberately does
// not implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the std source chain into our message chain.
        let mut msgs = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut tail = None;
        for msg in msgs.into_iter().rev() {
            tail = Some(Box::new(Error { msg, source: tail }));
        }
        Error { msg: e.to_string(), source: tail }
    }
}

/// Drop-in for `anyhow::Context` over `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Drop-in for `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Drop-in for `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Drop-in for `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!(
                "condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(e.to_string(), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn macros() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            ensure!(1 + 1 == 2, "math broke");
            Ok(3)
        }
        assert_eq!(inner(false).unwrap(), 3);
        assert_eq!(inner(true).unwrap_err().to_string(),
                   "failed with code 7");
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
        let owned = anyhow!(String::from("owned message"));
        assert_eq!(owned.to_string(), "owned message");
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<String> {
            let v = String::from_utf8(vec![0xff])?;
            Ok(v)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let v = Some(2u32);
        assert_eq!(v.with_context(|| "unused").unwrap(), 2);
    }
}
