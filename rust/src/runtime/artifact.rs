//! Artifact manifest model — the Rust half of the AOT contract.
//!
//! `python/compile/aot.py` writes one directory per experiment config:
//!
//! ```text
//! artifacts/<config>/
//!   manifest.json       <- parsed here
//!   hic_init.hlo.txt
//!   hic_train_step.hlo.txt
//!   ...
//! ```
//!
//! The manifest pins the *flattened* order, shape and dtype of every input
//! and output leaf of every entry point (JAX pytree flattening order), and
//! marks which span of the signature is the persistent model state.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element types the artifacts use (subset of XLA's).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            other => bail!("unsupported dtype '{other}' in manifest"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }

    #[cfg(feature = "pjrt")]
    pub fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
        }
    }
}

/// One flattened input/output leaf.
#[derive(Clone, Debug)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl LeafSpec {
    fn parse(j: &Json) -> Result<LeafSpec> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(LeafSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape,
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * self.dtype.size_bytes()
    }
}

/// Signature of one lowered entry point.
#[derive(Clone, Debug)]
pub struct EntrySig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<LeafSpec>,
    pub outputs: Vec<LeafSpec>,
    /// (start, len) span of the persistent state within `inputs`.
    pub state_input_span: (usize, usize),
    /// (start, len) span of the updated state within `outputs`.
    pub state_output_span: (usize, usize),
}

impl EntrySig {
    fn parse(j: &Json) -> Result<EntrySig> {
        let span = |key: &str| -> Result<(usize, usize)> {
            let a = j.get(key)?.as_arr()?;
            if a.len() != 2 {
                bail!("{key}: expected [start, len]");
            }
            Ok((a[0].as_usize()?, a[1].as_usize()?))
        };
        Ok(EntrySig {
            name: j.get("name")?.as_str()?.to_string(),
            file: j.get("file")?.as_str()?.to_string(),
            inputs: j
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(LeafSpec::parse)
                .collect::<Result<Vec<_>>>()?,
            outputs: j
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(LeafSpec::parse)
                .collect::<Result<Vec<_>>>()?,
            state_input_span: span("state_input_span")?,
            state_output_span: span("state_output_span")?,
        })
    }

    /// Inputs that follow the state span (batch data, keys, scalars…).
    pub fn extra_inputs(&self) -> &[LeafSpec] {
        let (s, l) = self.state_input_span;
        if l == 0 {
            &self.inputs
        } else {
            debug_assert_eq!(s, 0, "state must lead the signature");
            &self.inputs[s + l..]
        }
    }

    /// Outputs that follow the updated-state span (metrics).
    pub fn metric_outputs(&self) -> &[LeafSpec] {
        let (s, l) = self.state_output_span;
        if l == 0 {
            &self.outputs
        } else {
            debug_assert_eq!(s, 0);
            &self.outputs[s + l..]
        }
    }
}

/// One crossbar-mapped layer (geometry for the crossbar simulator and the
/// model-size accounting of Fig. 4).
#[derive(Clone, Debug)]
pub struct LayerInfo {
    pub name: String,
    pub k: usize,
    pub n: usize,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub stride: usize,
}

impl LayerInfo {
    pub fn num_weights(&self) -> usize {
        self.k * self.n
    }
}

/// Parsed `manifest.json` for one artifact config.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config_name: String,
    /// Raw config echo (hyperparameters baked at lowering time).
    pub config: Json,
    pub num_weights: usize,
    pub layers: Vec<LayerInfo>,
    pub entries: BTreeMap<String, EntrySig>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` (or `python -m \
                 compile.aot --configs <name>` from python/) first",
                path.display()
            )
        })?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;

        let config = j.get("config")?.clone();
        let config_name = config.get("name")?.as_str()?.to_string();
        let layers = j
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LayerInfo {
                    name: l.get("name")?.as_str()?.to_string(),
                    k: l.get("k")?.as_usize()?,
                    n: l.get("n")?.as_usize()?,
                    kh: l.get("kh")?.as_usize()?,
                    kw: l.get("kw")?.as_usize()?,
                    cin: l.get("cin")?.as_usize()?,
                    stride: l.get("stride")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries")?.as_obj()? {
            entries.insert(name.clone(), EntrySig::parse(e)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            config_name,
            config,
            num_weights: j.get("num_weights")?.as_usize()?,
            layers,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySig> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "entry '{name}' not in artifact set '{}' (have: {:?})",
                self.config_name,
                self.entries.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, entry: &EntrySig) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Convenience: typed scalar from the config echo, e.g.
    /// `cfg_f64("train", "lr")`.
    pub fn cfg_f64(&self, section: &str, key: &str) -> Result<f64> {
        self.config.get(section)?.get(key)?.as_f64()
    }

    pub fn cfg_usize(&self, section: &str, key: &str) -> Result<usize> {
        self.config.get(section)?.get(key)?.as_usize()
    }

    pub fn cfg_bool(&self, section: &str, key: &str) -> Result<bool> {
        self.config.get(section)?.get(key)?.as_bool()
    }

    /// Batch size the artifacts were lowered with.
    pub fn batch_size(&self) -> usize {
        self.cfg_usize("train", "batch_size").unwrap_or(32)
    }

    pub fn image_size(&self) -> usize {
        self.cfg_usize("net", "image_size").unwrap_or(32)
    }

    pub fn num_classes(&self) -> usize {
        self.cfg_usize("net", "num_classes").unwrap_or(10)
    }

    /// Inference model size in bits (Fig. 4 x-axis): HIC needs only the
    /// MSB array (~msb_bits/weight); the FP32 baseline needs 32.
    pub fn inference_model_bits(&self, hic: bool) -> usize {
        let per_weight = if hic {
            self.cfg_usize("hic", "msb_bits").unwrap_or(4)
        } else {
            32
        };
        self.num_weights * per_weight
    }
}

/// Locate the artifact root: $HIC_ARTIFACTS, else ./artifacts relative to
/// the working directory, else relative to the executable.
pub fn artifact_root() -> PathBuf {
    if let Ok(p) = std::env::var("HIC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    // target/{debug,release}/<bin> -> repo root
    if let Ok(exe) = std::env::current_exe() {
        for anc in exe.ancestors().skip(1) {
            let cand = anc.join("artifacts");
            if cand.join("..").join("Cargo.toml").exists() && cand.exists() {
                return cand;
            }
        }
    }
    cwd
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
          "config": {"name": "t", "train": {"batch_size": 4},
                     "net": {"image_size": 32, "num_classes": 10},
                     "hic": {"msb_bits": 4}},
          "num_weights": 100,
          "layers": [{"name": "stem", "k": 27, "n": 4, "kh": 3, "kw": 3,
                      "cin": 3, "stride": 1}],
          "entries": {
            "f": {"name": "f", "file": "f.hlo.txt",
                  "inputs": [
                    {"name": "state/a", "shape": [2,3], "dtype": "float32"},
                    {"name": "x", "shape": [4], "dtype": "int32"}],
                  "outputs": [
                    {"name": "0/a", "shape": [2,3], "dtype": "float32"},
                    {"name": "1/loss", "shape": [], "dtype": "float32"}],
                  "state_input_span": [0,1], "state_output_span": [0,1]}
          },
          "fingerprint": "x"
        }"#
        .to_string()
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("hic_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json())
            .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config_name, "t");
        assert_eq!(m.num_weights, 100);
        assert_eq!(m.batch_size(), 4);
        assert_eq!(m.layers[0].num_weights(), 108);
        let e = m.entry("f").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.extra_inputs().len(), 1);
        assert_eq!(e.extra_inputs()[0].name, "x");
        assert_eq!(e.metric_outputs()[0].name, "1/loss");
        assert_eq!(e.inputs[0].element_count(), 6);
        assert_eq!(e.inputs[0].size_bytes(), 24);
        assert_eq!(m.inference_model_bits(true), 400);
        assert_eq!(m.inference_model_bits(false), 3200);
        assert!(m.entry("nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert_eq!(DType::parse("uint32").unwrap(), DType::U32);
        assert!(DType::parse("float64").is_err());
    }
}
