//! PJRT runtime: load AOT artifacts, manage device state, execute entries.
//!
//! The compile path (`python/compile/aot.py`) emits, per experiment config,
//! a directory of HLO-**text** modules plus a `manifest.json` describing
//! the flattened input/output signature of every entry point.  This module
//! is the Rust half of that contract:
//!
//! * [`artifact`] — manifest model (leaf specs, entry signatures, layers)
//! * [`tensor`] — `HostTensor`, the typed host-side array that converts
//!   to/from `xla::Literal`
//! * [`engine`] — compile-once/execute-many wrapper around the PJRT CPU
//!   client, plus [`engine::ModelState`], the persistent state threaded
//!   through `*_train_step` / `refresh` / `adabs` calls
//!
//! Interchange is HLO text (not serialized protos): xla_extension 0.5.1
//! rejects jax>=0.5's 64-bit instruction ids; the text parser reassigns
//! them (see /opt/xla-example/README.md).
//!
//! The XLA linkage itself sits behind the default-off `pjrt` cargo
//! feature: default builds use a stub backend (manifests, tensors and
//! checkpoints all work; executing an entry returns a descriptive
//! error), so the crate builds and tests on machines without an XLA
//! toolchain.

pub mod artifact;
pub mod engine;
pub mod tensor;

pub use artifact::{DType, EntrySig, LeafSpec, Manifest};
pub use engine::{Engine, ModelState};
pub use tensor::HostTensor;
