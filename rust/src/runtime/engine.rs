//! Execution engine: compile-once / execute-many over the PJRT CPU client.
//!
//! One [`Engine`] wraps one artifact config.  Entry points are compiled
//! lazily on first use and cached.  [`ModelState`] is the persistent
//! flattened state pytree threaded through the stateful entries
//! (`hic_train_step`, `hic_refresh`, …); the engine validates every call
//! against the manifest signature so shape drift between the compile path
//! and the coordinator fails loudly rather than numerically.
//!
//! The XLA/PJRT linkage lives behind the default-off `pjrt` cargo
//! feature.  Without it the engine still loads manifests, validates
//! signatures and round-trips checkpoints (everything host-side), but
//! entry-point execution returns a descriptive error — see
//! [`backend`] for the stub.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::{EntrySig, Manifest};
use super::tensor::HostTensor;

/// Real PJRT-backed executor (feature `pjrt`): wraps the CPU client and
/// the per-entry compiled-executable cache.
#[cfg(feature = "pjrt")]
mod backend {
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::path::Path;
    use std::time::Instant;

    use anyhow::{anyhow, bail, Result};

    use crate::log_debug;
    use crate::runtime::tensor::HostTensor;

    pub struct Backend {
        client: xla::PjRtClient,
        executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl Backend {
        pub fn new() -> Result<Backend> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
            Ok(Backend {
                client,
                executables: RefCell::new(BTreeMap::new()),
            })
        }

        pub fn ensure_compiled(&self, name: &str, path: &Path)
                               -> Result<()> {
            if self.executables.borrow().contains_key(name) {
                return Ok(());
            }
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
            log_debug!("compiled {} in {:.2}s", name,
                       t0.elapsed().as_secs_f64());
            self.executables
                .borrow_mut()
                .insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute a compiled entry; returns the outputs plus the
        /// measured execute-and-fetch seconds (input conversion
        /// excluded, matching the historical per-entry stats span).
        pub fn execute(&self, name: &str, inputs: &[HostTensor])
                       -> Result<(Vec<HostTensor>, f64)> {
            let literals = inputs
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?;
            let t0 = Instant::now();
            let exes = self.executables.borrow();
            let Some(exe) = exes.get(name) else {
                bail!("entry '{name}' executed before compilation");
            };
            let out = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {name}: {e}"))?;
            let root = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
            // aot.py lowers with return_tuple=True: the root is always a
            // tuple.
            let parts = root
                .to_tuple()
                .map_err(|e| anyhow!("untupling {name} result: {e}"))?;
            let tensors = parts
                .iter()
                .map(HostTensor::from_literal)
                .collect::<Result<Vec<_>>>()?;
            Ok((tensors, t0.elapsed().as_secs_f64()))
        }
    }
}

/// Stub executor (feature `pjrt` disabled): manifest/checkpoint plumbing
/// keeps working so analyses, tests and `hic-train info` run on machines
/// without XLA; any attempt to compile or execute an entry errors with a
/// pointer at the feature flag.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;

    use anyhow::{Error, Result};

    use crate::runtime::tensor::HostTensor;

    pub struct Backend;

    fn unavailable(action: &str, name: &str) -> Error {
        anyhow::anyhow!(
            "cannot {action} entry '{name}': hic-train was built without \
             the `pjrt` feature (stub runtime backend); rebuild with \
             `--features pjrt` and an `xla` dependency to execute \
             artifacts"
        )
    }

    impl Backend {
        pub fn new() -> Result<Backend> {
            Ok(Backend)
        }

        pub fn ensure_compiled(&self, name: &str, _path: &Path)
                               -> Result<()> {
            Err(unavailable("compile", name))
        }

        pub fn execute(&self, name: &str, _inputs: &[HostTensor])
                       -> Result<(Vec<HostTensor>, f64)> {
            Err(unavailable("execute", name))
        }
    }
}

pub struct Engine {
    pub manifest: Manifest,
    backend: backend::Backend,
    /// cumulative (calls, seconds) per entry — perf accounting
    stats: RefCell<BTreeMap<String, (u64, f64)>>,
}

impl Engine {
    pub fn load(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Engine {
            manifest,
            backend: backend::Backend::new()?,
            stats: RefCell::new(BTreeMap::new()),
        })
    }

    /// Compile (or fetch cached) the named entry point.
    fn ensure_compiled(&self, entry: &EntrySig) -> Result<()> {
        self.backend
            .ensure_compiled(&entry.name, &self.manifest.hlo_path(entry))
    }

    /// Eagerly compile a set of entries (warmup before timed loops).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            if let Ok(e) = self.manifest.entry(n) {
                self.ensure_compiled(e)?;
            }
        }
        Ok(())
    }

    /// Execute an entry point with already-flattened inputs.
    pub fn call(&self, name: &str, inputs: &[HostTensor])
                -> Result<Vec<HostTensor>> {
        let entry = self.manifest.entry(name)?.clone();
        self.validate_inputs(&entry, inputs)?;
        self.ensure_compiled(&entry)?;

        let (tensors, dt) = self.backend.execute(name, inputs)?;
        if tensors.len() != entry.outputs.len() {
            bail!(
                "{name}: manifest promises {} outputs, runtime produced {}",
                entry.outputs.len(),
                tensors.len()
            );
        }

        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        Ok(tensors)
    }

    /// Execute a stateful entry: `state` is consumed/replaced in place and
    /// the metric outputs are returned.
    pub fn call_stateful(&self, name: &str, state: &mut ModelState,
                         extra: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let entry = self.manifest.entry(name)?;
        let (s, l) = entry.state_input_span;
        if l == 0 {
            bail!("{name} is not a stateful entry");
        }
        debug_assert_eq!(s, 0);
        if state.leaves.len() != l {
            bail!(
                "{name}: state has {} leaves, entry expects {l}",
                state.leaves.len()
            );
        }
        let mut inputs = Vec::with_capacity(l + extra.len());
        inputs.extend(state.leaves.iter().cloned());
        inputs.extend(extra.iter().cloned());
        let mut outputs = self.call(name, &inputs)?;

        let (_, ol) = self.manifest.entry(name)?.state_output_span;
        if ol > 0 {
            if ol != l {
                bail!("{name}: state span mismatch in={l} out={ol}");
            }
            let metrics = outputs.split_off(ol);
            state.leaves = outputs;
            Ok(metrics)
        } else {
            Ok(outputs)
        }
    }

    fn validate_inputs(&self, entry: &EntrySig, inputs: &[HostTensor])
                       -> Result<()> {
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                entry.name,
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (spec, t)) in entry.inputs.iter().zip(inputs).enumerate() {
            if spec.shape != t.shape || spec.dtype != t.dtype {
                bail!(
                    "{}: input {i} ('{}') expects {:?}{:?}, got {:?}{:?}",
                    entry.name, spec.name, spec.dtype, spec.shape,
                    t.dtype, t.shape
                );
            }
        }
        Ok(())
    }

    /// Initialize model state by running an init entry (e.g. `hic_init`).
    pub fn init_state(&self, init_entry: &str, key: [u32; 2])
                      -> Result<ModelState> {
        let outputs = self.call(init_entry, &[HostTensor::key(key)])?;
        let entry = self.manifest.entry(init_entry)?;
        let names = entry
            .outputs
            .iter()
            .map(|o| o.name.clone())
            .collect::<Vec<_>>();
        Ok(ModelState { names, leaves: outputs })
    }

    /// (calls, total_seconds) per entry, for perf reports.
    pub fn stats(&self) -> BTreeMap<String, (u64, f64)> {
        self.stats.borrow().clone()
    }
}

/// Flattened persistent state (JAX pytree leaf order, per the manifest).
#[derive(Clone)]
pub struct ModelState {
    pub names: Vec<String>,
    pub leaves: Vec<HostTensor>,
}

impl ModelState {
    /// Find leaves whose manifest path contains `needle`
    /// (e.g. "lsb_resets", "pcm_p/set_count").
    pub fn find(&self, needle: &str) -> Vec<(usize, &HostTensor)> {
        self.names
            .iter()
            .enumerate()
            .filter(|(_, n)| n.contains(needle))
            .map(|(i, _)| (i, &self.leaves[i]))
            .collect()
    }

    pub fn leaf(&self, needle: &str) -> Result<&HostTensor> {
        let hits = self.find(needle);
        match hits.len() {
            1 => Ok(hits[0].1),
            0 => bail!("no state leaf matches '{needle}'"),
            n => bail!("'{needle}' is ambiguous ({n} leaves)"),
        }
    }

    pub fn total_bytes(&self) -> usize {
        self.leaves
            .iter()
            .map(|l| l.element_count() * l.dtype.size_bytes())
            .collect::<Vec<_>>()
            .iter()
            .sum()
    }

    /// Save to a simple length-prefixed binary container.
    pub fn save(&self, path: &Path) -> Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"HICSTAT1")?;
        f.write_all(&(self.leaves.len() as u64).to_le_bytes())?;
        for (name, leaf) in self.names.iter().zip(&self.leaves) {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u64).to_le_bytes())?;
            f.write_all(nb)?;
            let dt = match leaf.dtype {
                super::artifact::DType::F32 => 0u8,
                super::artifact::DType::I32 => 1,
                super::artifact::DType::U32 => 2,
            };
            f.write_all(&[dt])?;
            f.write_all(&(leaf.shape.len() as u64).to_le_bytes())?;
            for d in &leaf.shape {
                f.write_all(&(*d as u64).to_le_bytes())?;
            }
            let bytes: &[u8] = match leaf.dtype {
                super::artifact::DType::F32 => {
                    let s = leaf.as_f32()?;
                    unsafe {
                        std::slice::from_raw_parts(
                            s.as_ptr() as *const u8, s.len() * 4)
                    }
                }
                super::artifact::DType::I32 => {
                    let s = leaf.as_i32()?;
                    unsafe {
                        std::slice::from_raw_parts(
                            s.as_ptr() as *const u8, s.len() * 4)
                    }
                }
                super::artifact::DType::U32 => {
                    let s = leaf.as_u32()?;
                    unsafe {
                        std::slice::from_raw_parts(
                            s.as_ptr() as *const u8, s.len() * 4)
                    }
                }
            };
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ModelState> {
        use super::artifact::DType;
        let data = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            let s = data
                .get(*pos..*pos + n)
                .ok_or_else(|| anyhow!("truncated checkpoint"))?;
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 8)?;
        if magic != b"HICSTAT1" {
            bail!("bad checkpoint magic");
        }
        let n = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
        let mut names = Vec::with_capacity(n);
        let mut leaves = Vec::with_capacity(n);
        for _ in 0..n {
            let nl =
                u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
            let name = String::from_utf8(take(&mut pos, nl)?.to_vec())?;
            let dt = match take(&mut pos, 1)?[0] {
                0 => DType::F32,
                1 => DType::I32,
                2 => DType::U32,
                other => bail!("bad dtype tag {other}"),
            };
            let rank =
                u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(
                    take(&mut pos, 8)?.try_into()?) as usize);
            }
            let nb =
                u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
            let bytes = take(&mut pos, nb)?;
            let count: usize = shape.iter().product();
            if nb != count * 4 {
                bail!("leaf '{name}': byte count {nb} != 4*{count}");
            }
            let t = match dt {
                DType::F32 => {
                    let mut v = vec![0f32; count];
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            bytes.as_ptr(),
                            v.as_mut_ptr() as *mut u8, nb);
                    }
                    HostTensor::from_f32(&shape, &v)
                }
                DType::I32 => {
                    let mut v = vec![0i32; count];
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            bytes.as_ptr(),
                            v.as_mut_ptr() as *mut u8, nb);
                    }
                    HostTensor::from_i32(&shape, &v)
                }
                DType::U32 => {
                    let mut v = vec![0u32; count];
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            bytes.as_ptr(),
                            v.as_mut_ptr() as *mut u8, nb);
                    }
                    HostTensor::from_u32(&shape, &v)
                }
            };
            names.push(name);
            leaves.push(t);
        }
        if pos != data.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(ModelState { names, leaves })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::DType;

    #[test]
    fn state_find_and_leaf() {
        let st = ModelState {
            names: vec![
                "state/layers/0/lsb".into(),
                "state/layers/0/lsb_resets".into(),
                "state/layers/1/lsb_resets".into(),
            ],
            leaves: vec![
                HostTensor::zeros(DType::I32, &[2]),
                HostTensor::zeros(DType::I32, &[2]),
                HostTensor::zeros(DType::I32, &[3]),
            ],
        };
        assert_eq!(st.find("lsb_resets").len(), 2);
        assert!(st.leaf("lsb_resets").is_err()); // ambiguous
        assert!(st.leaf("0/lsb_resets").is_ok());
        assert!(st.leaf("nothing").is_err());
        assert_eq!(st.total_bytes(), 28);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let st = ModelState {
            names: vec!["a".into(), "b/c".into()],
            leaves: vec![
                HostTensor::from_f32(&[2, 2], &[1., -2., 3.5, 0.]),
                HostTensor::from_i32(&[3], &[7, -9, 0]),
            ],
        };
        let path = std::env::temp_dir().join("hic_ckpt_test.bin");
        st.save(&path).unwrap();
        let back = ModelState::load(&path).unwrap();
        assert_eq!(back.names, st.names);
        assert_eq!(back.leaves[0].as_f32().unwrap(),
                   st.leaves[0].as_f32().unwrap());
        assert_eq!(back.leaves[1].as_i32().unwrap(),
                   st.leaves[1].as_i32().unwrap());
        let _ = std::fs::remove_file(&path);
    }
}
