//! `HostTensor` — typed host-side arrays bridging Rust and `xla::Literal`.
//!
//! The coordinator assembles batches, keys and scalars as `HostTensor`s;
//! the engine converts them to literals for execution and converts result
//! literals back.  Data is kept as raw bytes with typed views, matching
//! the manifest's dtype vocabulary (f32 / i32 / u32).

use anyhow::{bail, Result};

use super::artifact::DType;

#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: Vec<u8>,
}

impl HostTensor {
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data: bytes_of(values),
        }
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data: bytes_of(values),
        }
    }

    pub fn from_u32(shape: &[usize], values: &[u32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        HostTensor {
            dtype: DType::U32,
            shape: shape.to_vec(),
            data: bytes_of(values),
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::from_f32(&[], &[v])
    }

    pub fn key(k: [u32; 2]) -> Self {
        Self::from_u32(&[2], &k)
    }

    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor {
            dtype,
            shape: shape.to_vec(),
            data: vec![0u8; n * dtype.size_bytes()],
        }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    // -- typed views ---------------------------------------------------

    pub fn as_f32(&self) -> Result<&[f32]> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(cast_slice(&self.data))
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not i32", self.dtype);
        }
        Ok(cast_slice(&self.data))
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        if self.dtype != DType::U32 {
            bail!("tensor is {:?}, not u32", self.dtype);
        }
        Ok(cast_slice(&self.data))
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(cast_slice_mut(&mut self.data))
    }

    pub fn scalar(&self) -> Result<f32> {
        Ok(match self.dtype {
            DType::F32 => self.as_f32()?[0],
            DType::I32 => self.as_i32()?[0] as f32,
            DType::U32 => self.as_u32()?[0] as f32,
        })
    }

    pub fn scalar_i64(&self) -> Result<i64> {
        Ok(match self.dtype {
            DType::F32 => self.as_f32()?[0] as i64,
            DType::I32 => self.as_i32()?[0] as i64,
            DType::U32 => self.as_u32()?[0] as i64,
        })
    }

}

// -- literal bridge (PJRT builds only) ----------------------------------

#[cfg(feature = "pjrt")]
impl HostTensor {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.element_type(),
            &self.shape,
            &self.data,
        )?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let dtype = match shape.ty() {
            xla::ElementType::F32 => DType::F32,
            xla::ElementType::S32 => DType::I32,
            xla::ElementType::U32 => DType::U32,
            other => bail!("unsupported literal element type {other:?}"),
        };
        let mut t = HostTensor::zeros(dtype, &dims);
        match dtype {
            DType::F32 => lit.copy_raw_to::<f32>(cast_slice_mut(&mut t.data))?,
            DType::I32 => lit.copy_raw_to::<i32>(cast_slice_mut(&mut t.data))?,
            DType::U32 => lit.copy_raw_to::<u32>(cast_slice_mut(&mut t.data))?,
        }
        Ok(t)
    }
}

fn bytes_of<T: Copy>(v: &[T]) -> Vec<u8> {
    let ptr = v.as_ptr() as *const u8;
    let len = std::mem::size_of_val(v);
    unsafe { std::slice::from_raw_parts(ptr, len) }.to_vec()
}

fn cast_slice<T: Copy>(b: &[u8]) -> &[T] {
    debug_assert_eq!(b.len() % std::mem::size_of::<T>(), 0);
    debug_assert_eq!(b.as_ptr() as usize % std::mem::align_of::<T>(), 0);
    unsafe {
        std::slice::from_raw_parts(
            b.as_ptr() as *const T,
            b.len() / std::mem::size_of::<T>(),
        )
    }
}

fn cast_slice_mut<T: Copy>(b: &mut [u8]) -> &mut [T] {
    debug_assert_eq!(b.len() % std::mem::size_of::<T>(), 0);
    debug_assert_eq!(b.as_ptr() as usize % std::mem::align_of::<T>(), 0);
    unsafe {
        std::slice::from_raw_parts_mut(
            b.as_mut_ptr() as *mut T,
            b.len() / std::mem::size_of::<T>(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_roundtrip() {
        let t = HostTensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(t.as_i32().is_err());
        assert_eq!(t.element_count(), 4);

        let t = HostTensor::from_i32(&[3], &[-1, 0, 7]);
        assert_eq!(t.as_i32().unwrap(), &[-1, 0, 7]);

        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.scalar().unwrap(), 2.5);
        assert!(t.shape.is_empty());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip() {
        let cases = [
            HostTensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]),
            HostTensor::from_i32(&[4], &[i32::MIN, -1, 0, i32::MAX]),
            HostTensor::from_u32(&[2], &[0, u32::MAX]),
            HostTensor::scalar_f32(-0.5),
        ];
        for t in cases {
            let lit = t.to_literal().unwrap();
            let back = HostTensor::from_literal(&lit).unwrap();
            assert_eq!(back.dtype, t.dtype);
            assert_eq!(back.shape, t.shape);
            assert_eq!(back.data, t.data);
        }
    }

    #[test]
    fn zeros() {
        let t = HostTensor::zeros(DType::I32, &[5]);
        assert_eq!(t.as_i32().unwrap(), &[0; 5]);
    }
}
