//! Data pipeline: CIFAR-10 loading, synthetic fallback, augmentation and
//! batching with background prefetch.
//!
//! * [`cifar`] — parser for the standard CIFAR-10 binary format
//!   (`data_batch_*.bin`, 3073 bytes/record).  Used automatically when a
//!   dataset directory is present (`$HIC_CIFAR10` or `data/cifar-10`).
//! * [`synthetic`] — structured synthetic CIFAR-like dataset (per-class
//!   smooth prototypes + noise): linearly non-separable but learnable, so
//!   accuracy orderings across PCM ablations behave like a vision task.
//! * [`augment`] — pad-crop + horizontal flip (He et al. recipe).
//! * [`loader`] — epoch shuffling, batch assembly into `HostTensor`s, and
//!   a background prefetch thread that overlaps augmentation with PJRT
//!   execution.

pub mod augment;
pub mod cifar;
pub mod loader;
pub mod synthetic;

pub use loader::{Batch, DataLoader, Dataset};

/// Image geometry shared by the whole pipeline (CIFAR-10).
pub const IMG_H: usize = 32;
pub const IMG_W: usize = 32;
pub const IMG_C: usize = 3;
pub const IMG_ELEMS: usize = IMG_H * IMG_W * IMG_C;
pub const NUM_CLASSES: usize = 10;

/// Per-channel normalization constants (CIFAR-10 standard).
pub const MEAN: [f32; 3] = [0.4914, 0.4822, 0.4465];
pub const STD: [f32; 3] = [0.2470, 0.2435, 0.2616];
