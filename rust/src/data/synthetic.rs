//! Structured synthetic CIFAR-like dataset.
//!
//! Substitution for the real CIFAR-10 files when they are absent
//! (DESIGN.md §2): each class is a smooth spatial prototype (low-frequency
//! random field) plus a class-specific color cast; samples add white noise
//! and a random global intensity jitter.  Classes overlap enough that a
//! linear model underfits but a small ResNet separates them — preserving
//! the *relative* accuracy behaviour the experiments measure.

use crate::util::rng::Pcg64;

use super::{IMG_C, IMG_ELEMS, IMG_H, IMG_W, NUM_CLASSES};

pub struct SyntheticDataset {
    /// per-class prototype images, NHWC, normalized space
    pub prototypes: Vec<Vec<f32>>,
    /// observation noise std-dev
    pub noise: f32,
    pub train_len: usize,
    pub test_len: usize,
}

impl SyntheticDataset {
    pub fn new(seed: u64, train_len: usize, test_len: usize) -> Self {
        let mut rng = Pcg64::new(seed, 77);
        let prototypes = (0..NUM_CLASSES)
            .map(|c| Self::prototype(&mut rng, c))
            .collect();
        SyntheticDataset { prototypes, noise: 0.7, train_len, test_len }
    }

    /// Smooth low-frequency random field: sum of a few random cosine
    /// plane waves per channel + class color cast.
    fn prototype(rng: &mut Pcg64, class: usize) -> Vec<f32> {
        let mut img = vec![0f32; IMG_ELEMS];
        let waves = 4;
        let mut params = Vec::new();
        for _ in 0..waves * IMG_C {
            params.push((
                rng.uniform_in(0.3, 2.2),            // spatial freq (cycles)
                rng.uniform_in(0.0, std::f32::consts::TAU), // phase
                rng.uniform_in(-1.0, 1.0),           // direction x
                rng.uniform_in(-1.0, 1.0),           // direction y
                rng.uniform_in(0.4, 1.0),            // amplitude
            ));
        }
        let cast = [
            rng.normal_f32(0.0, 0.5),
            rng.normal_f32(0.0, 0.5),
            rng.normal_f32(0.0, 0.5),
        ];
        for h in 0..IMG_H {
            for w in 0..IMG_W {
                let u = h as f32 / IMG_H as f32;
                let v = w as f32 / IMG_W as f32;
                for c in 0..IMG_C {
                    let mut acc = cast[c];
                    for wi in 0..waves {
                        let (f, ph, dx, dy, a) = params[c * waves + wi];
                        acc += a
                            * (std::f32::consts::TAU * f
                                * (dx * u + dy * v)
                                + ph + class as f32 * 0.7)
                                .cos();
                    }
                    img[(h * IMG_W + w) * IMG_C + c] = acc;
                }
            }
        }
        img
    }

    /// Deterministic sample `i` of the train (or test) split.
    pub fn sample(&self, i: usize, test: bool) -> (Vec<f32>, u8) {
        // Per-sample generator: split determines the stream.
        let stream = if test { 0xDEAD } else { 0xBEEF };
        let mut rng = Pcg64::new(i as u64, stream);
        let class = (i % NUM_CLASSES) as u8;
        let proto = &self.prototypes[class as usize];
        let gain = rng.uniform_in(0.8, 1.2);
        let mut x = vec![0f32; IMG_ELEMS];
        for j in 0..IMG_ELEMS {
            x[j] = gain * proto[j] + rng.normal_f32(0.0, self.noise);
        }
        (x, class)
    }

    pub fn len(&self, test: bool) -> usize {
        if test {
            self.test_len
        } else {
            self.train_len
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let d = SyntheticDataset::new(1, 100, 20);
        let (x1, y1) = d.sample(7, false);
        let (x2, y2) = d.sample(7, false);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = d.sample(7, true);
        assert_ne!(x1, x3); // different split stream
    }

    #[test]
    fn classes_are_balanced_and_labeled() {
        let d = SyntheticDataset::new(2, 1000, 100);
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..100 {
            let (_, y) = d.sample(i, false);
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn prototypes_are_distinguishable() {
        // Nearest-prototype classification of noiseless prototypes must be
        // perfect, and of noisy samples clearly above chance — the dataset
        // is learnable.
        let d = SyntheticDataset::new(3, 1000, 100);
        let mut correct = 0;
        let n = 200;
        for i in 0..n {
            let (x, y) = d.sample(i, false);
            let mut best = (f32::MAX, 0usize);
            for (c, p) in d.prototypes.iter().enumerate() {
                let dist: f32 = x
                    .iter()
                    .zip(p)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / n as f32;
        assert!(acc > 0.8, "nearest-prototype acc {acc}");
    }

    #[test]
    fn samples_not_trivially_separable() {
        // Noise must actually move samples away from prototypes.
        let d = SyntheticDataset::new(4, 10, 10);
        let (x, y) = d.sample(0, false);
        let p = &d.prototypes[y as usize];
        let dist: f32 =
            x.iter().zip(p).map(|(a, b)| (a - b).abs()).sum::<f32>()
                / IMG_ELEMS as f32;
        assert!(dist > 0.3, "mean |noise| {dist}");
    }
}
