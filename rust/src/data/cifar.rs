//! CIFAR-10 binary-format loader.
//!
//! Standard format: each record is 3073 bytes — 1 label byte + 3072
//! pixel bytes in CHW order (1024 R, 1024 G, 1024 B), row-major within a
//! channel.  Train set: `data_batch_1..5.bin` (10 000 records each);
//! test set: `test_batch.bin`.
//!
//! Images convert to normalized NHWC f32 using the standard per-channel
//! statistics, matching what the compile-path model expects.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{IMG_C, IMG_ELEMS, IMG_H, IMG_W, MEAN, NUM_CLASSES, STD};

pub const RECORD_BYTES: usize = 1 + 3072;

pub struct CifarDataset {
    pub train_images: Vec<f32>,
    pub train_labels: Vec<u8>,
    pub test_images: Vec<f32>,
    pub test_labels: Vec<u8>,
}

impl CifarDataset {
    /// Look for a CIFAR-10 directory: `$HIC_CIFAR10`, `data/cifar-10`,
    /// `data/cifar-10-batches-bin`.
    pub fn discover() -> Option<PathBuf> {
        let mut cands = Vec::new();
        if let Ok(p) = std::env::var("HIC_CIFAR10") {
            cands.push(PathBuf::from(p));
        }
        cands.push(PathBuf::from("data/cifar-10"));
        cands.push(PathBuf::from("data/cifar-10-batches-bin"));
        cands
            .into_iter()
            .find(|p| p.join("test_batch.bin").exists())
    }

    pub fn load(dir: &Path) -> Result<CifarDataset> {
        let mut train_images = Vec::new();
        let mut train_labels = Vec::new();
        for i in 1..=5 {
            let path = dir.join(format!("data_batch_{i}.bin"));
            if !path.exists() {
                continue; // tolerate partial downloads
            }
            let (im, lb) = parse_batch(&path)?;
            train_images.extend(im);
            train_labels.extend(lb);
        }
        if train_labels.is_empty() {
            bail!("no data_batch_*.bin found in {}", dir.display());
        }
        let (test_images, test_labels) =
            parse_batch(&dir.join("test_batch.bin"))?;
        Ok(CifarDataset { train_images, train_labels, test_images,
                          test_labels })
    }

    pub fn train_len(&self) -> usize {
        self.train_labels.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_labels.len()
    }

    pub fn image(&self, i: usize, test: bool) -> &[f32] {
        let store = if test { &self.test_images } else { &self.train_images };
        &store[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]
    }

    pub fn label(&self, i: usize, test: bool) -> u8 {
        if test {
            self.test_labels[i]
        } else {
            self.train_labels[i]
        }
    }
}

/// Parse one batch file into (normalized NHWC images, labels).
pub fn parse_batch(path: &Path) -> Result<(Vec<f32>, Vec<u8>)> {
    let bytes = fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_records(&bytes)
        .with_context(|| format!("parsing {}", path.display()))
}

/// Parse raw record bytes (exposed for tests).
pub fn parse_records(bytes: &[u8]) -> Result<(Vec<f32>, Vec<u8>)> {
    if bytes.len() % RECORD_BYTES != 0 {
        bail!("file size {} is not a multiple of {}", bytes.len(),
              RECORD_BYTES);
    }
    let n = bytes.len() / RECORD_BYTES;
    let mut images = vec![0f32; n * IMG_ELEMS];
    let mut labels = vec![0u8; n];
    for r in 0..n {
        let rec = &bytes[r * RECORD_BYTES..(r + 1) * RECORD_BYTES];
        let label = rec[0];
        if label as usize >= NUM_CLASSES {
            bail!("record {r}: label {label} out of range");
        }
        labels[r] = label;
        let pix = &rec[1..];
        // CHW u8 -> normalized NHWC f32
        for c in 0..IMG_C {
            for h in 0..IMG_H {
                for w in 0..IMG_W {
                    let v = pix[c * 1024 + h * IMG_W + w] as f32 / 255.0;
                    images[r * IMG_ELEMS + (h * IMG_W + w) * IMG_C + c] =
                        (v - MEAN[c]) / STD[c];
                }
            }
        }
    }
    Ok((images, labels))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build one synthetic record: label + CHW gradient pattern.
    fn record(label: u8) -> Vec<u8> {
        let mut rec = vec![label];
        for c in 0..3u32 {
            for i in 0..1024u32 {
                rec.push(((i + c * 37) % 256) as u8);
            }
        }
        rec
    }

    #[test]
    fn parses_layout_and_normalization() {
        let mut bytes = record(3);
        bytes.extend(record(9));
        let (im, lb) = parse_records(&bytes).unwrap();
        assert_eq!(lb, vec![3, 9]);
        assert_eq!(im.len(), 2 * IMG_ELEMS);
        // First pixel of record 0: R channel byte 0 = 0 -> (0-mean)/std
        let expect_r = (0.0 - MEAN[0]) / STD[0];
        assert!((im[0] - expect_r).abs() < 1e-6);
        // Its G channel byte: (0 + 37) % 256 = 37
        let expect_g = (37.0 / 255.0 - MEAN[1]) / STD[1];
        assert!((im[1] - expect_g).abs() < 1e-6);
        // Pixel (h=1, w=2) R channel = byte 34 of channel plane
        let v = ((34u32) % 256) as f32 / 255.0;
        let idx = (IMG_W + 2) * IMG_C;
        assert!((im[idx] - (v - MEAN[0]) / STD[0]).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        assert!(parse_records(&[0u8; 100]).is_err());
        let mut bytes = record(3);
        bytes[0] = 11; // label out of range
        assert!(parse_records(&bytes).is_err());
    }

    #[test]
    fn discover_absent_is_none() {
        // (environment has no dataset; ensure the probe is quiet)
        std::env::remove_var("HIC_CIFAR10");
        let _ = CifarDataset::discover(); // must not panic
    }
}
