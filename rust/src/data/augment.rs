//! Training-time augmentation (He et al. CIFAR recipe): 4-pixel zero pad
//! + random 32x32 crop, and random horizontal flip.  Operates on
//! normalized NHWC f32 images in place.

use crate::util::rng::Pcg64;

use super::{IMG_C, IMG_ELEMS, IMG_H, IMG_W};

pub const PAD: usize = 4;

/// Random pad-crop: shift the image by (dy, dx) ∈ [-PAD, PAD], zero-fill.
pub fn pad_crop(img: &[f32], dy: i32, dx: i32, out: &mut [f32]) {
    assert_eq!(img.len(), IMG_ELEMS);
    assert_eq!(out.len(), IMG_ELEMS);
    out.fill(0.0);
    for h in 0..IMG_H as i32 {
        let sh = h + dy;
        if !(0..IMG_H as i32).contains(&sh) {
            continue;
        }
        for w in 0..IMG_W as i32 {
            let sw = w + dx;
            if !(0..IMG_W as i32).contains(&sw) {
                continue;
            }
            let src = ((sh as usize) * IMG_W + sw as usize) * IMG_C;
            let dst = ((h as usize) * IMG_W + w as usize) * IMG_C;
            out[dst..dst + IMG_C].copy_from_slice(&img[src..src + IMG_C]);
        }
    }
}

/// Horizontal flip in place.
pub fn hflip(img: &mut [f32]) {
    assert_eq!(img.len(), IMG_ELEMS);
    for h in 0..IMG_H {
        for w in 0..IMG_W / 2 {
            let a = (h * IMG_W + w) * IMG_C;
            let b = (h * IMG_W + (IMG_W - 1 - w)) * IMG_C;
            for c in 0..IMG_C {
                img.swap(a + c, b + c);
            }
        }
    }
}

/// Full augmentation of one image into `out`.
pub fn augment(img: &[f32], rng: &mut Pcg64, out: &mut [f32]) {
    let dy = rng.below(2 * PAD as u64 + 1) as i32 - PAD as i32;
    let dx = rng.below(2 * PAD as u64 + 1) as i32 - PAD as i32;
    pad_crop(img, dy, dx, out);
    if rng.below(2) == 1 {
        hflip(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp() -> Vec<f32> {
        (0..IMG_ELEMS).map(|i| i as f32).collect()
    }

    #[test]
    fn zero_shift_is_identity() {
        let img = ramp();
        let mut out = vec![0f32; IMG_ELEMS];
        pad_crop(&img, 0, 0, &mut out);
        assert_eq!(img, out);
    }

    #[test]
    fn shift_moves_and_zero_fills() {
        let img = ramp();
        let mut out = vec![0f32; IMG_ELEMS];
        pad_crop(&img, 2, -3, &mut out);
        // Row 0 of output samples source row 2.
        let src = (2 * IMG_W + 0) * IMG_C; // w=3+(−3)=0
        assert_eq!(out[(0 * IMG_W + 3) * IMG_C], img[src]);
        // Columns < 3 at any row are zero-filled (sw < 0).
        assert_eq!(out[(5 * IMG_W) * IMG_C], 0.0);
        // Bottom rows beyond the shift are zero (sh >= 32).
        assert_eq!(out[((IMG_H - 1) * IMG_W + 10) * IMG_C], 0.0);
    }

    #[test]
    fn hflip_is_involution() {
        let img = ramp();
        let mut a = img.clone();
        hflip(&mut a);
        assert_ne!(a, img);
        // pixel (0,0) swapped with (0,31)
        assert_eq!(a[0], img[(IMG_W - 1) * IMG_C]);
        hflip(&mut a);
        assert_eq!(a, img);
    }

    #[test]
    fn augment_preserves_shape_and_energy_bound() {
        let img = ramp();
        let mut rng = Pcg64::new(8, 0);
        let mut out = vec![0f32; IMG_ELEMS];
        for _ in 0..20 {
            augment(&img, &mut rng, &mut out);
            assert_eq!(out.len(), IMG_ELEMS);
            // Crop can only remove mass, never add.
            let sum_in: f32 = img.iter().map(|v| v.abs()).sum();
            let sum_out: f32 = out.iter().map(|v| v.abs()).sum();
            assert!(sum_out <= sum_in + 1e-3);
        }
    }
}
