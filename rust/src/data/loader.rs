//! Batching and background prefetch.
//!
//! `Dataset` abstracts the real CIFAR-10 files and the synthetic fallback
//! behind one sample-access interface; `DataLoader` shuffles per epoch,
//! augments (train split only) and assembles `HostTensor` batches.  A
//! bounded prefetch thread overlaps batch assembly with PJRT execution —
//! the L3 pipeline parallelism called out in DESIGN.md §7.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread;

use crate::runtime::HostTensor;
use crate::util::rng::Pcg64;

use super::augment::augment;
use super::cifar::CifarDataset;
use super::synthetic::SyntheticDataset;
use super::{IMG_C, IMG_ELEMS, IMG_H, IMG_W};

/// A dataset: real CIFAR-10 when available, synthetic otherwise.
pub enum Dataset {
    Cifar(CifarDataset),
    Synthetic(SyntheticDataset),
}

impl Dataset {
    /// Discover CIFAR-10 on disk, else build the synthetic set with the
    /// paper-like split sizes scaled by `scale` (1.0 -> 50k/10k).
    pub fn auto(seed: u64, scale: f64) -> Dataset {
        if let Some(dir) = CifarDataset::discover() {
            if let Ok(ds) = CifarDataset::load(&dir) {
                crate::log_info!("dataset: CIFAR-10 from {} ({} train)",
                                 dir.display(), ds.train_len());
                return Dataset::Cifar(ds);
            }
        }
        let train = ((50_000.0 * scale) as usize).max(100);
        let test = ((10_000.0 * scale) as usize).max(50);
        crate::log_info!(
            "dataset: synthetic CIFAR-like ({train} train / {test} test)");
        Dataset::Synthetic(SyntheticDataset::new(seed, train, test))
    }

    pub fn len(&self, test: bool) -> usize {
        match self {
            Dataset::Cifar(d) => {
                if test { d.test_len() } else { d.train_len() }
            }
            Dataset::Synthetic(d) => d.len(test),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len(false) == 0
    }

    /// Copy sample `i` into `out`, return its label.
    pub fn fill(&self, i: usize, test: bool, out: &mut [f32]) -> u8 {
        match self {
            Dataset::Cifar(d) => {
                out.copy_from_slice(d.image(i, test));
                d.label(i, test)
            }
            Dataset::Synthetic(d) => {
                let (x, y) = d.sample(i, test);
                out.copy_from_slice(&x);
                y
            }
        }
    }
}

/// One assembled batch.
pub struct Batch {
    pub x: HostTensor,
    pub y: HostTensor,
    /// epoch this batch belongs to
    pub epoch: usize,
    /// batch index within the epoch
    pub index: usize,
}

/// Epoch-shuffling batcher with optional augmentation.
pub struct DataLoader {
    dataset: Arc<Dataset>,
    pub batch_size: usize,
    pub test: bool,
    pub augment: bool,
    rng: Pcg64,
    order: Vec<u32>,
    cursor: usize,
    epoch: usize,
    index_in_epoch: usize,
}

impl DataLoader {
    pub fn new(dataset: Arc<Dataset>, batch_size: usize, test: bool,
               augmented: bool, seed: u64) -> Self {
        let n = dataset.len(test);
        let mut loader = DataLoader {
            dataset,
            batch_size,
            test,
            augment: augmented,
            rng: Pcg64::new(seed, 0x10ad),
            order: (0..n as u32).collect(),
            cursor: 0,
            epoch: 0,
            index_in_epoch: 0,
        };
        if !test {
            loader.rng.shuffle(&mut loader.order);
        }
        loader
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.dataset.len(self.test) / self.batch_size
    }

    /// Assemble the next batch (wraps epochs, reshuffling the train split).
    pub fn next_batch(&mut self) -> Batch {
        let b = self.batch_size;
        let mut x = vec![0f32; b * IMG_ELEMS];
        let mut y = vec![0i32; b];
        let mut raw = vec![0f32; IMG_ELEMS];
        for j in 0..b {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.epoch += 1;
                self.index_in_epoch = 0;
                if !self.test {
                    self.rng.shuffle(&mut self.order);
                }
            }
            let i = self.order[self.cursor] as usize;
            self.cursor += 1;
            let out = &mut x[j * IMG_ELEMS..(j + 1) * IMG_ELEMS];
            if self.augment && !self.test {
                let label = self.dataset.fill(i, self.test, &mut raw);
                augment(&raw, &mut self.rng, out);
                y[j] = label as i32;
            } else {
                y[j] = self.dataset.fill(i, self.test, out) as i32;
            }
        }
        let batch = Batch {
            x: HostTensor::from_f32(&[b, IMG_H, IMG_W, IMG_C], &x),
            y: HostTensor::from_i32(&[b], &y),
            epoch: self.epoch,
            index: self.index_in_epoch,
        };
        self.index_in_epoch += 1;
        batch
    }

    /// Move batch assembly to a background thread with a bounded queue.
    /// Returns a receiver yielding `count` batches.
    pub fn prefetch(mut self, count: usize, depth: usize)
                    -> Receiver<Batch> {
        let (tx, rx) = sync_channel(depth.max(1));
        thread::spawn(move || {
            for _ in 0..count {
                if tx.send(self.next_batch()).is_err() {
                    break; // consumer dropped
                }
            }
        });
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset() -> Arc<Dataset> {
        Arc::new(Dataset::Synthetic(SyntheticDataset::new(5, 64, 32)))
    }

    #[test]
    fn batches_have_shape_and_valid_labels() {
        let mut dl = DataLoader::new(tiny_dataset(), 8, false, true, 1);
        assert_eq!(dl.batches_per_epoch(), 8);
        for _ in 0..3 {
            let b = dl.next_batch();
            assert_eq!(b.x.shape, vec![8, IMG_H, IMG_W, IMG_C]);
            assert_eq!(b.y.shape, vec![8]);
            assert!(b.y.as_i32().unwrap().iter().all(|&y| (0..10).contains(&y)));
        }
    }

    #[test]
    fn epoch_covers_every_sample_once() {
        let mut dl = DataLoader::new(tiny_dataset(), 8, false, false, 2);
        let mut seen = std::collections::BTreeSet::new();
        // synthetic fill is deterministic per index: fingerprint by first
        // pixel + label over one epoch — all 64 distinct indices appear.
        for _ in 0..8 {
            let b = dl.next_batch();
            let xs = b.x.as_f32().unwrap();
            for j in 0..8 {
                let fp = (xs[j * IMG_ELEMS].to_bits(),
                          b.y.as_i32().unwrap()[j]);
                seen.insert(fp);
            }
        }
        assert_eq!(seen.len(), 64);
    }

    #[test]
    fn epochs_reshuffle_train_order() {
        let mut dl = DataLoader::new(tiny_dataset(), 64, false, false, 3);
        let b1 = dl.next_batch();
        let b2 = dl.next_batch(); // second epoch, reshuffled
        assert_eq!(b1.epoch, 0);
        assert_eq!(b2.epoch, 1);
        assert_ne!(b1.y.as_i32().unwrap(), b2.y.as_i32().unwrap());
    }

    #[test]
    fn test_split_is_stable_order() {
        let mut a = DataLoader::new(tiny_dataset(), 16, true, false, 4);
        let mut b = DataLoader::new(tiny_dataset(), 16, true, false, 99);
        assert_eq!(a.next_batch().y.as_i32().unwrap(),
                   b.next_batch().y.as_i32().unwrap());
    }

    #[test]
    fn prefetch_delivers_all_batches() {
        let dl = DataLoader::new(tiny_dataset(), 8, false, true, 6);
        let rx = dl.prefetch(10, 2);
        let got: Vec<Batch> = rx.iter().collect();
        assert_eq!(got.len(), 10);
        assert_eq!(got[9].epoch, 1); // wrapped into epoch 2 of 8 batches
    }
}
