//! Device fault model: stuck cells, programming failures, endurance
//! wear-out — and the accounting that makes degradation observable.
//!
//! Real PCM/memristor arrays are not perfect-yield: a fraction of
//! devices is stuck at SET (high conductance), stuck at RESET (low
//! conductance) or stuck open (no conductance at all), individual
//! programming pulses fail outright, and devices whose cumulative
//! write–erase traffic crosses the endurance limit freeze at their
//! last conductance.  [`FaultSpec`] declares all of it; the planar
//! kernels in [`crate::pcm::array`] consume it.
//!
//! # Determinism contract
//!
//! * **Off by default.**  `FaultSpec::default()` disables every
//!   mechanism, and *every* fault branch in the hot kernels is gated on
//!   [`FaultSpec::enabled`] — a fault-off run performs byte-identical
//!   arithmetic *and* byte-identical RNG draws to a build without this
//!   module, so all pinned goldens are unchanged.
//! * **Dedicated sampling streams.**  Stuck-fault placement is sampled
//!   once at grid construction from the per-(op, tile) counter stream
//!   `op_rng(seed, 0, OP_FAULT, tile)` (see `crossbar::grid`), one
//!   uniform per cell in row-major order, plus plane before minus
//!   plane — bitwise invariant across worker counts and disjoint from
//!   every init/program/VMM/update stream.
//! * **Programming-failure draws** come from the stream already driving
//!   the write (the per-(op, tile) program/update stream): one uniform
//!   *before* any write-noise draw, and no draw at all for a cell that
//!   is already stuck or worn — so the draw sequence is a pure function
//!   of the fault state, reproducible by the numpy oracle op for op.
//!
//! # Degradation machinery
//!
//! Write-verify (`write_verify` + `max_retries`) runs inside
//! `PcmArray::program_increment_at`: after the scheduled pulses, the
//! programmed conductance is read back (noise-free device-state read)
//! and compared against the target at half-granule tolerance; an
//! under-programmed healthy cell is re-pulsed up to `max_retries`
//! times, and a write still short after that is counted as a verify
//! failure in the per-array [`FaultMap`].  Refresh skips differential
//! pairs with a dead device, and the `remap` knob gives every tile's
//! differential pair a spare column strip that adopts the first dead
//! cell of each row (see `DifferentialPair::apply_remap_overrides`).

/// Fault classes stored in the per-cell fault plane
/// (`PcmArray::fault`).  `NONE` cells behave exactly as without the
/// fault model.
pub mod class {
    /// Healthy device.
    pub const NONE: u8 = 0;
    /// Stuck at SET: frozen at full conductance (g = 1).
    pub const STUCK_SET: u8 = 1;
    /// Stuck at RESET: frozen at zero conductance.
    pub const STUCK_RESET: u8 = 2;
    /// Stuck open (broken selector/via): no conductance at all.
    pub const STUCK_OPEN: u8 = 3;
    /// Worn out: write–erase traffic crossed `endurance_limit`; the
    /// device froze at its last programmed conductance.
    pub const WORN: u8 = 4;
}

/// Fault-injection configuration carried inside
/// [`crate::pcm::PcmParams`].  The default disables everything
/// ([`FaultSpec::enabled`] is false), which the pinned goldens rely
/// on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Fraction of devices stuck at SET (g = 1) from fabrication.
    pub stuck_set: f32,
    /// Fraction of devices stuck at RESET (g = 0).
    pub stuck_reset: f32,
    /// Fraction of devices stuck open (g = 0, broken access device).
    pub stuck_open: f32,
    /// Per-SET-pulse probability that the pulse has no effect on the
    /// conductance (the attempt still counts against endurance).
    pub prog_fail: f32,
    /// Write–erase budget per device: once `set_count + reset_count`
    /// reaches this, the device freezes at its current conductance.
    /// `0` disables wear-out.
    pub endurance_limit: u64,
    /// Read back each programmed increment and re-pulse
    /// under-programmed healthy cells (bounded by `max_retries`).
    pub write_verify: bool,
    /// Retry budget per verified write.
    pub max_retries: u32,
    /// Remap the first dead cell of each differential-pair row onto
    /// the pair's spare column strip.
    pub remap: bool,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            stuck_set: 0.0,
            stuck_reset: 0.0,
            stuck_open: 0.0,
            prog_fail: 0.0,
            endurance_limit: 0,
            write_verify: false,
            max_retries: 3,
            remap: false,
        }
    }
}

impl FaultSpec {
    /// True when any fault mechanism is active.  Every fault branch in
    /// the kernels is gated on this, so a disabled spec is bitwise
    /// free: no extra arithmetic, no extra RNG draws, no fault plane
    /// allocation.
    pub fn enabled(&self) -> bool {
        self.stuck_set > 0.0
            || self.stuck_reset > 0.0
            || self.stuck_open > 0.0
            || self.prog_fail > 0.0
            || self.endurance_limit > 0
    }

    /// Combined stuck-device rate (fabrication yield loss).
    pub fn stuck_rate(&self) -> f32 {
        self.stuck_set + self.stuck_reset + self.stuck_open
    }
}

/// Aggregated fault/degradation accounting: per-class stuck counts
/// from the fault planes plus the write-verify and wear-out event
/// counters.  Mergeable across planes, pairs, tiles and grids.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultMap {
    /// Devices stuck at SET (fabrication).
    pub stuck_set: u64,
    /// Devices stuck at RESET (fabrication).
    pub stuck_reset: u64,
    /// Devices stuck open (fabrication).
    pub stuck_open: u64,
    /// Devices worn out past the endurance limit.
    pub worn: u64,
    /// SET pulses that drew a programming failure.
    pub prog_failures: u64,
    /// Extra pulses issued by write-verify retries.
    pub verify_retries: u64,
    /// Verified writes still short of target after `max_retries`.
    pub verify_failures: u64,
    /// Differential-pair cells remapped onto a spare column strip.
    pub remapped: u64,
}

impl FaultMap {
    /// Fold another map into this one (plain counter sums).
    pub fn merge(&mut self, other: &FaultMap) {
        self.stuck_set += other.stuck_set;
        self.stuck_reset += other.stuck_reset;
        self.stuck_open += other.stuck_open;
        self.worn += other.worn;
        self.prog_failures += other.prog_failures;
        self.verify_retries += other.verify_retries;
        self.verify_failures += other.verify_failures;
        self.remapped += other.remapped;
    }

    /// Total dead devices (stuck + worn).
    pub fn dead(&self) -> u64 {
        self.stuck_set + self.stuck_reset + self.stuck_open + self.worn
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_fully_disabled() {
        let s = FaultSpec::default();
        assert!(!s.enabled());
        assert_eq!(s.stuck_rate(), 0.0);
        assert_eq!(s.endurance_limit, 0);
        assert!(!s.write_verify);
        assert!(!s.remap);
    }

    #[test]
    fn any_mechanism_enables() {
        for s in [
            FaultSpec { stuck_set: 0.01, ..Default::default() },
            FaultSpec { stuck_reset: 0.01, ..Default::default() },
            FaultSpec { stuck_open: 0.01, ..Default::default() },
            FaultSpec { prog_fail: 0.01, ..Default::default() },
            FaultSpec { endurance_limit: 5, ..Default::default() },
        ] {
            assert!(s.enabled(), "{s:?}");
        }
        // write_verify / remap alone change nothing without a fault
        // source, so they do not enable the machinery.
        let s = FaultSpec {
            write_verify: true,
            remap: true,
            ..Default::default()
        };
        assert!(!s.enabled());
    }

    #[test]
    fn merge_sums_every_counter() {
        let mut a = FaultMap {
            stuck_set: 1,
            stuck_reset: 2,
            stuck_open: 3,
            worn: 4,
            prog_failures: 5,
            verify_retries: 6,
            verify_failures: 7,
            remapped: 8,
        };
        a.merge(&a.clone());
        assert_eq!(a.stuck_set, 2);
        assert_eq!(a.worn, 8);
        assert_eq!(a.verify_retries, 12);
        assert_eq!(a.remapped, 16);
        assert_eq!(a.dead(), 2 + 4 + 6 + 8);
    }
}
