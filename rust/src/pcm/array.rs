//! Planar (struct-of-arrays) PCM state engine + differential-pair map.
//!
//! [`PcmArray`] stores one device *field* per contiguous plane (`g`,
//! `pulses`, `t_prog`, `nu`, `set_count`, `reset_count`), row-major, so
//! whole-array operations — drift evaluation, stochastic reads,
//! increment programming, endurance sweeps — are single passes over flat
//! `f32`/`u64` slices that the compiler autovectorizes, instead of walks
//! over a `Vec<PcmDevice>` of scalar structs.  This mirrors how the
//! lowered JAX model (`python/compile/pcm_model.py::PcmArrays`) holds
//! device state, and is what makes the Fig. 3–6 style sweeps (millions
//! of per-device conductance operations) tractable host-side.
//!
//! [`DifferentialPair`] combines two planar arrays into the signed-weight
//! map the MSB array uses: `w = w_max * (G+ − G−) / g_span`.
//!
//! RNG contract: batched kernels draw exactly the same stream as the
//! scalar [`PcmDevice`] reference path applied element-by-element in
//! row-major order — `new` draws one `normal()` per device for ν,
//! `read_into` one per device (when read noise is on), programming one
//! per SET pulse (when write noise is on).  The SoA-equivalence property
//! suite (`rust/tests/prop_soa_equivalence.rs`) pins this.  The only
//! divergence from the scalar path is the drift power law, which uses
//! `util::fastmath` (relative error < 1e-5 vs `powf`); ideal-params
//! paths are bit-for-bit identical.
//!
//! `PcmDevice` survives as the scalar reference model and a test-facing
//! view: [`PcmArray::device_at`] gathers one element's planes back into
//! a `PcmDevice` value.

use crate::util::fastmath::pow_fast;
use crate::util::rng::Pcg64;

use super::device::{PcmDevice, PcmParams};

/// Fraction of the conductance window used by the weight map (the rest is
/// the saturation guard band) — must match `python/compile/hic.py::G_SPAN`.
pub const G_SPAN: f32 = 0.8;
/// Saturation threshold policed by refresh — `hic.py::G_SAT`.
pub const G_SAT: f32 = 0.9;

/// Dense planar array of multi-level PCM devices (struct-of-arrays).
///
/// All planes have length `rows * cols` and are indexed row-major:
/// element `(r, c)` lives at `r * cols + c` in every plane.
pub struct PcmArray {
    pub params: PcmParams,
    pub rows: usize,
    pub cols: usize,
    /// conductance programmed at `t_prog` (drift reference value)
    pub g: Vec<f32>,
    /// SET pulses since last RESET
    pub pulses: Vec<f32>,
    /// time of last programming event (s)
    pub t_prog: Vec<f32>,
    /// per-device drift exponent
    pub nu: Vec<f32>,
    /// lifetime SET counters (endurance)
    pub set_count: Vec<u64>,
    /// lifetime RESET counters (endurance)
    pub reset_count: Vec<u64>,
}

impl PcmArray {
    /// Fresh (RESET, never-programmed) array; ν is sampled per device in
    /// row-major order — the same RNG stream as constructing
    /// `PcmDevice::new` sequentially.
    pub fn new(params: PcmParams, rows: usize, cols: usize,
               rng: &mut Pcg64) -> Self {
        let n = rows * cols;
        let mut nu = Vec::with_capacity(n);
        for _ in 0..n {
            nu.push(
                (params.drift_nu
                    + params.drift_nu_sigma * rng.normal() as f32)
                    .clamp(0.0, 0.12),
            );
        }
        PcmArray {
            params,
            rows,
            cols,
            g: vec![0.0; n],
            pulses: vec![0.0; n],
            t_prog: vec![0.0; n],
            nu,
            set_count: vec![0; n],
            reset_count: vec![0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.g.len()
    }

    pub fn is_empty(&self) -> bool {
        self.g.is_empty()
    }

    /// Row-major plane index of element `(r, c)`.
    #[inline]
    pub fn index(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        r * self.cols + c
    }

    /// Scalar view of element `(r, c)` — gathers the planes back into a
    /// `PcmDevice` value (test/inspection path, not a hot path).
    pub fn at(&self, r: usize, c: usize) -> PcmDevice {
        self.device_at(self.index(r, c))
    }

    /// Scalar view of flat element `i` (see [`PcmArray::at`]).
    pub fn device_at(&self, i: usize) -> PcmDevice {
        PcmDevice {
            g: self.g[i],
            pulses: self.pulses[i],
            t_prog: self.t_prog[i],
            nu: self.nu[i],
            set_count: self.set_count[i],
            reset_count: self.reset_count[i],
        }
    }

    // -- batched kernels ---------------------------------------------------

    /// Drifted conductance of one element at `t_now` (no read noise).
    #[inline]
    pub fn drift_at(&self, i: usize, t_now: f32) -> f32 {
        if !self.params.drift {
            return self.g[i];
        }
        let elapsed = (t_now - self.t_prog[i]).max(self.params.drift_t0);
        self.g[i] * pow_fast(elapsed / self.params.drift_t0, -self.nu[i])
    }

    /// Whole-array drift evaluation into a caller-provided buffer — one
    /// flat pass, no allocation.
    pub fn drift_into(&self, t_now: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        if !self.params.drift {
            out.copy_from_slice(&self.g);
            return;
        }
        let t0 = self.params.drift_t0;
        for ((o, (&g, &tp)), &nu) in out
            .iter_mut()
            .zip(self.g.iter().zip(&self.t_prog))
            .zip(&self.nu)
        {
            let elapsed = (t_now - tp).max(t0);
            *o = g * pow_fast(elapsed / t0, -nu);
        }
    }

    /// Drifted conductances at `t_now`, row-major (allocating wrapper of
    /// [`PcmArray::drift_into`]).
    pub fn drifted(&self, t_now: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.drift_into(t_now, &mut out);
        out
    }

    /// One stochastic read of every device into `out`: drift pass, then
    /// a per-element noise pass drawing one `normal()` per device in
    /// row-major order (same stream as the scalar reference path).
    pub fn read_into(&self, t_now: f32, rng: &mut Pcg64,
                     out: &mut [f32]) {
        self.drift_into(t_now, out);
        if self.params.read_noise {
            let sigma = self.params.read_sigma;
            for v in out.iter_mut() {
                *v += sigma * rng.normal() as f32;
            }
        }
        for v in out.iter_mut() {
            *v = v.clamp(0.0, 1.0);
        }
    }

    /// One stochastic read of every device (allocating wrapper).
    pub fn read(&self, t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.read_into(t_now, rng, &mut out);
        out
    }

    /// One stochastic read of a single element.
    pub fn read_at(&self, i: usize, t_now: f32, rng: &mut Pcg64) -> f32 {
        let mut g = self.drift_at(i, t_now);
        if self.params.read_noise {
            g += self.params.read_sigma * rng.normal() as f32;
        }
        g.clamp(0.0, 1.0)
    }

    /// Apply one SET pulse to element `i` at `t_now` — identical update
    /// rule to `PcmDevice::set_pulse`.
    pub fn set_pulse_at(&mut self, i: usize, t_now: f32,
                        rng: &mut Pcg64) {
        let mean = self.params.pulse_increment_mean(self.pulses[i]);
        let dg = if self.params.write_noise {
            mean + self.params.write_sigma * mean * rng.normal() as f32
        } else {
            mean
        };
        self.g[i] = (self.g[i] + dg.max(0.0)).clamp(0.0, 1.0);
        self.pulses[i] += 1.0;
        self.t_prog[i] = t_now;
        self.set_count[i] += 1;
    }

    /// Program element `i` towards a target increment (pulse-by-pulse);
    /// returns the pulses applied.
    pub fn program_increment_at(&mut self, i: usize, dg_target: f32,
                                t_now: f32, rng: &mut Pcg64) -> u32 {
        let n = self.params.pulses_for_target(self.pulses[i], dg_target);
        for _ in 0..n {
            self.set_pulse_at(i, t_now, rng);
        }
        n
    }

    /// Program the whole array towards per-element target increments
    /// (`dg_targets[i] <= 0` leaves element `i` untouched), element
    /// order, pulse-by-pulse; returns total pulses applied.
    pub fn program_increments(&mut self, dg_targets: &[f32], t_now: f32,
                              rng: &mut Pcg64) -> u64 {
        assert_eq!(dg_targets.len(), self.len());
        let mut total = 0u64;
        for (i, &dg) in dg_targets.iter().enumerate() {
            if dg > 0.0 {
                total += self.program_increment_at(i, dg, t_now, rng) as u64;
            }
        }
        total
    }

    /// RESET element `i` to the low-conductance state.
    pub fn reset_at(&mut self, i: usize, t_now: f32) {
        self.g[i] = 0.0;
        self.pulses[i] = 0.0;
        self.t_prog[i] = t_now;
        self.reset_count[i] += 1;
    }

    /// RESET every element whose mask entry is set; returns the count.
    pub fn reset_where(&mut self, mask: &[bool], t_now: f32) -> usize {
        assert_eq!(mask.len(), self.len());
        let mut n = 0;
        for (i, &m) in mask.iter().enumerate() {
            if m {
                self.reset_at(i, t_now);
                n += 1;
            }
        }
        n
    }
}

/// Differential pair of planar arrays encoding signed weights (the MSB
/// array).
pub struct DifferentialPair {
    pub plus: PcmArray,
    pub minus: PcmArray,
    pub w_max: f32,
}

impl DifferentialPair {
    pub fn new(params: PcmParams, rows: usize, cols: usize, w_max: f32,
               rng: &mut Pcg64) -> Self {
        DifferentialPair {
            plus: PcmArray::new(params, rows, cols, rng),
            minus: PcmArray::new(params, rows, cols, rng),
            w_max,
        }
    }

    pub fn rows(&self) -> usize {
        self.plus.rows
    }

    pub fn cols(&self) -> usize {
        self.plus.cols
    }

    pub fn len(&self) -> usize {
        self.plus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plus.is_empty()
    }

    /// Weight target -> differential conductance target.
    pub fn w_to_g(&self, w: f32) -> f32 {
        w * (G_SPAN / self.w_max)
    }

    /// Differential conductance -> weight value.
    pub fn g_to_w(&self, g: f32) -> f32 {
        g * (self.w_max / G_SPAN)
    }

    /// Program all weights from a row-major target matrix (used at init
    /// and by test fixtures).  Increment-only: positive targets pulse G+,
    /// negative pulse G−, assuming both devices start from RESET.  The
    /// targets are split into per-array increment planes and each array
    /// is programmed in one `program_increments` sweep (G+ first).
    pub fn program_weights(&mut self, w: &[f32], t_now: f32,
                           rng: &mut Pcg64) {
        assert_eq!(w.len(), self.plus.len());
        let mut dgp = vec![0.0f32; w.len()];
        let mut dgm = vec![0.0f32; w.len()];
        for (i, &wi) in w.iter().enumerate() {
            let g = self.w_to_g(wi.clamp(-self.w_max, self.w_max));
            if g >= 0.0 {
                dgp[i] = g;
            } else {
                dgm[i] = -g;
            }
        }
        self.plus.program_increments(&dgp, t_now, rng);
        self.minus.program_increments(&dgm, t_now, rng);
    }

    /// Apply one signed weight increment to element `i` (overflow
    /// programming): positive pulses G+, negative pulses G−.
    pub fn apply_increment(&mut self, i: usize, dw: f32, t_now: f32,
                           rng: &mut Pcg64) -> u32 {
        let dg = self.w_to_g(dw.abs());
        if dw > 0.0 {
            self.plus.program_increment_at(i, dg, t_now, rng)
        } else if dw < 0.0 {
            self.minus.program_increment_at(i, dg, t_now, rng)
        } else {
            0
        }
    }

    /// Decode the weight matrix at `t_now` into `out` (drift, no read
    /// noise) — one fused pass over both conductance planes.
    pub fn decode_into(&self, t_now: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        let scale = self.w_max / G_SPAN;
        for (i, o) in out.iter_mut().enumerate() {
            *o = (self.plus.drift_at(i, t_now)
                - self.minus.drift_at(i, t_now))
                * scale;
        }
    }

    /// Decode the weight matrix at `t_now` (allocating wrapper).
    pub fn decode(&self, t_now: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.decode_into(t_now, &mut out);
        out
    }

    /// Noisy read of the weight matrix into `out` (each device read
    /// independently; G+ noise drawn for the whole plane first, then G−,
    /// matching the scalar reference stream).  Both planes go through
    /// the vectorizable `read_into` passes; the one internal `gm`
    /// buffer is the price of the two-plane subtraction (callers that
    /// need full buffer control use `CrossbarTile`'s scratch path).
    pub fn read_weights_into(&self, t_now: f32, rng: &mut Pcg64,
                             out: &mut [f32]) {
        self.plus.read_into(t_now, rng, out);
        let mut gm = vec![0.0f32; self.len()];
        self.minus.read_into(t_now, rng, &mut gm);
        let scale = self.w_max / G_SPAN;
        for (o, &m) in out.iter_mut().zip(&gm) {
            *o = (*o - m) * scale;
        }
    }

    /// Noisy read of the weight matrix (allocating wrapper).
    pub fn read_weights(&self, t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.read_weights_into(t_now, rng, &mut out);
        out
    }

    /// Pairs whose devices entered the saturation guard band — one scan
    /// over the two programmed-conductance planes.
    pub fn saturating(&self) -> Vec<usize> {
        let mut idx = Vec::new();
        for i in 0..self.len() {
            if self.plus.g[i] > G_SAT || self.minus.g[i] > G_SAT {
                idx.push(i);
            }
        }
        idx
    }

    /// Selective saturation refresh (paper §III-A): read, RESET both,
    /// reprogram the difference.  Returns refreshed indices.
    pub fn refresh(&mut self, t_now: f32, rng: &mut Pcg64) -> Vec<usize> {
        let idx = self.saturating();
        for &i in &idx {
            let p = self.plus.read_at(i, t_now, rng);
            let m = self.minus.read_at(i, t_now, rng);
            let w = self.g_to_w(p - m).clamp(-self.w_max, self.w_max);
            self.plus.reset_at(i, t_now);
            self.minus.reset_at(i, t_now);
            let g = self.w_to_g(w);
            if g >= 0.0 {
                self.plus.program_increment_at(i, g, t_now, rng);
            } else {
                self.minus.program_increment_at(i, -g, t_now, rng);
            }
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(123, 0)
    }

    #[test]
    fn planes_are_row_major() {
        let mut r = rng();
        let mut a = PcmArray::new(PcmParams::ideal(), 3, 5, &mut r);
        a.program_increment_at(a.index(1, 2), 0.3, 1.0, &mut r);
        assert_eq!(a.index(1, 2), 7);
        assert!(a.g[7] > 0.0);
        assert_eq!(a.at(1, 2).g, a.g[7]);
        assert_eq!(a.at(1, 2).set_count, a.set_count[7]);
        // Scalar view gathers every plane.
        let d = a.device_at(7);
        assert_eq!(d.pulses, a.pulses[7]);
        assert_eq!(d.t_prog, 1.0);
    }

    #[test]
    fn program_and_decode_ideal() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 2, 3, 1.0, &mut r);
        let w = [0.4f32, -0.6, 0.0, 1.0, -1.0, 0.25];
        pair.program_weights(&w, 0.0, &mut r);
        let got = pair.decode(0.0);
        for (a, b) in w.iter().zip(&got) {
            // Ideal linear device: quantized to dg0-sized pulses through
            // the conductance map (pulse granularity ~0.1/0.8=0.125 weight)
            assert!((a - b).abs() <= 0.13, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_into_matches_decode() {
        let mut r = rng();
        let mut pair = DifferentialPair::new(
            PcmParams::default(), 4, 4, 1.0, &mut r);
        let w: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 10.0).collect();
        pair.program_weights(&w, 0.0, &mut r);
        let alloc = pair.decode(1e5);
        let mut buf = vec![0.0; 16];
        pair.decode_into(1e5, &mut buf);
        assert_eq!(alloc, buf);
    }

    #[test]
    fn increments_are_one_sided() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 1, 1, 1.0, &mut r);
        pair.apply_increment(0, 0.2, 0.0, &mut r);
        assert!(pair.plus.g[0] > 0.0);
        assert_eq!(pair.minus.g[0], 0.0);
        pair.apply_increment(0, -0.3, 0.0, &mut r);
        assert!(pair.minus.g[0] > 0.0);
        assert_eq!(pair.apply_increment(0, 0.0, 0.0, &mut r), 0);
    }

    #[test]
    fn refresh_targets_only_saturating_pairs() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 1, 4, 1.0, &mut r);
        // Drive element 0 into saturation via repeated +/- increments
        // (both devices climb; decoded weight stays small).
        for _ in 0..12 {
            pair.apply_increment(0, 0.12, 0.0, &mut r);
            pair.apply_increment(0, -0.12, 0.0, &mut r);
        }
        pair.apply_increment(1, 0.3, 0.0, &mut r); // healthy element
        let before = pair.decode(0.0);
        assert!(pair.plus.g[0] > G_SAT);

        let refreshed = pair.refresh(1.0, &mut r);
        assert_eq!(refreshed, vec![0]);
        // Refreshed pair decodes to (quantization-close) same weight...
        let after = pair.decode(1.0);
        assert!((after[0] - before[0]).abs() < 0.13,
                "{} vs {}", after[0], before[0]);
        // ...with conductances out of the guard band.
        assert!(pair.plus.g[0] < G_SAT);
        assert_eq!(pair.plus.reset_count[0], 1);
        // Healthy pair untouched.
        assert_eq!(pair.plus.reset_count[1], 0);
    }

    #[test]
    fn reset_where_masks() {
        let mut r = rng();
        let mut a = PcmArray::new(PcmParams::ideal(), 1, 4, &mut r);
        for i in 0..4 {
            a.program_increment_at(i, 0.2, 0.0, &mut r);
        }
        let n = a.reset_where(&[true, false, true, false], 5.0);
        assert_eq!(n, 2);
        assert_eq!(a.g, vec![0.0, 0.2, 0.0, 0.2]);
        assert_eq!(a.reset_count, vec![1, 0, 1, 0]);
        assert_eq!(a.t_prog[0], 5.0);
        assert_eq!(a.t_prog[1], 0.0);
    }

    #[test]
    fn noisy_read_tracks_decode() {
        let mut r = rng();
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut pair = DifferentialPair::new(params, 4, 4, 1.0, &mut r);
        let w: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 10.0).collect();
        pair.program_weights(&w, 0.0, &mut r);
        let clean = pair.decode(0.0);
        let n = 2000;
        let mut mean = vec![0f64; 16];
        for _ in 0..n {
            for (m, v) in mean.iter_mut().zip(pair.read_weights(0.0, &mut r))
            {
                *m += v as f64 / n as f64;
            }
        }
        for (c, m) in clean.iter().zip(&mean) {
            assert!((*c as f64 - m).abs() < 0.01, "{c} vs {m}");
        }
    }
}
