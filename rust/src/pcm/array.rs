//! Planar (struct-of-arrays) PCM state engine + differential-pair map.
//!
//! [`PcmArray`] stores one device *field* per contiguous plane (`g`,
//! `pulses`, `t_prog`, `nu`, `set_count`, `reset_count`), row-major, so
//! whole-array operations — drift evaluation, stochastic reads,
//! increment programming, endurance sweeps — are single passes over flat
//! `f32`/`u64` slices that the compiler autovectorizes, instead of walks
//! over a `Vec<PcmDevice>` of scalar structs.  This mirrors how the
//! lowered JAX model (`python/compile/pcm_model.py::PcmArrays`) holds
//! device state, and is what makes the Fig. 3–6 style sweeps (millions
//! of per-device conductance operations) tractable host-side.
//!
//! [`DifferentialPair`] combines two planar arrays into the signed-weight
//! map the MSB array uses: `w = w_max * (G+ − G−) / g_span`.
//!
//! RNG contract: batched kernels draw exactly the same stream as the
//! scalar [`PcmDevice`] reference path applied element-by-element in
//! row-major order — `new` draws one `normal()` per device for ν,
//! `read_into` one per device (when read noise is on), programming one
//! per SET pulse (when write noise is on).  The SoA-equivalence property
//! suite (`rust/tests/prop_soa_equivalence.rs`) pins this.  The only
//! divergence from the scalar path is the drift power law, which uses
//! `util::fastmath` (relative error < 1e-5 vs `powf`); ideal-params
//! paths are bit-for-bit identical.
//!
//! `PcmDevice` survives as the scalar reference model and a test-facing
//! view: [`PcmArray::device_at`] gathers one element's planes back into
//! a `PcmDevice` value.
//!
//! # Fault model (`params.fault`, off by default)
//!
//! When [`crate::pcm::fault::FaultSpec::enabled`] the array carries one extra `u8` fault
//! plane (see [`crate::pcm::fault::class`]) and the kernels degrade
//! gracefully instead of assuming perfect yield:
//!
//! * faulty devices (stuck or worn) freeze at their conductance — no
//!   drift, no programming effect (attempts still count against
//!   endurance), RESET ignored;
//! * each SET pulse on a healthy device first draws one uniform from
//!   the *caller's* stream when `prog_fail > 0` — a failed pulse
//!   leaves the conductance untouched;
//! * a healthy device whose `set_count + reset_count` reaches
//!   `endurance_limit` transitions to `WORN` at its last conductance;
//! * `write_verify` makes [`PcmArray::program_increment_at`] read the
//!   programmed conductance back (device state, RNG-free) and re-pulse
//!   an under-programmed healthy cell up to `max_retries` times,
//!   counting retries and terminal failures in the per-array counters
//!   ([`PcmArray::fault_stats`]).
//!
//! With faults disabled every branch above is skipped *before* any RNG
//! draw, so fault-off runs are byte-identical to the pre-fault engine.

use crate::util::fastmath::pow_fast;
use crate::util::rng::Pcg64;

use super::device::{PcmDevice, PcmParams};
use super::fault::{class, FaultMap};

/// Fraction of the conductance window used by the weight map (the rest is
/// the saturation guard band) — must match `python/compile/hic.py::G_SPAN`.
pub const G_SPAN: f32 = 0.8;
/// Saturation threshold policed by refresh — `hic.py::G_SAT`.
pub const G_SAT: f32 = 0.9;

/// Dense planar array of multi-level PCM devices (struct-of-arrays).
///
/// All planes have length `rows * cols` and are indexed row-major:
/// element `(r, c)` lives at `r * cols + c` in every plane.
pub struct PcmArray {
    pub params: PcmParams,
    pub rows: usize,
    pub cols: usize,
    /// conductance programmed at `t_prog` (drift reference value)
    pub g: Vec<f32>,
    /// SET pulses since last RESET
    pub pulses: Vec<f32>,
    /// time of last programming event (s)
    pub t_prog: Vec<f32>,
    /// per-device drift exponent
    pub nu: Vec<f32>,
    /// lifetime SET counters (endurance)
    pub set_count: Vec<u64>,
    /// lifetime RESET counters (endurance)
    pub reset_count: Vec<u64>,
    /// per-device fault class ([`class`]); **empty when
    /// `params.fault` is disabled** — every fault branch keys off this
    /// emptiness, so fault-off arrays pay nothing
    pub fault: Vec<u8>,
    /// SET pulses lost to programming failures
    pub prog_failures: u64,
    /// extra pulses issued by write-verify retries
    pub verify_retries: u64,
    /// verified writes still short of target after `max_retries`
    pub verify_failures: u64,
}

impl PcmArray {
    /// Fresh (RESET, never-programmed) array; ν is sampled per device in
    /// row-major order — the same RNG stream as constructing
    /// `PcmDevice::new` sequentially.
    pub fn new(params: PcmParams, rows: usize, cols: usize,
               rng: &mut Pcg64) -> Self {
        let n = rows * cols;
        let mut nu = Vec::with_capacity(n);
        for _ in 0..n {
            nu.push(
                (params.drift_nu
                    + params.drift_nu_sigma * rng.normal() as f32)
                    .clamp(0.0, 0.12),
            );
        }
        let fault = if params.fault.enabled() {
            vec![class::NONE; n]
        } else {
            Vec::new()
        };
        PcmArray {
            params,
            rows,
            cols,
            g: vec![0.0; n],
            pulses: vec![0.0; n],
            t_prog: vec![0.0; n],
            nu,
            set_count: vec![0; n],
            reset_count: vec![0; n],
            fault,
            prog_failures: 0,
            verify_retries: 0,
            verify_failures: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.g.len()
    }

    pub fn is_empty(&self) -> bool {
        self.g.is_empty()
    }

    /// Row-major plane index of element `(r, c)`.
    #[inline]
    pub fn index(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        r * self.cols + c
    }

    /// Scalar view of element `(r, c)` — gathers the planes back into a
    /// `PcmDevice` value (test/inspection path, not a hot path).
    pub fn at(&self, r: usize, c: usize) -> PcmDevice {
        self.device_at(self.index(r, c))
    }

    /// Scalar view of flat element `i` (see [`PcmArray::at`]).
    pub fn device_at(&self, i: usize) -> PcmDevice {
        PcmDevice {
            g: self.g[i],
            pulses: self.pulses[i],
            t_prog: self.t_prog[i],
            nu: self.nu[i],
            set_count: self.set_count[i],
            reset_count: self.reset_count[i],
        }
    }

    // -- fault plane -------------------------------------------------------

    /// Fault class of element `i` (`class::NONE` when faults are off).
    #[inline]
    pub fn fault_at(&self, i: usize) -> u8 {
        if self.fault.is_empty() {
            class::NONE
        } else {
            self.fault[i]
        }
    }

    /// Sample fabrication stuck faults over the whole array: one
    /// uniform per cell in row-major order against the cumulative
    /// class thresholds.  Stuck-at-SET cells freeze at g = 1, stuck-at-
    /// RESET and stuck-open at g = 0.  Draws nothing when every stuck
    /// rate is zero.  Called once per plane at grid construction from
    /// the dedicated per-(op, tile) `OP_FAULT` stream (see
    /// `crossbar::grid`).
    pub fn seed_faults(&mut self, rng: &mut Pcg64) {
        let fs = self.params.fault;
        if fs.stuck_rate() <= 0.0 {
            return;
        }
        debug_assert!(!self.fault.is_empty());
        let c1 = fs.stuck_set as f64;
        let c2 = c1 + fs.stuck_reset as f64;
        let c3 = c2 + fs.stuck_open as f64;
        for i in 0..self.g.len() {
            let u = rng.uniform();
            if u < c1 {
                self.fault[i] = class::STUCK_SET;
                self.g[i] = 1.0;
            } else if u < c2 {
                self.fault[i] = class::STUCK_RESET;
                self.g[i] = 0.0;
            } else if u < c3 {
                self.fault[i] = class::STUCK_OPEN;
                self.g[i] = 0.0;
            }
        }
    }

    /// Wear-out transition: a healthy device whose write–erase traffic
    /// reached the endurance limit freezes at its current conductance.
    #[inline]
    fn check_wear(&mut self, i: usize) {
        let limit = self.params.fault.endurance_limit;
        if limit > 0
            && self.fault[i] == class::NONE
            && self.set_count[i] + self.reset_count[i] >= limit
        {
            self.fault[i] = class::WORN;
        }
    }

    /// Per-class stuck/worn counts plus the write-verify and
    /// programming-failure counters of this array.
    pub fn fault_stats(&self) -> FaultMap {
        let mut m = FaultMap {
            prog_failures: self.prog_failures,
            verify_retries: self.verify_retries,
            verify_failures: self.verify_failures,
            ..Default::default()
        };
        for &f in &self.fault {
            match f {
                class::STUCK_SET => m.stuck_set += 1,
                class::STUCK_RESET => m.stuck_reset += 1,
                class::STUCK_OPEN => m.stuck_open += 1,
                class::WORN => m.worn += 1,
                _ => {}
            }
        }
        m
    }

    // -- batched kernels ---------------------------------------------------

    /// Drifted conductance of one element at `t_now` (no read noise).
    /// Faulty devices are frozen: their stored conductance is returned
    /// unchanged.
    #[inline]
    pub fn drift_at(&self, i: usize, t_now: f32) -> f32 {
        if !self.params.drift || self.fault_at(i) != class::NONE {
            return self.g[i];
        }
        let elapsed = (t_now - self.t_prog[i]).max(self.params.drift_t0);
        self.g[i] * pow_fast(elapsed / self.params.drift_t0, -self.nu[i])
    }

    /// Whole-array drift evaluation into a caller-provided buffer — one
    /// flat pass, no allocation.
    pub fn drift_into(&self, t_now: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        if !self.params.drift {
            out.copy_from_slice(&self.g);
            return;
        }
        let t0 = self.params.drift_t0;
        for ((o, (&g, &tp)), &nu) in out
            .iter_mut()
            .zip(self.g.iter().zip(&self.t_prog))
            .zip(&self.nu)
        {
            let elapsed = (t_now - tp).max(t0);
            *o = g * pow_fast(elapsed / t0, -nu);
        }
        // Fault fixup pass: faulty devices are frozen at their stored
        // conductance (no plane allocated -> no pass at all).
        if !self.fault.is_empty() {
            for (i, &f) in self.fault.iter().enumerate() {
                if f != class::NONE {
                    out[i] = self.g[i];
                }
            }
        }
    }

    /// Drifted conductances at `t_now`, row-major (allocating wrapper of
    /// [`PcmArray::drift_into`]).
    pub fn drifted(&self, t_now: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.drift_into(t_now, &mut out);
        out
    }

    /// One stochastic read of every device into `out`: drift pass, then
    /// a per-element noise pass drawing one `normal()` per device in
    /// row-major order (same stream as the scalar reference path).
    pub fn read_into(&self, t_now: f32, rng: &mut Pcg64,
                     out: &mut [f32]) {
        self.drift_into(t_now, out);
        if self.params.read_noise {
            let sigma = self.params.read_sigma;
            for v in out.iter_mut() {
                *v += sigma * rng.normal() as f32;
            }
        }
        for v in out.iter_mut() {
            *v = v.clamp(0.0, 1.0);
        }
    }

    /// One stochastic read of every device (allocating wrapper).
    pub fn read(&self, t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.read_into(t_now, rng, &mut out);
        out
    }

    /// One stochastic read of a single element.
    pub fn read_at(&self, i: usize, t_now: f32, rng: &mut Pcg64) -> f32 {
        let mut g = self.drift_at(i, t_now);
        if self.params.read_noise {
            g += self.params.read_sigma * rng.normal() as f32;
        }
        g.clamp(0.0, 1.0)
    }

    /// Apply one SET pulse to element `i` at `t_now` — identical update
    /// rule to `PcmDevice::set_pulse` when faults are off.
    ///
    /// Fault semantics (exact draw order, mirrored by the oracle): a
    /// stuck/worn device absorbs the pulse with **no RNG draw** (only
    /// `set_count` advances); otherwise, when `prog_fail > 0`, one
    /// uniform is drawn from `rng` *before* any write-noise draw and a
    /// failing pulse returns without touching the conductance.  Every
    /// attempt counts against the endurance limit.
    pub fn set_pulse_at(&mut self, i: usize, t_now: f32,
                        rng: &mut Pcg64) {
        if !self.fault.is_empty() {
            if self.fault[i] != class::NONE {
                self.set_count[i] += 1;
                return;
            }
            let pf = self.params.fault.prog_fail;
            if pf > 0.0 && rng.uniform() < pf as f64 {
                self.set_count[i] += 1;
                self.prog_failures += 1;
                self.check_wear(i);
                return;
            }
        }
        let mean = self.params.pulse_increment_mean(self.pulses[i]);
        let dg = if self.params.write_noise {
            mean + self.params.write_sigma * mean * rng.normal() as f32
        } else {
            mean
        };
        self.g[i] = (self.g[i] + dg.max(0.0)).clamp(0.0, 1.0);
        self.pulses[i] += 1.0;
        self.t_prog[i] = t_now;
        self.set_count[i] += 1;
        if !self.fault.is_empty() {
            self.check_wear(i);
        }
    }

    /// Program element `i` towards a target increment (pulse-by-pulse);
    /// returns the pulses applied (scheduled plus verify retries).
    ///
    /// With `params.fault.write_verify` (and the fault model enabled),
    /// the programmed conductance is read back after the scheduled
    /// pulses — a device-state read, no RNG — and compared against the
    /// target at half-granule (`dg0 / 2`) tolerance; an
    /// under-programmed *healthy* cell is re-pulsed up to
    /// `max_retries` extra times.  A write still short after the
    /// retry budget (stuck cell, wear-out mid-write, repeated
    /// programming failures, saturation shortfall) increments
    /// `verify_failures`.  Retries are bounded by construction, and
    /// both counters surface through [`PcmArray::fault_stats`].
    pub fn program_increment_at(&mut self, i: usize, dg_target: f32,
                                t_now: f32, rng: &mut Pcg64) -> u32 {
        let n = self.params.pulses_for_target(self.pulses[i], dg_target);
        let fs = self.params.fault;
        let verify =
            fs.write_verify && !self.fault.is_empty() && dg_target > 0.0;
        let g_before = self.g[i];
        for _ in 0..n {
            self.set_pulse_at(i, t_now, rng);
        }
        if !verify {
            return n;
        }
        let target = (g_before + dg_target).min(1.0);
        let granule = self.params.dg0 * 0.5;
        let mut retries = 0u32;
        while target - self.g[i] > granule
            && retries < fs.max_retries
            && self.fault[i] == class::NONE
        {
            self.set_pulse_at(i, t_now, rng);
            retries += 1;
        }
        self.verify_retries += retries as u64;
        if target - self.g[i] > granule {
            self.verify_failures += 1;
        }
        n + retries
    }

    /// Program the whole array towards per-element target increments
    /// (`dg_targets[i] <= 0` leaves element `i` untouched), element
    /// order, pulse-by-pulse; returns total pulses applied.
    pub fn program_increments(&mut self, dg_targets: &[f32], t_now: f32,
                              rng: &mut Pcg64) -> u64 {
        assert_eq!(dg_targets.len(), self.len());
        let mut total = 0u64;
        for (i, &dg) in dg_targets.iter().enumerate() {
            if dg > 0.0 {
                total += self.program_increment_at(i, dg, t_now, rng) as u64;
            }
        }
        total
    }

    /// RESET element `i` to the low-conductance state.  Faulty devices
    /// ignore the RESET (the attempt still counts against endurance).
    pub fn reset_at(&mut self, i: usize, t_now: f32) {
        if !self.fault.is_empty() && self.fault[i] != class::NONE {
            self.reset_count[i] += 1;
            return;
        }
        self.g[i] = 0.0;
        self.pulses[i] = 0.0;
        self.t_prog[i] = t_now;
        self.reset_count[i] += 1;
        if !self.fault.is_empty() {
            self.check_wear(i);
        }
    }

    /// RESET every element whose mask entry is set; returns the count.
    pub fn reset_where(&mut self, mask: &[bool], t_now: f32) -> usize {
        assert_eq!(mask.len(), self.len());
        let mut n = 0;
        for (i, &m) in mask.iter().enumerate() {
            if m {
                self.reset_at(i, t_now);
                n += 1;
            }
        }
        n
    }
}

/// Spare column strip of a differential pair (the `remap` mitigation):
/// one plus/minus device column of `rows` cells, each row able to
/// adopt the first dead cell of that row.
struct SpareStrip {
    plus: PcmArray,
    minus: PcmArray,
    /// `claim[r]` = column index remapped onto row `r`'s spare cell,
    /// or −1 while unclaimed.
    claim: Vec<i32>,
}

/// Differential pair of planar arrays encoding signed weights (the MSB
/// array).
pub struct DifferentialPair {
    pub plus: PcmArray,
    pub minus: PcmArray,
    pub w_max: f32,
    /// spare column strip, allocated only under `params.fault.remap`
    spare: Option<Box<SpareStrip>>,
}

impl DifferentialPair {
    pub fn new(params: PcmParams, rows: usize, cols: usize, w_max: f32,
               rng: &mut Pcg64) -> Self {
        let plus = PcmArray::new(params, rows, cols, rng);
        let minus = PcmArray::new(params, rows, cols, rng);
        // The spare strip shares the device physics (and its ν draws
        // come from the same construction stream, deterministically),
        // but is never seeded with fabrication faults: spares are
        // assumed tested-good at bind-out.
        let spare = if params.fault.enabled() && params.fault.remap {
            Some(Box::new(SpareStrip {
                plus: PcmArray::new(params, rows, 1, rng),
                minus: PcmArray::new(params, rows, 1, rng),
                claim: vec![-1; rows],
            }))
        } else {
            None
        };
        DifferentialPair { plus, minus, w_max, spare }
    }

    /// Seed fabrication stuck faults on both planes from one stream:
    /// every G+ cell first, then every G− cell (row-major each) — the
    /// order the oracle mirrors.  The spare strip is not seeded.
    pub fn seed_faults(&mut self, rng: &mut Pcg64) {
        self.plus.seed_faults(rng);
        self.minus.seed_faults(rng);
    }

    /// True when either device of pair element `i` is stuck or worn.
    pub fn pair_faulty(&self, i: usize) -> bool {
        self.plus.fault_at(i) != class::NONE
            || self.minus.fault_at(i) != class::NONE
    }

    /// Spare slot (row index) serving element `i`: an existing claim,
    /// or — when `claim` is allowed — a fresh claim if the pair is
    /// dead and row `i / cols`'s spare is still free.
    fn remap_slot(&mut self, i: usize, claim: bool) -> Option<usize> {
        let dead = self.pair_faulty(i);
        let cols = self.plus.cols;
        let sp = self.spare.as_mut()?;
        let r = i / cols;
        let c = (i % cols) as i32;
        if sp.claim[r] == c {
            return Some(r);
        }
        if claim && dead && sp.claim[r] < 0 {
            sp.claim[r] = c;
            return Some(r);
        }
        None
    }

    /// Overwrite drifted plane reads (`gp`/`gm`, full row-major G+/G−
    /// planes at `t_now`) at remapped positions with the spare strip's
    /// state.  No-op without claims; callers gate on nothing — the
    /// grid/tile read paths call this after every `drift_into` pair.
    pub fn apply_remap_overrides(&self, t_now: f32, gp: &mut [f32],
                                 gm: &mut [f32]) {
        let Some(sp) = self.spare.as_ref() else { return };
        let cols = self.plus.cols;
        for (r, &c) in sp.claim.iter().enumerate() {
            if c >= 0 {
                let i = r * cols + c as usize;
                gp[i] = sp.plus.drift_at(r, t_now);
                gm[i] = sp.minus.drift_at(r, t_now);
            }
        }
    }

    /// Differential-pair cells currently remapped onto the spare strip.
    pub fn remapped(&self) -> u64 {
        self.spare
            .as_ref()
            .map(|sp| sp.claim.iter().filter(|&&c| c >= 0).count() as u64)
            .unwrap_or(0)
    }

    /// Fault/degradation accounting over both planes (and the spare
    /// strip, including its claim count).
    pub fn fault_map(&self) -> FaultMap {
        let mut m = self.plus.fault_stats();
        m.merge(&self.minus.fault_stats());
        if let Some(sp) = &self.spare {
            m.merge(&sp.plus.fault_stats());
            m.merge(&sp.minus.fault_stats());
            m.remapped += sp.claim.iter().filter(|&&c| c >= 0).count() as u64;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.plus.rows
    }

    pub fn cols(&self) -> usize {
        self.plus.cols
    }

    pub fn len(&self) -> usize {
        self.plus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plus.is_empty()
    }

    /// Weight target -> differential conductance target.
    pub fn w_to_g(&self, w: f32) -> f32 {
        w * (G_SPAN / self.w_max)
    }

    /// Differential conductance -> weight value.
    pub fn g_to_w(&self, g: f32) -> f32 {
        g * (self.w_max / G_SPAN)
    }

    /// Program all weights from a row-major target matrix (used at init
    /// and by test fixtures).  Increment-only: positive targets pulse G+,
    /// negative pulse G−, assuming both devices start from RESET.  The
    /// targets are split into per-array increment planes and each array
    /// is programmed in one `program_increments` sweep (G+ first).
    pub fn program_weights(&mut self, w: &[f32], t_now: f32,
                           rng: &mut Pcg64) {
        assert_eq!(w.len(), self.plus.len());
        let mut dgp = vec![0.0f32; w.len()];
        let mut dgm = vec![0.0f32; w.len()];
        for (i, &wi) in w.iter().enumerate() {
            let g = self.w_to_g(wi.clamp(-self.w_max, self.w_max));
            if g >= 0.0 {
                dgp[i] = g;
            } else {
                dgm[i] = -g;
            }
        }
        self.plus.program_increments(&dgp, t_now, rng);
        self.minus.program_increments(&dgm, t_now, rng);
    }

    /// Apply one signed weight increment to element `i` (overflow
    /// programming): positive pulses G+, negative pulses G−.  Under
    /// the `remap` mitigation, a dead pair claims (or reuses) its
    /// row's spare slot and the write routes there instead.
    pub fn apply_increment(&mut self, i: usize, dw: f32, t_now: f32,
                           rng: &mut Pcg64) -> u32 {
        if dw == 0.0 {
            return 0;
        }
        let dg = self.w_to_g(dw.abs());
        if self.spare.is_some() {
            if let Some(slot) = self.remap_slot(i, true) {
                let sp = self.spare.as_mut().unwrap();
                return if dw > 0.0 {
                    sp.plus.program_increment_at(slot, dg, t_now, rng)
                } else {
                    sp.minus.program_increment_at(slot, dg, t_now, rng)
                };
            }
        }
        if dw > 0.0 {
            self.plus.program_increment_at(i, dg, t_now, rng)
        } else {
            self.minus.program_increment_at(i, dg, t_now, rng)
        }
    }

    /// Decode the weight matrix at `t_now` into `out` (drift, no read
    /// noise) — one fused pass over both conductance planes, with
    /// remapped cells decoded from the spare strip.
    pub fn decode_into(&self, t_now: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        let scale = self.w_max / G_SPAN;
        for (i, o) in out.iter_mut().enumerate() {
            *o = (self.plus.drift_at(i, t_now)
                - self.minus.drift_at(i, t_now))
                * scale;
        }
        if let Some(sp) = &self.spare {
            let cols = self.plus.cols;
            for (r, &c) in sp.claim.iter().enumerate() {
                if c >= 0 {
                    out[r * cols + c as usize] =
                        (sp.plus.drift_at(r, t_now)
                            - sp.minus.drift_at(r, t_now))
                            * scale;
                }
            }
        }
    }

    /// Decode the weight matrix at `t_now` (allocating wrapper).
    pub fn decode(&self, t_now: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.decode_into(t_now, &mut out);
        out
    }

    /// Noisy read of the weight matrix into `out` (each device read
    /// independently; G+ noise drawn for the whole plane first, then G−,
    /// matching the scalar reference stream).  Both planes go through
    /// the vectorizable `read_into` passes; the one internal `gm`
    /// buffer is the price of the two-plane subtraction (callers that
    /// need full buffer control use `CrossbarTile`'s scratch path).
    pub fn read_weights_into(&self, t_now: f32, rng: &mut Pcg64,
                             out: &mut [f32]) {
        self.plus.read_into(t_now, rng, out);
        let mut gm = vec![0.0f32; self.len()];
        self.minus.read_into(t_now, rng, &mut gm);
        let scale = self.w_max / G_SPAN;
        for (o, &m) in out.iter_mut().zip(&gm) {
            *o = (*o - m) * scale;
        }
    }

    /// Noisy read of the weight matrix (allocating wrapper).
    pub fn read_weights(&self, t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        let mut out = vec![0.0; self.len()];
        self.read_weights_into(t_now, rng, &mut out);
        out
    }

    /// Pairs whose devices entered the saturation guard band — one scan
    /// over the two programmed-conductance planes.
    pub fn saturating(&self) -> Vec<usize> {
        let mut idx = Vec::new();
        for i in 0..self.len() {
            if self.plus.g[i] > G_SAT || self.minus.g[i] > G_SAT {
                idx.push(i);
            }
        }
        idx
    }

    /// Selective saturation refresh (paper §III-A): read, RESET both,
    /// reprogram the difference.  Returns refreshed indices.
    ///
    /// Fault-aware: pairs with a stuck or worn device are skipped —
    /// RESET would not land and the reprogram would corrupt the frozen
    /// conductance's decoded weight (a stuck-SET device sits above
    /// `G_SAT` forever, so without the skip it would be re-attempted
    /// every cycle).
    pub fn refresh(&mut self, t_now: f32, rng: &mut Pcg64) -> Vec<usize> {
        let mut idx = self.saturating();
        if !self.plus.fault.is_empty() || !self.minus.fault.is_empty() {
            idx.retain(|&i| !self.pair_faulty(i));
        }
        for &i in &idx {
            let p = self.plus.read_at(i, t_now, rng);
            let m = self.minus.read_at(i, t_now, rng);
            let w = self.g_to_w(p - m).clamp(-self.w_max, self.w_max);
            self.plus.reset_at(i, t_now);
            self.minus.reset_at(i, t_now);
            let g = self.w_to_g(w);
            if g >= 0.0 {
                self.plus.program_increment_at(i, g, t_now, rng);
            } else {
                self.minus.program_increment_at(i, -g, t_now, rng);
            }
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(123, 0)
    }

    #[test]
    fn planes_are_row_major() {
        let mut r = rng();
        let mut a = PcmArray::new(PcmParams::ideal(), 3, 5, &mut r);
        a.program_increment_at(a.index(1, 2), 0.3, 1.0, &mut r);
        assert_eq!(a.index(1, 2), 7);
        assert!(a.g[7] > 0.0);
        assert_eq!(a.at(1, 2).g, a.g[7]);
        assert_eq!(a.at(1, 2).set_count, a.set_count[7]);
        // Scalar view gathers every plane.
        let d = a.device_at(7);
        assert_eq!(d.pulses, a.pulses[7]);
        assert_eq!(d.t_prog, 1.0);
    }

    #[test]
    fn program_and_decode_ideal() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 2, 3, 1.0, &mut r);
        let w = [0.4f32, -0.6, 0.0, 1.0, -1.0, 0.25];
        pair.program_weights(&w, 0.0, &mut r);
        let got = pair.decode(0.0);
        for (a, b) in w.iter().zip(&got) {
            // Ideal linear device: quantized to dg0-sized pulses through
            // the conductance map (pulse granularity ~0.1/0.8=0.125 weight)
            assert!((a - b).abs() <= 0.13, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_into_matches_decode() {
        let mut r = rng();
        let mut pair = DifferentialPair::new(
            PcmParams::default(), 4, 4, 1.0, &mut r);
        let w: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 10.0).collect();
        pair.program_weights(&w, 0.0, &mut r);
        let alloc = pair.decode(1e5);
        let mut buf = vec![0.0; 16];
        pair.decode_into(1e5, &mut buf);
        assert_eq!(alloc, buf);
    }

    #[test]
    fn increments_are_one_sided() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 1, 1, 1.0, &mut r);
        pair.apply_increment(0, 0.2, 0.0, &mut r);
        assert!(pair.plus.g[0] > 0.0);
        assert_eq!(pair.minus.g[0], 0.0);
        pair.apply_increment(0, -0.3, 0.0, &mut r);
        assert!(pair.minus.g[0] > 0.0);
        assert_eq!(pair.apply_increment(0, 0.0, 0.0, &mut r), 0);
    }

    #[test]
    fn refresh_targets_only_saturating_pairs() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 1, 4, 1.0, &mut r);
        // Drive element 0 into saturation via repeated +/- increments
        // (both devices climb; decoded weight stays small).
        for _ in 0..12 {
            pair.apply_increment(0, 0.12, 0.0, &mut r);
            pair.apply_increment(0, -0.12, 0.0, &mut r);
        }
        pair.apply_increment(1, 0.3, 0.0, &mut r); // healthy element
        let before = pair.decode(0.0);
        assert!(pair.plus.g[0] > G_SAT);

        let refreshed = pair.refresh(1.0, &mut r);
        assert_eq!(refreshed, vec![0]);
        // Refreshed pair decodes to (quantization-close) same weight...
        let after = pair.decode(1.0);
        assert!((after[0] - before[0]).abs() < 0.13,
                "{} vs {}", after[0], before[0]);
        // ...with conductances out of the guard band.
        assert!(pair.plus.g[0] < G_SAT);
        assert_eq!(pair.plus.reset_count[0], 1);
        // Healthy pair untouched.
        assert_eq!(pair.plus.reset_count[1], 0);
    }

    #[test]
    fn reset_where_masks() {
        let mut r = rng();
        let mut a = PcmArray::new(PcmParams::ideal(), 1, 4, &mut r);
        for i in 0..4 {
            a.program_increment_at(i, 0.2, 0.0, &mut r);
        }
        let n = a.reset_where(&[true, false, true, false], 5.0);
        assert_eq!(n, 2);
        assert_eq!(a.g, vec![0.0, 0.2, 0.0, 0.2]);
        assert_eq!(a.reset_count, vec![1, 0, 1, 0]);
        assert_eq!(a.t_prog[0], 5.0);
        assert_eq!(a.t_prog[1], 0.0);
    }

    #[test]
    fn noisy_read_tracks_decode() {
        let mut r = rng();
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut pair = DifferentialPair::new(params, 4, 4, 1.0, &mut r);
        let w: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 10.0).collect();
        pair.program_weights(&w, 0.0, &mut r);
        let clean = pair.decode(0.0);
        let n = 2000;
        let mut mean = vec![0f64; 16];
        for _ in 0..n {
            for (m, v) in mean.iter_mut().zip(pair.read_weights(0.0, &mut r))
            {
                *m += v as f64 / n as f64;
            }
        }
        for (c, m) in clean.iter().zip(&mean) {
            assert!((*c as f64 - m).abs() < 0.01, "{c} vs {m}");
        }
    }

    // -- fault model -------------------------------------------------------

    use crate::pcm::fault::{class, FaultSpec};

    fn faulty_params(fault: FaultSpec) -> PcmParams {
        PcmParams { fault, ..PcmParams::ideal() }
    }

    #[test]
    fn fault_off_allocates_nothing() {
        let mut r = rng();
        let a = PcmArray::new(PcmParams::default(), 4, 4, &mut r);
        assert!(a.fault.is_empty());
        assert_eq!(a.fault_at(3), class::NONE);
        assert_eq!(a.fault_stats(), Default::default());
    }

    #[test]
    fn stuck_cells_freeze_and_ignore_programming() {
        let mut r = rng();
        let spec = FaultSpec {
            stuck_set: 0.3,
            stuck_reset: 0.2,
            stuck_open: 0.1,
            ..Default::default()
        };
        let mut a = PcmArray::new(faulty_params(spec), 8, 8, &mut r);
        a.seed_faults(&mut r);
        let stats = a.fault_stats();
        assert!(stats.dead() > 0, "no faults seeded at 60% rate");
        let i = (0..a.len())
            .find(|&i| a.fault[i] == class::STUCK_SET)
            .expect("a stuck-SET cell at 30% rate");
        assert_eq!(a.g[i], 1.0);
        // Programming attempts wear but never move the conductance.
        a.program_increment_at(i, 0.4, 1.0, &mut r);
        assert_eq!(a.g[i], 1.0);
        assert!(a.set_count[i] > 0);
        // RESET is ignored too.
        a.reset_at(i, 2.0);
        assert_eq!(a.g[i], 1.0);
        assert_eq!(a.reset_count[i], 1);
        // Drift is frozen.
        let mut drifted = vec![0.0; a.len()];
        a.drift_into(1e6, &mut drifted);
        assert_eq!(drifted[i], 1.0);
        assert_eq!(a.drift_at(i, 1e6), 1.0);
    }

    #[test]
    fn seeding_draws_match_the_threshold_walk() {
        // Same seed, two arrays: seeding is one uniform per cell in
        // row-major order, so the placement is a pure function of the
        // stream — the worker-invariance contract at plane level.
        let spec = FaultSpec { stuck_reset: 0.4, ..Default::default() };
        let mut r1 = rng();
        let mut a = PcmArray::new(faulty_params(spec), 5, 7, &mut r1);
        let mut s1 = Pcg64::new(9, 9);
        a.seed_faults(&mut s1);
        let mut r2 = rng();
        let mut b = PcmArray::new(faulty_params(spec), 5, 7, &mut r2);
        let mut s2 = Pcg64::new(9, 9);
        b.seed_faults(&mut s2);
        assert_eq!(a.fault, b.fault);
        assert_eq!(a.g, b.g);
    }

    #[test]
    fn endurance_wearout_freezes_at_last_conductance() {
        let spec = FaultSpec { endurance_limit: 5, ..Default::default() };
        let mut r = rng();
        let mut a = PcmArray::new(faulty_params(spec), 1, 1, &mut r);
        for _ in 0..4 {
            a.set_pulse_at(0, 0.0, &mut r);
        }
        assert_eq!(a.fault[0], class::NONE);
        let g_then = a.g[0];
        a.set_pulse_at(0, 0.0, &mut r); // 5th write: crosses the limit
        assert_eq!(a.fault[0], class::WORN);
        let g_worn = a.g[0];
        // Further writes and resets do nothing.
        a.set_pulse_at(0, 0.0, &mut r);
        a.reset_at(0, 1.0);
        assert_eq!(a.g[0], g_worn);
        assert!(g_worn >= g_then);
        assert_eq!(a.fault_stats().worn, 1);
    }

    #[test]
    fn prog_fail_certain_failure_never_programs() {
        let spec = FaultSpec { prog_fail: 1.0, ..Default::default() };
        let mut r = rng();
        let mut a = PcmArray::new(faulty_params(spec), 1, 2, &mut r);
        a.program_increment_at(0, 0.3, 0.0, &mut r);
        assert_eq!(a.g[0], 0.0);
        assert_eq!(a.set_count[0], 3); // ceil(0.3/0.1) attempts
        assert_eq!(a.fault_stats().prog_failures, 3);
    }

    #[test]
    fn write_verify_retries_recover_lost_pulses() {
        // prog_fail = 0.5: some scheduled pulses fail; verify re-pulses
        // the shortfall within the retry budget.
        let spec = FaultSpec {
            prog_fail: 0.5,
            write_verify: true,
            max_retries: 8,
            ..Default::default()
        };
        let mut r = rng();
        let mut a = PcmArray::new(faulty_params(spec), 1, 8, &mut r);
        for i in 0..8 {
            a.program_increment_at(i, 0.3, 0.0, &mut r);
        }
        let stats = a.fault_stats();
        assert!(stats.prog_failures > 0, "no pulse failed at 50%");
        assert!(stats.verify_retries > 0, "verify never retried");
        // Every cell that verify did not flag reached its target.
        let made_it =
            (0..8).filter(|&i| (a.g[i] - 0.3).abs() < 0.051).count();
        assert!(made_it as u64 + stats.verify_failures >= 8);
        // Retry budget bounds the extra pulses per write.
        assert!(stats.verify_retries <= 8 * 8);
    }

    #[test]
    fn verify_is_inert_without_fault_sources() {
        // write_verify alone must not enable the machinery (no fault
        // plane, identical draws) — the golden-neutrality guard.
        let spec = FaultSpec { write_verify: true, ..Default::default() };
        let mut r1 = rng();
        let mut a = PcmArray::new(faulty_params(spec), 2, 2, &mut r1);
        let mut r2 = rng();
        let mut b = PcmArray::new(PcmParams::ideal(), 2, 2, &mut r2);
        a.program_increment_at(0, 0.35, 0.0, &mut r1);
        b.program_increment_at(0, 0.35, 0.0, &mut r2);
        assert!(a.fault.is_empty());
        assert_eq!(a.g, b.g);
        assert_eq!(r1.uniform().to_bits(), r2.uniform().to_bits());
    }

    #[test]
    fn fault_aware_refresh_skips_dead_pairs() {
        let spec = FaultSpec { endurance_limit: 1, ..Default::default() };
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(faulty_params(spec), 1, 2, 1.0, &mut r);
        // One pulse wears each written cell out at limit 1, frozen at
        // its first increment (dg0 = 0.1 < G_SAT, so craft saturation
        // by hand on the worn cell).
        pair.apply_increment(0, 0.2, 0.0, &mut r);
        assert_eq!(pair.plus.fault[0], class::WORN);
        pair.plus.g[0] = 0.95; // frozen above the guard band
        let refreshed = pair.refresh(1.0, &mut r);
        assert!(refreshed.is_empty(), "refresh touched a dead pair");
        assert_eq!(pair.plus.reset_count[0], 0);
    }

    #[test]
    fn remap_adopts_dead_cell_and_serves_reads() {
        let spec = FaultSpec {
            stuck_open: 1.0, // every cell dead
            remap: true,
            ..Default::default()
        };
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(faulty_params(spec), 2, 3, 1.0, &mut r);
        pair.seed_faults(&mut r);
        assert!(pair.pair_faulty(0));
        assert_eq!(pair.remapped(), 0);
        // First write to a dead pair claims the row's spare slot…
        pair.apply_increment(4, 0.5, 0.0, &mut r); // row 1, col 1
        assert_eq!(pair.remapped(), 1);
        let decoded = pair.decode(0.0);
        assert!(decoded[4] > 0.3, "remapped write lost: {decoded:?}");
        // …and the dead plane cells stayed untouched.
        assert_eq!(pair.plus.g[4], 0.0);
        // A second dead cell in the same row can't claim (strip is one
        // column wide) — its write lands on the dead device (no-op).
        pair.apply_increment(5, 0.5, 0.0, &mut r);
        assert_eq!(pair.remapped(), 1);
        assert_eq!(pair.decode(0.0)[5], 0.0);
        // Read-path override patches the drifted planes in place.
        let mut gp = vec![0.0f32; 6];
        let mut gm = vec![0.0f32; 6];
        pair.plus.drift_into(0.0, &mut gp);
        pair.minus.drift_into(0.0, &mut gm);
        pair.apply_remap_overrides(0.0, &mut gp, &mut gm);
        assert!(gp[4] > 0.0, "override missing: {gp:?}");
        assert_eq!(pair.fault_map().remapped, 1);
        // Negative updates route to the spare's minus device.
        pair.apply_increment(4, -0.2, 0.0, &mut r);
        assert!(pair.decode(0.0)[4] < decoded[4]);
    }
}
