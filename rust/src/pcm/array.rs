//! Arrays of PCM devices and the differential-pair weight mapping.
//!
//! `PcmArray` is a dense array of multi-level devices (one conductance per
//! element); `DifferentialPair` combines two arrays into the signed-weight
//! map the MSB array uses: `w = w_max * (G+ − G−) / g_span`.
//!
//! This is the host-side twin of `python/compile/hic.py`'s conductance
//! encoding — the crossbar simulator and the endurance/refresh analyses
//! run on it without touching PJRT.

use crate::util::rng::Pcg64;

use super::device::{PcmDevice, PcmParams};

/// Fraction of the conductance window used by the weight map (the rest is
/// the saturation guard band) — must match `python/compile/hic.py::G_SPAN`.
pub const G_SPAN: f32 = 0.8;
/// Saturation threshold policed by refresh — `hic.py::G_SAT`.
pub const G_SAT: f32 = 0.9;

/// Dense array of multi-level PCM devices.
pub struct PcmArray {
    pub params: PcmParams,
    pub devices: Vec<PcmDevice>,
    pub rows: usize,
    pub cols: usize,
}

impl PcmArray {
    pub fn new(params: PcmParams, rows: usize, cols: usize,
               rng: &mut Pcg64) -> Self {
        let devices = (0..rows * cols)
            .map(|_| PcmDevice::new(&params, rng))
            .collect();
        PcmArray { params, devices, rows, cols }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn at(&self, r: usize, c: usize) -> &PcmDevice {
        &self.devices[r * self.cols + c]
    }

    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut PcmDevice {
        &mut self.devices[r * self.cols + c]
    }

    /// Drifted conductances at `t_now`, row-major.
    pub fn drifted(&self, t_now: f32) -> Vec<f32> {
        self.devices
            .iter()
            .map(|d| d.drifted(&self.params, t_now))
            .collect()
    }

    /// One stochastic read of every device.
    pub fn read(&self, t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        self.devices
            .iter()
            .map(|d| d.read(&self.params, t_now, rng))
            .collect()
    }
}

/// Differential pair of arrays encoding signed weights (the MSB array).
pub struct DifferentialPair {
    pub plus: PcmArray,
    pub minus: PcmArray,
    pub w_max: f32,
}

impl DifferentialPair {
    pub fn new(params: PcmParams, rows: usize, cols: usize, w_max: f32,
               rng: &mut Pcg64) -> Self {
        DifferentialPair {
            plus: PcmArray::new(params, rows, cols, rng),
            minus: PcmArray::new(params, rows, cols, rng),
            w_max,
        }
    }

    pub fn rows(&self) -> usize {
        self.plus.rows
    }

    pub fn cols(&self) -> usize {
        self.plus.cols
    }

    /// Weight target -> differential conductance target.
    pub fn w_to_g(&self, w: f32) -> f32 {
        w * (G_SPAN / self.w_max)
    }

    /// Differential conductance -> weight value.
    pub fn g_to_w(&self, g: f32) -> f32 {
        g * (self.w_max / G_SPAN)
    }

    /// Program all weights from a row-major target matrix (used at init
    /// and by test fixtures).  Increment-only: positive targets pulse G+,
    /// negative pulse G−, assuming both devices start from RESET.
    pub fn program_weights(&mut self, w: &[f32], t_now: f32,
                           rng: &mut Pcg64) {
        assert_eq!(w.len(), self.plus.len());
        for (i, &wi) in w.iter().enumerate() {
            let g = self.w_to_g(wi.clamp(-self.w_max, self.w_max));
            if g >= 0.0 {
                self.plus.devices[i].program_increment(
                    &self.plus.params, g, t_now, rng);
            } else {
                self.minus.devices[i].program_increment(
                    &self.minus.params, -g, t_now, rng);
            }
        }
    }

    /// Apply one signed weight increment to element `i` (overflow
    /// programming): positive pulses G+, negative pulses G−.
    pub fn apply_increment(&mut self, i: usize, dw: f32, t_now: f32,
                           rng: &mut Pcg64) -> u32 {
        let dg = self.w_to_g(dw.abs());
        if dw > 0.0 {
            self.plus.devices[i].program_increment(
                &self.plus.params, dg, t_now, rng)
        } else if dw < 0.0 {
            self.minus.devices[i].program_increment(
                &self.minus.params, dg, t_now, rng)
        } else {
            0
        }
    }

    /// Decode the weight matrix at `t_now` (drift, no read noise).
    pub fn decode(&self, t_now: f32) -> Vec<f32> {
        let gp = self.plus.drifted(t_now);
        let gm = self.minus.drifted(t_now);
        gp.iter()
            .zip(&gm)
            .map(|(p, m)| self.g_to_w(p - m))
            .collect()
    }

    /// Noisy read of the weight matrix (each device read independently).
    pub fn read_weights(&self, t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        let gp = self.plus.read(t_now, rng);
        let gm = self.minus.read(t_now, rng);
        gp.iter()
            .zip(&gm)
            .map(|(p, m)| self.g_to_w(p - m))
            .collect()
    }

    /// Pairs whose devices entered the saturation guard band.
    pub fn saturating(&self) -> Vec<usize> {
        (0..self.plus.len())
            .filter(|&i| {
                self.plus.devices[i].g > G_SAT
                    || self.minus.devices[i].g > G_SAT
            })
            .collect()
    }

    /// Selective saturation refresh (paper §III-A): read, RESET both,
    /// reprogram the difference.  Returns refreshed indices.
    pub fn refresh(&mut self, t_now: f32, rng: &mut Pcg64) -> Vec<usize> {
        let idx = self.saturating();
        for &i in &idx {
            let p = self.plus.devices[i].read(&self.plus.params, t_now, rng);
            let m =
                self.minus.devices[i].read(&self.minus.params, t_now, rng);
            let w = self.g_to_w(p - m).clamp(-self.w_max, self.w_max);
            self.plus.devices[i].reset(t_now);
            self.minus.devices[i].reset(t_now);
            let g = self.w_to_g(w);
            if g >= 0.0 {
                self.plus.devices[i].program_increment(
                    &self.plus.params, g, t_now, rng);
            } else {
                self.minus.devices[i].program_increment(
                    &self.minus.params, -g, t_now, rng);
            }
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(123, 0)
    }

    #[test]
    fn program_and_decode_ideal() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 2, 3, 1.0, &mut r);
        let w = [0.4f32, -0.6, 0.0, 1.0, -1.0, 0.25];
        pair.program_weights(&w, 0.0, &mut r);
        let got = pair.decode(0.0);
        for (a, b) in w.iter().zip(&got) {
            // Ideal linear device: quantized to dg0-sized pulses through
            // the conductance map (pulse granularity ~0.1/0.8=0.125 weight)
            assert!((a - b).abs() <= 0.13, "{a} vs {b}");
        }
    }

    #[test]
    fn increments_are_one_sided() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 1, 1, 1.0, &mut r);
        pair.apply_increment(0, 0.2, 0.0, &mut r);
        assert!(pair.plus.devices[0].g > 0.0);
        assert_eq!(pair.minus.devices[0].g, 0.0);
        pair.apply_increment(0, -0.3, 0.0, &mut r);
        assert!(pair.minus.devices[0].g > 0.0);
        assert_eq!(pair.apply_increment(0, 0.0, 0.0, &mut r), 0);
    }

    #[test]
    fn refresh_targets_only_saturating_pairs() {
        let mut r = rng();
        let mut pair =
            DifferentialPair::new(PcmParams::ideal(), 1, 4, 1.0, &mut r);
        // Drive element 0 into saturation via repeated +/- increments
        // (both devices climb; decoded weight stays small).
        for _ in 0..12 {
            pair.apply_increment(0, 0.12, 0.0, &mut r);
            pair.apply_increment(0, -0.12, 0.0, &mut r);
        }
        pair.apply_increment(1, 0.3, 0.0, &mut r); // healthy element
        let before = pair.decode(0.0);
        assert!(pair.plus.devices[0].g > G_SAT);

        let refreshed = pair.refresh(1.0, &mut r);
        assert_eq!(refreshed, vec![0]);
        // Refreshed pair decodes to (quantization-close) same weight...
        let after = pair.decode(1.0);
        assert!((after[0] - before[0]).abs() < 0.13,
                "{} vs {}", after[0], before[0]);
        // ...with conductances out of the guard band.
        assert!(pair.plus.devices[0].g < G_SAT);
        assert_eq!(pair.plus.devices[0].reset_count, 1);
        // Healthy pair untouched.
        assert_eq!(pair.plus.devices[1].reset_count, 0);
    }

    #[test]
    fn noisy_read_tracks_decode() {
        let mut r = rng();
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut pair = DifferentialPair::new(params, 4, 4, 1.0, &mut r);
        let w: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) / 10.0).collect();
        pair.program_weights(&w, 0.0, &mut r);
        let clean = pair.decode(0.0);
        let n = 2000;
        let mut mean = vec![0f64; 16];
        for _ in 0..n {
            for (m, v) in mean.iter_mut().zip(pair.read_weights(0.0, &mut r))
            {
                *m += v as f64 / n as f64;
            }
        }
        for (c, m) in clean.iter().zip(&mean) {
            assert!((*c as f64 - m).abs() < 0.01, "{c} vs {m}");
        }
    }
}
