//! PCM device-physics substrate (Rust twin of `python/compile/pcm_model.py`).
//!
//! The JAX implementation lives *inside* the lowered training programs and
//! uses a pulse-aggregated approximation for vectorization.  This module
//! implements the reference **pulse-by-pulse** process (each SET pulse an
//! individual stochastic event) plus everything host-side the coordinator
//! needs, with device state held **planar** (struct-of-arrays — one
//! contiguous plane per field, like the JAX `PcmArrays` NamedTuple) so
//! whole-array reads, drift evaluations, programming sweeps and
//! endurance scans are flat-slice passes:
//!
//! * [`device`] — the scalar single-device reference model (programming
//!   curve, write & read stochasticity, temporal drift); oracle for the
//!   SoA-equivalence property tests and the `device_at` view type
//! * [`array`] — planar `PcmArray` planes + batched kernels
//!   (`read_into`, `drift_into`, `program_increments`, `reset_where`)
//!   and the differential-pair weight mapping
//! * [`endurance`] — write–erase-cycle ledger and histograms (Fig. 6),
//!   ingesting whole count planes per sweep
//! * [`fault`] — device fault injection (stuck-at-SET/RESET/open,
//!   per-pulse programming failures, endurance wear-out) plus the
//!   write-verify / spare-remap degradation machinery and its
//!   [`FaultMap`] accounting; fully disabled by default and gated so a
//!   fault-off run is byte-identical (same arithmetic, same RNG draws)
//!   to every pinned golden — see the `fault` module docs for the RNG
//!   stream assignment
//!
//! Unit/property tests cross-validate the aggregate statistics of the
//! pulse-by-pulse process against the closed-form aggregate the JAX model
//! uses (`expected_increment`), bounding the approximation error, and pin
//! the planar kernels against the scalar reference on identical RNG
//! streams.

pub mod array;
pub mod device;
pub mod endurance;
pub mod fault;

pub use array::{DifferentialPair, PcmArray};
pub use device::{PcmDevice, PcmParams};
pub use endurance::{EnduranceLedger, Histogram};
pub use fault::{FaultMap, FaultSpec};
