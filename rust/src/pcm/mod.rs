//! PCM device-physics substrate (Rust twin of `python/compile/pcm_model.py`).
//!
//! The JAX implementation lives *inside* the lowered training programs and
//! uses a pulse-aggregated approximation for vectorization.  This module
//! implements the reference **pulse-by-pulse** process (each SET pulse an
//! individual stochastic event) plus everything host-side the coordinator
//! needs:
//!
//! * [`device`] — single multi-level / binary device: programming curve,
//!   write & read stochasticity, temporal drift
//! * [`array`] — arrays of devices with differential-pair weight mapping
//! * [`endurance`] — write–erase-cycle ledger and histograms (Fig. 6)
//!
//! Unit/property tests cross-validate the aggregate statistics of the
//! pulse-by-pulse process against the closed-form aggregate the JAX model
//! uses (`expected_increment`), bounding the approximation error.

pub mod array;
pub mod device;
pub mod endurance;

pub use array::{DifferentialPair, PcmArray};
pub use device::{PcmDevice, PcmParams};
pub use endurance::{EnduranceLedger, Histogram};
