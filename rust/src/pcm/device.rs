//! Single PCM device: pulse-by-pulse statistical model.
//!
//! Since the planar refactor this is the **scalar reference path**: the
//! hot paths run on the struct-of-arrays [`crate::pcm::PcmArray`] planes,
//! and `PcmDevice` serves (a) as the oracle the SoA-equivalence property
//! tests compare against on identical RNG streams, and (b) as the value
//! type `PcmArray::device_at` gathers for test-facing inspection.
//!
//! Parameters mirror `python/compile/configs.py::PcmConfig`; conductance
//! is normalized to [0, 1] (1.0 == G_max ≈ 25 µS on silicon).
//!
//! The model (Nandakumar et al. 2018 structure):
//! * nonlinear programming curve — the expected increment of the n-th SET
//!   pulse since RESET decays as `dg0 / (1 + n/n0)`;
//! * stochastic write — per-pulse Gaussian noise `σ_w · E[ΔG]`;
//! * stochastic read — additive Gaussian `σ_r` per read;
//! * temporal drift — `G(t) = G_prog · ((t−t_prog)/t0)^(−ν)` with a
//!   per-device exponent `ν ~ N(ν̄, σ_ν)`.

use crate::util::rng::Pcg64;

use super::fault::FaultSpec;

/// Device-model parameters (see `PcmConfig` for provenance / defaults).
///
/// `fault` declares the yield/wear-out model and the write-verify /
/// remap degradation machinery ([`FaultSpec`]); the default spec is
/// fully disabled, and the planar kernels only take fault branches
/// when [`FaultSpec::enabled`] is true.  The scalar [`PcmDevice`]
/// reference path deliberately stays fault-free — the SoA-equivalence
/// suite compares it against the planes with faults off.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PcmParams {
    pub dg0: f32,
    pub n0: f32,
    pub nonlinear: bool,
    pub write_sigma: f32,
    pub write_noise: bool,
    pub read_sigma: f32,
    pub read_noise: bool,
    pub drift_nu: f32,
    pub drift_nu_sigma: f32,
    pub drift_t0: f32,
    pub drift: bool,
    pub max_pulses: u32,
    pub fault: FaultSpec,
}

impl Default for PcmParams {
    fn default() -> Self {
        PcmParams {
            dg0: 0.10,
            n0: 15.0,
            nonlinear: true,
            write_sigma: 0.30,
            write_noise: true,
            read_sigma: 0.009,
            read_noise: true,
            drift_nu: 0.031,
            drift_nu_sigma: 0.007,
            drift_t0: 1.0,
            drift: true,
            max_pulses: 10,
            fault: FaultSpec::default(),
        }
    }
}

impl PcmParams {
    /// Ideal device (all non-idealities off) — for deterministic tests.
    pub fn ideal() -> Self {
        PcmParams {
            nonlinear: false,
            write_noise: false,
            read_noise: false,
            drift: false,
            ..Default::default()
        }
    }

    /// Expected per-pulse increment after `pulses` accumulated pulses.
    pub fn pulse_increment_mean(&self, pulses: f32) -> f32 {
        if self.nonlinear {
            self.dg0 / (1.0 + pulses / self.n0)
        } else {
            self.dg0
        }
    }

    /// Closed-form aggregate increment of `n` pulses from pulse count `p`
    /// (the approximation the JAX model lowers; validated against the
    /// pulse-by-pulse process in tests).
    pub fn aggregate_increment_mean(&self, p: f32, n: f32) -> f32 {
        if self.nonlinear {
            self.dg0 * self.n0 * (((self.n0 + p + n) / (self.n0 + p)).ln())
        } else {
            self.dg0 * n
        }
    }

    /// Pulses the write circuit schedules for a target increment.
    pub fn pulses_for_target(&self, p: f32, dg_target: f32) -> u32 {
        if dg_target <= 0.0 {
            return 0;
        }
        let n = if self.nonlinear {
            (self.n0 + p) * ((dg_target / (self.dg0 * self.n0)).exp() - 1.0)
        } else {
            dg_target / self.dg0
        };
        (n.ceil().max(1.0) as u32).min(self.max_pulses)
    }
}

/// One multi-level PCM device.
#[derive(Clone, Debug)]
pub struct PcmDevice {
    /// conductance programmed at `t_prog` (drift reference value)
    pub g: f32,
    /// SET pulses since last RESET
    pub pulses: f32,
    /// time of last programming event (s)
    pub t_prog: f32,
    /// per-device drift exponent
    pub nu: f32,
    /// lifetime counters (endurance)
    pub set_count: u64,
    pub reset_count: u64,
}

impl PcmDevice {
    /// A fresh (RESET, never-programmed) device with a sampled ν.
    pub fn new(params: &PcmParams, rng: &mut Pcg64) -> Self {
        let nu = (params.drift_nu
            + params.drift_nu_sigma * rng.normal() as f32)
            .clamp(0.0, 0.12);
        PcmDevice { g: 0.0, pulses: 0.0, t_prog: 0.0, nu,
                    set_count: 0, reset_count: 0 }
    }

    /// Apply one SET pulse at time `t_now`.
    pub fn set_pulse(&mut self, params: &PcmParams, t_now: f32,
                     rng: &mut Pcg64) {
        let mean = params.pulse_increment_mean(self.pulses);
        let dg = if params.write_noise {
            mean + params.write_sigma * mean * rng.normal() as f32
        } else {
            mean
        };
        self.g = (self.g + dg.max(0.0)).clamp(0.0, 1.0);
        self.pulses += 1.0;
        self.t_prog = t_now;
        self.set_count += 1;
    }

    /// Program towards a target increment (`dg_target` >= 0) using the
    /// pulse-by-pulse process; returns the number of pulses applied.
    pub fn program_increment(&mut self, params: &PcmParams, dg_target: f32,
                             t_now: f32, rng: &mut Pcg64) -> u32 {
        let n = params.pulses_for_target(self.pulses, dg_target);
        for _ in 0..n {
            self.set_pulse(params, t_now, rng);
        }
        n
    }

    /// RESET to the low-conductance state.
    pub fn reset(&mut self, t_now: f32) {
        self.g = 0.0;
        self.pulses = 0.0;
        self.t_prog = t_now;
        self.reset_count += 1;
    }

    /// Drifted conductance at `t_now` (no read noise).
    pub fn drifted(&self, params: &PcmParams, t_now: f32) -> f32 {
        if !params.drift {
            return self.g;
        }
        let elapsed = (t_now - self.t_prog).max(params.drift_t0);
        self.g * (elapsed / params.drift_t0).powf(-self.nu)
    }

    /// One stochastic read at `t_now`.
    pub fn read(&self, params: &PcmParams, t_now: f32,
                rng: &mut Pcg64) -> f32 {
        let mut g = self.drifted(params, t_now);
        if params.read_noise {
            g += params.read_sigma * rng.normal() as f32;
        }
        g.clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(42, 0)
    }

    #[test]
    fn ideal_linear_programming_is_exact() {
        let p = PcmParams::ideal();
        let mut r = rng();
        let mut d = PcmDevice::new(&p, &mut r);
        let n = d.program_increment(&p, 0.35, 1.0, &mut r);
        assert_eq!(n, 4); // ceil(0.35 / 0.1)
        assert!((d.g - 0.4).abs() < 1e-6);
        assert_eq!(d.set_count, 4);
        assert_eq!(d.pulses, 4.0);
    }

    #[test]
    fn nonlinear_curve_saturates() {
        let p = PcmParams { write_noise: false, read_noise: false,
                            drift: false, ..Default::default() };
        let mut r = rng();
        let mut d = PcmDevice::new(&p, &mut r);
        let mut increments = Vec::new();
        for _ in 0..30 {
            let before = d.g;
            d.set_pulse(&p, 0.0, &mut r);
            increments.push(d.g - before);
        }
        // Strictly decreasing per-pulse gain.
        for w in increments.windows(2) {
            assert!(w[1] < w[0] + 1e-9, "{:?}", w);
        }
        // 30 pulses of the nonlinear curve stay below linear total (3.0)
        assert!(d.g < 1.0 + 1e-6);
    }

    #[test]
    fn aggregate_matches_pulsewise_mean() {
        // The closed-form aggregate the JAX model lowers must match the
        // pulse-by-pulse expectation within a few percent.
        let p = PcmParams { write_noise: false, read_noise: false,
                            drift: false, ..Default::default() };
        for start_pulses in [0.0f32, 5.0, 20.0] {
            for n in [1u32, 3, 7, 10] {
                let mut exact = 0.0f32;
                let mut pulses = start_pulses;
                for _ in 0..n {
                    exact += p.pulse_increment_mean(pulses);
                    pulses += 1.0;
                }
                let agg = p.aggregate_increment_mean(start_pulses, n as f32);
                let rel = (agg - exact).abs() / exact;
                assert!(rel < 0.05,
                        "p0={start_pulses} n={n}: exact={exact} agg={agg}");
            }
        }
    }

    #[test]
    fn write_noise_statistics() {
        let p = PcmParams { nonlinear: false, read_noise: false,
                            drift: false, ..Default::default() };
        let mut r = rng();
        let trials = 20_000;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for _ in 0..trials {
            let mut d = PcmDevice::new(&p, &mut r);
            d.set_pulse(&p, 0.0, &mut r);
            sum += d.g as f64;
            sumsq += (d.g as f64) * (d.g as f64);
        }
        let mean = sum / trials as f64;
        let std = (sumsq / trials as f64 - mean * mean).sqrt();
        assert!((mean - 0.1).abs() < 0.002, "mean={mean}");
        // σ = write_sigma * dg0 = 0.03 (slightly shrunk by the max(0) clip)
        assert!((std - 0.03).abs() < 0.004, "std={std}");
    }

    #[test]
    fn drift_decays_and_respects_t0() {
        let p = PcmParams { write_noise: false, read_noise: false,
                            nonlinear: false, drift_nu_sigma: 0.0,
                            ..Default::default() };
        let mut r = rng();
        let mut d = PcmDevice::new(&p, &mut r);
        d.program_increment(&p, 0.5, 100.0, &mut r);
        let g0 = d.drifted(&p, 100.0 + p.drift_t0);
        let g_day = d.drifted(&p, 100.0 + 86_400.0);
        let g_year = d.drifted(&p, 100.0 + 3.15e7);
        assert!(g0 > g_day && g_day > g_year);
        // ν = 0.031: one-day decay factor (86400)^-0.031 ≈ 0.70
        let expect = (86_400.0f32 / p.drift_t0).powf(-0.031);
        assert!((g_day / g0 - expect).abs() < 0.01,
                "ratio={} expect={expect}", g_day / g0);
        // within t0 of programming: no drift applied
        assert!((d.drifted(&p, 100.0) - d.g).abs() < 1e-6);
    }

    #[test]
    fn reset_clears_and_counts() {
        let p = PcmParams::ideal();
        let mut r = rng();
        let mut d = PcmDevice::new(&p, &mut r);
        d.program_increment(&p, 0.3, 5.0, &mut r);
        d.reset(6.0);
        assert_eq!(d.g, 0.0);
        assert_eq!(d.pulses, 0.0);
        assert_eq!(d.reset_count, 1);
        assert_eq!(d.t_prog, 6.0);
    }

    #[test]
    fn max_pulses_clamped() {
        let p = PcmParams::ideal();
        assert_eq!(p.pulses_for_target(0.0, 5.0), 10); // clamped
        assert_eq!(p.pulses_for_target(0.0, 0.0), 0);
        assert_eq!(p.pulses_for_target(0.0, 0.05), 1);
    }

    #[test]
    fn read_noise_zero_mean() {
        let p = PcmParams { nonlinear: false, write_noise: false,
                            drift: false, ..Default::default() };
        let mut r = rng();
        let mut d = PcmDevice::new(&p, &mut r);
        d.program_increment(&p, 0.5, 0.0, &mut r);
        let n = 10_000;
        let mean: f64 = (0..n)
            .map(|_| d.read(&p, 0.0, &mut r) as f64)
            .sum::<f64>() / n as f64;
        assert!((mean - d.g as f64).abs() < 0.001);
    }
}
