//! Write–erase-cycle accounting (paper §III-E, Fig. 6).
//!
//! Following Tuma et al. (2016), one **write–erase cycle** is a sequence
//! of at most 10 SET pulses followed by a RESET pulse.  The ledger
//! converts per-device lifetime (SET, RESET) counters — tracked both by
//! the Rust device model and, in packed form, by the lowered training
//! programs — into WE-cycle estimates and histograms, and compares them
//! against the 10^8 endurance limit.

use std::fmt;

/// PCM endurance limit (write–erase cycles), Tuma et al. 2016.
pub const ENDURANCE_LIMIT: f64 = 1e8;

/// SET pulses per WE cycle in the Tuma et al. definition.
pub const SETS_PER_CYCLE: u64 = 10;

/// Per-device WE-cycle estimate from lifetime counters.
///
/// Every RESET closes a cycle; additionally, every `SETS_PER_CYCLE` SET
/// pulses amount to a cycle even if the device was never RESET (the
/// definition's "at most 10 SETs" clause), so the estimate is
/// `max(resets, ceil(sets / 10))`.
pub fn we_cycles(sets: u64, resets: u64) -> u64 {
    let by_sets = sets.div_ceil(SETS_PER_CYCLE);
    resets.max(by_sets)
}

/// Log-bucketed histogram for WE-cycle distributions (Fig. 6 uses a log
/// x-axis; buckets are powers of two to keep it parameter-free).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts values in [2^i, 2^(i+1)); bucket 0 includes 0 and 1.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub max: u64,
    pub sum: u128,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: vec![0; 40], count: 0, max: 0, sum: 0 }
    }

    pub fn add(&mut self, v: u64) {
        let b = if v <= 1 { 0 } else { 63 - v.leading_zeros() as usize };
        if b >= self.buckets.len() {
            self.buckets.resize(b + 1, 0);
        }
        self.buckets[b] += 1;
        self.count += 1;
        self.max = self.max.max(v);
        self.sum += v as u128;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Percentile (nearest-rank over bucket lower-bounds; adequate for the
    /// order-of-magnitude comparisons of Fig. 6).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i == 0 { 0 } else { 1u64 << i };
            }
        }
        self.max
    }

    /// Fraction of the endurance limit consumed by the worst device.
    pub fn endurance_fraction(&self) -> f64 {
        self.max as f64 / ENDURANCE_LIMIT
    }

    /// Non-empty (bucket_lower_bound, count) pairs — CSV/report rows.
    pub fn rows(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (if i == 0 { 0 } else { 1u64 << i }, c))
            .collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Display for Histogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "devices={} mean={:.1} max={} (endurance {:.2e} of 1e8)",
                 self.count, self.mean(), self.max,
                 self.endurance_fraction())?;
        let peak = self.buckets.iter().copied().max().unwrap_or(1).max(1);
        for (lo, c) in self.rows() {
            let bar = "#".repeat((c * 50 / peak).max(1) as usize);
            writeln!(f, "{lo:>10} | {bar} {c}")?;
        }
        Ok(())
    }
}

/// Whole-array ledger: WE cycles per device, split MSB vs LSB.
#[derive(Clone, Debug, Default)]
pub struct EnduranceLedger {
    pub msb: Histogram,
    pub lsb: Histogram,
}

impl EnduranceLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one MSB device from lifetime (sets, resets).
    pub fn record_msb(&mut self, sets: u64, resets: u64) {
        self.msb.add(we_cycles(sets, resets));
    }

    /// Record a whole MSB array from its planar lifetime-counter planes
    /// (one `PcmArray` sweep — the planar twin of calling
    /// [`EnduranceLedger::record_msb`] per device in row-major order).
    pub fn record_msb_planes(&mut self, sets: &[u64], resets: &[u64]) {
        assert_eq!(sets.len(), resets.len());
        for (&s, &r) in sets.iter().zip(resets) {
            self.msb.add(we_cycles(s, r));
        }
    }

    /// Record one LSB *weight* (7 binary devices) from the packed
    /// training-program counters: total flips and RESET events are summed
    /// over the 7 devices, so attribute the per-device average.
    pub fn record_lsb_weight(&mut self, flips: u64, resets: u64,
                             bits: u64) {
        // Per-device: a binary device's WE cycle is SET followed by RESET;
        // resets counts exactly the completed cycles across the register.
        let per_device = resets.div_ceil(bits.max(1));
        let _ = flips;
        self.lsb.add(per_device);
    }

    /// Paper Fig. 6 headline check: MSB max < LSB max << endurance.
    pub fn summary(&self) -> String {
        format!(
            "MSB: max {} WE cycles ({:.2e} of limit) | LSB: max {} \
             ({:.2e} of limit)",
            self.msb.max,
            self.msb.endurance_fraction(),
            self.lsb.max,
            self.lsb.endurance_fraction()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn we_cycle_definition() {
        assert_eq!(we_cycles(0, 0), 0);
        assert_eq!(we_cycles(10, 1), 1);
        assert_eq!(we_cycles(11, 1), 2); // 11 SETs = 2 cycles by the clause
        assert_eq!(we_cycles(5, 3), 3);  // resets dominate
        assert_eq!(we_cycles(100, 0), 10);
    }

    #[test]
    fn histogram_buckets_and_stats() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 1, 2, 3, 4, 150, 20_000] {
            h.add(v);
        }
        assert_eq!(h.count, 8);
        assert_eq!(h.max, 20_000);
        assert!(h.endurance_fraction() < 1e-3);
        let rows = h.rows();
        assert_eq!(rows[0], (0, 3)); // 0,1,1
        assert!(rows.iter().any(|&(lo, c)| lo == 128 && c == 1)); // 150
        assert!(rows.iter().any(|&(lo, c)| lo == 16_384 && c == 1));
        assert!((h.mean() - 20160.0 / 8.0).abs() < 1.0);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = Histogram::new();
        for v in 0..1000u64 {
            h.add(v);
        }
        assert!(h.percentile(50.0) <= h.percentile(90.0));
        assert!(h.percentile(90.0) <= h.percentile(100.0).max(h.max));
        assert_eq!(Histogram::new().percentile(50.0), 0);
    }

    #[test]
    fn plane_sweep_matches_per_device() {
        let sets: Vec<u64> = (0..100).map(|i| 3 * i).collect();
        let resets: Vec<u64> = (0..100).map(|i| i % 7).collect();
        let mut a = EnduranceLedger::new();
        a.record_msb_planes(&sets, &resets);
        let mut b = EnduranceLedger::new();
        for (&s, &r) in sets.iter().zip(&resets) {
            b.record_msb(s, r);
        }
        assert_eq!(a.msb.count, b.msb.count);
        assert_eq!(a.msb.max, b.msb.max);
        assert_eq!(a.msb.buckets, b.msb.buckets);
    }

    #[test]
    fn ledger_paper_shape() {
        // Synthetic full-training ledger: MSB devices see < 150 cycles,
        // LSB weights see < 20 K — the Fig. 6 shape.
        let mut l = EnduranceLedger::new();
        for i in 0..1000u64 {
            l.record_msb(3 * (i % 50), i % 20);
            l.record_lsb_weight(14 * (i % 1000), 7 * (i % 1000), 7);
        }
        assert!(l.msb.max < 150);
        assert!(l.lsb.max <= 20_000);
        assert!(l.msb.max < l.lsb.max);
        assert!(l.msb.endurance_fraction() < 1e-4);
        assert!(l.lsb.endurance_fraction() < 1e-3);
        assert!(!l.summary().is_empty());
    }
}
