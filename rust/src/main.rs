//! `hic-train` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   train     run an end-to-end HIC training (loss curve + eval + CSVs)
//!   baseline  run the FP32 software baseline
//!   fig3      regenerate the PCM non-ideality ablation (paper Fig. 3)
//!   fig4      regenerate the width-multiplier sweep (paper Fig. 4)
//!   fig5      regenerate the drift/AdaBS study (paper Fig. 5)
//!   fig6      regenerate the write–erase-cycle histograms (paper Fig. 6)
//!   serve     drift-aware inference serving under synthetic load
//!   run       run an experiment described by a .hic spec file
//!   info      inspect an artifact set (entries, sizes, config echo)
//!
//! All compute runs through AOT-compiled HLO artifacts on PJRT; Python is
//! never invoked.

use std::path::PathBuf;

use anyhow::{bail, Result};

use hic_train::coordinator::schedule::LrSchedule;
use hic_train::coordinator::{BaselineTrainer, Trainer};
use hic_train::exp::{self, ExpOptions};
use hic_train::runtime::artifact::artifact_root;
use hic_train::runtime::Engine;
use hic_train::util::cli::Spec;
use hic_train::util::logging::{set_level, Level};
use hic_train::log_info;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "baseline" => cmd_baseline(rest),
        "fig3" => cmd_fig3(rest),
        "fig4" => cmd_fig4(rest),
        "fig5" => cmd_fig5(rest),
        "fig6" | "endurance" => cmd_fig6(rest),
        "serve" => cmd_serve(rest),
        "run" => cmd_run(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `hic-train help`)"),
    }
}

fn print_usage() {
    println!(
        "hic-train — Hybrid In-memory Computing DNN training \
         (Joshi et al. 2021 reproduction)\n\n\
         usage: hic-train <subcommand> [options]\n\n\
         subcommands:\n\
         \x20 train      end-to-end HIC training run\n\
         \x20 baseline   FP32 software baseline run\n\
         \x20 fig3       PCM non-ideality ablation      (paper Fig. 3)\n\
         \x20 fig4       width sweep: acc vs model size (paper Fig. 4)\n\
         \x20 fig5       drift + AdaBS study            (paper Fig. 5)\n\
         \x20 fig6       write–erase cycle histograms   (paper Fig. 6)\n\
         \x20 serve      drift-aware serving under load (fig5 axis)\n\
         \x20 run        run an experiment from a .hic spec file\n\
         \x20 info       inspect an artifact set\n\n\
         fig3/fig4/fig5/fig6 accept --device-grid to run on the sharded\n\
         crossbar grid device model (no artifacts needed); fig4's grid\n\
         path trains multi-layer networks with per-layer crossbar\n\
         grids and transposed-VMM backprop — dense stacks (--arch mlp)\n\
         or conv/residual ResNet stages via im2col patch lowering\n\
         (--arch resnet; --long-run = the paper's full ResNet-32 /\n\
         CIFAR-10 shape); fig6 --faults runs the device fault-injection\n\
         sweep (accuracy vs stuck rate / endurance limit with\n\
         write-verify degradation accounting).\n\
         run any subcommand with --help for its options"
    );
}

fn common_exp_spec(name: &'static str, about: &'static str) -> Spec {
    Spec::new(name, about)
        .opt("steps", "300", "training steps per run")
        .opt("seeds", "42", "comma-separated seeds")
        .opt("eval-batches", "16", "evaluation batches")
        .opt("lr", "0.5", "initial learning rate (scaled-run default)")
        .opt("lr-decay", "0.45", "decay factor at 50%/75% of the run")
        .opt("data-scale", "0.05",
             "synthetic dataset size vs CIFAR-10 (1.0 = 50k)")
        .opt("out", "results", "output directory for CSVs")
        .flag("verbose", "debug logging")
}

/// Grid-routing options shared by the fig3/fig5/fig6 subcommands: with
/// `--device-grid` the sweep runs on the sharded crossbar device model
/// (no artifacts/PJRT needed) and writes `<out>/figN_grid.json`.
fn with_grid_opts(spec: Spec) -> Spec {
    spec.flag("device-grid",
              "route the sweep through the crossbar grid device model")
        .opt("grid-k", "64", "[device-grid] logical matrix rows")
        .opt("grid-n", "32", "[device-grid] logical matrix cols")
        .opt("grid-tile", "16", "[device-grid] physical tile size")
        .opt("grid-steps", "60", "[device-grid] training steps")
        .opt("grid-batch", "8", "[device-grid] batch size")
        .opt("workers", "0",
             "[device-grid] worker threads (0 = HIC_WORKERS/auto)")
}

fn parse_grid_opts(m: &hic_train::util::cli::Matches)
                   -> Result<hic_train::exp::gridexp::GridExpOptions> {
    if m.flag("verbose") {
        set_level(Level::Debug);
    }
    for key in ["grid-k", "grid-n", "grid-tile", "grid-batch"] {
        if m.usize(key)? == 0 {
            bail!("--{key} must be >= 1");
        }
    }
    Ok(hic_train::exp::gridexp::GridExpOptions {
        k: m.usize("grid-k")?,
        n: m.usize("grid-n")?,
        tile: m.usize("grid-tile")?,
        steps: m.usize("grid-steps")?,
        batch: m.usize("grid-batch")?,
        seed: m
            .list("seeds")
            .first()
            .map(|s| s.parse::<u64>())
            .transpose()?
            .unwrap_or(42),
        workers: m.usize("workers")?,
        out_dir: PathBuf::from(m.str("out")?),
    })
}

fn parse_exp(m: &hic_train::util::cli::Matches) -> Result<ExpOptions> {
    if m.flag("verbose") {
        set_level(Level::Debug);
    }
    Ok(ExpOptions {
        steps: m.usize("steps")?,
        seeds: m
            .list("seeds")
            .iter()
            .map(|s| s.parse::<u64>())
            .collect::<std::result::Result<Vec<_>, _>>()?,
        eval_batches: m.usize("eval-batches")?,
        lr0: m.f32("lr")?,
        lr_decay: m.f32("lr-decay")?,
        data_scale: m.f64("data-scale")?,
        out_dir: PathBuf::from(m.str("out")?),
    })
}

fn cmd_train(args: &[String]) -> Result<()> {
    let spec = common_exp_spec("train", "end-to-end HIC training run")
        .opt("config", "core", "artifact config name")
        .opt("eval-every", "100", "steps between evaluations (0 = end only)")
        .opt("refresh-every", "10", "batches between MSB refreshes")
        .opt("checkpoint", "", "path to save the final device state");
    let m = spec.parse(args)?;
    let opts = parse_exp(&m)?;
    let config = m.string("config")?;

    let dir = exp::config_dir(&config)?;
    let mut topts = opts.trainer_options(opts.seeds[0]);
    topts.lr = LrSchedule::paper(opts.lr0, opts.lr_decay, opts.steps);
    topts.refresh_every = m.usize("refresh-every")?;
    let mut t = Trainer::new(&dir, topts)?;

    let eval_every = m.usize("eval-every")?;
    let mut done = 0;
    while done < opts.steps {
        let chunk = if eval_every == 0 {
            opts.steps - done
        } else {
            eval_every.min(opts.steps - done)
        };
        t.train_steps(chunk)?;
        done += chunk;
        let ev = t.evaluate(opts.eval_batches, None)?;
        log_info!(
            "step {:>5}: train loss {:.3} acc {:.3} | eval acc {:.3} | \
             {:.0} ms/step",
            t.step,
            t.metrics.smoothed_loss(50),
            t.metrics.smoothed_acc(50),
            ev.accuracy,
            t.metrics.mean_step_ms()
        );
    }

    exp::ensure_out_dir(&opts.out_dir)?;
    t.metrics
        .write_steps_csv(&opts.out_dir.join(format!("{config}_steps.csv")))?;
    t.metrics
        .write_evals_csv(&opts.out_dir.join(format!("{config}_evals.csv")))?;
    let ledger = t.endurance()?;
    println!("{}", ledger.summary());
    if let Some(path) = m.get("checkpoint") {
        if !path.is_empty() {
            t.save_checkpoint(&PathBuf::from(path))?;
        }
    }
    for (entry, (calls, secs)) in t.engine.stats() {
        log_info!("perf: {entry}: {calls} calls, {:.1} ms avg",
                  1e3 * secs / calls.max(1) as f64);
    }
    Ok(())
}

fn cmd_baseline(args: &[String]) -> Result<()> {
    let spec = common_exp_spec("baseline", "FP32 software baseline run")
        .opt("config", "core", "artifact config name (with baseline)");
    let m = spec.parse(args)?;
    let opts = parse_exp(&m)?;
    let config = m.string("config")?;
    let dir = exp::config_dir(&config)?;
    let mut topts = opts.trainer_options(opts.seeds[0]);
    topts.lr = LrSchedule::paper(0.1, 0.1, opts.steps);
    let mut bt = BaselineTrainer::new(&dir, topts)?;
    bt.train_steps(opts.steps)?;
    let ev = bt.evaluate(opts.eval_batches)?;
    log_info!(
        "baseline: train loss {:.3} acc {:.3} | eval acc {:.3}",
        bt.metrics.smoothed_loss(50),
        bt.metrics.smoothed_acc(50),
        ev.accuracy
    );
    exp::ensure_out_dir(&opts.out_dir)?;
    bt.metrics.write_steps_csv(
        &opts.out_dir.join(format!("{config}_baseline_steps.csv")))?;
    Ok(())
}

fn cmd_fig3(args: &[String]) -> Result<()> {
    let spec = with_grid_opts(common_exp_spec(
        "fig3", "PCM non-ideality ablation (paper Fig. 3)"));
    let m = spec.parse(args)?;
    if m.flag("device-grid") {
        let gopts = parse_grid_opts(&m)?;
        let variants: Vec<&str> = exp::fig3::VARIANTS.to_vec();
        let doc = exp::gridexp::run_fig3(&gopts, &variants)?;
        exp::gridexp::write_json(&gopts.out_dir, "fig3_grid.json", &doc)?;
        return Ok(());
    }
    let opts = parse_exp(&m)?;
    exp::fig3::run(&opts)?;
    Ok(())
}

fn cmd_fig4(args: &[String]) -> Result<()> {
    let spec = common_exp_spec(
        "fig4", "width sweep: accuracy vs model size (paper Fig. 4)")
        .flag("device-grid",
              "run the multi-layer sweep on the crossbar grid device \
               model (per-layer grids, transposed-VMM backprop)")
        .opt("arch", "mlp",
             "[device-grid] architecture: mlp (dense stack) or resnet \
              (conv/residual stages on the layer graph)")
        .opt("nn-data", "cifar",
             "[device-grid] feature source: cifar (pooled synthetic) \
              or blobs (portable)")
        .opt("nn-pool", "", "[device-grid] CIFAR pooling factor \
              (default: 8; resnet default: 4 -> 8x8 images)")
        .opt("nn-dim", "32", "[device-grid] blob feature dimension \
              (mlp)")
        .opt("nn-image", "8,8,3",
             "[device-grid] blob image shape h,w,c (resnet)")
        .opt("nn-hidden", "32,16",
             "[device-grid] base hidden widths (mlp)")
        .opt("nn-stages", "16,32,64",
             "[device-grid] base stage channels (resnet)")
        .opt("nn-blocks", "1",
             "[device-grid] residual blocks per stage (resnet; \
              ResNet-32 = 5)")
        .flag("long-run",
              "[device-grid] scale --arch resnet to the paper's full \
               ResNet-32 / CIFAR-10 shape (5 blocks per stage, \
               unpooled 32x32x3 inputs)")
        .opt("widths", "0.5,0.75,1.0,1.5",
             "[device-grid] width multipliers")
        .opt("nn-steps", "150", "[device-grid] training steps")
        .opt("nn-batch", "16", "[device-grid] batch size")
        .opt("nn-tile", "32", "[device-grid] physical tile size")
        .opt("nn-eval", "200", "[device-grid] evaluation samples")
        .opt("nn-lr", "0.1", "[device-grid] learning rate")
        .opt("workers", "0",
             "[device-grid] worker threads (0 = HIC_WORKERS/auto)");
    let m = spec.parse(args)?;
    if m.flag("device-grid") {
        let nopts = parse_nn_opts(&m)?;
        let name = match nopts.arch {
            hic_train::exp::gridexp::NnArch::Mlp => "fig4_grid.json",
            hic_train::exp::gridexp::NnArch::Resnet { .. } => {
                "fig4_resnet_grid.json"
            }
            hic_train::exp::gridexp::NnArch::Custom { .. } => {
                "fig4_custom_grid.json"
            }
        };
        let doc = exp::gridexp::run_fig4(&nopts)?;
        exp::gridexp::write_json(&nopts.out_dir, name, &doc)?;
        return Ok(());
    }
    let opts = parse_exp(&m)?;
    exp::fig4::run(&opts)?;
    Ok(())
}

fn parse_nn_opts(m: &hic_train::util::cli::Matches)
                 -> Result<hic_train::exp::gridexp::NnExpOptions> {
    use hic_train::exp::gridexp::{NnArch, NnExpData, NnExpOptions};
    if m.flag("verbose") {
        set_level(Level::Debug);
    }
    let arch = match m.str("arch")? {
        "mlp" => NnArch::Mlp,
        "resnet" => {
            let stages = m
                .list("nn-stages")
                .iter()
                .map(|s| s.parse::<usize>())
                .collect::<std::result::Result<Vec<_>, _>>()?;
            let [s1, s2, s3] = stages[..] else {
                bail!("--nn-stages needs exactly three channel bases");
            };
            let blocks = m.usize("nn-blocks")?;
            if blocks == 0 {
                bail!("--nn-blocks must be >= 1");
            }
            NnArch::Resnet { stages: [s1, s2, s3], blocks }
        }
        other => bail!("unknown --arch '{other}' (mlp | resnet)"),
    };
    let resnet = matches!(arch, NnArch::Resnet { .. });
    let data = match m.str("nn-data")? {
        "cifar" => {
            // The resnet arch wants spatial extent left to work with:
            // default to 4×-pooled 8x8 images unless --nn-pool is given.
            let pool = match m.get("nn-pool") {
                Some(s) => s.parse::<usize>()?,
                None if resnet => 4,
                None => 8,
            };
            if pool == 0 || 32 % pool != 0 {
                bail!("--nn-pool must divide the 32x32 image \
                       (1, 2, 4, 8, 16 or 32)");
            }
            NnExpData::Cifar { pool }
        }
        "blobs" if resnet => {
            let dims = m
                .list("nn-image")
                .iter()
                .map(|s| s.parse::<usize>())
                .collect::<std::result::Result<Vec<_>, _>>()?;
            let [h, w, c] = dims[..] else {
                bail!("--nn-image needs h,w,c");
            };
            if h == 0 || w == 0 || c == 0 {
                bail!("--nn-image extents must be >= 1");
            }
            NnExpData::BlobsImg { h, w, c }
        }
        "blobs" => NnExpData::Blobs { dim: m.usize("nn-dim")? },
        other => bail!("unknown --nn-data '{other}' (cifar | blobs)"),
    };
    let hidden_base = m
        .list("nn-hidden")
        .iter()
        .map(|s| s.parse::<usize>())
        .collect::<std::result::Result<Vec<_>, _>>()?;
    let widths_permille = m
        .list("widths")
        .iter()
        .map(|s| -> Result<u32> {
            let w: f64 = s.parse()?;
            if !(0.001..=64.0).contains(&w) {
                bail!("width multiplier {w} out of range");
            }
            Ok((w * 1000.0 + 0.5).floor() as u32)
        })
        .collect::<Result<Vec<_>>>()?;
    if hidden_base.is_empty() || widths_permille.is_empty() {
        bail!("--nn-hidden and --widths must be non-empty");
    }
    // (--nn-pool and --nn-image are validated where they are parsed.)
    for key in ["nn-dim", "nn-steps", "nn-batch", "nn-tile", "nn-eval"] {
        if m.usize(key)? == 0 {
            bail!("--{key} must be >= 1");
        }
    }
    let mut opts = NnExpOptions {
        data,
        arch,
        hidden_base,
        widths_permille,
        steps: m.usize("nn-steps")?,
        batch: m.usize("nn-batch")?,
        tile: m.usize("nn-tile")?,
        eval_n: m.usize("nn-eval")?,
        lr: m.f32("nn-lr")?,
        seed: m
            .list("seeds")
            .first()
            .map(|s| s.parse::<u64>())
            .transpose()?
            .unwrap_or(42),
        workers: m.usize("workers")?,
        out_dir: PathBuf::from(m.str("out")?),
        ..Default::default()
    };
    if m.flag("long-run") {
        opts.apply_long_run()?;
    }
    Ok(opts)
}

fn cmd_fig5(args: &[String]) -> Result<()> {
    let spec = with_grid_opts(common_exp_spec(
        "fig5", "drift + AdaBS inference study (paper Fig. 5)"))
        .opt("config", "fig5_drift", "artifact config to train");
    let m = spec.parse(args)?;
    if m.flag("device-grid") {
        let gopts = parse_grid_opts(&m)?;
        let doc = exp::gridexp::run_fig5(&gopts)?;
        exp::gridexp::write_json(&gopts.out_dir, "fig5_grid.json", &doc)?;
        return Ok(());
    }
    let opts = parse_exp(&m)?;
    exp::fig5::run(&opts, m.str("config")?)?;
    Ok(())
}

fn cmd_fig6(args: &[String]) -> Result<()> {
    let spec = with_grid_opts(common_exp_spec(
        "fig6", "write–erase cycle histograms (paper Fig. 6)"))
        .opt("config", "core", "artifact config to train")
        .flag("faults",
              "[device-grid] run the fault-injection sweep instead: \
               accuracy vs stuck-device rate and endurance limit, with \
               write-verify degradation accounting; writes \
               <out>/fig6_faults_grid.json")
        .opt("fault-rates", "0.0,0.02,0.05,0.1",
             "[faults] comma-separated total stuck-device rates")
        .opt("endurance-limits", "0,1000",
             "[faults] comma-separated endurance limits (0 = unlimited)")
        .opt("fault-retries", "3",
             "[faults] write-verify retry budget per programming event");
    let m = spec.parse(args)?;
    if m.flag("faults") {
        let fopts = hic_train::exp::gridexp::FaultSweepOptions {
            grid: parse_grid_opts(&m)?,
            rates: m
                .list("fault-rates")
                .iter()
                .map(|s| s.parse::<f32>())
                .collect::<std::result::Result<Vec<_>, _>>()?,
            endurance: m
                .list("endurance-limits")
                .iter()
                .map(|s| s.parse::<u64>())
                .collect::<std::result::Result<Vec<_>, _>>()?,
            max_retries: m.usize("fault-retries")? as u32,
        };
        let doc = exp::gridexp::run_fig6_faults(&fopts)?;
        exp::gridexp::write_json(&fopts.grid.out_dir,
                                 "fig6_faults_grid.json", &doc)?;
        return Ok(());
    }
    if m.flag("device-grid") {
        let gopts = parse_grid_opts(&m)?;
        let doc = exp::gridexp::run_fig6(&gopts)?;
        exp::gridexp::write_json(&gopts.out_dir, "fig6_grid.json", &doc)?;
        return Ok(());
    }
    let opts = parse_exp(&m)?;
    exp::fig6::run(&opts, m.str("config")?)?;
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use hic_train::exp::serve::{ServeData, ServeExpOptions};
    let spec = Spec::new(
        "serve",
        "drift-aware inference serving under synthetic load: train a \
         dense MLP on the crossbar grids, freeze it into a read-only \
         snapshot, then replay a deterministic request trace through \
         the batch-coalescing scheduler at each fig5 drift probe, \
         uncalibrated and gain-recalibrated; writes \
         <out>/fig5_serve.json")
        .opt("data", "cifar",
             "feature source: cifar (real bytes when present, synthetic \
              fallback) or blobs (portable)")
        .opt("nn-pool", "8", "CIFAR pooling factor")
        .opt("nn-dim", "32", "blob feature dimension")
        .opt("nn-hidden", "32,16", "hidden widths of the dense stack")
        .opt("nn-classes", "10", "classes (blobs; CIFAR is always 10)")
        .opt("nn-steps", "150", "training steps before the freeze")
        .opt("nn-batch", "16", "training batch size")
        .opt("nn-tile", "32", "physical tile size")
        .opt("nn-lr", "0.1", "learning rate")
        .opt("train-len", "2000", "train-split size (synthetic sources)")
        .opt("test-len", "500", "test-split size (synthetic sources)")
        .opt("seeds", "42", "comma-separated seeds (first one is used)")
        .opt("requests", "256", "requests per probe trace")
        .opt("mean-gap", "0.01",
             "mean request inter-arrival gap (simulated seconds)")
        .opt("window", "0.05",
             "coalescing window (simulated seconds)")
        .opt("max-batch", "16", "max requests per coalesced batch")
        .opt("queue-cap", "64", "bounded request-channel capacity")
        .opt("calib", "64",
             "held-out calibration samples for gain recalibration")
        .opt("workers", "0", "worker threads (0 = HIC_WORKERS/auto)")
        .opt("out", "results", "output directory")
        .flag("verbose", "debug logging");
    let m = spec.parse(args)?;
    if m.flag("verbose") {
        set_level(Level::Debug);
    }
    let data = match m.str("data")? {
        "cifar" => {
            let pool = m.usize("nn-pool")?;
            if pool == 0 || 32 % pool != 0 {
                bail!("--nn-pool must divide the 32x32 image \
                       (1, 2, 4, 8, 16 or 32)");
            }
            ServeData::Cifar { pool }
        }
        "blobs" => ServeData::Blobs { dim: m.usize("nn-dim")? },
        other => bail!("unknown --data '{other}' (cifar | blobs)"),
    };
    let hidden = m
        .list("nn-hidden")
        .iter()
        .map(|s| s.parse::<usize>())
        .collect::<std::result::Result<Vec<_>, _>>()?;
    for key in ["nn-dim", "nn-classes", "nn-steps", "nn-batch",
                "nn-tile", "train-len", "test-len", "requests",
                "max-batch", "queue-cap", "calib"] {
        if m.usize(key)? == 0 {
            bail!("--{key} must be >= 1");
        }
    }
    if m.f64("mean-gap")? <= 0.0 {
        bail!("--mean-gap must be > 0");
    }
    if m.f64("window")? < 0.0 {
        bail!("--window must be >= 0");
    }
    let opts = ServeExpOptions {
        data,
        hidden,
        classes: m.usize("nn-classes")?,
        steps: m.usize("nn-steps")?,
        batch: m.usize("nn-batch")?,
        tile: m.usize("nn-tile")?,
        train_len: m.usize("train-len")?,
        test_len: m.usize("test-len")?,
        lr: m.f32("nn-lr")?,
        seed: m
            .list("seeds")
            .first()
            .map(|s| s.parse::<u64>())
            .transpose()?
            .unwrap_or(42),
        requests: m.usize("requests")?,
        mean_gap: m.f64("mean-gap")?,
        window: m.f64("window")?,
        max_batch: m.usize("max-batch")?,
        queue_cap: m.usize("queue-cap")?,
        calib_n: m.usize("calib")?,
        workers: m.usize("workers")?,
        out_dir: PathBuf::from(m.str("out")?),
        ..Default::default()
    };
    let doc = exp::serve::run_fig5_serve(&opts)?;
    exp::gridexp::write_json(&opts.out_dir, "fig5_serve.json", &doc)?;
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let spec = Spec::new(
        "run",
        "run an experiment described by a .hic spec file: parse, \
         validate and lower the spec into the matching experiment \
         options, run it on the crossbar grid device model, and write \
         the same JSON document the flag-driven subcommand would \
         (see the library's `spec` module docs for the grammar and \
         the full key reference; examples live in examples/*.hic)")
        .pos("spec-file", "path to the .hic experiment spec")
        .opt("out", "", "output directory (overrides the spec's `out`)")
        .flag("check",
              "parse, validate and echo the canonical form, then exit \
               without running")
        .flag("verbose", "debug logging");
    let m = spec.parse(args)?;
    if m.flag("verbose") {
        set_level(Level::Debug);
    }
    let Some(path) = m.positional(0) else {
        bail!("missing spec file (usage: hic-train run <spec-file> \
               [--out DIR])");
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    // Spec diagnostics render as `file:line:col: message`.
    let ast = hic_train::spec::parse(&text)
        .map_err(|e| anyhow::anyhow!("{path}:{e}"))?;
    let mut lowered = hic_train::spec::lower(&ast)
        .map_err(|e| anyhow::anyhow!("{path}:{e}"))?;
    if m.flag("check") {
        print!("{}", hic_train::spec::print(&ast));
        return Ok(());
    }
    if let Some(out) = m.get("out") {
        if !out.is_empty() {
            lowered.set_out_dir(PathBuf::from(out));
        }
    }
    let doc = lowered.run()?;
    exp::gridexp::write_json(lowered.out_dir(), lowered.out_name(),
                             &doc)?;
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let spec = Spec::new("info", "inspect an artifact set")
        .opt("config", "core", "artifact config name");
    let m = spec.parse(args)?;
    let config = m.string("config")?;
    let dir = artifact_root().join(&config);
    let engine = Engine::load(&dir)?;
    let man = &engine.manifest;
    println!("artifact set '{}' at {}", man.config_name, dir.display());
    println!("  weights: {}  (inference: {:.1} KB HIC vs {:.1} KB FP32)",
             man.num_weights,
             hic_train::exp::widths::bits_to_kb(
                 man.inference_model_bits(true)),
             hic_train::exp::widths::bits_to_kb(
                 man.inference_model_bits(false)));
    println!("  batch: {}  image: {}x{}", man.batch_size(),
             man.image_size(), man.image_size());
    println!("  layers:");
    for l in &man.layers {
        println!("    {:10} [{:4} x {:3}]  {}x{} cin={} stride={}",
                 l.name, l.k, l.n, l.kh, l.kw, l.cin, l.stride);
    }
    println!("  entries:");
    for (name, e) in &man.entries {
        println!("    {:22} {:3} in / {:3} out  ({})", name,
                 e.inputs.len(), e.outputs.len(), e.file);
    }
    Ok(())
}
