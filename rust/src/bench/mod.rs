//! In-tree benchmark harness (criterion is unavailable offline).
//!
//! Minimal but honest: per-iteration wall times, warmup, fixed time/iter
//! budgets, and robust summary statistics (median / p10 / p90).  The
//! `benches/*.rs` targets (declared `harness = false`) build their own
//! `main` on top of [`Bench`].
//!
//! ```no_run
//! use hic_train::bench::Bench;
//! let mut b = Bench::new("suite");
//! b.bench("op", || { std::hint::black_box(1 + 1); });
//! b.finish();
//! ```

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    /// optional throughput numerator (elements per iteration)
    pub elements: Option<f64>,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e / (self.mean_ns / 1e9))
    }

    /// JSON record of this case (for `Bench::write_json`).
    pub fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("iters".into(), Json::Num(self.iters as f64));
        m.insert("mean_ns".into(), Json::Num(self.mean_ns));
        m.insert("median_ns".into(), Json::Num(self.median_ns));
        m.insert("p10_ns".into(), Json::Num(self.p10_ns));
        m.insert("p90_ns".into(), Json::Num(self.p90_ns));
        if let Some(e) = self.elements {
            m.insert("elements".into(), Json::Num(e));
        }
        if let Some(t) = self.throughput() {
            m.insert("throughput_elem_per_s".into(), Json::Num(t));
        }
        Json::Obj(m)
    }
}

/// Benchmark suite runner.
pub struct Bench {
    pub suite: String,
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
    pub results: Vec<Stats>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Respect a quick mode for CI: HIC_BENCH_QUICK=1.
        let quick = std::env::var("HIC_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            warmup: if quick { Duration::from_millis(50) }
                    else { Duration::from_millis(300) },
            budget: if quick { Duration::from_millis(200) }
                    else { Duration::from_secs(2) },
            max_iters: if quick { 20 } else { 1000 },
            results: Vec::new(),
        }
    }

    /// Benchmark a closure; returns the stats (also stored).
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Stats {
        self.bench_with_elements(name, None, f)
    }

    /// Benchmark with a throughput denominator (elements per iteration).
    pub fn bench_with_elements<F: FnMut()>(&mut self, name: &str,
                                           elements: Option<f64>,
                                           mut f: F) -> &Stats {
        // Warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measured iterations
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = summarize(name, &mut samples, elements);
        print_stats(&self.suite, &stats);
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Stats of a completed case, by name.
    pub fn get(&self, name: &str) -> Option<&Stats> {
        self.results.iter().find(|s| s.name == name)
    }

    /// Median-time speedup of `contender` over `baseline` (>1 = faster).
    pub fn speedup(&self, baseline: &str, contender: &str) -> Option<f64> {
        Some(self.get(baseline)?.median_ns / self.get(contender)?.median_ns)
    }

    /// Dump the suite (plus named comparison ratios) as a JSON datapoint
    /// — the before/after evidence file the perf-tracking PRs commit.
    pub fn write_json(&self, path: &Path, speedups: &[(String, f64)])
                      -> std::io::Result<()> {
        let mut cases = BTreeMap::new();
        for s in &self.results {
            cases.insert(s.name.clone(), s.json());
        }
        let mut sp = BTreeMap::new();
        for (name, v) in speedups {
            sp.insert(name.clone(), Json::Num(*v));
        }
        let mut root = BTreeMap::new();
        root.insert("suite".into(), Json::Str(self.suite.clone()));
        root.insert("cases".into(), Json::Obj(cases));
        root.insert("speedups".into(), Json::Obj(sp));
        std::fs::write(path, Json::Obj(root).to_string())?;
        println!("[{}] wrote {}", self.suite, path.display());
        Ok(())
    }

    /// Print the suite footer.  Call at the end of `main`.
    pub fn finish(&self) {
        println!("[{}] {} case(s) complete", self.suite, self.results.len());
    }
}

fn summarize(name: &str, samples: &mut [f64], elements: Option<f64>)
             -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let q = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
    Stats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        elements,
    }
}

fn print_stats(suite: &str, s: &Stats) {
    let scale = |ns: f64| -> String {
        if ns >= 1e9 {
            format!("{:.2} s", ns / 1e9)
        } else if ns >= 1e6 {
            format!("{:.2} ms", ns / 1e6)
        } else if ns >= 1e3 {
            format!("{:.2} µs", ns / 1e3)
        } else {
            format!("{:.0} ns", ns)
        }
    };
    let tp = s
        .throughput()
        .map(|t| {
            if t >= 1e9 {
                format!("  {:>8.2} Gelem/s", t / 1e9)
            } else if t >= 1e6 {
                format!("  {:>8.2} Melem/s", t / 1e6)
            } else {
                format!("  {:>8.0} elem/s", t)
            }
        })
        .unwrap_or_default();
    println!(
        "[{suite}] {:<40} {:>10} (p10 {:>10}, p90 {:>10}, n={}){}",
        s.name,
        scale(s.median_ns),
        scale(s.p10_ns),
        scale(s.p90_ns),
        s.iters,
        tp
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_summarizes() {
        std::env::set_var("HIC_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        let s = b.bench_with_elements("noop", Some(100.0), || {
            std::hint::black_box(42);
        });
        assert!(s.iters > 0);
        assert!(s.mean_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.throughput().unwrap() > 0.0);
        b.finish();
    }

    #[test]
    fn percentiles_ordered() {
        let mut samples = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let s = summarize("x", &mut samples, None);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.p10_ns, 1.0);
        assert_eq!(s.p90_ns, 4.0);
        assert_eq!(s.mean_ns, 3.0);
    }
}
