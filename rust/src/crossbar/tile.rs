//! Functional crossbar tile: the host-side oracle of the L1 Pallas kernel.
//!
//! One tile = one `[rows, cols]` block of differential PCM pairs with a
//! DAC per row and an ADC per column.  `vmm()` reproduces, on the device
//! model, exactly what the lowered kernel computes on its conductance
//! operands:
//!
//! ```text
//! y[c] = ADC( Σ_r DAC(x[r]) · w_eff[r, c] )
//! ```
//!
//! with `w_eff` the drifted differential read plus per-read Gaussian
//! noise.  Used by the crossbar explorer, the energy model (activity
//! factors) and cross-validation tests against the compiled artifact.
//!
//! Batched reads run on the planar device planes: `vmm_batch` evaluates
//! the drift power law **once per batch** into a [`TileScratch`] (drift
//! does not advance within one invocation — `t_now` is fixed), then per
//! sample draws a fresh stochastic read of the whole array (G+ noise
//! plane first, then G−) and runs a row-major inner loop over flat
//! slices.  No allocation per sample; callers that keep a `TileScratch`
//! across invocations (`vmm_batch_into`) allocate nothing per batch
//! either.
//!
//! Read-noise RNG contract: each noisy plane read fills the scratch
//! noise buffer with the **batched Box–Muller** stream
//! ([`Pcg64::fill_gaussian`] — `2·⌈len/2⌉` draws per plane per sample),
//! not the scalar `normal()` sequence.  The scalar-reference stream
//! survives unchanged on `PcmArray::read_into` /
//! `DifferentialPair::read_weights_into`, where the SoA-equivalence
//! property suite pins it.

use crate::hic::weight::HicWeight;
use crate::pcm::array::DifferentialPair;
use crate::util::rng::Pcg64;

use super::quant::{AdcSpec, DacSpec};

pub struct CrossbarTile {
    pub weights: HicWeight,
    pub dac: DacSpec,
    pub adc: AdcSpec,
}

/// Reusable per-tile read buffers: drifted conductance planes (valid for
/// one `t_now`), the per-sample effective-weight read, the batched
/// read-noise deviates and the quantized input row / error column.
pub struct TileScratch {
    gp: Vec<f32>,
    gm: Vec<f32>,
    w: Vec<f32>,
    noise: Vec<f32>,
    xq: Vec<f32>,
    eq: Vec<f32>,
}

/// One fresh stochastic read of a differential tile's effective weights
/// into `w` (`len = rows·cols`): the G+ noise plane is drawn first, then
/// G−, each with the batched Box–Muller fill, then the clamped
/// differential is scaled to weight units.  `gp`/`gm` are the drifted
/// conductance planes (valid for the invocation's `t_now`); `noise` is a
/// same-length deviate buffer.
///
/// This draw-a-plane-then-apply sequence is shared by
/// [`CrossbarTile::vmm_batch_into`], [`CrossbarTile::vmm_t_batch_into`]
/// and the grid's sample-major reference kernels; the blocked
/// tile-stationary grid kernels draw the same deviates up front (one
/// fused fill per sample block, see
/// [`crate::util::rng::fill_gaussian_block`]) and apply them through
/// [`read_noisy_weights_prefilled`].  The per-plane arithmetic (G+
/// first, then G−, clamp, differential scale) is part of the grid
/// determinism contract and of the golden oracle mirror, so keep all
/// three in sync.
pub(crate) fn read_noisy_weights(msb: &DifferentialPair, gp: &[f32],
                                 gm: &[f32], rng: &mut Pcg64,
                                 noise: &mut [f32], w: &mut [f32]) {
    let (noise_p, sigma_p) =
        (msb.plus.params.read_noise, msb.plus.params.read_sigma);
    let (noise_m, sigma_m) =
        (msb.minus.params.read_noise, msb.minus.params.read_sigma);
    let scale = msb.g_to_w(1.0);
    if noise_p {
        rng.fill_gaussian(noise, 0.0, 1.0);
        for ((wv, &g), &z) in w.iter_mut().zip(gp).zip(noise.iter()) {
            *wv = (g + sigma_p * z).clamp(0.0, 1.0);
        }
    } else {
        for (wv, &g) in w.iter_mut().zip(gp) {
            *wv = g.clamp(0.0, 1.0);
        }
    }
    if noise_m {
        rng.fill_gaussian(noise, 0.0, 1.0);
        for ((wv, &g), &z) in w.iter_mut().zip(gm).zip(noise.iter()) {
            *wv = (*wv - (g + sigma_m * z).clamp(0.0, 1.0)) * scale;
        }
    } else {
        for (wv, &g) in w.iter_mut().zip(gm) {
            *wv = (*wv - g.clamp(0.0, 1.0)) * scale;
        }
    }
}

/// Multi-sample variant of the noisy read: apply **pre-drawn** deviates
/// to the drifted planes.  `noise` holds this sample's even-length
/// `2·len` segment — G+ plane deviates first (`noise[..len]`), then G−
/// (`noise[len..]`) — drawn by the caller from the sample's
/// `(op, tile, sample)` sub-stream, typically as one fused
/// [`crate::util::rng::fill_gaussian_block`] pass over a whole sample
/// block.  The per-element arithmetic is exactly
/// [`read_noisy_weights`]'s, so blocked and sample-major reads agree on
/// identical deviates; with read noise off `noise` may be empty (no
/// deviates are consumed, matching the noise-free RNG contract).
/// The weight-stationary streaming conv path rides on this too: the
/// grid's generic forward kernel performs the identical prefilled
/// reads whether its input segments were staged
/// (`vmm_batch_base_into`) or generated by a patch source
/// (`vmm_batch_src_into`) — the read sequence never sees the
/// difference.
pub(crate) fn read_noisy_weights_prefilled(msb: &DifferentialPair,
                                           gp: &[f32], gm: &[f32],
                                           noise: &[f32],
                                           w: &mut [f32]) {
    let nt = w.len();
    let (noise_p, sigma_p) =
        (msb.plus.params.read_noise, msb.plus.params.read_sigma);
    let (noise_m, sigma_m) =
        (msb.minus.params.read_noise, msb.minus.params.read_sigma);
    let scale = msb.g_to_w(1.0);
    if noise_p {
        for ((wv, &g), &z) in w.iter_mut().zip(gp).zip(&noise[..nt]) {
            *wv = (g + sigma_p * z).clamp(0.0, 1.0);
        }
    } else {
        for (wv, &g) in w.iter_mut().zip(gp) {
            *wv = g.clamp(0.0, 1.0);
        }
    }
    if noise_m {
        for ((wv, &g), &z) in
            w.iter_mut().zip(gm).zip(&noise[nt..2 * nt])
        {
            *wv = (*wv - (g + sigma_m * z).clamp(0.0, 1.0)) * scale;
        }
    } else {
        for (wv, &g) in w.iter_mut().zip(gm) {
            *wv = (*wv - g.clamp(0.0, 1.0)) * scale;
        }
    }
}

impl CrossbarTile {
    pub fn new(weights: HicWeight, dac: DacSpec, adc: AdcSpec) -> Self {
        CrossbarTile { weights, dac, adc }
    }

    pub fn rows(&self) -> usize {
        self.weights.msb.rows()
    }

    pub fn cols(&self) -> usize {
        self.weights.msb.cols()
    }

    /// Allocate scratch buffers sized for this tile.
    pub fn scratch(&self) -> TileScratch {
        let n = self.rows() * self.cols();
        TileScratch {
            gp: vec![0.0; n],
            gm: vec![0.0; n],
            w: vec![0.0; n],
            noise: vec![0.0; n],
            xq: vec![0.0; self.rows()],
            eq: vec![0.0; self.cols()],
        }
    }

    /// One analog VMM: `y = ADC(DAC(x) @ W_read(t))`.
    ///
    /// Performs one stochastic read of the whole array (fresh read
    /// noise), like one pass through the hardware.
    pub fn vmm(&self, x: &[f32], t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        self.vmm_batch(x, 1, t_now, rng)
    }

    /// Batched VMM (`x: [m, rows]` row-major) — the whole-tile workload
    /// unit the energy model charges per invocation.  Allocating wrapper
    /// of [`CrossbarTile::vmm_batch_into`].
    pub fn vmm_batch(&self, x: &[f32], m: usize, t_now: f32,
                     rng: &mut Pcg64) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.cols()];
        self.vmm_batch_into(x, m, t_now, rng, &mut scratch, &mut out);
        out
    }

    /// Batched VMM into caller-provided buffers: drift evaluated once
    /// for the whole batch, one fresh whole-array stochastic read per
    /// sample, zero allocations.
    pub fn vmm_batch_into(&self, x: &[f32], m: usize, t_now: f32,
                          rng: &mut Pcg64, scratch: &mut TileScratch,
                          out: &mut [f32]) {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(x.len(), m * rows);
        assert_eq!(out.len(), m * cols);
        let msb = &self.weights.msb;
        assert_eq!(scratch.w.len(), msb.len());
        assert_eq!(scratch.xq.len(), rows, "scratch shape != tile shape");

        // Drift is a function of t_now only: evaluate both conductance
        // planes once per batch, not once per sample.
        msb.plus.drift_into(t_now, &mut scratch.gp);
        msb.minus.drift_into(t_now, &mut scratch.gm);
        // Fault-model spare-strip remap (no-op unless cells claimed).
        msb.apply_remap_overrides(t_now, &mut scratch.gp,
                                  &mut scratch.gm);

        for s in 0..m {
            // Fresh stochastic read of the whole array for this sample
            // (shared sequence: G+ noise plane first, then G−).
            read_noisy_weights(msb, &scratch.gp, &scratch.gm, rng,
                               &mut scratch.noise, &mut scratch.w);

            // DAC the input row, then a row-major inner loop over the
            // flat weight slice (autovectorizes per output column).
            let xs = &x[s * rows..(s + 1) * rows];
            for (q, &v) in scratch.xq.iter_mut().zip(xs) {
                *q = self.dac.convert(v);
            }
            let y = &mut out[s * cols..(s + 1) * cols];
            y.fill(0.0);
            for (r, &xv) in scratch.xq.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &scratch.w[r * cols..(r + 1) * cols];
                for (yc, &wc) in y.iter_mut().zip(row) {
                    *yc += xv * wc;
                }
            }
            for yc in y.iter_mut() {
                *yc = self.adc.convert(*yc);
            }
        }
    }

    /// Batched **transposed** analog VMM (`e: [m, cols]` row-major error
    /// inputs, `out: [m, rows]`): `y = ADC(DAC(e) @ W_read(t)ᵀ)` — the
    /// backward pass of on-grid training, where the error vector drives
    /// the tile's columns and the partial sums are read out on the rows.
    /// Same drift/read discipline as [`CrossbarTile::vmm_batch_into`]:
    /// drift once per batch, one fresh whole-array stochastic read per
    /// sample (G+ plane first, then G−), zero allocations.  Allocating
    /// wrapper: [`CrossbarTile::vmm_t_batch`].
    pub fn vmm_t_batch_into(&self, e: &[f32], m: usize, t_now: f32,
                            rng: &mut Pcg64, scratch: &mut TileScratch,
                            out: &mut [f32]) {
        let (rows, cols) = (self.rows(), self.cols());
        assert_eq!(e.len(), m * cols);
        assert_eq!(out.len(), m * rows);
        let msb = &self.weights.msb;
        assert_eq!(scratch.w.len(), msb.len());
        assert_eq!(scratch.eq.len(), cols, "scratch shape != tile shape");

        msb.plus.drift_into(t_now, &mut scratch.gp);
        msb.minus.drift_into(t_now, &mut scratch.gm);
        // Fault-model spare-strip remap (no-op unless cells claimed).
        msb.apply_remap_overrides(t_now, &mut scratch.gp,
                                  &mut scratch.gm);

        for s in 0..m {
            read_noisy_weights(msb, &scratch.gp, &scratch.gm, rng,
                               &mut scratch.noise, &mut scratch.w);

            // DAC the error row, then accumulate column-by-column into
            // the row sums (per output row the term order is ascending
            // logical column — the op order the grid's row-strip shards
            // reproduce exactly).
            let es = &e[s * cols..(s + 1) * cols];
            for (q, &v) in scratch.eq.iter_mut().zip(es) {
                *q = self.dac.convert(v);
            }
            let y = &mut out[s * rows..(s + 1) * rows];
            y.fill(0.0);
            for (c, &ev) in scratch.eq.iter().enumerate() {
                if ev == 0.0 {
                    continue;
                }
                for (r, yr) in y.iter_mut().enumerate() {
                    *yr += ev * scratch.w[r * cols + c];
                }
            }
            for yr in y.iter_mut() {
                *yr = self.adc.convert(*yr);
            }
        }
    }

    /// Allocating wrapper of [`CrossbarTile::vmm_t_batch_into`].
    pub fn vmm_t_batch(&self, e: &[f32], m: usize, t_now: f32,
                       rng: &mut Pcg64) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.rows()];
        self.vmm_t_batch_into(e, m, t_now, rng, &mut scratch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hic::weight::HicGeometry;
    use crate::pcm::device::PcmParams;

    fn ideal_tile(rows: usize, cols: usize, w: &[f32]) -> CrossbarTile {
        let mut rng = Pcg64::new(10, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let mut hw =
            HicWeight::new(PcmParams::ideal(), geom, rows, cols, &mut rng);
        hw.program_init(w, 0.0, &mut rng);
        CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default())
    }

    #[test]
    fn ideal_vmm_matches_host_matmul() {
        let rows = 8;
        let cols = 4;
        let w: Vec<f32> =
            (0..rows * cols).map(|i| ((i % 7) as f32 - 3.0) / 5.0).collect();
        let tile = ideal_tile(rows, cols, &w);
        // the programmed (quantized) weights, not the requested ones:
        let wq = tile.weights.decode(0.0);
        let x: Vec<f32> = (0..rows).map(|i| (i as f32) / 4.0 - 1.0).collect();
        let mut rng = Pcg64::new(11, 0);
        let y = tile.vmm(&x, 0.0, &mut rng);
        for c in 0..cols {
            let mut acc = 0f32;
            for r in 0..rows {
                acc += tile.dac.convert(x[r]) * wq[r * cols + c];
            }
            let expect = tile.adc.convert(acc);
            assert!((y[c] - expect).abs() < 1e-5,
                    "col {c}: {} vs {expect}", y[c]);
        }
    }

    #[test]
    fn noisy_vmm_is_unbiased() {
        let rows = 16;
        let cols = 2;
        let w = vec![0.25f32; rows * cols];
        let mut rng = Pcg64::new(12, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut hw = HicWeight::new(params, geom, rows, cols, &mut rng);
        hw.program_init(&w, 0.0, &mut rng);
        let clean = hw.decode(0.0);
        let tile =
            CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default());
        let x = vec![1.0f32; rows];
        let clean_y: f32 =
            (0..rows).map(|r| clean[r * cols]).sum();
        let n = 500;
        let mean: f64 = (0..n)
            .map(|_| tile.vmm(&x, 0.0, &mut rng)[0] as f64)
            .sum::<f64>() / n as f64;
        assert!((mean - clean_y as f64).abs() < 0.05,
                "mean={mean} clean={clean_y}");
    }

    #[test]
    fn batch_shape() {
        let tile = ideal_tile(4, 3, &[0.1; 12]);
        let mut rng = Pcg64::new(13, 0);
        let x = vec![0.5f32; 2 * 4];
        let y = tile.vmm_batch(&x, 2, 0.0, &mut rng);
        assert_eq!(y.len(), 2 * 3);
        assert!((y[0] - y[3]).abs() < 1e-6); // identical rows
    }

    #[test]
    fn batch_matches_sequential_vmm_on_same_stream() {
        // The batched path must consume the RNG exactly like m sequential
        // single-sample reads (fresh noise per sample), so with equal
        // seeds the outputs agree bit for bit.
        let rows = 6;
        let cols = 5;
        let mut rng = Pcg64::new(21, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut hw = HicWeight::new(params, geom, rows, cols, &mut rng);
        let w: Vec<f32> =
            (0..rows * cols).map(|i| ((i % 9) as f32 - 4.0) / 6.0).collect();
        hw.program_init(&w, 0.0, &mut rng);
        let tile =
            CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default());

        let m = 3;
        let x: Vec<f32> =
            (0..m * rows).map(|i| ((i % 11) as f32 - 5.0) / 5.0).collect();
        let mut rng_batch = Pcg64::new(77, 1);
        let mut rng_seq = Pcg64::new(77, 1);
        let batched = tile.vmm_batch(&x, m, 0.0, &mut rng_batch);
        let mut sequential = Vec::new();
        for s in 0..m {
            sequential.extend(tile.vmm(&x[s * rows..(s + 1) * rows], 0.0,
                                       &mut rng_seq));
        }
        assert_eq!(batched, sequential);
    }

    #[test]
    fn ideal_vmm_t_matches_host_transposed_matmul() {
        let rows = 6;
        let cols = 5;
        let w: Vec<f32> =
            (0..rows * cols).map(|i| ((i % 7) as f32 - 3.0) / 5.0).collect();
        let tile = ideal_tile(rows, cols, &w);
        let wq = tile.weights.decode(0.0);
        let e: Vec<f32> = (0..cols).map(|i| (i as f32) / 3.0 - 0.5).collect();
        let mut rng = Pcg64::new(14, 0);
        let y = tile.vmm_t_batch(&e, 1, 0.0, &mut rng);
        assert_eq!(y.len(), rows);
        for r in 0..rows {
            let mut acc = 0f32;
            for c in 0..cols {
                acc += tile.dac.convert(e[c]) * wq[r * cols + c];
            }
            let expect = tile.adc.convert(acc);
            assert!((y[r] - expect).abs() < 1e-5,
                    "row {r}: {} vs {expect}", y[r]);
        }
    }

    #[test]
    fn vmm_t_consumes_same_stream_as_forward() {
        // Per sample both kernels draw one G+ and one G− noise plane, so
        // with equal seeds the RNG ends in the same state.
        let rows = 5;
        let cols = 4;
        let mut rng = Pcg64::new(23, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let mut hw = HicWeight::new(PcmParams::default(), geom, rows, cols,
                                    &mut rng);
        hw.program_init(&vec![0.3; rows * cols], 0.0, &mut rng);
        let tile =
            CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default());
        let m = 2;
        let x = vec![0.5f32; m * rows];
        let e = vec![0.5f32; m * cols];
        let mut ra = Pcg64::new(91, 3);
        let mut rb = Pcg64::new(91, 3);
        tile.vmm_batch(&x, m, 0.0, &mut ra);
        tile.vmm_t_batch(&e, m, 0.0, &mut rb);
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn prefilled_read_matches_streaming_read_on_even_tiles() {
        // For even tile sizes one 2·nt fill equals two nt fills from
        // the same stream (Box–Muller pairing never crosses the plane
        // boundary), so the prefilled and streaming reads must agree
        // bit for bit on identical deviates.
        let rows = 4;
        let cols = 4;
        let nt = rows * cols;
        let mut rng = Pcg64::new(31, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut hw = HicWeight::new(params, geom, rows, cols, &mut rng);
        hw.program_init(&vec![0.3; nt], 0.0, &mut rng);
        let mut gp = vec![0.0f32; nt];
        let mut gm = vec![0.0f32; nt];
        hw.msb.plus.drift_into(0.0, &mut gp);
        hw.msb.minus.drift_into(0.0, &mut gm);

        let mut deviates = vec![0.0f32; 2 * nt];
        Pcg64::new(77, 5).fill_gaussian(&mut deviates, 0.0, 1.0);
        let mut w_pre = vec![0.0f32; nt];
        read_noisy_weights_prefilled(&hw.msb, &gp, &gm, &deviates,
                                     &mut w_pre);

        let mut stream = Pcg64::new(77, 5);
        let mut noise = vec![0.0f32; nt];
        let mut w_seq = vec![0.0f32; nt];
        read_noisy_weights(&hw.msb, &gp, &gm, &mut stream, &mut noise,
                           &mut w_seq);
        assert_eq!(w_pre, w_seq);
    }

    #[test]
    fn scratch_reuse_is_allocation_free_path() {
        let tile = ideal_tile(4, 4, &[0.2; 16]);
        let mut rng = Pcg64::new(30, 0);
        let mut scratch = tile.scratch();
        let x = vec![0.25f32; 2 * 4];
        let mut out = vec![0.0; 2 * 4];
        tile.vmm_batch_into(&x, 2, 0.0, &mut rng, &mut scratch, &mut out);
        let alloc = tile.vmm_batch(&x, 2, 0.0, &mut rng);
        assert_eq!(out, alloc); // ideal tile: no RNG consumed, same result
    }
}
