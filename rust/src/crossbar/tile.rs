//! Functional crossbar tile: the host-side oracle of the L1 Pallas kernel.
//!
//! One tile = one `[rows, cols]` block of differential PCM pairs with a
//! DAC per row and an ADC per column.  `vmm()` reproduces, on the device
//! model, exactly what the lowered kernel computes on its conductance
//! operands:
//!
//! ```text
//! y[c] = ADC( Σ_r DAC(x[r]) · w_eff[r, c] )
//! ```
//!
//! with `w_eff` the drifted differential read plus per-read Gaussian
//! noise.  Used by the crossbar explorer, the energy model (activity
//! factors) and cross-validation tests against the compiled artifact.

use crate::hic::weight::HicWeight;
use crate::util::rng::Pcg64;

use super::quant::{AdcSpec, DacSpec};

pub struct CrossbarTile {
    pub weights: HicWeight,
    pub dac: DacSpec,
    pub adc: AdcSpec,
}

impl CrossbarTile {
    pub fn new(weights: HicWeight, dac: DacSpec, adc: AdcSpec) -> Self {
        CrossbarTile { weights, dac, adc }
    }

    pub fn rows(&self) -> usize {
        self.weights.msb.rows()
    }

    pub fn cols(&self) -> usize {
        self.weights.msb.cols()
    }

    /// One analog VMM: `y = ADC(DAC(x) @ W_read(t))`.
    ///
    /// Each call performs one stochastic read of the whole array (fresh
    /// read noise), like one pass through the hardware.
    pub fn vmm(&self, x: &[f32], t_now: f32, rng: &mut Pcg64) -> Vec<f32> {
        assert_eq!(x.len(), self.rows());
        let xq: Vec<f32> = x.iter().map(|&v| self.dac.convert(v)).collect();
        let w = self.weights.msb.read_weights(t_now, rng);
        let (rows, cols) = (self.rows(), self.cols());
        let mut y = vec![0f32; cols];
        for r in 0..rows {
            let xv = xq[r];
            if xv == 0.0 {
                continue;
            }
            let row = &w[r * cols..(r + 1) * cols];
            for c in 0..cols {
                y[c] += xv * row[c];
            }
        }
        y.iter().map(|&v| self.adc.convert(v)).collect()
    }

    /// Batched VMM (`x: [m, rows]` row-major) — the whole-tile workload
    /// unit the energy model charges per invocation.
    pub fn vmm_batch(&self, x: &[f32], m: usize, t_now: f32,
                     rng: &mut Pcg64) -> Vec<f32> {
        assert_eq!(x.len(), m * self.rows());
        let mut out = Vec::with_capacity(m * self.cols());
        for i in 0..m {
            out.extend(self.vmm(&x[i * self.rows()..(i + 1) * self.rows()],
                                t_now, rng));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hic::weight::HicGeometry;
    use crate::pcm::device::PcmParams;

    fn ideal_tile(rows: usize, cols: usize, w: &[f32]) -> CrossbarTile {
        let mut rng = Pcg64::new(10, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let mut hw =
            HicWeight::new(PcmParams::ideal(), geom, rows, cols, &mut rng);
        hw.program_init(w, 0.0, &mut rng);
        CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default())
    }

    #[test]
    fn ideal_vmm_matches_host_matmul() {
        let rows = 8;
        let cols = 4;
        let w: Vec<f32> =
            (0..rows * cols).map(|i| ((i % 7) as f32 - 3.0) / 5.0).collect();
        let tile = ideal_tile(rows, cols, &w);
        // the programmed (quantized) weights, not the requested ones:
        let wq = tile.weights.decode(0.0);
        let x: Vec<f32> = (0..rows).map(|i| (i as f32) / 4.0 - 1.0).collect();
        let mut rng = Pcg64::new(11, 0);
        let y = tile.vmm(&x, 0.0, &mut rng);
        for c in 0..cols {
            let mut acc = 0f32;
            for r in 0..rows {
                acc += tile.dac.convert(x[r]) * wq[r * cols + c];
            }
            let expect = tile.adc.convert(acc);
            assert!((y[c] - expect).abs() < 1e-5,
                    "col {c}: {} vs {expect}", y[c]);
        }
    }

    #[test]
    fn noisy_vmm_is_unbiased() {
        let rows = 16;
        let cols = 2;
        let w = vec![0.25f32; rows * cols];
        let mut rng = Pcg64::new(12, 0);
        let geom = HicGeometry { stochastic_rounding: false,
                                 ..Default::default() };
        let params = PcmParams { nonlinear: false, drift: false,
                                 ..Default::default() };
        let mut hw = HicWeight::new(params, geom, rows, cols, &mut rng);
        hw.program_init(&w, 0.0, &mut rng);
        let clean = hw.decode(0.0);
        let tile =
            CrossbarTile::new(hw, DacSpec::default(), AdcSpec::default());
        let x = vec![1.0f32; rows];
        let clean_y: f32 =
            (0..rows).map(|r| clean[r * cols]).sum();
        let n = 500;
        let mean: f64 = (0..n)
            .map(|_| tile.vmm(&x, 0.0, &mut rng)[0] as f64)
            .sum::<f64>() / n as f64;
        assert!((mean - clean_y as f64).abs() < 0.05,
                "mean={mean} clean={clean_y}");
    }

    #[test]
    fn batch_shape() {
        let tile = ideal_tile(4, 3, &[0.1; 12]);
        let mut rng = Pcg64::new(13, 0);
        let x = vec![0.5f32; 2 * 4];
        let y = tile.vmm_batch(&x, 2, 0.0, &mut rng);
        assert_eq!(y.len(), 2 * 3);
        assert!((y[0] - y[3]).abs() < 1e-6); // identical rows
    }
}
