//! DAC / ADC transfer functions.
//!
//! Bit-exact with the quantizers baked into the Pallas kernel
//! (`python/compile/kernels/pcm_vmm.py::_quantize_uniform`): mid-rise
//! uniform quantizer over `[-range, range]` with `2^bits - 1` steps.
//! The integration test `runtime_roundtrip::crossbar_vmm_microkernel`
//! pins the Rust and kernel implementations against each other through
//! the compiled artifact.

/// Row driver DAC.
#[derive(Clone, Copy, Debug)]
pub struct DacSpec {
    pub bits: u32,
    pub range: f32,
}

/// Column ADC.
#[derive(Clone, Copy, Debug)]
pub struct AdcSpec {
    pub bits: u32,
    pub range: f32,
}

impl Default for DacSpec {
    fn default() -> Self {
        DacSpec { bits: 8, range: 4.0 }
    }
}

impl Default for AdcSpec {
    fn default() -> Self {
        AdcSpec { bits: 8, range: 16.0 }
    }
}

#[inline]
fn quantize_uniform(v: f32, bits: u32, range: f32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    let step = 2.0 * range / levels;
    (v.clamp(-range, range) / step).round() * step
}

impl DacSpec {
    #[inline]
    pub fn convert(&self, v: f32) -> f32 {
        quantize_uniform(v, self.bits, self.range)
    }

    pub fn step(&self) -> f32 {
        2.0 * self.range / ((1u32 << self.bits) - 1) as f32
    }

    /// Worst-case quantization error (half a step inside the range).
    pub fn max_error_in_range(&self) -> f32 {
        self.step() / 2.0
    }
}

impl AdcSpec {
    #[inline]
    pub fn convert(&self, v: f32) -> f32 {
        quantize_uniform(v, self.bits, self.range)
    }

    pub fn step(&self) -> f32 {
        2.0 * self.range / ((1u32 << self.bits) - 1) as f32
    }

    /// Signal-to-quantization-noise ratio (dB) for a full-scale sine —
    /// the classic 6.02·bits + 1.76 check, used to validate bit widths.
    pub fn sqnr_db(&self) -> f32 {
        6.02 * self.bits as f32 + 1.76
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dac_grid_and_clipping() {
        let d = DacSpec { bits: 8, range: 4.0 };
        assert_eq!(d.convert(0.0), 0.0);
        // Out-of-range clips to the largest on-grid code (127*step with
        // 255 levels — f32 round puts 4.0/step at 127, same as the kernel).
        assert_eq!(d.convert(100.0), 127.0 * d.step());
        assert_eq!(d.convert(-100.0), -127.0 * d.step());
        assert!(d.convert(100.0) <= d.range);
        let v = d.convert(1.2345);
        // On the grid: v / step is an integer.
        let k = v / d.step();
        assert!((k - k.round()).abs() < 1e-4);
        assert!((v - 1.2345).abs() <= d.max_error_in_range() + 1e-6);
    }

    #[test]
    fn adc_matches_kernel_constants() {
        // Same constants as AdcDacConfig defaults; the kernel's epilogue
        // uses step = 2*16/255.
        let a = AdcSpec { bits: 8, range: 16.0 };
        assert!((a.step() - 2.0 * 16.0 / 255.0).abs() < 1e-7);
        assert_eq!(a.convert(16.1), 127.0 * a.step());
        let v = a.convert(3.3333);
        assert!((v - 3.3333).abs() <= a.step() / 2.0 + 1e-6);
    }

    #[test]
    fn quantizer_is_idempotent_and_odd() {
        let d = DacSpec::default();
        for raw in [-3.7f32, -0.01, 0.0, 0.5, 3.99] {
            let q = d.convert(raw);
            assert_eq!(d.convert(q), q);
            assert_eq!(d.convert(-raw), -q);
        }
    }

    #[test]
    fn sqnr() {
        let a = AdcSpec { bits: 8, range: 1.0 };
        assert!((a.sqnr_db() - 49.92).abs() < 0.01);
    }
}
