//! Patch lowering for convolution-on-grid (im2col / col2im).
//!
//! The standard mixed-precision-in-memory construction maps a 2-D
//! convolution onto an analog crossbar by lowering each `[kh, kw, cin]`
//! receptive field to one row of a patch matrix, so the whole layer
//! becomes a single `[kh·kw·cin, cout]` VMM per patch (Nandakumar et
//! al. 2020; Joshi et al. 2020).  This module is the deterministic data
//! movement around that VMM:
//!
//! * [`PatchGeom`] — the lowering geometry (input `[h, w, c]` in HWC
//!   layout, kernel size, stride, zero padding) and its derived output
//!   extents;
//! * [`im2col_into`] — gather input patches into a caller-owned
//!   `[m·P, kh·kw·cin]` patch matrix (`P` output positions per sample);
//! * [`col2im_into`] — the exact adjoint: scatter-add patch-space
//!   gradients back to input-space activations.
//!
//! Both kernels shard by **sample** on the [`WorkerPool`]: every shard
//! writes a disjoint slice of the output buffer and consumes no RNG, so
//! they are trivially bitwise identical for any worker count — the grid
//! determinism contract extends to the patch shards for free
//! (`rust/tests/prop_conv_equivalence.rs` pins this).  Buffers are
//! caller-owned and reused across invocations: the conv layers keep
//! their patch matrices inside the layer state, so the training loop
//! allocates nothing per batch.
//!
//! The patch matrix is where the grid's sample axis explodes: one conv
//! layer's VMM runs over [`PatchGeom::patch_rows`]` = m·P` rows, each a
//! "sample" of the blocked grid kernels.  The tile-stationary
//! sample-blocked VMM strips (`crossbar::grid`) block exactly this
//! axis — per (tile, block) the read noise of a whole block of patch
//! rows is drawn in one fused Box–Muller pass, with each row on its own
//! `(op, tile, sample)` RNG sub-stream, so the conv path inherits the
//! bitwise worker-count and block-size invariance unchanged.
//!
//! Determinism contract of the scatter: `col2im_into` accumulates f32
//! partial sums in ascending patch-row order, then kernel-row, then
//! kernel-column, then channel — a pinned op order mirrored by the
//! golden oracle (`rust/tests/golden/oracle.py`).

use crate::util::pool::WorkerPool;

/// Geometry of one conv lowering: input `[in_h, in_w, cin]` (HWC,
/// row-major), `kh×kw` kernels, `cout` output channels, square stride
/// and symmetric zero padding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatchGeom {
    pub in_h: usize,
    pub in_w: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
}

impl PatchGeom {
    /// Output height `⌊(h + 2·pad − kh)/stride⌋ + 1`.
    pub fn out_h(&self) -> usize {
        assert!(self.stride > 0, "stride must be >= 1");
        assert!(self.in_h + 2 * self.pad >= self.kh,
                "kernel taller than padded input");
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output width `⌊(w + 2·pad − kw)/stride⌋ + 1`.
    pub fn out_w(&self) -> usize {
        assert!(self.stride > 0, "stride must be >= 1");
        assert!(self.in_w + 2 * self.pad >= self.kw,
                "kernel wider than padded input");
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Output positions per sample (`P = out_h · out_w`).
    pub fn positions(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Lowered patch length (`K = kh · kw · cin` — the grid fan-in).
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Patch-matrix rows of an `m`-sample batch (`m·P` — the sample
    /// axis the blocked grid VMM kernels block over).
    pub fn patch_rows(&self, m: usize) -> usize {
        m * self.positions()
    }

    /// Flat input activation length per sample.
    pub fn in_len(&self) -> usize {
        self.in_h * self.in_w * self.cin
    }

    /// Flat output activation length per sample (HWC).
    pub fn out_len(&self) -> usize {
        self.positions() * self.cout
    }
}

/// Gather `m` samples' input activations (`x: [m, in_len]`, HWC) into
/// the patch matrix `patches: [m·P, K]` — row `s·P + (oy·out_w + ox)`
/// holds sample `s`'s receptive field at output position `(oy, ox)` in
/// `(ky, kx, ci)` order; out-of-bounds taps are zero (padding).
/// Sample-sharded on `pool`; bitwise identical for any worker count.
pub fn im2col_into(g: &PatchGeom, x: &[f32], m: usize, pool: &WorkerPool,
                   patches: &mut [f32]) {
    let (p, k) = (g.positions(), g.patch_len());
    assert_eq!(x.len(), m * g.in_len());
    assert_eq!(patches.len(), m * p * k);
    let mut shards: Vec<&mut [f32]> = patches.chunks_mut(p * k).collect();
    pool.run(&mut shards, |s, sub| {
        im2col_sample(g, &x[s * g.in_len()..(s + 1) * g.in_len()], sub);
    });
}

/// One sample's patch gather (serial reference; the sharded kernel runs
/// exactly this per sample).
fn im2col_sample(g: &PatchGeom, x: &[f32], out: &mut [f32]) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.patch_len();
    let mut r = 0;
    for oy in 0..oh {
        for ox in 0..ow {
            let dst = &mut out[r * k..(r + 1) * k];
            let mut idx = 0;
            for ky in 0..g.kh {
                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                for kx in 0..g.kw {
                    let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                    let seg = &mut dst[idx..idx + g.cin];
                    if iy >= 0 && (iy as usize) < g.in_h
                        && ix >= 0 && (ix as usize) < g.in_w
                    {
                        let src =
                            ((iy as usize) * g.in_w + ix as usize) * g.cin;
                        seg.copy_from_slice(&x[src..src + g.cin]);
                    } else {
                        seg.fill(0.0);
                    }
                    idx += g.cin;
                }
            }
            r += 1;
        }
    }
}

/// Scatter-add patch-space gradients (`dpatches: [m·P, K]`) back to
/// input space (`dx: [m, in_len]`, zeroed first) — the exact adjoint of
/// [`im2col_into`]: overlapping receptive fields accumulate, padded
/// taps are dropped.  Accumulation order per element is ascending patch
/// row, then `(ky, kx, ci)` — pinned (oracle-mirrored) f32 op order.
/// Sample-sharded on `pool`; bitwise identical for any worker count.
pub fn col2im_into(g: &PatchGeom, dpatches: &[f32], m: usize,
                   pool: &WorkerPool, dx: &mut [f32]) {
    let (p, k) = (g.positions(), g.patch_len());
    assert_eq!(dpatches.len(), m * p * k);
    assert_eq!(dx.len(), m * g.in_len());
    let mut shards: Vec<&mut [f32]> = dx.chunks_mut(g.in_len()).collect();
    pool.run(&mut shards, |s, sub| {
        col2im_sample(g, &dpatches[s * p * k..(s + 1) * p * k], sub);
    });
}

/// One sample's adjoint scatter (serial reference).
fn col2im_sample(g: &PatchGeom, dp: &[f32], dx: &mut [f32]) {
    dx.fill(0.0);
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.patch_len();
    let mut r = 0;
    for oy in 0..oh {
        for ox in 0..ow {
            let src = &dp[r * k..(r + 1) * k];
            let mut idx = 0;
            for ky in 0..g.kh {
                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                for kx in 0..g.kw {
                    let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                    if iy >= 0 && (iy as usize) < g.in_h
                        && ix >= 0 && (ix as usize) < g.in_w
                    {
                        let dst =
                            ((iy as usize) * g.in_w + ix as usize) * g.cin;
                        for ci in 0..g.cin {
                            dx[dst + ci] += src[idx + ci];
                        }
                    }
                    idx += g.cin;
                }
            }
            r += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(h: usize, w: usize, c: usize, kh: usize, kw: usize,
            cout: usize, stride: usize, pad: usize) -> PatchGeom {
        PatchGeom { in_h: h, in_w: w, cin: c, kh, kw, cout, stride, pad }
    }

    #[test]
    fn output_extents() {
        // 3×3 same-padded stride 1 preserves the spatial extent.
        let g = geom(8, 8, 3, 3, 3, 16, 1, 1);
        assert_eq!((g.out_h(), g.out_w()), (8, 8));
        assert_eq!(g.patch_len(), 27);
        assert_eq!(g.out_len(), 8 * 8 * 16);
        assert_eq!(g.patch_rows(4), 4 * 64);
        // Stride-2 downsampling halves (floor) the extent.
        let g = geom(8, 8, 16, 3, 3, 32, 2, 1);
        assert_eq!((g.out_h(), g.out_w()), (4, 4));
        // 1×1 stride-2 projection matches the 3×3 pad-1 stride-2 body.
        let g = geom(8, 8, 16, 1, 1, 32, 2, 0);
        assert_eq!((g.out_h(), g.out_w()), (4, 4));
        // Odd extents floor.
        let g = geom(5, 5, 1, 3, 3, 1, 2, 1);
        assert_eq!((g.out_h(), g.out_w()), (3, 3));
    }

    #[test]
    fn im2col_identity_kernel_is_a_copy() {
        // 1×1 stride-1 no-pad lowering is the identity reshape.
        let g = geom(3, 2, 2, 1, 1, 4, 1, 0);
        let x: Vec<f32> = (0..2 * g.in_len()).map(|i| i as f32).collect();
        let mut p = vec![0.0f32; 2 * g.positions() * g.patch_len()];
        im2col_into(&g, &x, 2, &WorkerPool::serial(), &mut p);
        assert_eq!(p, x);
        // And the adjoint is the identity back.
        let mut dx = vec![1.0f32; x.len()];
        col2im_into(&g, &p, 2, &WorkerPool::serial(), &mut dx);
        assert_eq!(dx, x);
    }

    #[test]
    fn im2col_padding_and_neighborhood() {
        // Single channel 2×2 image, 3×3 same-padded kernel: the patch at
        // output (0,0) sees the image in its bottom-right quadrant.
        let g = geom(2, 2, 1, 3, 3, 1, 1, 1);
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut p = vec![0.0f32; g.positions() * g.patch_len()];
        im2col_into(&g, &x, 1, &WorkerPool::serial(), &mut p);
        assert_eq!(&p[..9],
                   &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
        // Patch at (1,1): image in the top-left quadrant.
        assert_eq!(&p[3 * 9..4 * 9],
                   &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for the linear pair.
        let g = geom(4, 3, 2, 3, 3, 5, 2, 1);
        let m = 2;
        let (p, k) = (g.positions(), g.patch_len());
        let x: Vec<f32> = (0..m * g.in_len())
            .map(|i| (((i * 7) % 11) as f32 - 5.0) / 4.0)
            .collect();
        let y: Vec<f32> = (0..m * p * k)
            .map(|i| (((i * 5) % 13) as f32 - 6.0) / 8.0)
            .collect();
        let mut px = vec![0.0f32; m * p * k];
        im2col_into(&g, &x, m, &WorkerPool::serial(), &mut px);
        let mut dy = vec![0.0f32; m * g.in_len()];
        col2im_into(&g, &y, m, &WorkerPool::serial(), &mut dy);
        let lhs: f64 = px.iter().zip(&y)
            .map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&dy)
            .map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn patch_kernels_are_worker_invariant() {
        let g = geom(6, 5, 3, 3, 3, 4, 2, 1);
        let m = 5;
        let (p, k) = (g.positions(), g.patch_len());
        let x: Vec<f32> = (0..m * g.in_len())
            .map(|i| (((i * 3) % 17) as f32 - 8.0) / 8.0)
            .collect();
        let run = |workers: usize| {
            let pool = WorkerPool::new(workers);
            let mut px = vec![0.0f32; m * p * k];
            im2col_into(&g, &x, m, &pool, &mut px);
            let mut dx = vec![0.0f32; m * g.in_len()];
            col2im_into(&g, &px, m, &pool, &mut dx);
            (px, dx)
        };
        let a = run(1);
        assert_eq!(a, run(2));
        assert_eq!(a, run(4));
    }
}
