//! Patch lowering for convolution-on-grid: weight-stationary streaming
//! (patch sources + fused adjoint drain) over the classic im2col /
//! col2im pair.
//!
//! The standard mixed-precision-in-memory construction maps a 2-D
//! convolution onto an analog crossbar by lowering each `[kh, kw, cin]`
//! receptive field to one row of a patch matrix, so the whole layer
//! becomes a single `[kh·kw·cin, cout]` VMM per patch (Nandakumar et
//! al. 2020; Joshi et al. 2020).  Through PR 8 the lowering
//! *materialized* that `[m·P, kh·kw·cin]` matrix per layer per step —
//! stride-1 3×3 windows copy 8/9 of every patch out of rows that were
//! already staged, and the patch buffers dominated the footprint of the
//! long-run ResNet path.  The conv weights never move between steps
//! (they live on the crossbar), so the right shape is
//! **weight-stationary streaming**: keep the weights on the grid and
//! stream activations through it, generating each patch segment on
//! demand.
//!
//! # Streaming lowering
//!
//! * [`PatchPlan`] — a [`PatchGeom`] with every derived extent
//!   (`out_h/out_w`, `positions`, `patch_len`, `in_len`, `out_len`)
//!   computed once; conv layers cache it at build time instead of
//!   re-deriving extents every forward/backward call.
//! * [`ConvPatchSource`] — the forward patch generator: a
//!   [`PatchSource`] over the **once-DAC'd** input image (HWC).  The
//!   blocked grid kernel asks for one `[r0, r0+len)` patch-row segment
//!   at a time ([`CrossbarGrid::vmm_batch_src_into`]); the source
//!   decomposes the request into contiguous channel runs and copies
//!   them straight out of the staged image rows — the whole image *is*
//!   the halo buffer, so overlapping stride-1 windows reuse staged
//!   rows instead of re-gathering them, and the input DAC runs once
//!   per pixel instead of up to `kh·kw` times.  Because the grid's
//!   hoisted DAC maps `0.0 → 0.0` exactly (mid-rise quantizer),
//!   `DAC ∘ im2col == im2col ∘ DAC`: gathering from the pre-quantized
//!   image is bit-equal to quantizing a materialized patch matrix,
//!   padding included.
//! * [`col2im_stream_into`] — the backward fusion: consumes the
//!   transposed VMM's per-(strip, sample) outputs through the
//!   read-only [`TvmmOut`] view ([`CrossbarGrid::vmm_t_batch_with`])
//!   and scatter-adds them into input space directly, so the
//!   `[m·P, kh·kw·cin]` adjoint patch matrix never exists.
//! * [`conv_grad_into`] — the digital weight gradient without the
//!   patch matrix: stages one patch *column* at a time (`[m·P]` — the
//!   k-axis twin of the row streaming) and accumulates the outer
//!   product in exactly the materialized kernel's op order.
//! * [`im2col_into`] / [`col2im_into`] — the materialized pair,
//!   retained as the equivalence reference and the
//!   `HIC_CONV_LOWERING=materialized` fallback.
//!
//! Patch staging drops from `O(m·P·k²·cin)` to `O(sample_block ·
//! tile_rows)` per shard (each generating read stages at most one
//! `tile_rows` segment in the shard's scratch).
//!
//! # Determinism contract
//!
//! The streamed path is **bit-identical** to the materialized one —
//! the executable proof that streaming only changed where patch
//! elements come from, not the arithmetic
//! (`rust/tests/prop_conv_equivalence.rs` pins it; the fig4 resnet
//! golden is unchanged):
//!
//! * **RNG stream assignment** is untouched: the forward VMM draws
//!   per-(`OP_VMM`, tile, `sample_base + patch_row`) sub-streams and
//!   the transposed VMM per-(`OP_VMM_T`, tile, patch_row) sub-streams
//!   exactly as before — patch rows *are* the grid's sample axis, and
//!   the conv layer still offsets `sample_base` by `batch_base · P`.
//! * **Forward op order** is untouched: same shard decomposition, same
//!   fused Box–Muller noise fills, same zero-skip micro-kernel, same
//!   once-per-column ADC; only the origin of the quantized row
//!   segments differs.
//! * **Scatter op order** is pinned per input element: for a fixed
//!   `dx` element and patch row there is at most one contributing tap
//!   (for fixed `(oy, ox)` and input pixel, `(ky, kx)` is unique), so
//!   the per-element accumulation order of [`col2im_into`] — ascending
//!   patch row — is replayed exactly by the fused drain's
//!   row-major-outer walk, whatever order strips complete in.
//! * **Gradient op order**: [`conv_grad_into`] keeps the shared
//!   outer-product kernel's `i`-outer / `j` / ascending-`r` loop nest,
//!   including multiply-adds of exact-zero padding taps.
//!
//! Both materialized kernels shard by **sample** on the
//! [`WorkerPool`]; every shard writes a disjoint slice and consumes no
//! RNG, so they are trivially bitwise identical for any worker count.
//! The streamed scatter inherits the same sharding (one shard per
//! sample's `dx` slice reading the shared [`TvmmOut`] view).  Buffers
//! are caller-owned and reused across invocations: the conv layers
//! keep their staging inside the layer state, so the training loop
//! allocates nothing per batch.
//!
//! [`CrossbarGrid::vmm_batch_src_into`]:
//! crate::crossbar::grid::CrossbarGrid::vmm_batch_src_into
//! [`CrossbarGrid::vmm_t_batch_with`]:
//! crate::crossbar::grid::CrossbarGrid::vmm_t_batch_with

use crate::crossbar::grid::{PatchSource, TvmmOut};
use crate::util::pool::WorkerPool;

/// Geometry of one conv lowering: input `[in_h, in_w, cin]` (HWC,
/// row-major), `kh×kw` kernels, `cout` output channels, square stride
/// and symmetric zero padding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatchGeom {
    pub in_h: usize,
    pub in_w: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
}

impl PatchGeom {
    /// Output height `⌊(h + 2·pad − kh)/stride⌋ + 1`.
    pub fn out_h(&self) -> usize {
        assert!(self.stride > 0, "stride must be >= 1");
        assert!(self.in_h + 2 * self.pad >= self.kh,
                "kernel taller than padded input");
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output width `⌊(w + 2·pad − kw)/stride⌋ + 1`.
    pub fn out_w(&self) -> usize {
        assert!(self.stride > 0, "stride must be >= 1");
        assert!(self.in_w + 2 * self.pad >= self.kw,
                "kernel wider than padded input");
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Output positions per sample (`P = out_h · out_w`).
    pub fn positions(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Lowered patch length (`K = kh · kw · cin` — the grid fan-in).
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Patch-matrix rows of an `m`-sample batch (`m·P` — the sample
    /// axis the blocked grid VMM kernels block over).
    pub fn patch_rows(&self, m: usize) -> usize {
        m * self.positions()
    }

    /// Flat input activation length per sample.
    pub fn in_len(&self) -> usize {
        self.in_h * self.in_w * self.cin
    }

    /// Flat output activation length per sample (HWC).
    pub fn out_len(&self) -> usize {
        self.positions() * self.cout
    }
}

/// A [`PatchGeom`] with every derived extent computed once — the
/// cached per-layer lowering plan.  The geometry accessors recompute
/// (and re-assert) their extents on every call; conv layers build one
/// `PatchPlan` at construction and index these fields on the hot path
/// instead.
#[derive(Clone, Copy, Debug)]
pub struct PatchPlan {
    pub geom: PatchGeom,
    pub out_h: usize,
    pub out_w: usize,
    /// Output positions per sample (`P = out_h · out_w`).
    pub positions: usize,
    /// Lowered patch length (`K = kh · kw · cin`).
    pub patch_len: usize,
    /// Flat input activation length per sample.
    pub in_len: usize,
    /// Flat output activation length per sample.
    pub out_len: usize,
}

impl PatchPlan {
    pub fn new(geom: PatchGeom) -> Self {
        PatchPlan {
            geom,
            out_h: geom.out_h(),
            out_w: geom.out_w(),
            positions: geom.positions(),
            patch_len: geom.patch_len(),
            in_len: geom.in_len(),
            out_len: geom.out_len(),
        }
    }

    /// Patch-matrix rows of an `m`-sample batch.
    pub fn patch_rows(&self, m: usize) -> usize {
        m * self.positions
    }
}

/// The streaming forward patch generator: a [`PatchSource`] over the
/// once-DAC'd input batch (`qimg: [m, in_len]`, HWC, already through
/// [`DacSpec::convert`]).  `segment(s, r0, len, buf)` stages patch row
/// `s`'s columns `[r0, r0+len)` — sample `s / P`, output position
/// `s % P` — by copying contiguous `(ky, kx)` channel runs out of the
/// staged image (padding taps fill `0.0`, which is exactly what the
/// DAC maps padding to — see the module docs for why that makes the
/// source bit-equal to a quantized materialized patch matrix).
///
/// [`DacSpec::convert`]: crate::crossbar::quant::DacSpec::convert
pub struct ConvPatchSource<'a> {
    plan: &'a PatchPlan,
    qimg: &'a [f32],
}

impl<'a> ConvPatchSource<'a> {
    pub fn new(plan: &'a PatchPlan, qimg: &'a [f32]) -> Self {
        assert!(plan.in_len > 0 && qimg.len() % plan.in_len == 0,
                "qimg is not a whole number of [in_len] samples");
        ConvPatchSource { plan, qimg }
    }
}

impl PatchSource for ConvPatchSource<'_> {
    fn segment<'a>(&'a self, s: usize, r0: usize, len: usize,
                   buf: &'a mut [f32]) -> &'a [f32] {
        let p = self.plan;
        let g = &p.geom;
        let sample = s / p.positions;
        let rr = s % p.positions;
        let (oy, ox) = (rr / p.out_w, rr % p.out_w);
        let img =
            &self.qimg[sample * p.in_len..(sample + 1) * p.in_len];
        let out = &mut buf[..len];
        // Walk the requested patch columns as contiguous channel runs:
        // column q = (ky·kw + kx)·cin + ci, so each (ky, kx) tap
        // contributes one ≤ cin run that is contiguous in the image
        // row too (HWC).
        let mut q = r0;
        let mut filled = 0;
        while filled < len {
            let tap = q / g.cin;
            let ci0 = q % g.cin;
            let take = (g.cin - ci0).min(len - filled);
            let (ky, kx) = (tap / g.kw, tap % g.kw);
            let iy = (oy * g.stride + ky) as isize - g.pad as isize;
            let ix = (ox * g.stride + kx) as isize - g.pad as isize;
            let dst = &mut out[filled..filled + take];
            if iy >= 0 && (iy as usize) < g.in_h
                && ix >= 0 && (ix as usize) < g.in_w
            {
                let src =
                    ((iy as usize) * g.in_w + ix as usize) * g.cin + ci0;
                dst.copy_from_slice(&img[src..src + take]);
            } else {
                dst.fill(0.0);
            }
            q += take;
            filled += take;
        }
        out
    }
}

/// Gather `m` samples' input activations (`x: [m, in_len]`, HWC) into
/// the patch matrix `patches: [m·P, K]` — row `s·P + (oy·out_w + ox)`
/// holds sample `s`'s receptive field at output position `(oy, ox)` in
/// `(ky, kx, ci)` order; out-of-bounds taps are zero (padding).
/// Sample-sharded on `pool`; bitwise identical for any worker count.
/// The materialized half of the equivalence pair — the streamed
/// forward ([`ConvPatchSource`]) never calls this.
pub fn im2col_into(g: &PatchGeom, x: &[f32], m: usize, pool: &WorkerPool,
                   patches: &mut [f32]) {
    let (p, k) = (g.positions(), g.patch_len());
    assert_eq!(x.len(), m * g.in_len());
    assert_eq!(patches.len(), m * p * k);
    let mut shards: Vec<&mut [f32]> = patches.chunks_mut(p * k).collect();
    pool.run(&mut shards, |s, sub| {
        im2col_sample(g, &x[s * g.in_len()..(s + 1) * g.in_len()], sub);
    });
}

/// One sample's patch gather (serial reference; the sharded kernel runs
/// exactly this per sample).
fn im2col_sample(g: &PatchGeom, x: &[f32], out: &mut [f32]) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.patch_len();
    let mut r = 0;
    for oy in 0..oh {
        for ox in 0..ow {
            let dst = &mut out[r * k..(r + 1) * k];
            let mut idx = 0;
            for ky in 0..g.kh {
                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                for kx in 0..g.kw {
                    let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                    let seg = &mut dst[idx..idx + g.cin];
                    if iy >= 0 && (iy as usize) < g.in_h
                        && ix >= 0 && (ix as usize) < g.in_w
                    {
                        let src =
                            ((iy as usize) * g.in_w + ix as usize) * g.cin;
                        seg.copy_from_slice(&x[src..src + g.cin]);
                    } else {
                        seg.fill(0.0);
                    }
                    idx += g.cin;
                }
            }
            r += 1;
        }
    }
}

/// Scatter-add patch-space gradients (`dpatches: [m·P, K]`) back to
/// input space (`dx: [m, in_len]`, zeroed first) — the exact adjoint of
/// [`im2col_into`]: overlapping receptive fields accumulate, padded
/// taps are dropped.  Accumulation order per element is ascending patch
/// row, then `(ky, kx, ci)` — pinned (oracle-mirrored) f32 op order.
/// Sample-sharded on `pool`; bitwise identical for any worker count.
/// The materialized half of the adjoint pair — the streamed backward
/// ([`col2im_stream_into`]) replays the same per-element order without
/// the `dpatches` intermediate.
pub fn col2im_into(g: &PatchGeom, dpatches: &[f32], m: usize,
                   pool: &WorkerPool, dx: &mut [f32]) {
    let (p, k) = (g.positions(), g.patch_len());
    assert_eq!(dpatches.len(), m * p * k);
    assert_eq!(dx.len(), m * g.in_len());
    let mut shards: Vec<&mut [f32]> = dx.chunks_mut(g.in_len()).collect();
    pool.run(&mut shards, |s, sub| {
        col2im_sample(g, &dpatches[s * p * k..(s + 1) * p * k], sub);
    });
}

/// One sample's adjoint scatter (serial reference).
fn col2im_sample(g: &PatchGeom, dp: &[f32], dx: &mut [f32]) {
    dx.fill(0.0);
    let (oh, ow) = (g.out_h(), g.out_w());
    let k = g.patch_len();
    let mut r = 0;
    for oy in 0..oh {
        for ox in 0..ow {
            let src = &dp[r * k..(r + 1) * k];
            let mut idx = 0;
            for ky in 0..g.kh {
                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                for kx in 0..g.kw {
                    let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                    if iy >= 0 && (iy as usize) < g.in_h
                        && ix >= 0 && (ix as usize) < g.in_w
                    {
                        let dst =
                            ((iy as usize) * g.in_w + ix as usize) * g.cin;
                        for ci in 0..g.cin {
                            dx[dst + ci] += src[idx + ci];
                        }
                    }
                    idx += g.cin;
                }
            }
            r += 1;
        }
    }
}

/// The fused backward drain: scatter-add the transposed VMM's
/// per-(strip, sample) outputs (the [`TvmmOut`] view of
/// [`CrossbarGrid::vmm_t_batch_with`]) straight into input space
/// (`dx: [m, in_len]`, zeroed here) — [`col2im_into`] without the
/// `[m·P, K]` adjoint patch matrix ever existing.
///
/// Bit-identity with the materialized pair: for a fixed `dx` element
/// and patch row `rr` there is at most one contributing tap, so the
/// per-element f32 accumulation order of `col2im_into` is just
/// *ascending patch row*.  This drain walks `rr` ascending in the
/// outer loop (row strips inner), replaying that order exactly; which
/// strip a tap lives on cannot matter per element.
///
/// Sample-sharded on `pool` (each shard owns one sample's `dx` slice
/// and reads the shared view); bitwise identical for any worker count.
///
/// [`CrossbarGrid::vmm_t_batch_with`]:
/// crate::crossbar::grid::CrossbarGrid::vmm_t_batch_with
pub fn col2im_stream_into(plan: &PatchPlan, res: &TvmmOut, m: usize,
                          pool: &WorkerPool, dx: &mut [f32]) {
    assert_eq!(dx.len(), m * plan.in_len);
    let mut shards: Vec<&mut [f32]> =
        dx.chunks_mut(plan.in_len).collect();
    pool.run(&mut shards, |s, sub| {
        let g = &plan.geom;
        sub.fill(0.0);
        for rr in 0..plan.positions {
            let row = s * plan.positions + rr;
            let (oy, ox) = (rr / plan.out_w, rr % plan.out_w);
            for gr in 0..res.strips() {
                let (r0, rows) = res.strip_extent(gr);
                let seg = res.row_segment(gr, row);
                // Decompose this strip's patch columns [r0, r0+rows)
                // into contiguous channel runs, exactly like the
                // forward source; padded runs are dropped (adjoint of
                // zero-fill).
                let mut q = r0;
                let mut off = 0;
                while off < rows {
                    let tap = q / g.cin;
                    let ci0 = q % g.cin;
                    let take = (g.cin - ci0).min(rows - off);
                    let (ky, kx) = (tap / g.kw, tap % g.kw);
                    let iy =
                        (oy * g.stride + ky) as isize - g.pad as isize;
                    let ix =
                        (ox * g.stride + kx) as isize - g.pad as isize;
                    if iy >= 0 && (iy as usize) < g.in_h
                        && ix >= 0 && (ix as usize) < g.in_w
                    {
                        let dst = ((iy as usize) * g.in_w + ix as usize)
                            * g.cin + ci0;
                        for t in 0..take {
                            sub[dst + t] += seg[off + t];
                        }
                    }
                    q += take;
                    off += take;
                }
            }
        }
    });
}

/// Digital conv weight gradient without the patch matrix:
/// `grad[i, j] = inv_m · Σ_r patch[r, i] · d_out[r, j]` over the
/// `rows = m·P` patch rows, staging one patch *column* `i` at a time
/// into the caller's `col` scratch (`O(m·P)` instead of `O(m·P·K)`).
/// Keeps the shared outer-product kernel's exact loop nest — `i`
/// outer, then `j`, then ascending `r` — including multiply-adds of
/// exact-zero padding taps, so it is bit-identical to running
/// `outer_product_grad` on a materialized `im2col` matrix.
pub fn conv_grad_into(plan: &PatchPlan, x: &[f32], d_out: &[f32],
                      m: usize, inv_m: f32, col: &mut Vec<f32>,
                      grad: &mut [f32]) {
    let g = &plan.geom;
    let (k, n, rows) = (plan.patch_len, g.cout, plan.patch_rows(m));
    assert_eq!(x.len(), m * plan.in_len);
    assert!(d_out.len() >= rows * n);
    assert_eq!(grad.len(), k * n);
    if col.len() < rows {
        col.resize(rows, 0.0);
    }
    let col = &mut col[..rows];
    for i in 0..k {
        // Stage patch column i: the (ky, kx, ci) tap of every patch
        // row, ascending r (sample, then oy, then ox) — raw input
        // values, zeros on padding, same as the materialized rows.
        let tap = i / g.cin;
        let ci = i % g.cin;
        let (ky, kx) = (tap / g.kw, tap % g.kw);
        let mut r = 0;
        for s in 0..m {
            let img = &x[s * plan.in_len..(s + 1) * plan.in_len];
            for oy in 0..plan.out_h {
                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                let row_ok = iy >= 0 && (iy as usize) < g.in_h;
                for ox in 0..plan.out_w {
                    let ix =
                        (ox * g.stride + kx) as isize - g.pad as isize;
                    col[r] = if row_ok
                        && ix >= 0 && (ix as usize) < g.in_w
                    {
                        img[((iy as usize) * g.in_w + ix as usize)
                            * g.cin + ci]
                    } else {
                        0.0
                    };
                    r += 1;
                }
            }
        }
        for j in 0..n {
            let mut acc = 0.0f32;
            for (r, &cv) in col.iter().enumerate() {
                acc += cv * d_out[r * n + j];
            }
            grad[i * n + j] = acc * inv_m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(h: usize, w: usize, c: usize, kh: usize, kw: usize,
            cout: usize, stride: usize, pad: usize) -> PatchGeom {
        PatchGeom { in_h: h, in_w: w, cin: c, kh, kw, cout, stride, pad }
    }

    #[test]
    fn output_extents() {
        // 3×3 same-padded stride 1 preserves the spatial extent.
        let g = geom(8, 8, 3, 3, 3, 16, 1, 1);
        assert_eq!((g.out_h(), g.out_w()), (8, 8));
        assert_eq!(g.patch_len(), 27);
        assert_eq!(g.out_len(), 8 * 8 * 16);
        assert_eq!(g.patch_rows(4), 4 * 64);
        // Stride-2 downsampling halves (floor) the extent.
        let g = geom(8, 8, 16, 3, 3, 32, 2, 1);
        assert_eq!((g.out_h(), g.out_w()), (4, 4));
        // 1×1 stride-2 projection matches the 3×3 pad-1 stride-2 body.
        let g = geom(8, 8, 16, 1, 1, 32, 2, 0);
        assert_eq!((g.out_h(), g.out_w()), (4, 4));
        // Odd extents floor.
        let g = geom(5, 5, 1, 3, 3, 1, 2, 1);
        assert_eq!((g.out_h(), g.out_w()), (3, 3));
        // The cached plan mirrors every accessor.
        let g = geom(8, 8, 3, 3, 3, 16, 2, 1);
        let p = PatchPlan::new(g);
        assert_eq!((p.out_h, p.out_w), (g.out_h(), g.out_w()));
        assert_eq!(p.positions, g.positions());
        assert_eq!(p.patch_len, g.patch_len());
        assert_eq!(p.in_len, g.in_len());
        assert_eq!(p.out_len, g.out_len());
        assert_eq!(p.patch_rows(3), g.patch_rows(3));
    }

    #[test]
    fn im2col_identity_kernel_is_a_copy() {
        // 1×1 stride-1 no-pad lowering is the identity reshape.
        let g = geom(3, 2, 2, 1, 1, 4, 1, 0);
        let x: Vec<f32> = (0..2 * g.in_len()).map(|i| i as f32).collect();
        let mut p = vec![0.0f32; 2 * g.positions() * g.patch_len()];
        im2col_into(&g, &x, 2, &WorkerPool::serial(), &mut p);
        assert_eq!(p, x);
        // And the adjoint is the identity back.
        let mut dx = vec![1.0f32; x.len()];
        col2im_into(&g, &p, 2, &WorkerPool::serial(), &mut dx);
        assert_eq!(dx, x);
    }

    #[test]
    fn im2col_padding_and_neighborhood() {
        // Single channel 2×2 image, 3×3 same-padded kernel: the patch at
        // output (0,0) sees the image in its bottom-right quadrant.
        let g = geom(2, 2, 1, 3, 3, 1, 1, 1);
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut p = vec![0.0f32; g.positions() * g.patch_len()];
        im2col_into(&g, &x, 1, &WorkerPool::serial(), &mut p);
        assert_eq!(&p[..9],
                   &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
        // Patch at (1,1): image in the top-left quadrant.
        assert_eq!(&p[3 * 9..4 * 9],
                   &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for the linear pair.
        let g = geom(4, 3, 2, 3, 3, 5, 2, 1);
        let m = 2;
        let (p, k) = (g.positions(), g.patch_len());
        let x: Vec<f32> = (0..m * g.in_len())
            .map(|i| (((i * 7) % 11) as f32 - 5.0) / 4.0)
            .collect();
        let y: Vec<f32> = (0..m * p * k)
            .map(|i| (((i * 5) % 13) as f32 - 6.0) / 8.0)
            .collect();
        let mut px = vec![0.0f32; m * p * k];
        im2col_into(&g, &x, m, &WorkerPool::serial(), &mut px);
        let mut dy = vec![0.0f32; m * g.in_len()];
        col2im_into(&g, &y, m, &WorkerPool::serial(), &mut dy);
        let lhs: f64 = px.iter().zip(&y)
            .map(|(&a, &b)| a as f64 * b as f64).sum();
        let rhs: f64 = x.iter().zip(&dy)
            .map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn patch_kernels_are_worker_invariant() {
        let g = geom(6, 5, 3, 3, 3, 4, 2, 1);
        let m = 5;
        let (p, k) = (g.positions(), g.patch_len());
        let x: Vec<f32> = (0..m * g.in_len())
            .map(|i| (((i * 3) % 17) as f32 - 8.0) / 8.0)
            .collect();
        let run = |workers: usize| {
            let pool = WorkerPool::new(workers);
            let mut px = vec![0.0f32; m * p * k];
            im2col_into(&g, &x, m, &pool, &mut px);
            let mut dx = vec![0.0f32; m * g.in_len()];
            col2im_into(&g, &px, m, &pool, &mut dx);
            (px, dx)
        };
        let a = run(1);
        assert_eq!(a, run(2));
        assert_eq!(a, run(4));
    }

    #[test]
    fn patch_source_segments_match_materialized_rows() {
        // Every (row, segment) read of the streaming source must
        // reproduce the materialized patch matrix bytes — including
        // segments that straddle tap and padding boundaries.
        for (stride, pad) in [(1usize, 1usize), (2, 1), (1, 0)] {
            let g = geom(4, 5, 3, 3, 3, 2, stride, pad);
            let plan = PatchPlan::new(g);
            let m = 2;
            let x: Vec<f32> = (0..m * plan.in_len)
                .map(|i| (((i * 7) % 19) as f32 - 9.0) / 8.0)
                .collect();
            let mut px =
                vec![0.0f32; plan.patch_rows(m) * plan.patch_len];
            im2col_into(&g, &x, m, &WorkerPool::serial(), &mut px);
            let src = ConvPatchSource::new(&plan, &x);
            let k = plan.patch_len;
            let mut buf = vec![0.0f32; k];
            for row in 0..plan.patch_rows(m) {
                // Tile-shaped reads at several strip widths, ragged
                // tails included.
                for tile_rows in [1usize, 4, 7, k] {
                    let mut r0 = 0;
                    while r0 < k {
                        let len = tile_rows.min(k - r0);
                        let seg = src.segment(row, r0, len, &mut buf);
                        assert_eq!(seg,
                                   &px[row * k + r0..row * k + r0 + len],
                                   "stride={stride} pad={pad} \
                                    row={row} r0={r0} len={len}");
                        r0 += len;
                    }
                }
            }
        }
    }

    #[test]
    fn conv_grad_matches_outer_product_on_materialized_patches() {
        // Column-streamed gradient == the shared outer-product kernel
        // on the materialized patch matrix, bit for bit.
        for (stride, pad) in [(1usize, 1usize), (2, 1)] {
            let g = geom(4, 4, 2, 3, 3, 3, stride, pad);
            let plan = PatchPlan::new(g);
            let m = 2;
            let rows = plan.patch_rows(m);
            let (k, n) = (plan.patch_len, g.cout);
            let x: Vec<f32> = (0..m * plan.in_len)
                .map(|i| (((i * 5) % 13) as f32 - 6.0) / 8.0)
                .collect();
            let d: Vec<f32> = (0..rows * n)
                .map(|i| (((i * 11) % 17) as f32 - 8.0) / 16.0)
                .collect();
            let mut px = vec![0.0f32; rows * k];
            im2col_into(&g, &x, m, &WorkerPool::serial(), &mut px);
            let inv_m = 1.0 / rows as f32;
            // Reference: the exact loop nest of the shared
            // outer-product kernel (nn::graph::outer_product_grad).
            let mut want = vec![0.0f32; k * n];
            for i in 0..k {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for r in 0..rows {
                        acc += px[r * k + i] * d[r * n + j];
                    }
                    want[i * n + j] = acc * inv_m;
                }
            }
            let mut col = Vec::new();
            let mut got = vec![0.0f32; k * n];
            conv_grad_into(&plan, &x, &d, m, inv_m, &mut col,
                           &mut got);
            assert_eq!(got, want, "stride={stride} pad={pad}");
        }
    }
}
