//! Energy / latency / area estimator for the crossbar accelerator.
//!
//! Architecture-level constants of ISAAC-class mixed-signal periphery
//! (Shafiee et al. 2016; Rekhi et al. 2019 for converter scaling), in
//! 32 nm-equivalent technology.  The absolute numbers are order-of-
//! magnitude — what matters for the paper's argument is the *relative*
//! cost structure: ADCs dominate, array reads are cheap, and the HIC
//! update path (bit-flips on the LSB array) is far cheaper than
//! reprogramming multi-level cells.

use super::mapper::LayerMapping;

/// Per-event energy constants (picojoules) and geometry constants.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// one 8-bit DAC conversion
    pub dac_pj: f64,
    /// one 8-bit ADC conversion (dominant periphery cost)
    pub adc_pj: f64,
    /// one cross-point read MAC (current summation share per device)
    pub cell_read_pj: f64,
    /// one SET pulse on a multi-level cell
    pub set_pulse_pj: f64,
    /// one RESET pulse
    pub reset_pulse_pj: f64,
    /// one binary-device flip on the LSB array
    pub lsb_flip_pj: f64,
    /// digital MAC (outer product / normalization path), per op
    pub digital_mac_pj: f64,
    /// tile read latency (ns) — row drive + settle + ADC scan
    pub tile_read_ns: f64,
    /// area of one 128x128 tile incl. periphery (mm^2)
    pub tile_area_mm2: f64,
    /// SRAM read energy per 32-bit word (the von-Neumann comparison)
    pub sram_read_pj: f64,
    /// DRAM read energy per 32-bit word
    pub dram_read_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            dac_pj: 0.1,
            adc_pj: 2.0,
            cell_read_pj: 0.001,
            set_pulse_pj: 10.0,
            reset_pulse_pj: 15.0,
            lsb_flip_pj: 5.0,
            digital_mac_pj: 0.25,
            tile_read_ns: 100.0,
            tile_area_mm2: 0.015,
            sram_read_pj: 5.0,
            dram_read_pj: 640.0,
        }
    }
}

/// Aggregated cost report for a workload phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    pub vmm_energy_pj: f64,
    pub program_energy_pj: f64,
    pub digital_energy_pj: f64,
    pub latency_ns: f64,
}

impl EnergyReport {
    pub fn total_pj(&self) -> f64 {
        self.vmm_energy_pj + self.program_energy_pj + self.digital_energy_pj
    }

    pub fn add(&mut self, other: &EnergyReport) {
        self.vmm_energy_pj += other.vmm_energy_pj;
        self.program_energy_pj += other.program_energy_pj;
        self.digital_energy_pj += other.digital_energy_pj;
        self.latency_ns += other.latency_ns;
    }
}

impl EnergyModel {
    /// Cost of one batched VMM (`m` input vectors) through a mapped layer.
    /// Tiles operate in parallel; latency counts sequential input vectors.
    pub fn layer_vmm(&self, mapping: &LayerMapping, m: usize)
                     -> EnergyReport {
        let mut e = 0.0;
        for t in &mapping.tiles {
            let dacs = t.used_rows as f64;
            let adcs = t.used_cols as f64;
            let cells = t.used() as f64;
            e += m as f64
                * (dacs * self.dac_pj + adcs * self.adc_pj
                   + 2.0 * cells * self.cell_read_pj);
        }
        // Partial sums across row-tiles are reduced digitally.
        let row_tiles = mapping.k.div_ceil(mapping.policy.tile_rows);
        let digital = if row_tiles > 1 {
            m as f64 * mapping.n as f64 * (row_tiles - 1) as f64
                * self.digital_mac_pj
        } else {
            0.0
        };
        EnergyReport {
            vmm_energy_pj: e,
            program_energy_pj: 0.0,
            digital_energy_pj: digital,
            latency_ns: m as f64 * self.tile_read_ns,
        }
    }

    /// Cost of one HIC update phase on a layer: `flips` LSB bit-flips and
    /// `set_pulses`/`reset_pulses` MSB programming events, plus the digital
    /// outer product `m x k x n`.
    pub fn layer_update(&self, mapping: &LayerMapping, m: usize,
                        flips: u64, set_pulses: u64, reset_pulses: u64)
                        -> EnergyReport {
        EnergyReport {
            vmm_energy_pj: 0.0,
            program_energy_pj: flips as f64 * self.lsb_flip_pj
                + set_pulses as f64 * self.set_pulse_pj
                + reset_pulses as f64 * self.reset_pulse_pj,
            digital_energy_pj: m as f64 * mapping.k as f64
                * mapping.n as f64 * self.digital_mac_pj,
            latency_ns: self.tile_read_ns, // update is one array cycle
        }
    }

    /// The von-Neumann strawman: same VMM with weights streamed from
    /// SRAM/DRAM into digital MACs (per 32-bit weight word read).
    pub fn digital_vmm(&self, k: usize, n: usize, m: usize,
                       from_dram: bool) -> EnergyReport {
        let words = (k * n) as f64;
        let mem = if from_dram { self.dram_read_pj } else { self.sram_read_pj };
        EnergyReport {
            vmm_energy_pj: 0.0,
            program_energy_pj: 0.0,
            digital_energy_pj: m as f64
                * (words * self.digital_mac_pj + words * mem),
            latency_ns: 0.0,
        }
    }

    /// Chip area of a mapped network (tiles only).
    pub fn network_area_mm2(&self, mappings: &[LayerMapping]) -> f64 {
        let tiles: usize = mappings.iter().map(|m| m.tile_count()).sum();
        tiles as f64 * self.tile_area_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::mapper::TilingPolicy;

    fn mapping(k: usize, n: usize) -> LayerMapping {
        LayerMapping::new("t", k, n, TilingPolicy::default())
    }

    #[test]
    fn adc_dominates_vmm_periphery() {
        let m = mapping(128, 128);
        let e = EnergyModel::default();
        let r = e.layer_vmm(&m, 1);
        let adc_share = 128.0 * e.adc_pj / r.vmm_energy_pj;
        assert!(adc_share > 0.5, "adc share {adc_share}");
        assert_eq!(r.program_energy_pj, 0.0);
    }

    #[test]
    fn in_memory_beats_dram_streaming() {
        // The core architectural claim: analog VMM ≪ DRAM-streamed digital.
        let m = mapping(576, 64);
        let e = EnergyModel::default();
        let analog = e.layer_vmm(&m, 1).total_pj();
        let dram = e.digital_vmm(576, 64, 1, true).total_pj();
        let sram = e.digital_vmm(576, 64, 1, false).total_pj();
        assert!(analog < sram, "analog={analog} sram={sram}");
        assert!(sram < dram);
        assert!(dram / analog > 50.0, "ratio {}", dram / analog);
    }

    #[test]
    fn hic_update_cheaper_than_reprogramming() {
        // LSB bit-flip accumulation vs programming every weight's MSB.
        let m = mapping(576, 64);
        let e = EnergyModel::default();
        let weights = (576 * 64) as u64;
        // Typical step: ~1 flip/weight, overflow on ~1% of weights.
        let hic = e.layer_update(&m, 1, weights, weights / 100, 0);
        // Naive multi-level update: 2 pulses per weight, every step.
        let naive = e.layer_update(&m, 1, 0, 2 * weights, 0);
        assert!(hic.program_energy_pj < naive.program_energy_pj / 2.0);
    }

    #[test]
    fn partial_sum_reduction_charged() {
        let small = mapping(128, 64);
        let tall = mapping(512, 64);
        let e = EnergyModel::default();
        assert_eq!(e.layer_vmm(&small, 1).digital_energy_pj, 0.0);
        assert!(e.layer_vmm(&tall, 1).digital_energy_pj > 0.0);
    }

    #[test]
    fn report_accumulates() {
        let e = EnergyModel::default();
        let m = mapping(128, 128);
        let mut total = EnergyReport::default();
        total.add(&e.layer_vmm(&m, 2));
        total.add(&e.layer_update(&m, 2, 10, 5, 1));
        assert!(total.total_pj() > 0.0);
        assert!(total.latency_ns > 0.0);
    }

    #[test]
    fn area_scales_with_tiles() {
        let e = EnergyModel::default();
        let a1 = e.network_area_mm2(&[mapping(128, 128)]);
        let a4 = e.network_area_mm2(&[mapping(256, 256)]);
        assert!((a4 / a1 - 4.0).abs() < 1e-9);
    }
}
