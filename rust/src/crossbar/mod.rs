//! Crossbar microarchitecture simulator (the hw-codesign substrate).
//!
//! The paper's accelerator organizes weights on fixed-size analog crossbar
//! tiles with 8-bit DACs on rows and 8-bit ADCs on columns.  This module
//! models that periphery at the architecture level:
//!
//! * [`quant`] — DAC/ADC transfer functions (bit-exact with the Pallas
//!   kernel's epilogue)
//! * [`mapper`] — tiling of layer weight matrices onto physical tiles,
//!   utilization accounting
//! * [`tile`] — a functional tile: VMM through the planar PCM device
//!   planes with quantized I/O (the host-side oracle of the L1 kernel).
//!   Batched reads evaluate drift once per invocation into a reusable
//!   [`tile::TileScratch`] and draw fresh per-sample read noise (batched
//!   Box–Muller fill) — no per-sample allocation or re-read of the
//!   array.  The forward and **transposed** kernels
//!   (`vmm_batch_into` / `vmm_t_batch_into`) share one
//!   noisy-weight-read helper, the single in-tree copy of the
//!   DAC/read/MAC/ADC weight-read sequence.
//! * [`grid`] — the sharded multi-tile engine: one logical weight matrix
//!   on an R×C grid of tiles.  State kernels run tile-parallel; the
//!   forward and transposed VMMs are **tile-stationary, sample-blocked**
//!   strip kernels (shard = column/row strip × sample block, drift
//!   planes hoisted per (tile, block), one fused Box–Muller noise fill
//!   per block, hoisted batch DAC) with counter-based per-shard and
//!   per-(op, tile, sample) RNG streams — bitwise identical for any
//!   worker count and any sample-block size, bit-compatible with the
//!   serial single-tile path in the noise-free domain
//! * [`conv`] — weight-stationary streaming patch lowering for
//!   convolution-on-grid: the forward VMM pulls patch segments on
//!   demand from a once-DAC'd image (`ConvPatchSource`, a grid
//!   [`grid::PatchSource`]) and the backward adjoint scatter drains
//!   the transposed VMM's strip outputs directly
//!   (`col2im_stream_into` over [`grid::TvmmOut`]), so a conv layer is
//!   one `[kh·kw·cin, cout]` analog VMM per patch with no
//!   materialized patch matrix — bit-identical to the retained
//!   im2col/col2im reference pair, and the worker-count determinism
//!   contract extends to the patch shards
//! * [`energy`] — energy / latency / area estimator with published-order
//!   constants (ISAAC-class periphery), used for the architecture
//!   comparisons in DESIGN.md and the `crossbar_explorer` example

pub mod conv;
pub mod energy;
pub mod grid;
pub mod mapper;
pub mod quant;
pub mod tile;

pub use conv::{ConvPatchSource, PatchGeom, PatchPlan};
pub use energy::{EnergyModel, EnergyReport};
pub use grid::{CrossbarGrid, GridScratch, GridView, PatchSource, TvmmOut};
pub use mapper::{LayerMapping, TileCoord, TilingPolicy};
pub use quant::{AdcSpec, DacSpec};
pub use tile::{CrossbarTile, TileScratch};
