//! Sharded multi-tile crossbar engine.
//!
//! [`CrossbarGrid`] maps one logical `[k, n]` weight matrix onto the
//! R×C tile grid computed by [`mapper::LayerMapping`] and runs the
//! device kernels — batched VMM, increment programming, training
//! updates, drift decode, saturation refresh — **tile-parallel** on a
//! [`WorkerPool`].  This converts the PR-1 planar data layout into
//! wall-clock scaling: every tile's planes are independent, exactly the
//! per-tile independence the paper's accelerator (and the
//! mixed-precision trainers it builds on) exploits.
//!
//! # Sharding scheme
//!
//! * **State kernels** (`program_init`, `program_increments`,
//!   `apply_update`, `refresh`): one shard per tile.  Each shard owns
//!   its tile's planes, so shards never alias; integer side-totals
//!   (pulses, overflows, refresh counts) fold through an atomic adder
//!   (exact: `u64` addition is commutative).
//! * **`vmm_batch_into`** (forward) is **tile-stationary and
//!   sample-blocked**: phase 1 evaluates drift once per batch, one
//!   shard per tile; phase 2 shards by *(column strip × sample
//!   block)* — a shard owns a disjoint `[B, strip_cols]` slice of the
//!   output (`B =` [`CrossbarGrid::sample_block`]).  Within a shard the
//!   loop is tile-outer: each row-tile's drifted `gp`/`gm` planes are
//!   hoisted once, the whole block's read noise is drawn in one fused
//!   Box–Muller pass ([`fill_gaussian_block`]: one even `2·rows·cols`
//!   segment per sample), and a `[B, tr] × [tr, tc]` micro-kernel
//!   accumulates the block's partial sums — so the conductance planes
//!   cross the cache hierarchy once per (tile, block) instead of once
//!   per (tile, sample), and the noise fill is amortized over the
//!   block.  Per output element the f32 addition sequence is still
//!   ascending row-tile then row (full-precision cross-row-tile
//!   accumulation, ADC once per logical column after the last
//!   row-tile), identical to a single tile spanning the whole matrix —
//!   which keeps the grid bit-compatible with the serial single-tile
//!   path in the noise-free domain.
//! * **`vmm_t_batch_into`** (transposed, the error-backpropagation
//!   pass): the mirror image — shard = *(row strip × sample block)*,
//!   tile-outer over the strip's column-tiles, per output row the f32
//!   term order is ascending logical column, ADC once per logical row
//!   after the last column-tile.
//! * **`drift_into`**: one shard per tile, serial deterministic gather.
//!
//! Both VMM kernels also hoist the input DAC: the batch's inputs
//! (forward `x`, transposed `e`) are quantized **once** into a shared
//! read-only scratch buffer instead of once per (sample, tile) inside
//! every strip — `DacSpec::convert` is a pure function, so the hoist is
//! value-neutral.
//!
//! # Streaming entry points (weight-stationary conv lowering)
//!
//! The forward micro-kernel only ever touches one `[r0, r0 + tile_rows)`
//! segment of one input row at a time, so it does not actually need the
//! whole `[m, k]` matrix staged: [`CrossbarGrid::vmm_batch_src_into`]
//! runs the identical phase structure against a [`PatchSource`] that
//! produces each quantized segment on demand.  The dense path's hoisted
//! DAC is itself a `PatchSource` (borrowed slices, zero copy), and the
//! conv lowering's patch generator (`crossbar::conv::ConvPatchSource`)
//! gathers segments from a once-DAC'd image instead of a materialized
//! im2col matrix.  Symmetrically, [`CrossbarGrid::vmm_t_batch_with`]
//! exposes the transposed kernel's per-(strip, sample) ADC'd outputs
//! through a read-only [`TvmmOut`] view *before* the logical gather, so
//! a caller can drain them straight into its own layout (the conv
//! lowering's fused col2im scatter) — `vmm_t_batch_into` is the
//! copy-gather drain.  Neither hook moves an RNG call or reorders an
//! f32 op: sources/drains only change where values come from and go to,
//! which is why the streamed conv path is bit-identical to the
//! materialized one.
//!
//! # RNG stream discipline
//!
//! Shards never share a generator; every stream is counter-based (see
//! `util::rng`'s op-stream derivation):
//!
//! * state kernels draw one [`op_rng`]`(seed, round, op_tag, tile)`
//!   stream per tile;
//! * fabrication stuck faults (`pcm::fault`) are placed once at
//!   construction from the dedicated `(seed, 0, OP_FAULT, tile)`
//!   stream — one uniform per cell, G+ plane then G− — so fault
//!   placement is worker-invariant and fault-off runs draw nothing
//!   extra (the goldens' byte-identity guarantee);
//!   programming-failure draws ride the op stream already driving
//!   each write (see `pcm::fault` for the exact draw-order contract);
//! * the blocked VMM kernels draw one
//!   [`op_sample_rng`]`(seed, round, op_tag, tile, sample)`
//!   **sub-stream per (op, tile, sample)** — `OP_VMM` forward,
//!   `OP_VMM_T` transposed, so a forward and a backward pass at the
//!   same `round` draw independent read noise.  One sample's noise for
//!   one tile is a single even `2·rows·cols` Gaussian segment (G+
//!   plane deviates first, then G−), applied through
//!   `tile::read_noisy_weights_prefilled`.
//!
//! The forward kernel additionally takes a **sample-base offset**
//! ([`CrossbarGrid::vmm_batch_base_into`]): the per-sample stream id
//! becomes `sample_base + s`, so a caller that assigns globally unique
//! ids to its rows (the serving scheduler's request trace, the conv
//! patch rows of a coalesced inference batch) gets per-row outputs
//! that depend only on `(seed, round, global id)` — never on how rows
//! were coalesced into batches.  [`CrossbarGrid::vmm_batch_into`] is
//! the `sample_base = 0` case, so every training path is byte-
//! identical to before the offset existed.
//!
//! Because a stream depends only on these stable ids — never on the
//! worker, the shard decomposition or the sample-block size — **all
//! grid kernels are bitwise identical for any worker count and any
//! `sample_block`**; `rust/tests/prop_parallel_equivalence.rs` pins
//! both invariances plus the noise-free equivalence against the
//! single-tile serial path.  Reusing a `(seed, round, op)` triple
//! replays the same noise, so callers advance `round` between
//! invocations.
//!
//! The pre-blocking **sample-major** kernels
//! (`vmm_batch_sample_major_into` / `vmm_t_batch_sample_major_into`,
//! one `op_rng` stream per strip, per-sample re-reads) are retained as
//! the bench baseline (`BENCH_grid.json` / `BENCH_conv.json`
//! blocked-vs-sample-major series) and as a noise-free equivalence
//! reference; their noise streams differ from the blocked kernels by
//! design.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::hic::weight::{HicGeometry, HicWeight};
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::WorkerPool;
use crate::util::rng::{fill_gaussian_block, Pcg64};

pub use crate::util::rng::{op_rng, op_sample_rng};

use super::mapper::{LayerMapping, TilingPolicy};
use super::quant::{AdcSpec, DacSpec};
use super::tile::{read_noisy_weights, read_noisy_weights_prefilled,
                  CrossbarTile};

/// Kernel-family tags baked into the high bits of each shard's RNG
/// stream id (see the module docs).
pub const OP_INIT: u64 = 1;
pub const OP_PROGRAM: u64 = 2;
pub const OP_UPDATE: u64 = 3;
pub const OP_VMM: u64 = 4;
pub const OP_REFRESH: u64 = 5;
pub const OP_PROGRAM_INIT: u64 = 6;
pub const OP_VMM_T: u64 = 7;
/// Fabrication stuck-fault placement (`pcm::fault`): sampled once at
/// grid construction, one `op_rng(seed, 0, OP_FAULT, tile)` stream per
/// tile — disjoint from every other op family, so enabling stuck
/// faults never perturbs init/program/VMM/update draws, and fault
/// placement is a pure function of `(seed, tile)`: bitwise invariant
/// across worker counts.
pub const OP_FAULT: u64 = 8;

/// Cache budget the auto-tuned sample block targets: one block's read
/// noise for one tile is `B` even segments of `2·rows·cols` f32
/// deviates, and the blocked micro-kernel streams those segments while
/// the tile's two drifted conductance planes stay hot — so `B` is
/// chosen to keep the block's noise footprint inside a per-core
/// L2-ish budget for the grid's **largest** tile.
pub const SAMPLE_BLOCK_BUDGET_BYTES: usize = 128 * 1024;

/// Ceiling on the auto-tuned block (beyond this, bigger blocks only
/// reduce shard-level parallelism); the floor of 2 keeps at least some
/// plane-hoist amortization even for giant tiles.
pub const MAX_SAMPLE_BLOCK: usize = 64;

/// Auto-tuned sample block for a grid whose largest tile is
/// `tile_rows × tile_cols`: the largest `B ∈ [2, 64]` whose per-tile
/// noise segments (`B · 2·rows·cols` f32) fit
/// [`SAMPLE_BLOCK_BUDGET_BYTES`].  Pure scheduling — outputs are
/// bitwise identical for any value (`prop_vmm_block_size_invariant`),
/// so this is a cache/parallelism default, never a correctness knob.
pub fn sample_block_for(tile_rows: usize, tile_cols: usize) -> usize {
    let per_sample =
        2 * tile_rows.max(1) * tile_cols.max(1) * std::mem::size_of::<f32>();
    (SAMPLE_BLOCK_BUDGET_BYTES / per_sample).clamp(2, MAX_SAMPLE_BLOCK)
}

/// [`sample_block_for`] with the `HIC_SAMPLE_BLOCK` environment
/// override (any value ≥ 1) — the escape hatch for cache-shape
/// experiments; invalid or unset values fall back to the auto-tune.
pub fn sample_block_from_env(tile_rows: usize, tile_cols: usize) -> usize {
    std::env::var("HIC_SAMPLE_BLOCK")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&b| b >= 1)
        .unwrap_or_else(|| sample_block_for(tile_rows, tile_cols))
}

/// One logical weight matrix sharded onto an R×C grid of
/// [`CrossbarTile`]s (edge tiles sized to their used extent, so the
/// grid holds exactly `k·n` weight cells).
pub struct CrossbarGrid {
    pub mapping: LayerMapping,
    /// Row-major tile grid (`mapping.tile_index` addressing).
    pub tiles: Vec<CrossbarTile>,
    pub dac: DacSpec,
    pub adc: AdcSpec,
    pub seed: u64,
    /// Sample-block size `B` of the blocked VMM kernels — pure
    /// scheduling: outputs are bitwise identical for any value ≥ 1
    /// (per-(tile, sample) RNG sub-streams), so this is a cache/
    /// parallelism knob, never a correctness one.
    pub sample_block: usize,
}

/// Per-tile drifted-conductance planes (valid for one `t_now`).
struct TileDrift {
    gp: Vec<f32>,
    gm: Vec<f32>,
}

/// Per-shard working buffers of the VMM kernels (one per
/// strip × sample-block shard; all buffers grow on demand and are
/// reused across invocations).
struct VmmShardScratch {
    /// per-sample noisy effective-weight read of the current tile
    w: Vec<f32>,
    /// the block's Gaussian deviates (`B` segments of `2·rows·cols`)
    noise: Vec<f32>,
    /// per-sample sub-streams of the current (tile, block)
    rngs: Vec<Pcg64>,
    /// the shard's `[B, strip_cols]` / `[B, strip_rows]` output slice
    out: Vec<f32>,
    /// per-tile quantized input staging: the sample-major reference
    /// kernels' DAC buffer, and the blocked forward kernel's
    /// [`PatchSource::segment`] scratch (a generating source stages at
    /// most one `tile_rows` segment here per read; the dense source
    /// returns borrows and never touches it)
    qbuf: Vec<f32>,
}

impl VmmShardScratch {
    fn new() -> Self {
        VmmShardScratch {
            w: Vec::new(),
            noise: Vec::new(),
            rngs: Vec::new(),
            out: Vec::new(),
            qbuf: Vec::new(),
        }
    }
}

/// Grow a reusable buffer to at least `need` elements.
#[inline]
fn grow(buf: &mut Vec<f32>, need: usize) {
    if buf.len() < need {
        buf.resize(need, 0.0);
    }
}

/// Reusable grid buffers: drift planes per tile, the strip × block
/// shard pool of both VMM kernels, the hoisted batch DAC staging, plus
/// the per-tile scatter buffers the state kernels
/// (`program_increments` / `apply_update`) and `drift_into` reuse —
/// with a long-lived `GridScratch`, none of the training-loop kernels
/// allocate per call once warm.
pub struct GridScratch {
    drift: Vec<TileDrift>,
    /// VMM shard pool, grown to `strips × ⌈m/B⌉` on demand (shared by
    /// the forward and transposed kernels — they never run
    /// concurrently on one scratch)
    shards: Vec<VmmShardScratch>,
    /// hoisted DAC'd batch inputs (`[m, k]` forward / `[m, n]`
    /// transposed), read-only during phase 2
    qin: Vec<f32>,
    /// per-tile row-major submatrix buffers (scatter targets for the
    /// state kernels, decode targets for `drift_into` — tiles are
    /// sized to their used extent, so one buffer serves both roles)
    subs: Vec<Vec<f32>>,
}

/// A provider of **quantized** (post-DAC) input-row segments for the
/// blocked forward VMM ([`CrossbarGrid::vmm_batch_src_into`]).  The
/// micro-kernel asks for exactly the `[r0, r0 + len)` slice of logical
/// row `s` that the current row-tile consumes; an implementation either
/// returns a borrow of already-staged storage (the dense path's hoisted
/// batch DAC — zero copy) or generates the segment into `buf` on the
/// fly (the conv patch path, which gathers from a once-DAC'd image so
/// the `[m·P, kh·kw·cin]` patch matrix never exists).
///
/// Contract: the returned values must be **exactly** what a staged
/// `[m, k]` matrix would hold at those positions (`DacSpec::convert`
/// applied elementwise) — the kernel's RNG streams and f32 op order
/// never depend on the source, so a value-faithful source is
/// bit-identical to staging.  Sources must be `Sync` (segments are
/// pulled concurrently from strip shards) and pure: the same
/// `(s, r0, len)` yields the same values in any call order.
pub trait PatchSource: Sync {
    /// Quantized elements `[r0, r0 + len)` of logical input row `s`,
    /// either borrowed from `self` or staged into `buf[..len]`
    /// (`buf.len() >= len`, per-shard scratch owned by the kernel).
    fn segment<'a>(&'a self, s: usize, r0: usize, len: usize,
                   buf: &'a mut [f32]) -> &'a [f32];
}

/// The staged dense case: segments are borrowed slices of the hoisted
/// batch-DAC buffer, so `vmm_batch_base_into` through the generic
/// kernel is the pre-streaming code path, zero-copy.
struct DenseRows<'a> {
    qin: &'a [f32],
    k: usize,
}

impl PatchSource for DenseRows<'_> {
    #[inline]
    fn segment<'a>(&'a self, s: usize, r0: usize, len: usize,
                   _buf: &'a mut [f32]) -> &'a [f32] {
        &self.qin[s * self.k + r0..s * self.k + r0 + len]
    }
}

/// Read-only view of one transposed VMM's shard outputs, handed to the
/// drain closure of [`CrossbarGrid::vmm_t_batch_with`] before anything
/// is gathered: [`TvmmOut::row_segment`]`(gr, s)` is sample `s`'s ADC'd
/// output segment for row-strip `gr`, covering the logical rows
/// [`TvmmOut::strip_extent`]`(gr)`.  The conv lowering's fused col2im
/// drain scatters straight from these segments into input space, so the
/// `[m·P, kh·kw·cin]` patch-gradient intermediate never exists; the
/// standard drain copies them into the logical `[m, k]` matrix.  The
/// view is `Sync` — drains may shard over it on a [`WorkerPool`].
pub struct TvmmOut<'a> {
    shards: &'a [VmmShardScratch],
    mapping: &'a LayerMapping,
    block: usize,
    nblocks: usize,
}

impl TvmmOut<'_> {
    /// Number of row strips (`⌈k / tile_rows⌉`).
    pub fn strips(&self) -> usize {
        self.mapping.grid_rows()
    }

    /// `(first logical row, row count)` covered by strip `gr`.
    pub fn strip_extent(&self, gr: usize) -> (usize, usize) {
        let t = &self.mapping.tiles[self.mapping.tile_index(gr, 0)];
        (self.mapping.origin(t).0, t.used_rows)
    }

    /// Sample `s`'s ADC'd output segment for row-strip `gr` (length
    /// `strip_extent(gr).1`).
    pub fn row_segment(&self, gr: usize, s: usize) -> &[f32] {
        let rows = self.mapping.tiles[self.mapping.tile_index(gr, 0)]
            .used_rows;
        let (b, i) = (s / self.block, s % self.block);
        let strip = &self.shards[gr * self.nblocks + b];
        &strip.out[i * rows..(i + 1) * rows]
    }
}

/// One grid's hybrid update packaged as a self-contained, `Send`
/// work item (see [`CrossbarGrid::update_item`]): borrows the tiles
/// exclusively and the already-scattered per-tile gradients, so it can
/// be moved into a background task and executed whenever the scheduler
/// reaches it — bitwise identical to running
/// [`CrossbarGrid::apply_update`] on a serial pool at the same `round`.
pub struct GridUpdateItem<'a> {
    tiles: &'a mut Vec<CrossbarTile>,
    subs: &'a [Vec<f32>],
    seed: u64,
    lr: f32,
    t_now: f32,
    round: u64,
}

impl GridUpdateItem<'_> {
    /// Execute the update (tile order, one `OP_UPDATE` stream per
    /// tile); returns total LSB→MSB overflow events.
    pub fn run(self) -> usize {
        let mut total = 0u64;
        for (ti, tile) in self.tiles.iter_mut().enumerate() {
            let mut rng = op_rng(self.seed, self.round, OP_UPDATE, ti);
            total += tile.weights.apply_update(
                &self.subs[ti], self.lr, self.t_now, &mut rng)
                as u64;
        }
        total as usize
    }
}

impl CrossbarGrid {
    /// Build the grid: tiles are constructed in row-major order, each
    /// from its own `(seed, OP_INIT, tile)` stream, so construction is
    /// deterministic and independent of tile count elsewhere.
    pub fn new(params: PcmParams, geom: HicGeometry, k: usize, n: usize,
               policy: TilingPolicy, dac: DacSpec, adc: AdcSpec,
               seed: u64) -> Self {
        let mapping = LayerMapping::new("grid", k, n, policy);
        let mut tiles = Vec::with_capacity(mapping.tile_count());
        let (mut max_r, mut max_c) = (1usize, 1usize);
        for (ti, t) in mapping.tiles.iter().enumerate() {
            let mut rng = op_rng(seed, 0, OP_INIT, ti);
            let mut hw = HicWeight::new(params, geom, t.used_rows,
                                        t.used_cols, &mut rng);
            if params.fault.stuck_rate() > 0.0 {
                // Dedicated per-tile sampling stream (see OP_FAULT):
                // the oracle mirrors this draw order exactly — one
                // uniform per cell, G+ plane then G−.
                let mut frng = op_rng(seed, 0, OP_FAULT, ti);
                hw.seed_faults(&mut frng);
            }
            tiles.push(CrossbarTile::new(hw, dac, adc));
            max_r = max_r.max(t.used_rows);
            max_c = max_c.max(t.used_cols);
        }
        CrossbarGrid {
            mapping,
            tiles,
            dac,
            adc,
            seed,
            sample_block: sample_block_from_env(max_r, max_c),
        }
    }

    pub fn k(&self) -> usize {
        self.mapping.k
    }

    pub fn n(&self) -> usize {
        self.mapping.n
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Tile at grid coordinate `(gr, gc)`.
    pub fn tile(&self, gr: usize, gc: usize) -> &CrossbarTile {
        &self.tiles[self.mapping.tile_index(gr, gc)]
    }

    /// Allocate reusable buffers sized for this grid (the VMM shard
    /// pool and DAC staging grow on first use — their extents depend on
    /// the batch size).
    pub fn scratch(&self) -> GridScratch {
        let drift = self
            .tiles
            .iter()
            .map(|t| {
                let nt = t.rows() * t.cols();
                TileDrift { gp: vec![0.0; nt], gm: vec![0.0; nt] }
            })
            .collect();
        let subs = self
            .tiles
            .iter()
            .map(|t| vec![0.0f32; t.rows() * t.cols()])
            .collect();
        GridScratch {
            drift,
            shards: Vec::new(),
            qin: Vec::new(),
            subs,
        }
    }

    // -- logical <-> tile layout ------------------------------------------

    /// Split a logical row-major `[k, n]` matrix into per-tile
    /// row-major submatrices (tile enumeration order) — allocating
    /// wrapper of [`CrossbarGrid::scatter_into`], used where no scratch
    /// is alive yet (construction-time programming).
    fn scatter(&self, src: &[f32]) -> Vec<Vec<f32>> {
        let mut subs: Vec<Vec<f32>> = self
            .mapping
            .tiles
            .iter()
            .map(|t| vec![0.0f32; t.used_rows * t.used_cols])
            .collect();
        self.scatter_into(src, &mut subs);
        subs
    }

    /// Split a logical row-major `[k, n]` matrix into the caller's
    /// per-tile buffers (tile enumeration order, no allocation).
    fn scatter_into(&self, src: &[f32], subs: &mut [Vec<f32>]) {
        assert_eq!(src.len(), self.k() * self.n());
        assert_eq!(subs.len(), self.tiles.len());
        let n = self.n();
        for (t, sub) in self.mapping.tiles.iter().zip(subs) {
            let (r0, c0) = self.mapping.origin(t);
            assert_eq!(sub.len(), t.used_rows * t.used_cols);
            for r in 0..t.used_rows {
                let src_row = (r0 + r) * n + c0;
                sub[r * t.used_cols..(r + 1) * t.used_cols]
                    .copy_from_slice(&src[src_row..src_row + t.used_cols]);
            }
        }
    }

    /// Gather per-tile row-major buffers back into the logical matrix.
    fn gather(&self, bufs: &[Vec<f32>], out: &mut [f32]) {
        assert_eq!(out.len(), self.k() * self.n());
        let n = self.n();
        for (t, buf) in self.mapping.tiles.iter().zip(bufs) {
            let (r0, c0) = self.mapping.origin(t);
            for r in 0..t.used_rows {
                let dst_row = (r0 + r) * n + c0;
                out[dst_row..dst_row + t.used_cols].copy_from_slice(
                    &buf[r * t.used_cols..(r + 1) * t.used_cols]);
            }
        }
    }

    // -- state kernels (shard = tile) -------------------------------------

    /// Program initial weights (MSB-quantized), tile-parallel.  Uses
    /// its own op tag (`OP_PROGRAM_INIT`), so an init followed by a
    /// `program_increments` at the same `round` still draws
    /// independent write-noise streams.  (Construction-time path: the
    /// one state kernel that allocates its scatter buffers itself, so
    /// it can run before any `GridScratch` exists.)
    pub fn program_init(&mut self, w: &[f32], t_now: f32, round: u64,
                        pool: &WorkerPool) {
        let subs = self.scatter(w);
        let seed = self.seed;
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_PROGRAM_INIT, ti);
            tile.weights.program_init(&subs[ti], t_now, &mut rng);
        });
    }

    /// Apply signed per-weight increments (`dw` logical `[k, n]`,
    /// zeros untouched) through the differential pairs, tile-parallel.
    /// Returns total SET pulses applied.
    pub fn program_increments(&mut self, dw: &[f32], t_now: f32,
                              round: u64, pool: &WorkerPool,
                              scratch: &mut GridScratch) -> u64 {
        self.scatter_into(dw, &mut scratch.subs);
        let subs: &[Vec<f32>] = &scratch.subs;
        let seed = self.seed;
        let total = AtomicU64::new(0);
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_PROGRAM, ti);
            let mut pulses = 0u64;
            for (i, &d) in subs[ti].iter().enumerate() {
                if d != 0.0 {
                    pulses += tile.weights.msb.apply_increment(
                        i, d, t_now, &mut rng) as u64;
                }
            }
            total.fetch_add(pulses, Ordering::Relaxed);
        });
        total.into_inner()
    }

    /// One hybrid training update (`grad` logical `[k, n]`),
    /// tile-parallel; returns total LSB→MSB overflow events.
    pub fn apply_update(&mut self, grad: &[f32], lr: f32, t_now: f32,
                        round: u64, pool: &WorkerPool,
                        scratch: &mut GridScratch) -> usize {
        self.scatter_into(grad, &mut scratch.subs);
        let subs: &[Vec<f32>] = &scratch.subs;
        let seed = self.seed;
        let total = AtomicU64::new(0);
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_UPDATE, ti);
            let ovf = tile.weights.apply_update(
                &subs[ti], lr, t_now, &mut rng) as u64;
            total.fetch_add(ovf, Ordering::Relaxed);
        });
        total.into_inner() as usize
    }

    /// Package one hybrid training update as an **enqueueable work
    /// item**: the gradient is scattered into the scratch's per-tile
    /// buffers immediately (so the caller's `grad` borrow can end), and
    /// the returned [`GridUpdateItem`] owns everything the update needs
    /// — move it into a [`crate::util::pool::PipelineScope`] task and
    /// [`GridUpdateItem::run`] it there.  Per-tile RNG streams
    /// (`op_rng(seed, round, OP_UPDATE, tile)`) and tile order are
    /// identical to [`CrossbarGrid::apply_update`] on a serial pool, so
    /// where the item runs is pure scheduling: results are bitwise
    /// identical.
    pub fn update_item<'a>(&'a mut self, grad: &[f32], lr: f32,
                           t_now: f32, round: u64,
                           scratch: &'a mut GridScratch)
                           -> GridUpdateItem<'a> {
        self.scatter_into(grad, &mut scratch.subs);
        GridUpdateItem {
            tiles: &mut self.tiles,
            subs: &scratch.subs,
            seed: self.seed,
            lr,
            t_now,
            round,
        }
    }

    /// Selective saturation refresh, tile-parallel; returns refreshed
    /// pair count.
    pub fn refresh(&mut self, t_now: f32, round: u64,
                   pool: &WorkerPool) -> usize {
        let seed = self.seed;
        let total = AtomicU64::new(0);
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_REFRESH, ti);
            let n = tile.weights.refresh(t_now, &mut rng) as u64;
            total.fetch_add(n, Ordering::Relaxed);
        });
        total.into_inner() as usize
    }

    // -- read kernels ------------------------------------------------------

    /// Drift-evaluated decode of the logical weight matrix at `t_now`
    /// (no read noise) — the grid twin of `DifferentialPair::decode_into`
    /// with the drift power law evaluated tile-parallel into the
    /// scratch's per-tile buffers (no allocation), then a serial
    /// deterministic gather.
    pub fn drift_into(&self, t_now: f32, pool: &WorkerPool,
                      scratch: &mut GridScratch, out: &mut [f32]) {
        let tiles = &self.tiles;
        pool.run(&mut scratch.subs, |ti, buf| {
            tiles[ti].weights.decode_into(t_now, buf);
        });
        self.gather(&scratch.subs, out);
    }

    /// Evaluate both drifted conductance planes once for the batch,
    /// tile-parallel (no RNG) — phase 1 of every VMM kernel.
    fn drift_phase(&self, t_now: f32, pool: &WorkerPool,
                   drift: &mut [TileDrift]) {
        let tiles = &self.tiles;
        pool.run(drift, |ti, d| {
            let msb = &tiles[ti].weights.msb;
            msb.plus.drift_into(t_now, &mut d.gp);
            msb.minus.drift_into(t_now, &mut d.gm);
            // Spare-strip remap: patch claimed dead cells with their
            // spare device's drifted conductance (no-op unless the
            // fault model's remap knob is on and a cell was claimed).
            msb.apply_remap_overrides(t_now, &mut d.gp, &mut d.gm);
        });
    }

    /// Batched analog VMM over the whole grid (`x: [m, k]` row-major
    /// logical inputs, `out: [m, n]`), drift once per batch, fresh
    /// per-sample read noise per tile — **tile-stationary,
    /// sample-blocked** (see the module docs for the sharding, RNG and
    /// bit-compatibility contracts).
    pub fn vmm_batch_into(&self, x: &[f32], m: usize, t_now: f32,
                          round: u64, pool: &WorkerPool,
                          scratch: &mut GridScratch, out: &mut [f32]) {
        self.vmm_batch_base_into(x, m, t_now, round, 0, pool, scratch,
                                 out);
    }

    /// [`CrossbarGrid::vmm_batch_into`] with a **sample-base offset**:
    /// row `s` of the batch draws its read noise from the
    /// `(OP_VMM, tile, sample_base + s)` sub-stream.  Because every
    /// per-row quantity (noise segment, micro-kernel row, ADC) is
    /// computed independently of the other rows in the batch, output
    /// row `s` depends only on `(seed, round, sample_base + s)` — a
    /// batch of rows with globally unique ids is bit-equal to the
    /// concatenation of any other batching of the same rows at the
    /// same `round` (the serving scheduler's coalescing-invariance
    /// contract; `rust/tests/prop_serve_equivalence.rs`).
    /// `sample_base = 0` reproduces `vmm_batch_into` exactly.
    pub fn vmm_batch_base_into(&self, x: &[f32], m: usize, t_now: f32,
                               round: u64, sample_base: u64,
                               pool: &WorkerPool,
                               scratch: &mut GridScratch,
                               out: &mut [f32]) {
        let k = self.k();
        let n = self.n();
        assert_eq!(x.len(), m * k);
        assert_eq!(out.len(), m * n);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");

        let GridScratch { drift, shards, qin, .. } = scratch;

        // Phase 1: drift both conductance planes once per batch.
        self.drift_phase(t_now, pool, drift);

        // Hoisted input DAC: quantize the whole batch once (pure
        // function of x, value-identical to the per-strip conversions
        // it replaces).
        grow(qin, m * k);
        let dac = self.dac;
        for (q, &v) in qin[..m * k].iter_mut().zip(x) {
            *q = dac.convert(v);
        }

        let src = DenseRows { qin: &qin[..m * k], k };
        self.vmm_fwd_blocked(&src, m, round, sample_base, pool, drift,
                             shards, out);
    }

    /// Forward VMM fed by a [`PatchSource`] instead of a staged
    /// `[m, k]` input matrix — the weight-stationary streaming entry
    /// point of the conv lowering (`m` logical rows, `out: [m, n]`).
    /// Identical phase structure, shard decomposition, RNG streams and
    /// f32 op order to [`CrossbarGrid::vmm_batch_base_into`]; only
    /// where the quantized row segments come from changes, so a source
    /// that reproduces the staged values is **bit-identical** to
    /// staging (`rust/tests/prop_conv_equivalence.rs` pins this for
    /// the conv patch source).
    pub fn vmm_batch_src_into<S: PatchSource>(
        &self, src: &S, m: usize, t_now: f32, round: u64,
        sample_base: u64, pool: &WorkerPool, scratch: &mut GridScratch,
        out: &mut [f32]) {
        assert_eq!(out.len(), m * self.n());
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");
        let GridScratch { drift, shards, .. } = scratch;
        self.drift_phase(t_now, pool, drift);
        self.vmm_fwd_blocked(src, m, round, sample_base, pool, drift,
                             shards, out);
    }

    /// Phase 2 + gather of the blocked forward kernel, generic over the
    /// row-segment source (monomorphized, so the dense instantiation is
    /// the pre-streaming codegen).  Phase 1 (drift) must have run.
    fn vmm_fwd_blocked<S: PatchSource>(
        &self, src: &S, m: usize, round: u64, sample_base: u64,
        pool: &WorkerPool, drift: &[TileDrift],
        shards: &mut Vec<VmmShardScratch>, out: &mut [f32]) {
        let n = self.n();
        let tiles = &self.tiles;

        // Phase 2: tile-stationary sample-blocked strips
        // (shard = column strip × sample block).
        let block = self.sample_block.max(1);
        let nblocks = m.div_ceil(block);
        let grid_c = self.mapping.grid_cols();
        let grid_r = self.mapping.grid_rows();
        let nshards = grid_c * nblocks;
        if shards.len() < nshards {
            shards.resize_with(nshards, VmmShardScratch::new);
        }
        let seed = self.seed;
        let mapping = &self.mapping;
        let adc = self.adc;
        pool.run(&mut shards[..nshards], |sh, strip| {
            let c = sh / nblocks;
            let b = sh % nblocks;
            let s0 = b * block;
            let bs = block.min(m - s0);
            let strip_cols =
                mapping.tiles[mapping.tile_index(0, c)].used_cols;
            let VmmShardScratch { w, noise, rngs, out: sout, qbuf } =
                strip;
            grow(sout, bs * strip_cols);
            sout[..bs * strip_cols].fill(0.0);
            for gr in 0..grid_r {
                let ti = mapping.tile_index(gr, c);
                let tile = &tiles[ti];
                let (tr, tc) = (tile.rows(), tile.cols());
                let nt = tr * tc;
                let d = &drift[ti];
                let msb = &tile.weights.msb;
                // One fused Box–Muller pass draws the whole block's
                // read noise for this tile: an even 2·nt segment per
                // sample (G+ plane deviates first, then G−) from its
                // own (op, tile, sample) sub-stream.
                let noisy = msb.plus.params.read_noise
                    || msb.minus.params.read_noise;
                if noisy {
                    grow(noise, bs * 2 * nt);
                    rngs.clear();
                    rngs.extend((s0..s0 + bs).map(|s| {
                        op_sample_rng(seed, round, OP_VMM, ti,
                                      sample_base.wrapping_add(s as u64))
                    }));
                    fill_gaussian_block(rngs, 2 * nt,
                                        &mut noise[..bs * 2 * nt],
                                        0.0, 1.0);
                }
                grow(w, nt);
                if !noisy {
                    // Noise-free read: identical for every sample —
                    // materialize the plane once per (tile, shard).
                    read_noisy_weights_prefilled(msb, &d.gp, &d.gm,
                                                 &[], &mut w[..nt]);
                }
                grow(qbuf, tr);
                let (r0, _) = mapping.origin(&mapping.tiles[ti]);
                // [B, tr] × [tr, tc] micro-kernel: per sample a fresh
                // stochastic read, then row-major accumulation into
                // the running column sums.
                for i in 0..bs {
                    let s = s0 + i;
                    if noisy {
                        read_noisy_weights_prefilled(
                            msb, &d.gp, &d.gm,
                            &noise[i * 2 * nt..(i + 1) * 2 * nt],
                            &mut w[..nt]);
                    }
                    let xs = src.segment(s, r0, tr, qbuf);
                    let y = &mut sout
                        [i * strip_cols..(i + 1) * strip_cols];
                    for (r, &xv) in xs.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let row = &w[r * tc..(r + 1) * tc];
                        for (yc, &wc) in y.iter_mut().zip(row) {
                            *yc += xv * wc;
                        }
                    }
                }
            }
            // ADC once per logical column per sample, after the last
            // row-tile (digital accumulation at full precision across
            // row-tiles — the modeling choice that keeps the grid
            // bit-compatible with a whole-matrix single tile; a
            // per-row-tile ADC is a future knob).
            for yc in sout[..bs * strip_cols].iter_mut() {
                *yc = adc.convert(*yc);
            }
        });

        // Serial deterministic gather: shard outputs → logical [m, n].
        for (sh, strip) in shards[..nshards].iter().enumerate() {
            let c = sh / nblocks;
            let s0 = (sh % nblocks) * block;
            let bs = block.min(m - s0);
            let t0 = &self.mapping.tiles[self.mapping.tile_index(0, c)];
            let (_, c0) = self.mapping.origin(t0);
            let strip_cols = t0.used_cols;
            for i in 0..bs {
                let s = s0 + i;
                out[s * n + c0..s * n + c0 + strip_cols].copy_from_slice(
                    &strip.out[i * strip_cols..(i + 1) * strip_cols]);
            }
        }
    }

    /// Allocating wrapper of [`CrossbarGrid::vmm_batch_into`].
    pub fn vmm_batch(&self, x: &[f32], m: usize, t_now: f32, round: u64,
                     pool: &WorkerPool) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.n()];
        self.vmm_batch_into(x, m, t_now, round, pool, &mut scratch,
                            &mut out);
        out
    }

    /// Batched **transposed** analog VMM over the whole grid
    /// (`e: [m, n]` row-major logical error inputs, `out: [m, k]`) —
    /// the error-backpropagation kernel: the same crossbars are driven
    /// from their columns and read out on their rows, so
    /// `out = ADC(DAC(e) @ Wᵀ)` under the full device model (drift once
    /// per batch, fresh per-sample read noise per tile).
    /// Tile-stationary and sample-blocked like the forward kernel —
    /// shard = (row strip × sample block), per-(op, tile, sample)
    /// `OP_VMM_T` sub-streams; see the module docs.
    pub fn vmm_t_batch_into(&self, e: &[f32], m: usize, t_now: f32,
                            round: u64, pool: &WorkerPool,
                            scratch: &mut GridScratch, out: &mut [f32]) {
        let k = self.k();
        assert_eq!(out.len(), m * k);
        self.vmm_t_batch_with(e, m, t_now, round, pool, scratch, |res| {
            // The default drain is the logical gather: strip-major
            // disjoint row-segment copies into `[m, k]` — byte-equal
            // to gathering in shard enumeration order because every
            // (strip, sample) writes a distinct segment.
            for gr in 0..res.strips() {
                let (r0, rows) = res.strip_extent(gr);
                for s in 0..m {
                    out[s * k + r0..s * k + r0 + rows]
                        .copy_from_slice(res.row_segment(gr, s));
                }
            }
        });
    }

    /// Transposed batched VMM that hands its per-(strip, sample) ADC'd
    /// outputs to a caller-supplied `drain` **instead of** gathering
    /// them into a `[m, k]` matrix — the streaming backward entry point
    /// of the conv lowering, whose fused col2im scatter consumes the
    /// [`TvmmOut`] view directly so the `[m·P, k²·cin]` adjoint patch
    /// matrix never exists.  Phases 1–2 (drift, DAC hoist, sharded
    /// transposed micro-kernel, per-row ADC) are byte-identical to
    /// [`CrossbarGrid::vmm_t_batch_into`]; only what happens to the
    /// finished shard outputs differs.
    pub fn vmm_t_batch_with(&self, e: &[f32], m: usize, t_now: f32,
                            round: u64, pool: &WorkerPool,
                            scratch: &mut GridScratch,
                            drain: impl FnOnce(&TvmmOut)) {
        let n = self.n();
        assert_eq!(e.len(), m * n);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");

        let GridScratch { drift, shards, qin, .. } = scratch;
        let tiles = &self.tiles;

        // Phase 1: drift both conductance planes once per batch.
        self.drift_phase(t_now, pool, drift);

        // Hoisted error DAC (the backward twin of the forward hoist).
        grow(qin, m * n);
        let dac = self.dac;
        for (q, &v) in qin[..m * n].iter_mut().zip(e) {
            *q = dac.convert(v);
        }

        // Phase 2: tile-stationary sample-blocked row strips
        // (shard = row strip × sample block).
        let block = self.sample_block.max(1);
        let nblocks = m.div_ceil(block);
        let grid_c = self.mapping.grid_cols();
        let grid_r = self.mapping.grid_rows();
        let nshards = grid_r * nblocks;
        if shards.len() < nshards {
            shards.resize_with(nshards, VmmShardScratch::new);
        }
        let seed = self.seed;
        let mapping = &self.mapping;
        let adc = self.adc;
        let drift_ro: &[TileDrift] = &drift[..];
        let qin_ro: &[f32] = &qin[..m * n];
        pool.run(&mut shards[..nshards], |sh, strip| {
            let gr = sh / nblocks;
            let b = sh % nblocks;
            let s0 = b * block;
            let bs = block.min(m - s0);
            let strip_rows =
                mapping.tiles[mapping.tile_index(gr, 0)].used_rows;
            grow(&mut strip.out, bs * strip_rows);
            strip.out[..bs * strip_rows].fill(0.0);
            for gc in 0..grid_c {
                let ti = mapping.tile_index(gr, gc);
                let tile = &tiles[ti];
                let (tr, tc) = (tile.rows(), tile.cols());
                let nt = tr * tc;
                let d = &drift_ro[ti];
                let msb = &tile.weights.msb;
                let noisy = msb.plus.params.read_noise
                    || msb.minus.params.read_noise;
                if noisy {
                    grow(&mut strip.noise, bs * 2 * nt);
                    strip.rngs.clear();
                    strip.rngs.extend((s0..s0 + bs).map(|s| {
                        op_sample_rng(seed, round, OP_VMM_T, ti,
                                      s as u64)
                    }));
                    fill_gaussian_block(&mut strip.rngs, 2 * nt,
                                        &mut strip.noise[..bs * 2 * nt],
                                        0.0, 1.0);
                }
                grow(&mut strip.w, nt);
                if !noisy {
                    // Noise-free read: identical for every sample —
                    // materialize the plane once per (tile, shard).
                    read_noisy_weights_prefilled(msb, &d.gp, &d.gm,
                                                 &[],
                                                 &mut strip.w[..nt]);
                }
                let (_, c0) = mapping.origin(&mapping.tiles[ti]);
                debug_assert_eq!(tr, strip_rows);
                // Per output row the f32 term order is ascending
                // logical column (gc ascending, local c ascending) —
                // identical to a whole-matrix single tile, which keeps
                // the backward pass bit-compatible with the serial
                // path in the noise-free domain.
                for i in 0..bs {
                    let s = s0 + i;
                    if noisy {
                        read_noisy_weights_prefilled(
                            msb, &d.gp, &d.gm,
                            &strip.noise[i * 2 * nt..(i + 1) * 2 * nt],
                            &mut strip.w[..nt]);
                    }
                    let w = &strip.w[..nt];
                    let es = &qin_ro[s * n + c0..s * n + c0 + tc];
                    let y = &mut strip.out
                        [i * strip_rows..(i + 1) * strip_rows];
                    for (c, &ev) in es.iter().enumerate() {
                        if ev == 0.0 {
                            continue;
                        }
                        for (r, yr) in y.iter_mut().enumerate() {
                            *yr += ev * w[r * tc + c];
                        }
                    }
                }
            }
            // ADC once per logical row per sample, after the last
            // column-tile (mirroring the forward kernel's
            // once-per-column ADC).
            for yr in strip.out[..bs * strip_rows].iter_mut() {
                *yr = adc.convert(*yr);
            }
        });

        // Serial deterministic drain: the caller reads the finished
        // shard outputs through the read-only view (the gather of
        // `vmm_t_batch_into`, or the conv lowering's fused col2im
        // scatter).
        let res = TvmmOut {
            shards: &shards[..nshards],
            mapping: &self.mapping,
            block,
            nblocks,
        };
        drain(&res);
    }

    /// Allocating wrapper of [`CrossbarGrid::vmm_t_batch_into`].
    pub fn vmm_t_batch(&self, e: &[f32], m: usize, t_now: f32,
                       round: u64, pool: &WorkerPool) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.k()];
        self.vmm_t_batch_into(e, m, t_now, round, pool, &mut scratch,
                              &mut out);
        out
    }

    // -- sample-major reference kernels ------------------------------------

    /// The PR-4 **sample-major** forward kernel, retained as the bench
    /// baseline of the blocked-vs-sample-major comparison series and as
    /// a noise-free equivalence reference: one `op_rng` stream per
    /// column strip, per-sample re-draw of every tile's read noise
    /// through the streaming `read_noisy_weights`, per-(sample, tile)
    /// input DAC.  Noise streams differ from the blocked kernel by
    /// design; in the noise-free domain outputs are bit-identical.
    pub fn vmm_batch_sample_major_into(&self, x: &[f32], m: usize,
                                       t_now: f32, round: u64,
                                       pool: &WorkerPool,
                                       scratch: &mut GridScratch,
                                       out: &mut [f32]) {
        let k = self.k();
        let n = self.n();
        assert_eq!(x.len(), m * k);
        assert_eq!(out.len(), m * n);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");

        let GridScratch { drift, shards, .. } = scratch;
        let tiles = &self.tiles;
        self.drift_phase(t_now, pool, drift);

        let grid_c = self.mapping.grid_cols();
        let grid_r = self.mapping.grid_rows();
        if shards.len() < grid_c {
            shards.resize_with(grid_c, VmmShardScratch::new);
        }
        let seed = self.seed;
        let mapping = &self.mapping;
        let dac = self.dac;
        let adc = self.adc;
        let drift_ro: &[TileDrift] = &drift[..];
        pool.run(&mut shards[..grid_c], |c, strip| {
            let strip_cols =
                mapping.tiles[mapping.tile_index(0, c)].used_cols;
            grow(&mut strip.out, m * strip_cols);
            let mut rng = op_rng(seed, round, OP_VMM, c);
            for s in 0..m {
                let y = &mut strip.out
                    [s * strip_cols..(s + 1) * strip_cols];
                y.fill(0.0);
                for gr in 0..grid_r {
                    let ti = mapping.tile_index(gr, c);
                    let tile = &tiles[ti];
                    let (tr, tc) = (tile.rows(), tile.cols());
                    let nt = tr * tc;
                    let d = &drift_ro[ti];
                    grow(&mut strip.w, nt);
                    grow(&mut strip.noise, nt);
                    read_noisy_weights(&tile.weights.msb, &d.gp, &d.gm,
                                       &mut rng, &mut strip.noise[..nt],
                                       &mut strip.w[..nt]);
                    let (r0, _) = mapping.origin(&mapping.tiles[ti]);
                    let xs = &x[s * k + r0..s * k + r0 + tr];
                    grow(&mut strip.qbuf, tr);
                    for (q, &v) in strip.qbuf[..tr].iter_mut().zip(xs) {
                        *q = dac.convert(v);
                    }
                    let w = &strip.w[..nt];
                    for (r, &xv) in strip.qbuf[..tr].iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let row = &w[r * tc..(r + 1) * tc];
                        for (yc, &wc) in y.iter_mut().zip(row) {
                            *yc += xv * wc;
                        }
                    }
                }
                for yc in y.iter_mut() {
                    *yc = adc.convert(*yc);
                }
            }
        });

        for (c, strip) in shards[..grid_c].iter().enumerate() {
            let t0 = &self.mapping.tiles[self.mapping.tile_index(0, c)];
            let (_, c0) = self.mapping.origin(t0);
            let strip_cols = t0.used_cols;
            for s in 0..m {
                out[s * n + c0..s * n + c0 + strip_cols].copy_from_slice(
                    &strip.out[s * strip_cols..(s + 1) * strip_cols]);
            }
        }
    }

    /// The PR-4 **sample-major** transposed kernel (see
    /// [`CrossbarGrid::vmm_batch_sample_major_into`]): one `op_rng`
    /// stream per row strip, per-sample re-reads, per-(sample, tile)
    /// error DAC.
    pub fn vmm_t_batch_sample_major_into(&self, e: &[f32], m: usize,
                                         t_now: f32, round: u64,
                                         pool: &WorkerPool,
                                         scratch: &mut GridScratch,
                                         out: &mut [f32]) {
        let k = self.k();
        let n = self.n();
        assert_eq!(e.len(), m * n);
        assert_eq!(out.len(), m * k);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");

        let GridScratch { drift, shards, .. } = scratch;
        let tiles = &self.tiles;
        self.drift_phase(t_now, pool, drift);

        let grid_c = self.mapping.grid_cols();
        let grid_r = self.mapping.grid_rows();
        if shards.len() < grid_r {
            shards.resize_with(grid_r, VmmShardScratch::new);
        }
        let seed = self.seed;
        let mapping = &self.mapping;
        let dac = self.dac;
        let adc = self.adc;
        let drift_ro: &[TileDrift] = &drift[..];
        pool.run(&mut shards[..grid_r], |gr, strip| {
            let strip_rows =
                mapping.tiles[mapping.tile_index(gr, 0)].used_rows;
            grow(&mut strip.out, m * strip_rows);
            let mut rng = op_rng(seed, round, OP_VMM_T, gr);
            for s in 0..m {
                let y = &mut strip.out
                    [s * strip_rows..(s + 1) * strip_rows];
                y.fill(0.0);
                for gc in 0..grid_c {
                    let ti = mapping.tile_index(gr, gc);
                    let tile = &tiles[ti];
                    let (tr, tc) = (tile.rows(), tile.cols());
                    let nt = tr * tc;
                    let d = &drift_ro[ti];
                    grow(&mut strip.w, nt);
                    grow(&mut strip.noise, nt);
                    read_noisy_weights(&tile.weights.msb, &d.gp, &d.gm,
                                       &mut rng, &mut strip.noise[..nt],
                                       &mut strip.w[..nt]);
                    let (_, c0) = mapping.origin(&mapping.tiles[ti]);
                    let es = &e[s * n + c0..s * n + c0 + tc];
                    grow(&mut strip.qbuf, tc);
                    for (q, &v) in strip.qbuf[..tc].iter_mut().zip(es) {
                        *q = dac.convert(v);
                    }
                    debug_assert_eq!(tr, strip_rows);
                    let w = &strip.w[..nt];
                    for (c, &ev) in strip.qbuf[..tc].iter().enumerate() {
                        if ev == 0.0 {
                            continue;
                        }
                        for (r, yr) in y.iter_mut().enumerate() {
                            *yr += ev * w[r * tc + c];
                        }
                    }
                }
                for yr in y.iter_mut() {
                    *yr = adc.convert(*yr);
                }
            }
        });

        for (gr, strip) in shards[..grid_r].iter().enumerate() {
            let t0 = &self.mapping.tiles[self.mapping.tile_index(gr, 0)];
            let (r0, _) = self.mapping.origin(t0);
            let strip_rows = t0.used_rows;
            for s in 0..m {
                out[s * k + r0..s * k + r0 + strip_rows].copy_from_slice(
                    &strip.out[s * strip_rows..(s + 1) * strip_rows]);
            }
        }
    }

    // -- accounting --------------------------------------------------------

    /// Fold every tile's device activity into an endurance ledger
    /// (tile enumeration order).
    pub fn record_endurance(&self, ledger: &mut EnduranceLedger) {
        for t in &self.tiles {
            t.weights.record_endurance(ledger);
        }
    }

    /// Fold every tile's fault/degradation accounting into one
    /// [`crate::pcm::FaultMap`] (tile enumeration order) — stuck/worn
    /// populations from the fault planes plus the programming-failure,
    /// write-verify and remap event counters.
    pub fn fault_summary(&self) -> crate::pcm::FaultMap {
        let mut map = crate::pcm::FaultMap::default();
        for t in &self.tiles {
            map.merge(&t.weights.fault_map());
        }
        map
    }

    /// Inference model bits held by this grid (MSB arrays only — the
    /// hybrid representation's inference footprint, paper Fig. 4).
    pub fn inference_bits(&self) -> usize {
        self.tiles.iter().map(|t| t.weights.inference_bits()).sum()
    }

    /// Lifetime SET pulses across all tiles (G+ and G− planes).
    pub fn total_set_pulses(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| {
                let msb = &t.weights.msb;
                msb.plus.set_count.iter().sum::<u64>()
                    + msb.minus.set_count.iter().sum::<u64>()
            })
            .sum()
    }

    /// Read-only serving view of this grid (see [`GridView`]): the
    /// conductance planes are sealed behind a shared borrow — only the
    /// RNG-pure read kernels are reachable — and `gain` is the digital
    /// post-ADC calibration multiplier of the drift-compensated
    /// inference path (`serve::ModelSnapshot`).
    pub fn view(&self, gain: f32) -> GridView<'_> {
        GridView { grid: self, gain }
    }
}

/// A sealed, read-only view of a [`CrossbarGrid`] with a digital
/// calibration gain hook — the grid-level half of the serving
/// snapshot contract:
///
/// * the shared borrow makes mutation (programming, updates, refresh)
///   unrepresentable while the view is alive — the drift clock keeps
///   ticking through `t_now`, but the programmed state is frozen;
/// * `gain` multiplies every ADC output when (and only when) it is not
///   exactly `1.0`, so a freshly-frozen view (`gain == 1.0`) is
///   **bitwise identical** to the underlying grid's forward kernel,
///   and a recalibrated view applies one f32 multiply per output
///   element — the "global gain recalibration" compensation of
///   Joshi et al. 2019 as a pure post-processing stage.
pub struct GridView<'a> {
    pub grid: &'a CrossbarGrid,
    pub gain: f32,
}

impl GridView<'_> {
    /// Forward VMM through the sealed grid (sample-base offset as in
    /// [`CrossbarGrid::vmm_batch_base_into`]), then the calibration
    /// gain.  The gain multiply preserves the per-row independence
    /// contract: it is elementwise, so coalescing invariance carries
    /// over to calibrated serving unchanged.
    #[allow(clippy::too_many_arguments)]
    pub fn vmm_batch_base_into(&self, x: &[f32], m: usize, t_now: f32,
                               round: u64, sample_base: u64,
                               pool: &WorkerPool,
                               scratch: &mut GridScratch,
                               out: &mut [f32]) {
        self.grid.vmm_batch_base_into(x, m, t_now, round, sample_base,
                                      pool, scratch, out);
        if self.gain != 1.0 {
            for v in out[..m * self.grid.n()].iter_mut() {
                *v *= self.gain;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal_geom() -> HicGeometry {
        HicGeometry { stochastic_rounding: false, ..Default::default() }
    }

    fn pattern(k: usize, n: usize) -> Vec<f32> {
        (0..k * n)
            .map(|i| (((i * 3) % 13) as f32 - 6.0) / 8.0)
            .collect()
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 10, 7,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            DacSpec::default(), AdcSpec::default(), 9);
        assert_eq!(g.tile_count(), 3 * 3);
        let src = pattern(10, 7);
        let subs = g.scatter(&src);
        let mut back = vec![0.0f32; 10 * 7];
        g.gather(&subs, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn grid_decode_matches_programmed_pattern() {
        let pool = WorkerPool::serial();
        let mut g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 9, 5,
            TilingPolicy { tile_rows: 4, tile_cols: 2 },
            DacSpec::default(), AdcSpec::default(), 11);
        let w = pattern(9, 5);
        g.program_init(&w, 0.0, 0, &pool);
        let mut scratch = g.scratch();
        let mut got = vec![0.0f32; 9 * 5];
        g.drift_into(0.0, &pool, &mut scratch, &mut got);
        // Ideal linear devices: programmed to within one pulse quantum
        // through the conductance map.
        for (a, b) in w.iter().zip(&got) {
            assert!((a - b).abs() <= 0.13, "{a} vs {b}");
        }
    }

    fn noisy_grid() -> CrossbarGrid {
        let mut g = CrossbarGrid::new(
            PcmParams::default(), HicGeometry::default(), 12, 9,
            TilingPolicy { tile_rows: 5, tile_cols: 4 },
            DacSpec::default(), AdcSpec::default(), 21);
        g.program_init(&pattern(12, 9), 0.0, 7, &WorkerPool::serial());
        g
    }

    #[test]
    fn vmm_t_worker_invariant_smoke() {
        let g = noisy_grid();
        let m = 3;
        let e: Vec<f32> =
            (0..m * 9).map(|i| ((i % 7) as f32 - 3.0) / 4.0).collect();
        let y1 = g.vmm_t_batch(&e, m, 2.0, 5, &WorkerPool::new(1));
        let y2 = g.vmm_t_batch(&e, m, 2.0, 5, &WorkerPool::new(4));
        assert_eq!(y1, y2);
        assert_eq!(y1.len(), m * 12);
        // A different round draws different noise, and the forward op
        // stream is independent of the transposed one.
        let y3 = g.vmm_t_batch(&e, m, 2.0, 6, &WorkerPool::new(1));
        assert_ne!(y1, y3);
    }

    #[test]
    fn vmm_worker_invariant_smoke() {
        // Full noisy params: the parallel schedule must not change a bit.
        let g = noisy_grid();
        let m = 3;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let y1 = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(1));
        let y2 = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(4));
        assert_eq!(y1, y2);
        // A different round draws different noise.
        let y3 = g.vmm_batch(&x, m, 2.0, 6, &WorkerPool::new(1));
        assert_ne!(y1, y3);
    }

    #[test]
    fn vmm_block_size_invariant_smoke() {
        // The sample-block size is pure scheduling: any B produces the
        // same bits, in both VMM directions, at any worker count.
        let mut g = noisy_grid();
        let m = 5;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let e: Vec<f32> =
            (0..m * 9).map(|i| ((i % 7) as f32 - 3.0) / 4.0).collect();
        g.sample_block = 1;
        let y_fwd = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(2));
        let y_bwd = g.vmm_t_batch(&e, m, 2.0, 5, &WorkerPool::new(2));
        for b in [2usize, 3, 8, 64] {
            g.sample_block = b;
            for workers in [1usize, 4] {
                let pool = WorkerPool::new(workers);
                assert_eq!(g.vmm_batch(&x, m, 2.0, 5, &pool), y_fwd,
                           "fwd B={b} workers={workers}");
                assert_eq!(g.vmm_t_batch(&e, m, 2.0, 5, &pool), y_bwd,
                           "bwd B={b} workers={workers}");
            }
        }
    }

    #[test]
    fn sample_base_zero_matches_vmm_batch_and_offsets_reseed() {
        // base = 0 must reproduce vmm_batch_into bit for bit; a
        // nonzero base shifts every row onto a different noise
        // sub-stream; and a batch is the concatenation of its rows run
        // one at a time with the same global ids (the serving
        // coalescing contract).
        let g = noisy_grid();
        let m = 4;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let pool = WorkerPool::new(2);
        let mut scratch = g.scratch();
        let base = vec![0.0f32; m * 9];
        let mut a = base.clone();
        let mut b = base.clone();
        g.vmm_batch_into(&x, m, 2.0, 5, &pool, &mut scratch, &mut a);
        g.vmm_batch_base_into(&x, m, 2.0, 5, 0, &pool, &mut scratch,
                              &mut b);
        assert_eq!(a, b);
        let mut c = base.clone();
        g.vmm_batch_base_into(&x, m, 2.0, 5, 100, &pool, &mut scratch,
                              &mut c);
        assert_ne!(a, c);
        // Row r of the offset batch == a single-sample run at
        // sample_base = 100 + r.
        for r in 0..m {
            let mut row = vec![0.0f32; 9];
            g.vmm_batch_base_into(&x[r * 12..(r + 1) * 12], 1, 2.0, 5,
                                  100 + r as u64, &pool, &mut scratch,
                                  &mut row);
            assert_eq!(&c[r * 9..(r + 1) * 9], &row[..], "row {r}");
        }
    }

    #[test]
    fn patch_source_matches_staged_input_noisy() {
        // A generating PatchSource that reproduces the staged DAC'd
        // values is bit-identical to the dense staged path — with full
        // read noise on, so the RNG stream assignment is pinned too.
        struct CopySrc<'a> {
            qin: &'a [f32],
            k: usize,
        }
        impl PatchSource for CopySrc<'_> {
            fn segment<'a>(&'a self, s: usize, r0: usize, len: usize,
                           buf: &'a mut [f32]) -> &'a [f32] {
                buf[..len].copy_from_slice(
                    &self.qin[s * self.k + r0..s * self.k + r0 + len]);
                &buf[..len]
            }
        }
        let g = noisy_grid();
        let m = 4;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let qin: Vec<f32> =
            x.iter().map(|&v| g.dac.convert(v)).collect();
        let src = CopySrc { qin: &qin, k: 12 };
        for workers in [1usize, 4] {
            let pool = WorkerPool::new(workers);
            let mut scratch = g.scratch();
            let mut a = vec![0.0f32; m * 9];
            let mut b = vec![0.0f32; m * 9];
            g.vmm_batch_base_into(&x, m, 2.0, 5, 7, &pool,
                                  &mut scratch, &mut a);
            g.vmm_batch_src_into(&src, m, 2.0, 5, 7, &pool,
                                 &mut scratch, &mut b);
            assert_eq!(a, b, "workers={workers}");
        }
    }

    #[test]
    fn tvmm_drain_view_matches_gather() {
        // Reconstructing [m, k] from the TvmmOut view — in a different
        // iteration order than the built-in gather — produces the same
        // bytes: the view exposes finished per-(strip, sample)
        // segments, so drain order cannot matter.
        let g = noisy_grid();
        let m = 5;
        let e: Vec<f32> =
            (0..m * 9).map(|i| ((i % 7) as f32 - 3.0) / 4.0).collect();
        let pool = WorkerPool::new(4);
        let mut scratch = g.scratch();
        let mut at = vec![0.0f32; m * 12];
        g.vmm_t_batch_into(&e, m, 2.0, 3, &pool, &mut scratch, &mut at);
        let mut bt = vec![0.0f32; m * 12];
        g.vmm_t_batch_with(&e, m, 2.0, 3, &pool, &mut scratch, |res| {
            for s in (0..m).rev() {
                for gr in (0..res.strips()).rev() {
                    let (r0, rows) = res.strip_extent(gr);
                    bt[s * 12 + r0..s * 12 + r0 + rows]
                        .copy_from_slice(res.row_segment(gr, s));
                }
            }
        });
        assert_eq!(at, bt);
    }

    #[test]
    fn grid_view_gain_hook() {
        // gain == 1.0 is bitwise transparent; any other gain is one
        // f32 multiply per output element.
        let g = noisy_grid();
        let m = 3;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let pool = WorkerPool::serial();
        let mut scratch = g.scratch();
        let mut raw = vec![0.0f32; m * 9];
        g.vmm_batch_base_into(&x, m, 2.0, 5, 7, &pool, &mut scratch,
                              &mut raw);
        let mut a = vec![0.0f32; m * 9];
        g.view(1.0).vmm_batch_base_into(&x, m, 2.0, 5, 7, &pool,
                                        &mut scratch, &mut a);
        assert_eq!(a, raw);
        let mut b = vec![0.0f32; m * 9];
        g.view(1.25).vmm_batch_base_into(&x, m, 2.0, 5, 7, &pool,
                                         &mut scratch, &mut b);
        let want: Vec<f32> = raw.iter().map(|&v| v * 1.25).collect();
        assert_eq!(b, want);
    }

    #[test]
    fn sample_major_reference_matches_blocked_noise_free() {
        // With read noise off neither kernel consumes RNG, so the
        // retained PR-4 reference and the blocked kernel agree bit for
        // bit in both directions.
        let params = PcmParams {
            nonlinear: false,
            write_noise: false,
            read_noise: false,
            drift: true,
            drift_nu_sigma: 0.0,
            ..Default::default()
        };
        let mut g = CrossbarGrid::new(
            params, ideal_geom(), 11, 7,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            DacSpec::default(), AdcSpec::default(), 13);
        let pool = WorkerPool::new(4);
        g.program_init(&pattern(11, 7), 0.0, 0, &pool);
        let mut scratch = g.scratch();
        let m = 4;
        let x: Vec<f32> =
            (0..m * 11).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let e: Vec<f32> =
            (0..m * 7).map(|i| ((i % 5) as f32 - 2.0) / 3.0).collect();
        let mut a = vec![0.0f32; m * 7];
        let mut b = vec![0.0f32; m * 7];
        g.vmm_batch_into(&x, m, 2.0, 3, &pool, &mut scratch, &mut a);
        g.vmm_batch_sample_major_into(&x, m, 2.0, 3, &pool,
                                      &mut scratch, &mut b);
        assert_eq!(a, b);
        let mut at = vec![0.0f32; m * 11];
        let mut bt = vec![0.0f32; m * 11];
        g.vmm_t_batch_into(&e, m, 2.0, 3, &pool, &mut scratch, &mut at);
        g.vmm_t_batch_sample_major_into(&e, m, 2.0, 3, &pool,
                                        &mut scratch, &mut bt);
        assert_eq!(at, bt);
    }

    #[test]
    fn update_item_matches_apply_update_bitwise() {
        // The enqueueable work item must replay apply_update exactly:
        // same per-tile streams, same tile order, same overflow total.
        let run_item = |via_item: bool| {
            let mut g = noisy_grid();
            let mut scratch = g.scratch();
            let grad: Vec<f32> = (0..12 * 9)
                .map(|i| (((i * 7) % 11) as f32 - 5.0) / 20.0)
                .collect();
            let ovf = if via_item {
                g.update_item(&grad, 0.3, 1.5, 4, &mut scratch).run()
            } else {
                g.apply_update(&grad, 0.3, 1.5, 4,
                               &WorkerPool::serial(), &mut scratch)
            };
            let mut w = vec![0.0f32; 12 * 9];
            g.drift_into(1.5, &WorkerPool::serial(), &mut scratch,
                         &mut w);
            (ovf, w, g.total_set_pulses())
        };
        assert_eq!(run_item(true), run_item(false));
    }

    #[test]
    fn sample_block_auto_tune_tracks_tile_footprint() {
        // Small tiles fit many samples in the budget; giant tiles fall
        // to the floor — and the chosen block is always in [2, 64].
        assert_eq!(sample_block_for(8, 8), MAX_SAMPLE_BLOCK);
        assert_eq!(sample_block_for(32, 32), 16);
        assert_eq!(sample_block_for(256, 256), 2);
        let mut prev = usize::MAX;
        for t in [4usize, 16, 32, 64, 128, 512] {
            let b = sample_block_for(t, t);
            assert!((2..=MAX_SAMPLE_BLOCK).contains(&b));
            assert!(b <= prev, "block must shrink with tile size");
            prev = b;
        }
        // The grid picks its block from its *largest* tile extent.
        let g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 10, 7,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            DacSpec::default(), AdcSpec::default(), 9);
        if std::env::var("HIC_SAMPLE_BLOCK").is_err() {
            assert_eq!(g.sample_block, sample_block_for(4, 3));
        }
    }

    #[test]
    fn total_set_pulses_counts_programming() {
        let pool = WorkerPool::serial();
        let mut g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 4, 4,
            TilingPolicy { tile_rows: 2, tile_cols: 2 },
            DacSpec::default(), AdcSpec::default(), 3);
        assert_eq!(g.total_set_pulses(), 0);
        let mut scratch = g.scratch();
        let dw = vec![0.25f32; 16];
        let pulses = g.program_increments(&dw, 0.0, 1, &pool, &mut scratch);
        assert!(pulses > 0);
        assert_eq!(pulses, g.total_set_pulses());
        let mut ledger = EnduranceLedger::new();
        g.record_endurance(&mut ledger);
        assert_eq!(ledger.msb.count as usize, 2 * 16);
    }
}
