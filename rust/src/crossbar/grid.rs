//! Sharded multi-tile crossbar engine.
//!
//! [`CrossbarGrid`] maps one logical `[k, n]` weight matrix onto the
//! R×C tile grid computed by [`mapper::LayerMapping`] and runs the
//! device kernels — batched VMM, increment programming, training
//! updates, drift decode, saturation refresh — **tile-parallel** on a
//! [`WorkerPool`].  This converts the PR-1 planar data layout into
//! wall-clock scaling: every tile's planes are independent, exactly the
//! per-tile independence the paper's accelerator (and the
//! mixed-precision trainers it builds on) exploits.
//!
//! # Sharding scheme
//!
//! * **State kernels** (`program_init`, `program_increments`,
//!   `apply_update`, `refresh`): one shard per tile.  Each shard owns
//!   its tile's planes, so shards never alias; integer side-totals
//!   (pulses, overflows, refresh counts) fold through an atomic adder
//!   (exact: `u64` addition is commutative).
//! * **`vmm_batch_into`** (forward): two phases.  Phase 1 evaluates
//!   drift once per batch, one shard per tile.  Phase 2 shards by
//!   **column strip** (all tiles of one grid column): a strip owns a
//!   disjoint slice of output columns, walks its row-tiles top-down per
//!   sample accumulating partial sums into the same running output, and
//!   applies the ADC once per logical column after the last row-tile.
//!   Row-tiles accumulating *into* the running sum (instead of
//!   reducing independent partials) keeps the f32 addition sequence
//!   identical to a single tile spanning the whole matrix — which is
//!   what makes the grid bit-compatible with the serial single-tile
//!   path in the noise-free domain.
//! * **`vmm_t_batch_into`** (transposed, the error-backpropagation
//!   pass): the mirror image.  Phase 1 is the same per-tile drift
//!   evaluation; phase 2 shards by **row strip** (all tiles of one grid
//!   row): a strip owns a disjoint slice of output *rows*, walks its
//!   column-tiles left-to-right per sample accumulating the transposed
//!   partial sums into the running row outputs, and applies the ADC
//!   once per logical row after the last column-tile.  Per output row
//!   the f32 term order is ascending logical column — identical to a
//!   whole-matrix single tile's `vmm_t_batch_into`, so the noise-free
//!   bit-compatibility contract extends to the backward pass.
//! * **`drift_into`**: one shard per tile, serial deterministic gather.
//!
//! # RNG stream discipline
//!
//! Shards never share a generator.  Every kernel invocation derives one
//! counter-based stream per shard:
//! `Pcg64::new(seed ⊕ round·φ, (op_tag << 32) | shard_id)` — `seed` is
//! the grid's, `round` is a caller-supplied invocation counter (training
//! step, probe index, …), `op_tag` separates kernel families, and
//! `shard_id` is the tile index (state kernels), the grid column
//! (forward VMM) or the grid **row** (transposed VMM — its own
//! `OP_VMM_T` op stream, so a forward and a backward pass at the same
//! `round` draw independent read noise).  Reusing a `(seed, round, op)`
//! triple replays the same noise, so callers advance `round` between
//! invocations.  Because a shard's stream depends only on these values
//! — never on the worker that runs it — **all grid kernels are bitwise
//! identical for any worker count**;
//! `rust/tests/prop_parallel_equivalence.rs` pins this, and the
//! noise-free equivalence against the single-tile serial path.
//!
//! Read noise inside both VMM kernels uses the shared noisy-weight-read
//! helper (`crossbar::tile::read_noisy_weights`: batched Box–Muller
//! fill, G+ plane first then G−), the same sequence as
//! `CrossbarTile::vmm_batch_into`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::hic::weight::{HicGeometry, HicWeight};
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

use super::mapper::{LayerMapping, TilingPolicy};
use super::quant::{AdcSpec, DacSpec};
use super::tile::{read_noisy_weights, CrossbarTile};

/// Kernel-family tags baked into the high bits of each shard's RNG
/// stream id (see the module docs).
pub const OP_INIT: u64 = 1;
pub const OP_PROGRAM: u64 = 2;
pub const OP_UPDATE: u64 = 3;
pub const OP_VMM: u64 = 4;
pub const OP_REFRESH: u64 = 5;
pub const OP_PROGRAM_INIT: u64 = 6;
pub const OP_VMM_T: u64 = 7;

/// Weyl constant mixing the invocation counter into the stream seed.
const ROUND_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// The per-shard generator: counter-based, scheduling-independent.
#[inline]
pub fn op_rng(seed: u64, round: u64, op: u64, shard: usize) -> Pcg64 {
    Pcg64::new(seed ^ round.wrapping_mul(ROUND_MIX),
               (op << 32) | shard as u64)
}

/// One logical weight matrix sharded onto an R×C grid of
/// [`CrossbarTile`]s (edge tiles sized to their used extent, so the
/// grid holds exactly `k·n` weight cells).
pub struct CrossbarGrid {
    pub mapping: LayerMapping,
    /// Row-major tile grid (`mapping.tile_index` addressing).
    pub tiles: Vec<CrossbarTile>,
    pub dac: DacSpec,
    pub adc: AdcSpec,
    pub seed: u64,
}

/// Per-tile drifted-conductance planes (valid for one `t_now`).
struct TileDrift {
    gp: Vec<f32>,
    gm: Vec<f32>,
}

/// Per-column-strip working buffers for the forward VMM shards.
struct StripScratch {
    w: Vec<f32>,
    noise: Vec<f32>,
    xq: Vec<f32>,
    out: Vec<f32>,
}

/// Per-row-strip working buffers for the transposed VMM shards.
struct RowStripScratch {
    w: Vec<f32>,
    noise: Vec<f32>,
    eq: Vec<f32>,
    out: Vec<f32>,
}

/// Reusable grid buffers: drift planes per tile, forward column-strip
/// and transposed row-strip scratch, plus the per-tile scatter buffers
/// the state kernels (`program_increments` / `apply_update`) and
/// `drift_into` reuse — with a long-lived `GridScratch`, none of the
/// training-loop kernels allocate per call.
pub struct GridScratch {
    drift: Vec<TileDrift>,
    strips: Vec<StripScratch>,
    rstrips: Vec<RowStripScratch>,
    /// per-tile row-major submatrix buffers (scatter targets for the
    /// state kernels, decode targets for `drift_into` — tiles are
    /// sized to their used extent, so one buffer serves both roles)
    subs: Vec<Vec<f32>>,
}

impl CrossbarGrid {
    /// Build the grid: tiles are constructed in row-major order, each
    /// from its own `(seed, OP_INIT, tile)` stream, so construction is
    /// deterministic and independent of tile count elsewhere.
    pub fn new(params: PcmParams, geom: HicGeometry, k: usize, n: usize,
               policy: TilingPolicy, dac: DacSpec, adc: AdcSpec,
               seed: u64) -> Self {
        let mapping = LayerMapping::new("grid", k, n, policy);
        let mut tiles = Vec::with_capacity(mapping.tile_count());
        for (ti, t) in mapping.tiles.iter().enumerate() {
            let mut rng = op_rng(seed, 0, OP_INIT, ti);
            let hw = HicWeight::new(params, geom, t.used_rows,
                                    t.used_cols, &mut rng);
            tiles.push(CrossbarTile::new(hw, dac, adc));
        }
        CrossbarGrid { mapping, tiles, dac, adc, seed }
    }

    pub fn k(&self) -> usize {
        self.mapping.k
    }

    pub fn n(&self) -> usize {
        self.mapping.n
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Tile at grid coordinate `(gr, gc)`.
    pub fn tile(&self, gr: usize, gc: usize) -> &CrossbarTile {
        &self.tiles[self.mapping.tile_index(gr, gc)]
    }

    /// Allocate reusable buffers sized for this grid.
    pub fn scratch(&self) -> GridScratch {
        let drift = self
            .tiles
            .iter()
            .map(|t| {
                let nt = t.rows() * t.cols();
                TileDrift { gp: vec![0.0; nt], gm: vec![0.0; nt] }
            })
            .collect();
        let tr_max = self.mapping.policy.tile_rows.min(self.mapping.k);
        let mut strips = Vec::with_capacity(self.mapping.grid_cols());
        for c in 0..self.mapping.grid_cols() {
            let strip_cols =
                self.mapping.tiles[self.mapping.tile_index(0, c)].used_cols;
            let nmax = tr_max * strip_cols;
            strips.push(StripScratch {
                w: vec![0.0; nmax],
                noise: vec![0.0; nmax],
                xq: vec![0.0; tr_max],
                out: Vec::new(),
            });
        }
        let tc_max = self.mapping.policy.tile_cols.min(self.mapping.n);
        let mut rstrips = Vec::with_capacity(self.mapping.grid_rows());
        for r in 0..self.mapping.grid_rows() {
            let strip_rows =
                self.mapping.tiles[self.mapping.tile_index(r, 0)].used_rows;
            let nmax = strip_rows * tc_max;
            rstrips.push(RowStripScratch {
                w: vec![0.0; nmax],
                noise: vec![0.0; nmax],
                eq: vec![0.0; tc_max],
                out: Vec::new(),
            });
        }
        let subs = self
            .tiles
            .iter()
            .map(|t| vec![0.0f32; t.rows() * t.cols()])
            .collect();
        GridScratch { drift, strips, rstrips, subs }
    }

    // -- logical <-> tile layout ------------------------------------------

    /// Split a logical row-major `[k, n]` matrix into per-tile
    /// row-major submatrices (tile enumeration order) — allocating
    /// wrapper of [`CrossbarGrid::scatter_into`], used where no scratch
    /// is alive yet (construction-time programming).
    fn scatter(&self, src: &[f32]) -> Vec<Vec<f32>> {
        let mut subs: Vec<Vec<f32>> = self
            .mapping
            .tiles
            .iter()
            .map(|t| vec![0.0f32; t.used_rows * t.used_cols])
            .collect();
        self.scatter_into(src, &mut subs);
        subs
    }

    /// Split a logical row-major `[k, n]` matrix into the caller's
    /// per-tile buffers (tile enumeration order, no allocation).
    fn scatter_into(&self, src: &[f32], subs: &mut [Vec<f32>]) {
        assert_eq!(src.len(), self.k() * self.n());
        assert_eq!(subs.len(), self.tiles.len());
        let n = self.n();
        for (t, sub) in self.mapping.tiles.iter().zip(subs) {
            let (r0, c0) = self.mapping.origin(t);
            assert_eq!(sub.len(), t.used_rows * t.used_cols);
            for r in 0..t.used_rows {
                let src_row = (r0 + r) * n + c0;
                sub[r * t.used_cols..(r + 1) * t.used_cols]
                    .copy_from_slice(&src[src_row..src_row + t.used_cols]);
            }
        }
    }

    /// Gather per-tile row-major buffers back into the logical matrix.
    fn gather(&self, bufs: &[Vec<f32>], out: &mut [f32]) {
        assert_eq!(out.len(), self.k() * self.n());
        let n = self.n();
        for (t, buf) in self.mapping.tiles.iter().zip(bufs) {
            let (r0, c0) = self.mapping.origin(t);
            for r in 0..t.used_rows {
                let dst_row = (r0 + r) * n + c0;
                out[dst_row..dst_row + t.used_cols].copy_from_slice(
                    &buf[r * t.used_cols..(r + 1) * t.used_cols]);
            }
        }
    }

    // -- state kernels (shard = tile) -------------------------------------

    /// Program initial weights (MSB-quantized), tile-parallel.  Uses
    /// its own op tag (`OP_PROGRAM_INIT`), so an init followed by a
    /// `program_increments` at the same `round` still draws
    /// independent write-noise streams.  (Construction-time path: the
    /// one state kernel that allocates its scatter buffers itself, so
    /// it can run before any `GridScratch` exists.)
    pub fn program_init(&mut self, w: &[f32], t_now: f32, round: u64,
                        pool: &WorkerPool) {
        let subs = self.scatter(w);
        let seed = self.seed;
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_PROGRAM_INIT, ti);
            tile.weights.program_init(&subs[ti], t_now, &mut rng);
        });
    }

    /// Apply signed per-weight increments (`dw` logical `[k, n]`,
    /// zeros untouched) through the differential pairs, tile-parallel.
    /// Returns total SET pulses applied.
    pub fn program_increments(&mut self, dw: &[f32], t_now: f32,
                              round: u64, pool: &WorkerPool,
                              scratch: &mut GridScratch) -> u64 {
        self.scatter_into(dw, &mut scratch.subs);
        let subs: &[Vec<f32>] = &scratch.subs;
        let seed = self.seed;
        let total = AtomicU64::new(0);
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_PROGRAM, ti);
            let mut pulses = 0u64;
            for (i, &d) in subs[ti].iter().enumerate() {
                if d != 0.0 {
                    pulses += tile.weights.msb.apply_increment(
                        i, d, t_now, &mut rng) as u64;
                }
            }
            total.fetch_add(pulses, Ordering::Relaxed);
        });
        total.into_inner()
    }

    /// One hybrid training update (`grad` logical `[k, n]`),
    /// tile-parallel; returns total LSB→MSB overflow events.
    pub fn apply_update(&mut self, grad: &[f32], lr: f32, t_now: f32,
                        round: u64, pool: &WorkerPool,
                        scratch: &mut GridScratch) -> usize {
        self.scatter_into(grad, &mut scratch.subs);
        let subs: &[Vec<f32>] = &scratch.subs;
        let seed = self.seed;
        let total = AtomicU64::new(0);
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_UPDATE, ti);
            let ovf = tile.weights.apply_update(
                &subs[ti], lr, t_now, &mut rng) as u64;
            total.fetch_add(ovf, Ordering::Relaxed);
        });
        total.into_inner() as usize
    }

    /// Selective saturation refresh, tile-parallel; returns refreshed
    /// pair count.
    pub fn refresh(&mut self, t_now: f32, round: u64,
                   pool: &WorkerPool) -> usize {
        let seed = self.seed;
        let total = AtomicU64::new(0);
        pool.run(&mut self.tiles, |ti, tile| {
            let mut rng = op_rng(seed, round, OP_REFRESH, ti);
            let n = tile.weights.refresh(t_now, &mut rng) as u64;
            total.fetch_add(n, Ordering::Relaxed);
        });
        total.into_inner() as usize
    }

    // -- read kernels ------------------------------------------------------

    /// Drift-evaluated decode of the logical weight matrix at `t_now`
    /// (no read noise) — the grid twin of `DifferentialPair::decode_into`
    /// with the drift power law evaluated tile-parallel into the
    /// scratch's per-tile buffers (no allocation), then a serial
    /// deterministic gather.
    pub fn drift_into(&self, t_now: f32, pool: &WorkerPool,
                      scratch: &mut GridScratch, out: &mut [f32]) {
        let tiles = &self.tiles;
        pool.run(&mut scratch.subs, |ti, buf| {
            tiles[ti].weights.decode_into(t_now, buf);
        });
        self.gather(&scratch.subs, out);
    }

    /// Batched analog VMM over the whole grid (`x: [m, k]` row-major
    /// logical inputs, `out: [m, n]`), drift once per batch, fresh
    /// per-sample read noise per tile.  See the module docs for the
    /// sharding and RNG scheme.
    pub fn vmm_batch_into(&self, x: &[f32], m: usize, t_now: f32,
                          round: u64, pool: &WorkerPool,
                          scratch: &mut GridScratch, out: &mut [f32]) {
        let k = self.k();
        let n = self.n();
        assert_eq!(x.len(), m * k);
        assert_eq!(out.len(), m * n);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");
        assert_eq!(scratch.strips.len(), self.mapping.grid_cols());

        let GridScratch { drift, strips, .. } = scratch;
        let tiles = &self.tiles;

        // Phase 1: drift both conductance planes once per batch,
        // tile-parallel (no RNG).
        pool.run(&mut drift[..], |ti, d| {
            let msb = &tiles[ti].weights.msb;
            msb.plus.drift_into(t_now, &mut d.gp);
            msb.minus.drift_into(t_now, &mut d.gm);
        });

        // Phase 2: column strips (shard = grid column).
        let grid_r = self.mapping.grid_rows();
        let seed = self.seed;
        let mapping = &self.mapping;
        let dac = self.dac;
        let adc = self.adc;
        let drift_ro: &[TileDrift] = &drift[..];
        pool.run(&mut strips[..], |c, strip| {
            let strip_cols =
                mapping.tiles[mapping.tile_index(0, c)].used_cols;
            let need = m * strip_cols;
            if strip.out.len() < need {
                strip.out.resize(need, 0.0);
            }
            let mut rng = op_rng(seed, round, OP_VMM, c);
            for s in 0..m {
                let y = &mut strip.out
                    [s * strip_cols..(s + 1) * strip_cols];
                y.fill(0.0);
                for gr in 0..grid_r {
                    let ti = mapping.tile_index(gr, c);
                    let tile = &tiles[ti];
                    let (tr, tc) = (tile.rows(), tile.cols());
                    let nt = tr * tc;
                    let d = &drift_ro[ti];

                    // Fresh stochastic read of this tile (shared
                    // sequence: G+ plane first, then G−).
                    read_noisy_weights(&tile.weights.msb, &d.gp, &d.gm,
                                       &mut rng, &mut strip.noise[..nt],
                                       &mut strip.w[..nt]);
                    let w = &strip.w[..nt];

                    // DAC this row block's inputs, accumulate row-major
                    // into the running column sums.
                    let (r0, _) = mapping.origin(&mapping.tiles[ti]);
                    let xs = &x[s * k + r0..s * k + r0 + tr];
                    let xq = &mut strip.xq[..tr];
                    for (q, &v) in xq.iter_mut().zip(xs) {
                        *q = dac.convert(v);
                    }
                    for (r, &xv) in xq.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let row = &w[r * tc..(r + 1) * tc];
                        for (yc, &wc) in y.iter_mut().zip(row) {
                            *yc += xv * wc;
                        }
                    }
                }
                // ADC once per logical column, after the last row-tile
                // (digital accumulation at full precision across
                // row-tiles — the modeling choice that keeps the grid
                // bit-compatible with a whole-matrix single tile; a
                // per-row-tile ADC is a future knob).
                for yc in y.iter_mut() {
                    *yc = adc.convert(*yc);
                }
            }
        });

        // Serial deterministic gather: strip outputs → logical [m, n].
        for (c, strip) in strips.iter().enumerate() {
            let t0 = &self.mapping.tiles[self.mapping.tile_index(0, c)];
            let (_, c0) = self.mapping.origin(t0);
            let strip_cols = t0.used_cols;
            for s in 0..m {
                out[s * n + c0..s * n + c0 + strip_cols].copy_from_slice(
                    &strip.out[s * strip_cols..(s + 1) * strip_cols]);
            }
        }
    }

    /// Allocating wrapper of [`CrossbarGrid::vmm_batch_into`].
    pub fn vmm_batch(&self, x: &[f32], m: usize, t_now: f32, round: u64,
                     pool: &WorkerPool) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.n()];
        self.vmm_batch_into(x, m, t_now, round, pool, &mut scratch,
                            &mut out);
        out
    }

    /// Batched **transposed** analog VMM over the whole grid
    /// (`e: [m, n]` row-major logical error inputs, `out: [m, k]`) —
    /// the error-backpropagation kernel: the same crossbars are driven
    /// from their columns and read out on their rows, so
    /// `out = ADC(DAC(e) @ Wᵀ)` under the full device model (drift once
    /// per batch, fresh per-sample read noise per tile).  Sharded by
    /// **row strip** on its own `OP_VMM_T` RNG op stream (shard id =
    /// grid row); see the module docs for the determinism contract.
    pub fn vmm_t_batch_into(&self, e: &[f32], m: usize, t_now: f32,
                            round: u64, pool: &WorkerPool,
                            scratch: &mut GridScratch, out: &mut [f32]) {
        let k = self.k();
        let n = self.n();
        assert_eq!(e.len(), m * n);
        assert_eq!(out.len(), m * k);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");
        assert_eq!(scratch.rstrips.len(), self.mapping.grid_rows());

        let GridScratch { drift, rstrips, .. } = scratch;
        let tiles = &self.tiles;

        // Phase 1: drift both conductance planes once per batch,
        // tile-parallel (no RNG) — same pass as the forward kernel.
        pool.run(&mut drift[..], |ti, d| {
            let msb = &tiles[ti].weights.msb;
            msb.plus.drift_into(t_now, &mut d.gp);
            msb.minus.drift_into(t_now, &mut d.gm);
        });

        // Phase 2: row strips (shard = grid row).
        let grid_c = self.mapping.grid_cols();
        let seed = self.seed;
        let mapping = &self.mapping;
        let dac = self.dac;
        let adc = self.adc;
        let drift_ro: &[TileDrift] = &drift[..];
        pool.run(&mut rstrips[..], |gr, strip| {
            let strip_rows =
                mapping.tiles[mapping.tile_index(gr, 0)].used_rows;
            let need = m * strip_rows;
            if strip.out.len() < need {
                strip.out.resize(need, 0.0);
            }
            let mut rng = op_rng(seed, round, OP_VMM_T, gr);
            for s in 0..m {
                let y = &mut strip.out
                    [s * strip_rows..(s + 1) * strip_rows];
                y.fill(0.0);
                for gc in 0..grid_c {
                    let ti = mapping.tile_index(gr, gc);
                    let tile = &tiles[ti];
                    let (tr, tc) = (tile.rows(), tile.cols());
                    let nt = tr * tc;
                    let d = &drift_ro[ti];

                    // Fresh stochastic read of this tile (shared
                    // sequence: G+ plane first, then G−).
                    read_noisy_weights(&tile.weights.msb, &d.gp, &d.gm,
                                       &mut rng, &mut strip.noise[..nt],
                                       &mut strip.w[..nt]);
                    let w = &strip.w[..nt];

                    // DAC this column block's errors, accumulate the
                    // transposed partial sums into the running row
                    // outputs.  Per output row the term order is
                    // ascending logical column (gc ascending, local c
                    // ascending) — identical to a whole-matrix single
                    // tile, which keeps the backward pass
                    // bit-compatible with the serial path in the
                    // noise-free domain.
                    let (_, c0) = mapping.origin(&mapping.tiles[ti]);
                    let es = &e[s * n + c0..s * n + c0 + tc];
                    let eq = &mut strip.eq[..tc];
                    for (q, &v) in eq.iter_mut().zip(es) {
                        *q = dac.convert(v);
                    }
                    debug_assert_eq!(tr, strip_rows);
                    for (c, &ev) in eq.iter().enumerate() {
                        if ev == 0.0 {
                            continue;
                        }
                        for (r, yr) in y.iter_mut().enumerate() {
                            *yr += ev * w[r * tc + c];
                        }
                    }
                }
                // ADC once per logical row, after the last column-tile
                // (digital accumulation at full precision across
                // column-tiles, mirroring the forward kernel's
                // once-per-column ADC).
                for yr in y.iter_mut() {
                    *yr = adc.convert(*yr);
                }
            }
        });

        // Serial deterministic gather: strip outputs → logical [m, k].
        for (gr, strip) in rstrips.iter().enumerate() {
            let t0 = &self.mapping.tiles[self.mapping.tile_index(gr, 0)];
            let (r0, _) = self.mapping.origin(t0);
            let strip_rows = t0.used_rows;
            for s in 0..m {
                out[s * k + r0..s * k + r0 + strip_rows].copy_from_slice(
                    &strip.out[s * strip_rows..(s + 1) * strip_rows]);
            }
        }
    }

    /// Allocating wrapper of [`CrossbarGrid::vmm_t_batch_into`].
    pub fn vmm_t_batch(&self, e: &[f32], m: usize, t_now: f32,
                       round: u64, pool: &WorkerPool) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.k()];
        self.vmm_t_batch_into(e, m, t_now, round, pool, &mut scratch,
                              &mut out);
        out
    }

    // -- accounting --------------------------------------------------------

    /// Fold every tile's device activity into an endurance ledger
    /// (tile enumeration order).
    pub fn record_endurance(&self, ledger: &mut EnduranceLedger) {
        for t in &self.tiles {
            t.weights.record_endurance(ledger);
        }
    }

    /// Inference model bits held by this grid (MSB arrays only — the
    /// hybrid representation's inference footprint, paper Fig. 4).
    pub fn inference_bits(&self) -> usize {
        self.tiles.iter().map(|t| t.weights.inference_bits()).sum()
    }

    /// Lifetime SET pulses across all tiles (G+ and G− planes).
    pub fn total_set_pulses(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| {
                let msb = &t.weights.msb;
                msb.plus.set_count.iter().sum::<u64>()
                    + msb.minus.set_count.iter().sum::<u64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal_geom() -> HicGeometry {
        HicGeometry { stochastic_rounding: false, ..Default::default() }
    }

    fn pattern(k: usize, n: usize) -> Vec<f32> {
        (0..k * n)
            .map(|i| (((i * 3) % 13) as f32 - 6.0) / 8.0)
            .collect()
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 10, 7,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            DacSpec::default(), AdcSpec::default(), 9);
        assert_eq!(g.tile_count(), 3 * 3);
        let src = pattern(10, 7);
        let subs = g.scatter(&src);
        let mut back = vec![0.0f32; 10 * 7];
        g.gather(&subs, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn grid_decode_matches_programmed_pattern() {
        let pool = WorkerPool::serial();
        let mut g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 9, 5,
            TilingPolicy { tile_rows: 4, tile_cols: 2 },
            DacSpec::default(), AdcSpec::default(), 11);
        let w = pattern(9, 5);
        g.program_init(&w, 0.0, 0, &pool);
        let mut scratch = g.scratch();
        let mut got = vec![0.0f32; 9 * 5];
        g.drift_into(0.0, &pool, &mut scratch, &mut got);
        // Ideal linear devices: programmed to within one pulse quantum
        // through the conductance map.
        for (a, b) in w.iter().zip(&got) {
            assert!((a - b).abs() <= 0.13, "{a} vs {b}");
        }
    }

    #[test]
    fn vmm_t_worker_invariant_smoke() {
        let params = PcmParams::default();
        let g = {
            let mut g = CrossbarGrid::new(
                params, HicGeometry::default(), 12, 9,
                TilingPolicy { tile_rows: 5, tile_cols: 4 },
                DacSpec::default(), AdcSpec::default(), 21);
            g.program_init(&pattern(12, 9), 0.0, 7, &WorkerPool::serial());
            g
        };
        let m = 3;
        let e: Vec<f32> =
            (0..m * 9).map(|i| ((i % 7) as f32 - 3.0) / 4.0).collect();
        let y1 = g.vmm_t_batch(&e, m, 2.0, 5, &WorkerPool::new(1));
        let y2 = g.vmm_t_batch(&e, m, 2.0, 5, &WorkerPool::new(4));
        assert_eq!(y1, y2);
        assert_eq!(y1.len(), m * 12);
        // A different round draws different noise, and the forward op
        // stream is independent of the transposed one.
        let y3 = g.vmm_t_batch(&e, m, 2.0, 6, &WorkerPool::new(1));
        assert_ne!(y1, y3);
    }

    #[test]
    fn vmm_worker_invariant_smoke() {
        // Full noisy params: the parallel schedule must not change a bit.
        let params = PcmParams::default();
        let g = {
            let mut g = CrossbarGrid::new(
                params, HicGeometry::default(), 12, 9,
                TilingPolicy { tile_rows: 5, tile_cols: 4 },
                DacSpec::default(), AdcSpec::default(), 21);
            g.program_init(&pattern(12, 9), 0.0, 7, &WorkerPool::serial());
            g
        };
        let m = 3;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let y1 = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(1));
        let y2 = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(4));
        assert_eq!(y1, y2);
        // A different round draws different noise.
        let y3 = g.vmm_batch(&x, m, 2.0, 6, &WorkerPool::new(1));
        assert_ne!(y1, y3);
    }

    #[test]
    fn total_set_pulses_counts_programming() {
        let pool = WorkerPool::serial();
        let mut g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 4, 4,
            TilingPolicy { tile_rows: 2, tile_cols: 2 },
            DacSpec::default(), AdcSpec::default(), 3);
        assert_eq!(g.total_set_pulses(), 0);
        let mut scratch = g.scratch();
        let dw = vec![0.25f32; 16];
        let pulses = g.program_increments(&dw, 0.0, 1, &pool, &mut scratch);
        assert!(pulses > 0);
        assert_eq!(pulses, g.total_set_pulses());
        let mut ledger = EnduranceLedger::new();
        g.record_endurance(&mut ledger);
        assert_eq!(ledger.msb.count as usize, 2 * 16);
    }
}
