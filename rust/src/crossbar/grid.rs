//! Sharded multi-tile crossbar engine.
//!
//! [`CrossbarGrid`] maps one logical `[k, n]` weight matrix onto the
//! R×C tile grid computed by [`mapper::LayerMapping`] and runs the
//! device kernels — batched VMM, increment programming, training
//! updates, drift decode, saturation refresh — **tile-parallel** on a
//! [`WorkerPool`].  This converts the PR-1 planar data layout into
//! wall-clock scaling: every tile's planes are independent, exactly the
//! per-tile independence the paper's accelerator (and the
//! mixed-precision trainers it builds on) exploits.
//!
//! # Sharding scheme
//!
//! * **State kernels** (`program_init`, `program_increments`,
//!   `apply_update`, `refresh`): one shard per tile.  Each shard owns
//!   its tile's planes, so shards never alias.
//! * **`vmm_batch_into`**: two phases.  Phase 1 evaluates drift once
//!   per batch, one shard per tile.  Phase 2 shards by **column strip**
//!   (all tiles of one grid column): a strip owns a disjoint slice of
//!   output columns, walks its row-tiles top-down per sample
//!   accumulating partial sums into the same running output, and
//!   applies the ADC once per logical column after the last row-tile.
//!   Row-tiles accumulating *into* the running sum (instead of
//!   reducing independent partials) keeps the f32 addition sequence
//!   identical to a single tile spanning the whole matrix — which is
//!   what makes the grid bit-compatible with the serial single-tile
//!   path in the noise-free domain.
//! * **`drift_into`**: one shard per tile, serial deterministic gather.
//!
//! # RNG stream discipline
//!
//! Shards never share a generator.  Every kernel invocation derives one
//! counter-based stream per shard:
//! `Pcg64::new(seed ⊕ round·φ, (op_tag << 32) | shard_id)` — `seed` is
//! the grid's, `round` is a caller-supplied invocation counter (training
//! step, probe index, …), `op_tag` separates kernel families, and
//! `shard_id` is the tile index (state kernels) or grid column (VMM).
//! Reusing a `(seed, round, op)` triple replays the same noise, so
//! callers advance `round` between invocations.  Because a shard's
//! stream depends only on these values — never on the worker that runs
//! it — **all grid kernels are bitwise identical for any worker
//! count**; `rust/tests/prop_parallel_equivalence.rs` pins this, and
//! the noise-free equivalence against the single-tile serial path.
//!
//! Read noise inside the VMM uses the batched Box–Muller fill
//! (`Pcg64::fill_gaussian`) per tile plane, the same discipline as
//! `CrossbarTile::vmm_batch_into`.

use crate::hic::weight::{HicGeometry, HicWeight};
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

use super::mapper::{LayerMapping, TilingPolicy};
use super::quant::{AdcSpec, DacSpec};
use super::tile::CrossbarTile;

/// Kernel-family tags baked into the high bits of each shard's RNG
/// stream id (see the module docs).
pub const OP_INIT: u64 = 1;
pub const OP_PROGRAM: u64 = 2;
pub const OP_UPDATE: u64 = 3;
pub const OP_VMM: u64 = 4;
pub const OP_REFRESH: u64 = 5;
pub const OP_PROGRAM_INIT: u64 = 6;

/// Weyl constant mixing the invocation counter into the stream seed.
const ROUND_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// The per-shard generator: counter-based, scheduling-independent.
#[inline]
pub fn op_rng(seed: u64, round: u64, op: u64, shard: usize) -> Pcg64 {
    Pcg64::new(seed ^ round.wrapping_mul(ROUND_MIX),
               (op << 32) | shard as u64)
}

/// One logical weight matrix sharded onto an R×C grid of
/// [`CrossbarTile`]s (edge tiles sized to their used extent, so the
/// grid holds exactly `k·n` weight cells).
pub struct CrossbarGrid {
    pub mapping: LayerMapping,
    /// Row-major tile grid (`mapping.tile_index` addressing).
    pub tiles: Vec<CrossbarTile>,
    pub dac: DacSpec,
    pub adc: AdcSpec,
    pub seed: u64,
}

/// Per-tile drifted-conductance planes (valid for one `t_now`).
struct TileDrift {
    gp: Vec<f32>,
    gm: Vec<f32>,
}

/// Per-column-strip working buffers for the VMM shards.
struct StripScratch {
    w: Vec<f32>,
    noise: Vec<f32>,
    xq: Vec<f32>,
    out: Vec<f32>,
}

/// Reusable grid buffers: drift planes per tile + VMM strip scratch.
pub struct GridScratch {
    drift: Vec<TileDrift>,
    strips: Vec<StripScratch>,
}

/// Per-tile task unit handed to the pool by the state kernels.
struct TileTask<'a> {
    tile: &'a mut CrossbarTile,
    sub: Vec<f32>,
    count: u64,
}

impl CrossbarGrid {
    /// Build the grid: tiles are constructed in row-major order, each
    /// from its own `(seed, OP_INIT, tile)` stream, so construction is
    /// deterministic and independent of tile count elsewhere.
    pub fn new(params: PcmParams, geom: HicGeometry, k: usize, n: usize,
               policy: TilingPolicy, dac: DacSpec, adc: AdcSpec,
               seed: u64) -> Self {
        let mapping = LayerMapping::new("grid", k, n, policy);
        let mut tiles = Vec::with_capacity(mapping.tile_count());
        for (ti, t) in mapping.tiles.iter().enumerate() {
            let mut rng = op_rng(seed, 0, OP_INIT, ti);
            let hw = HicWeight::new(params, geom, t.used_rows,
                                    t.used_cols, &mut rng);
            tiles.push(CrossbarTile::new(hw, dac, adc));
        }
        CrossbarGrid { mapping, tiles, dac, adc, seed }
    }

    pub fn k(&self) -> usize {
        self.mapping.k
    }

    pub fn n(&self) -> usize {
        self.mapping.n
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Tile at grid coordinate `(gr, gc)`.
    pub fn tile(&self, gr: usize, gc: usize) -> &CrossbarTile {
        &self.tiles[self.mapping.tile_index(gr, gc)]
    }

    /// Allocate reusable buffers sized for this grid.
    pub fn scratch(&self) -> GridScratch {
        let drift = self
            .tiles
            .iter()
            .map(|t| {
                let nt = t.rows() * t.cols();
                TileDrift { gp: vec![0.0; nt], gm: vec![0.0; nt] }
            })
            .collect();
        let tr_max = self.mapping.policy.tile_rows.min(self.mapping.k);
        let mut strips = Vec::with_capacity(self.mapping.grid_cols());
        for c in 0..self.mapping.grid_cols() {
            let strip_cols =
                self.mapping.tiles[self.mapping.tile_index(0, c)].used_cols;
            let nmax = tr_max * strip_cols;
            strips.push(StripScratch {
                w: vec![0.0; nmax],
                noise: vec![0.0; nmax],
                xq: vec![0.0; tr_max],
                out: Vec::new(),
            });
        }
        GridScratch { drift, strips }
    }

    // -- logical <-> tile layout ------------------------------------------

    /// Split a logical row-major `[k, n]` matrix into per-tile
    /// row-major submatrices (tile enumeration order).
    fn scatter(&self, src: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(src.len(), self.k() * self.n());
        let n = self.n();
        self.mapping
            .tiles
            .iter()
            .map(|t| {
                let (r0, c0) = self.mapping.origin(t);
                let mut sub = vec![0.0f32; t.used_rows * t.used_cols];
                for r in 0..t.used_rows {
                    let src_row = (r0 + r) * n + c0;
                    sub[r * t.used_cols..(r + 1) * t.used_cols]
                        .copy_from_slice(
                            &src[src_row..src_row + t.used_cols]);
                }
                sub
            })
            .collect()
    }

    /// Gather per-tile row-major buffers back into the logical matrix.
    fn gather(&self, bufs: &[Vec<f32>], out: &mut [f32]) {
        assert_eq!(out.len(), self.k() * self.n());
        let n = self.n();
        for (t, buf) in self.mapping.tiles.iter().zip(bufs) {
            let (r0, c0) = self.mapping.origin(t);
            for r in 0..t.used_rows {
                let dst_row = (r0 + r) * n + c0;
                out[dst_row..dst_row + t.used_cols].copy_from_slice(
                    &buf[r * t.used_cols..(r + 1) * t.used_cols]);
            }
        }
    }

    // -- state kernels (shard = tile) -------------------------------------

    /// Program initial weights (MSB-quantized), tile-parallel.  Uses
    /// its own op tag (`OP_PROGRAM_INIT`), so an init followed by a
    /// `program_increments` at the same `round` still draws
    /// independent write-noise streams.
    pub fn program_init(&mut self, w: &[f32], t_now: f32, round: u64,
                        pool: &WorkerPool) {
        let subs = self.scatter(w);
        let seed = self.seed;
        let mut tasks: Vec<TileTask> = self
            .tiles
            .iter_mut()
            .zip(subs)
            .map(|(tile, sub)| TileTask { tile, sub, count: 0 })
            .collect();
        pool.run(&mut tasks, |ti, task| {
            let mut rng = op_rng(seed, round, OP_PROGRAM_INIT, ti);
            task.tile.weights.program_init(&task.sub, t_now, &mut rng);
        });
    }

    /// Apply signed per-weight increments (`dw` logical `[k, n]`,
    /// zeros untouched) through the differential pairs, tile-parallel.
    /// Returns total SET pulses applied.
    pub fn program_increments(&mut self, dw: &[f32], t_now: f32,
                              round: u64, pool: &WorkerPool) -> u64 {
        let subs = self.scatter(dw);
        let seed = self.seed;
        let mut tasks: Vec<TileTask> = self
            .tiles
            .iter_mut()
            .zip(subs)
            .map(|(tile, sub)| TileTask { tile, sub, count: 0 })
            .collect();
        pool.run(&mut tasks, |ti, task| {
            let mut rng = op_rng(seed, round, OP_PROGRAM, ti);
            let mut pulses = 0u64;
            for (i, &d) in task.sub.iter().enumerate() {
                if d != 0.0 {
                    pulses += task.tile.weights.msb.apply_increment(
                        i, d, t_now, &mut rng) as u64;
                }
            }
            task.count = pulses;
        });
        tasks.iter().map(|t| t.count).sum()
    }

    /// One hybrid training update (`grad` logical `[k, n]`),
    /// tile-parallel; returns total LSB→MSB overflow events.
    pub fn apply_update(&mut self, grad: &[f32], lr: f32, t_now: f32,
                        round: u64, pool: &WorkerPool) -> usize {
        let subs = self.scatter(grad);
        let seed = self.seed;
        let mut tasks: Vec<TileTask> = self
            .tiles
            .iter_mut()
            .zip(subs)
            .map(|(tile, sub)| TileTask { tile, sub, count: 0 })
            .collect();
        pool.run(&mut tasks, |ti, task| {
            let mut rng = op_rng(seed, round, OP_UPDATE, ti);
            task.count = task.tile.weights.apply_update(
                &task.sub, lr, t_now, &mut rng) as u64;
        });
        tasks.iter().map(|t| t.count as usize).sum()
    }

    /// Selective saturation refresh, tile-parallel; returns refreshed
    /// pair count.
    pub fn refresh(&mut self, t_now: f32, round: u64,
                   pool: &WorkerPool) -> usize {
        let seed = self.seed;
        let mut tasks: Vec<TileTask> = self
            .tiles
            .iter_mut()
            .map(|tile| TileTask { tile, sub: Vec::new(), count: 0 })
            .collect();
        pool.run(&mut tasks, |ti, task| {
            let mut rng = op_rng(seed, round, OP_REFRESH, ti);
            task.count = task.tile.weights.refresh(t_now, &mut rng) as u64;
        });
        tasks.iter().map(|t| t.count as usize).sum()
    }

    // -- read kernels ------------------------------------------------------

    /// Drift-evaluated decode of the logical weight matrix at `t_now`
    /// (no read noise) — the grid twin of `DifferentialPair::decode_into`
    /// with the drift power law evaluated tile-parallel.
    pub fn drift_into(&self, t_now: f32, pool: &WorkerPool,
                      out: &mut [f32]) {
        let mut bufs: Vec<Vec<f32>> = self
            .tiles
            .iter()
            .map(|t| vec![0.0f32; t.rows() * t.cols()])
            .collect();
        let tiles = &self.tiles;
        pool.run(&mut bufs, |ti, buf| {
            tiles[ti].weights.decode_into(t_now, buf);
        });
        self.gather(&bufs, out);
    }

    /// Batched analog VMM over the whole grid (`x: [m, k]` row-major
    /// logical inputs, `out: [m, n]`), drift once per batch, fresh
    /// per-sample read noise per tile.  See the module docs for the
    /// sharding and RNG scheme.
    pub fn vmm_batch_into(&self, x: &[f32], m: usize, t_now: f32,
                          round: u64, pool: &WorkerPool,
                          scratch: &mut GridScratch, out: &mut [f32]) {
        let k = self.k();
        let n = self.n();
        assert_eq!(x.len(), m * k);
        assert_eq!(out.len(), m * n);
        assert_eq!(scratch.drift.len(), self.tiles.len(),
                   "scratch does not match this grid");
        assert_eq!(scratch.strips.len(), self.mapping.grid_cols());

        let GridScratch { drift, strips } = scratch;
        let tiles = &self.tiles;

        // Phase 1: drift both conductance planes once per batch,
        // tile-parallel (no RNG).
        pool.run(&mut drift[..], |ti, d| {
            let msb = &tiles[ti].weights.msb;
            msb.plus.drift_into(t_now, &mut d.gp);
            msb.minus.drift_into(t_now, &mut d.gm);
        });

        // Phase 2: column strips (shard = grid column).
        let grid_r = self.mapping.grid_rows();
        let seed = self.seed;
        let mapping = &self.mapping;
        let dac = self.dac;
        let adc = self.adc;
        let drift_ro: &[TileDrift] = &drift[..];
        pool.run(&mut strips[..], |c, strip| {
            let strip_cols =
                mapping.tiles[mapping.tile_index(0, c)].used_cols;
            let need = m * strip_cols;
            if strip.out.len() < need {
                strip.out.resize(need, 0.0);
            }
            let mut rng = op_rng(seed, round, OP_VMM, c);
            for s in 0..m {
                let y = &mut strip.out
                    [s * strip_cols..(s + 1) * strip_cols];
                y.fill(0.0);
                for gr in 0..grid_r {
                    let ti = mapping.tile_index(gr, c);
                    let tile = &tiles[ti];
                    let (tr, tc) = (tile.rows(), tile.cols());
                    let nt = tr * tc;
                    let msb = &tile.weights.msb;
                    let (noise_p, sigma_p) = (msb.plus.params.read_noise,
                                              msb.plus.params.read_sigma);
                    let (noise_m, sigma_m) = (msb.minus.params.read_noise,
                                              msb.minus.params.read_sigma);
                    let scale = msb.g_to_w(1.0);
                    let d = &drift_ro[ti];
                    let w = &mut strip.w[..nt];

                    // Fresh stochastic read of this tile: G+ plane
                    // first, then G− (the tile-kernel draw order).
                    if noise_p {
                        let z = &mut strip.noise[..nt];
                        rng.fill_gaussian(z, 0.0, 1.0);
                        for ((wv, &gp), &zv) in
                            w.iter_mut().zip(&d.gp).zip(z.iter())
                        {
                            *wv = (gp + sigma_p * zv).clamp(0.0, 1.0);
                        }
                    } else {
                        for (wv, &gp) in w.iter_mut().zip(&d.gp) {
                            *wv = gp.clamp(0.0, 1.0);
                        }
                    }
                    if noise_m {
                        let z = &mut strip.noise[..nt];
                        rng.fill_gaussian(z, 0.0, 1.0);
                        for ((wv, &gm), &zv) in
                            w.iter_mut().zip(&d.gm).zip(z.iter())
                        {
                            *wv = (*wv
                                - (gm + sigma_m * zv).clamp(0.0, 1.0))
                                * scale;
                        }
                    } else {
                        for (wv, &gm) in w.iter_mut().zip(&d.gm) {
                            *wv = (*wv - gm.clamp(0.0, 1.0)) * scale;
                        }
                    }

                    // DAC this row block's inputs, accumulate row-major
                    // into the running column sums.
                    let (r0, _) = mapping.origin(&mapping.tiles[ti]);
                    let xs = &x[s * k + r0..s * k + r0 + tr];
                    let xq = &mut strip.xq[..tr];
                    for (q, &v) in xq.iter_mut().zip(xs) {
                        *q = dac.convert(v);
                    }
                    for (r, &xv) in xq.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let row = &w[r * tc..(r + 1) * tc];
                        for (yc, &wc) in y.iter_mut().zip(row) {
                            *yc += xv * wc;
                        }
                    }
                }
                // ADC once per logical column, after the last row-tile
                // (digital accumulation at full precision across
                // row-tiles — the modeling choice that keeps the grid
                // bit-compatible with a whole-matrix single tile; a
                // per-row-tile ADC is a future knob).
                for yc in y.iter_mut() {
                    *yc = adc.convert(*yc);
                }
            }
        });

        // Serial deterministic gather: strip outputs → logical [m, n].
        for (c, strip) in strips.iter().enumerate() {
            let t0 = &self.mapping.tiles[self.mapping.tile_index(0, c)];
            let (_, c0) = self.mapping.origin(t0);
            let strip_cols = t0.used_cols;
            for s in 0..m {
                out[s * n + c0..s * n + c0 + strip_cols].copy_from_slice(
                    &strip.out[s * strip_cols..(s + 1) * strip_cols]);
            }
        }
    }

    /// Allocating wrapper of [`CrossbarGrid::vmm_batch_into`].
    pub fn vmm_batch(&self, x: &[f32], m: usize, t_now: f32, round: u64,
                     pool: &WorkerPool) -> Vec<f32> {
        let mut scratch = self.scratch();
        let mut out = vec![0.0; m * self.n()];
        self.vmm_batch_into(x, m, t_now, round, pool, &mut scratch,
                            &mut out);
        out
    }

    // -- accounting --------------------------------------------------------

    /// Fold every tile's device activity into an endurance ledger
    /// (tile enumeration order).
    pub fn record_endurance(&self, ledger: &mut EnduranceLedger) {
        for t in &self.tiles {
            t.weights.record_endurance(ledger);
        }
    }

    /// Lifetime SET pulses across all tiles (G+ and G− planes).
    pub fn total_set_pulses(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| {
                let msb = &t.weights.msb;
                msb.plus.set_count.iter().sum::<u64>()
                    + msb.minus.set_count.iter().sum::<u64>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal_geom() -> HicGeometry {
        HicGeometry { stochastic_rounding: false, ..Default::default() }
    }

    fn pattern(k: usize, n: usize) -> Vec<f32> {
        (0..k * n)
            .map(|i| (((i * 3) % 13) as f32 - 6.0) / 8.0)
            .collect()
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 10, 7,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            DacSpec::default(), AdcSpec::default(), 9);
        assert_eq!(g.tile_count(), 3 * 3);
        let src = pattern(10, 7);
        let subs = g.scatter(&src);
        let mut back = vec![0.0f32; 10 * 7];
        g.gather(&subs, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn grid_decode_matches_programmed_pattern() {
        let pool = WorkerPool::serial();
        let mut g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 9, 5,
            TilingPolicy { tile_rows: 4, tile_cols: 2 },
            DacSpec::default(), AdcSpec::default(), 11);
        let w = pattern(9, 5);
        g.program_init(&w, 0.0, 0, &pool);
        let mut got = vec![0.0f32; 9 * 5];
        g.drift_into(0.0, &pool, &mut got);
        // Ideal linear devices: programmed to within one pulse quantum
        // through the conductance map.
        for (a, b) in w.iter().zip(&got) {
            assert!((a - b).abs() <= 0.13, "{a} vs {b}");
        }
    }

    #[test]
    fn vmm_worker_invariant_smoke() {
        // Full noisy params: the parallel schedule must not change a bit.
        let params = PcmParams::default();
        let g = {
            let mut g = CrossbarGrid::new(
                params, HicGeometry::default(), 12, 9,
                TilingPolicy { tile_rows: 5, tile_cols: 4 },
                DacSpec::default(), AdcSpec::default(), 21);
            g.program_init(&pattern(12, 9), 0.0, 7, &WorkerPool::serial());
            g
        };
        let m = 3;
        let x: Vec<f32> =
            (0..m * 12).map(|i| ((i % 9) as f32 - 4.0) / 4.0).collect();
        let y1 = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(1));
        let y2 = g.vmm_batch(&x, m, 2.0, 5, &WorkerPool::new(4));
        assert_eq!(y1, y2);
        // A different round draws different noise.
        let y3 = g.vmm_batch(&x, m, 2.0, 6, &WorkerPool::new(1));
        assert_ne!(y1, y3);
    }

    #[test]
    fn total_set_pulses_counts_programming() {
        let pool = WorkerPool::serial();
        let mut g = CrossbarGrid::new(
            PcmParams::ideal(), ideal_geom(), 4, 4,
            TilingPolicy { tile_rows: 2, tile_cols: 2 },
            DacSpec::default(), AdcSpec::default(), 3);
        assert_eq!(g.total_set_pulses(), 0);
        let dw = vec![0.25f32; 16];
        let pulses = g.program_increments(&dw, 0.0, 1, &pool);
        assert!(pulses > 0);
        assert_eq!(pulses, g.total_set_pulses());
        let mut ledger = EnduranceLedger::new();
        g.record_endurance(&mut ledger);
        assert_eq!(ledger.msb.count as usize, 2 * 16);
    }
}
