//! Layer-to-tile mapping.
//!
//! A `[K, N]` crossbar-mapped weight matrix is split onto physical tiles
//! of `tile_rows x tile_cols` differential pairs.  The mapper computes
//! the tile grid, per-tile occupancy and array utilization — the numbers
//! behind the paper's memory-efficiency argument and the inputs to the
//! energy model.

use crate::runtime::artifact::LayerInfo;

/// Physical tile geometry / mapping policy.
#[derive(Clone, Copy, Debug)]
pub struct TilingPolicy {
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl Default for TilingPolicy {
    fn default() -> Self {
        // 128x128: the common crossbar macro size (ISAAC, PUMA) and the
        // MXU-aligned block the Pallas kernel tiles by.
        TilingPolicy { tile_rows: 128, tile_cols: 128 }
    }
}

/// Coordinates of one physical tile within a layer's grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileCoord {
    pub row: usize,
    pub col: usize,
    /// occupied rows/cols in this tile (edge tiles are partial)
    pub used_rows: usize,
    pub used_cols: usize,
}

impl TileCoord {
    pub fn used(&self) -> usize {
        self.used_rows * self.used_cols
    }
}

/// The mapping of one layer onto tiles.
#[derive(Clone, Debug)]
pub struct LayerMapping {
    pub name: String,
    pub k: usize,
    pub n: usize,
    pub policy: TilingPolicy,
    pub tiles: Vec<TileCoord>,
}

impl LayerMapping {
    pub fn new(name: &str, k: usize, n: usize,
               policy: TilingPolicy) -> Self {
        let mut tiles = Vec::new();
        let tr = policy.tile_rows;
        let tc = policy.tile_cols;
        let grid_r = k.div_ceil(tr);
        let grid_c = n.div_ceil(tc);
        for r in 0..grid_r {
            for c in 0..grid_c {
                tiles.push(TileCoord {
                    row: r,
                    col: c,
                    used_rows: (k - r * tr).min(tr),
                    used_cols: (n - c * tc).min(tc),
                });
            }
        }
        LayerMapping { name: name.to_string(), k, n, policy, tiles }
    }

    pub fn from_layer(info: &LayerInfo, policy: TilingPolicy) -> Self {
        Self::new(&info.name, info.k, info.n, policy)
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Number of tile rows in the grid (`⌈k / tile_rows⌉`).
    pub fn grid_rows(&self) -> usize {
        self.k.div_ceil(self.policy.tile_rows)
    }

    /// Number of tile columns in the grid (`⌈n / tile_cols⌉`).
    pub fn grid_cols(&self) -> usize {
        self.n.div_ceil(self.policy.tile_cols)
    }

    /// Row-major index of grid tile `(gr, gc)` into [`LayerMapping::tiles`].
    #[inline]
    pub fn tile_index(&self, gr: usize, gc: usize) -> usize {
        debug_assert!(gr < self.grid_rows() && gc < self.grid_cols());
        gr * self.grid_cols() + gc
    }

    /// Top-left logical-matrix coordinate covered by a tile.
    #[inline]
    pub fn origin(&self, t: &TileCoord) -> (usize, usize) {
        (t.row * self.policy.tile_rows, t.col * self.policy.tile_cols)
    }

    /// Devices provisioned (2 per weight cell — differential pairs).
    pub fn devices_provisioned(&self) -> usize {
        2 * self.tile_count() * self.policy.tile_rows * self.policy.tile_cols
    }

    pub fn devices_used(&self) -> usize {
        2 * self.k * self.n
    }

    /// Fraction of provisioned cross-points that hold real weights.
    pub fn utilization(&self) -> f64 {
        self.devices_used() as f64 / self.devices_provisioned() as f64
    }

    /// Column-current full-scale estimate for ADC range calibration:
    /// `x_range * w_max * sqrt(active rows)` (uncorrelated-sum scaling).
    pub fn adc_fullscale(&self, x_range: f32, w_max: f32) -> f32 {
        x_range * w_max * (self.policy.tile_rows.min(self.k) as f32).sqrt()
    }
}

/// Map an entire network; gives the whole-chip tile budget.
pub fn map_network(layers: &[LayerInfo], policy: TilingPolicy)
                   -> Vec<LayerMapping> {
    layers
        .iter()
        .map(|l| LayerMapping::from_layer(l, policy))
        .collect()
}

/// Total-chip summary used by `crossbar_explorer` and DESIGN.md tables.
pub fn network_summary(mappings: &[LayerMapping]) -> (usize, usize, f64) {
    let tiles: usize = mappings.iter().map(|m| m.tile_count()).sum();
    let used: usize = mappings.iter().map(|m| m.devices_used()).sum();
    let prov: usize =
        mappings.iter().map(|m| m.devices_provisioned()).sum();
    (tiles, used, used as f64 / prov as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        let m = LayerMapping::new("t", 128, 256, TilingPolicy::default());
        assert_eq!(m.tile_count(), 2);
        assert_eq!(m.utilization(), 1.0);
        assert!(m.tiles.iter().all(|t| t.used() == 128 * 128));
    }

    #[test]
    fn partial_edge_tiles() {
        let m = LayerMapping::new("t", 130, 10, TilingPolicy::default());
        assert_eq!(m.tile_count(), 2); // 2 row-tiles x 1 col-tile
        assert_eq!(m.tiles[0].used_rows, 128);
        assert_eq!(m.tiles[1].used_rows, 2);
        assert_eq!(m.tiles[0].used_cols, 10);
        let covered: usize = m.tiles.iter().map(|t| t.used()).sum();
        assert_eq!(covered, 130 * 10); // every element exactly once
        assert!((m.utilization() - (130.0 * 10.0) / (2.0 * 128.0 * 128.0))
            .abs() < 1e-12);
    }

    #[test]
    fn coverage_is_a_partition() {
        // Property: sum of used cells == K*N for arbitrary geometries.
        for (k, n) in [(1, 1), (27, 16), (129, 129), (576, 64), (64, 640)] {
            let m = LayerMapping::new("t", k, n, TilingPolicy {
                tile_rows: 100, tile_cols: 60 });
            let covered: usize = m.tiles.iter().map(|t| t.used()).sum();
            assert_eq!(covered, k * n, "k={k} n={n}");
            // no tile exceeds its physical size
            assert!(m.tiles.iter().all(
                |t| t.used_rows <= 100 && t.used_cols <= 60));
        }
    }

    #[test]
    fn grid_dims_and_origins() {
        let m = LayerMapping::new("t", 130, 10, TilingPolicy::default());
        assert_eq!((m.grid_rows(), m.grid_cols()), (2, 1));
        assert_eq!(m.tile_index(1, 0), 1);
        assert_eq!(m.origin(&m.tiles[0]), (0, 0));
        assert_eq!(m.origin(&m.tiles[1]), (128, 0));
        // Row-major enumeration matches (row, col) grid coordinates,
        // and every origin + extent stays inside the logical matrix.
        let m = LayerMapping::new("t", 65, 130, TilingPolicy {
            tile_rows: 32, tile_cols: 48 });
        assert_eq!((m.grid_rows(), m.grid_cols()), (3, 3));
        for gr in 0..m.grid_rows() {
            for gc in 0..m.grid_cols() {
                let t = &m.tiles[m.tile_index(gr, gc)];
                assert_eq!((t.row, t.col), (gr, gc));
                let (r0, c0) = m.origin(t);
                assert!(r0 + t.used_rows <= 65);
                assert!(c0 + t.used_cols <= 130);
            }
        }
    }

    #[test]
    fn adc_fullscale_scaling() {
        let m = LayerMapping::new("t", 512, 64, TilingPolicy::default());
        let fs = m.adc_fullscale(4.0, 1.0);
        assert!((fs - 4.0 * (128.0f32).sqrt()).abs() < 1e-3);
        // small layers bound by their own K
        let m = LayerMapping::new("t", 9, 4, TilingPolicy::default());
        assert!((m.adc_fullscale(4.0, 1.0) - 4.0 * 3.0).abs() < 1e-3);
    }
}
