//! The on-grid feed-forward network: per-layer crossbar grids + the
//! portable digital glue (ReLU, softmax cross-entropy).
//!
//! [`DeviceNet`] holds one [`CrossbarGrid`] per layer — every weight
//! matrix lives on its own sharded tile grid with the HIC hybrid
//! representation (4-bit MSB differential pairs + LSB accumulators).
//! Per-layer weight scaling follows the mixed-precision trainers: layer
//! `l` maps its conductance window to `w_max = w_scale / √fan_in`, so a
//! He-scaled initialization occupies several MSB quanta regardless of
//! width, and activations stay O(1) through depth (the DAC/ADC ranges
//! never re-calibrate per layer).
//!
//! Each layer derives its own grid seed ([`layer_seed`]) — combined
//! with the grid's counter-based `(round, op, shard)` streams, a
//! forward pass, a transposed backward pass and a hybrid update of any
//! layer at any step draw fully independent noise, independent of the
//! worker count.
//!
//! The digital nonlinearities ([`softmax_rows`], [`nll_sum`]) are pure
//! f32 arithmetic on the `fastmath` polynomials (no libm), so the
//! device-level fig4 documents are byte-stable and oracle-mirrored.

use crate::crossbar::grid::CrossbarGrid;
use crate::crossbar::{AdcSpec, DacSpec, GridScratch, TilingPolicy};
use crate::hic::weight::HicGeometry;
use crate::pcm::device::PcmParams;
use crate::util::fastmath::{exp_fast, ln_fast};
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

/// Weyl constant deriving per-layer grid seeds from the net seed.
const LAYER_SEED_MIX: u64 = 0xA24B_AED4_963E_E407;
/// Stream tag of the per-layer weight-initialization draws.
const INIT_STREAM: u64 = 0x1217;

/// Grid seed of layer `l` (distinct per layer, stable across widths of
/// *other* layers).
#[inline]
pub fn layer_seed(seed: u64, layer: usize) -> u64 {
    seed ^ (layer as u64 + 1).wrapping_mul(LAYER_SEED_MIX)
}

/// Hidden width scaled by the paper's width multiplier (permille —
/// integer so experiment documents stay byte-stable).  Half-away-from-
/// zero rounding spelled out as `⌊x + 0.5⌋` so every implementation
/// (Rust, oracle) agrees on ties.
#[inline]
pub fn scaled_width(base: usize, width_permille: u32) -> usize {
    let x = base as f64 * width_permille as f64 / 1000.0;
    ((x + 0.5).floor() as usize).max(1)
}

/// Architecture spec: input dim, base hidden widths, classes, and the
/// width multiplier applied to the hidden stack.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub input: usize,
    pub hidden_base: Vec<usize>,
    pub classes: usize,
    pub width_permille: u32,
}

impl NetSpec {
    /// Full layer-size chain `[input, hidden.., classes]`.
    pub fn dims(&self) -> Vec<usize> {
        let mut d = Vec::with_capacity(self.hidden_base.len() + 2);
        d.push(self.input);
        for &h in &self.hidden_base {
            d.push(scaled_width(h, self.width_permille));
        }
        d.push(self.classes);
        d
    }
}

/// A feed-forward network whose every weight matrix lives on its own
/// [`CrossbarGrid`].
pub struct DeviceNet {
    /// layer-size chain: layer `l` maps `dims[l] → dims[l+1]`
    pub dims: Vec<usize>,
    pub grids: Vec<CrossbarGrid>,
    pub seed: u64,
}

impl DeviceNet {
    /// Build and initialize the network: per-layer `w_max =
    /// w_scale / √fan_in`, weights drawn uniform in `±w_max/2` from the
    /// layer's init stream and programmed onto the grids
    /// (MSB-quantized) at `t = 0`, `round = 0`.
    pub fn new(params: PcmParams, dims: &[usize], policy: TilingPolicy,
               w_scale: f32, seed: u64, pool: &WorkerPool) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let mut grids = Vec::with_capacity(dims.len() - 1);
        for l in 0..dims.len() - 1 {
            let (k, n) = (dims[l], dims[l + 1]);
            let w_max = w_scale / (k as f32).sqrt();
            let geom = HicGeometry { w_max, ..Default::default() };
            let ls = layer_seed(seed, l);
            let mut grid = CrossbarGrid::new(
                params, geom, k, n, policy, DacSpec::default(),
                AdcSpec::default(), ls);
            let mut rng = Pcg64::new(ls, INIT_STREAM);
            let half = 0.5 * w_max;
            let w0: Vec<f32> =
                (0..k * n).map(|_| rng.uniform_in(-half, half)).collect();
            grid.program_init(&w0, 0.0, 0, pool);
            grids.push(grid);
        }
        DeviceNet { dims: dims.to_vec(), grids, seed }
    }

    pub fn layers(&self) -> usize {
        self.grids.len()
    }

    pub fn input_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// One reusable [`GridScratch`] per layer.
    pub fn scratches(&self) -> Vec<GridScratch> {
        self.grids.iter().map(|g| g.scratch()).collect()
    }

    /// Inference model bits across all layers (MSB arrays only — the
    /// fig4 model-size axis).
    pub fn inference_bits(&self) -> usize {
        self.grids.iter().map(|g| g.inference_bits()).sum()
    }
}

// -- portable digital glue (oracle-mirrored f32 op order) ----------------

/// Row-wise softmax of logits `z: [m, classes]` into `p` — max-shifted,
/// [`exp_fast`] exponentials, sequential f32 sum, one divide per
/// element.
pub fn softmax_rows(z: &[f32], m: usize, classes: usize, p: &mut [f32]) {
    assert_eq!(z.len(), m * classes);
    assert_eq!(p.len(), m * classes);
    for s in 0..m {
        let row = &z[s * classes..(s + 1) * classes];
        let out = &mut p[s * classes..(s + 1) * classes];
        let mut mx = row[0];
        for &v in &row[1..] {
            if v > mx {
                mx = v;
            }
        }
        let mut sum = 0.0f32;
        for (o, &v) in out.iter_mut().zip(row) {
            let e = exp_fast(v - mx);
            *o = e;
            sum += e;
        }
        for o in out.iter_mut() {
            *o /= sum;
        }
    }
}

/// Summed negative log-likelihood `Σ_s −ln p[s, y_s]` over the batch
/// (f64 accumulation of f32 logs; probabilities floored at 1e-30).
pub fn nll_sum(p: &[f32], labels: &[u8], classes: usize) -> f64 {
    let mut s = 0.0f64;
    for (si, &y) in labels.iter().enumerate() {
        let py = p[si * classes + y as usize].max(1e-30);
        s -= ln_fast(py) as f64;
    }
    s
}

/// Index of the row maximum (first occurrence on ties).
pub fn argmax_row(row: &[f32]) -> usize {
    let mut best = 0;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_dims_scale_with_width() {
        let spec = NetSpec { input: 48, hidden_base: vec![32, 16],
                             classes: 10, width_permille: 500 };
        assert_eq!(spec.dims(), vec![48, 16, 8, 10]);
        let spec = NetSpec { width_permille: 1500, ..spec };
        assert_eq!(spec.dims(), vec![48, 48, 24, 10]);
        // Floor at 1, half-away rounding at .5 ties.
        assert_eq!(scaled_width(1, 250), 1);
        assert_eq!(scaled_width(5, 500), 3); // 2.5 -> 3
        assert_eq!(scaled_width(3, 500), 2); // 1.5 -> 2
    }

    #[test]
    fn device_net_builds_and_decodes_near_init() {
        let pool = WorkerPool::serial();
        let dims = [6, 5, 3];
        let net = DeviceNet::new(
            PcmParams::ideal(), &dims,
            TilingPolicy { tile_rows: 4, tile_cols: 4 }, 2.0, 11, &pool);
        assert_eq!(net.layers(), 2);
        assert_eq!(net.inference_bits(), (6 * 5 + 5 * 3) * 4);
        // Programmed weights stay within the layer's representable
        // range and are not all zero (the init must survive MSB
        // quantization — the whole point of per-layer w_max).
        let mut scratch = net.grids[0].scratch();
        let mut w = vec![0.0f32; 6 * 5];
        net.grids[0].drift_into(0.0, &pool, &mut scratch, &mut w);
        let w_max = 2.0 / (6.0f32).sqrt();
        assert!(w.iter().any(|&v| v != 0.0), "init quantized to zero");
        assert!(w.iter().all(|&v| v.abs() <= w_max + 0.13));
    }

    #[test]
    fn layer_seeds_are_distinct() {
        let s: Vec<u64> = (0..6).map(|l| layer_seed(42, l)).collect();
        for i in 0..s.len() {
            for j in i + 1..s.len() {
                assert_ne!(s[i], s[j]);
            }
        }
    }

    #[test]
    fn softmax_rows_and_nll() {
        let z = [1.0f32, 1.0, 1.0, 0.0, 0.0, 10.0];
        let mut p = [0.0f32; 6];
        softmax_rows(&z, 2, 3, &mut p);
        for s in 0..2 {
            let sum: f32 = p[s * 3..(s + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {s} sums to {sum}");
        }
        assert!((p[0] - 1.0 / 3.0).abs() < 1e-5);
        assert!(p[5] > 0.999);
        assert_eq!(argmax_row(&p[3..6]), 2);
        assert_eq!(argmax_row(&p[0..3]), 0); // tie -> first
        // NLL of the confident row is tiny; of the uniform row, ln 3.
        let l = nll_sum(&p, &[0, 2], 3);
        assert!((l - (3.0f64).ln()).abs() < 1e-3, "nll {l}");
    }
}
