//! Dense-stack spec and the portable digital glue (ReLU-free softmax
//! cross-entropy helpers) shared by the on-grid graph and the FP32
//! baseline.
//!
//! The on-grid network itself lives in [`crate::nn::graph`]: the PR-3
//! `DeviceNet` dense stack is now the `GraphSpec::mlp` instance of the
//! layer-graph IR (per-layer [`crate::crossbar::CrossbarGrid`]s,
//! `w_max = w_scale/√fan_in` weight windows, per-layer seeds via
//! [`layer_seed`]).  This module keeps what is architecture-independent:
//!
//! * [`NetSpec`] / [`scaled_width`] — the paper's width-multiplier axis
//!   (permille integers so experiment documents stay byte-stable);
//! * [`layer_seed`] — the per-weighted-layer grid-seed derivation
//!   (stable across widths of *other* layers; combined with the grid's
//!   counter-based `(round, op, shard)` streams, every layer at every
//!   step draws independent noise for any worker count);
//! * [`softmax_rows`], [`nll_sum`], [`argmax_row`] — pure f32/f64
//!   arithmetic on the `fastmath` polynomials (no libm), so the
//!   device-level fig4 documents are byte-stable and oracle-mirrored.

use crate::util::fastmath::{exp_fast, ln_fast};

/// Weyl constant deriving per-layer grid seeds from the net seed.
const LAYER_SEED_MIX: u64 = 0xA24B_AED4_963E_E407;
/// Stream tag of the per-layer weight-initialization draws (shared by
/// every weighted layer kind of the device graph).
pub(crate) const INIT_STREAM: u64 = 0x1217;

/// Grid seed of weighted layer `l` (distinct per layer, stable across
/// widths of *other* layers).
#[inline]
pub fn layer_seed(seed: u64, layer: usize) -> u64 {
    seed ^ (layer as u64 + 1).wrapping_mul(LAYER_SEED_MIX)
}

/// Hidden width scaled by the paper's width multiplier (permille —
/// integer so experiment documents stay byte-stable).  Half-away-from-
/// zero rounding spelled out as `⌊x + 0.5⌋` so every implementation
/// (Rust, oracle) agrees on ties.
#[inline]
pub fn scaled_width(base: usize, width_permille: u32) -> usize {
    let x = base as f64 * width_permille as f64 / 1000.0;
    ((x + 0.5).floor() as usize).max(1)
}

/// Dense-stack architecture spec: input dim, base hidden widths,
/// classes, and the width multiplier applied to the hidden stack.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub input: usize,
    pub hidden_base: Vec<usize>,
    pub classes: usize,
    pub width_permille: u32,
}

impl NetSpec {
    /// Full layer-size chain `[input, hidden.., classes]`.
    pub fn dims(&self) -> Vec<usize> {
        let mut d = Vec::with_capacity(self.hidden_base.len() + 2);
        d.push(self.input);
        for &h in &self.hidden_base {
            d.push(scaled_width(h, self.width_permille));
        }
        d.push(self.classes);
        d
    }
}

// -- portable digital glue (oracle-mirrored f32 op order) ----------------

/// Row-wise softmax of logits `z: [m, classes]` into `p` — max-shifted,
/// [`exp_fast`] exponentials, sequential f32 sum, one divide per
/// element.
pub fn softmax_rows(z: &[f32], m: usize, classes: usize, p: &mut [f32]) {
    assert_eq!(z.len(), m * classes);
    assert_eq!(p.len(), m * classes);
    for s in 0..m {
        let row = &z[s * classes..(s + 1) * classes];
        let out = &mut p[s * classes..(s + 1) * classes];
        let mut mx = row[0];
        for &v in &row[1..] {
            if v > mx {
                mx = v;
            }
        }
        let mut sum = 0.0f32;
        for (o, &v) in out.iter_mut().zip(row) {
            let e = exp_fast(v - mx);
            *o = e;
            sum += e;
        }
        for o in out.iter_mut() {
            *o /= sum;
        }
    }
}

/// Summed negative log-likelihood `Σ_s −ln p[s, y_s]` over the batch
/// (f64 accumulation of f32 logs; probabilities floored at 1e-30).
pub fn nll_sum(p: &[f32], labels: &[u8], classes: usize) -> f64 {
    let mut s = 0.0f64;
    for (si, &y) in labels.iter().enumerate() {
        let py = p[si * classes + y as usize].max(1e-30);
        s -= ln_fast(py) as f64;
    }
    s
}

/// Index of the row maximum (first occurrence on ties).
pub fn argmax_row(row: &[f32]) -> usize {
    let mut best = 0;
    for (j, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_dims_scale_with_width() {
        let spec = NetSpec { input: 48, hidden_base: vec![32, 16],
                             classes: 10, width_permille: 500 };
        assert_eq!(spec.dims(), vec![48, 16, 8, 10]);
        let spec = NetSpec { width_permille: 1500, ..spec };
        assert_eq!(spec.dims(), vec![48, 48, 24, 10]);
        // Floor at 1, half-away rounding at .5 ties.
        assert_eq!(scaled_width(1, 250), 1);
        assert_eq!(scaled_width(5, 500), 3); // 2.5 -> 3
        assert_eq!(scaled_width(3, 500), 2); // 1.5 -> 2
    }

    #[test]
    fn layer_seeds_are_distinct() {
        let s: Vec<u64> = (0..6).map(|l| layer_seed(42, l)).collect();
        for i in 0..s.len() {
            for j in i + 1..s.len() {
                assert_ne!(s[i], s[j]);
            }
        }
    }

    #[test]
    fn softmax_rows_and_nll() {
        let z = [1.0f32, 1.0, 1.0, 0.0, 0.0, 10.0];
        let mut p = [0.0f32; 6];
        softmax_rows(&z, 2, 3, &mut p);
        for s in 0..2 {
            let sum: f32 = p[s * 3..(s + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {s} sums to {sum}");
        }
        assert!((p[0] - 1.0 / 3.0).abs() < 1e-5);
        assert!(p[5] > 0.999);
        assert_eq!(argmax_row(&p[3..6]), 2);
        assert_eq!(argmax_row(&p[0..3]), 0); // tie -> first
        // NLL of the confident row is tiny; of the uniform row, ln 3.
        let l = nll_sum(&p, &[0, 2], 3);
        assert!((l - (3.0f64).ln()).abs() < 1e-3, "nll {l}");
    }
}
