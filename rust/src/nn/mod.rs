//! On-grid multi-layer network training (the `nn` subsystem).
//!
//! The paper's headline result is multi-layer training on the hybrid
//! in-memory architecture; this module brings that workload onto the
//! **device-level** grid engine, no PJRT artifacts needed:
//!
//! * [`net::DeviceNet`] — a layered feed-forward network (hidden widths
//!   scaled by the paper's width multiplier, ReLU activations, softmax
//!   cross-entropy) where **every layer's weight matrix lives on its
//!   own sharded [`crate::crossbar::CrossbarGrid`]** with the HIC
//!   hybrid representation.  The forward pass is the analog batched
//!   VMM; the backward pass is the **transposed** analog VMM
//!   (`vmm_t_batch_into`) on the *same* crossbars — the mixed-precision
//!   computational-memory training scheme (Nandakumar et al.), where
//!   only the weight-gradient outer product and the nonlinearities run
//!   digitally.
//! * [`features`] — deterministic feature sources: pooled synthetic
//!   CIFAR from the existing `data` pipeline (default for accuracy
//!   runs) and portable Gaussian blobs (no libm; feeds the byte-stable
//!   fig4 golden).
//! * [`baseline::FpNet`] — the FP32 host MLP (32 bits/weight) the fig4
//!   accuracy-vs-model-size sweep compares against.
//!
//! The training loop itself lives in
//! [`crate::coordinator::nettrainer::NetTrainer`]; the fig4 sweep in
//! `exp::gridexp::run_fig4`.  Everything inherits the grid determinism
//! contract: bitwise identical for any worker count.

pub mod baseline;
pub mod features;
pub mod net;

pub use baseline::FpNet;
pub use features::{BlobDataset, FeatureSource, PooledCifar};
pub use net::{DeviceNet, NetSpec};
