//! On-grid multi-layer network training (the `nn` subsystem).
//!
//! The paper's headline result is multi-layer training on the hybrid
//! in-memory architecture; this module brings that workload onto the
//! **device-level** grid engine, no PJRT artifacts needed:
//!
//! * [`graph`] — the layer-graph IR ([`GraphSpec`] → [`GraphNet`]):
//!   `Dense`, `Conv2d`, `Relu`, `GlobalAvgPool`, `Residual` skip-add
//!   and the `Softmax` head, with explicit activation shapes.  **Every
//!   weighted layer's matrix lives on its own sharded
//!   [`crate::crossbar::CrossbarGrid`]** (per-layer
//!   `w_max = w_scale/√fan_in`, per-layer seeds); convolutions are
//!   lowered **weight-stationary** through the streaming patch kernels
//!   (`crossbar::conv`): each kernel is a `[kh·kw·cin, cout]` analog
//!   VMM fed patch segments on demand from the once-DAC'd image, its
//!   backprop the **transposed** analog VMM drained through the fused
//!   col2im scatter, its weight gradient a column-streamed digital
//!   patch outer product into the hybrid LSB/MSB update — the
//!   mixed-precision computational-memory scheme (Nandakumar et al.)
//!   extended to the paper's ResNet topology
//!   ([`graph::resnet_spec`]), with the materialized im2col/col2im
//!   path retained as a bit-identical fallback
//!   ([`graph::ConvLowering`]).
//! * [`features`] — deterministic feature sources with explicit
//!   `[h, w, c]` spatial metadata: pooled synthetic CIFAR from the
//!   existing `data` pipeline (default for accuracy runs) and portable
//!   Gaussian blobs, flat or image-shaped (no libm; feeds the
//!   byte-stable fig4 goldens).
//! * [`baseline`] — the FP32 host twins ([`FpNet`] dense,
//!   [`baseline::FpGraphNet`] layer-graph) the fig4
//!   accuracy-vs-model-size sweeps compare against.
//!
//! The training loop lives in
//! [`crate::coordinator::nettrainer::NetTrainer`]; the fig4 sweeps in
//! `exp::gridexp::run_fig4`.  Everything inherits the grid determinism
//! contract: bitwise identical for any worker count — which is what
//! lets the pipelined trainer overlap each layer's gradient/update
//! chain with the backward VMM walk
//! ([`GraphNet::backward_update_pipelined`]) without changing a single
//! bit of the result.

pub mod baseline;
pub mod features;
pub mod graph;
pub mod net;

pub use baseline::{FpGraphNet, FpNet};
pub use features::{BlobDataset, FeatureSource, PooledCifar};
pub use graph::{resnet_spec, ActShape, ConvLowering, GainCtx, GraphNet,
                GraphSpec, LayerSpec, StepTotals};
pub use net::NetSpec;
