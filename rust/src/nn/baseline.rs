//! FP32 host baseline of the on-grid network — the digital reference
//! the device-level fig4 sweep compares model sizes against.
//!
//! Same architecture, initialization scale and loss as [`DeviceNet`]
//! (ReLU MLP, softmax cross-entropy, plain SGD), but weights are plain
//! f32 matrices updated exactly (32 bits/weight at inference vs the
//! HIC grids' 4).  Every consumed op is portable f32/f64 arithmetic on
//! the `fastmath` nonlinearities, deterministic in loop order, so the
//! baseline rows of the fig4 document are byte-stable and
//! oracle-mirrored like the device rows.

use crate::nn::features::FeatureSource;
use crate::nn::net::{argmax_row, layer_seed, nll_sum, softmax_rows};
use crate::util::rng::Pcg64;

/// Stream tag of the baseline's weight-initialization draws (distinct
/// from the device net's, so the two models are independent draws of
/// the same distribution).
const INIT_STREAM: u64 = 0xF32B;

/// Plain f32 MLP trained with SGD on the host.
pub struct FpNet {
    /// layer-size chain: layer `l` maps `dims[l] → dims[l+1]`
    pub dims: Vec<usize>,
    /// per-layer row-major `[k, n]` weight matrices
    pub w: Vec<Vec<f32>>,
    pub seed: u64,
    /// per-step mean training cross-entropy
    pub losses: Vec<f64>,
    step: usize,
}

impl FpNet {
    /// Same init law as the device net: layer `l` draws uniform in
    /// `±(w_scale/√fan_in)/2` from its own stream.
    pub fn new(dims: &[usize], w_scale: f32, seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let mut w = Vec::with_capacity(dims.len() - 1);
        for l in 0..dims.len() - 1 {
            let (k, n) = (dims[l], dims[l + 1]);
            let w_max = w_scale / (k as f32).sqrt();
            let half = 0.5 * w_max;
            let mut rng = Pcg64::new(layer_seed(seed, l), INIT_STREAM);
            w.push((0..k * n)
                .map(|_| rng.uniform_in(-half, half))
                .collect());
        }
        FpNet { dims: dims.to_vec(), w, seed, losses: Vec::new(), step: 0 }
    }

    pub fn layers(&self) -> usize {
        self.w.len()
    }

    pub fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Inference model bits (32 per weight).
    pub fn inference_bits(&self) -> usize {
        self.w.iter().map(|m| m.len() * 32).sum()
    }

    /// Forward pass over `m` samples: returns per-layer pre-activations
    /// (`zs[l]: [m, dims[l+1]]`) and hidden ReLU outputs.
    fn forward(&self, x: &[f32], m: usize)
               -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let nl = self.layers();
        let mut zs = Vec::with_capacity(nl);
        let mut acts = Vec::with_capacity(nl - 1);
        for l in 0..nl {
            let (k, n) = (self.dims[l], self.dims[l + 1]);
            let a_in: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let wl = &self.w[l];
            let mut z = vec![0.0f32; m * n];
            for s in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for i in 0..k {
                        acc += a_in[s * k + i] * wl[i * n + j];
                    }
                    z[s * n + j] = acc;
                }
            }
            if l + 1 < nl {
                let a: Vec<f32> = z
                    .iter()
                    .map(|&v| if v > 0.0 { v } else { 0.0 })
                    .collect();
                acts.push(a);
            }
            zs.push(z);
        }
        (zs, acts)
    }

    /// Run `steps` SGD steps on the feature source (sequential epoch
    /// order, the device trainer's batch discipline).
    pub fn train_steps(&mut self, data: &FeatureSource, steps: usize,
                       batch: usize, lr: f32) {
        let d0 = self.dims[0];
        let classes = self.classes();
        let nl = self.layers();
        assert_eq!(d0, data.dim());
        assert_eq!(classes, data.classes());
        let m = batch;
        let mut x = vec![0.0f32; m * d0];
        let mut labels = vec![0u8; m];
        let mut probs = vec![0.0f32; m * classes];
        for _ in 0..steps {
            for j in 0..m {
                let idx = (self.step * m + j) % data.train_len();
                labels[j] = data.sample_into(
                    idx, false, &mut x[j * d0..(j + 1) * d0]);
            }
            let (zs, acts) = self.forward(&x, m);
            softmax_rows(&zs[nl - 1], m, classes, &mut probs);
            self.losses.push(nll_sum(&probs, &labels, classes) / m as f64);

            // Output delta, then backprop and update layer by layer.
            let mut delta = vec![0.0f32; m * classes];
            for s in 0..m {
                for j in 0..classes {
                    let y = if labels[s] as usize == j { 1.0 } else { 0.0 };
                    delta[s * classes + j] = probs[s * classes + j] - y;
                }
            }
            let inv_m = 1.0f32 / m as f32;
            for l in (0..nl).rev() {
                let (k, n) = (self.dims[l], self.dims[l + 1]);
                let a_in: &[f32] = if l == 0 { &x } else { &acts[l - 1] };
                // Backprop through the pre-update weights first.
                let prev = if l > 0 {
                    let wl = &self.w[l];
                    let zp = &zs[l - 1];
                    let mut d = vec![0.0f32; m * k];
                    for s in 0..m {
                        for i in 0..k {
                            let mut acc = 0.0f32;
                            for j in 0..n {
                                acc += delta[s * n + j] * wl[i * n + j];
                            }
                            d[s * k + i] =
                                if zp[s * k + i] > 0.0 { acc } else { 0.0 };
                        }
                    }
                    Some(d)
                } else {
                    None
                };
                let wl = &mut self.w[l];
                for i in 0..k {
                    for j in 0..n {
                        let mut acc = 0.0f32;
                        for s in 0..m {
                            acc += a_in[s * k + i] * delta[s * n + j];
                        }
                        wl[i * n + j] -= lr * (acc * inv_m);
                    }
                }
                if let Some(d) = prev {
                    delta = d;
                }
            }
            self.step += 1;
        }
    }

    /// Mean cross-entropy and accuracy over the first `n` test samples.
    pub fn evaluate(&self, data: &FeatureSource, n: usize,
                    batch: usize) -> (f64, f64) {
        let d0 = self.dims[0];
        let classes = self.classes();
        let nl = self.layers();
        let mut hits = 0usize;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        let mut x = vec![0.0f32; batch * d0];
        let mut labels = vec![0u8; batch];
        let mut probs = vec![0.0f32; batch * classes];
        while done < n {
            let mb = batch.min(n - done);
            for j in 0..mb {
                labels[j] = data.sample_into(
                    done + j, true, &mut x[j * d0..(j + 1) * d0]);
            }
            let (zs, _) = self.forward(&x[..mb * d0], mb);
            softmax_rows(&zs[nl - 1], mb, classes,
                         &mut probs[..mb * classes]);
            loss_sum += nll_sum(&probs[..mb * classes], &labels[..mb],
                                classes);
            for s in 0..mb {
                let row = &probs[s * classes..(s + 1) * classes];
                if argmax_row(row) == labels[s] as usize {
                    hits += 1;
                }
            }
            done += mb;
        }
        (loss_sum / n as f64, hits as f64 / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::features::BlobDataset;

    #[test]
    fn fp_net_learns_blobs() {
        let data = FeatureSource::Blobs(
            BlobDataset::new(3, 8, 4, 0.35, 400, 80));
        let mut net = FpNet::new(&[8, 12, 8, 4], 2.0, 7);
        let (_, acc0) = net.evaluate(&data, 80, 16);
        net.train_steps(&data, 150, 16, 0.2);
        let (loss, acc) = net.evaluate(&data, 80, 16);
        assert!(acc > 0.9, "fp32 eval acc {acc} (from {acc0})");
        assert!(acc > acc0);
        assert!(loss < net.losses[0], "loss {loss} vs {}", net.losses[0]);
        // Training loss trends down.
        let early: f64 = net.losses[..10].iter().sum::<f64>() / 10.0;
        let late: f64 =
            net.losses[net.losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(late < early * 0.7, "loss {early} -> {late}");
    }

    #[test]
    fn model_bits_are_32_per_weight() {
        let net = FpNet::new(&[6, 5, 3], 2.0, 1);
        assert_eq!(net.inference_bits(), (6 * 5 + 5 * 3) * 32);
    }
}
