//! FP32 host baselines of the on-grid networks — the digital reference
//! the device-level fig4 sweeps compare model sizes against.
//!
//! Two baselines, same init law and loss as the device side (uniform
//! `±(w_scale/√fan_in)/2` per weighted layer from its own
//! `layer_seed` stream, softmax cross-entropy, plain SGD), weights as
//! plain f32 matrices updated exactly (32 bits/weight at inference vs
//! the HIC grids' 4):
//!
//! * [`FpNet`] — the original dense ReLU MLP (kept verbatim: the dense
//!   fig4 golden pins its exact f32 op order);
//! * [`FpGraphNet`] — the layer-graph twin of
//!   [`crate::nn::graph::GraphNet`], growing the same layer set (conv
//!   via the shared im2col lowering, residual skip-add with auto
//!   projection, global average pooling), built from the same
//!   [`GraphPlan`] so its weighted layers line up one to one with the
//!   device grids.  Used by the fig4 `--arch resnet` sweep.
//!
//! Every consumed op is portable f32/f64 arithmetic on the `fastmath`
//! nonlinearities, deterministic in loop order, so the baseline rows of
//! the fig4 documents are byte-stable and oracle-mirrored like the
//! device rows.

use crate::crossbar::conv::{col2im_into, im2col_into, PatchGeom};
use crate::nn::features::FeatureSource;
use crate::nn::graph::{ensure, ActShape, GraphPlan, GraphSpec,
                       PlanLayer};
use crate::nn::net::{argmax_row, layer_seed, nll_sum, softmax_rows};
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

/// Stream tag of the baseline's weight-initialization draws (distinct
/// from the device net's, so the two models are independent draws of
/// the same distribution).
const INIT_STREAM: u64 = 0xF32B;

/// Plain f32 MLP trained with SGD on the host.
pub struct FpNet {
    /// layer-size chain: layer `l` maps `dims[l] → dims[l+1]`
    pub dims: Vec<usize>,
    /// per-layer row-major `[k, n]` weight matrices
    pub w: Vec<Vec<f32>>,
    pub seed: u64,
    /// per-step mean training cross-entropy
    pub losses: Vec<f64>,
    step: usize,
}

impl FpNet {
    /// Same init law as the device net: layer `l` draws uniform in
    /// `±(w_scale/√fan_in)/2` from its own stream.
    pub fn new(dims: &[usize], w_scale: f32, seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let mut w = Vec::with_capacity(dims.len() - 1);
        for l in 0..dims.len() - 1 {
            let (k, n) = (dims[l], dims[l + 1]);
            let w_max = w_scale / (k as f32).sqrt();
            let half = 0.5 * w_max;
            let mut rng = Pcg64::new(layer_seed(seed, l), INIT_STREAM);
            w.push((0..k * n)
                .map(|_| rng.uniform_in(-half, half))
                .collect());
        }
        FpNet { dims: dims.to_vec(), w, seed, losses: Vec::new(), step: 0 }
    }

    pub fn layers(&self) -> usize {
        self.w.len()
    }

    pub fn classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Inference model bits (32 per weight).
    pub fn inference_bits(&self) -> usize {
        self.w.iter().map(|m| m.len() * 32).sum()
    }

    /// Forward pass over `m` samples: returns per-layer pre-activations
    /// (`zs[l]: [m, dims[l+1]]`) and hidden ReLU outputs.
    fn forward(&self, x: &[f32], m: usize)
               -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let nl = self.layers();
        let mut zs = Vec::with_capacity(nl);
        let mut acts = Vec::with_capacity(nl - 1);
        for l in 0..nl {
            let (k, n) = (self.dims[l], self.dims[l + 1]);
            let a_in: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let wl = &self.w[l];
            let mut z = vec![0.0f32; m * n];
            for s in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for i in 0..k {
                        acc += a_in[s * k + i] * wl[i * n + j];
                    }
                    z[s * n + j] = acc;
                }
            }
            if l + 1 < nl {
                let a: Vec<f32> = z
                    .iter()
                    .map(|&v| if v > 0.0 { v } else { 0.0 })
                    .collect();
                acts.push(a);
            }
            zs.push(z);
        }
        (zs, acts)
    }

    /// Run `steps` SGD steps on the feature source (sequential epoch
    /// order, the device trainer's batch discipline).
    pub fn train_steps(&mut self, data: &FeatureSource, steps: usize,
                       batch: usize, lr: f32) {
        let d0 = self.dims[0];
        let classes = self.classes();
        let nl = self.layers();
        assert_eq!(d0, data.dim());
        assert_eq!(classes, data.classes());
        let m = batch;
        let mut x = vec![0.0f32; m * d0];
        let mut labels = vec![0u8; m];
        let mut probs = vec![0.0f32; m * classes];
        for _ in 0..steps {
            for j in 0..m {
                let idx = (self.step * m + j) % data.train_len();
                labels[j] = data.sample_into(
                    idx, false, &mut x[j * d0..(j + 1) * d0]);
            }
            let (zs, acts) = self.forward(&x, m);
            softmax_rows(&zs[nl - 1], m, classes, &mut probs);
            self.losses.push(nll_sum(&probs, &labels, classes) / m as f64);

            // Output delta, then backprop and update layer by layer.
            let mut delta = vec![0.0f32; m * classes];
            for s in 0..m {
                for j in 0..classes {
                    let y = if labels[s] as usize == j { 1.0 } else { 0.0 };
                    delta[s * classes + j] = probs[s * classes + j] - y;
                }
            }
            let inv_m = 1.0f32 / m as f32;
            for l in (0..nl).rev() {
                let (k, n) = (self.dims[l], self.dims[l + 1]);
                let a_in: &[f32] = if l == 0 { &x } else { &acts[l - 1] };
                // Backprop through the pre-update weights first.
                let prev = if l > 0 {
                    let wl = &self.w[l];
                    let zp = &zs[l - 1];
                    let mut d = vec![0.0f32; m * k];
                    for s in 0..m {
                        for i in 0..k {
                            let mut acc = 0.0f32;
                            for j in 0..n {
                                acc += delta[s * n + j] * wl[i * n + j];
                            }
                            d[s * k + i] =
                                if zp[s * k + i] > 0.0 { acc } else { 0.0 };
                        }
                    }
                    Some(d)
                } else {
                    None
                };
                let wl = &mut self.w[l];
                for i in 0..k {
                    for j in 0..n {
                        let mut acc = 0.0f32;
                        for s in 0..m {
                            acc += a_in[s * k + i] * delta[s * n + j];
                        }
                        wl[i * n + j] -= lr * (acc * inv_m);
                    }
                }
                if let Some(d) = prev {
                    delta = d;
                }
            }
            self.step += 1;
        }
    }

    /// Mean cross-entropy and accuracy over the first `n` test samples.
    pub fn evaluate(&self, data: &FeatureSource, n: usize,
                    batch: usize) -> (f64, f64) {
        let d0 = self.dims[0];
        let classes = self.classes();
        let nl = self.layers();
        let mut hits = 0usize;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        let mut x = vec![0.0f32; batch * d0];
        let mut labels = vec![0u8; batch];
        let mut probs = vec![0.0f32; batch * classes];
        while done < n {
            let mb = batch.min(n - done);
            for j in 0..mb {
                labels[j] = data.sample_into(
                    done + j, true, &mut x[j * d0..(j + 1) * d0]);
            }
            let (zs, _) = self.forward(&x[..mb * d0], mb);
            softmax_rows(&zs[nl - 1], mb, classes,
                         &mut probs[..mb * classes]);
            loss_sum += nll_sum(&probs[..mb * classes], &labels[..mb],
                                classes);
            for s in 0..mb {
                let row = &probs[s * classes..(s + 1) * classes];
                if argmax_row(row) == labels[s] as usize {
                    hits += 1;
                }
            }
            done += mb;
        }
        (loss_sum / n as f64, hits as f64 / n as f64)
    }
}

// -- FP32 layer-graph baseline -------------------------------------------

/// One FP32 graph layer (host twin of `nn::graph::Layer`).
enum FpLayer {
    Dense {
        k: usize,
        n: usize,
        /// row-major `[k, n]`
        w: Vec<f32>,
        input: Vec<f32>,
    },
    Conv {
        geom: PatchGeom,
        /// row-major `[K, cout]`
        w: Vec<f32>,
        patches: Vec<f32>,
        dpatches: Vec<f32>,
    },
    Relu { len: usize, z: Vec<f32> },
    Gap { h: usize, w: usize, c: usize },
    Residual {
        body: Vec<FpLayer>,
        proj: Option<Box<FpLayer>>,
        in_len: usize,
        out_len: usize,
        bacts: Vec<Vec<f32>>,
        skip: Vec<f32>,
        dbody: Vec<f32>,
        dtmp: Vec<f32>,
        dskip: Vec<f32>,
    },
}

/// Per-weighted-layer init draws — the [`FpNet`] law (`INIT_STREAM`
/// is this module's FP32 stream tag, distinct from the device net's).
fn init_weights(seed: u64, widx: usize, w_scale: f32, k: usize,
                n: usize) -> Vec<f32> {
    let w_max = w_scale / (k as f32).sqrt();
    let half = 0.5 * w_max;
    let mut rng = Pcg64::new(layer_seed(seed, widx), INIT_STREAM);
    (0..k * n).map(|_| rng.uniform_in(-half, half)).collect()
}

fn build_fp_layer(pl: &PlanLayer, w_scale: f32, seed: u64) -> FpLayer {
    match pl {
        PlanLayer::Dense { widx, k, n } => FpLayer::Dense {
            k: *k,
            n: *n,
            w: init_weights(seed, *widx, w_scale, *k, *n),
            input: Vec::new(),
        },
        PlanLayer::Conv { widx, geom } => FpLayer::Conv {
            geom: *geom,
            w: init_weights(seed, *widx, w_scale, geom.patch_len(),
                            geom.cout),
            patches: Vec::new(),
            dpatches: Vec::new(),
        },
        PlanLayer::Relu { len } => {
            FpLayer::Relu { len: *len, z: Vec::new() }
        }
        PlanLayer::GlobalAvgPool { h, w, c } => {
            FpLayer::Gap { h: *h, w: *w, c: *c }
        }
        PlanLayer::Residual { body, proj, in_len, out_len } => {
            let b: Vec<FpLayer> = body
                .iter()
                .map(|l| build_fp_layer(l, w_scale, seed))
                .collect();
            let pj = proj
                .as_ref()
                .map(|p| Box::new(build_fp_layer(p, w_scale, seed)));
            FpLayer::Residual {
                bacts: vec![Vec::new(); b.len()],
                body: b,
                proj: pj,
                in_len: *in_len,
                out_len: *out_len,
                skip: Vec::new(),
                dbody: Vec::new(),
                dtmp: Vec::new(),
                dskip: Vec::new(),
            }
        }
    }
}

impl FpLayer {
    fn in_len(&self) -> usize {
        match self {
            FpLayer::Dense { k, .. } => *k,
            FpLayer::Conv { geom, .. } => geom.in_len(),
            FpLayer::Relu { len, .. } => *len,
            FpLayer::Gap { h, w, c } => h * w * c,
            FpLayer::Residual { in_len, .. } => *in_len,
        }
    }

    fn out_len(&self) -> usize {
        match self {
            FpLayer::Dense { n, .. } => *n,
            FpLayer::Conv { geom, .. } => geom.out_len(),
            FpLayer::Relu { len, .. } => *len,
            FpLayer::Gap { c, .. } => *c,
            FpLayer::Residual { out_len, .. } => *out_len,
        }
    }

    fn forward(&mut self, x: &[f32], m: usize, pool: &WorkerPool,
               out: &mut Vec<f32>) {
        match self {
            FpLayer::Dense { k, n, w, input } => {
                let (k, n) = (*k, *n);
                ensure(input, m * k);
                input[..m * k].copy_from_slice(&x[..m * k]);
                ensure(out, m * n);
                for s in 0..m {
                    for j in 0..n {
                        let mut acc = 0.0f32;
                        for i in 0..k {
                            acc += x[s * k + i] * w[i * n + j];
                        }
                        out[s * n + j] = acc;
                    }
                }
            }
            FpLayer::Conv { geom, w, patches, .. } => {
                let (p, k, co) =
                    (geom.positions(), geom.patch_len(), geom.cout);
                let rows = m * p;
                ensure(patches, rows * k);
                im2col_into(geom, &x[..m * geom.in_len()], m, pool,
                            &mut patches[..rows * k]);
                ensure(out, rows * co);
                for r in 0..rows {
                    for j in 0..co {
                        let mut acc = 0.0f32;
                        for ki in 0..k {
                            acc += patches[r * k + ki] * w[ki * co + j];
                        }
                        out[r * co + j] = acc;
                    }
                }
            }
            FpLayer::Relu { len, z } => {
                let need = m * *len;
                ensure(z, need);
                z[..need].copy_from_slice(&x[..need]);
                ensure(out, need);
                for (o, &v) in out[..need].iter_mut().zip(&x[..need]) {
                    *o = if v > 0.0 { v } else { 0.0 };
                }
            }
            FpLayer::Gap { h, w, c } => {
                let (pp, cc) = (*h * *w, *c);
                let inv_area = 1.0f32 / pp as f32;
                ensure(out, m * cc);
                for s in 0..m {
                    for j in 0..cc {
                        let mut acc = 0.0f32;
                        for p in 0..pp {
                            acc += x[s * pp * cc + p * cc + j];
                        }
                        out[s * cc + j] = acc * inv_area;
                    }
                }
            }
            FpLayer::Residual { body, proj, out_len, bacts, skip, .. } => {
                let nb = body.len();
                for i in 0..nb {
                    let il = body[i].in_len();
                    let (done, rest) = bacts.split_at_mut(i);
                    let input: &[f32] =
                        if i == 0 { x } else { &done[i - 1][..m * il] };
                    body[i].forward(input, m, pool, &mut rest[0]);
                }
                let need = m * *out_len;
                ensure(out, need);
                if let Some(pj) = proj.as_mut() {
                    pj.forward(x, m, pool, skip);
                    let body_out = &bacts[nb - 1];
                    for i in 0..need {
                        out[i] = body_out[i] + skip[i];
                    }
                } else {
                    let body_out = &bacts[nb - 1];
                    for i in 0..need {
                        out[i] = body_out[i] + x[i];
                    }
                }
            }
        }
    }

    /// Backward through the **pre-update** weights (input gradient
    /// first), then the fused SGD update `w -= lr · (gradᵀ·mean)` —
    /// the [`FpNet`] discipline generalized to the graph.
    fn backward(&mut self, d_out: &[f32], m: usize, lr: f32, inv_m: f32,
                pool: &WorkerPool, d_in: &mut Vec<f32>,
                need_input_grad: bool) {
        match self {
            FpLayer::Dense { k, n, w, input } => {
                let (k, n) = (*k, *n);
                if need_input_grad {
                    ensure(d_in, m * k);
                    for s in 0..m {
                        for i in 0..k {
                            let mut acc = 0.0f32;
                            for j in 0..n {
                                acc += d_out[s * n + j] * w[i * n + j];
                            }
                            d_in[s * k + i] = acc;
                        }
                    }
                }
                for i in 0..k {
                    for j in 0..n {
                        let mut acc = 0.0f32;
                        for s in 0..m {
                            acc += input[s * k + i] * d_out[s * n + j];
                        }
                        w[i * n + j] -= lr * (acc * inv_m);
                    }
                }
            }
            FpLayer::Conv { geom, w, patches, dpatches } => {
                let (p, k, co) =
                    (geom.positions(), geom.patch_len(), geom.cout);
                let rows = m * p;
                if need_input_grad {
                    ensure(dpatches, rows * k);
                    for r in 0..rows {
                        for ki in 0..k {
                            let mut acc = 0.0f32;
                            for j in 0..co {
                                acc += d_out[r * co + j] * w[ki * co + j];
                            }
                            dpatches[r * k + ki] = acc;
                        }
                    }
                    let nin = m * geom.in_len();
                    ensure(d_in, nin);
                    col2im_into(geom, &dpatches[..rows * k], m, pool,
                                &mut d_in[..nin]);
                }
                for ki in 0..k {
                    for j in 0..co {
                        let mut acc = 0.0f32;
                        for r in 0..rows {
                            acc += patches[r * k + ki] * d_out[r * co + j];
                        }
                        w[ki * co + j] -= lr * (acc * inv_m);
                    }
                }
            }
            FpLayer::Relu { len, z } => {
                if need_input_grad {
                    let need = m * *len;
                    ensure(d_in, need);
                    for i in 0..need {
                        d_in[i] =
                            if z[i] > 0.0 { d_out[i] } else { 0.0 };
                    }
                }
            }
            FpLayer::Gap { h, w, c } => {
                if need_input_grad {
                    let (pp, cc) = (*h * *w, *c);
                    let inv_area = 1.0f32 / pp as f32;
                    ensure(d_in, m * pp * cc);
                    for s in 0..m {
                        for p in 0..pp {
                            for j in 0..cc {
                                d_in[s * pp * cc + p * cc + j] =
                                    d_out[s * cc + j] * inv_area;
                            }
                        }
                    }
                }
            }
            FpLayer::Residual { body, proj, in_len, out_len, dbody,
                                dtmp, dskip, .. } => {
                let nb = body.len();
                let need_out = m * *out_len;
                ensure(dbody, need_out);
                dbody[..need_out].copy_from_slice(&d_out[..need_out]);
                for i in (0..nb).rev() {
                    let inner_need = i > 0 || need_input_grad;
                    let ol = body[i].out_len();
                    body[i].backward(&dbody[..m * ol], m, lr, inv_m,
                                     pool, dtmp, inner_need);
                    if inner_need {
                        std::mem::swap(dbody, dtmp);
                    }
                }
                if let Some(pj) = proj.as_mut() {
                    pj.backward(d_out, m, lr, inv_m, pool, dskip,
                                need_input_grad);
                }
                if need_input_grad {
                    let nin = m * *in_len;
                    ensure(d_in, nin);
                    if proj.is_some() {
                        for i in 0..nin {
                            d_in[i] = dbody[i] + dskip[i];
                        }
                    } else {
                        for i in 0..nin {
                            d_in[i] = dbody[i] + d_out[i];
                        }
                    }
                }
            }
        }
    }
}

/// FP32 layer-graph network trained with SGD on the host — the
/// apples-to-apples baseline of the fig4 `--arch resnet` sweep.
pub struct FpGraphNet {
    pub input: ActShape,
    pub classes: usize,
    pub seed: u64,
    /// per-step mean training cross-entropy
    pub losses: Vec<f64>,
    layers: Vec<FpLayer>,
    weights_total: usize,
    step: usize,
    acts: Vec<Vec<f32>>,
    delta: Vec<f32>,
    dtmp: Vec<f32>,
}

impl FpGraphNet {
    pub fn new(spec: &GraphSpec, w_scale: f32, seed: u64) -> Self {
        Self::from_plan(&spec.plan(), w_scale, seed)
    }

    pub fn from_plan(plan: &GraphPlan, w_scale: f32, seed: u64) -> Self {
        let layers: Vec<FpLayer> = plan
            .layers
            .iter()
            .map(|l| build_fp_layer(l, w_scale, seed))
            .collect();
        let acts = layers.iter().map(|_| Vec::new()).collect();
        FpGraphNet {
            input: plan.input,
            classes: plan.classes,
            seed,
            losses: Vec::new(),
            layers,
            weights_total: plan.weights(),
            step: 0,
            acts,
            delta: Vec::new(),
            dtmp: Vec::new(),
        }
    }

    /// Inference model bits (32 per weight).
    pub fn inference_bits(&self) -> usize {
        self.weights_total * 32
    }

    fn forward_pass(&mut self, x: &[f32], m: usize,
                    pool: &WorkerPool) -> &[f32] {
        let nl = self.layers.len();
        for i in 0..nl {
            let il = self.layers[i].in_len();
            let (done, rest) = self.acts.split_at_mut(i);
            let input: &[f32] =
                if i == 0 { x } else { &done[i - 1][..m * il] };
            self.layers[i].forward(input, m, pool, &mut rest[0]);
        }
        &self.acts[nl - 1][..m * self.classes]
    }

    /// Run `steps` SGD steps on the feature source (sequential epoch
    /// order, the device trainer's batch discipline).
    pub fn train_steps(&mut self, data: &FeatureSource, steps: usize,
                       batch: usize, lr: f32) {
        let d0 = self.input.len();
        let classes = self.classes;
        assert_eq!(d0, data.dim());
        assert_eq!(classes, data.classes());
        let pool = WorkerPool::serial();
        let m = batch;
        let mut x = vec![0.0f32; m * d0];
        let mut labels = vec![0u8; m];
        let mut probs = vec![0.0f32; m * classes];
        for _ in 0..steps {
            for j in 0..m {
                let idx = (self.step * m + j) % data.train_len();
                labels[j] = data.sample_into(
                    idx, false, &mut x[j * d0..(j + 1) * d0]);
            }
            let logits = self.forward_pass(&x, m, &pool);
            softmax_rows(logits, m, classes, &mut probs);
            self.losses.push(nll_sum(&probs, &labels, classes) / m as f64);
            ensure(&mut self.delta, m * classes);
            for s in 0..m {
                for j in 0..classes {
                    let y = if labels[s] as usize == j { 1.0 } else { 0.0 };
                    self.delta[s * classes + j] =
                        probs[s * classes + j] - y;
                }
            }
            let inv_m = 1.0f32 / m as f32;
            for i in (0..self.layers.len()).rev() {
                let need = i > 0;
                let ol = self.layers[i].out_len();
                self.layers[i].backward(&self.delta[..m * ol], m, lr,
                                        inv_m, &pool, &mut self.dtmp,
                                        need);
                if need {
                    std::mem::swap(&mut self.delta, &mut self.dtmp);
                }
            }
            self.step += 1;
        }
    }

    /// Mean cross-entropy and accuracy over the first `n` test samples.
    pub fn evaluate(&mut self, data: &FeatureSource, n: usize,
                    batch: usize) -> (f64, f64) {
        let d0 = self.input.len();
        let classes = self.classes;
        let pool = WorkerPool::serial();
        let mut hits = 0usize;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        let mut x = vec![0.0f32; batch * d0];
        let mut labels = vec![0u8; batch];
        let mut probs = vec![0.0f32; batch * classes];
        while done < n {
            let mb = batch.min(n - done);
            for j in 0..mb {
                labels[j] = data.sample_into(
                    done + j, true, &mut x[j * d0..(j + 1) * d0]);
            }
            let logits = self.forward_pass(&x[..mb * d0], mb, &pool);
            softmax_rows(logits, mb, classes, &mut probs[..mb * classes]);
            loss_sum += nll_sum(&probs[..mb * classes], &labels[..mb],
                                classes);
            for s in 0..mb {
                let row = &probs[s * classes..(s + 1) * classes];
                if argmax_row(row) == labels[s] as usize {
                    hits += 1;
                }
            }
            done += mb;
        }
        (loss_sum / n as f64, hits as f64 / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::features::BlobDataset;

    #[test]
    fn fp_net_learns_blobs() {
        let data = FeatureSource::Blobs(
            BlobDataset::new(3, 8, 4, 0.35, 400, 80));
        let mut net = FpNet::new(&[8, 12, 8, 4], 2.0, 7);
        let (_, acc0) = net.evaluate(&data, 80, 16);
        net.train_steps(&data, 150, 16, 0.2);
        let (loss, acc) = net.evaluate(&data, 80, 16);
        assert!(acc > 0.9, "fp32 eval acc {acc} (from {acc0})");
        assert!(acc > acc0);
        assert!(loss < net.losses[0], "loss {loss} vs {}", net.losses[0]);
        // Training loss trends down.
        let early: f64 = net.losses[..10].iter().sum::<f64>() / 10.0;
        let late: f64 =
            net.losses[net.losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(late < early * 0.7, "loss {early} -> {late}");
    }

    #[test]
    fn model_bits_are_32_per_weight() {
        let net = FpNet::new(&[6, 5, 3], 2.0, 1);
        assert_eq!(net.inference_bits(), (6 * 5 + 5 * 3) * 32);
    }

    #[test]
    fn fp_graph_net_learns_image_blobs() {
        // Small conv net on image-shaped blobs: the FP32 graph baseline
        // must train end to end through conv, relu, residual and GAP.
        // Thresholds validated against the bit-exact oracle (FpGraph on
        // this exact config): acc 0.167 -> 0.667, loss 1.100 -> 0.734.
        let data = FeatureSource::Blobs(
            BlobDataset::with_shape(3, 4, 4, 2, 3, 0.35, 120, 36));
        let spec = GraphSpec::resnet([4, 4, 2], [3, 4, 5], 1, 3, 1000);
        let mut net = FpGraphNet::new(&spec, 2.0, 7);
        assert_eq!(net.classes, 3);
        assert_eq!(net.inference_bits() % 32, 0);
        let (_, acc0) = net.evaluate(&data, 36, 6);
        net.train_steps(&data, 120, 6, 0.3);
        let (loss, acc) = net.evaluate(&data, 36, 6);
        assert!(acc0 < 0.5, "untrained graph already accurate? {acc0}");
        assert!(acc > 0.55, "fp32 graph eval acc {acc} (from {acc0})");
        assert!(acc > acc0 + 0.3, "no real learning: {acc0} -> {acc}");
        assert!(loss < 0.9, "eval loss {loss}");
        // Training loss trends down.
        let early: f64 = net.losses[..10].iter().sum::<f64>() / 10.0;
        let late: f64 =
            net.losses[net.losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(late < early * 0.8, "loss {early} -> {late}");
    }

    #[test]
    fn fp_graph_mlp_matches_weight_count() {
        // The graph MLP and the dense FpNet hold the same weight set.
        let dims = [6, 5, 3];
        let spec = GraphSpec::mlp(&dims);
        let graph = FpGraphNet::new(&spec, 2.0, 1);
        let dense = FpNet::new(&dims, 2.0, 1);
        assert_eq!(graph.inference_bits(), dense.inference_bits());
        assert_eq!(graph.input, ActShape::Flat(6));
    }
}
