//! Feature sources feeding the on-grid network trainer.
//!
//! Three providers behind one [`FeatureSource`] enum:
//!
//! * [`PooledCifar`] — the existing `data` pipeline's structured
//!   synthetic CIFAR ([`SyntheticDataset`]) reduced to a feature vector
//!   by channel-preserving average pooling (`pool × pool` blocks).  The
//!   default for training runs and the accuracy experiments: pooling
//!   averages the per-pixel observation noise down by `pool` while the
//!   low-frequency class prototypes survive, so a small MLP separates
//!   the classes the way the full image pipeline does.  Sample
//!   generation inherits the dataset's libm-based streams, so this
//!   provider is **not** byte-stable across platforms — use it for
//!   accuracy, not goldens.
//! * [`RealCifar`] — the same pooling over **real CIFAR-10 bytes**
//!   ([`CifarDataset`]), used automatically by the CLI paths
//!   (`serve`, `fig4 --long-run`) when a dataset directory is present
//!   ([`FeatureSource::pooled_cifar_auto`]); the synthetic provider
//!   stays the fallback and the golden path.
//! * [`BlobDataset`] — Gaussian blobs around per-class centroids drawn
//!   from `Pcg64` uniforms, with sample noise from the batched
//!   Box–Muller fill.  Every consumed op is portable f32/f64 arithmetic
//!   (no libm), which is what lets the device-level fig4 golden
//!   document pin the whole layered training loop byte-for-byte
//!   (`rust/tests/golden/oracle.py` mirrors this generator op for op).
//!
//! The synthetic providers are deterministic per `(seed, index,
//! split)`: samples are generated on demand from counter-based streams
//! (the synthetic CIFAR convention), so the trainer needs no stored
//! dataset and the worker count can never affect the data.  The real
//! loader is deterministic trivially — stored bytes.

use std::path::Path;

use crate::data::cifar::CifarDataset;
use crate::data::synthetic::SyntheticDataset;
use crate::data::{IMG_C, IMG_H, IMG_W, NUM_CLASSES};
use crate::log_info;
use crate::nn::graph::ActShape;
use crate::util::rng::Pcg64;

/// Stream tag of the blob centroid draws.
const BLOB_CENTROID_STREAM: u64 = 0xB10B;
/// Per-sample noise stream tags (split-dependent, synthetic-CIFAR
/// convention: the index seeds, the stream selects the split).
const BLOB_TRAIN_STREAM: u64 = 0xB1E4;
const BLOB_TEST_STREAM: u64 = 0xB1E5;

/// Gaussian blobs: class centroids uniform in `[-1, 1]^dim`, samples
/// `centroid + σ·z` with `z` from `Pcg64::fill_gaussian` — fully
/// portable arithmetic (see the module docs).
pub struct BlobDataset {
    pub dim: usize,
    pub classes: usize,
    /// per-feature sample noise σ
    pub noise: f32,
    pub seed: u64,
    pub train_len: usize,
    pub test_len: usize,
    /// optional spatial interpretation `[h, w, c]` of the flat feature
    /// vector (HWC) — lets the conv graph consume blob data without
    /// changing a single draw (the streams depend only on `dim`)
    pub shape: Option<[usize; 3]>,
    /// class-major centroid matrix, `[classes, dim]` row-major
    centroids: Vec<f32>,
}

impl BlobDataset {
    pub fn new(seed: u64, dim: usize, classes: usize, noise: f32,
               train_len: usize, test_len: usize) -> Self {
        let mut rng = Pcg64::new(seed, BLOB_CENTROID_STREAM);
        let centroids = (0..classes * dim)
            .map(|_| rng.uniform_in(-1.0, 1.0))
            .collect();
        BlobDataset { dim, classes, noise, seed, train_len, test_len,
                      shape: None, centroids }
    }

    /// Image-shaped blobs: `dim = h·w·c`, identical draws to the flat
    /// constructor at the same `dim` (the shape is pure metadata).
    pub fn with_shape(seed: u64, h: usize, w: usize, c: usize,
                      classes: usize, noise: f32, train_len: usize,
                      test_len: usize) -> Self {
        let mut d = BlobDataset::new(seed, h * w * c, classes, noise,
                                     train_len, test_len);
        d.shape = Some([h, w, c]);
        d
    }

    /// Deterministic sample `i` of the train (or test) split into `x`;
    /// returns the label.
    pub fn sample_into(&self, i: usize, test: bool, x: &mut [f32]) -> u8 {
        assert_eq!(x.len(), self.dim);
        let stream =
            if test { BLOB_TEST_STREAM } else { BLOB_TRAIN_STREAM };
        let mut rng = Pcg64::new(i as u64, stream);
        let class = (i % self.classes) as u8;
        let c = &self.centroids
            [class as usize * self.dim..(class as usize + 1) * self.dim];
        rng.fill_gaussian(x, 0.0, self.noise);
        for (v, &cv) in x.iter_mut().zip(c) {
            *v = cv + *v;
        }
        class
    }
}

/// Synthetic CIFAR images reduced to `(H/pool)·(W/pool)·C` features by
/// block average pooling (channels kept separate).
pub struct PooledCifar {
    pub data: SyntheticDataset,
    pub pool: usize,
}

impl PooledCifar {
    pub fn new(seed: u64, pool: usize, train_len: usize,
               test_len: usize) -> Self {
        assert!(pool > 0 && IMG_H % pool == 0 && IMG_W % pool == 0,
                "pool must divide the {IMG_H}x{IMG_W} image");
        PooledCifar { data: SyntheticDataset::new(seed, train_len,
                                                  test_len),
                      pool }
    }

    /// Pooled spatial extents `[h, w, c]` (HWC feature layout — the
    /// explicit metadata conv layers consume; `dim` is its product).
    pub fn shape(&self) -> [usize; 3] {
        [IMG_H / self.pool, IMG_W / self.pool, IMG_C]
    }

    pub fn dim(&self) -> usize {
        let [h, w, c] = self.shape();
        h * w * c
    }

    pub fn sample_into(&self, i: usize, test: bool, x: &mut [f32]) -> u8 {
        assert_eq!(x.len(), self.dim());
        let (img, label) = self.data.sample(i, test);
        pool_blocks_into(&img, self.pool, x);
        label
    }
}

/// Channel-preserving `p × p` block average pooling of one HWC image —
/// the single in-tree copy of the pooling loop, shared by the
/// synthetic and real CIFAR providers (identical f32 accumulation
/// order, so the synthetic provider's streams are untouched by the
/// refactor).
fn pool_blocks_into(img: &[f32], p: usize, x: &mut [f32]) {
    let (bh, bw) = (IMG_H / p, IMG_W / p);
    let inv_area = 1.0f32 / (p * p) as f32;
    for by in 0..bh {
        for bx in 0..bw {
            for c in 0..IMG_C {
                let mut acc = 0.0f32;
                for h in by * p..(by + 1) * p {
                    for w in bx * p..(bx + 1) * p {
                        acc += img[(h * IMG_W + w) * IMG_C + c];
                    }
                }
                x[(by * bw + bx) * IMG_C + c] = acc * inv_area;
            }
        }
    }
}

/// Real CIFAR-10 bytes ([`CifarDataset`]) behind the same pooled
/// feature interface as [`PooledCifar`]: `pool = 1` passes the
/// loader's normalized NHWC pixels straight through, larger pools
/// average `pool × pool` blocks per channel.
pub struct RealCifar {
    pub data: CifarDataset,
    pub pool: usize,
}

impl RealCifar {
    pub fn new(data: CifarDataset, pool: usize) -> Self {
        assert!(pool > 0 && IMG_H % pool == 0 && IMG_W % pool == 0,
                "pool must divide the {IMG_H}x{IMG_W} image");
        RealCifar { data, pool }
    }

    /// Pooled spatial extents `[h, w, c]` (HWC feature layout).
    pub fn shape(&self) -> [usize; 3] {
        [IMG_H / self.pool, IMG_W / self.pool, IMG_C]
    }

    pub fn dim(&self) -> usize {
        let [h, w, c] = self.shape();
        h * w * c
    }

    pub fn sample_into(&self, i: usize, test: bool, x: &mut [f32]) -> u8 {
        assert_eq!(x.len(), self.dim());
        pool_blocks_into(self.data.image(i, test), self.pool, x);
        self.data.label(i, test)
    }
}

/// One interface over the feature providers (see the module docs for
/// when to use which).
pub enum FeatureSource {
    Blobs(BlobDataset),
    Cifar(PooledCifar),
    RealCifar(RealCifar),
}

impl FeatureSource {
    /// Pooled CIFAR features from **real CIFAR-10 bytes** when a
    /// dataset directory is present ([`CifarDataset::discover`] —
    /// `$HIC_CIFAR10` or `data/cifar-10*`), falling back to the
    /// synthetic pipeline otherwise.  The real provider serves the
    /// full downloaded splits; `train_len`/`test_len` size the
    /// synthetic fallback only (the golden path, byte-for-byte
    /// unchanged by this routing).
    pub fn pooled_cifar_auto(seed: u64, pool: usize, train_len: usize,
                             test_len: usize) -> FeatureSource {
        FeatureSource::pooled_cifar_from(None, seed, pool, train_len,
                                         test_len)
    }

    /// [`pooled_cifar_auto`](FeatureSource::pooled_cifar_auto) with an
    /// optional **explicit** dataset directory: when `dir` is given
    /// (the experiment-spec `data { cifar { dir = "…" } }` route), it
    /// wins over discovery unconditionally; `None` falls back to
    /// [`CifarDataset::discover`] and then the synthetic pipeline.
    pub fn pooled_cifar_from(dir: Option<&Path>, seed: u64, pool: usize,
                             train_len: usize, test_len: usize)
                             -> FeatureSource {
        let dir = dir.map(Path::to_path_buf)
            .or_else(CifarDataset::discover);
        if let Some(dir) = dir {
            match CifarDataset::load(&dir) {
                Ok(data) => {
                    log_info!(
                        "using real CIFAR-10 from {} ({} train / {} \
                         test)",
                        dir.display(), data.train_len(),
                        data.test_len());
                    return FeatureSource::RealCifar(
                        RealCifar::new(data, pool));
                }
                Err(e) => {
                    log_info!(
                        "CIFAR-10 dir {} unreadable ({e:#}); using \
                         the synthetic pipeline",
                        dir.display());
                }
            }
        }
        FeatureSource::Cifar(
            PooledCifar::new(seed, pool, train_len, test_len))
    }

    pub fn dim(&self) -> usize {
        match self {
            FeatureSource::Blobs(b) => b.dim,
            FeatureSource::Cifar(c) => c.dim(),
            FeatureSource::RealCifar(c) => c.dim(),
        }
    }

    pub fn classes(&self) -> usize {
        match self {
            FeatureSource::Blobs(b) => b.classes,
            FeatureSource::Cifar(_) | FeatureSource::RealCifar(_) => {
                NUM_CLASSES
            }
        }
    }

    /// Activation shape of one sample: pooled CIFAR is always an image
    /// (`[h, w, c]` HWC); blobs are flat unless built with a spatial
    /// interpretation ([`BlobDataset::with_shape`]).
    pub fn shape(&self) -> ActShape {
        match self {
            FeatureSource::Blobs(b) => match b.shape {
                Some([h, w, c]) => ActShape::Img { h, w, c },
                None => ActShape::Flat(b.dim),
            },
            FeatureSource::Cifar(c) => {
                let [h, w, ch] = c.shape();
                ActShape::Img { h, w, c: ch }
            }
            FeatureSource::RealCifar(c) => {
                let [h, w, ch] = c.shape();
                ActShape::Img { h, w, c: ch }
            }
        }
    }

    pub fn train_len(&self) -> usize {
        match self {
            FeatureSource::Blobs(b) => b.train_len,
            FeatureSource::Cifar(c) => c.data.train_len,
            FeatureSource::RealCifar(c) => c.data.train_len(),
        }
    }

    pub fn test_len(&self) -> usize {
        match self {
            FeatureSource::Blobs(b) => b.test_len,
            FeatureSource::Cifar(c) => c.data.test_len,
            FeatureSource::RealCifar(c) => c.data.test_len(),
        }
    }

    /// Deterministic sample `i` of a split into `x`; returns the label.
    pub fn sample_into(&self, i: usize, test: bool, x: &mut [f32]) -> u8 {
        match self {
            FeatureSource::Blobs(b) => b.sample_into(i, test, x),
            FeatureSource::Cifar(c) => c.sample_into(i, test, x),
            FeatureSource::RealCifar(c) => c.sample_into(i, test, x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_samples_are_deterministic_and_split_dependent() {
        let d = BlobDataset::new(5, 8, 3, 0.4, 90, 30);
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        let ya = d.sample_into(7, false, &mut a);
        let yb = d.sample_into(7, false, &mut b);
        assert_eq!(a, b);
        assert_eq!(ya, yb);
        assert_eq!(ya, (7 % 3) as u8);
        let mut c = vec![0.0f32; 8];
        d.sample_into(7, true, &mut c);
        assert_ne!(a, c, "test split must use its own stream");
    }

    #[test]
    fn blob_classes_cycle_and_cluster() {
        let d = BlobDataset::new(9, 6, 3, 0.2, 300, 60);
        // Labels cycle; samples sit nearer their own centroid than the
        // global mean distance (low noise).
        let mut x = vec![0.0f32; 6];
        let mut correct = 0;
        for i in 0..60 {
            let y = d.sample_into(i, false, &mut x) as usize;
            assert_eq!(y, i % 3);
            let mut best = (f32::MAX, 0usize);
            for cl in 0..3 {
                let c = &d.centroids[cl * 6..(cl + 1) * 6];
                let dist: f32 = x.iter().zip(c)
                    .map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, cl);
                }
            }
            if best.1 == y {
                correct += 1;
            }
        }
        assert!(correct >= 55, "nearest-centroid acc {correct}/60");
    }

    #[test]
    fn pooled_cifar_shapes_and_labels() {
        let p = PooledCifar::new(1, 8, 100, 20);
        assert_eq!(p.dim(), 4 * 4 * 3);
        let mut x = vec![0.0f32; p.dim()];
        let y = p.sample_into(13, false, &mut x);
        assert_eq!(y, (13 % NUM_CLASSES) as u8);
        // Pooling must average, not sum: features stay image-scaled.
        assert!(x.iter().all(|v| v.abs() < 16.0));
        // Deterministic.
        let mut x2 = vec![0.0f32; p.dim()];
        p.sample_into(13, false, &mut x2);
        assert_eq!(x, x2);
    }

    #[test]
    fn feature_source_dispatch() {
        let s = FeatureSource::Blobs(BlobDataset::new(1, 4, 2, 0.3, 10, 4));
        assert_eq!(s.dim(), 4);
        assert_eq!(s.classes(), 2);
        assert_eq!(s.train_len(), 10);
        assert_eq!(s.test_len(), 4);
        assert_eq!(s.shape(), ActShape::Flat(4));
        let c = FeatureSource::Cifar(PooledCifar::new(1, 16, 50, 10));
        assert_eq!(c.dim(), 2 * 2 * 3);
        assert_eq!(c.classes(), NUM_CLASSES);
        assert_eq!(c.shape(), ActShape::Img { h: 2, w: 2, c: 3 });
    }

    #[test]
    fn real_cifar_fixture_round_trip() {
        use crate::data::cifar::{CifarDataset, RECORD_BYTES};
        use crate::data::IMG_ELEMS;

        // 3-image on-disk fixture: 2 train records + 1 test record in
        // the binary batch format, through the real loader and both
        // pooling configurations.
        fn record(label: u8) -> Vec<u8> {
            let mut rec = vec![label];
            for c in 0..3u32 {
                for i in 0..1024u32 {
                    rec.push(((i + c * 37) % 256) as u8);
                }
            }
            rec
        }
        let dir = std::env::temp_dir()
            .join(format!("hic_cifar_fixture_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut train = record(3);
        train.extend(record(7));
        assert_eq!(train.len(), 2 * RECORD_BYTES);
        std::fs::write(dir.join("data_batch_1.bin"), &train).unwrap();
        std::fs::write(dir.join("test_batch.bin"), record(1)).unwrap();

        let data = CifarDataset::load(&dir).unwrap();
        assert_eq!(data.train_len(), 2);
        assert_eq!(data.test_len(), 1);

        // pool = 1 is a pure pass-through of the loader's pixels.
        let rc = RealCifar::new(data, 1);
        assert_eq!(rc.dim(), IMG_ELEMS);
        let mut x = vec![0.0f32; rc.dim()];
        assert_eq!(rc.sample_into(1, false, &mut x), 7);
        assert_eq!(&x[..], rc.data.image(1, false));
        assert_eq!(rc.sample_into(0, true, &mut x), 1);
        assert_eq!(&x[..], rc.data.image(0, true));

        // pool = 2 averages each 2x2 block per channel.
        let rc2 = RealCifar::new(rc.data, 2);
        assert_eq!(rc2.shape(), [16, 16, 3]);
        let mut p = vec![0.0f32; rc2.dim()];
        assert_eq!(rc2.sample_into(0, false, &mut p), 3);
        let img = rc2.data.image(0, false);
        let want = (img[0] // (h=0, w=0, c=0)
            + img[IMG_C] // (0, 1, 0)
            + img[IMG_W * IMG_C] // (1, 0, 0)
            + img[(IMG_W + 1) * IMG_C]) // (1, 1, 0)
            * 0.25;
        assert_eq!(p[0], want);

        // And through the FeatureSource dispatch.
        let fs = FeatureSource::RealCifar(rc2);
        assert_eq!(fs.dim(), 16 * 16 * 3);
        assert_eq!(fs.classes(), NUM_CLASSES);
        assert_eq!(fs.train_len(), 2);
        assert_eq!(fs.test_len(), 1);
        assert_eq!(fs.shape(), ActShape::Img { h: 16, w: 16, c: 3 });
        let mut q = vec![0.0f32; fs.dim()];
        assert_eq!(fs.sample_into(0, false, &mut q), 3);
        assert_eq!(q, p);

        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn explicit_cifar_dir_beats_discovery() {
        use crate::data::cifar::RECORD_BYTES;

        fn record(label: u8) -> Vec<u8> {
            let mut rec = vec![label];
            rec.resize(RECORD_BYTES, 0x40);
            rec
        }
        let dir = std::env::temp_dir().join(format!(
            "hic_cifar_explicit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut train = record(3);
        train.extend(record(7));
        std::fs::write(dir.join("data_batch_1.bin"), &train).unwrap();
        std::fs::write(dir.join("test_batch.bin"), record(1)).unwrap();

        // An explicit directory is loaded without consulting
        // discovery…
        let fs = FeatureSource::pooled_cifar_from(
            Some(&dir), 1, 2, 50, 10);
        let FeatureSource::RealCifar(rc) = &fs else {
            panic!("explicit dir must route to the real loader");
        };
        assert_eq!(rc.data.train_len(), 2);
        assert_eq!(rc.data.test_len(), 1);
        assert_eq!(rc.pool, 2);

        // …and an explicit-but-unreadable directory falls back to the
        // synthetic pipeline instead of trying discovery: the explicit
        // path always wins.
        let bogus = dir.join("definitely_missing");
        let fs = FeatureSource::pooled_cifar_from(
            Some(&bogus), 1, 2, 50, 10);
        assert!(matches!(fs, FeatureSource::Cifar(_)),
                "unreadable explicit dir must fall back to synthetic");

        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shaped_blobs_draw_identically_to_flat() {
        // The spatial interpretation is pure metadata: same seed and
        // dim, bit-identical samples.
        let flat = BlobDataset::new(9, 4 * 4 * 2, 3, 0.4, 30, 12);
        let img = BlobDataset::with_shape(9, 4, 4, 2, 3, 0.4, 30, 12);
        assert_eq!(img.dim, 32);
        assert_eq!(img.shape, Some([4, 4, 2]));
        let mut a = vec![0.0f32; 32];
        let mut b = vec![0.0f32; 32];
        for i in [0usize, 7, 19] {
            for test in [false, true] {
                let ya = flat.sample_into(i, test, &mut a);
                let yb = img.sample_into(i, test, &mut b);
                assert_eq!(ya, yb);
                assert_eq!(a, b);
            }
        }
        let s = FeatureSource::Blobs(
            BlobDataset::with_shape(9, 4, 4, 2, 3, 0.4, 30, 12));
        assert_eq!(s.shape(), ActShape::Img { h: 4, w: 4, c: 2 });
    }
}
