//! Layer-graph IR for on-grid networks: the device-side layer kinds the
//! paper's ResNet topology needs, each weighted layer on its own
//! [`CrossbarGrid`].
//!
//! Three levels:
//!
//! * [`GraphSpec`] / [`LayerSpec`] — the builder IR: `Dense`, `Conv2d`,
//!   `Relu`, `GlobalAvgPool`, `Residual` (skip-add, auto 1×1 projection
//!   when the body changes shape) and the trailing `Softmax` head, with
//!   explicit activation shapes ([`ActShape`], HWC layout for images).
//!   [`GraphSpec::mlp`] reproduces the PR-3 dense stack;
//!   [`GraphSpec::resnet`] builds the paper's `3 → 16w → 32w → 64w`
//!   stage structure with stride-2 downsampling residual stages
//!   ([`resnet_spec`] for the paper's channel bases).
//! * [`GraphPlan`] / [`PlanLayer`] — the resolved plan: shapes
//!   inferred, projections materialized, weighted layers indexed in
//!   DFS order (residual body first, then projection).  Shared by the
//!   device graph and the FP32 baseline so both assign identical
//!   per-layer seeds and `w_max` windows.
//! * [`GraphNet`] / [`Layer`] — the device network.  Every weighted
//!   layer owns a [`CrossbarGrid`] with `w_max = w_scale/√fan_in` and
//!   its own grid seed (`layer_seed(seed, weighted_index)`); `Conv2d`
//!   is lowered **weight-stationary** onto one `[kh·kw·cin, cout]`
//!   grid (`crossbar::conv`): the forward VMM streams patch segments
//!   on demand from the layer's once-DAC'd input image
//!   ([`ConvPatchSource`] through `vmm_batch_src_into`), backprop
//!   drains the transposed analog VMM (`vmm_t_batch_with`) straight
//!   through the fused col2im scatter, and the digital weight gradient
//!   streams one patch column at a time — no `[m·P, K]` patch matrix
//!   exists on the default path.  [`ConvLowering`] keeps the PR-4
//!   materialized im2col/col2im pair selectable
//!   (`HIC_CONV_LOWERING=materialized`); the two are **bit-identical**
//!   — a pure perf knob.  Each conv layer caches its [`PatchPlan`]
//!   (all derived lowering extents) at build time instead of
//!   re-deriving geometry every forward/backward call.
//!
//! RNG op-stream assignment: the patch kernels consume no RNG, and the
//! patch VMM is one grid invocation of the tile-stationary
//! sample-blocked strips (shard = column/row strip × sample block, one
//! `(op, tile, sample)` read-noise sub-stream per patch row on the
//! grid's `OP_VMM` / `OP_VMM_T` op tags) whatever the lowering, so the
//! grid determinism contract — bitwise identical for any worker count
//! and any sample-block size — extends to the conv path unchanged
//! (`rust/tests/prop_conv_equivalence.rs`).  All buffers (image/column
//! staging, activation caches, deltas) live in the layer state and are
//! reused across steps: the training loop allocates nothing per batch
//! once warm.
//!
//! Pipelined backward ([`GraphNet::backward_update_pipelined`]): the
//! backward walk is split per weighted layer into a **foreground** half
//! (error snapshot + transposed VMM, on the calling thread's pool) and
//! a **background** chain (digital outer-product gradient → hybrid
//! update → due refresh, on a [`PipelineScope`] lane) so layer `i`'s
//! gradient/update overlaps layer `i−1`'s VMM.  The per-layer `dout`
//! snapshot exists because the shared delta ping/pong buffers are
//! recycled as the walk descends; a memcpy is bitwise-neutral where
//! recomputation would not be.  Since every stochastic kernel draws
//! from counter-based `(op, tile[, sample])` sub-streams keyed only on
//! `(seed, round)` and weighted layers own disjoint grids, the overlap
//! is pure scheduling — outputs are bitwise identical to the
//! phase-serial `backward` + `apply_updates` + `refresh` sequence at
//! any worker count (`rust/tests/prop_pipeline_equivalence.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::crossbar::conv::{col2im_into, col2im_stream_into,
                            conv_grad_into, im2col_into,
                            ConvPatchSource, PatchGeom, PatchPlan};
use crate::crossbar::grid::CrossbarGrid;
use crate::crossbar::{AdcSpec, DacSpec, GridScratch, TilingPolicy};
use crate::hic::weight::HicGeometry;
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::{PipelineScope, WorkerPool};
use crate::util::rng::Pcg64;

use super::net::{layer_seed, scaled_width, INIT_STREAM};

/// Activation shape flowing between layers.  Images are HWC row-major
/// (`[h, w, c]`), matching the pooled-CIFAR feature layout, so
/// flattening for a `Dense` layer is a no-op on the buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActShape {
    Flat(usize),
    Img { h: usize, w: usize, c: usize },
}

impl ActShape {
    /// Flat activation length per sample.
    pub fn len(&self) -> usize {
        match *self {
            ActShape::Flat(n) => n,
            ActShape::Img { h, w, c } => h * w * c,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Builder-level layer kinds (no device state).
#[derive(Clone, Debug)]
pub enum LayerSpec {
    /// Fully connected `flat(in) → out` (image inputs flatten in place).
    Dense { out: usize },
    /// 2-D convolution, HWC, square stride, symmetric zero padding.
    Conv2d { cout: usize, kh: usize, kw: usize, stride: usize, pad: usize },
    Relu,
    /// Spatial mean per channel: `[h, w, c] → c`.
    GlobalAvgPool,
    /// Skip-add residual block: `out = body(x) + skip(x)`.  When the
    /// body changes shape, a 1×1 strided projection conv is inserted on
    /// the skip automatically; identity otherwise.
    Residual { body: Vec<LayerSpec> },
    /// Classification head marker — must be the final layer.  The
    /// trainer fuses softmax with the cross-entropy loss, so this layer
    /// carries no device state.
    Softmax,
}

/// An architecture: input shape plus the layer chain (ending in
/// `Softmax`).
#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub input: ActShape,
    pub layers: Vec<LayerSpec>,
}

impl GraphSpec {
    /// The PR-3 dense stack as a graph: `dims = [input, hidden.., classes]`
    /// becomes `Dense/Relu/…/Dense/Softmax`.  Weighted-layer indices
    /// (and so per-layer grid seeds) match the original `DeviceNet`
    /// layer numbering, which keeps the dense fig4 golden byte-stable
    /// across the refactor.
    pub fn mlp(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let mut layers = Vec::with_capacity(2 * (dims.len() - 1));
        for (l, &n) in dims[1..].iter().enumerate() {
            layers.push(LayerSpec::Dense { out: n });
            if l + 2 < dims.len() {
                layers.push(LayerSpec::Relu);
            }
        }
        layers.push(LayerSpec::Softmax);
        GraphSpec { input: ActShape::Flat(dims[0]), layers }
    }

    /// ResNet-style stage structure on an `[h, w, c]` input: a 3×3 stem
    /// into `stage_bases[0]` channels, then three stages of `blocks`
    /// residual blocks each (two 3×3 convs per block, stride-2 first
    /// block in stages 2 and 3, auto 1×1 projection on the skip when
    /// shape changes), global average pooling and a dense softmax head.
    /// Channel counts are `scaled_width(base, width_permille)` — the
    /// paper's width-multiplier axis.
    pub fn resnet(input: [usize; 3], stage_bases: [usize; 3],
                  blocks: usize, classes: usize,
                  width_permille: u32) -> Self {
        assert!(blocks >= 1, "need at least one block per stage");
        let [h, w, c] = input;
        let chans: Vec<usize> = stage_bases
            .iter()
            .map(|&b| scaled_width(b, width_permille))
            .collect();
        let mut layers = Vec::new();
        layers.push(LayerSpec::Conv2d {
            cout: chans[0], kh: 3, kw: 3, stride: 1, pad: 1,
        });
        layers.push(LayerSpec::Relu);
        for (si, &ch) in chans.iter().enumerate() {
            for b in 0..blocks {
                let stride = if si > 0 && b == 0 { 2 } else { 1 };
                layers.push(LayerSpec::Residual {
                    body: vec![
                        LayerSpec::Conv2d {
                            cout: ch, kh: 3, kw: 3, stride, pad: 1,
                        },
                        LayerSpec::Relu,
                        LayerSpec::Conv2d {
                            cout: ch, kh: 3, kw: 3, stride: 1, pad: 1,
                        },
                    ],
                });
                layers.push(LayerSpec::Relu);
            }
        }
        layers.push(LayerSpec::GlobalAvgPool);
        layers.push(LayerSpec::Dense { out: classes });
        layers.push(LayerSpec::Softmax);
        GraphSpec { input: ActShape::Img { h, w, c }, layers }
    }

    /// Non-panicking mirror of [`GraphSpec::plan`]'s shape inference:
    /// walk the layer chain, propagate activation shapes, and return
    /// the pre-softmax shape — or a human-readable description of the
    /// first inconsistency.  The experiment-spec DSL validates custom
    /// graphs through this (so a bad spec is a spanned diagnostic, not
    /// a panic); `plan` keeps its assertions as the internal contract.
    pub fn shape_check(&self) -> std::result::Result<ActShape, String> {
        let nl = self.layers.len();
        if nl < 2 {
            return Err("graph needs at least one layer plus the \
                        softmax head"
                .to_string());
        }
        if !matches!(self.layers[nl - 1], LayerSpec::Softmax) {
            return Err("graph must end with the softmax head"
                .to_string());
        }
        let mut shape = self.input;
        check_layers(&self.layers[..nl - 1], &mut shape)?;
        match shape {
            ActShape::Flat(n) if n > 0 => Ok(shape),
            ActShape::Img { h: 1, w: 1, c } if c > 0 => Ok(shape),
            other => Err(format!(
                "the softmax head needs a flat input, got {other:?}")),
        }
    }

    /// Resolve shapes, materialize skip projections and index the
    /// weighted layers.  Panics on malformed specs (conv on flat input,
    /// misplaced softmax, impossible residual shapes).
    pub fn plan(&self) -> GraphPlan {
        let nl = self.layers.len();
        assert!(nl >= 2, "graph needs at least one layer plus Softmax");
        assert!(matches!(self.layers[nl - 1], LayerSpec::Softmax),
                "graph must end with the Softmax head");
        let mut weighted = Vec::new();
        let mut shape = self.input;
        let layers =
            plan_layers(&self.layers[..nl - 1], &mut shape, &mut weighted);
        let classes = match shape {
            ActShape::Flat(n) => n,
            ActShape::Img { h: 1, w: 1, c } => c,
            other => panic!("softmax head needs a flat input, got {other:?}"),
        };
        GraphPlan { input: self.input, classes, layers, weighted }
    }
}

/// The paper's ResNet family on the device graph: channel bases
/// `[16, 32, 64]`, `blocks` residual blocks per stage (ResNet-32 is
/// `blocks = 5`: 6·5 + 2 weighted layers).
pub fn resnet_spec(width_permille: u32, blocks: usize,
                   input: [usize; 3], classes: usize) -> GraphSpec {
    GraphSpec::resnet(input, [16, 32, 64], blocks, classes, width_permille)
}

/// One weighted layer resolved to its grid extents (`k` = fan-in rows,
/// `n` = fan-out columns); `index` is the DFS weighted-layer index the
/// per-layer seed derives from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WeightDesc {
    pub index: usize,
    pub k: usize,
    pub n: usize,
}

/// Resolved layer plan (shapes inferred, projections explicit).
#[derive(Clone, Debug)]
pub enum PlanLayer {
    Dense { widx: usize, k: usize, n: usize },
    Conv { widx: usize, geom: PatchGeom },
    Relu { len: usize },
    GlobalAvgPool { h: usize, w: usize, c: usize },
    Residual {
        body: Vec<PlanLayer>,
        /// always a `PlanLayer::Conv` (1×1 strided projection)
        proj: Option<Box<PlanLayer>>,
        in_len: usize,
        out_len: usize,
    },
}

/// A fully resolved graph: what both [`GraphNet`] and the FP32 baseline
/// build from, so their weighted layers line up one to one.
#[derive(Clone, Debug)]
pub struct GraphPlan {
    pub input: ActShape,
    pub classes: usize,
    pub layers: Vec<PlanLayer>,
    pub weighted: Vec<WeightDesc>,
}

impl GraphPlan {
    /// Total weight count across weighted layers.
    pub fn weights(&self) -> usize {
        self.weighted.iter().map(|d| d.k * d.n).sum()
    }
}

fn push_weighted(weighted: &mut Vec<WeightDesc>, k: usize,
                 n: usize) -> usize {
    let index = weighted.len();
    weighted.push(WeightDesc { index, k, n });
    index
}

fn plan_layers(specs: &[LayerSpec], shape: &mut ActShape,
               weighted: &mut Vec<WeightDesc>) -> Vec<PlanLayer> {
    specs.iter().map(|s| plan_layer(s, shape, weighted)).collect()
}

fn plan_layer(spec: &LayerSpec, shape: &mut ActShape,
              weighted: &mut Vec<WeightDesc>) -> PlanLayer {
    match spec {
        LayerSpec::Dense { out } => {
            let k = shape.len();
            assert!(k > 0 && *out > 0, "dense layer with empty extent");
            let widx = push_weighted(weighted, k, *out);
            *shape = ActShape::Flat(*out);
            PlanLayer::Dense { widx, k, n: *out }
        }
        LayerSpec::Conv2d { cout, kh, kw, stride, pad } => {
            let ActShape::Img { h, w, c } = *shape else {
                panic!("Conv2d needs an image input, got {shape:?}");
            };
            let geom = PatchGeom {
                in_h: h, in_w: w, cin: c,
                kh: *kh, kw: *kw, cout: *cout,
                stride: *stride, pad: *pad,
            };
            let widx = push_weighted(weighted, geom.patch_len(), *cout);
            *shape = ActShape::Img {
                h: geom.out_h(), w: geom.out_w(), c: *cout,
            };
            PlanLayer::Conv { widx, geom }
        }
        LayerSpec::Relu => PlanLayer::Relu { len: shape.len() },
        LayerSpec::GlobalAvgPool => {
            let ActShape::Img { h, w, c } = *shape else {
                panic!("GlobalAvgPool needs an image input, got {shape:?}");
            };
            *shape = ActShape::Flat(c);
            PlanLayer::GlobalAvgPool { h, w, c }
        }
        LayerSpec::Residual { body } => {
            assert!(!body.is_empty(),
                    "residual block needs a non-empty body");
            let in_shape = *shape;
            let mut bshape = in_shape;
            let body_plan = plan_layers(body, &mut bshape, weighted);
            let proj = if bshape == in_shape {
                None
            } else {
                let (ActShape::Img { h: ih, w: iw, c: ic },
                     ActShape::Img { h: oh, w: ow, c: oc }) =
                    (in_shape, bshape)
                else {
                    panic!("residual shape change needs image shapes \
                            ({in_shape:?} -> {bshape:?})");
                };
                // 1×1 projection with the body's downsampling stride.
                assert!(oh > 0 && ow > 0, "residual body collapsed");
                let stride = ih.div_ceil(oh);
                let geom = PatchGeom {
                    in_h: ih, in_w: iw, cin: ic,
                    kh: 1, kw: 1, cout: oc,
                    stride, pad: 0,
                };
                assert_eq!((geom.out_h(), geom.out_w()), (oh, ow),
                           "no 1x1 projection matches the body's \
                            {ih}x{iw} -> {oh}x{ow} downsampling");
                let widx = push_weighted(weighted, ic, oc);
                Some(Box::new(PlanLayer::Conv { widx, geom }))
            };
            *shape = bshape;
            PlanLayer::Residual {
                body: body_plan,
                proj,
                in_len: in_shape.len(),
                out_len: bshape.len(),
            }
        }
        LayerSpec::Softmax => {
            panic!("Softmax must be the final layer of the graph")
        }
    }
}

fn check_layers(specs: &[LayerSpec], shape: &mut ActShape)
                -> std::result::Result<(), String> {
    for s in specs {
        check_layer(s, shape)?;
    }
    Ok(())
}

fn check_layer(spec: &LayerSpec, shape: &mut ActShape)
               -> std::result::Result<(), String> {
    match spec {
        LayerSpec::Dense { out } => {
            if shape.is_empty() || *out == 0 {
                return Err(format!(
                    "dense layer with empty extent \
                     ({} -> {out} units)", shape.len()));
            }
            *shape = ActShape::Flat(*out);
        }
        LayerSpec::Conv2d { cout, kh, kw, stride, pad } => {
            let ActShape::Img { h, w, c } = *shape else {
                return Err(format!(
                    "conv needs an image input, got a flat vector of \
                     {} values", shape.len()));
            };
            if *cout == 0 || *kh == 0 || *kw == 0 || c == 0 {
                return Err("conv layer with empty extent".to_string());
            }
            if *stride == 0 {
                return Err("conv stride must be at least 1".to_string());
            }
            if h + 2 * pad < *kh || w + 2 * pad < *kw {
                return Err(format!(
                    "conv kernel {kh}x{kw} does not fit the padded \
                     {h}x{w} input (pad {pad})"));
            }
            let geom = PatchGeom {
                in_h: h, in_w: w, cin: c,
                kh: *kh, kw: *kw, cout: *cout,
                stride: *stride, pad: *pad,
            };
            *shape = ActShape::Img {
                h: geom.out_h(), w: geom.out_w(), c: *cout,
            };
        }
        LayerSpec::Relu => {}
        LayerSpec::GlobalAvgPool => {
            let ActShape::Img { c, .. } = *shape else {
                return Err(format!(
                    "gap needs an image input, got a flat vector of \
                     {} values", shape.len()));
            };
            *shape = ActShape::Flat(c);
        }
        LayerSpec::Residual { body } => {
            if body.is_empty() {
                return Err("residual block needs a non-empty body"
                    .to_string());
            }
            let in_shape = *shape;
            let mut bshape = in_shape;
            check_layers(body, &mut bshape)?;
            if bshape != in_shape {
                let (ActShape::Img { h: ih, w: iw, c: ic },
                     ActShape::Img { h: oh, w: ow, c: oc }) =
                    (in_shape, bshape)
                else {
                    return Err(format!(
                        "residual shape change needs image shapes \
                         ({in_shape:?} -> {bshape:?})"));
                };
                if oh == 0 || ow == 0 || oc == 0 {
                    return Err("residual body collapsed to an empty \
                                shape".to_string());
                }
                let stride = ih.div_ceil(oh);
                let geom = PatchGeom {
                    in_h: ih, in_w: iw, cin: ic,
                    kh: 1, kw: 1, cout: oc,
                    stride, pad: 0,
                };
                if (geom.out_h(), geom.out_w()) != (oh, ow) {
                    return Err(format!(
                        "no 1x1 projection matches the residual \
                         body's {ih}x{iw} -> {oh}x{ow} downsampling"));
                }
            }
            *shape = bshape;
        }
        LayerSpec::Softmax => {
            return Err("softmax must be the final layer of the graph"
                .to_string());
        }
    }
    Ok(())
}

/// Whether any layer in the chain (residual bodies included) is a
/// convolution — decides the default `w_scale` the experiment runner
/// picks for custom graphs (conv nets train with the wider ResNet
/// window).
pub fn has_conv(layers: &[LayerSpec]) -> bool {
    layers.iter().any(|l| match l {
        LayerSpec::Conv2d { .. } => true,
        LayerSpec::Residual { body } => has_conv(body),
        _ => false,
    })
}

/// Number of weighted (grid-backed) layers a spec list declares —
/// `Dense` and `Conv2d`, residual bodies included.  Auto-inserted skip
/// projections are not counted: they inherit the body's already-scaled
/// channel count at plan time.
pub fn count_weighted(layers: &[LayerSpec]) -> usize {
    layers.iter().map(|l| match l {
        LayerSpec::Dense { .. } | LayerSpec::Conv2d { .. } => 1,
        LayerSpec::Residual { body } => count_weighted(body),
        _ => 0,
    }).sum()
}

/// Apply the paper's width-multiplier axis to a custom layer chain:
/// scale every weighted layer's fan-out (`Dense.out` / `Conv2d.cout`)
/// through [`scaled_width`] — except the last weighted layer, the
/// classifier head, whose width is the class count.  Mirrors what
/// [`GraphSpec::mlp`]-via-`scaled_dims` and [`GraphSpec::resnet`] do
/// for the built-in architectures.
pub fn scale_widths(layers: &mut [LayerSpec], width_permille: u32) {
    let total = count_weighted(layers);
    let mut idx = 0usize;
    scale_walk(layers, width_permille, total, &mut idx);
}

fn scale_walk(layers: &mut [LayerSpec], width_permille: u32,
              total: usize, idx: &mut usize) {
    for l in layers.iter_mut() {
        match l {
            LayerSpec::Dense { out } => {
                if *idx + 1 < total {
                    *out = scaled_width(*out, width_permille);
                }
                *idx += 1;
            }
            LayerSpec::Conv2d { cout, .. } => {
                if *idx + 1 < total {
                    *cout = scaled_width(*cout, width_permille);
                }
                *idx += 1;
            }
            LayerSpec::Residual { body } => {
                scale_walk(body, width_permille, total, idx);
            }
            _ => {}
        }
    }
}

// -- device layers -------------------------------------------------------

/// Grow a reusable buffer to at least `need` elements (shared with the
/// FP32 graph baseline — the two nets must grow buffers identically).
#[inline]
pub(crate) fn ensure(buf: &mut Vec<f32>, need: usize) {
    if buf.len() < need {
        buf.resize(need, 0.0);
    }
}

/// Digital weight gradient: input outer product over `rows` sample (or
/// patch) rows, batch-mean — `grad[i, j] = inv_m · Σ_r in[r, i]·d[r, j]`.
/// One shared kernel so the phase-serial backward and the pipelined
/// gradient stage are the same f32 op sequence, bit for bit.
fn outer_product_grad(input: &[f32], d_out: &[f32], grad: &mut [f32],
                      rows: usize, k: usize, n: usize, inv_m: f32) {
    for i in 0..k {
        for j in 0..n {
            let mut acc = 0.0f32;
            for r in 0..rows {
                acc += input[r * k + i] * d_out[r * n + j];
            }
            grad[i * n + j] = acc * inv_m;
        }
    }
}

/// Per-layer calibration-gain context of a forward pass — the
/// AdaBS-style statistics hook of the drift-compensated serving path
/// (`serve::ModelSnapshot`).  All slices are indexed by weighted-layer
/// index (`widx`); stateless layers never touch it.
pub enum GainCtx<'a> {
    /// Training/eval forward: no gain work at all (the historical
    /// byte-identical path).
    Off,
    /// Serving forward: multiply each weighted layer's output by its
    /// calibration gain.  A gain of exactly `1.0` skips the multiply,
    /// so a freshly-frozen snapshot (all gains `1.0`) is bitwise
    /// identical to `Off`.
    Apply(&'a [f32]),
    /// Freeze-time calibration pass: record each weighted layer's
    /// mean-absolute output as the reference statistic (gains stay
    /// `1.0`, outputs untouched).
    MeasureRefs(&'a mut [f32]),
    /// Recalibration pass at serving time: re-measure each weighted
    /// layer's statistic on the drifted device, set
    /// `gain = ref / current` and apply it immediately — so deeper
    /// layers are measured on already-compensated activations, exactly
    /// like the freeze-time pass saw them (layerwise AdaBS, Joshi et
    /// al. 2019).
    Recalibrate { refs: &'a [f32], gains: &'a mut [f32] },
}

/// Mean absolute value of one weighted layer's output — the AdaBS-ish
/// per-layer statistic of the calibration passes.  f64 accumulation in
/// index order, rounded to f32 once; mirrored op for op by the oracle
/// (sequential Python `float` loop), so recalibrated gains are
/// bit-stable.
fn mean_abs(v: &[f32]) -> f32 {
    let mut acc = 0f64;
    for &x in v {
        acc += x.abs() as f64;
    }
    (acc / v.len() as f64) as f32
}

/// The post-VMM gain hook every weighted layer's forward runs (see
/// [`GainCtx`]).
fn weighted_out(gain: &mut GainCtx<'_>, widx: usize, out: &mut [f32]) {
    match gain {
        GainCtx::Off => {}
        GainCtx::Apply(gains) => {
            let g = gains[widx];
            if g != 1.0 {
                for v in out.iter_mut() {
                    *v *= g;
                }
            }
        }
        GainCtx::MeasureRefs(refs) => {
            refs[widx] = mean_abs(out);
        }
        GainCtx::Recalibrate { refs, gains } => {
            let cur = mean_abs(out);
            let g = if cur == 0.0 { 1.0 } else { refs[widx] / cur };
            gains[widx] = g;
            if g != 1.0 {
                for v in out.iter_mut() {
                    *v *= g;
                }
            }
        }
    }
}

/// Per-invocation forward context.  `sample_base` is the global id of
/// the batch's first sample (0 on every training/eval path): weighted
/// layers pass it through to the grid's per-(op, tile, sample) RNG
/// sub-streams, so served outputs depend on a request's global trace
/// id, never on how requests were coalesced.  Conv layers scale it by
/// their patch count (patch row `p` of global sample `g` draws stream
/// id `g·P + p` — contiguous and disjoint across samples).
struct FwdCtx<'a> {
    t_now: f32,
    round: u64,
    pool: &'a WorkerPool,
    sample_base: u64,
    gain: GainCtx<'a>,
}

/// Per-invocation backward context (`gain`/`inv_gain` is the backward
/// DAC ranging of the transposed VMMs; `inv_m` the batch-mean factor of
/// the digital weight gradients).
struct BwdCtx<'a> {
    t_now: f32,
    round: u64,
    pool: &'a WorkerPool,
    gain: f32,
    inv_gain: f32,
    inv_m: f32,
}

/// Build one weighted layer's grid: `w_max = w_scale/√fan_in`, init
/// weights uniform in `±w_max/2` from the layer's init stream,
/// MSB-programmed at `t = 0`, `round = 0`.
fn make_grid(params: PcmParams, policy: TilingPolicy, w_scale: f32,
             seed: u64, widx: usize, k: usize, n: usize,
             pool: &WorkerPool) -> CrossbarGrid {
    let w_max = w_scale / (k as f32).sqrt();
    let geom = HicGeometry { w_max, ..Default::default() };
    let ls = layer_seed(seed, widx);
    let mut grid = CrossbarGrid::new(params, geom, k, n, policy,
                                     DacSpec::default(),
                                     AdcSpec::default(), ls);
    let mut rng = Pcg64::new(ls, INIT_STREAM);
    let half = 0.5 * w_max;
    let w0: Vec<f32> =
        (0..k * n).map(|_| rng.uniform_in(-half, half)).collect();
    grid.program_init(&w0, 0.0, 0, pool);
    grid
}

/// Fully connected layer on its own grid.
pub struct DenseLayer {
    pub widx: usize,
    pub k: usize,
    pub n: usize,
    pub grid: CrossbarGrid,
    scratch: GridScratch,
    /// cached input activations `[m, k]` (backward outer product)
    input: Vec<f32>,
    /// digital weight gradient `[k, n]`
    grad: Vec<f32>,
    /// gain-scaled error staging `[m, n]`
    escaled: Vec<f32>,
    /// transposed-VMM output staging `[m, k]`
    dtmp: Vec<f32>,
    /// pipelined-backward error snapshot `[m, n]`: the shared delta
    /// ping/pong buffers are overwritten as the backward walk descends,
    /// so the layer keeps its own copy for the deferred gradient stage
    dout: Vec<f32>,
}

impl DenseLayer {
    fn new(widx: usize, k: usize, n: usize, params: PcmParams,
           policy: TilingPolicy, w_scale: f32, seed: u64,
           pool: &WorkerPool) -> Self {
        let grid = make_grid(params, policy, w_scale, seed, widx, k, n,
                             pool);
        let scratch = grid.scratch();
        DenseLayer {
            widx, k, n, grid, scratch,
            input: Vec::new(),
            grad: vec![0.0; k * n],
            escaled: Vec::new(),
            dtmp: Vec::new(),
            dout: Vec::new(),
        }
    }

    fn forward(&mut self, x: &[f32], m: usize, ctx: &mut FwdCtx,
               out: &mut Vec<f32>) {
        let (k, n) = (self.k, self.n);
        ensure(&mut self.input, m * k);
        self.input[..m * k].copy_from_slice(&x[..m * k]);
        ensure(out, m * n);
        self.grid.vmm_batch_base_into(&self.input[..m * k], m,
                                      ctx.t_now, ctx.round,
                                      ctx.sample_base, ctx.pool,
                                      &mut self.scratch,
                                      &mut out[..m * n]);
        weighted_out(&mut ctx.gain, self.widx, &mut out[..m * n]);
    }

    fn backward(&mut self, d_out: &[f32], m: usize, ctx: &BwdCtx,
                d_in: &mut Vec<f32>, need_input_grad: bool) {
        let (k, n) = (self.k, self.n);
        outer_product_grad(&self.input, d_out, &mut self.grad, m, k, n,
                           ctx.inv_m);
        if need_input_grad {
            self.backward_err_vmm(d_out, m, ctx, d_in);
        }
    }

    /// The transposed-VMM half of the backward pass (shared verbatim by
    /// the phase-serial and pipelined walks — same buffers, same f32
    /// ops, same RNG streams).
    fn backward_err_vmm(&mut self, d_out: &[f32], m: usize,
                        ctx: &BwdCtx, d_in: &mut Vec<f32>) {
        let (k, n) = (self.k, self.n);
        ensure(&mut self.escaled, m * n);
        for (ev, &dv) in self.escaled[..m * n]
            .iter_mut()
            .zip(&d_out[..m * n])
        {
            *ev = dv * ctx.gain;
        }
        ensure(&mut self.dtmp, m * k);
        self.grid.vmm_t_batch_into(&self.escaled[..m * n], m,
                                   ctx.t_now, ctx.round, ctx.pool,
                                   &mut self.scratch,
                                   &mut self.dtmp[..m * k]);
        ensure(d_in, m * k);
        for (di, &dv) in d_in[..m * k]
            .iter_mut()
            .zip(&self.dtmp[..m * k])
        {
            *di = dv * ctx.inv_gain;
        }
    }

    /// Pipelined-backward foreground half: snapshot the error (the
    /// shared delta buffer is recycled as the walk descends) and run
    /// the transposed VMM; the digital gradient + hybrid update run in
    /// the background stages ([`GradUpdate`]).
    fn backward_vmm(&mut self, d_out: &[f32], m: usize, ctx: &BwdCtx,
                    d_in: &mut Vec<f32>, need_input_grad: bool) {
        let n = self.n;
        ensure(&mut self.dout, m * n);
        self.dout[..m * n].copy_from_slice(&d_out[..m * n]);
        if need_input_grad {
            self.backward_err_vmm(d_out, m, ctx, d_in);
        }
    }
}

/// How a [`ConvLayer`] lowers its patches onto the grid.  Both paths
/// are **bit-identical** (`rust/tests/prop_conv_equivalence.rs`) —
/// this is a performance knob, never a correctness one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvLowering {
    /// Weight-stationary streaming (the default): forward patch
    /// segments generated on demand from the once-DAC'd input image
    /// ([`ConvPatchSource`]), backward col2im fused into the
    /// transposed-VMM drain, weight gradient streamed one patch
    /// column at a time — the `[m·P, K]` patch matrix never exists.
    Streamed,
    /// The PR-4 materialize-then-VMM path (im2col / col2im), retained
    /// as the equivalence reference and bench baseline.
    Materialized,
}

impl ConvLowering {
    /// `HIC_CONV_LOWERING=materialized` selects the materialized
    /// path; anything else (including unset) streams.
    pub fn from_env() -> Self {
        match std::env::var("HIC_CONV_LOWERING") {
            Ok(v) if v == "materialized" => ConvLowering::Materialized,
            _ => ConvLowering::Streamed,
        }
    }
}

/// Convolution layer: weight-stationary lowering onto one
/// `[kh·kw·cin, cout]` grid (see [`ConvLowering`] for the two
/// bit-identical patch paths).
pub struct ConvLayer {
    pub widx: usize,
    pub geom: PatchGeom,
    /// cached lowering plan — every derived extent computed once at
    /// build time (out_h/out_w, positions, patch_len, in/out_len)
    plan: PatchPlan,
    lowering: ConvLowering,
    pub grid: CrossbarGrid,
    scratch: GridScratch,
    /// streamed path: cached raw input `[m, in_len]` (the gradient
    /// stage's patch-column staging source)
    xin: Vec<f32>,
    /// streamed path: once-DAC'd input image `[m, in_len]` (the
    /// forward patch source — each pixel quantized once, not per tap)
    qimg: Vec<f32>,
    /// streamed path: one patch-column staging buffer `[m·P]`
    gcol: Vec<f32>,
    /// materialized path: cached patch matrix `[m·P, K]` (forward
    /// input and backward outer product)
    patches: Vec<f32>,
    /// materialized path: transposed-VMM patch-gradient staging
    /// `[m·P, K]`
    dpatches: Vec<f32>,
    /// digital weight gradient `[K, cout]`
    grad: Vec<f32>,
    /// gain-scaled error staging `[m·P, cout]`
    escaled: Vec<f32>,
    /// pipelined-backward error snapshot `[m·P, cout]` (see
    /// [`DenseLayer`]'s `dout`)
    dout: Vec<f32>,
}

impl ConvLayer {
    fn new(widx: usize, geom: PatchGeom, params: PcmParams,
           policy: TilingPolicy, w_scale: f32, seed: u64,
           pool: &WorkerPool) -> Self {
        let plan = PatchPlan::new(geom);
        let (k, n) = (plan.patch_len, geom.cout);
        let grid = make_grid(params, policy, w_scale, seed, widx, k, n,
                             pool);
        let scratch = grid.scratch();
        ConvLayer {
            widx, geom, plan,
            lowering: ConvLowering::from_env(),
            grid, scratch,
            xin: Vec::new(),
            qimg: Vec::new(),
            gcol: Vec::new(),
            patches: Vec::new(),
            dpatches: Vec::new(),
            grad: vec![0.0; k * n],
            escaled: Vec::new(),
            dout: Vec::new(),
        }
    }

    /// Select the patch lowering (bit-identical paths — a perf knob).
    pub fn set_lowering(&mut self, lowering: ConvLowering) {
        self.lowering = lowering;
    }

    /// Bytes currently held by this layer's patch-lowering staging
    /// buffers (patch matrices on the materialized path; image/column
    /// staging on the streamed path).  Error/output buffers common to
    /// both paths are excluded so the metric isolates the footprint
    /// the streaming rework removes — the memory axis of
    /// `benches/bench_conv.rs`.
    pub fn patch_buf_bytes(&self) -> usize {
        (self.patches.capacity()
            + self.dpatches.capacity()
            + self.xin.capacity()
            + self.qimg.capacity()
            + self.gcol.capacity())
            * std::mem::size_of::<f32>()
    }

    fn forward(&mut self, x: &[f32], m: usize, ctx: &mut FwdCtx,
               out: &mut Vec<f32>) {
        // The blocked grid kernel treats every patch row as a sample;
        // the sample-base offset scales by the patch count so patch p
        // of global sample g draws stream id g·P + p (see FwdCtx).
        let rows = self.plan.patch_rows(m);
        let co = self.plan.geom.cout;
        let nin = m * self.plan.in_len;
        let base =
            ctx.sample_base.wrapping_mul(self.plan.positions as u64);
        ensure(out, rows * co);
        match self.lowering {
            ConvLowering::Streamed => {
                ensure(&mut self.xin, nin);
                self.xin[..nin].copy_from_slice(&x[..nin]);
                // DAC the image once per pixel; the patch source then
                // gathers quantized segments on demand.  Bit-equal to
                // quantizing a materialized patch matrix because the
                // DAC maps 0.0 (padding) to exactly 0.0.
                ensure(&mut self.qimg, nin);
                let dac = self.grid.dac;
                for (q, &v) in self.qimg[..nin]
                    .iter_mut()
                    .zip(&self.xin[..nin])
                {
                    *q = dac.convert(v);
                }
                let plan = self.plan;
                let src =
                    ConvPatchSource::new(&plan, &self.qimg[..nin]);
                self.grid.vmm_batch_src_into(
                    &src, rows, ctx.t_now, ctx.round, base, ctx.pool,
                    &mut self.scratch, &mut out[..rows * co]);
            }
            ConvLowering::Materialized => {
                let k = self.plan.patch_len;
                ensure(&mut self.patches, rows * k);
                im2col_into(&self.geom, &x[..nin], m, ctx.pool,
                            &mut self.patches[..rows * k]);
                self.grid.vmm_batch_base_into(
                    &self.patches[..rows * k], rows, ctx.t_now,
                    ctx.round, base, ctx.pool, &mut self.scratch,
                    &mut out[..rows * co]);
            }
        }
        weighted_out(&mut ctx.gain, self.widx, &mut out[..rows * co]);
    }

    /// Digital weight gradient: patch outer product summed over
    /// samples *and* positions, batch-mean (1/m, the dense convention
    /// — positions sum like the loss does).  Streamed and
    /// materialized paths share the exact f32 op order
    /// ([`conv_grad_into`]).
    fn grad_from(&mut self, d_out: &[f32], m: usize, inv_m: f32) {
        let co = self.plan.geom.cout;
        let rows = self.plan.patch_rows(m);
        match self.lowering {
            ConvLowering::Streamed => {
                let plan = self.plan;
                conv_grad_into(&plan, &self.xin[..m * plan.in_len],
                               &d_out[..rows * co], m, inv_m,
                               &mut self.gcol, &mut self.grad);
            }
            ConvLowering::Materialized => {
                let k = self.plan.patch_len;
                outer_product_grad(&self.patches, d_out,
                                   &mut self.grad, rows, k, co, inv_m);
            }
        }
    }

    fn backward(&mut self, d_out: &[f32], m: usize, ctx: &BwdCtx,
                d_in: &mut Vec<f32>, need_input_grad: bool) {
        self.grad_from(d_out, m, ctx.inv_m);
        if need_input_grad {
            self.backward_err_vmm(d_out, m, ctx, d_in);
        }
    }

    /// Transposed patch VMM + col2im adjoint scatter (shared verbatim
    /// by the phase-serial and pipelined walks).  Streamed lowering
    /// drains the VMM's strip outputs straight through the fused
    /// scatter ([`col2im_stream_into`]); materialized stages the
    /// `[m·P, K]` patch gradient and scatters it after.
    fn backward_err_vmm(&mut self, d_out: &[f32], m: usize,
                        ctx: &BwdCtx, d_in: &mut Vec<f32>) {
        let co = self.plan.geom.cout;
        let rows = self.plan.patch_rows(m);
        ensure(&mut self.escaled, rows * co);
        for (ev, &dv) in self.escaled[..rows * co]
            .iter_mut()
            .zip(&d_out[..rows * co])
        {
            *ev = dv * ctx.gain;
        }
        let nin = m * self.plan.in_len;
        ensure(d_in, nin);
        match self.lowering {
            ConvLowering::Streamed => {
                let plan = self.plan;
                let pool = ctx.pool;
                let dst = &mut d_in[..nin];
                self.grid.vmm_t_batch_with(
                    &self.escaled[..rows * co], rows, ctx.t_now,
                    ctx.round, pool, &mut self.scratch,
                    |res| col2im_stream_into(&plan, res, m, pool, dst));
            }
            ConvLowering::Materialized => {
                let k = self.plan.patch_len;
                ensure(&mut self.dpatches, rows * k);
                self.grid.vmm_t_batch_into(
                    &self.escaled[..rows * co], rows, ctx.t_now,
                    ctx.round, ctx.pool, &mut self.scratch,
                    &mut self.dpatches[..rows * k]);
                col2im_into(&self.geom, &self.dpatches[..rows * k], m,
                            ctx.pool, &mut d_in[..nin]);
            }
        }
        for v in d_in[..nin].iter_mut() {
            *v *= ctx.inv_gain;
        }
    }

    /// Pipelined-backward foreground half (see
    /// [`DenseLayer::backward_vmm`]).
    fn backward_vmm(&mut self, d_out: &[f32], m: usize, ctx: &BwdCtx,
                    d_in: &mut Vec<f32>, need_input_grad: bool) {
        let co = self.plan.geom.cout;
        let rows = self.plan.patch_rows(m);
        ensure(&mut self.dout, rows * co);
        self.dout[..rows * co].copy_from_slice(&d_out[..rows * co]);
        if need_input_grad {
            self.backward_err_vmm(d_out, m, ctx, d_in);
        }
    }
}

/// Skip-add residual block with an optional 1×1 projection conv.
pub struct ResBlock {
    pub body: Vec<Layer>,
    pub proj: Option<Box<ConvLayer>>,
    in_len: usize,
    out_len: usize,
    /// per-body-layer output activations
    bacts: Vec<Vec<f32>>,
    /// projection output `[m, out_len]`
    skip: Vec<f32>,
    /// backward delta ping/pong through the body
    dbody: Vec<f32>,
    dtmp: Vec<f32>,
    /// skip-path input gradient `[m, in_len]`
    dskip: Vec<f32>,
}

/// One device-graph layer.
pub enum Layer {
    Dense(DenseLayer),
    Conv(ConvLayer),
    Relu {
        len: usize,
        /// cached pre-activation input `[m, len]`
        z: Vec<f32>,
    },
    GlobalAvgPool { h: usize, w: usize, c: usize },
    Residual(ResBlock),
}

impl Layer {
    fn in_len(&self) -> usize {
        match self {
            Layer::Dense(d) => d.k,
            Layer::Conv(cv) => cv.plan.in_len,
            Layer::Relu { len, .. } => *len,
            Layer::GlobalAvgPool { h, w, c } => h * w * c,
            Layer::Residual(r) => r.in_len,
        }
    }

    fn out_len(&self) -> usize {
        match self {
            Layer::Dense(d) => d.n,
            Layer::Conv(cv) => cv.plan.out_len,
            Layer::Relu { len, .. } => *len,
            Layer::GlobalAvgPool { c, .. } => *c,
            Layer::Residual(r) => r.out_len,
        }
    }

    fn forward(&mut self, x: &[f32], m: usize, ctx: &mut FwdCtx,
               out: &mut Vec<f32>) {
        match self {
            Layer::Dense(d) => d.forward(x, m, ctx, out),
            Layer::Conv(cv) => cv.forward(x, m, ctx, out),
            Layer::Relu { len, z } => {
                let need = m * *len;
                ensure(z, need);
                z[..need].copy_from_slice(&x[..need]);
                ensure(out, need);
                for (o, &v) in out[..need].iter_mut().zip(&x[..need]) {
                    *o = if v > 0.0 { v } else { 0.0 };
                }
            }
            Layer::GlobalAvgPool { h, w, c } => {
                let (pp, cc) = (*h * *w, *c);
                let inv_area = 1.0f32 / pp as f32;
                ensure(out, m * cc);
                for s in 0..m {
                    for j in 0..cc {
                        let mut acc = 0.0f32;
                        for p in 0..pp {
                            acc += x[s * pp * cc + p * cc + j];
                        }
                        out[s * cc + j] = acc * inv_area;
                    }
                }
            }
            Layer::Residual(r) => r.forward(x, m, ctx, out),
        }
    }

    fn backward(&mut self, d_out: &[f32], m: usize, ctx: &BwdCtx,
                d_in: &mut Vec<f32>, need_input_grad: bool) {
        match self {
            Layer::Dense(d) => {
                d.backward(d_out, m, ctx, d_in, need_input_grad)
            }
            Layer::Conv(cv) => {
                cv.backward(d_out, m, ctx, d_in, need_input_grad)
            }
            Layer::Relu { len, z } => {
                if need_input_grad {
                    let need = m * *len;
                    ensure(d_in, need);
                    for i in 0..need {
                        d_in[i] =
                            if z[i] > 0.0 { d_out[i] } else { 0.0 };
                    }
                }
            }
            Layer::GlobalAvgPool { h, w, c } => {
                if need_input_grad {
                    let (pp, cc) = (*h * *w, *c);
                    let inv_area = 1.0f32 / pp as f32;
                    ensure(d_in, m * pp * cc);
                    for s in 0..m {
                        for p in 0..pp {
                            for j in 0..cc {
                                d_in[s * pp * cc + p * cc + j] =
                                    d_out[s * cc + j] * inv_area;
                            }
                        }
                    }
                }
            }
            Layer::Residual(r) => {
                r.backward(d_out, m, ctx, d_in, need_input_grad)
            }
        }
    }

    fn apply_update(&mut self, lr: f32, t_now: f32, round: u64,
                    pool: &WorkerPool) -> usize {
        match self {
            Layer::Dense(d) => d.grid.apply_update(
                &d.grad, lr, t_now, round, pool, &mut d.scratch),
            Layer::Conv(cv) => cv.grid.apply_update(
                &cv.grad, lr, t_now, round, pool, &mut cv.scratch),
            Layer::Residual(r) => {
                let mut total = 0;
                for l in &mut r.body {
                    total += l.apply_update(lr, t_now, round, pool);
                }
                if let Some(pj) = r.proj.as_mut() {
                    total += pj.grid.apply_update(
                        &pj.grad, lr, t_now, round, pool,
                        &mut pj.scratch);
                }
                total
            }
            _ => 0,
        }
    }

    fn refresh(&mut self, t_now: f32, round: u64,
               pool: &WorkerPool) -> usize {
        match self {
            Layer::Dense(d) => d.grid.refresh(t_now, round, pool),
            Layer::Conv(cv) => cv.grid.refresh(t_now, round, pool),
            Layer::Residual(r) => {
                let mut total = 0;
                for l in &mut r.body {
                    total += l.refresh(t_now, round, pool);
                }
                if let Some(pj) = r.proj.as_mut() {
                    total += pj.grid.refresh(t_now, round, pool);
                }
                total
            }
            _ => 0,
        }
    }

    fn record_endurance(&self, ledger: &mut EnduranceLedger) {
        match self {
            Layer::Dense(d) => d.grid.record_endurance(ledger),
            Layer::Conv(cv) => cv.grid.record_endurance(ledger),
            Layer::Residual(r) => {
                for l in &r.body {
                    l.record_endurance(ledger);
                }
                if let Some(pj) = r.proj.as_ref() {
                    pj.grid.record_endurance(ledger);
                }
            }
            _ => {}
        }
    }

    fn fault_summary(&self, map: &mut crate::pcm::FaultMap) {
        match self {
            Layer::Dense(d) => map.merge(&d.grid.fault_summary()),
            Layer::Conv(cv) => map.merge(&cv.grid.fault_summary()),
            Layer::Residual(r) => {
                for l in &r.body {
                    l.fault_summary(map);
                }
                if let Some(pj) = r.proj.as_ref() {
                    map.merge(&pj.grid.fault_summary());
                }
            }
            _ => {}
        }
    }

    fn inference_bits(&self) -> usize {
        match self {
            Layer::Dense(d) => d.grid.inference_bits(),
            Layer::Conv(cv) => cv.grid.inference_bits(),
            Layer::Residual(r) => {
                let mut total: usize =
                    r.body.iter().map(|l| l.inference_bits()).sum();
                if let Some(pj) = r.proj.as_ref() {
                    total += pj.grid.inference_bits();
                }
                total
            }
            _ => 0,
        }
    }

    fn total_set_pulses(&self) -> u64 {
        match self {
            Layer::Dense(d) => d.grid.total_set_pulses(),
            Layer::Conv(cv) => cv.grid.total_set_pulses(),
            Layer::Residual(r) => {
                let mut total: u64 =
                    r.body.iter().map(|l| l.total_set_pulses()).sum();
                if let Some(pj) = r.proj.as_ref() {
                    total += pj.grid.total_set_pulses();
                }
                total
            }
            _ => 0,
        }
    }

    fn set_conv_lowering(&mut self, lowering: ConvLowering) {
        match self {
            Layer::Conv(cv) => cv.set_lowering(lowering),
            Layer::Residual(r) => {
                for l in &mut r.body {
                    l.set_conv_lowering(lowering);
                }
                if let Some(pj) = r.proj.as_mut() {
                    pj.set_lowering(lowering);
                }
            }
            _ => {}
        }
    }

    fn patch_buf_bytes(&self) -> usize {
        match self {
            Layer::Conv(cv) => cv.patch_buf_bytes(),
            Layer::Residual(r) => {
                let mut total: usize =
                    r.body.iter().map(|l| l.patch_buf_bytes()).sum();
                if let Some(pj) = r.proj.as_ref() {
                    total += pj.patch_buf_bytes();
                }
                total
            }
            _ => 0,
        }
    }
}

impl ResBlock {
    fn forward(&mut self, x: &[f32], m: usize, ctx: &mut FwdCtx,
               out: &mut Vec<f32>) {
        let nb = self.body.len();
        for i in 0..nb {
            let il = self.body[i].in_len();
            let (done, rest) = self.bacts.split_at_mut(i);
            let input: &[f32] =
                if i == 0 { x } else { &done[i - 1][..m * il] };
            self.body[i].forward(input, m, ctx, &mut rest[0]);
        }
        let need = m * self.out_len;
        ensure(out, need);
        if let Some(pj) = self.proj.as_mut() {
            pj.forward(x, m, ctx, &mut self.skip);
            let body_out = &self.bacts[nb - 1];
            for i in 0..need {
                out[i] = body_out[i] + self.skip[i];
            }
        } else {
            let body_out = &self.bacts[nb - 1];
            for i in 0..need {
                out[i] = body_out[i] + x[i];
            }
        }
    }

    fn backward(&mut self, d_out: &[f32], m: usize, ctx: &BwdCtx,
                d_in: &mut Vec<f32>, need_input_grad: bool) {
        let nb = self.body.len();
        let need_out = m * self.out_len;
        ensure(&mut self.dbody, need_out);
        self.dbody[..need_out].copy_from_slice(&d_out[..need_out]);
        for i in (0..nb).rev() {
            let inner_need = i > 0 || need_input_grad;
            let ol = self.body[i].out_len();
            self.body[i].backward(&self.dbody[..m * ol], m, ctx,
                                  &mut self.dtmp, inner_need);
            if inner_need {
                std::mem::swap(&mut self.dbody, &mut self.dtmp);
            }
        }
        if let Some(pj) = self.proj.as_mut() {
            pj.backward(d_out, m, ctx, &mut self.dskip,
                        need_input_grad);
        }
        if need_input_grad {
            let nin = m * self.in_len;
            ensure(d_in, nin);
            if self.proj.is_some() {
                for i in 0..nin {
                    d_in[i] = self.dbody[i] + self.dskip[i];
                }
            } else {
                for i in 0..nin {
                    d_in[i] = self.dbody[i] + d_out[i];
                }
            }
        }
    }
}

// -- pipelined backward/update walk --------------------------------------

/// Commutative step totals folded by the background update stages
/// (u64-style atomic adds — order-independent, so completion order is
/// pure scheduling).
pub struct StepTotals {
    overflows: AtomicUsize,
    refreshed: AtomicUsize,
}

impl StepTotals {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        StepTotals {
            overflows: AtomicUsize::new(0),
            refreshed: AtomicUsize::new(0),
        }
    }

    fn add(&self, ovf: usize, refr: usize) {
        self.overflows.fetch_add(ovf, Ordering::Relaxed);
        self.refreshed.fetch_add(refr, Ordering::Relaxed);
    }

    /// Total LSB→MSB overflow events.
    pub fn overflows(&self) -> usize {
        self.overflows.load(Ordering::Relaxed)
    }

    /// Total refreshed pairs (0 unless the step's refresh was due).
    pub fn refreshed(&self) -> usize {
        self.refreshed.load(Ordering::Relaxed)
    }
}

/// Per-step update parameters carried into the background stages.
#[derive(Clone, Copy)]
struct UpdateArgs {
    lr: f32,
    t_now: f32,
    round: u64,
    refresh_due: bool,
}

/// The background half of a weighted layer's backward step, split at
/// the completion dependency: the **gradient stage** (digital outer
/// product from the layer's cached activations and error snapshot)
/// must finish before the **update stage** (hybrid LSB/MSB
/// `apply_update`, then the due refresh) starts.  Both touch only
/// layer-owned state and per-layer RNG streams, so stages of different
/// layers interleave freely without changing a bit.
trait GradUpdate {
    fn grad_stage(&mut self, m: usize, inv_m: f32);
    fn update_stage(&mut self, up: UpdateArgs) -> (usize, usize);
}

impl GradUpdate for DenseLayer {
    fn grad_stage(&mut self, m: usize, inv_m: f32) {
        let (k, n) = (self.k, self.n);
        outer_product_grad(&self.input, &self.dout, &mut self.grad, m,
                           k, n, inv_m);
    }

    fn update_stage(&mut self, up: UpdateArgs) -> (usize, usize) {
        let ovf = self
            .grid
            .update_item(&self.grad, up.lr, up.t_now, up.round,
                         &mut self.scratch)
            .run();
        let refr = if up.refresh_due {
            self.grid.refresh(up.t_now, up.round, &WorkerPool::serial())
        } else {
            0
        };
        (ovf, refr)
    }
}

impl GradUpdate for ConvLayer {
    fn grad_stage(&mut self, m: usize, inv_m: f32) {
        // Temporarily move the error snapshot out so the shared
        // gradient kernel can borrow the rest of the layer mutably —
        // a Vec move, no copy.
        let dout = std::mem::take(&mut self.dout);
        self.grad_from(&dout, m, inv_m);
        self.dout = dout;
    }

    fn update_stage(&mut self, up: UpdateArgs) -> (usize, usize) {
        let ovf = self
            .grid
            .update_item(&self.grad, up.lr, up.t_now, up.round,
                         &mut self.scratch)
            .run();
        let refr = if up.refresh_due {
            self.grid.refresh(up.t_now, up.round, &WorkerPool::serial())
        } else {
            0
        };
        (ovf, refr)
    }
}

/// Scheduling state threaded through the pipelined backward walk: the
/// background lane handle, the step totals, and the adaptive
/// eager/deferred budget (HyTrainDNN's `k`-fraction) with queue-depth
/// backpressure.
struct PipeCtx<'env, 'a> {
    scope: &'a PipelineScope<'env>,
    totals: &'env StepTotals,
    up: UpdateArgs,
    inv_m: f32,
    /// gradient/update chains still allowed to run eagerly in the
    /// background lane this step
    eager_left: usize,
    /// defer once the queue backs up past this depth, whatever the
    /// budget says — the lane is starved for workers
    depth_cap: usize,
}

impl<'env> PipeCtx<'env, '_> {
    /// Hand one weighted layer's gradient + update to the scheduler:
    /// eagerly as a completion-dependency chain in the background lane
    /// while the budget and queue depth allow, else parked for the
    /// end-of-step drain on the calling thread.  Either way the same
    /// closures run — the split is pure scheduling.
    fn dispatch<L>(&mut self, layer: &'env mut L, m: usize)
    where
        L: GradUpdate + Send + 'env,
    {
        let inv_m = self.inv_m;
        let up = self.up;
        let totals = self.totals;
        if self.eager_left > 0
            && self.scope.queue_depth() < self.depth_cap
        {
            self.eager_left -= 1;
            self.scope.spawn_then(
                move || {
                    layer.grad_stage(m, inv_m);
                    layer
                },
                move |layer: &'env mut L| {
                    let (ovf, refr) = layer.update_stage(up);
                    totals.add(ovf, refr);
                },
            );
        } else {
            self.scope.defer(move || {
                layer.grad_stage(m, inv_m);
                let (ovf, refr) = layer.update_stage(up);
                totals.add(ovf, refr);
            });
        }
    }
}

/// One layer of the pipelined backward walk: weighted layers run their
/// foreground transposed VMM, then their `&mut` state moves into the
/// background gradient/update stages; stateless layers backprop inline.
fn backward_layer_pipelined<'env>(
    layer: &'env mut Layer, d_out: &[f32], m: usize, ctx: &BwdCtx,
    d_in: &mut Vec<f32>, need_input_grad: bool,
    pc: &mut PipeCtx<'env, '_>) {
    match layer {
        Layer::Dense(d) => {
            d.backward_vmm(d_out, m, ctx, d_in, need_input_grad);
            pc.dispatch(d, m);
        }
        Layer::Conv(cv) => {
            cv.backward_vmm(d_out, m, ctx, d_in, need_input_grad);
            pc.dispatch(cv, m);
        }
        Layer::Residual(r) => {
            backward_res_pipelined(r, d_out, m, ctx, d_in,
                                   need_input_grad, pc);
        }
        stateless => {
            stateless.backward(d_out, m, ctx, d_in, need_input_grad);
        }
    }
}

/// Pipelined mirror of [`ResBlock::backward`]: same delta ping/pong
/// through the body, same projection/skip combine, but every weighted
/// sublayer is handed to the background lane the moment its foreground
/// VMM completes.
fn backward_res_pipelined<'env>(
    r: &'env mut ResBlock, d_out: &[f32], m: usize, ctx: &BwdCtx,
    d_in: &mut Vec<f32>, need_input_grad: bool,
    pc: &mut PipeCtx<'env, '_>) {
    let ResBlock { body, proj, in_len, out_len, dbody, dtmp, dskip, .. } = r;
    let (in_len, out_len) = (*in_len, *out_len);
    let nb = body.len();
    let need_out = m * out_len;
    ensure(dbody, need_out);
    dbody[..need_out].copy_from_slice(&d_out[..need_out]);
    let mut slots: Vec<Option<&mut Layer>> =
        body.iter_mut().map(Some).collect();
    for i in (0..nb).rev() {
        let inner_need = i > 0 || need_input_grad;
        let bl = slots[i].take().expect("body layer visited once");
        let ol = bl.out_len();
        backward_layer_pipelined(bl, &dbody[..m * ol], m, ctx, dtmp,
                                 inner_need, pc);
        if inner_need {
            std::mem::swap(dbody, dtmp);
        }
    }
    let has_proj = proj.is_some();
    if let Some(pj) = proj.as_deref_mut() {
        pj.backward_vmm(d_out, m, ctx, dskip, need_input_grad);
        pc.dispatch(pj, m);
    }
    if need_input_grad {
        let nin = m * in_len;
        ensure(d_in, nin);
        if has_proj {
            for i in 0..nin {
                d_in[i] = dbody[i] + dskip[i];
            }
        } else {
            for i in 0..nin {
                d_in[i] = dbody[i] + d_out[i];
            }
        }
    }
}

// -- the device graph ----------------------------------------------------

/// A layer-graph network whose every weighted layer lives on its own
/// [`CrossbarGrid`].
pub struct GraphNet {
    pub input: ActShape,
    pub classes: usize,
    pub layers: Vec<Layer>,
    pub seed: u64,
    weighted: Vec<WeightDesc>,
    /// per-top-level-layer output activations
    acts: Vec<Vec<f32>>,
    /// backward delta ping/pong
    delta: Vec<f32>,
    dtmp: Vec<f32>,
}

fn build_layer(pl: &PlanLayer, params: PcmParams, policy: TilingPolicy,
               w_scale: f32, seed: u64, pool: &WorkerPool) -> Layer {
    match pl {
        PlanLayer::Dense { widx, k, n } => Layer::Dense(DenseLayer::new(
            *widx, *k, *n, params, policy, w_scale, seed, pool)),
        PlanLayer::Conv { widx, geom } => Layer::Conv(ConvLayer::new(
            *widx, *geom, params, policy, w_scale, seed, pool)),
        PlanLayer::Relu { len } => {
            Layer::Relu { len: *len, z: Vec::new() }
        }
        PlanLayer::GlobalAvgPool { h, w, c } => {
            Layer::GlobalAvgPool { h: *h, w: *w, c: *c }
        }
        PlanLayer::Residual { body, proj, in_len, out_len } => {
            let b: Vec<Layer> = body
                .iter()
                .map(|l| build_layer(l, params, policy, w_scale, seed,
                                     pool))
                .collect();
            let pj = proj.as_ref().map(|p| {
                let PlanLayer::Conv { widx, geom } = &**p else {
                    unreachable!("projection is always a conv");
                };
                Box::new(ConvLayer::new(*widx, *geom, params, policy,
                                        w_scale, seed, pool))
            });
            Layer::Residual(ResBlock {
                bacts: vec![Vec::new(); b.len()],
                body: b,
                proj: pj,
                in_len: *in_len,
                out_len: *out_len,
                skip: Vec::new(),
                dbody: Vec::new(),
                dtmp: Vec::new(),
                dskip: Vec::new(),
            })
        }
    }
}

impl GraphNet {
    /// Build and initialize the device graph from a spec (weighted
    /// layers in DFS order, per-layer grid seeds and `w_max` windows —
    /// see the module docs).
    pub fn new(params: PcmParams, spec: &GraphSpec, policy: TilingPolicy,
               w_scale: f32, seed: u64, pool: &WorkerPool) -> Self {
        Self::from_plan(params, &spec.plan(), policy, w_scale, seed, pool)
    }

    /// Build from an already resolved plan.
    pub fn from_plan(params: PcmParams, plan: &GraphPlan,
                     policy: TilingPolicy, w_scale: f32, seed: u64,
                     pool: &WorkerPool) -> Self {
        let layers: Vec<Layer> = plan
            .layers
            .iter()
            .map(|l| build_layer(l, params, policy, w_scale, seed, pool))
            .collect();
        let acts = layers.iter().map(|_| Vec::new()).collect();
        GraphNet {
            input: plan.input,
            classes: plan.classes,
            layers,
            seed,
            weighted: plan.weighted.clone(),
            acts,
            delta: Vec::new(),
            dtmp: Vec::new(),
        }
    }

    pub fn input_dim(&self) -> usize {
        self.input.len()
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Number of weighted layers (each on its own grid).
    pub fn weighted_layers(&self) -> usize {
        self.weighted.len()
    }

    /// Total weight count across weighted layers.
    pub fn weights(&self) -> usize {
        self.weighted.iter().map(|d| d.k * d.n).sum()
    }

    /// Analog forward pass over `m` samples; returns the logits
    /// `[m, classes]`.  Caches activations for a following
    /// [`GraphNet::backward`].
    pub fn forward(&mut self, x: &[f32], m: usize, t_now: f32,
                   round: u64, pool: &WorkerPool) -> &[f32] {
        self.forward_with(x, m, t_now, round, 0, GainCtx::Off, pool)
    }

    /// [`GraphNet::forward`] with the serving knobs exposed:
    /// `sample_base` is the global id of the batch's first sample
    /// (threaded into every weighted layer's per-sample RNG
    /// sub-streams — see [`FwdCtx`]) and `gain` is the per-layer
    /// calibration context ([`GainCtx`]).  `(0, GainCtx::Off)`
    /// reproduces `forward` exactly, bit for bit.
    pub fn forward_with(&mut self, x: &[f32], m: usize, t_now: f32,
                        round: u64, sample_base: u64,
                        gain: GainCtx<'_>, pool: &WorkerPool)
                        -> &[f32] {
        assert_eq!(x.len(), m * self.input.len());
        let mut ctx = FwdCtx { t_now, round, pool, sample_base, gain };
        let nl = self.layers.len();
        for i in 0..nl {
            let il = self.layers[i].in_len();
            let (done, rest) = self.acts.split_at_mut(i);
            let input: &[f32] =
                if i == 0 { x } else { &done[i - 1][..m * il] };
            self.layers[i].forward(input, m, &mut ctx, &mut rest[0]);
        }
        &self.acts[nl - 1][..m * self.classes]
    }

    /// Backward pass from the logits gradient (`softmax − one-hot`):
    /// digital weight gradients into each layer, transposed analog VMMs
    /// carrying the error down the graph (pre-scaled by `bwd_gain`
    /// around each analog hop).  Must follow a `forward` at the same
    /// batch size.
    pub fn backward(&mut self, dlogits: &[f32], m: usize, t_now: f32,
                    round: u64, pool: &WorkerPool, bwd_gain: f32) {
        assert_eq!(dlogits.len(), m * self.classes);
        let ctx = BwdCtx {
            t_now,
            round,
            pool,
            gain: bwd_gain,
            inv_gain: 1.0 / bwd_gain,
            inv_m: 1.0 / m as f32,
        };
        ensure(&mut self.delta, dlogits.len());
        self.delta[..dlogits.len()].copy_from_slice(dlogits);
        for i in (0..self.layers.len()).rev() {
            let need = i > 0;
            let ol = self.layers[i].out_len();
            self.layers[i].backward(&self.delta[..m * ol], m, &ctx,
                                    &mut self.dtmp, need);
            if need {
                std::mem::swap(&mut self.delta, &mut self.dtmp);
            }
        }
    }

    /// Pipelined backward **and** update: the foreground (calling)
    /// thread walks the graph top-down exactly like
    /// [`GraphNet::backward`] — same delta ping/pong, same transposed
    /// VMMs on the `fg` pool — but the moment a weighted layer's
    /// backward VMM completes, its digital outer-product gradient and
    /// hybrid LSB/MSB update (plus the due refresh) are handed to the
    /// background lane (`scope`) as a completion-dependency chain,
    /// overlapping with the next layer's VMM.  At most `eager_budget`
    /// chains run eagerly (HyTrainDNN's `k`-fraction); the rest are
    /// parked for `scope.drain()` on the caller.  Overflow/refresh
    /// counts fold into `totals`.
    ///
    /// Bitwise identical to `backward` + `apply_updates` (+ `refresh`
    /// when due) at any worker count: every kernel draws from
    /// per-(op, tile, sample) counter streams keyed only on
    /// `(seed, round)`, layers own disjoint grids, and the totals are
    /// commutative sums — scheduling moves *when* work runs, never
    /// *what* it computes.  The caller must `scope.drain()` before the
    /// next forward so updates land before they are read.
    #[allow(clippy::too_many_arguments)]
    pub fn backward_update_pipelined<'env>(
        &'env mut self, dlogits: &[f32], m: usize, t_now: f32,
        round: u64, fg: &WorkerPool, scope: &PipelineScope<'env>,
        bwd_gain: f32, lr: f32, refresh_due: bool, eager_budget: usize,
        totals: &'env StepTotals) {
        assert_eq!(dlogits.len(), m * self.classes);
        let GraphNet { layers, delta, dtmp, .. } = self;
        let ctx = BwdCtx {
            t_now,
            round,
            pool: fg,
            gain: bwd_gain,
            inv_gain: 1.0 / bwd_gain,
            inv_m: 1.0 / m as f32,
        };
        ensure(delta, dlogits.len());
        delta[..dlogits.len()].copy_from_slice(dlogits);
        let up = UpdateArgs { lr, t_now, round, refresh_due };
        let mut pc = PipeCtx {
            scope,
            totals,
            up,
            inv_m: ctx.inv_m,
            eager_left: eager_budget,
            depth_cap: 2 * scope.workers().max(1),
        };
        let nl = layers.len();
        let mut slots: Vec<Option<&mut Layer>> =
            layers.iter_mut().map(Some).collect();
        for i in (0..nl).rev() {
            let need = i > 0;
            let layer = slots[i].take().expect("layer visited once");
            let ol = layer.out_len();
            backward_layer_pipelined(layer, &delta[..m * ol], m, &ctx,
                                     dtmp, need, &mut pc);
            if need {
                std::mem::swap(delta, dtmp);
            }
        }
    }

    /// Apply the per-layer hybrid updates (DFS order); returns total
    /// LSB→MSB overflow events.
    pub fn apply_updates(&mut self, lr: f32, t_now: f32, round: u64,
                         pool: &WorkerPool) -> usize {
        self.layers
            .iter_mut()
            .map(|l| l.apply_update(lr, t_now, round, pool))
            .sum()
    }

    /// Selective saturation refresh across every grid; returns the
    /// refreshed pair count.
    pub fn refresh(&mut self, t_now: f32, round: u64,
                   pool: &WorkerPool) -> usize {
        self.layers
            .iter_mut()
            .map(|l| l.refresh(t_now, round, pool))
            .sum()
    }

    /// Inference model bits across all grids (MSB arrays only — the
    /// fig4 model-size axis).
    pub fn inference_bits(&self) -> usize {
        self.layers.iter().map(|l| l.inference_bits()).sum()
    }

    /// Fold every grid's device activity into an endurance ledger.
    pub fn record_endurance(&self, ledger: &mut EnduranceLedger) {
        for l in &self.layers {
            l.record_endurance(ledger);
        }
    }

    /// Fold every grid's fault/degradation accounting into one
    /// [`crate::pcm::FaultMap`] (layer order; all-zero when the fault
    /// model is disabled).
    pub fn fault_summary(&self) -> crate::pcm::FaultMap {
        let mut map = crate::pcm::FaultMap::default();
        for l in &self.layers {
            l.fault_summary(&mut map);
        }
        map
    }

    /// Total SET pulses across all grids.
    pub fn total_set_pulses(&self) -> u64 {
        self.layers.iter().map(|l| l.total_set_pulses()).sum()
    }

    /// Select every conv layer's patch lowering (residual bodies and
    /// projections included).  Both paths are bit-identical — this
    /// switches performance characteristics only; see
    /// [`ConvLowering`].
    pub fn set_conv_lowering(&mut self, lowering: ConvLowering) {
        for l in &mut self.layers {
            l.set_conv_lowering(lowering);
        }
    }

    /// Bytes currently held by conv patch-lowering staging buffers
    /// across the whole graph (see [`ConvLayer::patch_buf_bytes`]) —
    /// the streamed-vs-materialized memory axis of
    /// `benches/bench_conv.rs`.
    pub fn patch_buf_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.patch_buf_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_plan_matches_device_net_layout() {
        let spec = GraphSpec::mlp(&[8, 12, 8, 4]);
        let plan = spec.plan();
        assert_eq!(plan.classes, 4);
        assert_eq!(plan.weighted.len(), 3);
        assert_eq!(plan.weighted[0], WeightDesc { index: 0, k: 8, n: 12 });
        assert_eq!(plan.weighted[1], WeightDesc { index: 1, k: 12, n: 8 });
        assert_eq!(plan.weighted[2], WeightDesc { index: 2, k: 8, n: 4 });
        assert_eq!(plan.weights(), 8 * 12 + 12 * 8 + 8 * 4);
        // Dense / Relu alternation, no trailing Relu.
        assert_eq!(plan.layers.len(), 5);
        assert!(matches!(plan.layers[0], PlanLayer::Dense { .. }));
        assert!(matches!(plan.layers[1], PlanLayer::Relu { len: 12 }));
        assert!(matches!(plan.layers[4], PlanLayer::Dense { .. }));
    }

    #[test]
    fn resnet_plan_shapes_and_projections() {
        let spec = GraphSpec::resnet([8, 8, 3], [4, 6, 8], 1, 10, 1000);
        let plan = spec.plan();
        assert_eq!(plan.classes, 10);
        // stem + 3 blocks × 2 convs + 2 projections + head = 10 grids.
        assert_eq!(plan.weighted.len(), 10);
        // Stem: 3×3×3 → 4.
        assert_eq!(plan.weighted[0],
                   WeightDesc { index: 0, k: 27, n: 4 });
        // Stage-2 body: 3×3 convs 4→6 then 6→6 (DFS body first) …
        assert_eq!(plan.weighted[3],
                   WeightDesc { index: 3, k: 9 * 4, n: 6 });
        assert_eq!(plan.weighted[4],
                   WeightDesc { index: 4, k: 9 * 6, n: 6 });
        // … then its 1×1 stride-2 skip projection, 4 → 6 channels.
        assert_eq!(plan.weighted[5], WeightDesc { index: 5, k: 4, n: 6 });
        // Head: GAP leaves 8 channels.
        assert_eq!(plan.weighted[9], WeightDesc { index: 9, k: 8, n: 10 });
        // Width multiplier scales the channel counts.
        let half = GraphSpec::resnet([8, 8, 3], [4, 6, 8], 1, 10, 500);
        let ph = half.plan();
        assert_eq!(ph.weighted[0].n, 2);
        assert!(ph.weights() < plan.weights());
    }

    #[test]
    fn identity_residual_needs_no_projection() {
        let spec = GraphSpec {
            input: ActShape::Img { h: 4, w: 4, c: 3 },
            layers: vec![
                LayerSpec::Residual {
                    body: vec![
                        LayerSpec::Conv2d {
                            cout: 3, kh: 3, kw: 3, stride: 1, pad: 1,
                        },
                        LayerSpec::Relu,
                        LayerSpec::Conv2d {
                            cout: 3, kh: 3, kw: 3, stride: 1, pad: 1,
                        },
                    ],
                },
                LayerSpec::GlobalAvgPool,
                LayerSpec::Softmax,
            ],
        };
        let plan = spec.plan();
        assert_eq!(plan.weighted.len(), 2);
        assert_eq!(plan.classes, 3);
        let PlanLayer::Residual { proj, in_len, out_len, .. } =
            &plan.layers[0]
        else {
            panic!("expected a residual block");
        };
        assert!(proj.is_none());
        assert_eq!((*in_len, *out_len), (48, 48));
    }

    #[test]
    fn graph_net_builds_and_runs_forward_backward() {
        let pool = WorkerPool::serial();
        let spec = GraphSpec::resnet([4, 4, 2], [3, 4, 5], 1, 3, 1000);
        let mut net = GraphNet::new(
            PcmParams::ideal(), &spec,
            TilingPolicy { tile_rows: 8, tile_cols: 8 }, 2.0, 11, &pool);
        assert_eq!(net.input_dim(), 32);
        assert_eq!(net.classes(), 3);
        assert_eq!(net.weighted_layers(), 10);
        assert_eq!(net.inference_bits(), net.weights() * 4);
        let m = 2;
        let x: Vec<f32> = (0..m * 32)
            .map(|i| (((i * 5) % 9) as f32 - 4.0) / 4.0)
            .collect();
        let logits = net.forward(&x, m, 0.0, 0, &pool).to_vec();
        assert_eq!(logits.len(), m * 3);
        assert!(logits.iter().all(|v| v.is_finite()));
        let dl: Vec<f32> =
            (0..m * 3).map(|i| ((i % 3) as f32 - 1.0) / 4.0).collect();
        net.backward(&dl, m, 0.0, 0, &pool, 4.0);
        let ovf = net.apply_updates(0.1, 0.0, 0, &pool);
        let _ = ovf; // overflow count is workload-dependent
        assert!(net.total_set_pulses() > 0, "init never programmed");
        let mut ledger = EnduranceLedger::new();
        net.record_endurance(&mut ledger);
        assert_eq!(ledger.msb.count as usize, 2 * net.weights());
    }

    #[test]
    fn graph_mlp_init_survives_msb_quantization() {
        let pool = WorkerPool::serial();
        let spec = GraphSpec::mlp(&[6, 5, 3]);
        let net = GraphNet::new(
            PcmParams::ideal(), &spec,
            TilingPolicy { tile_rows: 4, tile_cols: 4 }, 2.0, 11, &pool);
        assert_eq!(net.weighted_layers(), 2);
        assert_eq!(net.inference_bits(), (6 * 5 + 5 * 3) * 4);
        // Programmed weights stay within the layer's representable
        // range and are not all zero (the init must survive MSB
        // quantization — the whole point of per-layer w_max).
        let Layer::Dense(d) = &net.layers[0] else {
            panic!("mlp graph must start with a dense layer");
        };
        let mut scratch = d.grid.scratch();
        let mut w = vec![0.0f32; 6 * 5];
        d.grid.drift_into(0.0, &pool, &mut scratch, &mut w);
        let w_max = 2.0 / (6.0f32).sqrt();
        assert!(w.iter().any(|&v| v != 0.0), "init quantized to zero");
        assert!(w.iter().all(|&v| v.abs() <= w_max + 0.13));
    }

    #[test]
    #[should_panic(expected = "residual block needs a non-empty body")]
    fn empty_residual_body_is_rejected() {
        let spec = GraphSpec {
            input: ActShape::Img { h: 4, w: 4, c: 2 },
            layers: vec![
                LayerSpec::Residual { body: vec![] },
                LayerSpec::GlobalAvgPool,
                LayerSpec::Softmax,
            ],
        };
        let _ = spec.plan();
    }

    #[test]
    #[should_panic(expected = "Softmax must be the final layer")]
    fn misplaced_softmax_is_rejected() {
        let spec = GraphSpec {
            input: ActShape::Flat(4),
            layers: vec![LayerSpec::Softmax, LayerSpec::Dense { out: 2 },
                         LayerSpec::Softmax],
        };
        let _ = spec.plan();
    }

    #[test]
    #[should_panic(expected = "needs an image input")]
    fn conv_on_flat_input_is_rejected() {
        let spec = GraphSpec {
            input: ActShape::Flat(9),
            layers: vec![
                LayerSpec::Conv2d { cout: 2, kh: 3, kw: 3, stride: 1,
                                    pad: 1 },
                LayerSpec::Softmax,
            ],
        };
        let _ = spec.plan();
    }

    #[test]
    fn shape_check_accepts_what_plan_accepts() {
        let mlp = GraphSpec::mlp(&[8, 12, 8, 4]);
        assert_eq!(mlp.shape_check(), Ok(ActShape::Flat(4)));
        let rn = GraphSpec::resnet([8, 8, 3], [4, 6, 8], 1, 10, 1000);
        assert_eq!(rn.shape_check(), Ok(ActShape::Flat(10)));
    }

    #[test]
    fn shape_check_reports_instead_of_panicking() {
        let flat_conv = GraphSpec {
            input: ActShape::Flat(9),
            layers: vec![
                LayerSpec::Conv2d { cout: 2, kh: 3, kw: 3, stride: 1,
                                    pad: 1 },
                LayerSpec::Softmax,
            ],
        };
        let e = flat_conv.shape_check().unwrap_err();
        assert!(e.contains("conv needs an image input"), "{e}");

        let no_head = GraphSpec {
            input: ActShape::Flat(4),
            layers: vec![LayerSpec::Dense { out: 2 }, LayerSpec::Relu],
        };
        let e = no_head.shape_check().unwrap_err();
        assert!(e.contains("softmax head"), "{e}");

        let big_kernel = GraphSpec {
            input: ActShape::Img { h: 2, w: 2, c: 1 },
            layers: vec![
                LayerSpec::Conv2d { cout: 2, kh: 5, kw: 5, stride: 1,
                                    pad: 0 },
                LayerSpec::GlobalAvgPool,
                LayerSpec::Softmax,
            ],
        };
        let e = big_kernel.shape_check().unwrap_err();
        assert!(e.contains("does not fit"), "{e}");

        let empty_body = GraphSpec {
            input: ActShape::Img { h: 4, w: 4, c: 2 },
            layers: vec![
                LayerSpec::Residual { body: vec![] },
                LayerSpec::GlobalAvgPool,
                LayerSpec::Softmax,
            ],
        };
        let e = empty_body.shape_check().unwrap_err();
        assert!(e.contains("non-empty body"), "{e}");

        let img_head = GraphSpec {
            input: ActShape::Img { h: 4, w: 4, c: 2 },
            layers: vec![LayerSpec::Relu, LayerSpec::Softmax],
        };
        let e = img_head.shape_check().unwrap_err();
        assert!(e.contains("softmax head needs a flat input"), "{e}");
    }

    #[test]
    fn scale_widths_spares_the_classifier_head() {
        let mut layers = vec![
            LayerSpec::Dense { out: 8 },
            LayerSpec::Relu,
            LayerSpec::Residual {
                body: vec![LayerSpec::Dense { out: 8 }],
            },
            LayerSpec::Dense { out: 3 },
            LayerSpec::Softmax,
        ];
        assert_eq!(count_weighted(&layers), 3);
        assert!(!has_conv(&layers));
        scale_widths(&mut layers, 500);
        let LayerSpec::Dense { out } = layers[0] else { panic!() };
        assert_eq!(out, 4);
        let LayerSpec::Residual { ref body } = layers[2] else { panic!() };
        let LayerSpec::Dense { out } = body[0] else { panic!() };
        assert_eq!(out, 4);
        // Head keeps the class count.
        let LayerSpec::Dense { out } = layers[3] else { panic!() };
        assert_eq!(out, 3);
    }

    #[test]
    fn has_conv_sees_through_residual_bodies() {
        let layers = vec![
            LayerSpec::Residual {
                body: vec![LayerSpec::Conv2d {
                    cout: 2, kh: 3, kw: 3, stride: 1, pad: 1,
                }],
            },
            LayerSpec::GlobalAvgPool,
            LayerSpec::Dense { out: 3 },
            LayerSpec::Softmax,
        ];
        assert!(has_conv(&layers));
        assert_eq!(count_weighted(&layers), 2);
    }
}
