//! FIG3 — ablation of PCM non-idealities (paper Fig. 3).
//!
//! Trains the same network under eight PCM-model variants (each its own
//! artifact set, flags baked at lowering time) plus the FP32 baseline,
//! and reports training/eval accuracy per variant.  Paper shape to
//! reproduce:
//!
//! * nonlinearity < linear (programming-curve saturation hurts),
//! * write/read stochasticity hurt further,
//! * **drift alone helps** (acts as weight-decay regularization),
//! * full model trails the FP32 baseline (by ~4.4 % in the paper's
//!   470 K-parameter / 205-epoch setting).

use anyhow::Result;

use crate::coordinator::BaselineTrainer;
use crate::util::csv::{CsvCell, CsvWriter};
use crate::log_info;

use super::{config_dir, ensure_out_dir, mean_std, print_row, run_hic,
            ExpOptions};

/// Variant tags in the paper's bar order.
pub const VARIANTS: [&str; 8] = [
    "linear",
    "linear_write",
    "linear_read",
    "linear_drift",
    "nonlinear",
    "nonlinear_write",
    "nonlinear_read",
    "full",
];

#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub variant: String,
    pub train_acc: f64,
    pub train_std: f64,
    pub eval_acc: f64,
    pub eval_std: f64,
}

pub fn run(opts: &ExpOptions) -> Result<Vec<Fig3Row>> {
    ensure_out_dir(&opts.out_dir)?;
    let mut rows = Vec::new();

    // FP32 reference (lowered alongside fig3_linear).
    let base_dir = config_dir("fig3_linear")?;
    let mut base_accs = Vec::new();
    for &seed in &opts.seeds {
        let mut bt =
            BaselineTrainer::new(&base_dir, opts.trainer_options(seed))?;
        bt.lr = crate::coordinator::schedule::LrSchedule::paper(
            0.1, 0.1, opts.steps);
        bt.train_steps(opts.steps)?;
        base_accs.push(bt.evaluate(opts.eval_batches)?.accuracy);
    }
    let (bm, bs) = mean_std(&base_accs);
    rows.push(Fig3Row {
        variant: "fp32_baseline".into(),
        train_acc: f64::NAN,
        train_std: 0.0,
        eval_acc: bm,
        eval_std: bs,
    });
    log_info!("fig3: fp32 baseline eval acc {:.3} ± {:.3}", bm, bs);

    for tag in VARIANTS {
        let cfg = format!("fig3_{tag}");
        let mut train_accs = Vec::new();
        let mut eval_accs = Vec::new();
        for &seed in &opts.seeds {
            let (t, acc) = run_hic(&cfg, opts, seed)?;
            train_accs.push(t.metrics.smoothed_acc(20));
            eval_accs.push(acc);
        }
        let (tm, ts) = mean_std(&train_accs);
        let (em, es) = mean_std(&eval_accs);
        log_info!("fig3 {tag}: train {:.3} ± {:.3}, eval {:.3} ± {:.3}",
                  tm, ts, em, es);
        rows.push(Fig3Row {
            variant: tag.to_string(),
            train_acc: tm,
            train_std: ts,
            eval_acc: em,
            eval_std: es,
        });
    }

    write_csv(opts, &rows)?;
    print_table(&rows);
    Ok(rows)
}

fn write_csv(opts: &ExpOptions, rows: &[Fig3Row]) -> Result<()> {
    let mut w = CsvWriter::new(
        &["variant", "train_acc", "train_std", "eval_acc", "eval_std",
          "steps", "seeds"]);
    for r in rows {
        w.row(&[
            CsvCell::s(&r.variant),
            CsvCell::F(r.train_acc),
            CsvCell::F(r.train_std),
            CsvCell::F(r.eval_acc),
            CsvCell::F(r.eval_std),
            CsvCell::U(opts.steps as u64),
            CsvCell::U(opts.seeds.len() as u64),
        ]);
    }
    w.write(&opts.out_dir.join("fig3_ablation.csv"))
}

fn print_table(rows: &[Fig3Row]) {
    println!("\nFIG3 — PCM non-ideality ablation (paper Fig. 3)");
    print_row(&["variant".into(), "train acc".into(), "eval acc".into()]);
    for r in rows {
        print_row(&[
            r.variant.clone(),
            if r.train_acc.is_nan() {
                "-".into()
            } else {
                format!("{:.3} ± {:.3}", r.train_acc, r.train_std)
            },
            format!("{:.3} ± {:.3}", r.eval_acc, r.eval_std),
        ]);
    }
    // Shape checks (reported, not asserted — short runs are noisy).
    let get = |v: &str| rows.iter().find(|r| r.variant == v)
        .map(|r| r.eval_acc);
    if let (Some(lin), Some(drift), Some(full)) =
        (get("linear"), get("linear_drift"), get("full"))
    {
        println!("shape: drift-vs-linear delta = {:+.3} (paper: positive)",
                 drift - lin);
        println!("shape: full-vs-linear delta  = {:+.3} (paper: negative)",
                 full - lin);
    }
}
