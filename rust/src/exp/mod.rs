//! Experiment drivers — one per figure of the paper's evaluation.
//!
//! Each driver regenerates its figure's data series as CSV under
//! `results/` and prints the paper-comparison rows.  Absolute accuracies
//! differ from the paper (scaled networks, synthetic data, short runs —
//! see DESIGN.md §2); the drivers check and report the *shape*: orderings,
//! gaps, crossovers.
//!
//! | driver | paper figure | headline shape |
//! |--------|--------------|----------------|
//! | [`fig3`] | Fig. 3 | non-ideality ablation ordering; drift helps |
//! | [`fig4`] | Fig. 4 | HIC above baseline at matched model size |
//! | [`fig5`] | Fig. 5 | drift knee at ~1e6 s; AdaBS recovers it |
//! | [`fig6`] | Fig. 6 | WE cycles: MSB ≪ LSB ≪ 1e8 endurance |
//!
//! [`gridexp`] routes the fig3/fig5/fig6 shapes through the sharded
//! crossbar grid device model instead of the artifacts (runs anywhere
//! the crate builds; byte-stable metric JSON pinned by the golden
//! regression suite), and `gridexp::run_fig4` runs the fig4 width
//! sweep as true **multi-layer on-grid training** (per-layer crossbar
//! grids, transposed-VMM backprop, FP32 host baseline) — dense stacks
//! or, with `--arch resnet`, the paper's conv/residual topology via
//! im2col patch lowering.  [`widths`] holds the shared
//! width-multiplier table and model-size accounting.  The CLI exposes
//! all of it as `--device-grid`.  [`serve`] re-measures the fig5 axis
//! through the drift-aware serving stack (`crate::serve`): frozen
//! snapshot, coalesced synthetic load, per-probe gain recalibration —
//! the `serve` CLI command.

pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod gridexp;
pub mod serve;
pub mod widths;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::{Trainer, TrainerOptions};
use crate::runtime::artifact::artifact_root;

/// Common run parameters shared by the drivers.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    pub steps: usize,
    pub seeds: Vec<u64>,
    pub eval_batches: usize,
    pub lr0: f32,
    pub lr_decay: f32,
    pub data_scale: f64,
    pub out_dir: PathBuf,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            steps: 300,
            seeds: vec![42],
            eval_batches: 16,
            lr0: 0.5,
            lr_decay: 0.45,
            data_scale: 0.05,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl ExpOptions {
    pub fn trainer_options(&self, seed: u64) -> TrainerOptions {
        TrainerOptions {
            seed,
            lr: LrSchedule::paper(self.lr0, self.lr_decay, self.steps),
            data_scale: self.data_scale,
            ..Default::default()
        }
    }
}

/// Resolve `artifacts/<config>`, with a actionable error if missing.
pub fn config_dir(config: &str) -> Result<PathBuf> {
    let dir = artifact_root().join(config);
    if !dir.join("manifest.json").exists() {
        anyhow::bail!(
            "artifact set '{config}' not found under {} — build it with \
             `cd python && python -m compile.aot --configs {config}` (or \
             `make artifacts-all`)",
            artifact_root().display()
        );
    }
    Ok(dir)
}

/// Train one HIC run to completion and return (trainer, eval accuracy).
pub fn run_hic(config: &str, opts: &ExpOptions, seed: u64)
               -> Result<(Trainer, f64)> {
    let dir = config_dir(config)?;
    let mut t = Trainer::new(&dir, opts.trainer_options(seed))
        .with_context(|| format!("creating trainer for '{config}'"))?;
    t.train_steps(opts.steps)?;
    let ev = t.evaluate(opts.eval_batches, None)?;
    Ok((t, ev.accuracy))
}

/// Mean ± population std over seeds.
pub fn mean_std(vals: &[f64]) -> (f64, f64) {
    let n = vals.len().max(1) as f64;
    let mean = vals.iter().sum::<f64>() / n;
    let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Markdown-ish row printer used by all drivers.
pub fn print_row(cols: &[String]) {
    println!("| {} |", cols.join(" | "));
}

pub fn ensure_out_dir(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        let (m, s) = mean_std(&[5.0]);
        assert_eq!(m, 5.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn missing_config_is_actionable() {
        let err = config_dir("definitely_not_a_config").unwrap_err();
        assert!(err.to_string().contains("compile.aot"));
    }
}
