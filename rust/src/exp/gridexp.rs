//! Grid-routed figure sweeps (device level, no artifacts needed).
//!
//! The artifact-backed fig3–fig6 drivers need AOT-lowered programs; these
//! variants route the same experiment *shapes* through the sharded
//! [`crate::crossbar::CrossbarGrid`] device model via
//! [`GridTrainer`]: train an analog linear-regression task under the
//! figure's PCM-variant parameters and report device-level metrics.
//!
//! Output is a **byte-stable metric JSON** document (`util::json`
//! serialization is deterministic: sorted keys, integer fast path; all
//! float metrics are quantized to integer micro-units before they enter
//! the document).  Determinism contract: a document depends only on
//! `(GridExpOptions, variant set)` — never on the worker count — so the
//! golden regression suite (`rust/tests/golden_gridexp.rs`) can pin
//! experiment outputs across refactors, and the CI worker matrix
//! (`HIC_WORKERS=1` / `4`) proves the routing is schedule-independent.
//!
//! Two deliberate modeling choices keep the sweeps reproducible:
//! `drift_nu_sigma = 0` (per-device ν spread off, so streams do not
//! depend on device enumeration) and refresh disabled (its saturation
//! reads draw from the scalar libm Box–Muller path; refresh coverage
//! lives in the property suites instead).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::gridtrainer::{GridTrainer, GridTrainerOptions,
                                      EVAL_ROUND_BASE};
use crate::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use crate::coordinator::schedule::LrSchedule;
use crate::crossbar::TilingPolicy;
use crate::data::{IMG_C, IMG_H, IMG_W, NUM_CLASSES};
use crate::hic::weight::HicGeometry;
use crate::nn::features::{BlobDataset, FeatureSource};
use crate::nn::graph::{has_conv, scale_widths, ActShape, GraphSpec,
                       LayerSpec};
use crate::nn::net::NetSpec;
use crate::nn::{FpGraphNet, FpNet};
use crate::pcm::device::PcmParams;
use crate::util::json::Json;
use crate::util::pool::WorkerPool;
use crate::log_info;

use super::ensure_out_dir;
use super::widths::WIDTHS_PERMILLE;

/// The fig3 variant subset whose device math is fully portable
/// (no libm in any consumed path), used by the golden byte-regression
/// tests; the CLI sweeps all of `super::fig3::VARIANTS`.
pub const GOLDEN_FIG3_VARIANTS: [&str; 3] =
    ["linear", "linear_read", "linear_drift"];

/// Common parameters of the grid-routed sweeps.
#[derive(Clone, Debug)]
pub struct GridExpOptions {
    /// logical weight matrix rows (layer fan-in)
    pub k: usize,
    /// logical weight matrix cols (layer fan-out)
    pub n: usize,
    /// square physical tile size
    pub tile: usize,
    pub steps: usize,
    pub batch: usize,
    pub seed: u64,
    /// worker threads (0 = `HIC_WORKERS` / machine default)
    pub workers: usize,
    pub out_dir: PathBuf,
}

impl Default for GridExpOptions {
    fn default() -> Self {
        GridExpOptions {
            k: 64,
            n: 32,
            tile: 16,
            steps: 60,
            batch: 8,
            seed: 42,
            workers: 0,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl GridExpOptions {
    pub fn pool(&self) -> WorkerPool {
        if self.workers == 0 {
            WorkerPool::from_env()
        } else {
            WorkerPool::new(self.workers)
        }
    }

    fn policy(&self) -> TilingPolicy {
        TilingPolicy { tile_rows: self.tile, tile_cols: self.tile }
    }

    fn trainer_options(&self) -> GridTrainerOptions {
        GridTrainerOptions {
            seed: self.seed,
            lr: LrSchedule::constant(0.5),
            refresh_every: 0,
            batch: self.batch,
            ..Default::default()
        }
    }

    /// Deterministic regression target `W*` (exact small rationals).
    fn target(&self) -> Vec<f32> {
        (0..self.k * self.n)
            .map(|i| (((i * 3 + 5) % 13) as f32 - 6.0) / 8.0)
            .collect()
    }

    fn trainer(&self, params: PcmParams) -> GridTrainer {
        let geom = HicGeometry::default();
        GridTrainer::new(params, geom, self.k, self.n, self.policy(),
                         self.target(), self.pool(),
                         self.trainer_options())
    }

    /// Config echo shared by every document (workers deliberately
    /// excluded: documents must be worker-count invariant).
    fn echo(&self, experiment: &str) -> Vec<(&'static str, Json)> {
        vec![
            ("experiment", Json::str(experiment)),
            ("k", Json::Num(self.k as f64)),
            ("n", Json::Num(self.n as f64)),
            ("tile", Json::Num(self.tile as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ]
    }
}

/// PCM parameters of one fig3 ablation variant (paper Fig. 3 bar set),
/// with the gridexp determinism choices applied (ν spread off).
pub fn variant_params(tag: &str) -> Result<PcmParams> {
    let mut p = PcmParams {
        nonlinear: false,
        write_noise: false,
        read_noise: false,
        drift: false,
        drift_nu_sigma: 0.0,
        ..Default::default()
    };
    match tag {
        "linear" => {}
        "linear_write" => p.write_noise = true,
        "linear_read" => p.read_noise = true,
        "linear_drift" => p.drift = true,
        "nonlinear" => p.nonlinear = true,
        "nonlinear_write" => {
            p.nonlinear = true;
            p.write_noise = true;
        }
        "nonlinear_read" => {
            p.nonlinear = true;
            p.read_noise = true;
        }
        "full" => {
            p.nonlinear = true;
            p.write_noise = true;
            p.read_noise = true;
            p.drift = true;
        }
        // The serving device model (fig5/fig5-serve): read noise plus
        // drift on an otherwise linear device.
        "linear_read_drift" => {
            p.read_noise = true;
            p.drift = true;
        }
        other => bail!("unknown fig3 variant '{other}'"),
    }
    Ok(p)
}

/// Raw device-physics overrides layered on top of a variant's
/// [`PcmParams`] — the spec DSL's `device { … }` knobs (ROADMAP open
/// item (b)).  `None` leaves the variant's value untouched, so a
/// fully-unset tweak set changes neither the run nor the document
/// (the pinned goldens predate these keys).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeviceTweaks {
    /// per-device drift-exponent spread σ_ν (`drift_nu_sigma`)
    pub nu_sigma: Option<f32>,
    /// read-noise scale σ_read (`read_sigma`)
    pub read_sigma: Option<f32>,
    /// programming granularity Δg₀ (`dg0`)
    pub granularity: Option<f32>,
}

impl DeviceTweaks {
    pub fn apply(&self, p: &mut PcmParams) {
        if let Some(v) = self.nu_sigma {
            p.drift_nu_sigma = v;
        }
        if let Some(v) = self.read_sigma {
            p.read_sigma = v;
        }
        if let Some(v) = self.granularity {
            p.dg0 = v;
        }
    }

    /// Echo the set knobs into a document (unset knobs emit nothing —
    /// golden neutrality).
    pub(crate) fn echo_into(&self, doc: &mut Vec<(&'static str, Json)>) {
        if let Some(v) = self.nu_sigma {
            doc.push(("device_nu_sigma_u6", u6(v as f64)));
        }
        if let Some(v) = self.read_sigma {
            doc.push(("device_read_sigma_u6", u6(v as f64)));
        }
        if let Some(v) = self.granularity {
            doc.push(("device_granularity_u6", u6(v as f64)));
        }
    }
}

/// Quantize a float metric to integer micro-units (round half away from
/// zero, like `f64::round`) — every number in the documents is integral,
/// which keeps serialization byte-stable across formatters.
pub(crate) fn u6(v: f64) -> Json {
    Json::Num((v * 1e6).round())
}

fn u3(v: f64) -> Json {
    Json::Num((v * 1e3).round())
}

/// FIG3 (grid-routed): PCM non-ideality ablation on the device model.
pub fn run_fig3(opts: &GridExpOptions, variants: &[&str]) -> Result<Json> {
    let mut vmap = std::collections::BTreeMap::new();
    for &tag in variants {
        let params = variant_params(tag)?;
        let mut t = opts.trainer(params);
        t.train_steps(opts.steps);
        let t_final = t.clock.now_f32();
        let final_mse = *t.losses.last().unwrap_or(&f64::NAN);
        let eval_mse = t.eval_mse(t_final, EVAL_ROUND_BASE, false);
        let werr = t.weight_error(t_final);
        log_info!(
            "fig3-grid {tag}: train mse {final_mse:.4}, eval mse \
             {eval_mse:.4}, weight err {werr:.4}");
        vmap.insert(tag.to_string(), Json::obj(vec![
            ("final_mse_u6", u6(final_mse)),
            ("eval_mse_u6", u6(eval_mse)),
            ("weight_err_u6", u6(werr)),
            ("overflows", Json::Num(t.overflows as f64)),
            ("set_pulses", Json::Num(t.grid.total_set_pulses() as f64)),
        ]));
    }
    let mut doc = opts.echo("fig3_grid");
    doc.push(("variants", Json::Obj(vmap)));
    Ok(Json::obj(doc))
}

/// FIG5 (grid-routed): drifted inference MSE vs probe time, with and
/// without global-gain drift compensation (the device-level AdaBS
/// stand-in).  Device model: linear, read noise on, drift on.
pub fn run_fig5(opts: &GridExpOptions) -> Result<Json> {
    let params = PcmParams {
        nonlinear: false,
        write_noise: false,
        read_noise: true,
        drift: true,
        drift_nu_sigma: 0.0,
        ..Default::default()
    };
    let mut t = opts.trainer(params);
    t.train_steps(opts.steps);
    let trained_mse = *t.losses.last().unwrap_or(&f64::NAN);
    let mut probes = Vec::new();
    for (i, &probe_t) in super::fig5::probe_times().iter().enumerate() {
        let round = EVAL_ROUND_BASE + i as u64;
        // One forward pass per probe: both scores on the same
        // read-noise realization (a clean paired comparison).
        let (nocomp, comp) = t.eval_mse_pair(probe_t as f32, round);
        log_info!("fig5-grid t={probe_t:.0e}s: nocomp {nocomp:.4}, \
                   gain-comp {comp:.4}");
        probes.push(Json::obj(vec![
            ("t_seconds", Json::Num(probe_t)),
            ("mse_nocomp_u6", u6(nocomp)),
            ("mse_adabs_u6", u6(comp)),
        ]));
    }
    let mut doc = opts.echo("fig5_grid");
    doc.push(("trained_mse_u6", u6(trained_mse)));
    doc.push(("probes", Json::Arr(probes)));
    Ok(Json::obj(doc))
}

/// FIG6 (grid-routed): write–erase-cycle accounting over one training
/// run on the full device model.
pub fn run_fig6(opts: &GridExpOptions) -> Result<Json> {
    let mut t = opts.trainer(variant_params("full")?);
    t.train_steps(opts.steps);
    let ledger = t.endurance();
    log_info!("fig6-grid: {}", ledger.summary());
    let mut doc = opts.echo("fig6_grid");
    doc.push(("msb_count", Json::Num(ledger.msb.count as f64)));
    doc.push(("msb_max", Json::Num(ledger.msb.max as f64)));
    doc.push(("msb_mean_u3", u3(ledger.msb.mean())));
    doc.push(("lsb_count", Json::Num(ledger.lsb.count as f64)));
    doc.push(("lsb_max", Json::Num(ledger.lsb.max as f64)));
    doc.push(("overflows", Json::Num(t.overflows as f64)));
    doc.push(("set_pulses",
              Json::Num(t.grid.total_set_pulses() as f64)));
    Ok(Json::obj(doc))
}

// -- FIG6 --faults: accuracy vs fault rate / endurance limit -------------

/// Parameters of the fault-injection sweep (`fig6 --faults`).
#[derive(Clone, Debug)]
pub struct FaultSweepOptions {
    pub grid: GridExpOptions,
    /// total stuck-device rates swept (each split evenly over
    /// stuck-SET / stuck-RESET / stuck-open, with a proportional
    /// per-pulse programming-failure rate — see [`fault_point_spec`])
    pub rates: Vec<f32>,
    /// endurance limits swept (`0` = wear-out off)
    pub endurance: Vec<u64>,
    /// write-verify retry budget (verify is on for every point; the
    /// all-zero point has no fault plane, so verify is inert there and
    /// the point is byte-identical to a fault-free run)
    pub max_retries: u32,
}

impl Default for FaultSweepOptions {
    fn default() -> Self {
        FaultSweepOptions {
            grid: GridExpOptions::default(),
            rates: vec![0.0, 0.02, 0.05, 0.1],
            endurance: vec![0, 1000],
            max_retries: 3,
        }
    }
}

/// The [`crate::pcm::FaultSpec`] of one sweep point: the total stuck
/// rate splits evenly across the three stuck classes, the per-pulse
/// programming-failure probability scales at rate/5, and write-verify
/// is always armed with the sweep's retry budget.  Pure f32
/// arithmetic — the oracle mirrors it literally.
pub fn fault_point_spec(rate: f32, endurance_limit: u64,
                        max_retries: u32) -> crate::pcm::FaultSpec {
    crate::pcm::FaultSpec {
        stuck_set: rate / 3.0f32,
        stuck_reset: rate / 3.0f32,
        stuck_open: rate / 3.0f32,
        prog_fail: rate / 5.0f32,
        endurance_limit,
        write_verify: true,
        max_retries,
        remap: false,
    }
}

/// FIG6 `--faults` (grid-routed): final regression MSE (raw and
/// gain-compensated) vs stuck-device rate and endurance limit on the
/// linear device, with write-verify always armed.  One fresh trainer
/// per (rate, limit) point; every point reports the grid's full
/// [`crate::pcm::FaultMap`] accounting, so the document shows both the
/// accuracy decay *and* the degradation machinery's work (retry
/// totals bounded by `max_retries · verified writes`).  The
/// `(0, 0)` point allocates no fault plane and is byte-identical to a
/// fault-free run — the in-document baseline.
pub fn run_fig6_faults(opts: &FaultSweepOptions) -> Result<Json> {
    if opts.rates.is_empty() || opts.endurance.is_empty() {
        bail!("fault sweep needs at least one rate and one limit");
    }
    let mut points = Vec::new();
    for &rate in &opts.rates {
        if !(0.0..=1.0).contains(&rate) {
            bail!("fault rate {rate} outside [0, 1]");
        }
        for &limit in &opts.endurance {
            let mut params = variant_params("linear")?;
            params.fault =
                fault_point_spec(rate, limit, opts.max_retries);
            let mut t = opts.grid.trainer(params);
            t.train_steps(opts.grid.steps);
            let t_final = t.clock.now_f32();
            let (mse, mse_gain) =
                t.eval_mse_pair(t_final, EVAL_ROUND_BASE);
            let map = t.fault_summary();
            log_info!(
                "fig6-faults rate={rate} limit={limit}: mse {mse:.4} \
                 (gain {mse_gain:.4}), dead {}, retries {}",
                map.dead(), map.verify_retries);
            points.push(Json::obj(vec![
                ("fault_rate_u6", u6(rate as f64)),
                ("endurance_limit", Json::Num(limit as f64)),
                ("mse_u6", u6(mse)),
                ("mse_gain_u6", u6(mse_gain)),
                ("stuck_set", Json::Num(map.stuck_set as f64)),
                ("stuck_reset", Json::Num(map.stuck_reset as f64)),
                ("stuck_open", Json::Num(map.stuck_open as f64)),
                ("worn", Json::Num(map.worn as f64)),
                ("prog_failures",
                 Json::Num(map.prog_failures as f64)),
                ("verify_retries",
                 Json::Num(map.verify_retries as f64)),
                ("verify_failures",
                 Json::Num(map.verify_failures as f64)),
                ("overflows", Json::Num(t.overflows as f64)),
                ("set_pulses",
                 Json::Num(t.grid.total_set_pulses() as f64)),
            ]));
        }
    }
    let mut doc = opts.grid.echo("fig6_faults");
    doc.push(("max_retries", Json::Num(opts.max_retries as f64)));
    doc.push(("points", Json::Arr(points)));
    Ok(Json::obj(doc))
}

// -- FIG4 (grid-routed): the multi-layer width sweep ---------------------

/// Feature source of the fig4 device sweep.
#[derive(Clone, Debug)]
pub enum NnExpData {
    /// portable Gaussian blobs (no libm — the golden-pinned source)
    Blobs { dim: usize },
    /// image-shaped portable blobs (`[h, w, c]` HWC — the
    /// golden-pinned source of the resnet arch)
    BlobsImg { h: usize, w: usize, c: usize },
    /// pooled synthetic CIFAR from the `data` pipeline (default)
    Cifar { pool: usize },
}

/// Weight-window scale of the resnet arch (`w_max = w_scale/√fan_in`).
/// The conv/residual graph is 4+ analog hops deep: with the dense
/// default (2.0) the backprop errors attenuate below the ADC's
/// quantization floor after ~2 transposed VMMs and the deep grids
/// receive exactly-zero gradients; 4.0 keeps activations and errors
/// O(1) through depth so the whole stack trains (validated against the
/// oracle: 0.33 → 1.00 eval accuracy on the residual learning config).
/// An AdaBS-style per-layer backward range calibration is the next
/// modeling rung (see ROADMAP).
pub const RESNET_W_SCALE: f32 = 4.0;

/// Architecture of the fig4 device sweep.
#[derive(Clone, Debug)]
pub enum NnArch {
    /// dense ReLU stack (`hidden_base` scaled per width — the PR-3
    /// sweep, document layout unchanged)
    Mlp,
    /// ResNet-style conv/residual stages on the layer graph
    /// (`GraphSpec::resnet`): per-stage channel bases scaled per
    /// width, `blocks` residual blocks per stage
    Resnet { stages: [usize; 3], blocks: usize },
    /// Explicit layer list (the experiment-spec DSL's `layers { … }`
    /// block): the base extents of every weighted layer except the
    /// classifier head are scaled per width
    /// ([`crate::nn::graph::scale_widths`]).
    Custom { layers: Vec<LayerSpec> },
}

/// Default device variant of the fig4 sweep (see [`variant_params`]):
/// linear device, read noise on — the golden-pinned model.
pub const FIG4_DEFAULT_VARIANT: &str = "linear_read";

/// Parameters of the grid-routed fig4 width sweep.
#[derive(Clone, Debug)]
pub struct NnExpOptions {
    pub data: NnExpData,
    pub arch: NnArch,
    /// base hidden widths, scaled by each width multiplier (mlp arch)
    pub hidden_base: Vec<usize>,
    /// width multipliers in permille (integers keep documents
    /// byte-stable)
    pub widths_permille: Vec<u32>,
    /// classes (blobs; the CIFAR source is always 10)
    pub classes: usize,
    pub steps: usize,
    pub batch: usize,
    /// square physical tile size
    pub tile: usize,
    /// evaluation samples per accuracy point
    pub eval_n: usize,
    pub train_len: usize,
    pub test_len: usize,
    pub lr: f32,
    /// blob per-feature noise σ
    pub blob_noise: f32,
    pub seed: u64,
    /// worker threads (0 = `HIC_WORKERS` / machine default)
    pub workers: usize,
    pub out_dir: PathBuf,
    /// device variant tag ([`variant_params`]); the default
    /// ([`FIG4_DEFAULT_VARIANT`]) is the golden-pinned model
    pub device_variant: String,
    /// raw device-knob overrides on top of the variant (the spec
    /// DSL's `device { … }` block; all-`None` = golden-neutral)
    pub device_tweaks: DeviceTweaks,
    /// batches between MSB refreshes (0 = never — the golden default)
    pub refresh_every: usize,
    /// explicit CIFAR-10 directory (overrides `$HIC_CIFAR10` and the
    /// `data/` discovery; `None` = auto-discover)
    pub cifar_dir: Option<PathBuf>,
}

impl Default for NnExpOptions {
    fn default() -> Self {
        NnExpOptions {
            data: NnExpData::Cifar { pool: 8 },
            arch: NnArch::Mlp,
            hidden_base: vec![32, 16],
            widths_permille: WIDTHS_PERMILLE.to_vec(),
            classes: 10,
            steps: 150,
            batch: 16,
            tile: 32,
            eval_n: 200,
            train_len: 2000,
            test_len: 500,
            lr: 0.1,
            blob_noise: 0.5,
            seed: 42,
            workers: 0,
            out_dir: PathBuf::from("results"),
            device_variant: FIG4_DEFAULT_VARIANT.to_string(),
            device_tweaks: DeviceTweaks::default(),
            refresh_every: 0,
            cifar_dir: None,
        }
    }
}

impl NnExpOptions {
    /// Scale the resnet sweep to the paper's full ResNet-32 / CIFAR-10
    /// shape (CLI `--long-run`): 5 residual blocks per stage (the
    /// paper's 6·5 + 2 weighted layers, plus the 1×1 skip projections)
    /// on unpooled 32×32×3 synthetic CIFAR inputs.  Stage channel
    /// bases, widths, steps and batch stay caller-controlled — the
    /// flag pins the *shape*, the smoke configs pin the budget.
    /// Errors unless the resnet arch is selected.
    pub fn apply_long_run(&mut self) -> Result<()> {
        match self.arch {
            NnArch::Resnet { stages, .. } => {
                self.arch = NnArch::Resnet { stages, blocks: 5 };
                self.data = NnExpData::Cifar { pool: 1 };
                Ok(())
            }
            NnArch::Mlp | NnArch::Custom { .. } => {
                bail!("--long-run needs --arch resnet")
            }
        }
    }

    pub fn pool(&self) -> WorkerPool {
        if self.workers == 0 {
            WorkerPool::from_env()
        } else {
            WorkerPool::new(self.workers)
        }
    }

    fn feature_source(&self) -> FeatureSource {
        match self.data {
            NnExpData::Blobs { dim } => FeatureSource::Blobs(
                BlobDataset::new(self.seed, dim, self.classes,
                                 self.blob_noise, self.train_len,
                                 self.test_len)),
            NnExpData::BlobsImg { h, w, c } => FeatureSource::Blobs(
                BlobDataset::with_shape(self.seed, h, w, c,
                                        self.classes, self.blob_noise,
                                        self.train_len, self.test_len)),
            // Real CIFAR-10 bytes when a dataset directory is present
            // (explicit `cifar_dir` first, then `$HIC_CIFAR10` /
            // `data/` discovery); the synthetic provider stays the
            // fallback, so CI and the goldens never see the real path.
            NnExpData::Cifar { pool } => FeatureSource::pooled_cifar_from(
                self.cifar_dir.as_deref(), self.seed, pool,
                self.train_len, self.test_len),
        }
    }

    /// Feature dimension of the configured source, computed without
    /// building a dataset (the CIFAR source generates its class
    /// prototypes at construction — don't pay that just for a shape).
    fn input_dim(&self) -> usize {
        self.input_shape().len()
    }

    /// Activation shape of the configured source (same no-dataset
    /// shortcut as [`NnExpOptions::input_dim`]).
    fn input_shape(&self) -> ActShape {
        match self.data {
            NnExpData::Blobs { dim } => ActShape::Flat(dim),
            NnExpData::BlobsImg { h, w, c } => ActShape::Img { h, w, c },
            NnExpData::Cifar { pool } => ActShape::Img {
                h: IMG_H / pool, w: IMG_W / pool, c: IMG_C,
            },
        }
    }

    fn data_classes(&self) -> usize {
        match self.data {
            NnExpData::Blobs { .. } | NnExpData::BlobsImg { .. } => {
                self.classes
            }
            NnExpData::Cifar { .. } => NUM_CLASSES,
        }
    }

    fn spec(&self, width_permille: u32) -> NetSpec {
        NetSpec {
            input: self.input_dim(),
            hidden_base: self.hidden_base.clone(),
            classes: self.data_classes(),
            width_permille,
        }
    }

    /// Layer graph of one width point under the configured arch.
    fn graph_spec(&self, width_permille: u32) -> Result<GraphSpec> {
        match self.arch {
            NnArch::Mlp => Ok(GraphSpec::mlp(&self.spec(width_permille)
                .dims())),
            NnArch::Resnet { stages, blocks } => {
                let ActShape::Img { h, w, c } = self.input_shape()
                else {
                    bail!("--arch resnet needs image-shaped data \
                           (cifar or image blobs)");
                };
                Ok(GraphSpec::resnet([h, w, c], stages, blocks,
                                     self.data_classes(),
                                     width_permille))
            }
            NnArch::Custom { ref layers } => {
                let mut scaled = layers.clone();
                scale_widths(&mut scaled, width_permille);
                let spec = GraphSpec {
                    input: self.input_shape(),
                    layers: scaled,
                };
                if let Err(e) = spec.shape_check() {
                    bail!("custom arch at width {width_permille}: {e}");
                }
                Ok(spec)
            }
        }
    }

    fn echo(&self) -> Vec<(&'static str, Json)> {
        let (data_tag, data_param) = match self.data {
            NnExpData::Blobs { dim } => ("blobs", dim),
            NnExpData::BlobsImg { h, w, c } => ("blobs_img", h * w * c),
            NnExpData::Cifar { pool } => ("cifar_pooled", pool),
        };
        let mut doc = vec![
            ("experiment", Json::str("fig4_grid")),
            ("data", Json::str(data_tag)),
            ("data_param", Json::Num(data_param as f64)),
            ("input", Json::Num(self.input_dim() as f64)),
            ("classes", Json::Num(self.data_classes() as f64)),
        ];
        // Arch-specific keys; the mlp set is exactly the PR-3 document
        // layout (the dense golden pins those bytes).
        match self.arch {
            NnArch::Mlp => {
                doc.push(("hidden_base", Json::Arr(
                    self.hidden_base.iter()
                        .map(|&h| Json::Num(h as f64)).collect())));
            }
            NnArch::Resnet { stages, blocks } => {
                doc.push(("arch", Json::str("resnet")));
                doc.push(("stage_bases", Json::Arr(
                    stages.iter()
                        .map(|&s| Json::Num(s as f64)).collect())));
                doc.push(("blocks_per_stage",
                          Json::Num(blocks as f64)));
            }
            NnArch::Custom { ref layers } => {
                doc.push(("arch", Json::str("custom")));
                doc.push(("custom_layers",
                          Json::Num(layers.len() as f64)));
            }
        }
        doc.extend([
            ("steps", Json::Num(self.steps as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("tile", Json::Num(self.tile as f64)),
            ("eval_n", Json::Num(self.eval_n as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ]);
        // Non-default knobs only: the pinned golden documents predate
        // these keys, and their configs leave them at the defaults.
        if self.device_variant != FIG4_DEFAULT_VARIANT {
            doc.push(("device_variant",
                      Json::Str(self.device_variant.clone())));
        }
        if self.refresh_every != 0 {
            doc.push(("refresh_every",
                      Json::Num(self.refresh_every as f64)));
        }
        self.device_tweaks.echo_into(&mut doc);
        doc
    }
}

/// FIG4 (grid-routed): accuracy vs inference model size across width
/// multipliers, multi-layer training **on the device grids** (forward
/// analog VMM, transposed-VMM backprop — with im2col patch lowering
/// through conv/residual layers under `--arch resnet` — and hybrid
/// updates) against the FP32 host baseline of the same architecture.
/// Device model: linear, read noise on (every consumed op portable, so
/// the documents are byte-stable and golden-pinnable).
pub fn run_fig4(opts: &NnExpOptions) -> Result<Json> {
    if opts.widths_permille.is_empty() {
        bail!("fig4 needs at least one width multiplier");
    }
    // Default variant "linear_read" reproduces the historical
    // hard-coded model (linear device, read noise on) byte for byte;
    // tweaks layer on top (all-None = untouched).
    let mut params = variant_params(&opts.device_variant)?;
    opts.device_tweaks.apply(&mut params);
    let policy =
        TilingPolicy { tile_rows: opts.tile, tile_cols: opts.tile };
    let mut rows = Vec::new();
    // Per-arch weight-window scale (see `RESNET_W_SCALE`); custom
    // graphs take the conv scale iff they go through conv depth.
    let w_scale = match opts.arch {
        NnArch::Mlp => NetTrainerOptions::default().w_scale,
        NnArch::Resnet { .. } => RESNET_W_SCALE,
        NnArch::Custom { ref layers } => {
            if has_conv(layers) {
                RESNET_W_SCALE
            } else {
                NetTrainerOptions::default().w_scale
            }
        }
    };
    for &w in &opts.widths_permille {
        let spec = opts.graph_spec(w)?;
        let mut t = NetTrainer::from_spec(
            params, &spec, policy, opts.feature_source(), opts.pool(),
            NetTrainerOptions {
                seed: opts.seed,
                lr: LrSchedule::constant(opts.lr),
                refresh_every: opts.refresh_every,
                batch: opts.batch,
                w_scale,
                ..Default::default()
            });
        t.train_steps(opts.steps);
        let (eval_loss, acc) = t.evaluate(opts.eval_n, t.clock.now_f32());
        let train_loss = *t.losses.last().unwrap_or(&f64::NAN);
        let bits = t.net.inference_bits();
        log_info!(
            "fig4-grid hic w={:.2}: {} grids, {} bits, eval acc \
             {acc:.3}, eval loss {eval_loss:.3}",
            w as f64 / 1000.0, t.net.weighted_layers(), bits);
        rows.push(Json::obj(vec![
            ("series", Json::str("hic")),
            ("width_permille", Json::Num(w as f64)),
            ("model_bits", Json::Num(bits as f64)),
            ("eval_acc_u6", u6(acc)),
            ("eval_loss_u6", u6(eval_loss)),
            ("final_train_loss_u6", u6(train_loss)),
            ("overflows", Json::Num(t.overflows as f64)),
            ("set_pulses", Json::Num(t.total_set_pulses() as f64)),
        ]));
    }
    for &w in &opts.widths_permille {
        let data = opts.feature_source();
        let (eval_loss, acc, train_loss, bits) = match opts.arch {
            // The dense arch keeps the original `FpNet` baseline — its
            // exact f32 op order is what the dense golden pins.
            NnArch::Mlp => {
                let dims = opts.spec(w).dims();
                let mut net = FpNet::new(&dims, 2.0, opts.seed);
                net.train_steps(&data, opts.steps, opts.batch, opts.lr);
                let (el, acc) =
                    net.evaluate(&data, opts.eval_n, opts.batch);
                (el, acc, *net.losses.last().unwrap_or(&f64::NAN),
                 net.inference_bits())
            }
            NnArch::Resnet { .. } | NnArch::Custom { .. } => {
                let spec = opts.graph_spec(w)?;
                // Same init law as the device rows (w_scale included).
                let mut net =
                    FpGraphNet::new(&spec, w_scale, opts.seed);
                net.train_steps(&data, opts.steps, opts.batch, opts.lr);
                let (el, acc) =
                    net.evaluate(&data, opts.eval_n, opts.batch);
                (el, acc, *net.losses.last().unwrap_or(&f64::NAN),
                 net.inference_bits())
            }
        };
        log_info!(
            "fig4-grid fp32 w={:.2}: {} bits, eval acc {acc:.3}, \
             eval loss {eval_loss:.3}",
            w as f64 / 1000.0, bits);
        rows.push(Json::obj(vec![
            ("series", Json::str("fp32")),
            ("width_permille", Json::Num(w as f64)),
            ("model_bits", Json::Num(bits as f64)),
            ("eval_acc_u6", u6(acc)),
            ("eval_loss_u6", u6(eval_loss)),
            ("final_train_loss_u6", u6(train_loss)),
        ]));
    }
    let mut doc = opts.echo();
    doc.push(("widths_permille", Json::Arr(
        opts.widths_permille.iter()
            .map(|&w| Json::Num(w as f64)).collect())));
    doc.push(("rows", Json::Arr(rows)));
    Ok(Json::obj(doc))
}

/// Write a metric document under the experiment output directory.
pub fn write_json(dir: &Path, name: &str, doc: &Json) -> Result<PathBuf> {
    ensure_out_dir(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, doc.to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    log_info!("wrote {}", path.display());
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> GridExpOptions {
        GridExpOptions {
            k: 6,
            n: 4,
            tile: 3,
            steps: 4,
            batch: 3,
            seed: 5,
            workers: 1,
            out_dir: PathBuf::from("/tmp"),
        }
    }

    #[test]
    fn fig3_document_shape() {
        let doc = run_fig3(&tiny(), &["linear", "full"]).unwrap();
        assert_eq!(doc.get("experiment").unwrap().as_str().unwrap(),
                   "fig3_grid");
        let variants = doc.get("variants").unwrap().as_obj().unwrap();
        assert_eq!(variants.len(), 2);
        for v in variants.values() {
            for key in ["final_mse_u6", "eval_mse_u6", "weight_err_u6",
                        "overflows", "set_pulses"] {
                let num = v.get(key).unwrap().as_f64().unwrap();
                assert!(num.is_finite() && num.fract() == 0.0,
                        "{key} = {num} not integral");
            }
        }
    }

    fn tiny_nn() -> NnExpOptions {
        NnExpOptions {
            data: NnExpData::Blobs { dim: 6 },
            hidden_base: vec![4, 3],
            widths_permille: vec![500, 1000],
            classes: 3,
            steps: 4,
            batch: 3,
            tile: 3,
            eval_n: 6,
            train_len: 30,
            test_len: 12,
            lr: 0.05, // pinned: the golden/oracle TINY_NN config
            workers: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fig4_document_shape_and_worker_invariance() {
        let doc = run_fig4(&tiny_nn()).unwrap();
        assert_eq!(doc.get("experiment").unwrap().as_str().unwrap(),
                   "fig4_grid");
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        // One HIC + one FP32 row per width, HIC first.
        assert_eq!(rows.len(), 4);
        for (i, r) in rows.iter().enumerate() {
            let series = r.get("series").unwrap().as_str().unwrap();
            assert_eq!(series, if i < 2 { "hic" } else { "fp32" });
            for key in ["width_permille", "model_bits", "eval_acc_u6",
                        "eval_loss_u6", "final_train_loss_u6"] {
                let num = r.get(key).unwrap().as_f64().unwrap();
                assert!(num.is_finite() && num.fract() == 0.0,
                        "{key} = {num} not integral");
            }
        }
        // The hybrid representation must actually be smaller: 4 bits
        // vs 32 at equal width.
        let hic_bits = rows[1].get("model_bits").unwrap().as_f64().unwrap();
        let fp_bits = rows[3].get("model_bits").unwrap().as_f64().unwrap();
        assert_eq!(fp_bits, 8.0 * hic_bits);
        // Document is worker-count invariant.
        let w4 = run_fig4(&NnExpOptions { workers: 4, ..tiny_nn() })
            .unwrap();
        assert_eq!(doc.to_string(), w4.to_string());
    }

    /// The golden/oracle RESNET_NN config: tiny image blobs, reduced
    /// stage bases, one block per stage, four width multipliers.
    fn tiny_resnet() -> NnExpOptions {
        NnExpOptions {
            data: NnExpData::BlobsImg { h: 4, w: 4, c: 3 },
            arch: NnArch::Resnet { stages: [4, 6, 8], blocks: 1 },
            widths_permille: vec![500, 750, 1000, 1500],
            classes: 3,
            steps: 3,
            batch: 2,
            tile: 4,
            eval_n: 4,
            train_len: 24,
            test_len: 8,
            lr: 0.08,
            workers: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fig4_resnet_document_shape() {
        let doc = run_fig4(&tiny_resnet()).unwrap();
        assert_eq!(doc.get("experiment").unwrap().as_str().unwrap(),
                   "fig4_grid");
        assert_eq!(doc.get("arch").unwrap().as_str().unwrap(), "resnet");
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        // One HIC + one FP32 row per width, HIC first.
        assert_eq!(rows.len(), 8);
        for (i, r) in rows.iter().enumerate() {
            let series = r.get("series").unwrap().as_str().unwrap();
            assert_eq!(series, if i < 4 { "hic" } else { "fp32" });
            for key in ["width_permille", "model_bits", "eval_acc_u6",
                        "eval_loss_u6", "final_train_loss_u6"] {
                let num = r.get(key).unwrap().as_f64().unwrap();
                assert!(num.is_finite() && num.fract() == 0.0,
                        "{key} = {num} not integral");
            }
        }
        // Same architecture per width: FP32 holds 8× the bits.
        for i in 0..4 {
            let hic = rows[i].get("model_bits").unwrap().as_f64().unwrap();
            let fp =
                rows[i + 4].get("model_bits").unwrap().as_f64().unwrap();
            assert_eq!(fp, 8.0 * hic);
        }
        // Wider nets hold more weights.
        let b0 = rows[0].get("model_bits").unwrap().as_f64().unwrap();
        let b3 = rows[3].get("model_bits").unwrap().as_f64().unwrap();
        assert!(b3 > b0);
    }

    #[test]
    fn fig4_resnet_is_worker_invariant() {
        // One width point is enough here (the golden suite pins the
        // full document): the conv/residual path must not depend on
        // the worker count.
        let opts = NnExpOptions {
            widths_permille: vec![750],
            ..tiny_resnet()
        };
        let a = run_fig4(&opts).unwrap().to_string();
        let b = run_fig4(&NnExpOptions { workers: 4, ..opts })
            .unwrap()
            .to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn long_run_scales_to_the_paper_shape() {
        // Mlp arch: refused.
        let mut mlp = tiny_nn();
        assert!(mlp.apply_long_run().is_err());
        // Resnet arch: 5 blocks per stage on unpooled 32x32x3 CIFAR.
        let mut opts = NnExpOptions {
            arch: NnArch::Resnet { stages: [16, 32, 64], blocks: 1 },
            data: NnExpData::Cifar { pool: 4 },
            ..NnExpOptions::default()
        };
        opts.apply_long_run().unwrap();
        assert!(matches!(opts.arch,
                         NnArch::Resnet { blocks: 5,
                                          stages: [16, 32, 64] }));
        assert!(matches!(opts.data, NnExpData::Cifar { pool: 1 }));
        assert_eq!(opts.input_shape(),
                   ActShape::Img { h: 32, w: 32, c: 3 });
        // ResNet-32: stem + 6·5 body convs + dense head = the paper's
        // 32 weighted layers, plus the two 1x1 skip projections.
        let plan = opts.graph_spec(1000).unwrap().plan();
        assert_eq!(plan.weighted.len(), 34);
    }

    #[test]
    fn resnet_arch_rejects_flat_data() {
        let opts = NnExpOptions {
            data: NnExpData::Blobs { dim: 48 },
            ..tiny_resnet()
        };
        assert!(run_fig4(&opts).is_err());
    }

    #[test]
    fn unknown_variant_is_an_error() {
        assert!(variant_params("linear").is_ok());
        assert!(variant_params("warp_drive").is_err());
    }

    #[test]
    fn fig5_probes_cover_the_time_axis() {
        let doc = run_fig5(&tiny()).unwrap();
        let probes = doc.get("probes").unwrap().as_arr().unwrap();
        assert_eq!(probes.len(), super::super::fig5::probe_times().len());
        let t0 = probes[0].get("t_seconds").unwrap().as_f64().unwrap();
        assert_eq!(t0, 1e2);
    }

    #[test]
    fn fig6_ledger_counts_every_device() {
        let o = tiny();
        let doc = run_fig6(&o).unwrap();
        let msb = doc.get("msb_count").unwrap().as_f64().unwrap();
        // 2 devices per weight cell, G+ and G− planes both recorded.
        assert_eq!(msb as usize, 2 * o.k * o.n);
    }

    fn tiny_faults() -> FaultSweepOptions {
        FaultSweepOptions {
            grid: tiny(),
            rates: vec![0.0, 0.2],
            endurance: vec![0, 30],
            max_retries: 2,
        }
    }

    #[test]
    fn fault_sweep_document_shape() {
        let doc = run_fig6_faults(&tiny_faults()).unwrap();
        assert_eq!(doc.get("experiment").unwrap().as_str().unwrap(),
                   "fig6_faults");
        let points = doc.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 4); // 2 rates × 2 limits
        for p in points {
            for key in ["fault_rate_u6", "endurance_limit", "mse_u6",
                        "mse_gain_u6", "stuck_set", "stuck_reset",
                        "stuck_open", "worn", "prog_failures",
                        "verify_retries", "verify_failures",
                        "overflows", "set_pulses"] {
                let num = p.get(key).unwrap().as_f64().unwrap();
                assert!(num.is_finite() && num.fract() == 0.0,
                        "{key} = {num} not integral");
            }
        }
        // The all-zero point is fault-free: no dead devices, no
        // verify activity.
        let base = &points[0];
        for key in ["stuck_set", "stuck_reset", "stuck_open", "worn",
                    "prog_failures", "verify_retries",
                    "verify_failures"] {
            assert_eq!(base.get(key).unwrap().as_f64().unwrap(), 0.0,
                       "baseline {key} nonzero");
        }
        // At 20% stuck rate the dead population must be visible, and
        // the stuck counts are worker-schedule-free placement counts.
        let faulty = &points[2];
        let dead = faulty.get("stuck_set").unwrap().as_f64().unwrap()
            + faulty.get("stuck_reset").unwrap().as_f64().unwrap()
            + faulty.get("stuck_open").unwrap().as_f64().unwrap();
        assert!(dead > 0.0, "no stuck devices at 20%");
        // Retry totals are bounded by budget × verified writes (each
        // verified write is ≤ one overflow-programmed increment, and
        // set_pulses counts every pulse including retries).
        let retries =
            faulty.get("verify_retries").unwrap().as_f64().unwrap();
        let pulses = faulty.get("set_pulses").unwrap().as_f64().unwrap();
        assert!(retries <= pulses,
                "retries {retries} exceed total pulses {pulses}");
    }

    #[test]
    fn fault_sweep_zero_point_matches_fault_free_run() {
        // The (rate=0, limit=0) point trains the identical model to a
        // plain linear fig3 run: same MSE to the last micro-unit.
        let o = tiny();
        let sweep = run_fig6_faults(&FaultSweepOptions {
            grid: o.clone(),
            rates: vec![0.0],
            endurance: vec![0],
            max_retries: 2,
        })
        .unwrap();
        let point = &sweep.get("points").unwrap().as_arr().unwrap()[0];
        let fig3 = run_fig3(&o, &["linear"]).unwrap();
        let want = fig3.get("variants").unwrap().get("linear").unwrap()
            .get("eval_mse_u6").unwrap().as_f64().unwrap();
        assert_eq!(point.get("mse_u6").unwrap().as_f64().unwrap(), want);
    }

    #[test]
    fn fault_sweep_is_worker_invariant() {
        let a = run_fig6_faults(&tiny_faults()).unwrap().to_string();
        let opts = FaultSweepOptions {
            grid: GridExpOptions { workers: 4, ..tiny() },
            ..tiny_faults()
        };
        let b = run_fig6_faults(&opts).unwrap().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn fault_sweep_rejects_bad_config() {
        let mut o = tiny_faults();
        o.rates = vec![1.5];
        assert!(run_fig6_faults(&o).is_err());
        o.rates = Vec::new();
        assert!(run_fig6_faults(&o).is_err());
    }

    #[test]
    fn device_tweaks_apply_and_echo() {
        let mut p = variant_params("linear").unwrap();
        let none = DeviceTweaks::default();
        let before = p;
        none.apply(&mut p);
        assert_eq!(p, before);
        let tw = DeviceTweaks {
            nu_sigma: Some(0.01),
            read_sigma: Some(0.02),
            granularity: Some(0.05),
        };
        tw.apply(&mut p);
        assert_eq!(p.drift_nu_sigma, 0.01);
        assert_eq!(p.read_sigma, 0.02);
        assert_eq!(p.dg0, 0.05);
        // Echo: nothing for the default, three keys when all set.
        let mut doc = Vec::new();
        none.echo_into(&mut doc);
        assert!(doc.is_empty());
        tw.echo_into(&mut doc);
        assert_eq!(doc.len(), 3);
        // And a default tweak set leaves the fig4 document unchanged.
        let plain = run_fig4(&tiny_nn()).unwrap().to_string();
        let tweaked = run_fig4(&NnExpOptions {
            device_tweaks: DeviceTweaks::default(),
            ..tiny_nn()
        })
        .unwrap()
        .to_string();
        assert_eq!(plain, tweaked);
    }
}
