//! The paper's width-multiplier table and model-size accounting —
//! shared by the artifact-backed fig4 driver (`exp::fig4`) and the
//! grid-routed device sweep (`exp::gridexp::run_fig4`), so the legacy
//! and device-grid paths can never drift apart.
//!
//! Widths are permille integers (`500 = 0.5×`) everywhere; the legacy
//! artifact configs encode them as `0p5`-style tags
//! ([`permille_tag`]), reports as `0.5`-style labels
//! ([`permille_label`]).

/// The HIC width sweep of paper Fig. 4 (×0.5 … ×1.5).
pub const WIDTHS_PERMILLE: [u32; 4] = [500, 750, 1000, 1500];

/// The FP32 baseline sweep (×0.25 … ×1.0 — the paper compares smaller
/// baselines because FP32 stores 8× the bits per weight).
pub const BASE_WIDTHS_PERMILLE: [u32; 4] = [250, 500, 750, 1000];

/// `"0.5"`-style display label of a permille width (trailing zeros of
/// the fraction trimmed; integral widths keep one zero: `"1.0"`).
pub fn permille_label(w: u32) -> String {
    let ip = w / 1000;
    let frac = w % 1000;
    if frac == 0 {
        return format!("{ip}.0");
    }
    let mut digits = format!("{frac:03}");
    while digits.ends_with('0') {
        digits.pop();
    }
    format!("{ip}.{digits}")
}

/// `"0p5"`-style artifact-config tag of a permille width (the label
/// with `.` replaced, matching the `fig4_hic_w0p5` config names).
pub fn permille_tag(w: u32) -> String {
    permille_label(w).replace('.', "p")
}

/// Bits → KB (the fig4 report axis; also `hic-train info`'s model-size
/// echo).  Per-weight bit counts stay with their owners — the grids'
/// `inference_bits` (4-bit MSB arrays) and the FP32 nets' (32).
pub fn bits_to_kb(bits: usize) -> f64 {
    bits as f64 / 8.0 / 1024.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_tags_match_the_legacy_config_names() {
        let tags: Vec<String> =
            WIDTHS_PERMILLE.iter().map(|&w| permille_tag(w)).collect();
        assert_eq!(tags, vec!["0p5", "0p75", "1p0", "1p5"]);
        let base: Vec<String> = BASE_WIDTHS_PERMILLE
            .iter()
            .map(|&w| permille_tag(w))
            .collect();
        assert_eq!(base, vec!["0p25", "0p5", "0p75", "1p0"]);
        assert_eq!(permille_label(1500), "1.5");
        assert_eq!(permille_label(250), "0.25");
        assert_eq!(permille_label(1000), "1.0");
    }

    #[test]
    fn model_size_accounting() {
        assert_eq!(bits_to_kb(8 * 1024), 1.0);
        assert_eq!(bits_to_kb(0), 0.0);
    }
}
