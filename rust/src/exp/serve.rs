//! FIG5-SERVE — drifted inference accuracy **through the serving
//! stack** (paper Fig. 5's axis, measured under load instead of via
//! the trainer's eval path).
//!
//! Train a dense MLP on the device grids, freeze it into a
//! [`ModelSnapshot`], then at each fig5 probe time replay a
//! deterministic synthetic request trace through the coalescing
//! scheduler twice — uncalibrated, then gain-recalibrated — and report
//! per-probe accuracy, coalescing counters and simulated-latency
//! quantiles as a byte-stable metric JSON document (same `u6`
//! quantization and determinism contract as the other grid sweeps:
//! the document depends only on the options, never on the worker
//! count or the coalescing schedule's execution order).
//!
//! Both serving passes of a probe replay the *same* trace, so they
//! consume identical `(SERVE_ROUND_BASE, request id)` read-noise
//! streams: the calibrated-vs-uncalibrated accuracy delta isolates
//! the gain compensation exactly (a paired comparison, like
//! `run_fig5`'s `eval_mse_pair`).
//!
//! Recalibration runs as a **low-priority background task** on the
//! PR-6 pipeline lane ([`crate::util::pool::PipelineScope::spawn`]),
//! joining before the calibrated pass — lane placement is pure
//! scheduling and cannot change a served bit (the snapshot's
//! calibration streams are counter-based, like everything else).

use std::path::PathBuf;

use anyhow::Result;

use super::gridexp::{variant_params, DeviceTweaks};

use crate::coordinator::nettrainer::{NetTrainer, NetTrainerOptions};
use crate::coordinator::schedule::LrSchedule;
use crate::crossbar::TilingPolicy;
use crate::data::{IMG_C, IMG_H, IMG_W, NUM_CLASSES};
use crate::log_info;
use crate::nn::features::{BlobDataset, FeatureSource};
use crate::nn::graph::GraphSpec;
use crate::serve::{gen_trace, serve_trace, CoalescePolicy, ModelSnapshot};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

use super::gridexp::u6;

/// Feature source of the serving sweep (the blobs source is the
/// golden-pinned one; CIFAR auto-routes to real bytes when present).
#[derive(Clone, Debug)]
pub enum ServeData {
    Blobs { dim: usize },
    Cifar { pool: usize },
}

/// Parameters of the fig5-serve run: a training config (dense MLP on
/// the device grids), a snapshot config (calibration-set size) and a
/// serving config (trace and coalescing knobs).
#[derive(Clone, Debug)]
pub struct ServeExpOptions {
    pub data: ServeData,
    /// hidden widths of the dense stack
    pub hidden: Vec<usize>,
    /// classes (blobs; the CIFAR source is always 10)
    pub classes: usize,
    pub steps: usize,
    pub batch: usize,
    /// square physical tile size
    pub tile: usize,
    pub train_len: usize,
    pub test_len: usize,
    pub lr: f32,
    /// blob per-feature noise σ
    pub blob_noise: f32,
    pub seed: u64,
    /// requests per probe trace
    pub requests: usize,
    /// mean inter-arrival gap (simulated seconds)
    pub mean_gap: f64,
    /// coalescing window (simulated seconds)
    pub window: f64,
    pub max_batch: usize,
    pub queue_cap: usize,
    /// held-out calibration samples (first `calib_n` of the train split)
    pub calib_n: usize,
    /// worker threads (0 = `HIC_WORKERS` / machine default)
    pub workers: usize,
    pub out_dir: PathBuf,
    /// device variant tag ([`variant_params`]); the default
    /// ([`SERVE_DEFAULT_VARIANT`]) is the golden-pinned fig5 model
    pub device_variant: String,
    /// raw device-knob overrides on top of the variant (the spec
    /// DSL's `device { … }` block; all-`None` = golden-neutral)
    pub device_tweaks: DeviceTweaks,
    /// fault-injection spec carried through training into the frozen
    /// snapshot (default disabled — golden-neutral)
    pub fault: crate::pcm::FaultSpec,
    /// batches between MSB refreshes during training (0 = never)
    pub refresh_every: usize,
    /// drift probe times in simulated seconds (default: the fig5 axis,
    /// [`super::fig5::probe_times`])
    pub probes: Vec<f64>,
    /// explicit CIFAR-10 directory (overrides discovery; `None` = auto)
    pub cifar_dir: Option<PathBuf>,
}

/// Default device variant of the serving sweep: linear device, read
/// noise and drift on — the same model `run_fig5` hard-codes.
pub const SERVE_DEFAULT_VARIANT: &str = "linear_read_drift";

impl Default for ServeExpOptions {
    fn default() -> Self {
        ServeExpOptions {
            data: ServeData::Cifar { pool: 8 },
            hidden: vec![32, 16],
            classes: 10,
            steps: 150,
            batch: 16,
            tile: 32,
            train_len: 2000,
            test_len: 500,
            lr: 0.1,
            blob_noise: 0.5,
            seed: 42,
            requests: 256,
            mean_gap: 0.01,
            window: 0.05,
            max_batch: 16,
            queue_cap: 64,
            calib_n: 64,
            workers: 0,
            out_dir: PathBuf::from("results"),
            device_variant: SERVE_DEFAULT_VARIANT.to_string(),
            device_tweaks: DeviceTweaks::default(),
            fault: crate::pcm::FaultSpec::default(),
            refresh_every: 0,
            probes: super::fig5::probe_times(),
            cifar_dir: None,
        }
    }
}

impl ServeExpOptions {
    pub fn pool(&self) -> WorkerPool {
        if self.workers == 0 {
            WorkerPool::from_env()
        } else {
            WorkerPool::new(self.workers)
        }
    }

    fn feature_source(&self) -> FeatureSource {
        match self.data {
            ServeData::Blobs { dim } => FeatureSource::Blobs(
                BlobDataset::new(self.seed, dim, self.classes,
                                 self.blob_noise, self.train_len,
                                 self.test_len)),
            ServeData::Cifar { pool } => FeatureSource::pooled_cifar_from(
                self.cifar_dir.as_deref(), self.seed, pool,
                self.train_len, self.test_len),
        }
    }

    fn input_dim(&self) -> usize {
        match self.data {
            ServeData::Blobs { dim } => dim,
            ServeData::Cifar { pool } => {
                (IMG_H / pool) * (IMG_W / pool) * IMG_C
            }
        }
    }

    fn data_classes(&self) -> usize {
        match self.data {
            ServeData::Blobs { .. } => self.classes,
            ServeData::Cifar { .. } => NUM_CLASSES,
        }
    }

    fn dims(&self) -> Vec<usize> {
        let mut dims = vec![self.input_dim()];
        dims.extend(self.hidden.iter().copied());
        dims.push(self.data_classes());
        dims
    }

    /// Config echo (workers deliberately excluded: documents must be
    /// worker-count invariant; float knobs enter as micro-units).
    fn echo(&self) -> Vec<(&'static str, Json)> {
        let (data_tag, data_param) = match self.data {
            ServeData::Blobs { dim } => ("blobs", dim),
            ServeData::Cifar { pool } => ("cifar_pooled", pool),
        };
        let mut doc = vec![
            ("experiment", Json::str("fig5_serve")),
            ("data", Json::str(data_tag)),
            ("data_param", Json::Num(data_param as f64)),
            ("input", Json::Num(self.input_dim() as f64)),
            ("classes", Json::Num(self.data_classes() as f64)),
            ("hidden", Json::Arr(
                self.hidden.iter().map(|&h| Json::Num(h as f64))
                    .collect())),
            ("steps", Json::Num(self.steps as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("tile", Json::Num(self.tile as f64)),
            ("train_len", Json::Num(self.train_len as f64)),
            ("test_len", Json::Num(self.test_len as f64)),
            ("lr_u6", u6(self.lr as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("mean_gap_u6", u6(self.mean_gap)),
            ("window_u6", u6(self.window)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("queue_cap", Json::Num(self.queue_cap as f64)),
            ("calib_n", Json::Num(self.calib_n as f64)),
        ];
        // Non-default knobs only: the pinned golden document predates
        // these keys, and its config leaves them at the defaults.
        if self.device_variant != SERVE_DEFAULT_VARIANT {
            doc.push(("device_variant",
                      Json::Str(self.device_variant.clone())));
        }
        if self.refresh_every != 0 {
            doc.push(("refresh_every",
                      Json::Num(self.refresh_every as f64)));
        }
        self.device_tweaks.echo_into(&mut doc);
        if self.fault.enabled() {
            doc.push(("fault_rate_u6",
                      u6(self.fault.stuck_rate() as f64)));
            doc.push(("fault_prog_fail_u6",
                      u6(self.fault.prog_fail as f64)));
            doc.push(("fault_endurance_limit",
                      Json::Num(self.fault.endurance_limit as f64)));
        }
        doc
    }
}

/// Train → freeze → serve each fig5 probe time under synthetic load,
/// uncalibrated and recalibrated (see the module docs).
pub fn run_fig5_serve(opts: &ServeExpOptions) -> Result<Json> {
    // Default variant "linear_read_drift" is the grid fig5 device
    // model: linear, read noise on, drift on, ν spread off (stream
    // determinism — variant_params zeroes drift_nu_sigma throughout).
    // Tweaks and the fault spec layer on top (defaults = untouched).
    let mut params = variant_params(&opts.device_variant)?;
    opts.device_tweaks.apply(&mut params);
    params.fault = opts.fault;
    let policy =
        TilingPolicy { tile_rows: opts.tile, tile_cols: opts.tile };
    let spec = GraphSpec::mlp(&opts.dims());
    let pool = opts.pool();
    let mut t = NetTrainer::from_spec(
        params, &spec, policy, opts.feature_source(), pool,
        NetTrainerOptions {
            seed: opts.seed,
            lr: LrSchedule::constant(opts.lr),
            refresh_every: opts.refresh_every,
            batch: opts.batch,
            ..Default::default()
        });
    t.train_steps(opts.steps);
    let train_loss = *t.losses.last().unwrap_or(&0.0);
    log_info!("fig5-serve: trained {} steps, final loss {train_loss:.4}",
              opts.steps);

    let mut snap = ModelSnapshot::freeze(t, opts.calib_n);
    let cpolicy = CoalescePolicy {
        window: opts.window,
        max_batch: opts.max_batch,
        queue_cap: opts.queue_cap,
    };
    let test_len = snap.data.test_len();

    let mut probes = Vec::new();
    let mut preds = Vec::new();
    for (i, &probe_t) in opts.probes.iter().enumerate() {
        // Disjoint id range per probe: every request in the run owns a
        // globally unique read-noise stream.
        let trace = gen_trace(opts.seed, (i * opts.requests) as u64,
                              opts.requests, opts.mean_gap, test_len);
        let tf = probe_t as f32;
        let nocal = serve_trace(&mut snap, &trace, &cpolicy, tf, false,
                                &pool, &mut preds);
        // Low-priority drift compensation on the pipeline's background
        // lane; the scope joins before the calibrated pass reads the
        // gains.
        pool.pipeline(|scope| {
            let snap = &mut snap;
            scope.spawn(move || snap.recalibrate(tf, &pool));
        });
        let cal = serve_trace(&mut snap, &trace, &cpolicy, tf, true,
                              &pool, &mut preds);
        let acc_nocal = nocal.hits as f64 / nocal.requests as f64;
        let acc_cal = cal.hits as f64 / cal.requests as f64;
        log_info!(
            "fig5-serve t={probe_t:.0e}s: acc nocal {acc_nocal:.3}, \
             cal {acc_cal:.3} ({} batches, max coalesce {}, p99 wait \
             {:.4}s)",
            nocal.batches, nocal.max_coalesced, nocal.p99_latency);
        probes.push(Json::obj(vec![
            ("t_seconds", Json::Num(probe_t)),
            ("acc_nocal_u6", u6(acc_nocal)),
            ("acc_cal_u6", u6(acc_cal)),
            ("batches", Json::Num(nocal.batches as f64)),
            ("max_coalesced", Json::Num(nocal.max_coalesced as f64)),
            ("p50_latency_u6", u6(nocal.p50_latency)),
            ("p99_latency_u6", u6(nocal.p99_latency)),
            ("gains_u6", Json::Arr(
                snap.gains().iter().map(|&g| u6(g as f64)).collect())),
        ]));
    }

    let mut doc = opts.echo();
    doc.push(("final_train_loss_u6", u6(train_loss)));
    doc.push(("recalibrations",
              Json::Num(snap.recalibrations as f64)));
    // With faults on, the frozen snapshot carries the training-time
    // degradation — report it (absent otherwise: golden neutrality).
    if opts.fault.enabled() {
        let map = snap.fault_summary();
        doc.push(("fault_dead", Json::Num(map.dead() as f64)));
        doc.push(("fault_prog_failures",
                  Json::Num(map.prog_failures as f64)));
        doc.push(("fault_verify_retries",
                  Json::Num(map.verify_retries as f64)));
        doc.push(("fault_verify_failures",
                  Json::Num(map.verify_failures as f64)));
    }
    doc.push(("probes", Json::Arr(probes)));
    Ok(Json::obj(doc))
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_serve() -> ServeExpOptions {
        ServeExpOptions {
            data: ServeData::Blobs { dim: 6 },
            hidden: vec![4, 3],
            classes: 3,
            steps: 4,
            batch: 3,
            tile: 3,
            train_len: 30,
            test_len: 12,
            lr: 0.05,
            requests: 24,
            mean_gap: 0.05,
            window: 0.2,
            max_batch: 6,
            queue_cap: 8,
            calib_n: 6,
            workers: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fig5_serve_document_shape() {
        let doc = run_fig5_serve(&tiny_serve()).unwrap();
        assert_eq!(doc.get("experiment").unwrap().as_str().unwrap(),
                   "fig5_serve");
        let probes = doc.get("probes").unwrap().as_arr().unwrap();
        assert_eq!(probes.len(), super::super::fig5::probe_times().len());
        // One recalibration per probe.
        assert_eq!(doc.get("recalibrations").unwrap().as_f64().unwrap(),
                   probes.len() as f64);
        for p in probes {
            for key in ["acc_nocal_u6", "acc_cal_u6", "batches",
                        "max_coalesced", "p50_latency_u6",
                        "p99_latency_u6"] {
                let num = p.get(key).unwrap().as_f64().unwrap();
                assert!(num.is_finite() && num.fract() == 0.0,
                        "{key} must be an integral metric");
            }
            let gains =
                p.get("gains_u6").unwrap().as_arr().unwrap();
            assert_eq!(gains.len(), 3); // one per weighted layer
        }
    }

    #[test]
    fn faulted_serve_carries_degradation_into_the_snapshot() {
        // Faults injected at training time surface in the frozen
        // snapshot's accounting; the default config emits none of the
        // fault keys (golden neutrality).
        let mut o = tiny_serve();
        o.fault = crate::pcm::FaultSpec {
            stuck_open: 0.15,
            prog_fail: 0.05,
            write_verify: true,
            max_retries: 2,
            ..Default::default()
        };
        let doc = run_fig5_serve(&o).unwrap();
        assert!(doc.get("fault_dead").unwrap().as_f64().unwrap() > 0.0,
                "15% stuck-open left no dead devices");
        assert!(doc.get("fault_rate_u6").is_some());
        let base = run_fig5_serve(&tiny_serve()).unwrap();
        assert!(base.get("fault_dead").is_none());
        assert!(base.get("fault_rate_u6").is_none());
    }

    #[test]
    fn fig5_serve_document_is_worker_invariant() {
        let mut a = tiny_serve();
        a.workers = 1;
        let mut b = tiny_serve();
        b.workers = 4;
        let da = run_fig5_serve(&a).unwrap().to_string();
        let db = run_fig5_serve(&b).unwrap().to_string();
        assert_eq!(da, db);
    }
}
