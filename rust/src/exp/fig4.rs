//! FIG4 — accuracy vs. inference model size across width multipliers
//! (paper Fig. 4).
//!
//! HIC stores ~4 bits/weight at inference (MSB array only); the FP32
//! baseline stores 32.  Sweeping the network width multiplier for both
//! gives two accuracy-vs-size curves; the paper's shape:
//!
//! * HIC sits **above** the baseline at comparable model size (≥ 1 %),
//! * HIC reaches baseline-comparable accuracy at **~50 % less** size.

use anyhow::Result;

use crate::coordinator::BaselineTrainer;
use crate::runtime::Engine;
use crate::util::csv::{CsvCell, CsvWriter};
use crate::log_info;

use super::widths::{bits_to_kb, permille_label, permille_tag,
                    BASE_WIDTHS_PERMILLE, WIDTHS_PERMILLE};
use super::{config_dir, ensure_out_dir, mean_std, print_row, run_hic,
            ExpOptions};

#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub series: &'static str,
    pub width: String,
    pub model_kb: f64,
    pub eval_acc: f64,
    pub eval_std: f64,
}

pub fn run(opts: &ExpOptions) -> Result<Vec<Fig4Row>> {
    ensure_out_dir(&opts.out_dir)?;
    let mut rows = Vec::new();

    for wp in WIDTHS_PERMILLE {
        let w = permille_tag(wp);
        let cfg = format!("fig4_hic_w{w}");
        let mut accs = Vec::new();
        let mut kb = 0.0;
        for &seed in &opts.seeds {
            let (t, acc) = run_hic(&cfg, opts, seed)?;
            kb = bits_to_kb(t.engine.manifest.inference_model_bits(true));
            accs.push(acc);
        }
        let (m, s) = mean_std(&accs);
        log_info!("fig4 hic w={w}: {:.1} KB, acc {:.3} ± {:.3}", kb, m, s);
        rows.push(Fig4Row { series: "hic", width: permille_label(wp),
                            model_kb: kb, eval_acc: m, eval_std: s });
    }

    for wp in BASE_WIDTHS_PERMILLE {
        let w = permille_tag(wp);
        let cfg = format!("fig4_base_w{w}");
        let dir = config_dir(&cfg)?;
        let mut accs = Vec::new();
        let mut kb = 0.0;
        for &seed in &opts.seeds {
            let mut bt =
                BaselineTrainer::new(&dir, opts.trainer_options(seed))?;
            bt.lr = crate::coordinator::schedule::LrSchedule::paper(
                0.1, 0.1, opts.steps);
            bt.train_steps(opts.steps)?;
            accs.push(bt.evaluate(opts.eval_batches)?.accuracy);
            kb = bits_to_kb(
                bt.engine.manifest.inference_model_bits(false));
        }
        let (m, s) = mean_std(&accs);
        log_info!("fig4 base w={w}: {:.1} KB, acc {:.3} ± {:.3}", kb, m, s);
        rows.push(Fig4Row { series: "fp32", width: permille_label(wp),
                            model_kb: kb, eval_acc: m, eval_std: s });
    }

    write_csv(opts, &rows)?;
    print_table(&rows);
    Ok(rows)
}

/// Model size (KB) of a config without training it — for reports.
pub fn model_size_kb(config: &str, hic: bool) -> Result<f64> {
    let engine = Engine::load(&config_dir(config)?)?;
    Ok(bits_to_kb(engine.manifest.inference_model_bits(hic)))
}

fn write_csv(opts: &ExpOptions, rows: &[Fig4Row]) -> Result<()> {
    let mut w = CsvWriter::new(
        &["series", "width_mult", "model_kb", "eval_acc", "eval_std",
          "steps", "seeds"]);
    for r in rows {
        w.row(&[
            CsvCell::s(r.series),
            CsvCell::s(&r.width),
            CsvCell::F(r.model_kb),
            CsvCell::F(r.eval_acc),
            CsvCell::F(r.eval_std),
            CsvCell::U(opts.steps as u64),
            CsvCell::U(opts.seeds.len() as u64),
        ]);
    }
    w.write(&opts.out_dir.join("fig4_width_sweep.csv"))
}

fn print_table(rows: &[Fig4Row]) {
    println!("\nFIG4 — accuracy vs inference model size (paper Fig. 4)");
    print_row(&["series".into(), "width".into(), "size KB".into(),
                "eval acc".into()]);
    for r in rows {
        print_row(&[
            r.series.to_string(),
            r.width.clone(),
            format!("{:.1}", r.model_kb),
            format!("{:.3} ± {:.3}", r.eval_acc, r.eval_std),
        ]);
    }
    shape_checks(rows);
}

/// The two headline comparisons of the figure.
pub fn shape_checks(rows: &[Fig4Row]) {
    let hic: Vec<_> = rows.iter().filter(|r| r.series == "hic").collect();
    let base: Vec<_> = rows.iter().filter(|r| r.series == "fp32").collect();
    if hic.is_empty() || base.is_empty() {
        return;
    }
    // (a) At comparable model size, HIC above baseline: compare every HIC
    // point against the baseline point with the closest size.
    let mut wins = 0;
    let mut total = 0;
    for h in &hic {
        if let Some(b) = base.iter().min_by(|a, b| {
            (a.model_kb - h.model_kb)
                .abs()
                .partial_cmp(&(b.model_kb - h.model_kb).abs())
                .unwrap()
        }) {
            total += 1;
            if h.eval_acc > b.eval_acc {
                wins += 1;
            }
            println!(
                "shape: HIC {:.0}KB acc {:.3} vs FP32 {:.0}KB acc {:.3} \
                 -> {}",
                h.model_kb, h.eval_acc, b.model_kb, b.eval_acc,
                if h.eval_acc > b.eval_acc { "HIC wins" } else { "FP32 wins" }
            );
        }
    }
    println!("shape: HIC wins at matched size in {wins}/{total} pairings \
              (paper: all)");
    // (b) size ratio at matched accuracy: find smallest HIC model whose
    // accuracy >= the largest baseline's, report the size ratio.
    if let Some(best_base) = base
        .iter()
        .max_by(|a, b| a.eval_acc.partial_cmp(&b.eval_acc).unwrap())
    {
        if let Some(h) = hic
            .iter()
            .filter(|h| h.eval_acc >= best_base.eval_acc)
            .min_by(|a, b| a.model_kb.partial_cmp(&b.model_kb).unwrap())
        {
            println!(
                "shape: matched-accuracy size ratio HIC/FP32 = {:.2} \
                 (paper: ~0.5)",
                h.model_kb / best_base.model_kb
            );
        }
    }
}
