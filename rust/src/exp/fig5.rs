//! FIG5 — post-training inference accuracy under PCM drift, with and
//! without AdaBS compensation (paper Fig. 5).
//!
//! Train once, checkpoint the device state, then probe inference accuracy
//! at exponentially spaced times from 10^2 to 4·10^7 s:
//!
//! * **no compensation** — evaluate the drifted weights as-is;
//! * **AdaBS** — first restore the checkpointed BN statistics, run the
//!   calibration pass (~5 % of the train set) *at the probe time*, then
//!   evaluate.
//!
//! Paper shape: flat to ~10^6 s; large degradation at a year without
//! compensation (−9.37 %), almost none with AdaBS (−0.12 %).

use anyhow::Result;

use crate::util::csv::{CsvCell, CsvWriter};
use crate::log_info;

use super::{ensure_out_dir, print_row, run_hic, ExpOptions};

/// Probe times (s): 1e2 … 4e7 (~1.3 years), paper Fig. 5 x-axis.
pub fn probe_times() -> Vec<f64> {
    vec![1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 4e7]
}

#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub t_seconds: f64,
    pub acc_nocomp: f64,
    pub acc_adabs: f64,
}

pub fn run(opts: &ExpOptions, config: &str) -> Result<Vec<Fig5Row>> {
    ensure_out_dir(&opts.out_dir)?;
    let seed = *opts.seeds.first().unwrap_or(&42);
    log_info!("fig5: training '{config}' for {} steps", opts.steps);
    let (mut trainer, trained_acc) = run_hic(config, opts, seed)?;
    log_info!("fig5: trained, eval acc {:.3}", trained_acc);

    // Reference point: the state right after training.
    let snapshot = trainer.state.clone();
    let adabs_batches = trainer.adabs_batches();

    let mut rows = Vec::new();
    for &t in &probe_times().iter().copied().collect::<Vec<_>>() {
        let t_f = t as f32;
        // (a) no compensation
        trainer.state = snapshot.clone();
        let no_comp = trainer.evaluate(opts.eval_batches, Some(t_f))?;
        // (b) AdaBS at the probe time
        trainer.state = snapshot.clone();
        trainer.adabs_calibrate(adabs_batches, t_f)?;
        let with = trainer.evaluate(opts.eval_batches, Some(t_f))?;
        log_info!(
            "fig5 t={t:.0e}s: nocomp {:.3}, adabs {:.3}",
            no_comp.accuracy, with.accuracy
        );
        rows.push(Fig5Row {
            t_seconds: t,
            acc_nocomp: no_comp.accuracy,
            acc_adabs: with.accuracy,
        });
    }

    write_csv(opts, &rows, trained_acc)?;
    print_table(&rows, trained_acc);
    Ok(rows)
}

fn write_csv(opts: &ExpOptions, rows: &[Fig5Row],
             trained_acc: f64) -> Result<()> {
    let mut w = CsvWriter::new(
        &["t_seconds", "acc_nocomp", "acc_adabs", "trained_acc", "steps"]);
    for r in rows {
        w.row(&[
            CsvCell::F(r.t_seconds),
            CsvCell::F(r.acc_nocomp),
            CsvCell::F(r.acc_adabs),
            CsvCell::F(trained_acc),
            CsvCell::U(opts.steps as u64),
        ]);
    }
    w.write(&opts.out_dir.join("fig5_drift.csv"))
}

fn print_table(rows: &[Fig5Row], trained_acc: f64) {
    println!("\nFIG5 — drifted inference accuracy (paper Fig. 5)");
    print_row(&["t (s)".into(), "no comp".into(), "AdaBS".into()]);
    for r in rows {
        print_row(&[
            format!("{:.0e}", r.t_seconds),
            format!("{:.3}", r.acc_nocomp),
            format!("{:.3}", r.acc_adabs),
        ]);
    }
    if let (Some(first), Some(last)) = (rows.first(), rows.last()) {
        println!(
            "shape: year-long drop no-comp {:+.3} (paper −0.094), \
             AdaBS {:+.3} (paper −0.001); trained acc {:.3}",
            last.acc_nocomp - first.acc_nocomp,
            last.acc_adabs - first.acc_adabs,
            trained_acc
        );
    }
}
