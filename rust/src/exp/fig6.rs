//! FIG6 — write–erase cycles per device over one full training
//! (paper Fig. 6).
//!
//! Trains once, then reads the endurance counters out of the device state
//! (MSB SET/RESET per device; LSB flip/RESET per weight register) and
//! builds the two histograms.  Paper shape: MSB max < 150 cycles, LSB max
//! < 20 K, both tiny fractions of the 10^8 endurance limit.

use anyhow::Result;

use crate::pcm::endurance::{EnduranceLedger, ENDURANCE_LIMIT};
use crate::util::csv::{CsvCell, CsvWriter};
use crate::log_info;

use super::{ensure_out_dir, run_hic, ExpOptions};

pub struct Fig6Result {
    pub ledger: EnduranceLedger,
    pub steps: usize,
    /// scale factor to a paper-sized run (205 epochs x 500 batches)
    pub full_training_scale: f64,
}

pub fn run(opts: &ExpOptions, config: &str) -> Result<Fig6Result> {
    ensure_out_dir(&opts.out_dir)?;
    let seed = *opts.seeds.first().unwrap_or(&42);
    let (trainer, acc) = run_hic(config, opts, seed)?;
    log_info!("fig6: trained '{config}' ({} steps, eval acc {:.3})",
              opts.steps, acc);
    let ledger = trainer.endurance()?;

    // Project to a paper-scale training (linear in update steps — every
    // batch touches the LSB array once and refresh cadence is per-batch).
    let paper_steps = 205.0 * 500.0;
    let scale = paper_steps / opts.steps as f64;

    write_csv(opts, &ledger, opts.steps, scale)?;
    print_report(&ledger, scale);
    Ok(Fig6Result { ledger, steps: opts.steps,
                    full_training_scale: scale })
}

fn write_csv(opts: &ExpOptions, ledger: &EnduranceLedger, steps: usize,
             scale: f64) -> Result<()> {
    let mut w = CsvWriter::new(
        &["array", "we_cycles_bucket", "devices", "steps",
          "paper_scale_factor"]);
    for (lo, c) in ledger.msb.rows() {
        w.row(&[CsvCell::s("msb"), CsvCell::U(lo), CsvCell::U(c),
                CsvCell::U(steps as u64), CsvCell::F(scale)]);
    }
    for (lo, c) in ledger.lsb.rows() {
        w.row(&[CsvCell::s("lsb"), CsvCell::U(lo), CsvCell::U(c),
                CsvCell::U(steps as u64), CsvCell::F(scale)]);
    }
    w.write(&opts.out_dir.join("fig6_endurance.csv"))
}

fn print_report(ledger: &EnduranceLedger, scale: f64) {
    println!("\nFIG6 — write–erase cycles per device (paper Fig. 6)");
    println!("\nMSB array:\n{}", ledger.msb);
    println!("LSB array:\n{}", ledger.lsb);
    println!("{}", ledger.summary());
    println!(
        "projected to a paper-scale run (x{scale:.0}): MSB max ~{:.0} \
         (paper <150), LSB max ~{:.0} (paper <20k); endurance limit {:.0e}",
        ledger.msb.max as f64 * scale,
        ledger.lsb.max as f64 * scale,
        ENDURANCE_LIMIT
    );
    let ok = (ledger.msb.max as f64 * scale) < 0.01 * ENDURANCE_LIMIT
        && (ledger.lsb.max as f64 * scale) < 0.01 * ENDURANCE_LIMIT;
    println!("shape: both arrays ≪ endurance limit: {}",
             if ok { "HOLDS" } else { "VIOLATED" });
}
