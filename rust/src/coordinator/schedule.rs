//! Schedules: learning rate, refresh cadence, the simulated drift clock.

/// Step-decay learning-rate schedule (paper: HIC trains with lr 0.05 and
/// decay factor 0.45; boundaries default to 50 % / 75 % of the run like
//  the He et al. recipe).
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub lr0: f32,
    pub decay: f32,
    /// absolute step boundaries at which lr multiplies by `decay`
    pub boundaries: Vec<usize>,
}

impl LrSchedule {
    pub fn constant(lr: f32) -> Self {
        LrSchedule { lr0: lr, decay: 1.0, boundaries: vec![] }
    }

    /// Paper-style schedule scaled to a run of `total_steps`.
    pub fn paper(lr0: f32, decay: f32, total_steps: usize) -> Self {
        LrSchedule {
            lr0,
            decay,
            boundaries: vec![total_steps / 2, (3 * total_steps) / 4],
        }
    }

    pub fn at(&self, step: usize) -> f32 {
        let k = self.boundaries.iter().filter(|&&b| step >= b).count();
        self.lr0 * self.decay.powi(k as i32)
    }
}

/// Refresh cadence (paper: every 10 batches).
#[derive(Clone, Copy, Debug)]
pub struct RefreshScheduler {
    pub every: usize,
}

impl RefreshScheduler {
    pub fn new(every: usize) -> Self {
        RefreshScheduler { every }
    }

    /// Refresh fires *after* the step-th batch (1-indexed internally).
    pub fn due(&self, step: usize) -> bool {
        self.every > 0 && (step + 1) % self.every == 0
    }
}

/// Simulated wall-clock driving PCM drift.
///
/// Training advances the clock by `seconds_per_batch` per step; the
/// Fig. 5 study then jumps the clock far into the future to measure
/// drifted inference.  f32 keeps adequate resolution because training
/// accumulates small times (≤ ~1e5 s) and inference probes use large
/// absolute times where per-batch increments no longer matter.
#[derive(Clone, Copy, Debug)]
pub struct DriftClock {
    pub now: f64,
    pub seconds_per_batch: f64,
}

impl DriftClock {
    pub fn new(seconds_per_batch: f64) -> Self {
        DriftClock { now: 0.0, seconds_per_batch }
    }

    pub fn tick(&mut self) -> f32 {
        self.now += self.seconds_per_batch;
        self.now as f32
    }

    pub fn now_f32(&self) -> f32 {
        self.now as f32
    }

    /// Absolute jump (Fig. 5 inference-time probes).
    pub fn jump_to(&mut self, t: f64) {
        debug_assert!(t >= self.now, "drift clock cannot run backwards");
        self.now = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_step_decay() {
        let s = LrSchedule::paper(0.5, 0.45, 100);
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(49), 0.5);
        assert!((s.at(50) - 0.225).abs() < 1e-6);
        assert!((s.at(75) - 0.10125).abs() < 1e-6);
        assert!((s.at(99) - 0.10125).abs() < 1e-6);
        let c = LrSchedule::constant(0.1);
        assert_eq!(c.at(0), c.at(10_000));
    }

    #[test]
    fn refresh_every_10() {
        let r = RefreshScheduler::new(10);
        let due: Vec<usize> = (0..35).filter(|&s| r.due(s)).collect();
        assert_eq!(due, vec![9, 19, 29]);
        let off = RefreshScheduler::new(0);
        assert!((0..100).all(|s| !off.due(s)));
    }

    #[test]
    fn drift_clock_ticks_and_jumps() {
        let mut c = DriftClock::new(0.05);
        assert_eq!(c.now_f32(), 0.0);
        let t1 = c.tick();
        let t2 = c.tick();
        assert!((t1 - 0.05).abs() < 1e-6);
        assert!((t2 - 0.10).abs() < 1e-6);
        c.jump_to(1e6);
        assert_eq!(c.now_f32(), 1e6);
    }
}
