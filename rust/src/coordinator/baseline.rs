//! FP32 software-baseline trainer (the comparison curves of Figs. 3–4).
//!
//! Same data pipeline and schedules as [`super::Trainer`], driving the
//! `baseline_*` artifacts (exact matmuls, SGD + momentum + weight decay,
//! no PCM anywhere).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::data::{Dataset, DataLoader};
use crate::runtime::{Engine, HostTensor, ModelState};
use crate::util::rng::Pcg64;
use crate::log_info;

use super::metrics::{EvalResult, MetricsRecorder, StepMetrics};
use super::schedule::LrSchedule;
use super::trainer::TrainerOptions;

pub struct BaselineTrainer {
    pub engine: Arc<Engine>,
    pub state: ModelState,
    pub metrics: MetricsRecorder,
    pub lr: LrSchedule,
    dataset: Arc<Dataset>,
    rng: Pcg64,
    augment: bool,
    prefetch: usize,
    pub step: usize,
}

impl BaselineTrainer {
    pub fn new(artifact_dir: &Path, opts: TrainerOptions) -> Result<Self> {
        let engine = Arc::new(Engine::load(artifact_dir)?);
        Self::with_engine(engine, opts)
    }

    pub fn with_engine(engine: Arc<Engine>, opts: TrainerOptions)
                       -> Result<Self> {
        let mut rng = Pcg64::new(opts.seed, 0xba5e);
        let dataset = Arc::new(Dataset::auto(opts.seed, opts.data_scale));
        let state = engine
            .init_state("baseline_init", rng.jax_key())
            .context("initializing baseline state — was this config \
                      lowered with with_baseline=True?")?;
        log_info!("baseline trainer: config '{}', state {:.1} MB",
                  engine.manifest.config_name,
                  state.total_bytes() as f64 / 1e6);
        Ok(BaselineTrainer {
            metrics: MetricsRecorder::new(),
            lr: opts.lr.clone(),
            dataset,
            state,
            engine,
            rng,
            augment: opts.augment,
            prefetch: opts.prefetch,
            step: 0,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.engine.manifest.batch_size()
    }

    pub fn train_steps(&mut self, n: usize) -> Result<()> {
        let loader = DataLoader::new(
            Arc::clone(&self.dataset),
            self.batch_size(),
            false,
            self.augment,
            self.rng.next_u64(),
        );
        let sig = self.engine.manifest.entry("baseline_train_step")?;
        let i_acc = sig
            .metric_outputs()
            .iter()
            .position(|l| l.name.ends_with("acc"))
            .ok_or_else(|| anyhow!("no acc metric"))?;
        let i_loss = sig
            .metric_outputs()
            .iter()
            .position(|l| l.name.ends_with("loss"))
            .ok_or_else(|| anyhow!("no loss metric"))?;

        let rx = loader.prefetch(n, self.prefetch.max(1));
        for batch in rx {
            let lr = self.lr.at(self.step);
            let t0 = Instant::now();
            let m = self.engine.call_stateful(
                "baseline_train_step",
                &mut self.state,
                &[batch.x, batch.y, HostTensor::scalar_f32(lr)],
            )?;
            self.metrics.record_step(StepMetrics {
                step: self.step,
                loss: m[i_loss].scalar()?,
                acc: m[i_acc].scalar()?,
                grad_norm: 0.0,
                overflow_events: 0.0,
                lr,
                t_now: 0.0,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            });
            self.step += 1;
        }
        Ok(())
    }

    pub fn evaluate(&mut self, batches: usize) -> Result<EvalResult> {
        let b = self.batch_size();
        let mut loader =
            DataLoader::new(Arc::clone(&self.dataset), b, true, false, 0);
        let mut correct = 0i64;
        let mut loss_sum = 0f64;
        let mut samples = 0usize;
        for _ in 0..batches {
            let batch = loader.next_batch();
            let out = self.engine.call_stateful(
                "baseline_eval_step",
                &mut self.state,
                &[batch.x, batch.y],
            )?;
            correct += out[0].scalar_i64()?;
            loss_sum += out[1].scalar()? as f64;
            samples += b;
        }
        let res = EvalResult {
            step: self.step,
            t_now: 0.0,
            accuracy: correct as f64 / samples as f64,
            avg_loss: loss_sum / samples as f64,
            samples,
        };
        self.metrics.record_eval(res);
        Ok(res)
    }
}
