//! Device-level training loop on the sharded crossbar grid.
//!
//! The artifact-backed [`super::Trainer`] needs AOT-lowered HLO programs
//! (and a PJRT toolchain) to run; this trainer instead drives the
//! **device model directly** through `crossbar::CrossbarGrid`, so the
//! fig3/fig5/fig6-style sweeps can run anywhere the crate builds.  The
//! task is analog in-memory linear regression: a fixed target matrix
//! `W*` defines `y = x·W*`; every step draws an input batch, runs the
//! analog forward pass (`vmm_batch` — DAC/ADC, drift, read noise),
//! forms the least-squares gradient on the host, and applies the hybrid
//! update (`apply_update` — LSB accumulation, MSB overflow programming),
//! with the drift clock and refresh cadence of the real loop.
//!
//! Everything is deterministic given `(seed, worker pool)` — and, by
//! the grid's sharding contract, **independent of the worker count**:
//! per-step kernels use the step index as the RNG `round`, evaluation
//! probes use caller-supplied rounds in a disjoint range
//! ([`EVAL_ROUND_BASE`]).

use crate::crossbar::grid::{CrossbarGrid, GridScratch};
use crate::crossbar::{AdcSpec, DacSpec, TilingPolicy};
use crate::hic::weight::HicGeometry;
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;

use super::schedule::{DriftClock, LrSchedule, RefreshScheduler};

/// First RNG round reserved for evaluation probes (training steps use
/// rounds `0..steps`, far below this).
pub const EVAL_ROUND_BASE: u64 = 1 << 32;

/// Options of one grid-trainer run.
#[derive(Clone, Debug)]
pub struct GridTrainerOptions {
    pub seed: u64,
    pub lr: LrSchedule,
    /// batches between MSB refresh operations (0 = never)
    pub refresh_every: usize,
    /// simulated seconds of wall time per batch (drift clock)
    pub seconds_per_batch: f64,
    /// input batch size
    pub batch: usize,
    /// inputs drawn uniform in [-x_range, x_range]
    pub x_range: f32,
}

impl Default for GridTrainerOptions {
    fn default() -> Self {
        GridTrainerOptions {
            seed: 42,
            lr: LrSchedule::constant(0.5),
            refresh_every: 10,
            seconds_per_batch: 0.05,
            batch: 8,
            x_range: 1.0,
        }
    }
}

pub struct GridTrainer {
    pub grid: CrossbarGrid,
    pub pool: WorkerPool,
    /// the regression target `W*`, logical `[k, n]` row-major
    pub target: Vec<f32>,
    pub opts: GridTrainerOptions,
    pub clock: DriftClock,
    refresh: RefreshScheduler,
    data_rng: Pcg64,
    scratch: GridScratch,
    pub step: usize,
    /// per-step training MSE of the analog forward pass
    pub losses: Vec<f64>,
    pub overflows: usize,
    pub refreshed: usize,
    // reusable step buffers
    x: Vec<f32>,
    y_ref: Vec<f32>,
    y_hat: Vec<f32>,
    diff: Vec<f32>,
    grad: Vec<f32>,
}

impl GridTrainer {
    /// Build a trainer over a fresh (RESET) grid; training starts from
    /// zero weights, so no initial programming pass is consumed.
    pub fn new(params: PcmParams, geom: HicGeometry, k: usize, n: usize,
               policy: TilingPolicy, target: Vec<f32>, pool: WorkerPool,
               opts: GridTrainerOptions) -> Self {
        assert_eq!(target.len(), k * n);
        let grid = CrossbarGrid::new(params, geom, k, n, policy,
                                     DacSpec::default(),
                                     AdcSpec::default(), opts.seed);
        let scratch = grid.scratch();
        let m = opts.batch;
        GridTrainer {
            clock: DriftClock::new(opts.seconds_per_batch),
            refresh: RefreshScheduler::new(opts.refresh_every),
            data_rng: Pcg64::new(opts.seed, 0xDA7A),
            scratch,
            step: 0,
            losses: Vec::new(),
            overflows: 0,
            refreshed: 0,
            x: vec![0.0; m * k],
            y_ref: vec![0.0; m * n],
            y_hat: vec![0.0; m * n],
            diff: vec![0.0; m * n],
            grad: vec![0.0; k * n],
            target,
            grid,
            pool,
            opts,
        }
    }

    pub fn k(&self) -> usize {
        self.grid.k()
    }

    pub fn n(&self) -> usize {
        self.grid.n()
    }

    /// Run `steps` training steps (forward VMM → host gradient → hybrid
    /// update, with drift clock and refresh cadence).
    pub fn train_steps(&mut self, steps: usize) {
        let k = self.grid.k();
        let n = self.grid.n();
        let m = self.opts.batch;
        for _ in 0..steps {
            let t_now = self.clock.tick();
            let lr = self.opts.lr.at(self.step);
            let round = self.step as u64;

            // Input batch.
            for v in self.x.iter_mut() {
                *v = self
                    .data_rng
                    .uniform_in(-self.opts.x_range, self.opts.x_range);
            }
            // Reference outputs y* = x · W* (host, fp32).
            for s in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for i in 0..k {
                        acc += self.x[s * k + i] * self.target[i * n + j];
                    }
                    self.y_ref[s * n + j] = acc;
                }
            }
            // Analog forward pass.
            self.grid.vmm_batch_into(&self.x, m, t_now, round,
                                     &self.pool, &mut self.scratch,
                                     &mut self.y_hat);
            // Residual + loss.
            let mut se = 0.0f64;
            for (d, (&yh, &yr)) in self
                .diff
                .iter_mut()
                .zip(self.y_hat.iter().zip(&self.y_ref))
            {
                *d = yh - yr;
                se += (*d as f64) * (*d as f64);
            }
            self.losses.push(se / (m * n) as f64);
            // Least-squares gradient G = xᵀ·diff / m.
            let inv_m = 1.0f32 / m as f32;
            for i in 0..k {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for s in 0..m {
                        acc += self.x[s * k + i] * self.diff[s * n + j];
                    }
                    self.grad[i * n + j] = acc * inv_m;
                }
            }
            self.overflows += self.grid.apply_update(
                &self.grad, lr, t_now, round, &self.pool,
                &mut self.scratch);
            if self.refresh.due(self.step) {
                self.refreshed +=
                    self.grid.refresh(t_now, round, &self.pool);
            }
            self.step += 1;
        }
    }

    /// MSE of the analog forward pass against `y* = x·W*` on a fresh
    /// deterministic evaluation batch at inference time `t_eval`.
    ///
    /// With `gain_comp`, scores `α·ŷ` with the global scale `α`
    /// minimizing ‖α·ŷ − y*‖² on the same batch (the drift-compensation
    /// scaling of the mixed-precision trainers, a device-level stand-in
    /// for AdaBS).  `round` must be unique per probe (use
    /// [`EVAL_ROUND_BASE`]` + i`).  Wrapper over
    /// [`GridTrainer::eval_mse_pair`].
    pub fn eval_mse(&mut self, t_eval: f32, round: u64,
                    gain_comp: bool) -> f64 {
        let (raw, comp) = self.eval_mse_pair(t_eval, round);
        if gain_comp { comp } else { raw }
    }

    /// One forward pass, both scores: `(raw MSE, gain-compensated
    /// MSE)` on the **same** read-noise realization — the paired
    /// comparison the fig5 sweep plots, at one VMM's cost.
    pub fn eval_mse_pair(&mut self, t_eval: f32, round: u64)
                         -> (f64, f64) {
        let k = self.grid.k();
        let n = self.grid.n();
        let m = self.opts.batch;
        let mut rng = Pcg64::new(self.opts.seed, 0xE7A1);
        let mut x = vec![0.0f32; m * k];
        for v in x.iter_mut() {
            *v = rng.uniform_in(-self.opts.x_range, self.opts.x_range);
        }
        let mut y_ref = vec![0.0f32; m * n];
        for s in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += x[s * k + i] * self.target[i * n + j];
                }
                y_ref[s * n + j] = acc;
            }
        }
        let mut y_hat = vec![0.0f32; m * n];
        self.grid.vmm_batch_into(&x, m, t_eval, round, &self.pool,
                                 &mut self.scratch, &mut y_hat);
        let mut se_raw = 0.0f64;
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&yh, &yr) in y_hat.iter().zip(&y_ref) {
            let d = yh as f64 - yr as f64;
            se_raw += d * d;
            num += yh as f64 * yr as f64;
            den += yh as f64 * yh as f64;
        }
        let gain = if den > 0.0 { num / den } else { 1.0 };
        let mut se_comp = 0.0f64;
        for (&yh, &yr) in y_hat.iter().zip(&y_ref) {
            let d = gain * yh as f64 - yr as f64;
            se_comp += d * d;
        }
        let mn = (m * n) as f64;
        (se_raw / mn, se_comp / mn)
    }

    /// Mean |decoded − target| over the logical matrix at time `t`
    /// (drift-evaluated, no read noise).
    pub fn weight_error(&mut self, t: f32) -> f64 {
        let mut w = vec![0.0f32; self.grid.k() * self.grid.n()];
        self.grid.drift_into(t, &self.pool, &mut self.scratch, &mut w);
        let mut s = 0.0f64;
        for (&a, &b) in w.iter().zip(&self.target) {
            s += (a as f64 - b as f64).abs();
        }
        s / w.len() as f64
    }

    /// Endurance snapshot over every grid tile.
    pub fn endurance(&self) -> EnduranceLedger {
        let mut ledger = EnduranceLedger::new();
        self.grid.record_endurance(&mut ledger);
        ledger
    }

    /// Fault/degradation accounting over every grid tile (all-zero
    /// when the fault model is disabled).
    pub fn fault_summary(&self) -> crate::pcm::FaultMap {
        self.grid.fault_summary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn target(k: usize, n: usize) -> Vec<f32> {
        (0..k * n)
            .map(|i| (((i * 3 + 5) % 13) as f32 - 6.0) / 8.0)
            .collect()
    }

    fn opts() -> GridTrainerOptions {
        GridTrainerOptions { refresh_every: 0, ..Default::default() }
    }

    #[test]
    fn loss_decreases_on_ideal_devices() {
        let geom =
            HicGeometry { stochastic_rounding: false, ..Default::default() };
        let mut t = GridTrainer::new(
            PcmParams::ideal(), geom, 8, 6,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            target(8, 6), WorkerPool::serial(), opts());
        t.train_steps(60);
        let early = t.losses[0];
        let late = *t.losses.last().unwrap();
        assert!(late < early * 0.2, "loss {early} -> {late}");
        // The decoded matrix approaches W* to within ~1 MSB quantum.
        assert!(t.weight_error(t.clock.now_f32()) < 0.14,
                "weight err {}", t.weight_error(t.clock.now_f32()));
        assert!(t.overflows > 0);
    }

    #[test]
    fn gain_compensation_recovers_drift_loss() {
        // Drift shrinks all conductances by a common-ish factor; the
        // global-gain calibration must recover most of the MSE at long
        // probe times (the fig5 shape at device level).
        let geom =
            HicGeometry { stochastic_rounding: false, ..Default::default() };
        let params = PcmParams {
            nonlinear: false,
            write_noise: false,
            read_noise: false,
            drift: true,
            drift_nu_sigma: 0.0,
            ..Default::default()
        };
        let mut t = GridTrainer::new(
            params, geom, 8, 6,
            TilingPolicy { tile_rows: 4, tile_cols: 3 },
            target(8, 6), WorkerPool::serial(), opts());
        t.train_steps(60);
        let nocomp = t.eval_mse(1e7, EVAL_ROUND_BASE, false);
        let comp = t.eval_mse(1e7, EVAL_ROUND_BASE + 1, true);
        assert!(comp < nocomp, "gain comp must help: {comp} vs {nocomp}");
    }

    #[test]
    fn run_is_worker_count_invariant() {
        let run = |workers: usize| {
            let mut t = GridTrainer::new(
                PcmParams::default(), HicGeometry::default(), 6, 5,
                TilingPolicy { tile_rows: 3, tile_cols: 2 },
                target(6, 5), WorkerPool::new(workers),
                GridTrainerOptions::default());
            t.train_steps(12);
            (t.losses.clone(), t.overflows,
             t.eval_mse(100.0, EVAL_ROUND_BASE, true))
        };
        let a = run(1);
        assert_eq!(a, run(2));
        assert_eq!(a, run(4));
    }
}
