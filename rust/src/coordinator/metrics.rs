//! Run metrics: per-step records, moving statistics, CSV export.

use std::path::Path;

use anyhow::Result;

use crate::util::csv::{CsvCell, CsvWriter};

/// Metrics of one training step (order matches the sorted metric outputs
/// of `hic_train_step`: acc, grad_norm, loss, overflow_events).
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub grad_norm: f32,
    pub overflow_events: f32,
    pub lr: f32,
    pub t_now: f32,
    pub wall_ms: f64,
}

/// Result of an evaluation pass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub step: usize,
    pub t_now: f32,
    pub accuracy: f64,
    pub avg_loss: f64,
    pub samples: usize,
}

/// Accumulates step/eval records for a run.
#[derive(Default)]
pub struct MetricsRecorder {
    pub steps: Vec<StepMetrics>,
    pub evals: Vec<EvalResult>,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_step(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    pub fn record_eval(&mut self, e: EvalResult) {
        self.evals.push(e);
    }

    /// Mean loss over the trailing `window` steps.
    pub fn smoothed_loss(&self, window: usize) -> f64 {
        if self.steps.is_empty() {
            return f64::NAN;
        }
        let n = self.steps.len().min(window.max(1));
        self.steps[self.steps.len() - n..]
            .iter()
            .map(|m| m.loss as f64)
            .sum::<f64>()
            / n as f64
    }

    pub fn smoothed_acc(&self, window: usize) -> f64 {
        if self.steps.is_empty() {
            return f64::NAN;
        }
        let n = self.steps.len().min(window.max(1));
        self.steps[self.steps.len() - n..]
            .iter()
            .map(|m| m.acc as f64)
            .sum::<f64>()
            / n as f64
    }

    pub fn best_eval_accuracy(&self) -> Option<f64> {
        self.evals
            .iter()
            .map(|e| e.accuracy)
            .fold(None, |best, a| Some(best.map_or(a, |b: f64| b.max(a))))
    }

    pub fn total_overflow_events(&self) -> f64 {
        self.steps.iter().map(|m| m.overflow_events as f64).sum()
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|m| m.wall_ms).sum::<f64>()
            / self.steps.len() as f64
    }

    /// Write the loss curve (`step,loss,acc,lr,overflow,ms`).
    pub fn write_steps_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::new(
            &["step", "t_now_s", "loss", "acc", "grad_norm",
              "overflow_events", "lr", "wall_ms"]);
        for m in &self.steps {
            w.row(&[
                CsvCell::U(m.step as u64),
                CsvCell::F(m.t_now as f64),
                CsvCell::F(m.loss as f64),
                CsvCell::F(m.acc as f64),
                CsvCell::F(m.grad_norm as f64),
                CsvCell::F(m.overflow_events as f64),
                CsvCell::F(m.lr as f64),
                CsvCell::F(m.wall_ms),
            ]);
        }
        w.write(path)
    }

    pub fn write_evals_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::new(
            &["step", "t_now_s", "accuracy", "avg_loss", "samples"]);
        for e in &self.evals {
            w.row(&[
                CsvCell::U(e.step as u64),
                CsvCell::F(e.t_now as f64),
                CsvCell::F(e.accuracy),
                CsvCell::F(e.avg_loss),
                CsvCell::U(e.samples as u64),
            ]);
        }
        w.write(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(step: usize, loss: f32, acc: f32) -> StepMetrics {
        StepMetrics { step, loss, acc, grad_norm: 1.0,
                      overflow_events: 2.0, lr: 0.5, t_now: 0.0,
                      wall_ms: 10.0 }
    }

    #[test]
    fn smoothing_and_totals() {
        let mut r = MetricsRecorder::new();
        assert!(r.smoothed_loss(5).is_nan());
        for i in 0..10 {
            r.record_step(m(i, (10 - i) as f32, i as f32 / 10.0));
        }
        assert!((r.smoothed_loss(2) - 1.5).abs() < 1e-9);
        assert!((r.smoothed_loss(100) - 5.5).abs() < 1e-9);
        assert!((r.smoothed_acc(10) - 0.45).abs() < 1e-6);
        assert_eq!(r.total_overflow_events(), 20.0);
        assert_eq!(r.mean_step_ms(), 10.0);
    }

    #[test]
    fn eval_best() {
        let mut r = MetricsRecorder::new();
        assert_eq!(r.best_eval_accuracy(), None);
        r.record_eval(EvalResult { step: 1, t_now: 0.0, accuracy: 0.4,
                                   avg_loss: 2.0, samples: 100 });
        r.record_eval(EvalResult { step: 2, t_now: 0.0, accuracy: 0.7,
                                   avg_loss: 1.0, samples: 100 });
        r.record_eval(EvalResult { step: 3, t_now: 0.0, accuracy: 0.6,
                                   avg_loss: 1.2, samples: 100 });
        assert_eq!(r.best_eval_accuracy(), Some(0.7));
    }

    #[test]
    fn csv_roundtrip_shapes() {
        let mut r = MetricsRecorder::new();
        r.record_step(m(0, 2.0, 0.1));
        r.record_eval(EvalResult { step: 0, t_now: 5.0, accuracy: 0.5,
                                   avg_loss: 1.5, samples: 64 });
        let dir = std::env::temp_dir().join("hic_metrics_test");
        r.write_steps_csv(&dir.join("steps.csv")).unwrap();
        r.write_evals_csv(&dir.join("evals.csv")).unwrap();
        let s = std::fs::read_to_string(dir.join("steps.csv")).unwrap();
        assert!(s.starts_with("step,"));
        assert_eq!(s.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
