//! The Layer-3 coordinator — the training orchestrator.
//!
//! Owns everything the paper's digital control plane does:
//!
//! * the batch loop driving `hic_train_step` artifacts through PJRT,
//! * the **refresh scheduler** (MSB saturation refresh every N batches,
//!   paper §III-A: N = 10),
//! * the **drift clock** — simulated wall time advanced per batch, fed to
//!   every program so PCM drift accrues across training and inference,
//! * the **AdaBS calibrator** (Fig. 5): streaming BN-statistics
//!   recalibration over ~5 % of the training set,
//! * LR scheduling, evaluation cadence, metrics and checkpoints,
//! * the endurance snapshot (device ledgers out of the state buffers).
//!
//! [`baseline`] mirrors the loop for the FP32 software baseline;
//! [`gridtrainer`] runs the same cycle directly on the sharded
//! `crossbar::CrossbarGrid` device model (no artifacts/PJRT needed) —
//! the engine behind the grid-routed fig3/fig5/fig6 sweeps; and
//! [`nettrainer`] extends the device-level path to **multi-layer**
//! layer graphs (per-layer grids, transposed-VMM backprop with im2col
//! patch lowering through conv/residual layers, shared drift clock and
//! refresh cadence) — the engine behind the grid-routed fig4 width
//! sweeps (dense `--arch mlp` and ResNet-style `--arch resnet`).  On
//! multi-worker pools the net trainer defaults to the **pipelined**
//! schedule ([`TrainMode::Pipelined`]): per-layer gradient/update
//! chains overlap the backward VMM walk on an adaptively split pool,
//! bitwise identical to the phase-serial reference.

pub mod baseline;
pub mod gridtrainer;
pub mod metrics;
pub mod nettrainer;
pub mod schedule;
pub mod trainer;

pub use baseline::BaselineTrainer;
pub use gridtrainer::{GridTrainer, GridTrainerOptions};
pub use metrics::{EvalResult, MetricsRecorder, StepMetrics};
pub use nettrainer::{KSplit, NetTrainer, NetTrainerOptions, TrainMode};
pub use schedule::{DriftClock, LrSchedule, RefreshScheduler};
pub use trainer::{Trainer, TrainerOptions};
