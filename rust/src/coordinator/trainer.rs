//! The HIC training orchestrator.
//!
//! Drives the lowered artifacts through a full run: batches from the data
//! pipeline (with background prefetch), the train-step call, the
//! every-N-batches MSB refresh, the drift clock, periodic evaluation, the
//! AdaBS recalibration pass, checkpoints and the endurance snapshot.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::data::{Dataset, DataLoader};
use crate::pcm::endurance::EnduranceLedger;
use crate::runtime::{Engine, HostTensor, ModelState};
use crate::util::rng::Pcg64;
use crate::{log_debug, log_info};

use super::metrics::{EvalResult, MetricsRecorder, StepMetrics};
use super::schedule::{DriftClock, LrSchedule, RefreshScheduler};

/// Options of one training run.
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub seed: u64,
    pub lr: LrSchedule,
    /// batches between MSB refresh operations (paper: 10)
    pub refresh_every: usize,
    /// simulated seconds of wall time per batch (drift clock)
    pub seconds_per_batch: f64,
    pub augment: bool,
    /// synthetic-dataset size scale (1.0 == 50k/10k)
    pub data_scale: f64,
    /// prefetch queue depth (0 = synchronous)
    pub prefetch: usize,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            seed: 42,
            // Scaled-run default: the paper's 0.05 with 205 epochs maps to
            // ~0.5 for the few-hundred-step runs this testbed executes
            // (update-quantum per unit data kept comparable); both are
            // runtime inputs, so full-fidelity runs just pass --lr 0.05.
            lr: LrSchedule::constant(0.5),
            refresh_every: 10,
            seconds_per_batch: 0.05,
            augment: true,
            data_scale: 0.05,
            prefetch: 4,
        }
    }
}

pub struct Trainer {
    pub engine: Arc<Engine>,
    pub state: ModelState,
    pub opts: TrainerOptions,
    pub metrics: MetricsRecorder,
    pub clock: DriftClock,
    dataset: Arc<Dataset>,
    refresh: RefreshScheduler,
    rng: Pcg64,
    pub step: usize,
}

impl Trainer {
    pub fn new(artifact_dir: &Path, opts: TrainerOptions) -> Result<Self> {
        let engine = Arc::new(Engine::load(artifact_dir)?);
        Self::with_engine(engine, opts)
    }

    pub fn with_engine(engine: Arc<Engine>, opts: TrainerOptions)
                       -> Result<Self> {
        let mut rng = Pcg64::new(opts.seed, 0x7ea1);
        let dataset = Arc::new(Dataset::auto(opts.seed, opts.data_scale));
        let state = engine
            .init_state("hic_init", rng.jax_key())
            .context("initializing HIC state")?;
        log_info!(
            "trainer: config '{}', {} weights, state {:.1} MB, batch {}",
            engine.manifest.config_name,
            engine.manifest.num_weights,
            state.total_bytes() as f64 / 1e6,
            engine.manifest.batch_size()
        );
        Ok(Trainer {
            clock: DriftClock::new(opts.seconds_per_batch),
            refresh: RefreshScheduler::new(opts.refresh_every),
            metrics: MetricsRecorder::new(),
            dataset,
            state,
            engine,
            rng,
            opts,
            step: 0,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.engine.manifest.batch_size()
    }

    fn metric_index(&self, entry: &str, name: &str) -> Result<usize> {
        let sig = self.engine.manifest.entry(entry)?;
        sig.metric_outputs()
            .iter()
            .position(|l| l.name.ends_with(name))
            .ok_or_else(|| anyhow!("{entry}: no metric output '{name}'"))
    }

    /// Run `n` training steps (with refresh scheduling + drift clock).
    pub fn train_steps(&mut self, n: usize) -> Result<()> {
        let loader = DataLoader::new(
            Arc::clone(&self.dataset),
            self.batch_size(),
            false,
            self.opts.augment,
            self.rng.next_u64(),
        );
        let i_acc = self.metric_index("hic_train_step", "acc")?;
        let i_gn = self.metric_index("hic_train_step", "grad_norm")?;
        let i_loss = self.metric_index("hic_train_step", "loss")?;
        let i_ovf = self.metric_index("hic_train_step", "overflow_events")?;

        let rx = loader.prefetch(n, self.opts.prefetch.max(1));
        for batch in rx {
            let t_now = self.clock.tick();
            let lr = self.opts.lr.at(self.step);
            let t0 = Instant::now();
            let m = self.engine.call_stateful(
                "hic_train_step",
                &mut self.state,
                &[
                    batch.x,
                    batch.y,
                    HostTensor::key(self.rng.jax_key()),
                    HostTensor::scalar_f32(t_now),
                    HostTensor::scalar_f32(lr),
                ],
            )?;
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let sm = StepMetrics {
                step: self.step,
                loss: m[i_loss].scalar()?,
                acc: m[i_acc].scalar()?,
                grad_norm: m[i_gn].scalar()?,
                overflow_events: m[i_ovf].scalar()?,
                lr,
                t_now,
                wall_ms,
            };
            if !sm.loss.is_finite() {
                return Err(anyhow!("non-finite loss at step {}", self.step));
            }
            self.metrics.record_step(sm);

            if self.refresh.due(self.step) {
                let refreshed = self.refresh_now()?;
                log_debug!("step {}: refreshed {} pairs", self.step,
                           refreshed);
            }
            self.step += 1;
        }
        Ok(())
    }

    /// Immediate MSB saturation refresh; returns refreshed-pair count.
    pub fn refresh_now(&mut self) -> Result<f32> {
        let t_now = self.clock.now_f32();
        let m = self.engine.call_stateful(
            "hic_refresh",
            &mut self.state,
            &[HostTensor::key(self.rng.jax_key()),
              HostTensor::scalar_f32(t_now)],
        )?;
        m[0].scalar()
    }

    /// Evaluate on `batches` test batches at time `t_eval` (defaults to
    /// the current drift clock — Fig. 5 passes future times).
    pub fn evaluate(&mut self, batches: usize, t_eval: Option<f32>)
                    -> Result<EvalResult> {
        let t = t_eval.unwrap_or_else(|| self.clock.now_f32());
        let b = self.batch_size();
        let mut loader = DataLoader::new(
            Arc::clone(&self.dataset), b, true, false, 0);
        let mut correct = 0i64;
        let mut loss_sum = 0f64;
        let mut samples = 0usize;
        for _ in 0..batches {
            let batch = loader.next_batch();
            let out = self.engine.call_stateful(
                "hic_eval_step",
                &mut self.state,
                &[batch.x, batch.y, HostTensor::key(self.rng.jax_key()),
                  HostTensor::scalar_f32(t)],
            )?;
            correct += out[0].scalar_i64()?;
            loss_sum += out[1].scalar()? as f64;
            samples += b;
        }
        let res = EvalResult {
            step: self.step,
            t_now: t,
            accuracy: correct as f64 / samples as f64,
            avg_loss: loss_sum / samples as f64,
            samples,
        };
        self.metrics.record_eval(res);
        Ok(res)
    }

    /// AdaBS recalibration (Joshi et al. 2020): recompute global BN
    /// statistics from `batches` training batches at inference time `t`.
    pub fn adabs_calibrate(&mut self, batches: usize, t: f32) -> Result<()> {
        let mut loader = DataLoader::new(
            Arc::clone(&self.dataset), self.batch_size(), false, false,
            self.rng.next_u64());
        for k in 1..=batches {
            let batch = loader.next_batch();
            self.engine.call_stateful(
                "hic_adabs",
                &mut self.state,
                &[batch.x, HostTensor::key(self.rng.jax_key()),
                  HostTensor::scalar_f32(t),
                  HostTensor::scalar_f32(k as f32)],
            )?;
        }
        log_debug!("adabs: recalibrated BN stats over {batches} batches");
        Ok(())
    }

    /// Calibration batch count for the paper's "~5 % of the train set".
    pub fn adabs_batches(&self) -> usize {
        ((self.dataset.len(false) as f64 * 0.05)
            / self.batch_size() as f64)
            .ceil()
            .max(1.0) as usize
    }

    /// Snapshot the endurance ledgers out of the device state.
    pub fn endurance(&self) -> Result<EnduranceLedger> {
        let mut ledger = EnduranceLedger::new();
        for side in ["pcm_p", "pcm_m"] {
            let sets = self.state.find(&format!("{side}/set_count"));
            let resets = self.state.find(&format!("{side}/reset_count"));
            if sets.len() != resets.len() || sets.is_empty() {
                return Err(anyhow!("endurance counters missing for {side}"));
            }
            for ((_, s), (_, r)) in sets.iter().zip(resets.iter()) {
                for (a, b) in s.as_i32()?.iter().zip(r.as_i32()?) {
                    ledger.record_msb(*a as u64, *b as u64);
                }
            }
        }
        let flips = self.state.find("lsb_flips");
        let resets = self.state.find("lsb_resets");
        for ((_, f), (_, r)) in flips.iter().zip(resets.iter()) {
            for (a, b) in f.as_i32()?.iter().zip(r.as_i32()?) {
                ledger.record_lsb_weight(*a as u64, *b as u64, 7);
            }
        }
        Ok(ledger)
    }

    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.state.save(path)?;
        log_info!("checkpoint saved to {} (step {}, t={:.1}s)",
                  path.display(), self.step, self.clock.now_f32());
        Ok(())
    }

    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let loaded = ModelState::load(path)?;
        if loaded.leaves.len() != self.state.leaves.len() {
            return Err(anyhow!(
                "checkpoint arity {} != state arity {}",
                loaded.leaves.len(),
                self.state.leaves.len()
            ));
        }
        self.state = loaded;
        Ok(())
    }
}
