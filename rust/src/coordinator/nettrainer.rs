//! Multi-layer device-level training loop on per-layer crossbar grids.
//!
//! [`NetTrainer`] drives a [`DeviceNet`] end to end: analog forward
//! VMMs layer by layer, softmax cross-entropy on the host, analog
//! **transposed** VMMs (`CrossbarGrid::vmm_t_batch_into`) carrying the
//! error back down the stack, digital weight-gradient outer products,
//! and the per-layer hybrid update (LSB accumulation, MSB overflow
//! programming) — with one shared drift clock, one refresh cadence and
//! the endurance ledgers folded across every layer's tiles.  This is
//! the mixed-precision computational-memory training loop (Nandakumar
//! et al. 1712.01192 / 2001.11773) run entirely on the device model.
//!
//! Backward DAC headroom: backprop errors shrink as training converges,
//! so the error batch is pre-scaled by `bwd_gain` before the transposed
//! VMM and the result scaled back by `1/bwd_gain` — the ranged-scaling
//! trick of the mixed-precision trainers, keeping the error inside the
//! DAC's quantization range without per-batch calibration.
//!
//! Determinism: data sampling is counter-based (sequential epoch
//! order), every grid kernel uses the step index as its RNG `round`
//! (evaluation probes use the disjoint [`EVAL_ROUND_BASE`] range), and
//! per-layer grid seeds keep all layer streams independent — so a full
//! training-plus-eval run is **bitwise identical for any worker
//! count**, pinned by `rust/tests/prop_parallel_equivalence.rs`.

use crate::crossbar::{GridScratch, TilingPolicy};
use crate::nn::features::FeatureSource;
use crate::nn::net::{argmax_row, nll_sum, softmax_rows, DeviceNet};
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::WorkerPool;

use super::gridtrainer::EVAL_ROUND_BASE;
use super::schedule::{DriftClock, LrSchedule, RefreshScheduler};

/// Options of one net-trainer run.
#[derive(Clone, Debug)]
pub struct NetTrainerOptions {
    pub seed: u64,
    pub lr: LrSchedule,
    /// batches between MSB refresh operations (0 = never)
    pub refresh_every: usize,
    /// simulated seconds of wall time per batch (drift clock)
    pub seconds_per_batch: f64,
    /// input batch size
    pub batch: usize,
    /// backward error pre-scale before the transposed VMM's DAC
    pub bwd_gain: f32,
    /// per-layer weight range scale: `w_max = w_scale / √fan_in`
    pub w_scale: f32,
}

impl Default for NetTrainerOptions {
    fn default() -> Self {
        NetTrainerOptions {
            seed: 42,
            lr: LrSchedule::constant(0.05),
            refresh_every: 0,
            seconds_per_batch: 0.05,
            batch: 8,
            bwd_gain: 4.0,
            w_scale: 2.0,
        }
    }
}

pub struct NetTrainer {
    pub net: DeviceNet,
    pub data: FeatureSource,
    pub pool: WorkerPool,
    pub opts: NetTrainerOptions,
    pub clock: DriftClock,
    refresh: RefreshScheduler,
    /// one reusable scratch per layer grid
    scratches: Vec<GridScratch>,
    pub step: usize,
    /// per-step mean training cross-entropy
    pub losses: Vec<f64>,
    pub overflows: usize,
    pub refreshed: usize,
    eval_rounds: u64,
    // reusable step buffers
    x: Vec<f32>,
    labels: Vec<u8>,
    /// per-layer pre-activations `[m, dims[l+1]]`
    zs: Vec<Vec<f32>>,
    /// per-layer hidden ReLU outputs `[m, dims[l+1]]` (layers `0..L-1`)
    acts: Vec<Vec<f32>>,
    probs: Vec<f32>,
    /// per-layer backprop errors `[m, dims[l+1]]`
    deltas: Vec<Vec<f32>>,
    /// gain-scaled error staging buffer
    escaled: Vec<f32>,
    /// per-layer weight gradients `[dims[l] * dims[l+1]]`
    grads: Vec<Vec<f32>>,
}

impl NetTrainer {
    /// Build a trainer: the net is constructed and its init weights
    /// programmed through `pool` (deterministic for any worker count).
    pub fn new(params: PcmParams, dims: &[usize], policy: TilingPolicy,
               data: FeatureSource, pool: WorkerPool,
               opts: NetTrainerOptions) -> Self {
        assert_eq!(dims[0], data.dim(), "input dim != feature dim");
        assert_eq!(*dims.last().unwrap(), data.classes(),
                   "output dim != classes");
        let net = DeviceNet::new(params, dims, policy, opts.w_scale,
                                 opts.seed, &pool);
        let scratches = net.scratches();
        let m = opts.batch;
        let nl = net.layers();
        let classes = net.classes();
        let zs: Vec<Vec<f32>> =
            (0..nl).map(|l| vec![0.0; m * dims[l + 1]]).collect();
        let acts: Vec<Vec<f32>> =
            (0..nl - 1).map(|l| vec![0.0; m * dims[l + 1]]).collect();
        let deltas: Vec<Vec<f32>> =
            (0..nl).map(|l| vec![0.0; m * dims[l + 1]]).collect();
        let grads: Vec<Vec<f32>> =
            (0..nl).map(|l| vec![0.0; dims[l] * dims[l + 1]]).collect();
        let wmax_dim = *dims.iter().max().unwrap();
        NetTrainer {
            clock: DriftClock::new(opts.seconds_per_batch),
            refresh: RefreshScheduler::new(opts.refresh_every),
            scratches,
            step: 0,
            losses: Vec::new(),
            overflows: 0,
            refreshed: 0,
            eval_rounds: 0,
            x: vec![0.0; m * dims[0]],
            labels: vec![0; m],
            zs,
            acts,
            probs: vec![0.0; m * classes],
            deltas,
            escaled: vec![0.0; m * wmax_dim],
            grads,
            net,
            data,
            pool,
            opts,
        }
    }

    /// Run `steps` training steps: forward VMMs → softmax CE → backward
    /// transposed VMMs → per-layer hybrid updates, drift clock and
    /// refresh cadence included.
    pub fn train_steps(&mut self, steps: usize) {
        let nl = self.net.layers();
        let classes = self.net.classes();
        let d0 = self.net.input_dim();
        let m = self.opts.batch;
        for _ in 0..steps {
            let t_now = self.clock.tick();
            let lr = self.opts.lr.at(self.step);
            let round = self.step as u64;

            // Input batch: sequential epoch order (counter-based, so
            // the data stream is schedule-independent by construction).
            for j in 0..m {
                let idx = (self.step * m + j) % self.data.train_len();
                self.labels[j] = self.data.sample_into(
                    idx, false, &mut self.x[j * d0..(j + 1) * d0]);
            }

            // Forward: analog VMM per layer, ReLU between layers.
            for l in 0..nl {
                let input: &[f32] =
                    if l == 0 { &self.x } else { &self.acts[l - 1] };
                self.net.grids[l].vmm_batch_into(
                    input, m, t_now, round, &self.pool,
                    &mut self.scratches[l], &mut self.zs[l]);
                if l + 1 < nl {
                    for (a, &z) in
                        self.acts[l].iter_mut().zip(&self.zs[l])
                    {
                        *a = if z > 0.0 { z } else { 0.0 };
                    }
                }
            }

            // Loss and output error (softmax − one-hot).
            softmax_rows(&self.zs[nl - 1], m, classes, &mut self.probs);
            self.losses.push(
                nll_sum(&self.probs, &self.labels, classes) / m as f64);
            for s in 0..m {
                for j in 0..classes {
                    let y = if self.labels[s] as usize == j {
                        1.0
                    } else {
                        0.0
                    };
                    self.deltas[nl - 1][s * classes + j] =
                        self.probs[s * classes + j] - y;
                }
            }

            // Backward: digital weight-gradient outer product per
            // layer, then the analog transposed VMM carries the error
            // to the layer below (pre-update weights: all updates are
            // applied after the full backward pass).
            let inv_m = 1.0f32 / m as f32;
            for l in (0..nl).rev() {
                let (k, n) = (self.net.dims[l], self.net.dims[l + 1]);
                let a_in: &[f32] =
                    if l == 0 { &self.x } else { &self.acts[l - 1] };
                for i in 0..k {
                    for j in 0..n {
                        let mut acc = 0.0f32;
                        for s in 0..m {
                            acc += a_in[s * k + i]
                                * self.deltas[l][s * n + j];
                        }
                        self.grads[l][i * n + j] = acc * inv_m;
                    }
                }
                if l > 0 {
                    let gain = self.opts.bwd_gain;
                    for (ev, &dv) in self.escaled[..m * n]
                        .iter_mut()
                        .zip(&self.deltas[l][..m * n])
                    {
                        *ev = dv * gain;
                    }
                    self.net.grids[l].vmm_t_batch_into(
                        &self.escaled[..m * n], m, t_now, round,
                        &self.pool, &mut self.scratches[l],
                        &mut self.deltas[l - 1]);
                    let inv_gain = 1.0f32 / gain;
                    for (d, &z) in
                        self.deltas[l - 1].iter_mut().zip(&self.zs[l - 1])
                    {
                        *d = if z > 0.0 { *d * inv_gain } else { 0.0 };
                    }
                }
            }

            // Hybrid updates + refresh cadence across every layer.
            for l in 0..nl {
                self.overflows += self.net.grids[l].apply_update(
                    &self.grads[l], lr, t_now, round, &self.pool,
                    &mut self.scratches[l]);
            }
            if self.refresh.due(self.step) {
                for l in 0..nl {
                    self.refreshed += self.net.grids[l].refresh(
                        t_now, round, &self.pool);
                }
            }
            self.step += 1;
        }
    }

    /// Mean cross-entropy and accuracy of the analog forward pass over
    /// the first `n` test samples at inference time `t_eval`.  Each
    /// chunk uses a fresh evaluation round (disjoint from training
    /// rounds), so repeated probes draw fresh read noise and never
    /// replay training noise.
    pub fn evaluate(&mut self, n: usize, t_eval: f32) -> (f64, f64) {
        let nl = self.net.layers();
        let classes = self.net.classes();
        let d0 = self.net.input_dim();
        let m = self.opts.batch;
        let mut hits = 0usize;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        while done < n {
            let mb = m.min(n - done);
            let round = EVAL_ROUND_BASE + self.eval_rounds;
            self.eval_rounds += 1;
            for j in 0..mb {
                self.labels[j] = self.data.sample_into(
                    done + j, true, &mut self.x[j * d0..(j + 1) * d0]);
            }
            for l in 0..nl {
                let (k, n_out) = (self.net.dims[l], self.net.dims[l + 1]);
                let input: &[f32] = if l == 0 {
                    &self.x[..mb * k]
                } else {
                    &self.acts[l - 1][..mb * k]
                };
                self.net.grids[l].vmm_batch_into(
                    input, mb, t_eval, round, &self.pool,
                    &mut self.scratches[l],
                    &mut self.zs[l][..mb * n_out]);
                if l + 1 < nl {
                    for (a, &z) in self.acts[l][..mb * n_out]
                        .iter_mut()
                        .zip(&self.zs[l][..mb * n_out])
                    {
                        *a = if z > 0.0 { z } else { 0.0 };
                    }
                }
            }
            softmax_rows(&self.zs[nl - 1][..mb * classes], mb, classes,
                         &mut self.probs[..mb * classes]);
            loss_sum += nll_sum(&self.probs[..mb * classes],
                                &self.labels[..mb], classes);
            for s in 0..mb {
                let row = &self.probs[s * classes..(s + 1) * classes];
                if argmax_row(row) == self.labels[s] as usize {
                    hits += 1;
                }
            }
            done += mb;
        }
        (loss_sum / n as f64, hits as f64 / n as f64)
    }

    /// Endurance snapshot folded over every layer's tiles.
    pub fn endurance(&self) -> EnduranceLedger {
        let mut ledger = EnduranceLedger::new();
        for g in &self.net.grids {
            g.record_endurance(&mut ledger);
        }
        ledger
    }

    /// Total SET pulses across all layers.
    pub fn total_set_pulses(&self) -> u64 {
        self.net.grids.iter().map(|g| g.total_set_pulses()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::features::{BlobDataset, PooledCifar};

    fn blob_data() -> FeatureSource {
        FeatureSource::Blobs(BlobDataset::new(3, 8, 4, 0.35, 400, 80))
    }

    fn linear_read_params() -> PcmParams {
        PcmParams {
            nonlinear: false,
            write_noise: false,
            read_noise: true,
            drift: false,
            drift_nu_sigma: 0.0,
            ..Default::default()
        }
    }

    fn policy(t: usize) -> TilingPolicy {
        TilingPolicy { tile_rows: t, tile_cols: t }
    }

    #[test]
    fn device_net_learns_blobs() {
        // Thresholds validated against the bit-exact oracle
        // (`rust/tests/golden/oracle.py` NnTrainer on this exact
        // config): acc 0.175 -> 0.988 (60 steps) -> 1.0 (120).
        let mut t = NetTrainer::new(
            linear_read_params(), &[8, 12, 8, 4], policy(6), blob_data(),
            WorkerPool::serial(),
            NetTrainerOptions { batch: 16,
                                lr: LrSchedule::constant(0.2),
                                ..Default::default() });
        let (_, acc0) = t.evaluate(80, 0.0);
        t.train_steps(60);
        let (_, acc_mid) = t.evaluate(80, t.clock.now_f32());
        t.train_steps(60);
        let (loss, acc) = t.evaluate(80, t.clock.now_f32());
        assert!(acc0 < 0.5, "untrained net is already accurate? {acc0}");
        assert!(acc_mid > acc0 + 0.3, "mid {acc_mid} vs start {acc0}");
        assert!(acc > 0.85, "device eval acc {acc} (from {acc0})");
        assert!(acc >= acc_mid - 0.05, "end {acc} << mid {acc_mid}");
        assert!(loss < 0.5, "eval loss {loss}");
        assert!(t.overflows > 0, "no LSB->MSB overflow ever fired");
        // Training loss trends down too.
        let early: f64 = t.losses[..10].iter().sum::<f64>() / 10.0;
        let late: f64 =
            t.losses[t.losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(late < early * 0.5, "train loss {early} -> {late}");
    }

    #[test]
    fn device_net_learns_pooled_synthetic_cifar() {
        // The acceptance-criterion workload: >= 2 hidden layers on the
        // data pipeline's synthetic CIFAR, monotonically improving eval
        // accuracy (non-strict: probes allow small noise wiggle).
        let data =
            FeatureSource::Cifar(PooledCifar::new(1, 8, 1000, 200));
        let mut t = NetTrainer::new(
            linear_read_params(), &[48, 16, 12, 10], policy(16), data,
            WorkerPool::from_env(),
            NetTrainerOptions { batch: 16,
                                lr: LrSchedule::constant(0.1),
                                ..Default::default() });
        let (_, acc0) = t.evaluate(60, 0.0);
        t.train_steps(40);
        let (_, acc1) = t.evaluate(60, t.clock.now_f32());
        t.train_steps(40);
        let (_, acc2) = t.evaluate(60, t.clock.now_f32());
        assert!(acc1 >= acc0, "acc {acc0} -> {acc1}");
        assert!(acc2 >= acc1 - 0.05, "acc {acc1} -> {acc2}");
        assert!(acc2 > acc0 + 0.2 && acc2 > 0.5,
                "no real learning: {acc0} -> {acc1} -> {acc2}");
    }

    #[test]
    fn refresh_and_endurance_cover_all_layers() {
        let mut t = NetTrainer::new(
            linear_read_params(), &[8, 12, 8, 4], policy(6), blob_data(),
            WorkerPool::serial(),
            NetTrainerOptions { batch: 8, refresh_every: 5,
                                ..Default::default() });
        t.train_steps(20);
        let ledger = t.endurance();
        // 2 devices per weight cell over every layer's matrix.
        let weights = 8 * 12 + 12 * 8 + 8 * 4;
        assert_eq!(ledger.msb.count as usize, 2 * weights);
        assert!(t.total_set_pulses() > 0);
    }

    #[test]
    fn run_is_worker_count_invariant() {
        let run = |workers: usize| {
            let mut t = NetTrainer::new(
                PcmParams::default(), &[8, 12, 8, 4], policy(5),
                blob_data(), WorkerPool::new(workers),
                NetTrainerOptions { batch: 6, refresh_every: 4,
                                    ..Default::default() });
            t.train_steps(8);
            let ev = t.evaluate(24, t.clock.now_f32());
            (t.losses.clone(), t.overflows, t.refreshed, ev)
        };
        let a = run(1);
        assert_eq!(a, run(2));
        assert_eq!(a, run(4));
    }
}
