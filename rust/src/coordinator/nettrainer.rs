//! Multi-layer device-level training loop over the layer-graph IR.
//!
//! [`NetTrainer`] drives a [`GraphNet`] end to end: analog forward VMMs
//! layer by layer (conv layers through the **weight-stationary
//! streaming** patch lowering — patch segments generated on demand
//! from the once-DAC'd image, no materialized im2col matrix; see
//! `nn::graph::ConvLowering`), softmax cross-entropy on the host,
//! analog **transposed** VMMs carrying the error back down the graph
//! (conv layers drain theirs straight through the fused col2im
//! scatter, residual blocks through skip-adds), digital
//! weight-gradient outer products, and the per-layer hybrid update
//! (LSB accumulation, MSB overflow programming) — with one shared
//! drift clock, one refresh cadence and the endurance ledgers folded
//! across every grid's tiles.  This is the mixed-precision
//! computational-memory training loop (Nandakumar et al. 1712.01192 /
//! 2001.11773) run entirely on the device model, now covering the
//! paper's conv/residual topology class.  The streamed and
//! materialized conv lowerings are bit-identical, so everything below
//! — goldens included — holds for either.
//!
//! Backward DAC headroom: backprop errors shrink as training converges,
//! so every error batch is pre-scaled by `bwd_gain` before its
//! transposed VMM and the result scaled back by `1/bwd_gain` — the
//! ranged-scaling trick of the mixed-precision trainers, keeping the
//! error inside the DAC's quantization range without per-batch
//! calibration.
//!
//! Determinism: data sampling is counter-based (sequential epoch
//! order), every grid kernel uses the step index as its RNG `round`
//! (evaluation probes use the disjoint [`EVAL_ROUND_BASE`] range), and
//! per-layer grid seeds keep all layer streams independent — so a full
//! training-plus-eval run is **bitwise identical for any worker count
//! and any grid sample-block size** (the VMMs run on the blocked
//! tile-stationary strip kernels with per-(op, tile, sample) read-noise
//! sub-streams), pinned by `rust/tests/prop_parallel_equivalence.rs`
//! (dense) and `rust/tests/prop_conv_equivalence.rs` (conv/residual).
//! The
//! dense path builds `GraphSpec::mlp(dims)`, whose grid seeds and
//! kernel invocation order replay the PR-3 `DeviceNet` loop exactly —
//! the dense fig4 golden pins this byte for byte.
//!
//! # Pipelined training ([`TrainMode::Pipelined`], the default)
//!
//! The phase-serial loop leaves workers idle during every non-VMM
//! stage: full backward, then all updates, then refresh — three
//! barriers.  The pipelined mode splits the pool into a **foreground**
//! lane (the calling thread + `W − B` workers driving the backward
//! transposed-VMM chain) and a **background** lane (`B` workers on a
//! [`PipelineScope`](crate::util::pool::PipelineScope)): the moment
//! layer `i`'s backward VMM completes,
//! its digital outer-product gradient and hybrid LSB/MSB update (and
//! the refresh, when due) are enqueued as a completion-dependency chain
//! that overlaps layer `i−1`'s VMM — the HyTrainDNN overlap schedule.
//!
//! The lane split `B` and the per-step eager budget follow an adaptive
//! `k`-fraction ([`KSplit`]): the controller watches the share of step
//! time spent in the end-of-step drain (deferred + unfinished eager
//! chains) and nudges `k` up when the background lane is starved (big
//! drain share) or down when it over-claims workers the VMM chain
//! needs.  `k` only moves *scheduling* knobs — worker counts and
//! eager-vs-deferred placement — so it is free to adapt on wall-clock
//! time without touching numerics.
//!
//! **Why overlap is numerics-free:** every stochastic kernel draws from
//! counter-based per-`(op, tile[, sample])` RNG sub-streams keyed only
//! on `(layer seed, round)`; weighted layers own disjoint grids; the
//! overflow/refresh totals are commutative sums.  Scheduling therefore
//! moves *when* work runs, never *what* it computes: the pipelined
//! trainer is **bitwise identical** to the phase-serial one at any
//! worker count and any `k` trajectory, pinned by
//! `rust/tests/prop_pipeline_equivalence.rs` and the byte-identical
//! fig4 goldens.  With one worker (or [`TrainMode::PhaseSerial`]) the
//! loop runs the original phase-serial path.

use std::time::Instant;

use crate::crossbar::TilingPolicy;
use crate::nn::features::FeatureSource;
use crate::nn::graph::{GraphNet, GraphSpec, StepTotals};
use crate::nn::net::{argmax_row, nll_sum, softmax_rows};
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::util::pool::WorkerPool;

use super::gridtrainer::EVAL_ROUND_BASE;
use super::schedule::{DriftClock, LrSchedule, RefreshScheduler};

/// Scheduling mode of the training loop.  Purely a scheduling choice:
/// both modes produce bitwise-identical nets, losses and counters (see
/// the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Full backward → all updates → refresh, each phase a barrier
    /// (the reference schedule; also used whenever the pool has a
    /// single worker).
    PhaseSerial,
    /// Per-layer gradient/update chains overlap the backward VMM walk
    /// on a split worker pool.
    Pipelined,
}

/// Options of one net-trainer run.
#[derive(Clone, Debug)]
pub struct NetTrainerOptions {
    pub seed: u64,
    pub lr: LrSchedule,
    /// batches between MSB refresh operations (0 = never)
    pub refresh_every: usize,
    /// simulated seconds of wall time per batch (drift clock)
    pub seconds_per_batch: f64,
    /// input batch size
    pub batch: usize,
    /// backward error pre-scale before each transposed VMM's DAC
    pub bwd_gain: f32,
    /// per-layer weight range scale: `w_max = w_scale / √fan_in`
    pub w_scale: f32,
    /// backward/update scheduling (numerics-identical either way)
    pub mode: TrainMode,
}

impl Default for NetTrainerOptions {
    fn default() -> Self {
        NetTrainerOptions {
            seed: 42,
            lr: LrSchedule::constant(0.05),
            refresh_every: 0,
            seconds_per_batch: 0.05,
            batch: 8,
            bwd_gain: 4.0,
            w_scale: 2.0,
            mode: TrainMode::Pipelined,
        }
    }
}

// -- adaptive k-fraction split -------------------------------------------

/// Smallest / largest `k` the controller will pick (permille of the
/// pool handed to the background update lane).
pub const K_MIN_PERMILLE: u32 = 125;
pub const K_MAX_PERMILLE: u32 = 875;
/// Controller step per observation.
const K_STEP_PERMILLE: u32 = 125;
/// Hysteresis band on the observed drain share (permille of step
/// time): above `HIGH` the background lane is starved → raise `k`;
/// below `LOW` it over-claims workers → lower `k`; in between, hold.
const DRAIN_HIGH_PERMILLE: u32 = 150;
const DRAIN_LOW_PERMILLE: u32 = 50;

/// Adaptive split of the worker pool between the backward-VMM
/// foreground lane and the gradient/update background lane —
/// HyTrainDNN's `k`-fraction.  The observed signal is the share of
/// step time spent in the end-of-step drain: a big share means update
/// work queued up faster than the background lane could chew it.
///
/// `k` only ever selects worker counts and eager-vs-deferred
/// placement, so the controller may react to wall-clock noise freely —
/// the trained net is bitwise identical for every `k` trajectory.
#[derive(Clone, Copy, Debug)]
pub struct KSplit {
    k_permille: u32,
}

impl KSplit {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        KSplit { k_permille: 500 }
    }

    /// Current `k` in permille.
    pub fn k_permille(&self) -> u32 {
        self.k_permille
    }

    /// Feed one step's observed drain share (permille of step time).
    pub fn observe(&mut self, drain_permille: u32) {
        if drain_permille > DRAIN_HIGH_PERMILLE {
            self.k_permille =
                (self.k_permille + K_STEP_PERMILLE).min(K_MAX_PERMILLE);
        } else if drain_permille < DRAIN_LOW_PERMILLE {
            self.k_permille = self
                .k_permille
                .saturating_sub(K_STEP_PERMILLE)
                .max(K_MIN_PERMILLE);
        }
    }

    /// Background-lane width for a `w`-worker pool: `round(w·k)`,
    /// always leaving at least one worker per lane.
    pub fn bg_workers(&self, w: usize) -> usize {
        debug_assert!(w >= 2, "split needs at least two workers");
        let b = (w as u32 * self.k_permille + 500) / 1000;
        (b as usize).clamp(1, w - 1)
    }

    /// How many of this step's `jobs` gradient/update chains run
    /// eagerly in the background lane (the rest are deferred to the
    /// end-of-step drain).  Ceiling so `k > 0` always pipelines at
    /// least one chain.
    pub fn eager_budget(&self, jobs: usize) -> usize {
        ((jobs as u64 * self.k_permille as u64).div_ceil(1000)) as usize
    }
}

pub struct NetTrainer {
    pub net: GraphNet,
    pub data: FeatureSource,
    pub pool: WorkerPool,
    pub opts: NetTrainerOptions,
    pub clock: DriftClock,
    refresh: RefreshScheduler,
    pub step: usize,
    /// per-step mean training cross-entropy
    pub losses: Vec<f64>,
    pub overflows: usize,
    pub refreshed: usize,
    /// adaptive foreground/background split (pipelined mode)
    ksplit: KSplit,
    eval_rounds: u64,
    // reusable step buffers
    x: Vec<f32>,
    labels: Vec<u8>,
    probs: Vec<f32>,
    /// softmax − one-hot logits gradient `[m, classes]`
    dlogits: Vec<f32>,
}

impl NetTrainer {
    /// Dense-stack trainer (the PR-3 entry point): `dims` becomes
    /// `GraphSpec::mlp(dims)`.
    pub fn new(params: PcmParams, dims: &[usize], policy: TilingPolicy,
               data: FeatureSource, pool: WorkerPool,
               opts: NetTrainerOptions) -> Self {
        Self::from_spec(params, &GraphSpec::mlp(dims), policy, data,
                        pool, opts)
    }

    /// Build a trainer over an arbitrary layer graph: the net is
    /// constructed and its init weights programmed through `pool`
    /// (deterministic for any worker count).
    pub fn from_spec(params: PcmParams, spec: &GraphSpec,
                     policy: TilingPolicy, data: FeatureSource,
                     pool: WorkerPool, opts: NetTrainerOptions) -> Self {
        assert_eq!(spec.input.len(), data.dim(),
                   "graph input dim != feature dim");
        let net = GraphNet::new(params, spec, policy, opts.w_scale,
                                opts.seed, &pool);
        assert_eq!(net.classes(), data.classes(),
                   "graph head dim != classes");
        let m = opts.batch;
        let d0 = net.input_dim();
        let classes = net.classes();
        NetTrainer {
            clock: DriftClock::new(opts.seconds_per_batch),
            refresh: RefreshScheduler::new(opts.refresh_every),
            step: 0,
            losses: Vec::new(),
            overflows: 0,
            refreshed: 0,
            ksplit: KSplit::new(),
            eval_rounds: 0,
            x: vec![0.0; m * d0],
            labels: vec![0; m],
            probs: vec![0.0; m * classes],
            dlogits: vec![0.0; m * classes],
            net,
            data,
            pool,
            opts,
        }
    }

    /// Run `steps` training steps: forward VMMs → softmax CE → backward
    /// transposed VMMs → per-layer hybrid updates, drift clock and
    /// refresh cadence included.  With [`TrainMode::Pipelined`] and a
    /// multi-worker pool, each layer's gradient/update overlaps the
    /// next layer's backward VMM (bitwise identical either way — see
    /// the module docs).
    pub fn train_steps(&mut self, steps: usize) {
        for _ in 0..steps {
            self.train_step_once();
        }
    }

    /// Current adaptive `k` (permille of the pool on the background
    /// lane) — observability for benches and the convergence test.
    pub fn k_permille(&self) -> u32 {
        self.ksplit.k_permille()
    }

    fn train_step_once(&mut self) {
        let classes = self.net.classes();
        let d0 = self.net.input_dim();
        let m = self.opts.batch;
        let t_now = self.clock.tick();
        let lr = self.opts.lr.at(self.step);
        let round = self.step as u64;

        // Input batch: sequential epoch order (counter-based, so
        // the data stream is schedule-independent by construction).
        for j in 0..m {
            let idx = (self.step * m + j) % self.data.train_len();
            self.labels[j] = self.data.sample_into(
                idx, false, &mut self.x[j * d0..(j + 1) * d0]);
        }

        // Forward walk: analog VMM per weighted layer, digital
        // nonlinearities between (activations cached in the graph).
        let logits =
            self.net.forward(&self.x, m, t_now, round, &self.pool);

        // Loss and output error (softmax − one-hot).
        softmax_rows(logits, m, classes, &mut self.probs);
        self.losses.push(
            nll_sum(&self.probs, &self.labels, classes) / m as f64);
        for s in 0..m {
            for j in 0..classes {
                let y = if self.labels[s] as usize == j {
                    1.0
                } else {
                    0.0
                };
                self.dlogits[s * classes + j] =
                    self.probs[s * classes + j] - y;
            }
        }

        let w = self.pool.workers();
        if self.opts.mode == TrainMode::PhaseSerial || w < 2 {
            self.backward_update_phase_serial(m, t_now, lr, round);
        } else {
            self.backward_update_pipelined(m, t_now, lr, round, w);
        }
        self.step += 1;
    }

    /// The reference schedule: full backward walk (pre-update weights
    /// throughout), then all hybrid updates, then the due refresh —
    /// three barriers on the full pool.
    fn backward_update_phase_serial(&mut self, m: usize, t_now: f32,
                                    lr: f32, round: u64) {
        self.net.backward(&self.dlogits, m, t_now, round, &self.pool,
                          self.opts.bwd_gain);
        self.overflows +=
            self.net.apply_updates(lr, t_now, round, &self.pool);
        if self.refresh.due(self.step) {
            self.refreshed +=
                self.net.refresh(t_now, round, &self.pool);
        }
    }

    /// The overlapped schedule: the pool splits into a foreground VMM
    /// lane (`w − b` workers, driven by this thread) and a background
    /// gradient/update lane (`b` scoped workers); per-layer chains are
    /// enqueued as their backward VMMs complete and everything joins at
    /// the end-of-step drain, whose share of step time feeds the
    /// [`KSplit`] controller.  Weights read by the backward VMMs are
    /// still the pre-update weights — each layer's update is enqueued
    /// only *after* that layer's (sole) transposed VMM of the step.
    fn backward_update_pipelined(&mut self, m: usize, t_now: f32,
                                 lr: f32, round: u64, w: usize) {
        let b = self.ksplit.bg_workers(w);
        let fg = WorkerPool::new(w - b);
        let bg = WorkerPool::new(b);
        let refresh_due = self.refresh.due(self.step);
        let eager_budget =
            self.ksplit.eager_budget(self.net.weighted_layers());
        let bwd_gain = self.opts.bwd_gain;
        let totals = StepTotals::new();
        let step_start = Instant::now();
        let net = &mut self.net;
        let dlogits = &self.dlogits;
        let drain_time = bg.pipeline(|scope| {
            net.backward_update_pipelined(
                dlogits, m, t_now, round, &fg, scope, bwd_gain, lr,
                refresh_due, eager_budget, &totals);
            let drain_start = Instant::now();
            scope.drain();
            drain_start.elapsed()
        });
        let step_time = step_start.elapsed().as_nanos().max(1);
        let drain_permille =
            (drain_time.as_nanos() * 1000 / step_time) as u32;
        self.ksplit.observe(drain_permille);
        self.overflows += totals.overflows();
        self.refreshed += totals.refreshed();
    }

    /// Mean cross-entropy and accuracy of the analog forward pass over
    /// the first `n` test samples at inference time `t_eval`.  Each
    /// chunk uses a fresh evaluation round (disjoint from training
    /// rounds), so repeated probes draw fresh read noise and never
    /// replay training noise.
    pub fn evaluate(&mut self, n: usize, t_eval: f32) -> (f64, f64) {
        let classes = self.net.classes();
        let d0 = self.net.input_dim();
        let m = self.opts.batch;
        let mut hits = 0usize;
        let mut loss_sum = 0.0f64;
        let mut done = 0usize;
        while done < n {
            let mb = m.min(n - done);
            let round = EVAL_ROUND_BASE + self.eval_rounds;
            self.eval_rounds += 1;
            for j in 0..mb {
                self.labels[j] = self.data.sample_into(
                    done + j, true, &mut self.x[j * d0..(j + 1) * d0]);
            }
            let logits = self.net.forward(&self.x[..mb * d0], mb,
                                          t_eval, round, &self.pool);
            softmax_rows(logits, mb, classes,
                         &mut self.probs[..mb * classes]);
            loss_sum += nll_sum(&self.probs[..mb * classes],
                                &self.labels[..mb], classes);
            for s in 0..mb {
                let row = &self.probs[s * classes..(s + 1) * classes];
                if argmax_row(row) == self.labels[s] as usize {
                    hits += 1;
                }
            }
            done += mb;
        }
        (loss_sum / n as f64, hits as f64 / n as f64)
    }

    /// Train→freeze handoff to the serving layer
    /// ([`crate::serve::ModelSnapshot::freeze`] is the caller): consume
    /// the trainer and hand over the trained net (its conductance
    /// planes are sealed behind the snapshot's read-only API from here
    /// on), the feature source (train split = calibration set, test
    /// split = request corpus) and the drift clock's current time —
    /// the shared clock keeps ticking in the snapshot, training just
    /// stops advancing it.
    pub fn freeze(self) -> (GraphNet, FeatureSource, f64) {
        (self.net, self.data, self.clock.now)
    }

    /// Endurance snapshot folded over every grid's tiles.
    pub fn endurance(&self) -> EnduranceLedger {
        let mut ledger = EnduranceLedger::new();
        self.net.record_endurance(&mut ledger);
        ledger
    }

    /// Fault/degradation accounting folded over every grid's tiles
    /// (all-zero when the fault model is disabled); carried through
    /// the freeze handoff, since the frozen net keeps its fault planes.
    pub fn fault_summary(&self) -> crate::pcm::FaultMap {
        self.net.fault_summary()
    }

    /// Total SET pulses across all grids.
    pub fn total_set_pulses(&self) -> u64 {
        self.net.total_set_pulses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::features::{BlobDataset, PooledCifar};

    fn blob_data() -> FeatureSource {
        FeatureSource::Blobs(BlobDataset::new(3, 8, 4, 0.35, 400, 80))
    }

    fn linear_read_params() -> PcmParams {
        PcmParams {
            nonlinear: false,
            write_noise: false,
            read_noise: true,
            drift: false,
            drift_nu_sigma: 0.0,
            ..Default::default()
        }
    }

    fn policy(t: usize) -> TilingPolicy {
        TilingPolicy { tile_rows: t, tile_cols: t }
    }

    #[test]
    fn device_net_learns_blobs() {
        // Thresholds validated against the bit-exact oracle
        // (`rust/tests/golden/oracle.py` NnTrainer on this exact
        // config, re-run for the PR-5 per-(op, tile, sample)
        // read-noise sub-streams): acc 0.163 -> 0.988 (60 steps)
        // -> 1.000 (120), final eval loss 0.032.
        let mut t = NetTrainer::new(
            linear_read_params(), &[8, 12, 8, 4], policy(6), blob_data(),
            WorkerPool::serial(),
            NetTrainerOptions { batch: 16,
                                lr: LrSchedule::constant(0.2),
                                ..Default::default() });
        let (_, acc0) = t.evaluate(80, 0.0);
        t.train_steps(60);
        let (_, acc_mid) = t.evaluate(80, t.clock.now_f32());
        t.train_steps(60);
        let (loss, acc) = t.evaluate(80, t.clock.now_f32());
        assert!(acc0 < 0.5, "untrained net is already accurate? {acc0}");
        assert!(acc_mid > acc0 + 0.3, "mid {acc_mid} vs start {acc0}");
        assert!(acc > 0.85, "device eval acc {acc} (from {acc0})");
        assert!(acc >= acc_mid - 0.05, "end {acc} << mid {acc_mid}");
        assert!(loss < 0.5, "eval loss {loss}");
        assert!(t.overflows > 0, "no LSB->MSB overflow ever fired");
        // Training loss trends down too.
        let early: f64 = t.losses[..10].iter().sum::<f64>() / 10.0;
        let late: f64 =
            t.losses[t.losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(late < early * 0.5, "train loss {early} -> {late}");
    }

    #[test]
    fn device_net_learns_pooled_synthetic_cifar() {
        // The acceptance-criterion workload: >= 2 hidden layers on the
        // data pipeline's synthetic CIFAR, monotonically improving eval
        // accuracy (non-strict: probes allow small noise wiggle).
        let data =
            FeatureSource::Cifar(PooledCifar::new(1, 8, 1000, 200));
        let mut t = NetTrainer::new(
            linear_read_params(), &[48, 16, 12, 10], policy(16), data,
            WorkerPool::from_env(),
            NetTrainerOptions { batch: 16,
                                lr: LrSchedule::constant(0.1),
                                ..Default::default() });
        let (_, acc0) = t.evaluate(60, 0.0);
        t.train_steps(40);
        let (_, acc1) = t.evaluate(60, t.clock.now_f32());
        t.train_steps(40);
        let (_, acc2) = t.evaluate(60, t.clock.now_f32());
        assert!(acc1 >= acc0, "acc {acc0} -> {acc1}");
        assert!(acc2 >= acc1 - 0.05, "acc {acc1} -> {acc2}");
        assert!(acc2 > acc0 + 0.2 && acc2 > 0.5,
                "no real learning: {acc0} -> {acc1} -> {acc2}");
    }

    #[test]
    fn refresh_and_endurance_cover_all_layers() {
        let mut t = NetTrainer::new(
            linear_read_params(), &[8, 12, 8, 4], policy(6), blob_data(),
            WorkerPool::serial(),
            NetTrainerOptions { batch: 8, refresh_every: 5,
                                ..Default::default() });
        t.train_steps(20);
        let ledger = t.endurance();
        // 2 devices per weight cell over every layer's matrix.
        let weights = 8 * 12 + 12 * 8 + 8 * 4;
        assert_eq!(ledger.msb.count as usize, 2 * weights);
        assert!(t.total_set_pulses() > 0);
    }

    #[test]
    fn run_is_worker_count_invariant() {
        // Default mode is Pipelined, so workers 2/4 take the
        // overlapped schedule while workers=1 falls back to the
        // phase-serial reference — this pins both worker-count
        // invariance AND pipelined-vs-serial bit-equality in one go.
        let run = |workers: usize| {
            let mut t = NetTrainer::new(
                PcmParams::default(), &[8, 12, 8, 4], policy(5),
                blob_data(), WorkerPool::new(workers),
                NetTrainerOptions { batch: 6, refresh_every: 4,
                                    ..Default::default() });
            t.train_steps(8);
            let ev = t.evaluate(24, t.clock.now_f32());
            (t.losses.clone(), t.overflows, t.refreshed, ev)
        };
        let a = run(1);
        assert_eq!(a, run(2));
        assert_eq!(a, run(4));
    }

    #[test]
    fn pipelined_matches_phase_serial_smoke() {
        // Full-noise params, refresh cadence on: the two schedules
        // must agree bit for bit on the same multi-worker pool.  (The
        // heavier sweep lives in
        // rust/tests/prop_pipeline_equivalence.rs.)
        let run = |mode: TrainMode| {
            let mut t = NetTrainer::new(
                PcmParams::default(), &[8, 12, 8, 4], policy(5),
                blob_data(), WorkerPool::new(4),
                NetTrainerOptions { batch: 6, refresh_every: 3, mode,
                                    ..Default::default() });
            t.train_steps(9);
            let ev = t.evaluate(24, t.clock.now_f32());
            (t.losses.clone(), t.overflows, t.refreshed, ev,
             t.total_set_pulses())
        };
        assert_eq!(run(TrainMode::PhaseSerial),
                   run(TrainMode::Pipelined));
    }

    #[test]
    fn adaptive_k_split_converges() {
        // Starved background lane (big drain share) → k climbs to the
        // ceiling and sticks; idle drain → k falls to the floor; the
        // hysteresis band holds k in place.
        let mut k = KSplit::new();
        assert_eq!(k.k_permille(), 500);
        for _ in 0..10 {
            k.observe(400);
        }
        assert_eq!(k.k_permille(), K_MAX_PERMILLE);
        let before = k.k_permille();
        k.observe(100); // inside [50, 150] band: hold
        assert_eq!(k.k_permille(), before);
        for _ in 0..10 {
            k.observe(0);
        }
        assert_eq!(k.k_permille(), K_MIN_PERMILLE);
        // Lane split honors the bounds at every k.
        for w in 2..=16 {
            let b = k.bg_workers(w);
            assert!(b >= 1 && b <= w - 1, "w {w} b {b}");
        }
        // k > 0 always pipelines at least one chain.
        assert!(k.eager_budget(3) >= 1);
        assert!(k.eager_budget(3) <= 3);
    }
}
