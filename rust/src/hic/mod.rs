//! HIC weight-representation substrate (host-side twin of
//! `python/compile/hic.py` + `kernels/lsb_update.py`).
//!
//! * [`fixedpoint`] — the 7-bit signed LSB accumulator: saturating
//!   accumulate, round-toward-zero overflow extraction, per-bit flip
//!   accounting.  Bit-exact with the Pallas kernel (shared golden vectors
//!   in tests).  [`fixedpoint::AccumulatorPlane`] is the planar (SoA)
//!   register file the weight tensor sweeps.
//! * [`weight`] — one HIC-mapped weight tensor over a planar
//!   [`crate::pcm::DifferentialPair`] MSB array + accumulator LSB plane,
//!   with the full update / refresh / decode cycle running on flat
//!   slices.
//!
//! The coordinator uses this twin for host-side analyses (endurance
//! projections, refresh policy studies, crossbar mapping) and the test
//! suite uses it to cross-validate the lowered JAX implementation.

pub mod fixedpoint;
pub mod weight;

pub use fixedpoint::{AccumulatorPlane, FixedPointAccumulator,
                     UpdateOutcome};
pub use weight::HicWeight;
