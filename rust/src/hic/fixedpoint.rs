//! The LSB array's signed fixed-point accumulator semantics.
//!
//! Must stay **bit-exact** with `python/compile/kernels/lsb_update.py`
//! (and its jnp oracle): round-toward-zero overflow division, residue in
//! `(-half_range, half_range)`, two's-complement per-bit flip accounting
//! in offset-encoded u(nbits).

/// Outcome of accumulating one quantized update into one weight's LSB
/// register.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UpdateOutcome {
    /// residual accumulator counts after overflow extraction
    pub acc: i32,
    /// whole MSB quanta carried out (signed)
    pub overflow: i32,
    /// binary devices rewritten (SET or RESET)
    pub flips: u32,
    /// of those, 1→0 transitions (RESET pulses — WE-cycle commits)
    pub resets: u32,
}

/// A single weight's accumulator register.
#[derive(Clone, Copy, Debug)]
pub struct FixedPointAccumulator {
    pub bits: u32,
    pub acc: i32,
}

impl FixedPointAccumulator {
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits));
        FixedPointAccumulator { bits, acc: 0 }
    }

    pub fn half_range(&self) -> i32 {
        1 << (self.bits - 1)
    }

    /// Accumulate `delta` counts; extract overflow (round-toward-zero).
    pub fn update(&mut self, delta: i32) -> UpdateOutcome {
        let half = self.half_range();
        let s = self.acc + delta;
        // Round-toward-zero division (Rust `/` already truncates).
        let ovf = s / half;
        let mut res = s - ovf * half;
        res = res.clamp(-half, half - 1);

        let old_u = (self.acc + half) as u32;
        let new_u = (res + half) as u32;
        let changed = old_u ^ new_u;
        let mut flips = 0u32;
        let mut resets = 0u32;
        for b in 0..self.bits {
            let bit = (changed >> b) & 1;
            flips += bit;
            resets += ((old_u >> b) & 1) & bit;
        }
        self.acc = res;
        UpdateOutcome { acc: res, overflow: ovf, flips, resets }
    }

    /// Quantize a weight-space update to accumulator counts with optional
    /// stochastic rounding (mirrors `hic.py::apply_update`).
    pub fn quantize_counts(dw_over_lsb_step: f32, stochastic: bool,
                           dither: f32, half: i32) -> i32 {
        let clamp = (2 * half - 1) as f32;
        let v = dw_over_lsb_step;
        let q = if stochastic {
            debug_assert!((0.0..1.0).contains(&dither));
            (v + dither).floor()
        } else {
            v.round()
        };
        q.clamp(-clamp, clamp) as i32
    }
}

/// A whole weight tensor's accumulator registers as one planar `i32`
/// slice (all registers share the bit width), the SoA twin of
/// `Vec<FixedPointAccumulator>`: half the memory per register and a
/// contiguous plane for the update sweep.
#[derive(Clone, Debug)]
pub struct AccumulatorPlane {
    pub bits: u32,
    pub acc: Vec<i32>,
}

impl AccumulatorPlane {
    pub fn new(bits: u32, n: usize) -> Self {
        assert!((2..=16).contains(&bits));
        AccumulatorPlane { bits, acc: vec![0; n] }
    }

    pub fn len(&self) -> usize {
        self.acc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    pub fn half_range(&self) -> i32 {
        1 << (self.bits - 1)
    }

    /// Accumulate `delta` counts into register `i` — identical semantics
    /// to [`FixedPointAccumulator::update`] on the scalar view.
    #[inline]
    pub fn update(&mut self, i: usize, delta: i32) -> UpdateOutcome {
        let mut scalar = FixedPointAccumulator {
            bits: self.bits,
            acc: self.acc[i],
        };
        let out = scalar.update(delta);
        self.acc[i] = scalar.acc;
        out
    }

    /// Scalar view of register `i` (test/inspection path).
    pub fn at(&self, i: usize) -> FixedPointAccumulator {
        FixedPointAccumulator { bits: self.bits, acc: self.acc[i] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn acc7(start: i32) -> FixedPointAccumulator {
        let mut a = FixedPointAccumulator::new(7);
        a.acc = start;
        a
    }

    #[test]
    fn overflow_round_toward_zero() {
        // Mirrors the kernel smoke cases.
        let cases = [
            // (acc, delta, acc', ovf)
            (0, 63, 63, 0),
            (0, 64, 0, 1),
            (0, -64, 0, -1),
            (-1, -64, -1, -1),
            (63, 1, 0, 1),
            (-63, -2, -1, -1),
            (10, 127, 9, 2),
            (-10, -127, -9, -2),
            (0, 0, 0, 0),
        ];
        for (start, delta, want_acc, want_ovf) in cases {
            let mut a = acc7(start);
            let out = a.update(delta);
            assert_eq!((out.acc, out.overflow), (want_acc, want_ovf),
                       "acc={start} delta={delta}");
            // Conservation: start + delta == acc' + 64*ovf
            assert_eq!(start + delta, out.acc + 64 * out.overflow);
        }
    }

    #[test]
    fn residue_always_in_open_range() {
        let mut rng = Pcg64::new(1, 0);
        for _ in 0..10_000 {
            let start = rng.below(127) as i32 - 63;
            let delta = rng.below(255) as i32 - 127;
            let mut a = acc7(start);
            let out = a.update(delta);
            assert!((-64..=63).contains(&out.acc),
                    "start={start} delta={delta} -> {out:?}");
            assert_eq!(start + delta, out.acc + 64 * out.overflow);
        }
    }

    #[test]
    fn flip_accounting() {
        // 0 -> 1 counts one flip (a SET on bit 0 of the offset register:
        // 64=1000000b -> 65=1000001b).
        let mut a = acc7(0);
        let out = a.update(1);
        assert_eq!(out.flips, 1);
        assert_eq!(out.resets, 0);

        // 63 + 1 -> overflow: register 127 (1111111b) -> 64 (1000000b):
        // six 1->0 transitions.
        let mut a = acc7(63);
        let out = a.update(1);
        assert_eq!(out.overflow, 1);
        assert_eq!(out.flips, 6);
        assert_eq!(out.resets, 6);

        // No change -> no flips.
        let mut a = acc7(17);
        let out = a.update(0);
        assert_eq!(out.flips, 0);
    }

    #[test]
    fn flips_bounded_by_bits() {
        let mut rng = Pcg64::new(2, 0);
        for _ in 0..5_000 {
            let start = rng.below(127) as i32 - 63;
            let delta = rng.below(255) as i32 - 127;
            let mut a = acc7(start);
            let out = a.update(delta);
            assert!(out.flips <= 7);
            assert!(out.resets <= out.flips);
        }
    }

    #[test]
    fn plane_matches_scalar_registers() {
        let mut rng = Pcg64::new(9, 0);
        let n = 64;
        let mut plane = AccumulatorPlane::new(7, n);
        let mut scalars = vec![FixedPointAccumulator::new(7); n];
        for _ in 0..200 {
            let i = rng.below(n as u64) as usize;
            let d = rng.below(255) as i32 - 127;
            let a = plane.update(i, d);
            let b = scalars[i].update(d);
            assert_eq!(a, b);
        }
        for i in 0..n {
            assert_eq!(plane.at(i).acc, scalars[i].acc);
        }
        assert_eq!(plane.len(), n);
        assert_eq!(plane.half_range(), 64);
    }

    #[test]
    fn quantize_counts_deterministic() {
        assert_eq!(
            FixedPointAccumulator::quantize_counts(2.4, false, 0.0, 64), 2);
        assert_eq!(
            FixedPointAccumulator::quantize_counts(-2.6, false, 0.0, 64),
            -3);
        // clamp at +-127
        assert_eq!(
            FixedPointAccumulator::quantize_counts(500.0, false, 0.0, 64),
            127);
        assert_eq!(
            FixedPointAccumulator::quantize_counts(-500.0, false, 0.0, 64),
            -127);
    }

    #[test]
    fn quantize_counts_stochastic_unbiased() {
        let mut rng = Pcg64::new(3, 0);
        let v = 0.3f32;
        let n = 50_000;
        let mean: f64 = (0..n)
            .map(|_| FixedPointAccumulator::quantize_counts(
                v, true, rng.uniform() as f32, 64) as f64)
            .sum::<f64>() / n as f64;
        assert!((mean - 0.3).abs() < 0.01, "mean={mean}");
    }
}
