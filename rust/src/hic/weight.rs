//! One HIC-mapped weight tensor: MSB differential pair + LSB accumulators.
//!
//! Host-side twin of the per-layer state inside the lowered training
//! programs; the update cycle (quantize → accumulate → overflow → program
//! → selective refresh) matches `python/compile/hic.py` step for step.
//! Used by the crossbar simulator, the refresh/endurance analyses and the
//! property-test suite.

use crate::pcm::array::{DifferentialPair, G_SPAN};
use crate::pcm::device::PcmParams;
use crate::pcm::endurance::EnduranceLedger;
use crate::pcm::fault::FaultMap;
use crate::util::rng::Pcg64;

use super::fixedpoint::{AccumulatorPlane, FixedPointAccumulator};

/// Geometry of the hybrid representation (mirrors `HicConfig`).
#[derive(Clone, Copy, Debug)]
pub struct HicGeometry {
    pub msb_bits: u32,
    pub lsb_bits: u32,
    pub w_max: f32,
    pub max_pulses: u32,
    pub stochastic_rounding: bool,
}

impl Default for HicGeometry {
    fn default() -> Self {
        HicGeometry { msb_bits: 4, lsb_bits: 7, w_max: 1.0, max_pulses: 10,
                      stochastic_rounding: true }
    }
}

impl HicGeometry {
    pub fn msb_levels(&self) -> u32 {
        (1 << self.msb_bits) - 1
    }

    /// One MSB weight quantum ε.
    pub fn msb_step(&self) -> f32 {
        2.0 * self.w_max / self.msb_levels() as f32
    }

    pub fn lsb_half_range(&self) -> i32 {
        1 << (self.lsb_bits - 1)
    }

    /// Weight value of one accumulator count.
    pub fn lsb_step(&self) -> f32 {
        self.msb_step() / self.lsb_half_range() as f32
    }

    /// Snap to the MSB (15-level) grid: ±(levels-1)/2 · ε representable,
    /// so every quantized value is an exact grid multiple (matches
    /// `python/compile/hic.py::quantize_msb`).
    pub fn quantize_msb(&self, w: f32) -> f32 {
        let eps = self.msb_step();
        let kmax = ((self.msb_levels() - 1) / 2) as f32;
        (w / eps).round().clamp(-kmax, kmax) * eps
    }
}

/// One weight tensor on hybrid memory.  All per-weight state is planar:
/// the MSB differential pair holds two `PcmArray` plane sets, the LSB
/// registers one `i32` plane, the flip/RESET counters one `u64` plane
/// each — so the update cycle and the endurance snapshot sweep flat
/// slices.
pub struct HicWeight {
    pub geom: HicGeometry,
    pub msb: DifferentialPair,
    pub acc: AccumulatorPlane,
    pub lsb_flips: Vec<u64>,
    pub lsb_resets: Vec<u64>,
}

impl HicWeight {
    pub fn new(params: PcmParams, geom: HicGeometry, rows: usize,
               cols: usize, rng: &mut Pcg64) -> Self {
        let msb = DifferentialPair::new(params, rows, cols, geom.w_max, rng);
        let n = rows * cols;
        HicWeight {
            geom,
            msb,
            acc: AccumulatorPlane::new(geom.lsb_bits, n),
            lsb_flips: vec![0; n],
            lsb_resets: vec![0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.acc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Program initial weights (MSB-quantized).
    pub fn program_init(&mut self, w0: &[f32], t_now: f32,
                        rng: &mut Pcg64) {
        let q: Vec<f32> =
            w0.iter().map(|&w| self.geom.quantize_msb(w)).collect();
        self.msb.program_weights(&q, t_now, rng);
    }

    /// Decode the inference weights at `t_now` (drift, no read noise).
    pub fn decode(&self, t_now: f32) -> Vec<f32> {
        self.msb.decode(t_now)
    }

    /// Decode into a caller-provided buffer (no allocation).
    pub fn decode_into(&self, t_now: f32, out: &mut [f32]) {
        self.msb.decode_into(t_now, out);
    }

    /// One training update over the planar state: quantize `-lr * grad`
    /// into the accumulator plane, program MSB on overflow.  Returns the
    /// number of overflow events.
    ///
    /// RNG contract: one `uniform()` dither per element **only when
    /// stochastic rounding is on** (deterministic rounding consumes no
    /// draws), plus the write-noise draws of any overflow programming —
    /// so a grid of tiles running this kernel on per-tile streams stays
    /// schedule-independent.
    pub fn apply_update(&mut self, grad: &[f32], lr: f32, t_now: f32,
                        rng: &mut Pcg64) -> usize {
        assert_eq!(grad.len(), self.len());
        let half = self.geom.lsb_half_range();
        let eps = self.geom.msb_step();
        let lsb_step = self.geom.lsb_step();
        let stochastic = self.geom.stochastic_rounding;
        let mut overflows = 0usize;
        for (i, &gi) in grad.iter().enumerate() {
            let v = -lr * gi / lsb_step;
            let dither =
                if stochastic { rng.uniform() as f32 } else { 0.0 };
            let delta = FixedPointAccumulator::quantize_counts(
                v, stochastic, dither, half);
            let out = self.acc.update(i, delta);
            self.lsb_flips[i] += out.flips as u64;
            self.lsb_resets[i] += out.resets as u64;
            if out.overflow != 0 {
                overflows += out.overflow.unsigned_abs() as usize;
                self.msb.apply_increment(
                    i, out.overflow as f32 * eps, t_now, rng);
            }
        }
        overflows
    }

    /// Selective saturation refresh; returns refreshed pair count.
    pub fn refresh(&mut self, t_now: f32, rng: &mut Pcg64) -> usize {
        self.msb.refresh(t_now, rng).len()
    }

    /// Fold this tensor's device activity into an endurance ledger —
    /// whole-plane sweeps over the lifetime-counter planes (G+ then G−,
    /// like the scalar chain the ledger previously walked).
    pub fn record_endurance(&self, ledger: &mut EnduranceLedger) {
        ledger.record_msb_planes(&self.msb.plus.set_count,
                                 &self.msb.plus.reset_count);
        ledger.record_msb_planes(&self.msb.minus.set_count,
                                 &self.msb.minus.reset_count);
        for (&f, &r) in self.lsb_flips.iter().zip(&self.lsb_resets) {
            ledger.record_lsb_weight(f, r, self.geom.lsb_bits as u64);
        }
    }

    /// Seed fabrication stuck faults on the MSB differential pair from
    /// a dedicated sampling stream (no-op when the fault model is off).
    pub fn seed_faults(&mut self, rng: &mut Pcg64) {
        self.msb.seed_faults(rng);
    }

    /// Aggregated fault/degradation accounting for this tensor (both
    /// MSB planes plus spare-strip remap state).
    pub fn fault_map(&self) -> FaultMap {
        self.msb.fault_map()
    }

    /// Inference model bits: only the MSB array is needed at inference.
    pub fn inference_bits(&self) -> usize {
        self.len() * self.geom.msb_bits as usize
    }
}

/// Conductance-window headroom check used by tests and the mapper: the
/// weight map must keep programmed conductances within the guard band.
pub fn conductance_headroom(w_max: f32) -> f32 {
    1.0 - G_SPAN * (w_max / w_max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ideal() -> (PcmParams, HicGeometry) {
        (PcmParams::ideal(),
         HicGeometry { stochastic_rounding: false, ..Default::default() })
    }

    #[test]
    fn geometry() {
        let g = HicGeometry::default();
        assert_eq!(g.msb_levels(), 15);
        assert!((g.msb_step() - 2.0 / 15.0).abs() < 1e-6);
        assert_eq!(g.lsb_half_range(), 64);
        assert!((g.lsb_step() - g.msb_step() / 64.0).abs() < 1e-9);
        assert_eq!(g.quantize_msb(0.0), 0.0);
        // clamp to the outermost grid code: 7 * (2/15)
        let wmax_repr = 7.0 * g.msb_step();
        assert_eq!(g.quantize_msb(5.0), wmax_repr);
        assert_eq!(g.quantize_msb(-5.0), -wmax_repr);
        let q = g.quantize_msb(0.31);
        assert!((q - 0.2667).abs() < 1e-3);
    }

    #[test]
    fn gradient_descends_a_quadratic() {
        // Minimize ||w - target||^2 through the full hybrid pipeline.
        let (p, g) = ideal();
        let mut rng = Pcg64::new(4, 0);
        let mut hw = HicWeight::new(p, g, 4, 4, &mut rng);
        let target: Vec<f32> =
            (0..16).map(|i| ((i as f32) - 8.0) / 10.0).collect();
        hw.program_init(&[0.0; 16], 0.0, &mut rng);
        let mut t = 1.0;
        for _ in 0..400 {
            let w = hw.decode(t);
            let grad: Vec<f32> =
                w.iter().zip(&target).map(|(a, b)| a - b).collect();
            hw.apply_update(&grad, 0.5, t, &mut rng);
            t += 0.05;
        }
        let w = hw.decode(t);
        let err: f32 = w.iter().zip(&target)
            .map(|(a, b)| (a - b).abs()).sum::<f32>() / 16.0;
        // Converges to within ~1 MSB quantum on average.
        assert!(err < g.msb_step(), "err={err}");
    }

    #[test]
    fn overflow_drives_msb_only() {
        let (p, g) = ideal();
        let mut rng = Pcg64::new(5, 0);
        let mut hw = HicWeight::new(p, g, 1, 1, &mut rng);
        hw.program_init(&[0.0], 0.0, &mut rng);
        // Updates summing to less than one quantum: MSB untouched.
        let small_grad = [-g.lsb_step() * 10.0 / 0.5];
        for _ in 0..5 {
            hw.apply_update(&small_grad, 0.5, 1.0, &mut rng);
        }
        assert_eq!(hw.msb.plus.set_count[0], 0);
        assert_eq!(hw.acc.acc[0], 50);
        // Push past the boundary.
        for _ in 0..2 {
            hw.apply_update(&small_grad, 0.5, 1.0, &mut rng);
        }
        assert!(hw.msb.plus.set_count[0] > 0);
        assert_eq!(hw.acc.acc[0], 70 - 64);
    }

    #[test]
    fn endurance_recording() {
        let (p, g) = ideal();
        let mut rng = Pcg64::new(6, 0);
        let mut hw = HicWeight::new(p, g, 2, 2, &mut rng);
        hw.program_init(&[0.5, -0.5, 0.2, 0.0], 0.0, &mut rng);
        let grad = [1.0f32, -1.0, 0.5, -0.5];
        for _ in 0..50 {
            hw.apply_update(&grad, 0.5, 1.0, &mut rng);
        }
        let mut ledger = EnduranceLedger::new();
        hw.record_endurance(&mut ledger);
        assert_eq!(ledger.msb.count as usize, 2 * hw.len());
        assert_eq!(ledger.lsb.count as usize, hw.len());
        assert!(ledger.msb.max > 0);
    }

    #[test]
    fn inference_bits() {
        let (p, g) = ideal();
        let mut rng = Pcg64::new(7, 0);
        let hw = HicWeight::new(p, g, 8, 4, &mut rng);
        assert_eq!(hw.inference_bits(), 32 * 4);
    }
}
