//! Spanned diagnostics of the experiment-spec pipeline.
//!
//! Every stage (lexer, parser, lowering) reports failures as a
//! [`SpecError`]: one message anchored at a 1-based line/column
//! [`Span`] of the source text.  The CLI prefixes the file path, so a
//! rendered diagnostic reads `examples/fig4_grid.hic:7:3: unknown key
//! 'stepz' in 'train' (expected one of: batch, eval_n, lr,
//! refresh_every, steps)` — grep-able and editor-clickable.

use std::fmt;

/// A 1-based source position.  Spans deliberately stay points (not
/// ranges): every token and block the grammar produces is short enough
/// that the start position locates it unambiguously, and a point span
/// keeps the lexer allocation-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub line: u32,
    pub col: u32,
}

impl Span {
    pub fn new(line: u32, col: u32) -> Self {
        Span { line, col }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// One spec diagnostic: a message at a source position.
///
/// Renders as `LINE:COL: MESSAGE` (the caller prepends the file path).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpecError {
    pub span: Span,
    pub msg: String,
}

impl SpecError {
    pub fn new(span: Span, msg: impl Into<String>) -> Self {
        SpecError { span, msg: msg.into() }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.span, self.msg)
    }
}

impl std::error::Error for SpecError {}

/// Shorthand constructor used across the parser and lowering.
pub fn err<T>(span: Span, msg: impl Into<String>) -> Result<T, SpecError> {
    Err(SpecError::new(span, msg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_line_col_and_message() {
        let e = SpecError::new(Span::new(3, 14), "unknown key 'x'");
        assert_eq!(e.to_string(), "3:14: unknown key 'x'");
    }

    #[test]
    fn err_helper_propagates() {
        let r: Result<(), SpecError> = err(Span::new(1, 1), "boom");
        assert_eq!(r.unwrap_err().span, Span::new(1, 1));
    }
}
