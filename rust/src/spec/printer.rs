//! Pretty-printer for the `.hic` experiment-spec format.
//!
//! Deterministic canonical layout: two-space indentation, one entry
//! per line, single-line lists, number literals emitted **verbatim**
//! (the lexer keeps their source text) and strings re-escaped with the
//! exact escape set the lexer accepts.  Comments do not survive a
//! round trip (the parser drops them), but structure and values do:
//! `parse(print(parse(src))) == parse(src)` for every valid source —
//! the round-trip identity `rust/tests/spec_dsl.rs` pins over the
//! shipped examples and generated specs.

use std::fmt::Write as _;

use super::ast::{Block, Entry, Scalar, SpecAst, Value};

/// Render a spec document in canonical layout (trailing newline
/// included).
pub fn print(ast: &SpecAst) -> String {
    let mut out = String::new();
    let _ = write!(out, "experiment {} ", ast.kind.text);
    print_block(&mut out, &ast.body, 0);
    out.push('\n');
    out
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn print_block(out: &mut String, block: &Block, depth: usize) {
    if block.entries.is_empty() {
        out.push_str("{}");
        return;
    }
    out.push_str("{\n");
    for e in &block.entries {
        indent(out, depth + 1);
        match e {
            Entry::Assign(a) => {
                let _ = write!(out, "{} = ", a.key.text);
                print_value(out, &a.value);
            }
            Entry::Block(b) => {
                let _ = write!(out, "{} ", b.name.text);
                print_block(out, &b.body, depth + 1);
            }
            Entry::Marker(m) => out.push_str(&m.text),
        }
        out.push('\n');
    }
    indent(out, depth);
    out.push('}');
}

fn print_value(out: &mut String, v: &Value) {
    match v {
        Value::Scalar(s) => print_scalar(out, s),
        Value::List { items, .. } => {
            out.push('[');
            for (i, s) in items.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                print_scalar(out, s);
            }
            out.push(']');
        }
    }
}

fn print_scalar(out: &mut String, s: &Scalar) {
    match s {
        Scalar::Num(n) => out.push_str(&n.text),
        Scalar::Word(w) => out.push_str(&w.text),
        Scalar::Str(st) => {
            out.push('"');
            for c in st.value.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::parser::parse;

    #[test]
    fn canonical_layout() {
        let src = "experiment fig4{seed=42 # c\n model{arch=mlp \
                   widths=[0.5,1e2] layers{relu dense{out=3}}} \
                   out=\"a\\nb\"}";
        let ast = parse(src).unwrap();
        let printed = print(&ast);
        assert_eq!(printed, "\
experiment fig4 {
  seed = 42
  model {
    arch = mlp
    widths = [0.5, 1e2]
    layers {
      relu
      dense {
        out = 3
      }
    }
  }
  out = \"a\\nb\"
}
");
    }

    #[test]
    fn round_trip_is_identity() {
        let src = "experiment serve {\n  data { blobs { dim = 6 } }\n  \
                   serve { probes = [1e2, 4e7] window = 0.2 }\n  \
                   empty {}\n}\n";
        let a = parse(src).unwrap();
        let printed = print(&a);
        let b = parse(&printed).unwrap();
        assert_eq!(a, b, "parse -> print -> parse must be identity");
        assert_eq!(print(&b), printed, "printing is idempotent");
    }
}
