//! Recursive-descent parser for the `.hic` experiment-spec format.
//!
//! Grammar (see `spec` module docs for the key schema):
//!
//! ```text
//! spec    := "experiment" WORD block EOF
//! block   := "{" entry* "}"
//! entry   := WORD "=" value        # assignment
//!          | WORD block            # named sub-block
//!          | WORD                  # bare marker (relu, gap, softmax)
//! value   := scalar | list
//! scalar  := NUMBER | STRING | WORD
//! list    := "[" [ scalar ("," scalar)* [","] ] "]"
//! ```
//!
//! The grammar is LL(1): after a key word, one token of lookahead
//! (`=` / `{` / anything else) decides the entry form.  All errors are
//! spanned [`SpecError`]s naming both what was found and what was
//! expected.

use super::ast::{Assign, Block, Entry, Ident, NamedBlock, NumLit,
                 Scalar, SpecAst, StrLit, Value};
use super::diag::{err, SpecError};
use super::lexer::{lex, Tok, Token};

/// Parse one spec document from source text.
pub fn parse(text: &str) -> Result<SpecAst, SpecError> {
    let toks = lex(text)?;
    let mut p = Parser { toks, i: 0 };
    let kw = p.ident("expected the 'experiment' header")?;
    if kw.text != "experiment" {
        return err(kw.span, format!(
            "expected 'experiment', found '{}'", kw.text));
    }
    let kind = p.ident("expected an experiment kind after 'experiment'")?;
    let body = p.block()?;
    let t = p.peek();
    if t.tok != Tok::Eof {
        return err(t.span, format!(
            "expected end of file after the experiment block, found {}",
            t.tok.describe()));
    }
    Ok(SpecAst { kind, body })
}

struct Parser {
    toks: Vec<Token>,
    i: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        // The token vector always ends with Eof, which is never
        // consumed.
        &self.toks[self.i.min(self.toks.len() - 1)]
    }

    fn bump(&mut self) -> Token {
        let t = self.peek().clone();
        if self.i + 1 < self.toks.len() {
            self.i += 1;
        }
        t
    }

    fn ident(&mut self, what: &str) -> Result<Ident, SpecError> {
        let t = self.bump();
        match t.tok {
            Tok::Ident(text) => Ok(Ident { text, span: t.span }),
            other => err(t.span, format!(
                "{what}, found {}", other.describe())),
        }
    }

    fn expect(&mut self, want: Tok, what: &str) -> Result<Token, SpecError> {
        let t = self.bump();
        if t.tok == want {
            Ok(t)
        } else {
            err(t.span, format!("{what}, found {}", t.tok.describe()))
        }
    }

    fn block(&mut self) -> Result<Block, SpecError> {
        let open = self.expect(Tok::LBrace, "expected '{'")?;
        let mut entries = Vec::new();
        loop {
            let t = self.peek().clone();
            match t.tok {
                Tok::RBrace => {
                    self.bump();
                    return Ok(Block { entries, span: open.span });
                }
                Tok::Eof => {
                    return err(t.span, format!(
                        "unclosed block (opened at {})", open.span));
                }
                Tok::Ident(_) => entries.push(self.entry()?),
                other => {
                    return err(t.span, format!(
                        "expected a key or '}}', found {}",
                        other.describe()));
                }
            }
        }
    }

    fn entry(&mut self) -> Result<Entry, SpecError> {
        let key = self.ident("expected a key")?;
        match self.peek().tok {
            Tok::Eq => {
                self.bump();
                let value = self.value()?;
                Ok(Entry::Assign(Assign { key, value }))
            }
            Tok::LBrace => {
                let body = self.block()?;
                Ok(Entry::Block(NamedBlock { name: key, body }))
            }
            // Next token starts another entry or closes the block: the
            // key stands alone as a marker.
            _ => Ok(Entry::Marker(key)),
        }
    }

    fn value(&mut self) -> Result<Value, SpecError> {
        if self.peek().tok == Tok::LBracket {
            let open = self.bump();
            let mut items = Vec::new();
            loop {
                if self.peek().tok == Tok::RBracket {
                    self.bump();
                    return Ok(Value::List { items, span: open.span });
                }
                items.push(self.scalar()?);
                match self.peek().tok {
                    Tok::Comma => {
                        self.bump();
                    }
                    Tok::RBracket => {}
                    _ => {
                        let t = self.peek();
                        return err(t.span, format!(
                            "expected ',' or ']' in the list, found {}",
                            t.tok.describe()));
                    }
                }
            }
        }
        Ok(Value::Scalar(self.scalar()?))
    }

    fn scalar(&mut self) -> Result<Scalar, SpecError> {
        let t = self.bump();
        match t.tok {
            Tok::Num { text, value } => {
                Ok(Scalar::Num(NumLit { text, value, span: t.span }))
            }
            Tok::Str(value) => Ok(Scalar::Str(StrLit { value, span: t.span })),
            Tok::Ident(text) => Ok(Scalar::Word(Ident { text, span: t.span })),
            other => err(t.span, format!(
                "expected a value (number, string, word or list), \
                 found {}",
                other.describe())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::diag::Span;

    #[test]
    fn parses_nested_blocks_and_all_value_kinds() {
        let src = "\
experiment fig4 {
  seed = 42
  out = \"results\"
  model {
    arch = mlp
    widths = [0.5, 1.0]
    layers {
      dense { out = 4 }
      relu
    }
  }
}
";
        let ast = parse(src).unwrap();
        assert_eq!(ast.kind.text, "fig4");
        assert_eq!(ast.body.entries.len(), 3);
        let Entry::Block(model) = &ast.body.entries[2] else {
            panic!("expected model block");
        };
        assert_eq!(model.name.text, "model");
        assert_eq!(model.body.entries.len(), 3);
        let Entry::Assign(widths) = &model.body.entries[1] else {
            panic!("expected widths assign");
        };
        let Value::List { items, .. } = &widths.value else {
            panic!("expected list");
        };
        assert_eq!(items.len(), 2);
        let Entry::Block(layers) = &model.body.entries[2] else {
            panic!("expected layers block");
        };
        assert!(matches!(&layers.body.entries[1],
                         Entry::Marker(m) if m.text == "relu"));
    }

    #[test]
    fn trailing_comma_in_list_is_fine() {
        let ast = parse("experiment fig4 { widths = [1, 2,] }").unwrap();
        let Entry::Assign(a) = &ast.body.entries[0] else { panic!() };
        let Value::List { items, .. } = &a.value else { panic!() };
        assert_eq!(items.len(), 2);
    }

    #[test]
    fn missing_experiment_header() {
        let e = parse("fig4 { }").unwrap_err();
        assert_eq!(e.span, Span::new(1, 1));
        assert!(e.msg.contains("expected 'experiment'"), "{e}");
    }

    #[test]
    fn unclosed_block_points_at_the_open_brace() {
        let e = parse("experiment fig4 {\n  seed = 1\n").unwrap_err();
        assert!(e.msg.contains("unclosed block (opened at 1:17)"), "{e}");
    }

    #[test]
    fn stray_value_token_is_spanned() {
        let e = parse("experiment fig4 { seed = }").unwrap_err();
        assert_eq!(e.span, Span::new(1, 26));
        assert!(e.msg.contains("expected a value"), "{e}");
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let e = parse("experiment fig4 { } extra").unwrap_err();
        assert_eq!(e.span, Span::new(1, 21));
        assert!(e.msg.contains("expected end of file"), "{e}");
    }

    #[test]
    fn list_separator_error_is_spanned() {
        let e = parse("experiment fig4 { w = [1 2] }").unwrap_err();
        assert!(e.msg.contains("expected ',' or ']'"), "{e}");
        assert_eq!(e.span, Span::new(1, 26));
    }
}
